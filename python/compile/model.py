"""L2 — NITRO-D integer block graphs (JAX, build-time only).

This module defines the *integer local-loss block* computations of the paper
(§3.2, §3.3) as pure JAX functions over int32/int64 tensors:

  * ``conv_block_forward`` / ``linear_block_forward`` — the forward layers
    (Integer Conv2D/Linear -> NITRO Scaling -> NITRO-ReLU -> [MaxPool]).
  * ``conv_block_train`` / ``linear_block_train`` — one full local training
    step: forward, learning layers (adaptive pool -> flatten -> Integer
    Linear -> NITRO scaling), RSS loss, manual integer backward (autodiff is
    useless in Z — every gradient rule is written out), IntegerSGD updates
    with the NITRO Amplification Factor on the forward layers.
  * ``head_train`` / ``head_forward`` — the network output layers.
  * ``network_infer`` — whole-network integer inference.

``use_pallas=True`` routes the hot contractions through the L1 Pallas
kernels (which lower to plain HLO under interpret mode and therefore AOT-
export cleanly); ``use_pallas=False`` uses the pure-jnp reference ops. Both
paths are bit-identical — asserted by python/tests and by the golden-vector
cross-check against the Rust engine.

Runtime scalars (learning rate, decay rates) are graph *inputs* (s64[]), so
the Rust coordinator can anneal the learning rate without re-AOT. Topology
constants (SF, alpha_inv, mu, AF, d_lr) are baked in.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax.numpy as jnp

from .kernels import ref
from .kernels import int_matmul as k_mm
from .kernels import int_conv2d as k_conv
from .kernels import nitro_ops as k_nitro

I32 = jnp.int32
I64 = jnp.int64

DEFAULT_ALPHA_INV = 10  # LeakyReLU slope 0.1 -> alpha_inv = floor(1/0.1)


# ---------------------------------------------------------------------------
# block specifications
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ConvBlockSpec:
    """One integer convolutional local-loss block."""
    in_channels: int
    out_channels: int
    in_h: int
    in_w: int
    kernel: int = 3
    padding: int = 1
    pool: bool = False            # 2x2/s2 MaxPool after the activation
    alpha_inv: int = DEFAULT_ALPHA_INV
    d_lr: int = 4096              # learning-layers input features (paper 4.3)
    num_classes: int = 10

    @property
    def out_h(self) -> int:
        h = self.in_h + 2 * self.padding - self.kernel + 1
        return h // 2 if self.pool else h

    @property
    def out_w(self) -> int:
        w = self.in_w + 2 * self.padding - self.kernel + 1
        return w // 2 if self.pool else w

    @property
    def sf(self) -> int:
        return ref.scale_factor_conv(self.kernel, self.in_channels)

    @property
    def lr_pool(self) -> Tuple[int, int, int]:
        """(target s, pool kernel, kept s) for the learning-layer adaptive
        max-pool: s = max(1, isqrt(d_lr / C_out)) clamped to the feature
        map; windows are k x k non-overlapping, k = floor(H/s); remainder
        rows/cols are discarded (zero gradient)."""
        s = max(1, ref.isqrt(max(1, self.d_lr // self.out_channels)))
        s = min(s, self.out_h, self.out_w)
        k = min(self.out_h, self.out_w) // s
        return s, k, s

    @property
    def lr_features(self) -> int:
        s, _, _ = self.lr_pool
        return self.out_channels * s * s

    def weight_shapes(self):
        wf = (self.out_channels, self.in_channels, self.kernel, self.kernel)
        wl = (self.lr_features, self.num_classes)
        return wf, wl

    @property
    def fan_in(self) -> int:
        return self.in_channels * self.kernel * self.kernel


@dataclass(frozen=True)
class LinearBlockSpec:
    """One integer linear (fully-connected) local-loss block."""
    in_features: int
    out_features: int
    alpha_inv: int = DEFAULT_ALPHA_INV
    num_classes: int = 10

    @property
    def sf(self) -> int:
        return ref.scale_factor_linear(self.in_features)

    def weight_shapes(self):
        return (self.in_features, self.out_features), \
               (self.out_features, self.num_classes)

    @property
    def lr_features(self) -> int:
        return self.out_features

    @property
    def fan_in(self) -> int:
        return self.in_features


@dataclass(frozen=True)
class HeadSpec:
    """The network output layers: Integer Linear -> NITRO scaling."""
    in_features: int
    num_classes: int = 10

    @property
    def sf(self) -> int:
        return ref.scale_factor_linear(self.in_features)

    def weight_shape(self):
        return (self.in_features, self.num_classes)

    @property
    def fan_in(self) -> int:
        return self.in_features


@dataclass(frozen=True)
class NetworkSpec:
    """A full NITRO-D network: local-loss blocks + output head."""
    name: str
    input_shape: Tuple[int, ...]            # (C, H, W) or (F,)
    blocks: Tuple = field(default_factory=tuple)
    head: Optional[HeadSpec] = None
    num_classes: int = 10


# ---------------------------------------------------------------------------
# op dispatch (pallas kernels vs jnp reference)
# ---------------------------------------------------------------------------

def _matmul(a, w, use_pallas: bool):
    if use_pallas:
        return k_mm.int_matmul(a, w)
    return ref.int_matmul(a, w)


def _conv(x, w, spec: ConvBlockSpec, use_pallas: bool):
    if use_pallas:
        return k_conv.int_conv2d(x, w, kernel=spec.kernel,
                                 padding=spec.padding)
    return ref.int_conv2d(x, w, padding=spec.padding)


def _scale_relu(z, sf: int, alpha_inv: int, use_pallas: bool):
    if use_pallas:
        return k_nitro.nitro_scale_relu(z, sf=sf, alpha_inv=alpha_inv)
    return ref.nitro_relu(ref.nitro_scale(z, sf), alpha_inv).astype(I32)


def _scale_only(z, sf: int, use_pallas: bool):
    if use_pallas:
        return k_nitro.nitro_scale(z, sf=sf)
    return ref.nitro_scale(z, sf).astype(I32)


# ---------------------------------------------------------------------------
# learning layers (shared by conv blocks; linear blocks use features direct)
# ---------------------------------------------------------------------------

def _learning_forward(feat, wl, use_pallas: bool):
    """feat: (B, F) int32, wl: (F, G) int32 -> yhat (B, G) int32.
    The trailing NITRO scaling keeps yhat in the one-hot magnitude regime
    (|yhat| <~ 64), which is what makes b_grad ~ 6 bits as the paper's AF
    analysis assumes (DESIGN.md interp. #3)."""
    zl = _matmul(feat, wl, use_pallas)                 # (B, G) i64
    return _scale_only(zl, ref.scale_factor_linear(feat.shape[1]),
                       use_pallas)


def _learning_backward(feat, wl, grad_l, gamma_lr, eta_lr, use_pallas: bool):
    """Update the learning-layer weights and return the gradient delta^fw
    propagated into the forward layers (through the scaling STE).

    feat: (B, F) i32; grad_l: (B, G) i32; returns (wl', dfeat (B, F) i32).
    """
    gw = _matmul(feat.T, grad_l, use_pallas)           # (F, G) i64
    dfeat = _matmul(grad_l, wl.T, use_pallas)          # (B, F) i64
    wl2 = ref.integer_sgd(wl, gw, gamma_lr, eta_lr)
    return wl2, dfeat.astype(I32)


# ---------------------------------------------------------------------------
# adaptive max-pool for conv-block learning layers
# ---------------------------------------------------------------------------

def _adaptive_pool(x, spec: ConvBlockSpec):
    """x: (B, C, H, W) -> (feat (B, C*s*s), argmax, pooled_shape)."""
    s, k, _ = spec.lr_pool
    if k <= 1 and x.shape[2] == s and x.shape[3] == s:
        b = x.shape[0]
        return x.reshape(b, -1), None, x.shape
    pooled, arg = ref.maxpool2d(x, size=k, stride=k)
    pooled = pooled[:, :, :s, :s]
    arg = arg[:, :, :s, :s]
    b = x.shape[0]
    return pooled.reshape(b, -1), arg, (b, x.shape[1], s, s)


def _adaptive_pool_bwd(dfeat, arg, pooled_shape, in_shape,
                       spec: ConvBlockSpec):
    s, k, _ = spec.lr_pool
    g = dfeat.reshape(pooled_shape)
    if arg is None:
        return g.reshape(in_shape)
    b, c, h, w = in_shape
    ho, wo = h // k if k else s, w // k if k else s
    # re-embed the kept s x s windows into the full floor(H/k) grid
    gfull = jnp.zeros((b, c, (h - k) // k + 1, (w - k) // k + 1),
                      dtype=g.dtype)
    gfull = gfull.at[:, :, :s, :s].set(g)
    argfull = jnp.zeros(gfull.shape, dtype=arg.dtype)
    argfull = argfull.at[:, :, :s, :s].set(arg)
    return ref.maxpool2d_bwd(gfull, argfull, in_shape, size=k, stride=k)


# ---------------------------------------------------------------------------
# conv block
# ---------------------------------------------------------------------------

def conv_block_forward(a, wf, spec: ConvBlockSpec, use_pallas: bool = False,
                       want_intermediates: bool = False):
    """Forward layers of a conv block. a: (B, C, H, W) i32 -> a_out i32."""
    z = _conv(a, wf, spec, use_pallas)                      # i64
    zs = ref.nitro_scale(z, spec.sf).astype(I32)            # scaled pre-act
    act = (ref.nitro_relu(zs, spec.alpha_inv)).astype(I32) \
        if not use_pallas else \
        k_nitro.nitro_scale_relu(z, sf=spec.sf, alpha_inv=spec.alpha_inv)
    arg = None
    out = act
    if spec.pool:
        out, arg = ref.maxpool2d(act, size=2, stride=2)
    if want_intermediates:
        return out, (zs, act.shape, arg)
    return out


def conv_block_train(a, wf, wl, y32, gamma_lr, eta_fw, eta_lr,
                     spec: ConvBlockSpec, use_pallas: bool = False):
    """One integer local training step of a conv block.

    Returns (a_out, wf', wl', loss_sum). Gradients never leave the block
    (LES); forward-layer updates use gamma_fw_inv = gamma_lr_inv * AF.
    """
    a_out, (zs, act_shape, pool_arg) = conv_block_forward(
        a, wf, spec, use_pallas, want_intermediates=True)

    feat, lr_arg, pooled_shape = _adaptive_pool(a_out, spec)
    yhat = _learning_forward(feat, wl, use_pallas)
    loss, grad_l = ref.rss_loss_grad(yhat, y32)
    wl2, dfeat = _learning_backward(feat, wl, grad_l, gamma_lr, eta_lr,
                                    use_pallas)

    # delta^fw: back through adaptive pool -> block maxpool -> NITRO-ReLU
    # -> scaling STE -> conv weight grad.
    d = _adaptive_pool_bwd(dfeat, lr_arg, pooled_shape, a_out.shape, spec)
    if spec.pool:
        d = ref.maxpool2d_bwd(d, pool_arg, act_shape, size=2, stride=2)
    d = ref.nitro_relu_bwd(zs, d, spec.alpha_inv)           # i32
    # scaling layer backward = STE (identity)
    if use_pallas:
        patches = ref.im2col(a, spec.kernel, spec.padding)  # (B, P, CKK)
        b, p, ckk = patches.shape
        gmat = d.reshape(b, spec.out_channels, p)
        g2 = jnp.transpose(gmat, (1, 0, 2)).reshape(spec.out_channels, b * p)
        p2 = patches.reshape(b * p, ckk)
        gw = k_mm.int_matmul(g2, p2).reshape(wf.shape)      # i64
    else:
        gw = ref.conv2d_weight_grad(a, d, spec.kernel, spec.padding)

    af = ref.amplification_factor(spec.num_classes)
    gamma_fw = gamma_lr.astype(I64) * af if hasattr(gamma_lr, "astype") \
        else gamma_lr * af
    wf2 = ref.integer_sgd(wf, gw, gamma_fw, eta_fw)
    return a_out, wf2, wl2, loss


# ---------------------------------------------------------------------------
# linear block
# ---------------------------------------------------------------------------

def linear_block_forward(a, wf, spec: LinearBlockSpec,
                         use_pallas: bool = False,
                         want_intermediates: bool = False):
    """a: (B, M) i32, wf: (M, N) i32 -> a_out (B, N) i32."""
    z = _matmul(a, wf, use_pallas)                          # i64
    zs = ref.nitro_scale(z, spec.sf).astype(I32)
    out = (ref.nitro_relu(zs, spec.alpha_inv)).astype(I32) \
        if not use_pallas else \
        k_nitro.nitro_scale_relu(z, sf=spec.sf, alpha_inv=spec.alpha_inv)
    if want_intermediates:
        return out, zs
    return out


def linear_block_train(a, wf, wl, y32, gamma_lr, eta_fw, eta_lr,
                       spec: LinearBlockSpec, use_pallas: bool = False):
    """One integer local training step of a linear block."""
    a_out, zs = linear_block_forward(a, wf, spec, use_pallas,
                                     want_intermediates=True)
    yhat = _learning_forward(a_out, wl, use_pallas)
    loss, grad_l = ref.rss_loss_grad(yhat, y32)
    wl2, dfeat = _learning_backward(a_out, wl, grad_l, gamma_lr, eta_lr,
                                    use_pallas)
    d = ref.nitro_relu_bwd(zs, dfeat, spec.alpha_inv)
    gw = _matmul(a.T, d, use_pallas)                        # (M, N) i64
    af = ref.amplification_factor(spec.num_classes)
    gamma_fw = gamma_lr.astype(I64) * af if hasattr(gamma_lr, "astype") \
        else gamma_lr * af
    wf2 = ref.integer_sgd(wf, gw, gamma_fw, eta_fw)
    return a_out, wf2, wl2, loss


# ---------------------------------------------------------------------------
# output head
# ---------------------------------------------------------------------------

def head_forward(a, wo, spec: HeadSpec, use_pallas: bool = False):
    """a: (B, F) i32 -> yhat (B, G) i32 (NITRO-scaled logits)."""
    z = _matmul(a, wo, use_pallas)
    return _scale_only(z, spec.sf, use_pallas)


def head_train(a, wo, y32, gamma_lr, eta_lr, spec: HeadSpec,
               use_pallas: bool = False):
    """Output-layer step: the head receives the global loss gradient
    directly (no amplification — it plays the learning-layer role)."""
    yhat = head_forward(a, wo, spec, use_pallas)
    loss, grad = ref.rss_loss_grad(yhat, y32)
    gw = _matmul(a.T, grad, use_pallas)
    wo2 = ref.integer_sgd(wo, gw, gamma_lr, eta_lr)
    return yhat, wo2, loss


# ---------------------------------------------------------------------------
# whole networks
# ---------------------------------------------------------------------------

def network_infer(x, weights: List, spec: NetworkSpec,
                  use_pallas: bool = False):
    """Integer-only inference through all blocks + head.

    weights: [wf_0, wf_1, ..., wf_{L-1}, wo] (learning layers are unused at
    inference — the paper's App. E.3 memory-saving note).
    """
    a = x
    for i, blk in enumerate(spec.blocks):
        if isinstance(blk, ConvBlockSpec):
            a = conv_block_forward(a, weights[i], blk, use_pallas)
        else:
            if a.ndim > 2:
                a = a.reshape(a.shape[0], -1)
            a = linear_block_forward(a, weights[i], blk, use_pallas)
    if a.ndim > 2:
        a = a.reshape(a.shape[0], -1)
    return head_forward(a, weights[-1], spec.head, use_pallas)


# ---------------------------------------------------------------------------
# model zoo (paper App. C) — mirrored by rust/src/nn/zoo.rs
# ---------------------------------------------------------------------------

def mlp_spec(name: str, dims: List[int], num_classes: int = 10,
             input_dim: int = 784) -> NetworkSpec:
    blocks = []
    prev = input_dim
    for d in dims:
        blocks.append(LinearBlockSpec(prev, d, num_classes=num_classes))
        prev = d
    return NetworkSpec(name=name, input_shape=(input_dim,),
                       blocks=tuple(blocks),
                       head=HeadSpec(prev, num_classes),
                       num_classes=num_classes)


def cnn_spec(name: str, plan: List, in_shape=(3, 32, 32),
             num_classes: int = 10, d_lr: int = 4096) -> NetworkSpec:
    """plan entries: ('C', out_ch) conv block, ('CP', out_ch) conv+pool
    block, ('L', features) linear block."""
    c, h, w = in_shape
    blocks = []
    for kind, n in plan:
        if kind in ("C", "CP"):
            blk = ConvBlockSpec(c, n, h, w, pool=(kind == "CP"),
                                d_lr=d_lr, num_classes=num_classes)
            c, h, w = n, blk.out_h, blk.out_w
            blocks.append(blk)
        elif kind == "L":
            blocks.append(LinearBlockSpec(c * h * w, n,
                                          num_classes=num_classes))
            c, h, w = n, 1, 1
    return NetworkSpec(name=name, input_shape=in_shape,
                       blocks=tuple(blocks),
                       head=HeadSpec(c * h * w, num_classes),
                       num_classes=num_classes)


ZOO = {
    # paper App. C, exact
    "mlp1": lambda: mlp_spec("mlp1", [100, 50]),
    "mlp2": lambda: mlp_spec("mlp2", [200, 100, 50]),
    "mlp3": lambda: mlp_spec("mlp3", [1024, 1024, 1024]),
    "mlp4": lambda: mlp_spec("mlp4", [3000, 3000, 3000], input_dim=3072),
    "vgg8b": lambda: cnn_spec("vgg8b", [
        ("C", 128), ("CP", 256), ("C", 256), ("CP", 512), ("CP", 512),
        ("CP", 512), ("L", 1024)]),
    "vgg11b": lambda: cnn_spec("vgg11b", [
        ("C", 128), ("C", 128), ("C", 128), ("CP", 256), ("C", 256),
        ("CP", 512), ("C", 512), ("CP", 512), ("CP", 512), ("L", 1024)]),
    # CPU-budget presets (DESIGN.md §Substitutions): same topology family
    "tinycnn": lambda: cnn_spec("tinycnn", [
        ("CP", 8), ("CP", 16), ("L", 32)], in_shape=(1, 8, 8), d_lr=64),
    "mlp1-mini": lambda: mlp_spec("mlp1-mini", [32, 16], input_dim=64),
    "vgg8b-narrow": lambda: cnn_spec("vgg8b-narrow", [
        ("C", 32), ("CP", 64), ("C", 64), ("CP", 128), ("CP", 128),
        ("CP", 128), ("L", 256)], in_shape=(3, 32, 32), d_lr=1024),
}


def init_network(spec: NetworkSpec, seed: int = 0):
    """Integer Kaiming init (paper App. B.1) of all block forward weights,
    learning-layer weights and the head. Returns (fwd_weights, lr_weights,
    head_weight) as numpy int32 arrays. Mirrors rust nn::init exactly
    (same PCG32 stream — see aot.py golden generation)."""
    import numpy as np
    rng = np.random.RandomState(seed)
    fwd, lrw = [], []
    for blk in spec.blocks:
        wf_shape, wl_shape = blk.weight_shapes()
        fwd.append(ref.init_weights(rng, wf_shape, blk.fan_in))
        lrw.append(ref.init_weights(rng, wl_shape, wl_shape[0]))
    wo = ref.init_weights(rng, spec.head.weight_shape(), spec.head.fan_in)
    return fwd, lrw, wo
