"""Pallas integer conv2d kernel (L1).

The paper's Integer Conv2D: 3x3, stride 1, padding 1, no bias, integer
weights/activations. TPU mapping: the grid walks samples; each step stages
one padded image plus the (O, C, K, K) weights into VMEM and contracts the
K*K shifted copies against the weight matrix on the MXU (an in-VMEM im2col —
Pallas BlockSpecs cannot express overlapping windows, so the shift happens
inside the kernel where the whole image is resident).

Under ``interpret=True`` (this image) the kernel lowers to plain HLO.
Bit-exact against ``ref.int_conv2d`` (pytest + hypothesis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref  # noqa: F401

I64 = jnp.int64


def _conv_sample_kernel(xp_ref, w_ref, o_ref, *, kernel: int,
                        ho: int, wo: int):
    """One grid step = one sample.

    xp_ref: (1, C, Hp, Wp) int32 (zero-padded input image)
    w_ref:  (O, C, K, K) int32
    o_ref:  (1, O, Ho, Wo) int64
    """
    xp = xp_ref[0].astype(I64)            # (C, Hp, Wp)
    w = w_ref[...].astype(I64)            # (O, C, K, K)
    k = kernel
    shifts = []
    for ki in range(k):
        for kj in range(k):
            shifts.append(xp[:, ki:ki + ho, kj:kj + wo])
    # (C, K*K, Ho, Wo) with (ki, kj) row-major — same patch layout as ref.
    patches = jnp.stack(shifts, axis=1)
    lhs = w.reshape(w.shape[0], -1)                         # (O, C*K*K)
    rhs = patches.reshape(-1, ho * wo)                      # (C*K*K, Ho*Wo)
    out = jax.lax.dot_general(
        lhs, rhs, (((1,), (0,)), ((), ())), preferred_element_type=I64
    )
    o_ref[...] = out.reshape(1, w.shape[0], ho, wo)


@functools.partial(jax.jit, static_argnames=("kernel", "padding"))
def int_conv2d(x, w, kernel: int = 3, padding: int = 1):
    """Integer conv2d via the Pallas per-sample kernel.

    x: (B, C, H, W) int32, w: (O, C, K, K) int32 -> (B, O, Ho, Wo) int64.
    Stride 1 (the only stride the paper's architectures use).
    """
    b, c, h, wd = x.shape
    o = w.shape[0]
    k = kernel
    ho, wo = h + 2 * padding - k + 1, wd + 2 * padding - k + 1
    hp, wp = h + 2 * padding, wd + 2 * padding
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    return pl.pallas_call(
        functools.partial(_conv_sample_kernel, kernel=k, ho=ho, wo=wo),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, c, hp, wp), lambda n: (n, 0, 0, 0)),
            pl.BlockSpec((o, c, k, k), lambda n: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, o, ho, wo), lambda n: (n, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, o, ho, wo), I64),
        interpret=True,
    )(xp, w)


def vmem_footprint_bytes(c: int, o: int, k: int, h: int, w_in: int,
                         pad: int) -> int:
    """VMEM estimate for one grid step: padded image + weights + int64
    output (EXPERIMENTS.md §Perf feeds on this)."""
    hp, wp = h + 2 * pad, w_in + 2 * pad
    ho, wo = h + 2 * pad - k + 1, w_in + 2 * pad - k + 1
    return 4 * (c * hp * wp) + 4 * (o * c * k * k) + 8 * (o * ho * wo)
