"""Pallas fused NITRO scale + NITRO-ReLU kernel (L1).

This is the epilogue the TPU mapping fuses after the MXU contraction: one
pass over the int64 pre-activation tile while it is still in VMEM —
floor-divide by the analytic scale factor SF, clamp to [-127, 127], apply
the leaky integer segment, subtract the pre-computed mean mu.

SF, alpha_inv and mu are *static* per layer (they depend only on topology),
so they are baked into the lowered HLO as constants — exactly what a real
deployment would do.

Bit-exact against ``ref.nitro_relu(ref.nitro_scale(z, sf), alpha_inv)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

I32 = jnp.int32
I64 = jnp.int64


def _scale_relu_kernel(z_ref, o_ref, *, sf: int, alpha_inv: int, mu: int):
    z = z_ref[...]
    zs = jnp.floor_divide(z, jnp.asarray(sf, z.dtype))
    neg = jnp.floor_divide(
        jnp.maximum(zs, -ref.INT8_MAX), jnp.asarray(alpha_inv, z.dtype)
    )
    pos = jnp.minimum(zs, ref.INT8_MAX)
    o_ref[...] = (jnp.where(zs < 0, neg, pos) - mu).astype(I32)


@functools.partial(jax.jit, static_argnames=("sf", "alpha_inv"))
def nitro_scale_relu(z, sf: int, alpha_inv: int):
    """Fused NITRO Scaling Layer + NITRO-ReLU.

    z: int64 pre-activations (any rank >= 2, leading dim = batch)
    -> int32 activations, zero-centered, ~int8 range.
    """
    mu = ref.nitro_relu_mu(alpha_inv)
    flat = z.reshape(z.shape[0], -1)
    b, f = flat.shape
    out = pl.pallas_call(
        functools.partial(_scale_relu_kernel, sf=sf, alpha_inv=alpha_inv,
                          mu=mu),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, f), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, f), I32),
        interpret=True,
    )(flat)
    return out.reshape(z.shape)


def _scale_only_kernel(z_ref, o_ref, *, sf: int):
    o_ref[...] = jnp.floor_divide(
        z_ref[...], jnp.asarray(sf, z_ref.dtype)
    ).astype(I32)


@functools.partial(jax.jit, static_argnames=("sf",))
def nitro_scale(z, sf: int):
    """NITRO Scaling Layer alone (used on learning-layer / output heads,
    which have no activation function after the final linear)."""
    flat = z.reshape(z.shape[0], -1)
    b, f = flat.shape
    out = pl.pallas_call(
        functools.partial(_scale_only_kernel, sf=sf),
        grid=(b,),
        in_specs=[pl.BlockSpec((1, f), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, f), I32),
        interpret=True,
    )(flat)
    return out.reshape(z.shape)
