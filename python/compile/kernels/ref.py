"""Pure-jnp integer-only reference ops — the correctness oracle for NITRO-D.

Every operation here is defined over integer tensors with *floor-division*
semantics (rounding toward -inf, like Python ``//``). These functions are the
single source of truth for the numeric format:

  * Pallas kernels (``int_matmul.py``, ``int_conv2d.py``, ``nitro_ops.py``)
    are tested bit-exactly against them (pytest + hypothesis).
  * The Rust NativeEngine replicates them and is tested bit-exactly against
    golden vectors generated from this module (``aot.py --golden``).

Accumulation rule (DESIGN.md §Numeric-format rules): contractions (matmul,
conv, gradient reductions) are performed in int64, then rescaled by an
integer floor-division, then stored as int32. Intermediates that the paper
guarantees to fit int32 are checked by ``assert_int32`` in debug paths.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

I32 = jnp.int32
I64 = jnp.int64

INT8_MAX = 127
ONE_HOT_VALUE = 32  # paper App. B.2: one-hot encoding uses 32, not 1


# ---------------------------------------------------------------------------
# primitive integer ops
# ---------------------------------------------------------------------------

def div_floor(x, d):
    """Floor division toward -inf. ``d`` may be a scalar or array (> 0)."""
    return jnp.floor_divide(x, d)


def int_matmul(a, w):
    """Integer matmul with int64 accumulation.

    a: (B, M) int32, w: (M, N) int32  ->  (B, N) int64.
    The caller rescales (NITRO scaling / learning-rate division) before
    casting back down to int32.
    """
    return jnp.matmul(a.astype(I64), w.astype(I64))


def im2col(x, kernel: int, padding: int):
    """Extract KxK patches (stride 1) of an NCHW int tensor.

    x: (B, C, H, W)  ->  (B, H_out * W_out, C * K * K)

    Patch layout is (c, ki, kj) row-major — the Rust engine and the Pallas
    conv kernel use the identical layout so weight gradients match
    bit-exactly.
    """
    b, c, h, w = x.shape
    k = kernel
    xp = jnp.pad(x, ((0, 0), (0, 0), (padding, padding), (padding, padding)))
    ho, wo = h + 2 * padding - k + 1, w + 2 * padding - k + 1
    cols = []
    for ki in range(k):
        for kj in range(k):
            cols.append(xp[:, :, ki:ki + ho, kj:kj + wo])
    # (K*K, B, C, Ho*Wo) -> (B, Ho*Wo, C, K*K) with (c, ki, kj) row-major
    stacked = jnp.stack(cols, axis=0).reshape(k * k, b, c, ho * wo)
    patches = jnp.transpose(stacked, (1, 3, 2, 0))  # (B, Ho*Wo, C, K*K)
    return patches.reshape(b, ho * wo, c * k * k)


def int_conv2d(x, w, padding: int = 1):
    """Integer 2D convolution (cross-correlation), stride 1, int64 accum.

    x: (B, C, H, W) int32, w: (O, C, K, K) int32 -> (B, O, Ho, Wo) int64.
    """
    b, c, h, wd = x.shape
    o, _, k, _ = w.shape
    ho, wo = h + 2 * padding - k + 1, wd + 2 * padding - k + 1
    patches = im2col(x, k, padding)                       # (B, P, CKK)
    wmat = w.reshape(o, c * k * k).T                      # (CKK, O)
    z = jnp.matmul(patches.astype(I64), wmat.astype(I64))  # (B, P, O)
    return jnp.transpose(z, (0, 2, 1)).reshape(b, o, ho, wo)


def conv2d_input_grad(g, w, padding: int = 1):
    """Gradient of int_conv2d wrt its input (correlation with flipped,
    transposed kernels). g: (B, O, Ho, Wo), w: (O, C, K, K) -> (B, C, H, W)
    int64 (stride-1, same-size case)."""
    k = w.shape[2]
    wflip = jnp.flip(jnp.flip(w, 2), 3)            # (O, C, K, K)
    wt = jnp.transpose(wflip, (1, 0, 2, 3))        # (C, O, K, K)
    return int_conv2d(g, wt, padding=k - 1 - padding)


def conv2d_weight_grad(x, g, kernel: int, padding: int = 1):
    """Gradient of int_conv2d wrt weights.

    x: (B, C, H, W), g: (B, O, Ho, Wo) -> (O, C, K, K) int64, summed over
    the batch (integer mean would truncate; DESIGN.md interpretation #4).
    """
    b, c, _, _ = x.shape
    o = g.shape[1]
    patches = im2col(x, kernel, padding)           # (B, P, CKK)
    gmat = g.reshape(b, o, -1)                     # (B, O, P)
    gw = jnp.einsum(
        "bop,bpk->ok", gmat.astype(I64), patches.astype(I64)
    )                                              # (O, CKK)
    return gw.reshape(o, c, kernel, kernel)


def maxpool2d(x, size: int = 2, stride: int = 2):
    """Max pooling, NCHW. Returns (pooled, argmax_index) where argmax_index
    in [0, size*size) is the *first* maximal element in (ki, kj) row-major
    order — the tie-break every engine must replicate."""
    b, c, h, w = x.shape
    ho, wo = (h - size) // stride + 1, (w - size) // stride + 1
    wins = []
    for ki in range(size):
        for kj in range(size):
            wins.append(
                x[:, :, ki:ki + stride * ho:stride, kj:kj + stride * wo:stride]
            )
    stacked = jnp.stack(wins, axis=0)              # (S*S, B, C, Ho, Wo)
    pooled = jnp.max(stacked, axis=0)
    arg = jnp.argmax(stacked, axis=0).astype(I32)  # first max wins
    return pooled, arg


def maxpool2d_bwd(g, arg, in_shape, size: int = 2, stride: int = 2):
    """Scatter gradient to the argmax positions recorded by maxpool2d."""
    b, c, h, w = in_shape
    ho, wo = g.shape[2], g.shape[3]
    sel = jax.nn.one_hot(arg, size * size, axis=0, dtype=g.dtype)
    routed = sel * g[None]                         # (S*S, B, C, Ho, Wo)
    full = jnp.zeros((b, c, h, w), dtype=g.dtype)
    idx = 0
    for ki in range(size):
        for kj in range(size):
            full = full.at[
                :, :, ki:ki + stride * ho:stride, kj:kj + stride * wo:stride
            ].add(routed[idx])
            idx += 1
    return full


# ---------------------------------------------------------------------------
# NITRO components (paper §3.2)
# ---------------------------------------------------------------------------

def scale_factor_linear(fan_in: int) -> int:
    """SF for Integer Linear pre-activations: 2^8 * M_{l-1}."""
    return 256 * fan_in


def scale_factor_conv(kernel: int, in_channels: int) -> int:
    """SF for Integer Conv2D pre-activations: 2^8 * K^2 * C_{l-1}."""
    return 256 * kernel * kernel * in_channels


def nitro_scale(z, sf: int):
    """NITRO Scaling Layer forward: z* = floor(z / SF). Backward is the
    straight-through estimator (identity), handled by callers."""
    return div_floor(z, sf)


def nitro_relu_mu(alpha_inv: int) -> int:
    """Pre-computed integer mean of the 4-segment NITRO-ReLU (paper §3.2).

    mu^0 = floor(-127/a), mu^1 = floor(-127/(2a)), mu^2 = 63, mu^3 = 127;
    mu = floor(mean(mu^i)) — all with floor semantics.
    """
    mu0 = -INT8_MAX // alpha_inv          # python // floors
    mu1 = -INT8_MAX // (2 * alpha_inv)
    mu2 = 63
    mu3 = INT8_MAX
    return (mu0 + mu1 + mu2 + mu3) // 4


def nitro_relu(x, alpha_inv: int):
    """NITRO-ReLU forward. Input: scaled pre-activations (int). Output is
    confined to ~int8 range and zero-centered by the pre-computed mu."""
    mu = nitro_relu_mu(alpha_inv)
    neg = div_floor(jnp.maximum(x, -INT8_MAX), alpha_inv)
    pos = jnp.minimum(x, INT8_MAX)
    return jnp.where(x < 0, neg, pos) - mu


def nitro_relu_bwd(x, g, alpha_inv: int):
    """Exact piecewise derivative of the 4 segments (DESIGN.md interp. #2):
    clamped segments have zero slope; the leaky segment floor-divides the
    incoming gradient by alpha_inv. ``x`` is the *pre*-activation input that
    was fed to nitro_relu (i.e. the scaling-layer output)."""
    zero = jnp.zeros_like(g)
    return jnp.where(
        x < -INT8_MAX,
        zero,
        jnp.where(x < 0, div_floor(g, alpha_inv),
                  jnp.where(x <= INT8_MAX, g, zero)),
    )


# ---------------------------------------------------------------------------
# loss / labels / optimizer (paper §3.3)
# ---------------------------------------------------------------------------

def one_hot32(y, num_classes: int):
    """One-hot with value 32 for the true class (paper App. B.2)."""
    return (jax.nn.one_hot(y, num_classes, dtype=I32) * ONE_HOT_VALUE).astype(I32)


def rss_loss_grad(yhat, y32):
    """RSS loss L = 1/2 sum (yhat - y)^2 ; grad = yhat - y. Returns
    (loss_sum int64 scalar, grad int32)."""
    d = yhat.astype(I64) - y32.astype(I64)
    loss = jnp.sum(d * d) // 2
    return loss, d.astype(I32)


def amplification_factor(num_classes: int) -> int:
    """NITRO Amplification Factor AF = 2^6 * G (paper §3.3)."""
    return 64 * num_classes


def div_trunc(x, d):
    """Division truncating toward zero (C semantics)."""
    ax = jnp.abs(x)
    return jnp.sign(x) * jnp.floor_divide(ax, d)


def integer_sgd(w, grad, gamma_inv, eta_inv):
    """IntegerSGD step (paper Algorithm 1).

    w: int32, grad: int64 (batch-summed); gamma_inv: traced/static scalar;
    eta_inv: scalar, 0 disables weight decay.
    delta = floor(grad / gamma_inv) [+ trunc(w / eta_inv)] ; w' = w - delta.

    The decay term uses *truncating* division: the paper's §3.3 states that
    weights with |w| < eta_inv receive no penalization, which only holds if
    the division rounds toward zero (floor would push every negative weight
    up by one). The gradient term keeps Algorithm 1's floor.
    """
    delta = div_floor(grad.astype(I64), jnp.asarray(gamma_inv, I64))
    eta = jnp.asarray(eta_inv, I64)
    decay = jnp.where(
        eta != 0,
        div_trunc(w.astype(I64), jnp.maximum(eta, 1)),
        jnp.zeros(w.shape, dtype=I64),
    )
    return (w.astype(I64) - delta - decay).astype(I32)


# ---------------------------------------------------------------------------
# weight init / data preprocessing (paper App. B)
# ---------------------------------------------------------------------------

def isqrt(n: int) -> int:
    """Integer square root (floor). Mirrors rust util::isqrt."""
    import math
    return math.isqrt(n)


def kaiming_bound(fan_in: int) -> int:
    """Integer Kaiming bound: b = floor(128*1732 / (isqrt(fan_in)*1000))."""
    return max(1, (128 * 1732) // (isqrt(fan_in) * 1000))


def init_weights(rng: np.random.RandomState, shape, fan_in: int):
    """Discrete uniform U(-b, b) int32 weights (biases are disabled)."""
    b = kaiming_bound(fan_in)
    return rng.randint(-b, b + 1, size=shape).astype(np.int32)


def mad_normalize(x):
    """Integer-only MAD pre-processing (paper App. B.2) over the whole
    dataset: center by integer mean, rescale so sigma ~ 64 via MAD
    (x - mu) * 51 // omega, all in integer arithmetic."""
    x = np.asarray(x, dtype=np.int64)
    n = x.size
    mu = int(x.sum()) // n
    omega = int(np.abs(x - mu).sum()) // n
    omega = max(omega, 1)
    return (((x - mu) * 51) // omega).astype(np.int32)
