"""Pallas integer matmul kernel — the NITRO-D compute hot-spot (L1).

TPU mapping (DESIGN.md §Hardware-Adaptation): int8-range activations times
int16-range weights accumulate on the MXU in int32/int64. BlockSpec tiles
are MXU-shaped (128-lane quantum); the grid walks (M/bm, N/bn) output tiles
and the kernel keeps an accumulator tile in VMEM while looping the K axis.

On this image the kernel runs with ``interpret=True`` (CPU PJRT cannot run
Mosaic custom-calls), which lowers to plain HLO — numerics are identical to
what the TPU path would compute. Correctness is asserted bit-exactly against
``ref.int_matmul`` by ``python/tests/test_int_matmul.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref  # noqa: F401  (enables x64 as an import side-effect)

I64 = jnp.int64


def _matmul_kernel(a_ref, w_ref, o_ref):
    """One (bm, bn) output tile: o = a @ w with int64 accumulation.

    a_ref: (bm, K) int32, w_ref: (K, bn) int32, o_ref: (bm, bn) int64.
    On TPU this is the MXU contraction with the int32->int64 accumulate
    epilogue; under interpret mode it is a plain dot.
    """
    a = a_ref[...].astype(I64)
    w = w_ref[...].astype(I64)
    o_ref[...] = jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())), preferred_element_type=I64
    )


def _pick_tile(dim: int, target: int = 128) -> int:
    """Largest divisor of ``dim`` not exceeding ``target`` (MXU quantum).

    Integer training shapes (e.g. M=784, N=100) are rarely multiples of 128;
    rather than pad (which changes golden vectors) we tile on a divisor.
    """
    best = 1
    for t in range(1, min(dim, target) + 1):
        if dim % t == 0:
            best = t
    return best


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def _run(a, w, bm: int, bn: int):
    m, k = a.shape
    _, n = w.shape
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), I64),
        interpret=True,
    )(a, w)


def int_matmul(a, w, bm: int | None = None, bn: int | None = None):
    """Integer matmul via the Pallas kernel.

    a: (M, K) int32, w: (K, N) int32 -> (M, N) int64 (batch-summed
    contractions stay in int64 until the caller rescales; see ref.py).
    """
    m, _ = a.shape
    _, n = w.shape
    bm = bm or _pick_tile(m)
    bn = bn or _pick_tile(n)
    return _run(a, w, bm=bm, bn=bn)


def vmem_footprint_bytes(m: int, k: int, n: int,
                         bm: int = 128, bn: int = 128) -> int:
    """Estimated VMEM bytes for one grid step (used by the perf analysis in
    EXPERIMENTS.md): an (bm, K) int32 slab + (K, bn) int32 slab + (bm, bn)
    int64 accumulator tile."""
    return 4 * (bm * k) + 4 * (k * bn) + 8 * (bm * bn)
