"""AOT export: lower NITRO-D block graphs to HLO text + golden vectors.

Outputs (under ``artifacts/``):

  <preset>/block<i>_fwd.hlo.txt    forward layers of block i
  <preset>/block<i>_train.hlo.txt  full local train step of block i
  <preset>/head_fwd.hlo.txt        output layers forward
  <preset>/head_train.hlo.txt      output layers train step
  <preset>/infer.hlo.txt           whole-network integer inference
  <preset>/manifest.json           shapes/constants/artifact index
  golden/ops.json                  op-level golden vectors (rust tensor tests)
  golden/<preset>_steps.json       3-step full-network training trace
                                   (losses + weight checksums) for the
                                   bit-exact rust trainer cross-check

Interchange format is HLO **text**, not serialized HloModuleProto: jax>=0.5
emits 64-bit instruction ids which xla_extension 0.5.1 (the version behind
the rust ``xla`` crate) rejects; the text parser reassigns ids. See
/opt/xla-example/README.md.

The lowered graphs route their hot contractions through the L1 Pallas
kernels (interpret=True lowers them to plain HLO). At export time every
artifact's numerics are asserted bit-exact against the pure-jnp reference
path — the Pallas/ref equivalence is re-proven on the real shapes here, not
just on the pytest shapes.
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os
import sys

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ref

I32 = jnp.int32
I64 = jnp.int64

# presets exported by default: small enough to AOT + execute quickly on the
# CPU PJRT client, yet cover both block kinds and the full trainer.
DEFAULT_PRESETS = [("tinycnn", 8), ("mlp1-mini", 8)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=I32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _scalar():
    return jax.ShapeDtypeStruct((), I64)


def _arr_json(name, a):
    a = np.asarray(a)
    return {
        "name": name,
        "shape": list(a.shape),
        "dtype": str(a.dtype),
        "data": a.reshape(-1).tolist(),
    }


def _checksum(a) -> dict:
    """Order-sensitive FNV-1a over the little-endian int32/int64 bytes plus
    an i64 element sum — mirrored by rust util::checksum."""
    a = np.asarray(a)
    h = 14695981039346656037
    for byte in a.astype("<i8").tobytes():
        h = ((h ^ byte) * 1099511628211) & 0xFFFFFFFFFFFFFFFF
    # fnv is u64; JSON ints are read as i64 on the rust side, so ship it as
    # a decimal string.
    return {"fnv": str(h), "sum": int(a.astype(np.int64).sum())}


# ---------------------------------------------------------------------------
# golden vectors: op level
# ---------------------------------------------------------------------------

def golden_ops(out_dir: str) -> str:
    """Deterministic op-level vectors exercising every primitive the rust
    tensor engine replicates (incl. negative operands — the floor-division
    traps live there)."""
    rng = np.random.RandomState(1234)
    cases = []

    a = rng.randint(-127, 128, (6, 20)).astype(np.int32)
    w = rng.randint(-2000, 2001, (20, 7)).astype(np.int32)
    cases.append({"op": "int_matmul",
                  "inputs": [_arr_json("a", a), _arr_json("w", w)],
                  "outputs": [_arr_json("z", ref.int_matmul(a, w))]})

    x = rng.randint(-127, 128, (3, 4, 9, 7)).astype(np.int32)
    wc = rng.randint(-900, 901, (5, 4, 3, 3)).astype(np.int32)
    cases.append({"op": "int_conv2d", "padding": 1,
                  "inputs": [_arr_json("x", x), _arr_json("w", wc)],
                  "outputs": [_arr_json("z", ref.int_conv2d(x, wc))]})

    g = rng.randint(-500, 501, (3, 5, 9, 7)).astype(np.int32)
    cases.append({"op": "conv2d_weight_grad", "kernel": 3, "padding": 1,
                  "inputs": [_arr_json("x", x), _arr_json("g", g)],
                  "outputs": [_arr_json("gw",
                                        ref.conv2d_weight_grad(x, g, 3, 1))]})

    pooled, arg = ref.maxpool2d(x, 2, 2)
    gp = rng.randint(-100, 101, np.asarray(pooled).shape).astype(np.int32)
    cases.append({"op": "maxpool2d", "size": 2, "stride": 2,
                  "inputs": [_arr_json("x", x), _arr_json("g", gp)],
                  "outputs": [_arr_json("pooled", pooled),
                              _arr_json("arg", arg),
                              _arr_json("gx", ref.maxpool2d_bwd(
                                  gp, arg, x.shape, 2, 2))]})

    z = rng.randint(-400, 401, (4, 33)).astype(np.int32)
    gg = rng.randint(-1000, 1001, (4, 33)).astype(np.int32)
    for ainv in (2, 10, 100):
        cases.append({"op": "nitro_relu", "alpha_inv": ainv,
                      "mu": ref.nitro_relu_mu(ainv),
                      "inputs": [_arr_json("z", z), _arr_json("g", gg)],
                      "outputs": [
                          _arr_json("a", ref.nitro_relu(z, ainv)),
                          _arr_json("gz", ref.nitro_relu_bwd(z, gg, ainv))]})

    wsgd = rng.randint(-30000, 30001, (11, 5)).astype(np.int32)
    gsgd = rng.randint(-10**7, 10**7, (11, 5)).astype(np.int64)
    for gamma, eta in ((512, 0), (512, 3000), (1024, 28000)):
        cases.append({"op": "integer_sgd", "gamma_inv": gamma,
                      "eta_inv": eta,
                      "inputs": [_arr_json("w", wsgd), _arr_json("g", gsgd)],
                      "outputs": [_arr_json(
                          "w2", ref.integer_sgd(wsgd, gsgd, gamma, eta))]})

    raw = rng.randint(0, 256, (1000,)).astype(np.int64)
    cases.append({"op": "mad_normalize",
                  "inputs": [_arr_json("x", raw)],
                  "outputs": [_arr_json("xn", ref.mad_normalize(raw))]})

    path = os.path.join(out_dir, "golden", "ops.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump({"cases": cases}, f)
    return path


# ---------------------------------------------------------------------------
# per-preset artifact export
# ---------------------------------------------------------------------------

def _block_fns(blk, use_pallas):
    if isinstance(blk, M.ConvBlockSpec):
        fwd = functools.partial(M.conv_block_forward, spec=blk,
                                use_pallas=use_pallas)
        train = functools.partial(M.conv_block_train, spec=blk,
                                  use_pallas=use_pallas)
    else:
        fwd = functools.partial(M.linear_block_forward, spec=blk,
                                use_pallas=use_pallas)
        train = functools.partial(M.linear_block_train, spec=blk,
                                  use_pallas=use_pallas)
    return fwd, train


def _block_io_shapes(spec: M.NetworkSpec, batch: int):
    """Activation shape entering each block (after the flatten that the
    coordinator performs before the first linear block of a CNN)."""
    shapes = []
    if len(spec.input_shape) == 3:
        cur = (batch,) + tuple(spec.input_shape)
    else:
        cur = (batch, spec.input_shape[0])
    for blk in spec.blocks:
        if isinstance(blk, M.ConvBlockSpec):
            shapes.append(cur)
            cur = (batch, blk.out_channels, blk.out_h, blk.out_w)
        else:
            flat = int(np.prod(cur[1:]))
            shapes.append((batch, flat))
            cur = (batch, blk.out_features)
    return shapes, cur


def export_preset(name: str, batch: int, out_dir: str, run_check: bool):
    spec = M.ZOO[name]()
    pdir = os.path.join(out_dir, name)
    os.makedirs(pdir, exist_ok=True)
    in_shapes, head_in = _block_io_shapes(spec, batch)
    g = spec.num_classes

    manifest = {
        "preset": name, "batch": batch, "num_classes": g,
        "input_shape": list(spec.input_shape),
        "one_hot_value": ref.ONE_HOT_VALUE,
        "amplification_factor": ref.amplification_factor(g),
        "blocks": [], "head": None, "infer": "infer.hlo.txt",
    }

    fwd_w, lr_w, head_w = M.init_network(spec, seed=7)
    rng = np.random.RandomState(99)
    x0 = rng.randint(-127, 128, in_shapes[0]).astype(np.int32)
    y = rng.randint(0, g, (batch,))
    y32 = np.asarray(ref.one_hot32(y, g)).astype(np.int32)
    gamma, eta_fw, eta_lr = 512, 12000, 3000

    a_ref = x0
    for i, blk in enumerate(spec.blocks):
        fwd_p, train_p = _block_fns(blk, use_pallas=True)
        fwd_r, train_r = _block_fns(blk, use_pallas=False)
        a_shape = in_shapes[i]
        wf_shape, wl_shape = blk.weight_shapes()

        lowered_f = jax.jit(fwd_p).lower(_spec(a_shape), _spec(wf_shape))
        lowered_t = jax.jit(train_p).lower(
            _spec(a_shape), _spec(wf_shape), _spec(wl_shape),
            _spec((batch, g)), _scalar(), _scalar(), _scalar())
        f_fwd = f"block{i}_fwd.hlo.txt"
        f_train = f"block{i}_train.hlo.txt"
        with open(os.path.join(pdir, f_fwd), "w") as f:
            f.write(to_hlo_text(lowered_f))
        with open(os.path.join(pdir, f_train), "w") as f:
            f.write(to_hlo_text(lowered_t))

        entry = {
            "index": i,
            "kind": "conv" if isinstance(blk, M.ConvBlockSpec) else "linear",
            "artifact_fwd": f_fwd, "artifact_train": f_train,
            "in_shape": list(a_shape), "wf_shape": list(wf_shape),
            "wl_shape": list(wl_shape), "sf": blk.sf,
            "alpha_inv": blk.alpha_inv,
            "mu": ref.nitro_relu_mu(blk.alpha_inv),
        }
        if isinstance(blk, M.ConvBlockSpec):
            s, k, _ = blk.lr_pool
            entry.update({"pool": blk.pool, "lr_pool_s": s, "lr_pool_k": k,
                          "out_shape": [batch, blk.out_channels,
                                        blk.out_h, blk.out_w]})
        else:
            entry.update({"out_shape": [batch, blk.out_features]})
        manifest["blocks"].append(entry)

        if run_check:
            # pallas path == ref path on the real shapes, bit-exact
            args = (a_ref, fwd_w[i], lr_w[i], y32,
                    np.int64(gamma), np.int64(eta_fw), np.int64(eta_lr))
            out_p = jax.jit(train_p)(*args)
            out_r = jax.jit(train_r)(*args)
            for op, orr in zip(out_p, out_r):
                np.testing.assert_array_equal(np.asarray(op), np.asarray(orr))
            a_ref = np.asarray(out_r[0])
            if not isinstance(blk, M.ConvBlockSpec) or i + 1 == len(spec.blocks):
                pass
            # flatten if the next block is linear
            if i + 1 < len(spec.blocks) and \
               not isinstance(spec.blocks[i + 1], M.ConvBlockSpec):
                a_ref = a_ref.reshape(batch, -1)

    # head
    hf = functools.partial(M.head_forward, spec=spec.head, use_pallas=True)
    ht = functools.partial(M.head_train, spec=spec.head, use_pallas=True)
    lowered_hf = jax.jit(hf).lower(_spec(head_in), _spec(spec.head.weight_shape()))
    lowered_ht = jax.jit(ht).lower(
        _spec(head_in), _spec(spec.head.weight_shape()), _spec((batch, g)),
        _scalar(), _scalar())
    with open(os.path.join(pdir, "head_fwd.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered_hf))
    with open(os.path.join(pdir, "head_train.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered_ht))
    manifest["head"] = {
        "artifact_fwd": "head_fwd.hlo.txt",
        "artifact_train": "head_train.hlo.txt",
        "in_shape": list(head_in), "w_shape": list(spec.head.weight_shape()),
        "sf": spec.head.sf,
    }

    # whole-network inference
    infer = functools.partial(M.network_infer, spec=spec, use_pallas=True)
    wspecs = [_spec(w.shape) for w in fwd_w] + [_spec(head_w.shape)]
    lowered_i = jax.jit(lambda x, *ws: infer(x, list(ws))).lower(
        _spec(in_shapes[0]), *wspecs)
    with open(os.path.join(pdir, "infer.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered_i))

    with open(os.path.join(pdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return spec, in_shapes, head_in


# ---------------------------------------------------------------------------
# golden training trace: 3 full-network steps, bit-exact
# ---------------------------------------------------------------------------

def golden_steps(name: str, batch: int, out_dir: str, steps: int = 3):
    """Run `steps` sequential full-network training iterations with the ref
    path and record everything the rust trainer needs to replicate them
    bit-exactly: initial weights, per-step inputs/labels, per-block losses,
    final weight checksums and final activations."""
    spec = M.ZOO[name]()
    g = spec.num_classes
    fwd_w, lr_w, head_w = M.init_network(spec, seed=7)
    rng = np.random.RandomState(99)
    gamma, eta_fw, eta_lr = 512, 12000, 3000
    in_shapes, _ = _block_io_shapes(spec, batch)

    trace = {"preset": name, "batch": batch, "seed": 7, "data_seed": 99,
             "gamma_inv": gamma, "eta_fw_inv": eta_fw, "eta_lr_inv": eta_lr,
             "init_weights": {
                 "fwd": [_arr_json(f"wf{i}", w) for i, w in enumerate(fwd_w)],
                 "lr": [_arr_json(f"wl{i}", w) for i, w in enumerate(lr_w)],
                 "head": _arr_json("wo", head_w)},
             "steps": []}

    jit_cache = {}
    for t in range(steps):
        x = rng.randint(-127, 128, in_shapes[0]).astype(np.int32)
        y = rng.randint(0, g, (batch,))
        y32 = np.asarray(ref.one_hot32(y, g)).astype(np.int32)
        step = {"x": _arr_json("x", x), "y": y.tolist(), "block_loss": []}
        a = x
        for i, blk in enumerate(spec.blocks):
            if not isinstance(blk, M.ConvBlockSpec) and a.ndim > 2:
                a = a.reshape(batch, -1)
            key = ("train", i)
            if key not in jit_cache:
                _, train_r = _block_fns(blk, use_pallas=False)
                jit_cache[key] = jax.jit(train_r)
            a, wf2, wl2, loss = jit_cache[key](
                a, fwd_w[i], lr_w[i], y32, np.int64(gamma),
                np.int64(eta_fw), np.int64(eta_lr))
            a = np.asarray(a)
            fwd_w[i], lr_w[i] = np.asarray(wf2), np.asarray(wl2)
            step["block_loss"].append(int(loss))
        if a.ndim > 2:
            a = a.reshape(batch, -1)
        if "head" not in jit_cache:
            jit_cache["head"] = jax.jit(functools.partial(
                M.head_train, spec=spec.head, use_pallas=False))
        yhat, wo2, loss = jit_cache["head"](
            a, head_w, y32, np.int64(gamma), np.int64(eta_lr))
        head_w = np.asarray(wo2)
        step["head_loss"] = int(loss)
        step["yhat_checksum"] = _checksum(yhat)
        trace["steps"].append(step)

    trace["final"] = {
        "fwd_checksums": [_checksum(w) for w in fwd_w],
        "lr_checksums": [_checksum(w) for w in lr_w],
        "head_checksum": _checksum(head_w),
    }
    path = os.path.join(out_dir, "golden", f"{name}_steps.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(trace, f)
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifacts output directory")
    ap.add_argument("--preset", action="append", default=None,
                    help="preset[:batch] to export (repeatable)")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the pallas==ref export-time assertion")
    ap.add_argument("--golden-steps", type=int, default=3)
    args = ap.parse_args()

    presets = DEFAULT_PRESETS
    if args.preset:
        presets = []
        for p in args.preset:
            if ":" in p:
                n, b = p.split(":")
                presets.append((n, int(b)))
            else:
                presets.append((p, 8))

    os.makedirs(args.out, exist_ok=True)
    print(f"[aot] op-level golden -> {golden_ops(args.out)}")
    for name, batch in presets:
        print(f"[aot] exporting preset {name} (batch={batch}) ...")
        export_preset(name, batch, args.out, run_check=not args.no_check)
        print(f"[aot] golden trace -> "
              f"{golden_steps(name, batch, args.out, args.golden_steps)}")
    stamp = os.path.join(args.out, ".stamp")
    with open(stamp, "w") as f:
        f.write(",".join(f"{n}:{b}" for n, b in presets) + "\n")
    print("[aot] done")


if __name__ == "__main__":
    main()
