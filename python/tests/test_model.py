"""L2 block-graph tests: shapes, ranges, learning dynamics, pallas==ref."""

import functools

import numpy as np
import pytest

import jax

from compile import model as M
from compile.kernels import ref


def _toy_labels(rng, batch, g):
    y = rng.randint(0, g, batch)
    return y, np.asarray(ref.one_hot32(y, g)).astype(np.int32)


def test_zoo_topologies_match_paper():
    vgg8b = M.ZOO["vgg8b"]()
    assert len(vgg8b.blocks) == 7  # 6 conv + 1 linear; head = 8th layer
    assert [b.out_channels for b in vgg8b.blocks[:6]] == \
        [128, 256, 256, 512, 512, 512]
    # after 4 pools: 32 -> 16 -> 8 -> 4 -> 2
    assert vgg8b.blocks[5].out_h == 2
    assert vgg8b.blocks[6].in_features == 512 * 2 * 2
    assert vgg8b.head.in_features == 1024

    vgg11b = M.ZOO["vgg11b"]()
    assert len(vgg11b.blocks) == 10  # 9 conv + 1 linear; head = 11th layer
    mlp4 = M.ZOO["mlp4"]()
    assert mlp4.input_shape == (3072,)
    assert [b.out_features for b in mlp4.blocks] == [3000, 3000, 3000]


def test_conv_block_shapes_and_range():
    spec = M.ConvBlockSpec(3, 8, 8, 8, pool=True, d_lr=64)
    rng = np.random.RandomState(0)
    a = rng.randint(-127, 128, (4, 3, 8, 8)).astype(np.int32)
    wf = ref.init_weights(rng, spec.weight_shapes()[0], spec.fan_in)
    out = np.asarray(M.conv_block_forward(a, wf, spec))
    assert out.shape == (4, 8, 4, 4)
    mu = ref.nitro_relu_mu(spec.alpha_inv)
    assert out.min() >= -127 - mu and out.max() <= 127 - mu


def test_linear_block_shapes_and_range():
    spec = M.LinearBlockSpec(64, 32)
    rng = np.random.RandomState(0)
    a = rng.randint(-127, 128, (4, 64)).astype(np.int32)
    wf = ref.init_weights(rng, (64, 32), 64)
    out = np.asarray(M.linear_block_forward(a, wf, spec))
    assert out.shape == (4, 32)
    mu = ref.nitro_relu_mu(spec.alpha_inv)
    assert out.min() >= -127 - mu and out.max() <= 127 - mu


@pytest.mark.parametrize("preset", ["tinycnn", "mlp1-mini"])
def test_block_train_pallas_equals_ref(preset):
    """Bit-exact equivalence of the full train step between the Pallas
    kernel path and the reference path, per block."""
    spec = M.ZOO[preset]()
    fwd_w, lr_w, _ = M.init_network(spec, seed=3)
    rng = np.random.RandomState(5)
    batch, g = 4, spec.num_classes
    if len(spec.input_shape) == 3:
        a = rng.randint(-127, 128, (batch,) + spec.input_shape).astype(np.int32)
    else:
        a = rng.randint(-127, 128, (batch, spec.input_shape[0])).astype(np.int32)
    _, y32 = _toy_labels(rng, batch, g)
    for i, blk in enumerate(spec.blocks):
        if not isinstance(blk, M.ConvBlockSpec) and a.ndim > 2:
            a = a.reshape(batch, -1)
        args = (a, fwd_w[i], lr_w[i], y32, np.int64(512), np.int64(0),
                np.int64(0))
        train_r = functools.partial(
            M.conv_block_train if isinstance(blk, M.ConvBlockSpec)
            else M.linear_block_train, spec=blk, use_pallas=False)
        train_p = functools.partial(
            M.conv_block_train if isinstance(blk, M.ConvBlockSpec)
            else M.linear_block_train, spec=blk, use_pallas=True)
        out_r = jax.jit(train_r)(*args)
        out_p = jax.jit(train_p)(*args)
        for o_r, o_p in zip(out_r, out_p):
            np.testing.assert_array_equal(np.asarray(o_r), np.asarray(o_p))
        a = np.asarray(out_r[0])


def test_training_reduces_loss_linear_block():
    """A single linear block must fit a small separable problem: the local
    RSS loss decreases substantially over integer-only updates."""
    spec = M.LinearBlockSpec(32, 24, num_classes=4)
    rng = np.random.RandomState(1)
    wf = ref.init_weights(rng, (32, 24), 32)
    wl = ref.init_weights(rng, (24, 4), 24)
    # 4 class prototypes, strongly separable
    protos = rng.randint(-100, 101, (4, 32))
    xs, ys = [], []
    for i in range(64):
        c = i % 4
        xs.append(np.clip(protos[c] + rng.randint(-10, 11, 32), -127, 127))
        ys.append(c)
    x = np.array(xs, dtype=np.int32)
    y32 = np.asarray(ref.one_hot32(np.array(ys), 4)).astype(np.int32)
    train = jax.jit(functools.partial(M.linear_block_train, spec=spec))
    losses = []
    for _ in range(30):
        _, wf, wl, loss = train(x, wf, wl, y32, np.int64(512), np.int64(0),
                                np.int64(0))
        losses.append(int(loss))
    assert losses[-1] < losses[0] * 0.5, losses


def test_head_train_reduces_loss():
    spec = M.HeadSpec(16, 4)
    rng = np.random.RandomState(2)
    wo = ref.init_weights(rng, (16, 4), 16)
    protos = rng.randint(-100, 101, (4, 16))
    x = np.array([np.clip(protos[i % 4] + rng.randint(-5, 6, 16), -127, 127)
                  for i in range(32)], dtype=np.int32)
    y32 = np.asarray(ref.one_hot32(np.arange(32) % 4, 4)).astype(np.int32)
    train = jax.jit(functools.partial(M.head_train, spec=spec))
    losses = []
    for _ in range(40):
        _, wo, loss = train(x, wo, y32, np.int64(512), np.int64(0))
        losses.append(int(loss))
    assert losses[-1] < losses[0] * 0.5, losses


def test_network_infer_shape_and_integrality():
    spec = M.ZOO["tinycnn"]()
    fwd_w, _, wo = M.init_network(spec, seed=0)
    rng = np.random.RandomState(0)
    x = rng.randint(-127, 128, (4, 1, 8, 8)).astype(np.int32)
    yhat = np.asarray(M.network_infer(x, fwd_w + [wo], spec))
    assert yhat.shape == (4, 10)
    assert yhat.dtype == np.int32


def test_amplified_lr_wiring():
    """gamma_fw_inv = gamma_lr_inv * AF (DESIGN.md interp. #1): with a
    gradient exactly AF*gamma large, the forward update is exactly -1."""
    g = 10
    af = ref.amplification_factor(g)
    assert af == 640
    w = np.zeros((1, 1), dtype=np.int32)
    grad = np.array([[512 * af]], dtype=np.int64)
    w2 = np.asarray(ref.integer_sgd(w, grad, 512 * af, 0))
    assert w2[0, 0] == -1


def test_learning_layer_output_magnitude():
    """yhat from the learning head stays in the one-hot regime (|.| <= 64)
    so the RSS gradient fits the 6-7 bit budget of the AF analysis."""
    spec = M.LinearBlockSpec(48, 32, num_classes=10)
    rng = np.random.RandomState(3)
    a = rng.randint(-127, 128, (16, 48)).astype(np.int32)
    wf = ref.init_weights(rng, (48, 32), 48)
    wl = rng.randint(-127, 128, (32, 10)).astype(np.int32)
    feat = np.asarray(M.linear_block_forward(a, wf, spec))
    yhat = np.asarray(M._learning_forward(feat, wl, False))
    assert np.abs(yhat).max() <= 64


def test_adaptive_pool_roundtrip_gradient():
    spec = M.ConvBlockSpec(1, 4, 8, 8, pool=False, d_lr=16)
    s, k, _ = spec.lr_pool  # C_out=4, d_lr=16 -> s=2
    assert (s, k) == (2, 4)
    rng = np.random.RandomState(0)
    x = rng.randint(-127, 128, (2, 4, 8, 8)).astype(np.int32)
    feat, arg, pshape = M._adaptive_pool(x, spec)
    assert feat.shape == (2, 16)
    g = rng.randint(-50, 51, feat.shape).astype(np.int32)
    gx = np.asarray(M._adaptive_pool_bwd(g, arg, pshape, x.shape, spec))
    assert gx.shape == x.shape
    # every window routes its gradient to exactly one position
    assert np.count_nonzero(gx) <= g.size
    assert gx.astype(np.int64).sum() == g.astype(np.int64).sum()
