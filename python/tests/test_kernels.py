"""L1 Pallas kernels vs the pure-jnp oracle — bit-exact, hypothesis-swept.

The kernels run under interpret=True; equality must be exact (integers),
never allclose.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import int_conv2d as k_conv
from compile.kernels import int_matmul as k_mm
from compile.kernels import nitro_ops as k_nitro
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def matmul_case(draw):
    m = draw(st.integers(1, 48))
    k = draw(st.integers(1, 64))
    n = draw(st.integers(1, 32))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.RandomState(seed)
    # int8-range activations, int16-range weights (paper App. E.3 regime)
    a = rng.randint(-127, 128, (m, k)).astype(np.int32)
    w = rng.randint(-32768, 32768, (k, n)).astype(np.int32)
    return a, w


@given(matmul_case())
@settings(**SETTINGS)
def test_int_matmul_bitexact(case):
    a, w = case
    got = np.asarray(k_mm.int_matmul(a, w))
    want = np.asarray(ref.int_matmul(a, w))
    assert got.dtype == np.int64
    np.testing.assert_array_equal(got, want)


def test_int_matmul_extreme_values():
    """Operands at the int32 rails: i64 accumulation must not wrap."""
    a = np.full((2, 1024), 127, dtype=np.int32)
    w = np.full((1024, 2), 32767, dtype=np.int32)
    got = np.asarray(k_mm.int_matmul(a, w))
    assert (got == 127 * 32767 * 1024).all()
    assert got[0, 0] > np.iinfo(np.int32).max  # genuinely needed int64


def test_pick_tile_divides():
    for dim in (1, 7, 100, 128, 784, 1000, 1024):
        t = k_mm._pick_tile(dim)
        assert dim % t == 0 and 1 <= t <= 128


@st.composite
def conv_case(draw):
    b = draw(st.integers(1, 4))
    c = draw(st.integers(1, 6))
    o = draw(st.integers(1, 8))
    h = draw(st.integers(3, 12))
    w = draw(st.integers(3, 12))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.RandomState(seed)
    x = rng.randint(-127, 128, (b, c, h, w)).astype(np.int32)
    wt = rng.randint(-4000, 4001, (o, c, 3, 3)).astype(np.int32)
    return x, wt


@given(conv_case())
@settings(**SETTINGS)
def test_int_conv2d_bitexact(case):
    x, w = case
    got = np.asarray(k_conv.int_conv2d(x, w, kernel=3, padding=1))
    want = np.asarray(ref.int_conv2d(x, w, padding=1))
    assert got.dtype == np.int64
    np.testing.assert_array_equal(got, want)


def test_int_conv2d_identity_kernel():
    """A delta kernel reproduces the input channel."""
    x = np.arange(2 * 1 * 5 * 5, dtype=np.int32).reshape(2, 1, 5, 5) - 25
    w = np.zeros((1, 1, 3, 3), dtype=np.int32)
    w[0, 0, 1, 1] = 1
    got = np.asarray(k_conv.int_conv2d(x, w))
    np.testing.assert_array_equal(got, x.astype(np.int64))


@st.composite
def scale_relu_case(draw):
    b = draw(st.integers(1, 6))
    f = draw(st.integers(1, 80))
    sf = draw(st.sampled_from([256, 256 * 9, 256 * 64, 256 * 784]))
    alpha_inv = draw(st.sampled_from([2, 3, 10, 100]))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.RandomState(seed)
    z = rng.randint(-2**40, 2**40, (b, f)).astype(np.int64)
    return z, sf, alpha_inv


@given(scale_relu_case())
@settings(**SETTINGS)
def test_nitro_scale_relu_bitexact(case):
    z, sf, alpha_inv = case
    got = np.asarray(k_nitro.nitro_scale_relu(z, sf=sf, alpha_inv=alpha_inv))
    want = np.asarray(
        ref.nitro_relu(ref.nitro_scale(z, sf), alpha_inv)).astype(np.int32)
    assert got.dtype == np.int32
    np.testing.assert_array_equal(got, want)


@given(scale_relu_case())
@settings(**SETTINGS)
def test_nitro_scale_only_bitexact(case):
    z, sf, _ = case
    got = np.asarray(k_nitro.nitro_scale(z, sf=sf))
    want = np.asarray(ref.nitro_scale(z, sf)).astype(np.int32)
    np.testing.assert_array_equal(got, want)


def test_scale_relu_negative_division_floor_not_trunc():
    """The exact trap: -1 / 256 must be -1 (floor), not 0 (truncate)."""
    z = np.array([[-1, -255, -256, -257, 255, 256]], dtype=np.int64)
    got = np.asarray(k_nitro.nitro_scale(z, sf=256))
    np.testing.assert_array_equal(got, [[-1, -1, -1, -2, 0, 1]])


def test_vmem_footprints_are_positive_and_bounded():
    # structural perf probes used by EXPERIMENTS.md
    assert 0 < k_mm.vmem_footprint_bytes(128, 1152, 128) < 16 * 2**20
    assert 0 < k_conv.vmem_footprint_bytes(128, 256, 3, 32, 32, 1) < 16 * 2**20
