from hypothesis import settings
settings.register_profile("ci", deadline=None)
settings.load_profile("ci")
