"""AOT export tests: HLO text round-trips, manifests are consistent, and
the golden trace is deterministic."""

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model as M
from compile.kernels import ref

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrip(tmp_path):
    """The emitted text is real HLO: it parses back through xla_client and
    executes with the expected integer result."""
    def fn(a, w):
        z = ref.int_matmul(a, w)
        return (ref.nitro_scale(z, 256 * 4).astype(jnp.int32),)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((2, 4), jnp.int32),
        jax.ShapeDtypeStruct((4, 3), jnp.int32))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "s64" in text  # int64 accumulation visible
    # execute through jax's own CPU client for a numerics check
    a = np.array([[1, 2, 3, 4], [-4, -3, -2, -1]], dtype=np.int32)
    w = np.arange(12, dtype=np.int32).reshape(4, 3) * 100
    want = np.asarray(fn(a, w)[0])
    got = np.asarray(jax.jit(fn)(a, w)[0])
    np.testing.assert_array_equal(got, want)


@pytest.mark.skipif(not os.path.isdir(os.path.join(ART, "tinycnn")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_consistency():
    for preset in ("tinycnn", "mlp1-mini"):
        pdir = os.path.join(ART, preset)
        with open(os.path.join(pdir, "manifest.json")) as f:
            man = json.load(f)
        assert man["preset"] == preset
        assert man["one_hot_value"] == 32
        assert man["amplification_factor"] == 64 * man["num_classes"]
        spec = M.ZOO[preset]()
        assert len(man["blocks"]) == len(spec.blocks)
        for entry, blk in zip(man["blocks"], spec.blocks):
            assert entry["sf"] == blk.sf
            assert entry["mu"] == ref.nitro_relu_mu(blk.alpha_inv)
            for key in ("artifact_fwd", "artifact_train"):
                path = os.path.join(pdir, entry[key])
                assert os.path.isfile(path)
                head = open(path).read(4096)
                assert "ENTRY" in head or "HloModule" in head
        assert os.path.isfile(os.path.join(pdir, man["infer"]))
        assert os.path.isfile(os.path.join(pdir, man["head"]["artifact_fwd"]))


@pytest.mark.skipif(not os.path.isdir(os.path.join(ART, "golden")),
                    reason="artifacts not built (run `make artifacts`)")
def test_golden_ops_file_wellformed():
    with open(os.path.join(ART, "golden", "ops.json")) as f:
        g = json.load(f)
    ops = {c["op"] for c in g["cases"]}
    assert {"int_matmul", "int_conv2d", "conv2d_weight_grad", "maxpool2d",
            "nitro_relu", "integer_sgd", "mad_normalize"} <= ops
    for c in g["cases"]:
        for arr in c["inputs"] + c["outputs"]:
            assert len(arr["data"]) == int(np.prod(arr["shape"]))


def test_golden_steps_deterministic(tmp_path):
    """Two generations of the 1-step mlp1-mini trace are identical."""
    p1 = aot.golden_steps("mlp1-mini", 4, str(tmp_path / "a"), steps=1)
    p2 = aot.golden_steps("mlp1-mini", 4, str(tmp_path / "b"), steps=1)
    assert open(p1).read() == open(p2).read()


def test_checksum_mirrors_spec():
    """FNV-1a over little-endian int64 bytes + int64 sum — pinned so the
    rust util::checksum implementation can be verified against it."""
    arr = np.array([1, -2, 300000], dtype=np.int32)
    c = aot._checksum(arr)
    assert c["sum"] == 299999
    # recompute by hand
    h = 14695981039346656037
    for byte in np.array([1, -2, 300000], dtype="<i8").tobytes():
        h = ((h ^ byte) * 1099511628211) % 2**64
    assert c["fnv"] == str(h)
