"""Property tests on the pure-jnp reference ops (the numeric-format core).

These pin down the floor-division semantics and the paper-specified
invariants that the Pallas kernels and the Rust engine must replicate.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


@given(st.integers(-10**9, 10**9), st.integers(1, 10**6))
def test_div_floor_matches_python(x, d):
    assert int(ref.div_floor(np.int64(x), np.int64(d))) == x // d


@given(st.integers(-10**6, 10**6), st.integers(1, 1000))
def test_div_floor_is_not_truncation_on_negatives(x, d):
    got = int(ref.div_floor(np.int64(x), np.int64(d)))
    assert got * d <= x < (got + 1) * d  # floor bracketing


@pytest.mark.parametrize("alpha_inv,expected_mu", [
    # hand-computed from the paper's four segment means
    (10, (-13 + -7 + 63 + 127) // 4),
    (2, (-64 + -32 + 63 + 127) // 4),
    (100, (-2 + -1 + 63 + 127) // 4),
])
def test_nitro_relu_mu(alpha_inv, expected_mu):
    assert ref.nitro_relu_mu(alpha_inv) == expected_mu


@given(st.integers(2, 128))
def test_nitro_relu_output_range(alpha_inv):
    x = np.arange(-1000, 1000, dtype=np.int32)
    out = np.asarray(ref.nitro_relu(x, alpha_inv))
    mu = ref.nitro_relu_mu(alpha_inv)
    # paper: output confined to [-127, 127] before centering
    assert out.min() >= -127 - mu
    assert out.max() <= 127 - mu
    # monotone non-decreasing
    assert (np.diff(out) >= 0).all()


@given(st.integers(2, 64), st.integers(0, 2**31 - 1))
@settings(max_examples=50)
def test_nitro_relu_bwd_zero_outside_clamp(alpha_inv, seed):
    rng = np.random.RandomState(seed % 2**31)
    x = rng.randint(-500, 501, 256).astype(np.int32)
    g = rng.randint(-10**6, 10**6, 256).astype(np.int32)
    gz = np.asarray(ref.nitro_relu_bwd(x, g, alpha_inv))
    assert (gz[(x < -127) | (x > 127)] == 0).all()
    inner_neg = (x >= -127) & (x < 0)
    assert (gz[inner_neg] == g[inner_neg] // alpha_inv).all()
    inner_pos = (x >= 0) & (x <= 127)
    assert (gz[inner_pos] == g[inner_pos]).all()


def test_scale_factors_match_paper():
    assert ref.scale_factor_linear(784) == 256 * 784
    assert ref.scale_factor_conv(3, 128) == 256 * 9 * 128
    assert ref.amplification_factor(10) == 640


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=30)
def test_scaling_layer_bounds(seed):
    """Worst-case int8 x int8 operands through SF land within +-64 ~ int8
    range — the analytic bound the paper derives."""
    rng = np.random.RandomState(seed % 2**31)
    m = int(rng.randint(1, 512))
    a = rng.randint(-127, 128, (4, m)).astype(np.int32)
    w = rng.randint(-127, 128, (m, 6)).astype(np.int32)
    z = ref.int_matmul(a, w)
    zs = np.asarray(ref.nitro_scale(z, ref.scale_factor_linear(m)))
    assert np.abs(zs).max() <= 64


def test_integer_sgd_no_decay_below_threshold():
    """Paper §3.3: weights with |w| < eta_inv receive no decay."""
    w = np.array([10, -10, 2999, -2999, 3000, -3001], dtype=np.int32)
    g = np.zeros_like(w, dtype=np.int64)
    w2 = np.asarray(ref.integer_sgd(w, g, 512, 3000))
    np.testing.assert_array_equal(w2[:4], w[:4])        # untouched
    assert w2[4] == 3000 - 1                            # trunc(3000/3000)=1
    assert w2[5] == -3001 + 1                           # trunc(-3001/3000)=-1


@given(st.integers(0, 2**31 - 1), st.integers(1, 10**5),
       st.integers(0, 10**5))
@settings(max_examples=50)
def test_integer_sgd_matches_algorithm1(seed, gamma, eta):
    rng = np.random.RandomState(seed % 2**31)
    w = rng.randint(-30000, 30001, 64).astype(np.int32)
    g = rng.randint(-10**8, 10**8, 64).astype(np.int64)
    w2 = np.asarray(ref.integer_sgd(w, g, gamma, eta))
    delta = g // gamma  # gradient term: floor (Algorithm 1)
    if eta != 0:
        wi = w.astype(np.int64)
        delta = delta + np.sign(wi) * (np.abs(wi) // eta)  # decay: trunc
    np.testing.assert_array_equal(w2, (w - delta).astype(np.int32))


def test_one_hot32():
    y32 = np.asarray(ref.one_hot32(np.array([0, 3]), 4))
    np.testing.assert_array_equal(
        y32, [[32, 0, 0, 0], [0, 0, 0, 32]])


def test_rss_loss_grad():
    yhat = np.array([[10, -5]], dtype=np.int32)
    y32 = np.array([[32, 0]], dtype=np.int32)
    loss, grad = ref.rss_loss_grad(yhat, y32)
    assert int(loss) == (22 * 22 + 5 * 5) // 2
    np.testing.assert_array_equal(np.asarray(grad), [[-22, -5]])


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20)
def test_mad_normalize_properties(seed):
    rng = np.random.RandomState(seed % 2**31)
    x = rng.randint(0, 256, 5000)
    xn = ref.mad_normalize(x)
    assert xn.dtype == np.int32
    # centered: integer mean within quantization of 0
    assert abs(int(xn.astype(np.int64).sum()) // xn.size) <= 2
    # dispersion: MAD close to 51 (sigma ~ 64) up to integer truncation
    mad = np.abs(xn.astype(np.int64)).mean()
    assert 30 <= mad <= 70


def test_kaiming_bound_examples():
    # b = floor(128*1732 / (isqrt(fan_in)*1000))
    assert ref.kaiming_bound(784) == (128 * 1732) // (28 * 1000)
    assert ref.kaiming_bound(9) == (128 * 1732) // (3 * 1000)


@given(st.integers(1, 10**6))
def test_isqrt(n):
    s = ref.isqrt(n)
    assert s * s <= n < (s + 1) * (s + 1)
