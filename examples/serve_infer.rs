//! Serving example: load the AOT **inference** artifact (learning layers
//! stripped — they exist only for training, App. E.3) and serve batched
//! classification requests through the PJRT runtime, reporting latency and
//! throughput percentiles.
//!
//! This is the deployment path a downstream user of the library would run:
//! `python` is not involved — the artifact directory plus this binary is
//! the whole server.

use nitro::coordinator::engine::{Engine, PjrtEngine};
use nitro::nn::{zoo, Network};
use nitro::tensor::ITensor;
use nitro::util::rng::Pcg32;
use std::time::Instant;

fn main() {
    let preset = "tinycnn";
    let dir = format!("artifacts/{preset}");
    if !std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        println!("serve_infer: artifacts not built (`make artifacts`); \
                  falling back to the native engine only");
    }

    // load the engine (PJRT if artifacts exist, else native)
    let use_pjrt = std::path::Path::new(&format!("{dir}/manifest.json"))
        .exists();
    let mut pjrt = if use_pjrt {
        Some(PjrtEngine::load(&dir, 7).expect("load artifacts"))
    } else {
        None
    };
    let spec = zoo::get(preset).unwrap();
    let net = Network::new(spec.clone(), 7);
    if let Some(p) = pjrt.as_mut() {
        p.set_weights(
            net.blocks.iter().map(|b| b.wf.clone()).collect(),
            net.blocks.iter().map(|b| b.wl.clone()).collect(),
            net.head.wo.clone(),
        );
    }
    let mut native = nitro::coordinator::engine::NativeEngine::new(net, 7, false);

    let batch = pjrt.as_ref().map(|p| p.manifest.batch).unwrap_or(8);
    let mut rng = Pcg32::new(42);
    let mut shape = vec![batch];
    shape.extend(&spec.input_shape);
    let n: usize = shape.iter().product();

    // request loop: 200 batched requests
    let requests: Vec<ITensor> = (0..200)
        .map(|_| {
            ITensor::from_vec(&shape,
                              (0..n).map(|_| rng.range_i32(-127, 127)).collect())
        })
        .collect();

    for (name, engine) in [("native", true), ("pjrt", false)] {
        if name == "pjrt" && pjrt.is_none() {
            continue;
        }
        let mut lat_ns: Vec<u64> = Vec::with_capacity(requests.len());
        let t0 = Instant::now();
        let mut check = 0i64;
        for req in &requests {
            let t = Instant::now();
            let yhat = if engine {
                native.infer(req)
            } else {
                pjrt.as_mut().unwrap().infer(req)
            };
            lat_ns.push(t.elapsed().as_nanos() as u64);
            check += yhat.data.iter().map(|&v| v as i64).sum::<i64>();
        }
        let total = t0.elapsed().as_secs_f64();
        lat_ns.sort_unstable();
        let p = |q: f64| lat_ns[(q * (lat_ns.len() - 1) as f64) as usize]
            as f64 / 1e6;
        println!(
            "{name:<7} {} reqs x batch {}: {:.1} img/s | latency ms \
             p50 {:.3} p90 {:.3} p99 {:.3} (checksum {check})",
            requests.len(),
            batch,
            (requests.len() * batch) as f64 / total,
            p(0.5),
            p(0.9),
            p(0.99)
        );
    }

    // parity spot-check between the two serving paths
    if let Some(p) = pjrt.as_mut() {
        let a = native.infer(&requests[0]);
        let b = p.infer(&requests[0]);
        assert_eq!(a, b, "serving engines disagree");
        println!("native/pjrt serving parity ✓");
    }

    // the production path: the micro-batching serve subsystem
    // (`coordinator::serve`) over the grad-free fused forward — here fed
    // from concurrent client threads, as `nitro serve --listen` would be
    use nitro::coordinator::serve::{MicroBatcher, ModelRegistry,
                                    ServeConfig, ShardedBatcher};
    let registry = ModelRegistry::new();
    let dir = std::env::temp_dir().join("nitro_serve_example");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("tinycnn.ckpt");
    // same spec + seed as the engine above (which consumed its Network),
    // so the served model is byte-identical to the parity section's
    let serve_net = Network::new(spec.clone(), 7);
    nitro::train::checkpoint::save(&serve_net, ckpt.to_str().unwrap())
        .expect("save checkpoint");
    registry.load(ckpt.to_str().unwrap()).expect("load checkpoint");
    let registry = std::sync::Arc::new(registry);
    let mb = MicroBatcher::start(
        registry.clone(),
        ServeConfig { max_batch: 32, max_wait_us: 200,
                      ..Default::default() },
    );
    let ss: usize = spec.input_shape.iter().product();
    let t0 = Instant::now();
    let nclients = 4usize;
    let per_client = 50usize;
    std::thread::scope(|s| {
        for c in 0..nclients {
            let client = mb.client();
            let reqs = &requests;
            s.spawn(move || {
                for r in 0..per_client {
                    let req = &reqs[(c * per_client + r) % reqs.len()];
                    let sample = req.data[..ss].to_vec();
                    let (_, y) = client.predict(None, sample)
                        .expect("predict");
                    assert_eq!(y.shape[1], 10);
                }
            });
        }
    });
    let total = t0.elapsed().as_secs_f64();
    println!(
        "micro-batched serve: {} concurrent clients x {} reqs: {:.0} req/s",
        nclients,
        per_client,
        (nclients * per_client) as f64 / total
    );
    // batch-composition invariance: a coalesced single-sample request
    // equals the reference forward on that sample
    let client = mb.client();
    let sample = requests[0].data[..ss].to_vec();
    let (_, y) = client.predict(None, sample).unwrap();
    let full = serve_net.infer(&requests[0]);
    assert_eq!(y.data[..], full.data[..10],
               "micro-batched logits diverge from Network::infer");
    println!("micro-batch determinism ✓");
    // shard invariance: every shard of the production ShardedBatcher
    // serves the same bits (the `nitro serve --shards N` path)
    let sb = ShardedBatcher::start(
        registry,
        ServeConfig { shards: 2, max_wait_us: 0, ..Default::default() },
    );
    for key in 0..sb.nshards() as u64 {
        let sample = requests[0].data[..ss].to_vec();
        let (m, y) = sb.client(key).predict(None, sample).unwrap();
        assert_eq!(y.data[..], full.data[..10],
                   "shard {key} logits diverge");
        assert_eq!(m.version, 1);
    }
    println!("shard determinism ✓ ({} shards)", sb.nshards());
    println!("serve_infer PASSED");
}
