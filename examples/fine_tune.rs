//! On-device fine-tuning scenario (paper App. E.3's closing argument):
//! because NITRO-D weights are *natively* integer, a deployed model can be
//! fine-tuned locally when new data arrives — no dequantize/retrain/requantize
//! cycle, which is impossible for post-training-quantized models.
//!
//! The scenario: a model trained on one data distribution is deployed
//! (checkpointed), the distribution shifts (new synthetic seed = new class
//! styles), accuracy drops, and a short integer-only fine-tune on a small
//! local buffer recovers most of it.

use nitro::data::loader;
use nitro::nn::{zoo, Hyper, Network};
use nitro::train::{checkpoint, evaluate, fit, TrainConfig};

fn main() {
    // train the "factory" model on distribution A
    let (mut tr_a, mut te_a) = loader::load("tiny", "data", 1200, 300, 1)
        .expect("dataset A");
    tr_a.mad_normalize();
    te_a.mad_normalize();
    let mut net = Network::new(zoo::get("tinycnn").unwrap(), 3);
    let cfg = TrainConfig {
        epochs: 110,
        batch: 64,
        hyper: Hyper { gamma_inv: 512, eta_fw_inv: 12000, eta_lr_inv: 3000 },
        seed: 3,
        ..Default::default()
    };
    let res_a = fit(&mut net, &tr_a, &te_a, &cfg);
    println!("factory model on distribution A: {:.2}%",
             res_a.final_test_acc * 100.0);

    // deploy = checkpoint (integers round-trip exactly)
    std::fs::create_dir_all("results").ok();
    checkpoint::save(&net, "results/deployed.ckpt").unwrap();

    // distribution B: same classes, shifted styles (different seed)
    let (mut tr_b, mut te_b) = loader::load("tiny", "data", 600, 400, 99)
        .expect("dataset B");
    tr_b.mad_normalize();
    te_b.mad_normalize();
    let acc_before = evaluate(&net, &te_b, 64);
    println!("deployed model on shifted distribution B: {:.2}%",
             acc_before * 100.0);

    // local fine-tune: small buffer, few epochs, smaller LR (gamma_inv x3),
    // straight on the integer weights
    let mut local = Network::new(zoo::get("tinycnn").unwrap(), 0);
    checkpoint::load(&mut local, "results/deployed.ckpt").unwrap();
    let ft_cfg = TrainConfig {
        epochs: 40,
        batch: 32,
        hyper: Hyper { gamma_inv: 1536, eta_fw_inv: 12000, eta_lr_inv: 3000 },
        seed: 11,
        ..Default::default()
    };
    let res_ft = fit(&mut local, &tr_b, &te_b, &ft_cfg);
    println!("after local integer-only fine-tune: {:.2}%",
             res_ft.final_test_acc * 100.0);

    assert!(
        res_ft.final_test_acc >= acc_before + 0.02,
        "fine-tune should recover accuracy: {:.3} -> {:.3}",
        acc_before,
        res_ft.final_test_acc
    );
    // and the weights are still deployable integers
    for s in &res_ft.weight_stats {
        assert!(s.bitwidth <= 16);
    }
    println!("fine_tune PASSED (recovered {:+.2} points, weights still \
              int16)",
             (res_ft.final_test_acc - acc_before) * 100.0);
}
