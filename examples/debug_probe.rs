use nitro::data::loader;
use nitro::nn::{zoo, Hyper, Network};
use nitro::util::rng::Pcg32;

fn stats(name: &str, t: &nitro::tensor::ITensor) {
    let (lo, hi) = t.minmax();
    println!("  {name:<14} range [{lo},{hi}] mean|.| {:.2} bits {}", t.mean_abs(), t.bitwidth());
}

fn main() {
    let (mut tr, _) = loader::load("tiny", "data", 1000, 10, 42).unwrap();
    tr.mad_normalize();
    let spec = zoo::get("tinycnn").unwrap();
    let mut net = Network::new(spec, 7);
    let hp = Hyper { gamma_inv: 512, eta_fw_inv: 0, eta_lr_inv: 0 };
    let mut rng = Pcg32::new(1);
    let mut drop = nitro::nn::DropoutRngs::new(1, net.blocks.len());
    let mut order: Vec<usize> = (0..tr.len()).collect();
    for epoch in 0..60 {
        rng.shuffle(&mut order);
        for chunk in order.chunks(64) {
            let (x, labels) = tr.gather(chunk, false);
            net.train_batch(&x, &labels, &hp, &mut drop);
        }
        if epoch % 10 == 0 {
            println!("epoch {epoch}:");
            let (x, _) = tr.gather(&order[..64], false);
            let mut a = x.clone();
            for (i, blk) in net.blocks.iter().enumerate() {
                if matches!(blk.spec, nitro::nn::BlockSpec::Linear(_)) && a.shape.len() > 2 {
                    let (b, f) = a.batch_feat();
                    a = a.reshaped(&[b, f]);
                }
                a = blk.forward(&a);
                stats(&format!("a{} out", i), &a);
                stats(&format!("wf{}", i), &blk.wf);
                stats(&format!("wl{}", i), &blk.wl);
            }
            stats("wo", &net.head.wo);
        }
    }
}
