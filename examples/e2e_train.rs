//! End-to-end system driver (the DESIGN.md "end-to-end validation" run):
//! exercises **all three layers together** on a real small workload.
//!
//! 1. Loads the AOT artifacts (L1 Pallas kernels lowered inside L2 JAX
//!    block graphs) through the PJRT runtime and cross-checks the first
//!    training steps bit-exactly against the native engine.
//! 2. Trains a VGG8B-narrow integer CNN (~1M params) for several hundred
//!    steps on a CIFAR-shaped synthetic dataset with the block-parallel
//!    LES scheduler, logging the loss curve.
//! 3. Reports the App. E.3 bit-width probes at the end.
//!
//! Run via `make artifacts && cargo run --release --example e2e_train`.
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use nitro::coordinator::engine::{Engine, NativeEngine, PjrtEngine};
use nitro::data::loader;
use nitro::nn::{zoo, Hyper, Network};
use nitro::train::{fit, TrainConfig};
use nitro::util::rng::Pcg32;

fn main() {
    // ---- phase 1: three-layer cross-check on the artifact preset -------
    let preset = "tinycnn";
    let dir = format!("artifacts/{preset}");
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        println!("[1/3] PJRT cross-check on {preset} artifacts");
        let mut pjrt = PjrtEngine::load(&dir, 7).expect("artifacts");
        let m = pjrt.manifest.clone();
        let net = Network::new(zoo::get(preset).unwrap(), 7);
        pjrt.set_weights(
            net.blocks.iter().map(|b| b.wf.clone()).collect(),
            net.blocks.iter().map(|b| b.wl.clone()).collect(),
            net.head.wo.clone(),
        );
        let mut native = NativeEngine::new(net, 7, true);
        let hp = Hyper::default();
        let mut rng = Pcg32::new(5);
        for step in 0..3 {
            let mut shape = vec![m.batch];
            shape.extend(&m.input_shape);
            let n: usize = shape.iter().product();
            let x = nitro::tensor::ITensor::from_vec(
                &shape, (0..n).map(|_| rng.range_i32(-127, 127)).collect());
            let labels: Vec<usize> =
                (0..m.batch).map(|i| i % m.num_classes).collect();
            let (bl_n, hl_n, _) = native.train_batch(&x, &labels, &hp);
            let (bl_p, hl_p, _) = pjrt.train_batch(&x, &labels, &hp);
            assert_eq!((&bl_n, hl_n), (&bl_p, hl_p),
                       "layer stack diverged at step {step}");
            println!("  step {step}: native == pjrt (block losses {bl_n:?})");
        }
        println!("  three-layer stack bit-exact ✓");
    } else {
        println!("[1/3] skipped PJRT cross-check (run `make artifacts`)");
    }

    // ---- phase 2: the real training workload ---------------------------
    println!("[2/3] training vgg8b-micro on cifar-like (synthetic, \
              DESIGN.md §Substitutions)");
    let (mut tr, mut te) = loader::load("cifar10", "data", 1500, 300, 42)
        .expect("dataset");
    tr.mad_normalize();
    te.mad_normalize();
    let spec = zoo::get("vgg8b-micro").unwrap();
    println!("  model: {} params ({} at inference)", spec.param_count(),
             spec.inference_param_count());
    let mut net = Network::new(spec, 42);
    let cfg = TrainConfig {
        epochs: 45, // ~2100 steps at batch 32 (clears the integer bootstrap)
        batch: 32,
        hyper: Hyper { gamma_inv: 128, eta_fw_inv: 25000, eta_lr_inv: 3000 },
        seed: 42,
        verbose: true,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let res = fit(&mut net, &tr, &te, &cfg);
    let el = t0.elapsed().as_secs_f64();
    println!("  loss curve (mean head RSS per epoch):");
    for e in &res.epochs {
        let bar = "#".repeat((e.mean_head_loss / res.epochs[0].mean_head_loss
            * 40.0) as usize);
        println!("    epoch {:>2} {:>12.0} {}", e.epoch, e.mean_head_loss, bar);
    }
    println!("  final test accuracy {:.2}% after {:.1}s ({:.1} steps/s)",
             res.final_test_acc * 100.0, el,
             (cfg.epochs * tr.len() / cfg.batch) as f64 / el);
    assert!(res.final_test_acc > 0.25,
            "e2e training must clearly beat 10% chance");
    assert!(res.epochs.last().unwrap().mean_head_loss
            < res.epochs[0].mean_head_loss,
            "loss must decrease");

    // ---- phase 3: bit-width probes (App. E.3) ---------------------------
    println!("[3/3] integer bit-width probes");
    let mut max_bits = 0;
    for s in &res.weight_stats {
        max_bits = max_bits.max(s.bitwidth);
    }
    println!("  max weight bit-width: {max_bits} (paper claims <= 16)");
    assert!(max_bits <= 16);
    println!("e2e_train PASSED");
}
