//! Quickstart: train a small integer-only CNN end to end in ~a minute.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the public API surface: dataset loading + integer MAD
//! pre-processing, the model zoo, IntegerSGD hyper-parameters, the LES
//! trainer, evaluation, and checkpointing — everything integer-only, no
//! float ever touches a weight or activation.

use nitro::data::loader;
use nitro::nn::{zoo, Hyper, Network};
use nitro::train::{checkpoint, fit, TrainConfig};

fn main() {
    // 1. data: synthetic MNIST-shaped set (auto-falls back since no real
    //    MNIST files are bundled), integer MAD normalization (App. B.2)
    let (mut train, mut test) =
        loader::load("tiny", "data", 1200, 300, 42).expect("dataset");
    train.mad_normalize();
    test.mad_normalize();
    println!("dataset: {} train / {} test, shape {:?}", train.len(),
             test.len(), train.shape);

    // 2. model: an integer local-loss CNN from the zoo (paper §3.2)
    let spec = zoo::get("tinycnn").unwrap();
    println!("model: {} ({} params, {} at inference — learning layers drop \
              away, App. E.3)",
             spec.name, spec.param_count(), spec.inference_param_count());
    let mut net = Network::new(spec, 7);

    // 3. train with IntegerSGD (Algorithm 1) + the NITRO amplification
    //    factor wiring, block-parallel LES scheduler on
    let cfg = TrainConfig {
        epochs: 110,
        batch: 64,
        hyper: Hyper { gamma_inv: 512, eta_fw_inv: 12000, eta_lr_inv: 3000 },
        seed: 7,
        verbose: true,
        ..Default::default()
    };
    let res = fit(&mut net, &train, &test, &cfg);
    println!("final test accuracy: {:.2}%", res.final_test_acc * 100.0);
    assert!(res.final_test_acc > 0.4, "quickstart should learn");

    // 4. the weights are int16-range integers (the paper's deployment
    //    story): show the bit-width probe
    for s in &res.weight_stats {
        println!("  {:<12} max|w| {:>6} ({} bits)", s.name, s.max_abs,
                 s.bitwidth);
        assert!(s.bitwidth <= 16, "int16 claim violated");
    }

    // 5. checkpoint: integers round-trip exactly
    std::fs::create_dir_all("results").ok();
    checkpoint::save(&net, "results/quickstart.ckpt").unwrap();
    let mut net2 = Network::new(zoo::get("tinycnn").unwrap(), 999);
    checkpoint::load(&mut net2, "results/quickstart.ckpt").unwrap();
    let acc2 = nitro::train::evaluate(&net2, &test, 64);
    assert_eq!(res.final_test_acc, acc2, "checkpoint must be bit-exact");
    println!("checkpoint round-trip OK -> results/quickstart.ckpt");
}
