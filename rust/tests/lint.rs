//! End-to-end tests for `nitro lint`: the real binary against the real
//! tree (must be clean), against a violating fixture tree (must exit 1
//! with a file:line diagnostic naming the rule), and the --fix-allow
//! stub flow (must keep the tree red until reasons are written).

use std::path::PathBuf;
use std::process::Command;

fn run(args: &[&str]) -> (i32, String, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_nitro"))
        .args(args)
        .output()
        .expect("spawn nitro");
    (
        out.status.code().unwrap_or(-1),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

/// A throwaway repo-shaped tree with one violating file.
fn fixture_tree(name: &str, src: &str) -> (PathBuf, PathBuf) {
    let root = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&root);
    let dir = root.join("rust").join("src").join("tensor");
    std::fs::create_dir_all(&dir).unwrap();
    let file = dir.join("ops_int.rs");
    std::fs::write(&file, src).unwrap();
    (root, file)
}

#[test]
fn tree_is_clean_and_json_schema_is_stable() {
    // cwd is the package root (rust/); find_root walks up to the repo
    let (code, stdout, stderr) = run(&["lint", "--json"]);
    assert_eq!(code, 0, "tree not lint-clean:\nstdout: {stdout}\n{stderr}");
    for key in [
        "\"schema_version\":1",
        "\"files_scanned\":",
        "\"violations\":0",
        "\"allowed\":",
        "\"findings\":",
    ] {
        assert!(stdout.contains(key), "missing {key} in: {stdout}");
    }
}

#[test]
fn violations_exit_1_with_file_line_and_rule() {
    let (root, _) = fixture_tree(
        "nitro_lint_fixture",
        "fn f(a: i32, b: i32) -> i32 { a + b }\n",
    );
    let (code, stdout, _) =
        run(&["lint", "--root", root.to_str().unwrap()]);
    assert_eq!(code, 1, "{stdout}");
    assert!(stdout.contains("ops_int.rs:1"), "{stdout}");
    assert!(stdout.contains("int-discipline"), "{stdout}");
    assert!(stdout.contains("1 violation(s)"), "{stdout}");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn fix_allow_inserts_stub_that_keeps_the_tree_red() {
    let (root, file) = fixture_tree(
        "nitro_lint_fixture_fix",
        "fn f(o: Option<u32>, b: i32) -> i32 { b.wrapping_add(1) }\n\
         fn g(a: i32, b: i32) -> i32 { a * b }\n",
    );
    let (code, _, stderr) = run(&[
        "lint",
        "--fix-allow",
        "--root",
        root.to_str().unwrap(),
    ]);
    assert_eq!(code, 1);
    assert!(stderr.contains("inserted 1 placeholder"), "{stderr}");
    let patched = std::fs::read_to_string(&file).unwrap();
    assert!(
        patched.contains("allow(int-discipline) FIXME"),
        "{patched}"
    );
    // the stub reason is rejected on purpose: still red, now with an
    // allow-syntax diagnostic alongside the unsuppressed violation
    let (code, stdout, _) =
        run(&["lint", "--root", root.to_str().unwrap()]);
    assert_eq!(code, 1, "{stdout}");
    assert!(stdout.contains("allow-syntax"), "{stdout}");
    assert!(stdout.contains("int-discipline"), "{stdout}");
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn bad_root_is_an_internal_error_not_a_finding() {
    let (code, _, stderr) =
        run(&["lint", "--root", "/nonexistent/nitro/lint/root"]);
    assert_eq!(code, 2, "{stderr}");
    assert!(stderr.contains("repo root"), "{stderr}");
}
