//! PJRT integration: the artifact-backed engine (L1 Pallas kernels inside
//! L2 JAX graphs, AOT-compiled, executed from Rust) must be bit-identical
//! to the pure-Rust NativeEngine — the three-layer equivalence the whole
//! architecture rests on.
//!
//! Requires `make artifacts`. Tests skip cleanly if artifacts are missing.
//!
//! The whole target is additionally gated on the `pjrt` cargo feature
//! (Cargo.toml `required-features` plus the cfg below): a default build has
//! only the stub runtime, so these tests would always fail to load.

#![cfg(feature = "pjrt")]

use nitro::coordinator::engine::{Engine, NativeEngine, PjrtEngine};
use nitro::nn::{zoo, Hyper, Network};
use nitro::util::rng::Pcg32;

fn have_artifacts(preset: &str) -> bool {
    let ok = std::path::Path::new(&format!("artifacts/{preset}/manifest.json"))
        .exists();
    if !ok {
        eprintln!("skipping pjrt test: run `make artifacts` first");
    }
    ok
}

fn engines_match(preset: &str, steps: usize) {
    if !have_artifacts(preset) {
        return;
    }
    let dir = format!("artifacts/{preset}");
    let mut pjrt = PjrtEngine::load(&dir, 7).expect("load artifacts");
    let m = pjrt.manifest.clone();

    // identical starting weights for both engines
    let spec = zoo::get(preset).unwrap();
    let net = Network::new(spec, 7);
    let wf: Vec<_> = net.blocks.iter().map(|b| b.wf.clone()).collect();
    let wl: Vec<_> = net.blocks.iter().map(|b| b.wl.clone()).collect();
    pjrt.set_weights(wf, wl, net.head.wo.clone());
    let mut native = NativeEngine::new(net, 7, true);

    let hp = Hyper { gamma_inv: 512, eta_fw_inv: 12000, eta_lr_inv: 3000 };
    let mut rng = Pcg32::new(123);
    for step in 0..steps {
        let mut shape = vec![m.batch];
        shape.extend(&m.input_shape);
        let n: usize = shape.iter().product();
        let x = nitro::tensor::ITensor::from_vec(
            &shape,
            (0..n).map(|_| rng.range_i32(-127, 127)).collect(),
        );
        let labels: Vec<usize> =
            (0..m.batch).map(|_| rng.below(m.num_classes as u32) as usize)
                .collect();
        let (bl_n, hl_n, c_n) = native.train_batch(&x, &labels, &hp);
        let (bl_p, hl_p, c_p) = pjrt.train_batch(&x, &labels, &hp);
        assert_eq!(bl_n, bl_p, "step {step}: block losses native != pjrt");
        assert_eq!(hl_n, hl_p, "step {step}: head loss native != pjrt");
        assert_eq!(c_n, c_p, "step {step}: correct-count diverged");
        // inference parity on the same batch
        let y_n = native.infer(&x);
        let y_p = pjrt.infer(&x);
        assert_eq!(y_n, y_p, "step {step}: inference diverged");
    }
    // full weight equality at the end
    let wn = native.weights();
    let wp = pjrt.weights();
    assert_eq!(wn.len(), wp.len());
    for (i, (a, b)) in wn.iter().zip(&wp).enumerate() {
        assert_eq!(a, b, "weight tensor {i} diverged after {steps} steps");
    }
}

#[test]
fn tinycnn_native_pjrt_bitexact() {
    engines_match("tinycnn", 3);
}

#[test]
fn mlp1_mini_native_pjrt_bitexact() {
    engines_match("mlp1-mini", 3);
}

#[test]
fn runtime_loads_and_reports_platform() {
    if !have_artifacts("tinycnn") {
        return;
    }
    let rt = nitro::runtime::Runtime::cpu().unwrap();
    let platform = rt.platform();
    assert!(platform.to_lowercase().contains("cpu")
            || platform.to_lowercase().contains("host"),
            "platform = {platform}");
    // load one artifact directly
    let exe = rt.load("artifacts/tinycnn/infer.hlo.txt").unwrap();
    assert!(exe.name.ends_with("infer.hlo.txt"));
}
