//! End-to-end CLI tests: drive the actual `nitro` binary the way a user
//! would (train -> checkpoint -> eval, zoo listing, error paths).

use std::process::Command;

fn nitro() -> Command {
    Command::new(env!("CARGO_BIN_EXE_nitro"))
}

fn run(args: &[&str]) -> (i32, String, String) {
    let out = nitro().args(args).output().expect("spawn nitro");
    (
        out.status.code().unwrap_or(-1),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn zoo_lists_paper_architectures() {
    let (code, stdout, _) = run(&["zoo"]);
    assert_eq!(code, 0);
    for preset in ["mlp1", "mlp4", "vgg8b", "vgg11b", "tinycnn"] {
        assert!(stdout.contains(preset), "zoo missing {preset}:\n{stdout}");
    }
}

#[test]
fn help_and_unknown_subcommand() {
    let (code, _, stderr) = run(&["--help"]);
    assert_eq!(code, 0);
    assert!(stderr.contains("experiment"));
    let (code, _, stderr) = run(&["frobnicate"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown subcommand"));
}

#[test]
fn train_checkpoint_eval_roundtrip() {
    let dir = std::env::temp_dir().join("nitro_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("m.ckpt");
    let ckpt_s = ckpt.to_str().unwrap();
    // short training run: tests plumbing, not accuracy (bootstrap needs
    // ~100 epochs; 12 keeps the test fast)
    let (code, stdout, stderr) = run(&[
        "train", "--preset", "tinycnn", "--dataset", "tiny", "--epochs",
        "12", "--n-train", "300", "--n-test", "60", "--quiet", "--save",
        ckpt_s,
    ]);
    assert_eq!(code, 0, "train failed: {stderr}");
    assert!(stdout.contains("final test accuracy"), "{stdout}");
    assert!(ckpt.exists());
    let (code, stdout, stderr) = run(&[
        "eval", ckpt_s, "--preset", "tinycnn", "--dataset", "tiny",
        "--n-test", "60",
    ]);
    assert_eq!(code, 0, "eval failed: {stderr}");
    assert!(stdout.contains("accuracy:"), "{stdout}");
}

#[test]
fn train_rejects_unknown_preset_and_dataset() {
    let (code, _, stderr) = run(&["train", "--preset", "nope"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown preset"), "{stderr}");
    let (code, _, stderr) = run(&["train", "--dataset", "nope"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown dataset"), "{stderr}");
}

#[test]
fn experiment_rejects_unknown_name() {
    let (code, _, stderr) = run(&["experiment", "bogus"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown experiment"), "{stderr}");
    let (code, _, stderr) = run(&["experiment", "table1", "--scale", "weird"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown scale"), "{stderr}");
}

#[test]
fn run_spec_smoke_emits_bench_json() {
    let dir = std::env::temp_dir().join("nitro_cli_runspec");
    std::fs::create_dir_all(&dir).unwrap();
    let dir_s = dir.to_str().unwrap();
    // the committed spec, one epoch (plumbing, not accuracy); cwd of
    // integration tests is the package root (rust/)
    let (code, stdout, stderr) = run(&[
        "run-spec", "../experiments/smoke.json", "--epochs", "1",
        "--out-dir", dir_s, "--bench-dir", dir_s,
    ]);
    assert_eq!(code, 0, "run-spec failed: {stderr}");
    assert!(stdout.contains("BENCH_smoke.json"), "{stdout}");
    let bench = std::fs::read_to_string(dir.join("BENCH_smoke.json")).unwrap();
    assert!(bench.contains("\"schema_version\""), "{bench}");
    assert!(bench.contains("\"final_test_acc\""), "{bench}");

    let (code, _, stderr) = run(&["run-spec", "does/not/exist.json"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("exist.json"), "{stderr}");
}

/// Strip machine-dependent keys (timings, RSS, scheduler label) so BENCH
/// records from different scheduler runs can be compared byte-for-byte.
fn strip_volatile(j: nitro::util::jsonio::Json) -> nitro::util::jsonio::Json {
    use nitro::util::jsonio::Json;
    const VOLATILE: &[&str] = &["secs", "wall_secs", "peak_rss_kb",
                                "scheduler"];
    match j {
        Json::Object(m) => Json::Object(
            m.into_iter()
                .filter(|(k, _)| !VOLATILE.contains(&k.as_str()))
                .map(|(k, v)| (k, strip_volatile(v)))
                .collect(),
        ),
        Json::Array(v) => {
            Json::Array(v.into_iter().map(strip_volatile).collect())
        }
        other => other,
    }
}

#[test]
fn run_spec_metrics_identical_across_all_three_schedulers() {
    // the scheduler bit-identity contract, end to end through the binary:
    // same spec, three schedulers, byte-identical metrics once the
    // timing/scheduler keys are stripped
    let dir = std::env::temp_dir().join("nitro_cli_sched");
    std::fs::create_dir_all(&dir).unwrap();
    let mut records = Vec::new();
    for sched in ["sequential", "block-parallel", "pipelined"] {
        let sub = dir.join(sched);
        std::fs::create_dir_all(&sub).unwrap();
        let sub_s = sub.to_str().unwrap();
        // NITRO_WORKERS=8 covers tinycnn's 4 stages so the pipelined run
        // genuinely pipelines even on small test machines (below blocks+1
        // workers it would degrade to block-parallel and prove nothing)
        let out = nitro()
            .env("NITRO_WORKERS", "8")
            .args([
                "run-spec", "../experiments/smoke.json", "--epochs", "1",
                "--scheduler", sched, "--out-dir", sub_s, "--bench-dir",
                sub_s,
            ])
            .output()
            .expect("spawn nitro");
        let code = out.status.code().unwrap_or(-1);
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert_eq!(code, 0, "{sched}: {stderr}");
        let j = nitro::util::jsonio::Json::parse_file(
            sub.join("BENCH_smoke.json").to_str().unwrap(),
        )
        .unwrap();
        records.push(strip_volatile(j));
    }
    assert_eq!(records[0], records[1],
               "block-parallel metrics differ from sequential");
    assert_eq!(records[0], records[2],
               "pipelined metrics differ from sequential");

    let (code, _, stderr) =
        run(&["run-spec", "../experiments/smoke.json", "--scheduler", "warp"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown scheduler"), "{stderr}");
}

#[test]
fn bench_kernels_emits_schema_versioned_json() {
    let dir = std::env::temp_dir().join("nitro_cli_benchk");
    std::fs::create_dir_all(&dir).unwrap();
    let out = dir.join("BENCH_kernels.json");
    let out_s = out.to_str().unwrap();
    // quick subset with a tiny budget: plumbing, not timings (the test
    // binary is unoptimized)
    let (code, stdout, stderr) = run(&[
        "bench-kernels", "--quick", "--budget", "0.005", "--out", out_s,
    ]);
    assert_eq!(code, 0, "bench-kernels failed: {stderr}");
    assert!(stdout.contains("bit-exactness: all kernel paths agree"),
            "{stdout}");
    assert!(stdout.contains("pool speedup vs per-call spawn"), "{stdout}");
    let bench = std::fs::read_to_string(&out).unwrap();
    for key in ["\"schema_version\"", "\"rows\"", "\"bitexact\": true",
                "\"pool_speedup_vs_spawn\""] {
        assert!(bench.contains(key), "missing {key} in {bench}");
    }
    // baseline comparison is advisory: self-comparison exits 0 even with
    // noisy timings; a missing baseline file is a hard error
    let out2 = dir.join("BENCH_kernels2.json");
    let (code, stdout, stderr) = run(&[
        "bench-kernels", "--quick", "--budget", "0.005", "--out",
        out2.to_str().unwrap(), "--baseline", out_s,
    ]);
    assert_eq!(code, 0, "baseline comparison failed: {stderr}");
    assert!(stdout.contains("rows compared"), "{stdout}");
    let (code, _, stderr) = run(&[
        "bench-kernels", "--quick", "--budget", "0.005", "--out",
        out2.to_str().unwrap(), "--baseline", "does/not/exist.json",
    ]);
    assert_eq!(code, 2);
    assert!(stderr.contains("exist.json"), "{stderr}");
}

#[test]
fn runtime_smoke_if_artifacts_present() {
    if !std::path::Path::new("artifacts/tinycnn/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (code, stdout, stderr) = run(&["runtime", "--preset", "tinycnn"]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("smoke check PASSED"), "{stdout}");
}
