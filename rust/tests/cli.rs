//! End-to-end CLI tests: drive the actual `nitro` binary the way a user
//! would (train -> checkpoint -> eval, zoo listing, error paths).

use std::process::Command;

fn nitro() -> Command {
    Command::new(env!("CARGO_BIN_EXE_nitro"))
}

fn run(args: &[&str]) -> (i32, String, String) {
    let out = nitro().args(args).output().expect("spawn nitro");
    (
        out.status.code().unwrap_or(-1),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn zoo_lists_paper_architectures() {
    let (code, stdout, _) = run(&["zoo"]);
    assert_eq!(code, 0);
    for preset in ["mlp1", "mlp4", "vgg8b", "vgg11b", "tinycnn"] {
        assert!(stdout.contains(preset), "zoo missing {preset}:\n{stdout}");
    }
}

#[test]
fn help_and_unknown_subcommand() {
    let (code, _, stderr) = run(&["--help"]);
    assert_eq!(code, 0);
    assert!(stderr.contains("experiment"));
    let (code, _, stderr) = run(&["frobnicate"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown subcommand"));
}

#[test]
fn train_checkpoint_eval_roundtrip() {
    let dir = std::env::temp_dir().join("nitro_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("m.ckpt");
    let ckpt_s = ckpt.to_str().unwrap();
    // short training run: tests plumbing, not accuracy (bootstrap needs
    // ~100 epochs; 12 keeps the test fast)
    let (code, stdout, stderr) = run(&[
        "train", "--preset", "tinycnn", "--dataset", "tiny", "--epochs",
        "12", "--n-train", "300", "--n-test", "60", "--quiet", "--save",
        ckpt_s,
    ]);
    assert_eq!(code, 0, "train failed: {stderr}");
    assert!(stdout.contains("final test accuracy"), "{stdout}");
    assert!(ckpt.exists());
    let (code, stdout, stderr) = run(&[
        "eval", ckpt_s, "--preset", "tinycnn", "--dataset", "tiny",
        "--n-test", "60",
    ]);
    assert_eq!(code, 0, "eval failed: {stderr}");
    assert!(stdout.contains("accuracy:"), "{stdout}");
}

#[test]
fn train_rejects_unknown_preset_and_dataset() {
    let (code, _, stderr) = run(&["train", "--preset", "nope"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown preset"), "{stderr}");
    let (code, _, stderr) = run(&["train", "--dataset", "nope"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown dataset"), "{stderr}");
}

#[test]
fn experiment_rejects_unknown_name() {
    let (code, _, stderr) = run(&["experiment", "bogus"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown experiment"), "{stderr}");
    let (code, _, stderr) = run(&["experiment", "table1", "--scale", "weird"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown scale"), "{stderr}");
}

#[test]
fn run_spec_smoke_emits_bench_json() {
    let dir = std::env::temp_dir().join("nitro_cli_runspec");
    std::fs::create_dir_all(&dir).unwrap();
    let dir_s = dir.to_str().unwrap();
    // the committed spec, one epoch (plumbing, not accuracy); cwd of
    // integration tests is the package root (rust/)
    let (code, stdout, stderr) = run(&[
        "run-spec", "../experiments/smoke.json", "--epochs", "1",
        "--out-dir", dir_s, "--bench-dir", dir_s,
    ]);
    assert_eq!(code, 0, "run-spec failed: {stderr}");
    assert!(stdout.contains("BENCH_smoke.json"), "{stdout}");
    let bench = std::fs::read_to_string(dir.join("BENCH_smoke.json")).unwrap();
    assert!(bench.contains("\"schema_version\""), "{bench}");
    assert!(bench.contains("\"final_test_acc\""), "{bench}");

    let (code, _, stderr) = run(&["run-spec", "does/not/exist.json"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("exist.json"), "{stderr}");
}

/// Strip machine-dependent keys (timings, RSS, scheduler/replica labels)
/// so BENCH records from different scheduler or replica-count runs can
/// be compared byte-for-byte.
fn strip_volatile(j: nitro::util::jsonio::Json) -> nitro::util::jsonio::Json {
    use nitro::util::jsonio::Json;
    const VOLATILE: &[&str] = &["secs", "wall_secs", "peak_rss_kb",
                                "scheduler", "replicas"];
    match j {
        Json::Object(m) => Json::Object(
            m.into_iter()
                .filter(|(k, _)| !VOLATILE.contains(&k.as_str()))
                .map(|(k, v)| (k, strip_volatile(v)))
                .collect(),
        ),
        Json::Array(v) => {
            Json::Array(v.into_iter().map(strip_volatile).collect())
        }
        other => other,
    }
}

#[test]
fn run_spec_metrics_identical_across_all_three_schedulers() {
    // the scheduler bit-identity contract, end to end through the binary:
    // same spec, three schedulers, byte-identical metrics once the
    // timing/scheduler keys are stripped
    let dir = std::env::temp_dir().join("nitro_cli_sched");
    std::fs::create_dir_all(&dir).unwrap();
    let mut records = Vec::new();
    for sched in ["sequential", "block-parallel", "pipelined"] {
        let sub = dir.join(sched);
        std::fs::create_dir_all(&sub).unwrap();
        let sub_s = sub.to_str().unwrap();
        // NITRO_WORKERS=8 covers tinycnn's 4 stages so the pipelined run
        // genuinely pipelines even on small test machines (below blocks+1
        // workers it would degrade to block-parallel and prove nothing)
        let out = nitro()
            .env("NITRO_WORKERS", "8")
            .args([
                "run-spec", "../experiments/smoke.json", "--epochs", "1",
                "--scheduler", sched, "--out-dir", sub_s, "--bench-dir",
                sub_s,
            ])
            .output()
            .expect("spawn nitro");
        let code = out.status.code().unwrap_or(-1);
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert_eq!(code, 0, "{sched}: {stderr}");
        let j = nitro::util::jsonio::Json::parse_file(
            sub.join("BENCH_smoke.json").to_str().unwrap(),
        )
        .unwrap();
        records.push(strip_volatile(j));
    }
    assert_eq!(records[0], records[1],
               "block-parallel metrics differ from sequential");
    assert_eq!(records[0], records[2],
               "pipelined metrics differ from sequential");

    let (code, _, stderr) =
        run(&["run-spec", "../experiments/smoke.json", "--scheduler", "warp"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("unknown scheduler"), "{stderr}");
}

#[test]
fn run_spec_metrics_identical_across_replica_counts() {
    // the replicated-training determinism contract end to end through
    // the binary: same spec, replicas 1/2/4, byte-identical metrics once
    // the timing/scheduler/replicas keys are stripped
    let dir = std::env::temp_dir().join("nitro_cli_replicas");
    std::fs::create_dir_all(&dir).unwrap();
    let mut records = Vec::new();
    for n in ["1", "2", "4"] {
        let sub = dir.join(format!("r{n}"));
        std::fs::create_dir_all(&sub).unwrap();
        let sub_s = sub.to_str().unwrap();
        // NITRO_WORKERS=8 lets the shard compute genuinely fan out
        let out = nitro()
            .env("NITRO_WORKERS", "8")
            .args([
                "run-spec", "../experiments/smoke.json", "--epochs", "1",
                "--replicas", n, "--out-dir", sub_s, "--bench-dir", sub_s,
            ])
            .output()
            .expect("spawn nitro");
        let code = out.status.code().unwrap_or(-1);
        let stderr = String::from_utf8_lossy(&out.stderr);
        assert_eq!(code, 0, "replicas={n}: {stderr}");
        let raw = std::fs::read_to_string(sub.join("BENCH_smoke.json"))
            .unwrap();
        // the record carries the replica count actually used
        assert!(raw.contains(&format!("\"replicas\": {n}")), "{raw}");
        records.push(strip_volatile(
            nitro::util::jsonio::Json::parse(&raw).unwrap(),
        ));
    }
    assert_eq!(records[0], records[1],
               "replicas=2 metrics differ from replicas=1");
    assert_eq!(records[0], records[2],
               "replicas=4 metrics differ from replicas=1");
}

#[test]
fn train_cli_replicas_metric_identical() {
    // `nitro train --replicas N`: stdout (param counts + final accuracy)
    // must be byte-identical across replica counts; 120 samples at the
    // default batch 64 end on a partial batch, so uneven shards are
    // exercised too
    let mut outputs = Vec::new();
    for n in ["1", "3"] {
        let (code, stdout, stderr) = run(&[
            "train", "--preset", "tinycnn", "--dataset", "tiny",
            "--epochs", "3", "--n-train", "120", "--n-test", "40",
            "--p-c", "0.2", "--p-l", "0.2", "--quiet", "--replicas", n,
        ]);
        assert_eq!(code, 0, "replicas={n}: {stderr}");
        assert!(stdout.contains("final test accuracy"), "{stdout}");
        outputs.push(stdout);
    }
    assert_eq!(outputs[0], outputs[1],
               "--replicas 3 changed the training metrics");
    // 0 is rejected, matching the spec parser — not silently clamped
    let (code, _, stderr) =
        run(&["train", "--preset", "tinycnn", "--replicas", "0"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("replicas"), "{stderr}");
}

/// The full fault-tolerance story through the real binary: a 2-rank
/// TCP distributed run where rank 1 is killed mid-training by an
/// injected crash (exit 43), restarted with `--resume`, catches up from
/// its periodic checkpoint and re-enters the group — and every rank's
/// final checkpoint is byte-identical to a single-process
/// `--replicas 2` run on the same data.
#[test]
fn train_distributed_crash_rejoin_matches_single_process() {
    use std::net::TcpListener;
    let dir = std::env::temp_dir().join("nitro_cli_dist");
    std::fs::create_dir_all(&dir).unwrap();
    let path =
        |n: &str| dir.join(n).to_str().unwrap().to_string();
    let common: &[&str] = &[
        "--preset", "tinycnn", "--dataset", "tiny", "--epochs", "4",
        "--batch", "32", "--n-train", "120", "--n-test", "40", "--p-c",
        "0.2", "--p-l", "0.2", "--quiet",
    ];
    // ground truth: one process, two in-process replicas
    let ref_ckpt = path("ref.ckpt");
    let args =
        [&["train"][..], common, &["--replicas", "2", "--save",
                                   &ref_ckpt]]
            .concat();
    let (code, _, stderr) = run(&args);
    assert_eq!(code, 0, "reference run failed: {stderr}");
    // two free loopback ports (bound then released; the trainer's bind
    // retry loop covers the reuse window)
    let la = TcpListener::bind("127.0.0.1:0").unwrap();
    let lb = TcpListener::bind("127.0.0.1:0").unwrap();
    let peers = format!("127.0.0.1:{},127.0.0.1:{}",
                        la.local_addr().unwrap().port(),
                        lb.local_addr().unwrap().port());
    drop((la, lb));
    let (f0, f1) = (path("final0.ckpt"), path("final1.ckpt"));
    let ck1 = path("ck1.ckpt");
    let spawn_rank = |rank: &str, extra: &[&str]| {
        let args = [&["train"][..], common,
                    &["--distributed", "--rank", rank, "--peers",
                      &peers],
                    extra]
            .concat();
        nitro()
            .args(&args)
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::piped())
            .spawn()
            .expect("spawn nitro rank")
    };
    // 120 samples at batch 32 = 4 steps/epoch; rank 1 checkpoints every
    // 2 epochs (so a state exists at step 8) and is crashed at step 10
    let r0 = spawn_rank("0", &["--save", &f0]);
    let r1 = spawn_rank(
        "1",
        &["--save", &f1, "--checkpoint", &ck1, "--checkpoint-every",
          "2", "--fault-plan",
          r#"[{"kind": "crash", "rank": 1, "step": 10}]"#],
    );
    let out1 = r1.wait_with_output().unwrap();
    assert_eq!(
        out1.status.code(),
        Some(43),
        "rank 1 should die with the crash exit code: {}",
        String::from_utf8_lossy(&out1.stderr)
    );
    // elastic rejoin: same rank, same port, resumed from the checkpoint
    let r1b = spawn_rank(
        "1",
        &["--save", &f1, "--checkpoint", &ck1, "--resume"],
    );
    let out0 = r0.wait_with_output().unwrap();
    assert_eq!(out0.status.code(), Some(0), "rank 0: {}",
               String::from_utf8_lossy(&out0.stderr));
    let out1b = r1b.wait_with_output().unwrap();
    assert_eq!(out1b.status.code(), Some(0), "rank 1 rejoin: {}",
               String::from_utf8_lossy(&out1b.stderr));
    let reference = std::fs::read(&ref_ckpt).unwrap();
    assert_eq!(std::fs::read(&f0).unwrap(), reference,
               "rank 0 weights diverged from single-process training");
    assert_eq!(std::fs::read(&f1).unwrap(), reference,
               "rejoined rank 1 weights diverged from single-process \
                training");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bench_kernels_emits_schema_versioned_json() {
    let dir = std::env::temp_dir().join("nitro_cli_benchk");
    std::fs::create_dir_all(&dir).unwrap();
    let out = dir.join("BENCH_kernels.json");
    let out_s = out.to_str().unwrap();
    let serve_out = dir.join("BENCH_serve.json");
    let serve_s = serve_out.to_str().unwrap();
    // quick subset with a tiny budget: plumbing, not timings (the test
    // binary is unoptimized)
    let (code, stdout, stderr) = run(&[
        "bench-kernels", "--quick", "--budget", "0.005", "--out", out_s,
        "--serve-out", serve_s,
    ]);
    assert_eq!(code, 0, "bench-kernels failed: {stderr}");
    assert!(stdout.contains("bit-exactness: all kernel paths agree"),
            "{stdout}");
    assert!(stdout.contains("pool speedup vs per-call spawn"), "{stdout}");
    let bench = std::fs::read_to_string(&out).unwrap();
    for key in ["\"schema_version\"", "\"rows\"", "\"bitexact\": true",
                "\"pool_speedup_vs_spawn\""] {
        assert!(bench.contains(key), "missing {key} in {bench}");
    }
    // the serve-throughput record rides along, schema-versioned
    let serve = std::fs::read_to_string(&serve_out).unwrap();
    for key in ["\"schema_version\"", "\"serve_throughput\"",
                "\"requests_per_sec\"", "\"p99_ns\"",
                "\"bitexact\": true"] {
        assert!(serve.contains(key), "missing {key} in {serve}");
    }
    // baseline comparison is advisory: self-comparison exits 0 even with
    // noisy timings; a missing baseline file is a hard error
    let out2 = dir.join("BENCH_kernels2.json");
    let (code, stdout, stderr) = run(&[
        "bench-kernels", "--quick", "--budget", "0.005", "--out",
        out2.to_str().unwrap(), "--serve-out", serve_s, "--baseline", out_s,
    ]);
    assert_eq!(code, 0, "baseline comparison failed: {stderr}");
    assert!(stdout.contains("rows compared"), "{stdout}");
    let (code, _, stderr) = run(&[
        "bench-kernels", "--quick", "--budget", "0.005", "--out",
        out2.to_str().unwrap(), "--serve-out", serve_s, "--baseline",
        "does/not/exist.json",
    ]);
    assert_eq!(code, 2);
    assert!(stderr.contains("exist.json"), "{stderr}");
}

/// Train a quick tinycnn checkpoint into `dir` and return its path plus
/// a deterministic 2-sample input JSON file for it (tinycnn input is
/// 1x8x8 = 64 ints per sample).
fn trained_ckpt_and_input(dir: &std::path::Path) -> (String, String) {
    std::fs::create_dir_all(dir).unwrap();
    let ckpt = dir.join("m.ckpt");
    let ckpt_s = ckpt.to_str().unwrap().to_string();
    let (code, _, stderr) = run(&[
        "train", "--preset", "tinycnn", "--dataset", "tiny", "--epochs",
        "2", "--n-train", "120", "--n-test", "40", "--quiet", "--save",
        &ckpt_s,
    ]);
    assert_eq!(code, 0, "train failed: {stderr}");
    let vals: Vec<String> =
        (0..128).map(|i| ((i * 37) % 255 - 127).to_string()).collect();
    let input = dir.join("input.json");
    std::fs::write(&input, format!("[{}]", vals.join(","))).unwrap();
    (ckpt_s, input.to_str().unwrap().to_string())
}

#[test]
fn predict_scores_checkpoint_bit_identically_across_runs_and_workers() {
    let dir = std::env::temp_dir().join("nitro_cli_predict");
    let (ckpt, input) = trained_ckpt_and_input(&dir);
    let mut outputs = Vec::new();
    // twice with default workers, once in the deterministic
    // single-thread mode: all byte-identical
    for workers in [None, None, Some("1")] {
        let mut cmd = nitro();
        if let Some(w) = workers {
            cmd.env("NITRO_WORKERS", w);
        }
        let out = cmd
            .args(["predict", ckpt.as_str(), input.as_str()])
            .output()
            .expect("spawn nitro");
        assert_eq!(out.status.code(), Some(0), "{}",
                   String::from_utf8_lossy(&out.stderr));
        outputs.push(out.stdout);
    }
    assert_eq!(outputs[0], outputs[1], "predict is not deterministic");
    assert_eq!(outputs[0], outputs[2],
               "NITRO_WORKERS=1 changed the logits");
    let text = String::from_utf8_lossy(&outputs[0]);
    assert!(text.contains("\"model\": \"tinycnn\""), "{text}");
    assert!(text.contains("\"logits\""), "{text}");
    assert!(text.contains("\"argmax\""), "{text}");
}

#[test]
fn predict_rejects_corrupt_checkpoints_without_panicking() {
    let dir = std::env::temp_dir().join("nitro_cli_predict_corrupt");
    let (ckpt, input) = trained_ckpt_and_input(&dir);
    let full = std::fs::read(&ckpt).unwrap();
    // truncated, garbage, and oversized-header corruptions must all exit
    // with a clean error (code 2) — a panic/abort would give a different
    // code or a signal (None)
    let cases: Vec<Vec<u8>> = vec![
        full[..full.len() / 2].to_vec(),
        full[..9].to_vec(),
        b"total garbage".to_vec(),
        {
            let mut v = full.clone();
            v[7..11].copy_from_slice(&u32::MAX.to_le_bytes());
            v
        },
    ];
    for (i, bytes) in cases.iter().enumerate() {
        let bad = dir.join(format!("bad{i}.ckpt"));
        std::fs::write(&bad, bytes).unwrap();
        let (code, _, stderr) =
            run(&["predict", bad.to_str().unwrap(), &input]);
        assert_eq!(code, 2, "case {i}: expected clean error, got {stderr}");
        assert!(!stderr.contains("panicked"), "case {i}: {stderr}");
    }
    // malformed input documents error cleanly too
    let badin = dir.join("badin.json");
    std::fs::write(&badin, "[1, 2, 3]").unwrap();
    let (code, _, stderr) = run(&["predict", &ckpt,
                                  badin.to_str().unwrap()]);
    assert_eq!(code, 2);
    assert!(stderr.contains("sample size"), "{stderr}");
}

#[test]
fn serve_stdio_answers_json_lines_matching_predict() {
    use std::io::{BufRead, BufReader, Write};
    let dir = std::env::temp_dir().join("nitro_cli_serve");
    let (ckpt, input) = trained_ckpt_and_input(&dir);
    // ground truth from the one-shot path
    let (code, predict_out, stderr) = run(&["predict", &ckpt, &input]);
    assert_eq!(code, 0, "{stderr}");
    let expect = nitro::util::jsonio::Json::parse(&predict_out).unwrap();
    let flat: Vec<String> = (0..128)
        .map(|i| ((i * 37) % 255 - 127).to_string())
        .collect();
    let mut child = nitro()
        .args(["serve", ckpt.as_str()])
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn nitro serve");
    {
        let stdin = child.stdin.as_mut().unwrap();
        // request 1: both samples in one request; request 2: sample 0
        // alone (batch composition must not change the logits); then a
        // bad request that must produce an error line, not kill the
        // server
        writeln!(stdin, "{{\"id\": 1, \"input\": [{}]}}", flat.join(","))
            .unwrap();
        writeln!(stdin, "{{\"id\": 2, \"input\": [{}]}}",
                 flat[..64].join(","))
            .unwrap();
        writeln!(stdin, "{{\"id\": 3, \"input\": [1, 2]}}").unwrap();
        writeln!(stdin, "{{\"id\": 4, \"input\": [{}]}}",
                 flat[..64].join(","))
            .unwrap();
    }
    drop(child.stdin.take()); // EOF ends the server loop
    let reader = BufReader::new(child.stdout.take().unwrap());
    let lines: Vec<String> =
        reader.lines().map(|l| l.unwrap()).collect();
    let status = child.wait().unwrap();
    assert!(status.success(), "serve exited {status}");
    assert_eq!(lines.len(), 4, "{lines:?}");
    let parse =
        |s: &String| nitro::util::jsonio::Json::parse(s).unwrap();
    let r1 = parse(&lines[0]);
    assert_eq!(r1.req("id").unwrap().as_i64(), Some(1));
    assert_eq!(r1.req("logits").unwrap(), expect.req("logits").unwrap(),
               "serve logits differ from predict");
    let r2 = parse(&lines[1]);
    let expect_rows = expect.req("logits").unwrap().as_array().unwrap();
    assert_eq!(r2.req("logits").unwrap().as_array().unwrap()[0],
               expect_rows[0],
               "micro-batch composition changed sample-0 logits");
    let r3 = parse(&lines[2]);
    assert!(r3.get("error").is_some(), "{}", lines[2]);
    let r4 = parse(&lines[3]);
    assert_eq!(r4.req("logits").unwrap().as_array().unwrap()[0],
               expect_rows[0], "server died or drifted after bad request");
}

#[test]
fn serve_models_flag_v1_round_trip_and_v0_compat() {
    use std::io::{BufRead, BufReader, Write};
    let dir = std::env::temp_dir().join("nitro_cli_serve_v1");
    let (ckpt, input) = trained_ckpt_and_input(&dir);
    let (code, predict_out, stderr) = run(&["predict", &ckpt, &input]);
    assert_eq!(code, 0, "{stderr}");
    let expect = nitro::util::jsonio::Json::parse(&predict_out).unwrap();
    let flat: Vec<String> = (0..64)
        .map(|i| ((i * 37) % 255 - 127).to_string())
        .collect();
    let mut child = nitro()
        .args(["serve", "--models", &format!("tc={ckpt}"), "--shards",
               "2"])
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn nitro serve");
    {
        let stdin = child.stdin.as_mut().unwrap();
        // v1 predict under the alias, a v1 typed error, a v1 stats/
        // reload pair, and a bare v0 line — all on one server
        writeln!(stdin,
                 "{{\"v\": 1, \"id\": 1, \"model\": \"tc\", \
                  \"input\": [{}]}}",
                 flat.join(","))
            .unwrap();
        writeln!(stdin,
                 "{{\"v\": 1, \"id\": 2, \"model\": \"nope\", \
                  \"input\": [1]}}")
            .unwrap();
        writeln!(stdin, "{{\"v\": 1, \"id\": 3, \"op\": \"stats\"}}")
            .unwrap();
        writeln!(stdin, "{{\"v\": 1, \"id\": 4, \"op\": \"reload\"}}")
            .unwrap();
        writeln!(stdin, "{{\"id\": 5, \"input\": [{}]}}",
                 flat.join(","))
            .unwrap();
    }
    drop(child.stdin.take());
    let reader = BufReader::new(child.stdout.take().unwrap());
    let lines: Vec<String> = reader.lines().map(|l| l.unwrap()).collect();
    assert!(child.wait().unwrap().success());
    assert_eq!(lines.len(), 5, "{lines:?}");
    let parse = |s: &String| nitro::util::jsonio::Json::parse(s).unwrap();
    let r1 = parse(&lines[0]);
    assert_eq!(r1.req("v").unwrap().as_i64(), Some(1));
    assert_eq!(r1.req("model").unwrap().as_str(), Some("tc"));
    assert_eq!(r1.req("model_version").unwrap().as_i64(), Some(1));
    let expect_rows = expect.req("logits").unwrap().as_array().unwrap();
    assert_eq!(r1.req("logits").unwrap().as_array().unwrap()[0],
               expect_rows[0],
               "v1 serve logits differ from predict");
    let r2 = parse(&lines[1]);
    assert_eq!(
        r2.req("error").unwrap().req("code").unwrap().as_str(),
        Some("unknown_model"),
        "{}", lines[1]
    );
    let r3 = parse(&lines[2]);
    assert!(r3.get("models").is_some() && r3.get("shards").is_some(),
            "{}", lines[2]);
    let r4 = parse(&lines[3]);
    let reloaded = r4.req("reloaded").unwrap().as_array().unwrap();
    assert_eq!(reloaded[0].req("version").unwrap().as_i64(), Some(2),
               "{}", lines[3]);
    // v0 request: legacy shape, logits bit-identical after the reload
    let r5 = parse(&lines[4]);
    assert!(r5.get("v").is_none(), "v0 response grew a v: {}", lines[4]);
    assert_eq!(r5.req("logits").unwrap().as_array().unwrap()[0],
               expect_rows[0], "hot reload changed the logits");
}

#[test]
fn serve_validates_flags_at_startup() {
    let dir = std::env::temp_dir().join("nitro_cli_serve_flags");
    let (ckpt, _) = trained_ckpt_and_input(&dir);
    let spec = format!("tc={ckpt}");
    for (args, needle) in [
        (vec!["serve", "--models", spec.as_str(), "--max-batch", "0"],
         "--max-batch"),
        (vec!["serve", "--models", spec.as_str(), "--shards", "1000"],
         "--shards"),
        (vec!["serve", "--models", spec.as_str(), "--queue-budget-ms",
              "-1"],
         "--queue-budget-ms"),
        (vec!["serve", "--models", "=path.ckpt"], "--models"),
        (vec!["serve", "--models", ","], "--models"),
        (vec!["serve"], "--models"),
        (vec!["serve", "--models", spec.as_str(), ckpt.as_str()],
         "mutually exclusive"),
    ] {
        let (code, _, stderr) = run(&args);
        assert_eq!(code, 2, "{args:?} should fail at startup: {stderr}");
        assert!(stderr.contains(needle),
                "{args:?}: '{needle}' not in {stderr}");
        assert!(!stderr.contains("panicked"), "{stderr}");
    }
}

#[test]
fn serve_positional_paths_warn_but_work() {
    let dir = std::env::temp_dir().join("nitro_cli_serve_depr");
    let (ckpt, _) = trained_ckpt_and_input(&dir);
    let mut child = nitro()
        .args(["serve", ckpt.as_str()])
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::piped())
        .spawn()
        .expect("spawn nitro serve");
    drop(child.stdin.take()); // immediate EOF: clean empty session
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("deprecation"),
            "positional form should warn: {stderr}");
    assert!(stderr.contains("--models"), "{stderr}");
}

#[test]
fn loadgen_fails_cleanly_without_a_server() {
    // nothing listens on port 9 of localhost (discard is never bound)
    let (code, _, stderr) = run(&[
        "loadgen", "--connect", "127.0.0.1:9", "--rate", "50",
        "--duration", "0.2",
    ]);
    assert_eq!(code, 2, "{stderr}");
    assert!(!stderr.contains("panicked"), "{stderr}");
    let (code, _, stderr) = run(&[
        "loadgen", "--connect", "127.0.0.1:9", "--rate", "0",
    ]);
    assert_eq!(code, 2);
    assert!(stderr.contains("--rate"), "{stderr}");
}

#[test]
fn serve_rejects_missing_and_corrupt_checkpoints() {
    let dir = std::env::temp_dir().join("nitro_cli_serve_bad");
    std::fs::create_dir_all(&dir).unwrap();
    let (code, _, stderr) = run(&["serve", "does/not/exist.ckpt"]);
    assert_eq!(code, 2);
    assert!(stderr.contains("exist.ckpt"), "{stderr}");
    let bad = dir.join("bad.ckpt");
    std::fs::write(&bad, b"NITRO1\n\x10\x00\x00\x00not json at all!")
        .unwrap();
    let (code, _, stderr) = run(&["serve", bad.to_str().unwrap()]);
    assert_eq!(code, 2, "corrupt checkpoint must fail cleanly: {stderr}");
    assert!(!stderr.contains("panicked"), "{stderr}");
}

#[test]
fn runtime_smoke_if_artifacts_present() {
    if !std::path::Path::new("artifacts/tinycnn/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (code, stdout, stderr) = run(&["runtime", "--preset", "tinycnn"]);
    assert_eq!(code, 0, "{stderr}");
    assert!(stdout.contains("smoke check PASSED"), "{stdout}");
}
