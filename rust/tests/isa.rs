//! Cross-ISA training determinism: the SIMD kernel backends must be
//! drop-in bit-identical to scalar — not just per kernel (the property
//! tests in `tensor::backend` / `tensor::ops_int` cover that) but end
//! to end: a short `fit` on each zoo preset family must produce
//! byte-identical weights and losses on every supported ISA, under
//! every scheduler, with dropout enabled.
//!
//! The process-wide backend is flipped with `backend::set_active` — the
//! in-process equivalent of launching with `NITRO_ISA=...` (the CI
//! matrix lane covers the env-var path itself).

use nitro::data::synthetic;
use nitro::nn::{zoo, Hyper, Network};
use nitro::tensor::backend::{self, Isa};
use nitro::tensor::ITensor;
use nitro::train::{fit, Scheduler, TrainConfig};
use nitro::util::par;

/// One short real training run: synthetic 8×8 data (matches both the
/// tinycnn input and mlp1-mini's 64 flattened features), dropout on,
/// returns `(final weights, per-epoch mean head losses)`.
fn short_fit(preset: &str, scheduler: Scheduler) -> (Vec<ITensor>, Vec<f64>) {
    let ds = synthetic::by_name("tiny", 128, 17).expect("tiny");
    let (mut tr, mut te) = ds.split_test(32);
    tr.mad_normalize();
    te.mad_normalize();
    let mut net = Network::new(zoo::get(preset).expect("preset"), 5);
    net.set_dropout(0.25, 0.25);
    let cfg = TrainConfig {
        epochs: 1,
        batch: 32,
        hyper: Hyper { gamma_inv: 128, eta_fw_inv: 12000, eta_lr_inv: 3000 },
        seed: 5,
        scheduler,
        eval_every: 1,
        ..Default::default()
    };
    let res = fit(&mut net, &tr, &te, &cfg);
    let weights =
        net.weights().into_iter().map(|(_, t)| t.clone()).collect();
    let losses = res.epochs.iter().map(|e| e.mean_head_loss).collect();
    (weights, losses)
}

#[test]
fn short_fit_bitexact_across_isas_schedulers_and_presets() {
    // the pipelined scheduler needs one thread per stage to engage
    let _scope =
        par::scoped_thread_workers(par::current_workers().max(4));
    let prior = backend::active();
    for preset in ["tinycnn", "mlp1-mini"] {
        for sched in [Scheduler::Sequential, Scheduler::BlockParallel,
                      Scheduler::Pipelined] {
            backend::set_active(Isa::Scalar);
            let (w_ref, l_ref) = short_fit(preset, sched);
            for isa in backend::supported_isas() {
                if isa == Isa::Scalar {
                    continue;
                }
                backend::set_active(isa);
                let (w, l) = short_fit(preset, sched);
                assert_eq!(w, w_ref,
                           "{preset}/{}: weights diverged on {}",
                           sched.name(), isa.name());
                assert_eq!(l, l_ref,
                           "{preset}/{}: losses diverged on {}",
                           sched.name(), isa.name());
            }
        }
    }
    backend::set_active(prior);
}

#[test]
fn detected_backend_matches_scalar_pin_on_a_plain_fit() {
    // what a fresh process picks with no NITRO_ISA (detection) vs an
    // explicit scalar pin, on the default scheduler
    let prior = backend::active();
    backend::set_active(Isa::Scalar);
    let (w_ref, l_ref) = short_fit("tinycnn", Scheduler::default());
    backend::set_active(backend::detect());
    let (w, l) = short_fit("tinycnn", Scheduler::default());
    assert_eq!(w, w_ref, "detected backend diverged from scalar");
    assert_eq!(l, l_ref, "detected backend diverged from scalar");
    backend::set_active(prior);
}
