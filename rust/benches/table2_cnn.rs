//! Table 2 bench: end-to-end training-step and inference latency for the
//! paper's CNN architectures (narrow presets by default; set
//! NITRO_BENCH_FULL=1 for the paper-width VGG8B/VGG11B — minutes per
//! iteration on CPU). Accuracy rows come from `nitro experiment table2`.

use nitro::baselines::fp;
use nitro::nn::{zoo, Hyper, Network};
use nitro::util::bench::Bencher;
use nitro::util::rng::Pcg32;

fn main() {
    let mut b = Bencher::default();
    println!("{}", Bencher::header());
    let full = std::env::var("NITRO_BENCH_FULL").is_ok();
    let presets: &[&str] = if full {
        &["vgg8b", "vgg11b"]
    } else {
        &["tinycnn", "vgg8b-narrow", "vgg11b-narrow"]
    };
    let batch = if full { 8 } else { 16 };

    for preset in presets {
        let spec = zoo::get(preset).unwrap();
        let mut shape = vec![batch];
        shape.extend(&spec.input_shape);
        let n: usize = shape.iter().product();
        let mut rng = Pcg32::new(3);
        let x = nitro::tensor::ITensor::from_vec(
            &shape, (0..n).map(|_| rng.range_i32(-127, 127)).collect());
        let labels: Vec<usize> = (0..batch).map(|i| i % 10).collect();
        let hp = Hyper { gamma_inv: 512, eta_fw_inv: 25000, eta_lr_inv: 3000 };
        let work = Some(spec.param_count() as f64 * batch as f64);

        let mut net = Network::new(spec.clone(), 1);
        let mut drop = nitro::nn::DropoutRngs::new(4, net.blocks.len());
        b.bench(&format!("{preset} nitro-d step b{batch}"), work, || {
            std::hint::black_box(
                net.train_batch_parallel(&x, &labels, &hp, &mut drop));
        });
        b.bench(&format!("{preset} nitro-d infer b{batch}"), work, || {
            std::hint::black_box(net.infer(&x));
        });

        // float twin: one full BP step on the same topology
        let xf = nitro::tensor::FTensor::from_vec(
            &shape, x.data.iter().map(|&v| v as f32 / 64.0).collect());
        let mut fnet = fp::FpNet::new(spec.clone(), 1);
        b.bench(&format!("{preset} fp-bp fwd b{batch}"), work, || {
            std::hint::black_box(fnet.forward(&xf, None));
        });
        let _ = &mut fnet;
    }

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_table2.json", b.json()).ok();
    println!("-> results/bench_table2.json");
}
