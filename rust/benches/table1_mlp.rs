//! Table 1 bench: end-to-end training-step latency for every MLP
//! architecture of the paper (NITRO-D vs the PocketNN-style DFA baseline
//! vs FP BP on identical topologies). The accuracy dimension of Table 1 is
//! produced by `nitro experiment table1`; this target covers the
//! systems dimension — cost per step at the paper's batch size 64.

use nitro::baselines::{fp, pocketnn};
use nitro::data::synthetic;
use nitro::nn::{zoo, Hyper, Network};
use nitro::util::bench::Bencher;
use nitro::util::rng::Pcg32;

fn main() {
    let mut b = Bencher::default();
    println!("{}", Bencher::header());
    let batch = 64usize;

    for preset in ["mlp1", "mlp2", "mlp3-narrow", "mlp4-narrow"] {
        let spec = zoo::get(preset).unwrap();
        let input_dim = spec.input_shape[0];
        let work = Some(spec.param_count() as f64 * batch as f64);

        // shared batch
        let mut rng = Pcg32::new(3);
        let x = nitro::tensor::ITensor::from_vec(
            &[batch, input_dim],
            (0..batch * input_dim).map(|_| rng.range_i32(-127, 127)).collect(),
        );
        let labels: Vec<usize> = (0..batch).map(|i| i % 10).collect();
        let hp = Hyper { gamma_inv: 512, eta_fw_inv: 12000, eta_lr_inv: 3000 };

        // NITRO-D (block-parallel scheduler)
        let mut net = Network::new(spec.clone(), 1);
        let mut drop = nitro::nn::DropoutRngs::new(4, net.blocks.len());
        b.bench(&format!("{preset} nitro-d step b{batch}"), work, || {
            std::hint::black_box(
                net.train_batch_parallel(&x, &labels, &hp, &mut drop));
        });

        // PocketNN DFA
        let mut dims = vec![input_dim];
        for blk in &spec.blocks {
            dims.push(blk.out_features());
        }
        dims.push(spec.num_classes);
        let mut pnet = pocketnn::PocketNet::new(&dims, 1);
        b.bench(&format!("{preset} pocketnn-dfa step b{batch}"), work, || {
            std::hint::black_box(pnet.train_batch(&x, &labels, 512));
        });

        // FP BP on the same topology (one batch through train_bp's inner
        // loop ≈ one call with a 1-batch dataset)
        let ds = synthetic::generate("bench", (1, 1, input_dim), 10, batch,
                                     synthetic::Difficulty::easy(), 5);
        let mut fnet = fp::FpNet::new(spec.clone(), 1);
        b.bench(&format!("{preset} fp-bp(adam) step b{batch}"), work, || {
            std::hint::black_box(
                fp::train_bp(&mut fnet, &ds, &ds, 1, batch, 1e-3, 5));
        });
    }

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_table1.json", b.json()).ok();
    println!("-> results/bench_table1.json");
}
