//! The L3 contribution bench: LES scheduling — sequential vs
//! block-parallel (within one batch) vs cross-batch pipelined.
//!
//! The paper (§3.3) observes that local-loss blocks train independently
//! "allowing them to be executed in parallel and enhancing the efficiency
//! of the training process" but does not build it; this repo schedules it
//! two ways: `Network::train_batch_parallel` fans every block backward +
//! the head step out on the worker pool within a batch, and
//! `train::pipeline` keeps persistent per-block stage workers so block `l`
//! trains batch `t` while block `l+1` is on batch `t-1`. All modes are
//! bit-identical (tested in nn::block / train::pipeline); this bench
//! quantifies the speedups across worker budgets.

use nitro::data::synthetic;
use nitro::nn::{zoo, DropoutRngs, Hyper, Network};
use nitro::train::{fit, Scheduler, TrainConfig};
use nitro::util::bench::Bencher;
use nitro::util::{par, rng::Pcg32};

fn main() {
    let mut b = Bencher::default();
    println!("{}", Bencher::header());
    let batch = 16usize;

    // ---- single-step latency: sequential vs block-parallel -------------
    for preset in ["vgg8b-narrow", "vgg11b-narrow"] {
        let spec = zoo::get(preset).unwrap();
        let mut shape = vec![batch];
        shape.extend(&spec.input_shape);
        let n: usize = shape.iter().product();
        let mut rng = Pcg32::new(3);
        let x = nitro::tensor::ITensor::from_vec(
            &shape, (0..n).map(|_| rng.range_i32(-127, 127)).collect());
        let labels: Vec<usize> = (0..batch).map(|i| i % 10).collect();
        let hp = Hyper { gamma_inv: 512, eta_fw_inv: 25000, eta_lr_inv: 3000 };

        let mut net = Network::new(spec.clone(), 1);
        let mut drop = DropoutRngs::new(4, net.blocks.len());
        let seq = b
            .bench(&format!("{preset} sequential step"), None, || {
                std::hint::black_box(
                    net.train_batch(&x, &labels, &hp, &mut drop));
            })
            .median_ns;

        let mut net2 = Network::new(spec.clone(), 1);
        let mut drop2 = DropoutRngs::new(4, net2.blocks.len());
        let par_ns = b
            .bench(&format!("{preset} block-parallel step"), None, || {
                std::hint::black_box(
                    net2.train_batch_parallel(&x, &labels, &hp, &mut drop2));
            })
            .median_ns;

        println!("  {preset}: block-parallel speedup {:.2}x", seq / par_ns);
    }

    // ---- full-epoch throughput: all three schedulers --------------------
    // the pipeline only pays off across batches, so it is measured on
    // whole epochs (samples/sec), not single steps
    let ds = synthetic::by_name("tiny", 1100, 7).unwrap();
    let (mut tr, mut te) = ds.split_test(100);
    tr.mad_normalize();
    te.mad_normalize();
    let mut seq_secs = 0f64;
    for sched in [Scheduler::Sequential, Scheduler::BlockParallel,
                  Scheduler::Pipelined] {
        let mut net = Network::new(zoo::get("tinycnn").unwrap(), 1);
        let cfg = TrainConfig {
            epochs: 5,
            batch: 32,
            hyper: Hyper { gamma_inv: 128, eta_fw_inv: 12000,
                           eta_lr_inv: 3000 },
            scheduler: sched,
            eval_every: 5,
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let res = fit(&mut net, &tr, &te, &cfg);
        let secs = t0.elapsed().as_secs_f64();
        if sched == Scheduler::Sequential {
            seq_secs = secs;
        }
        println!(
            "  tinycnn epochs [{:<14}] {:>9.1} samples/sec (speedup {:.2}x)",
            sched.name(),
            (tr.len() * res.epochs.len()) as f64 / secs.max(1e-9),
            seq_secs / secs.max(1e-9)
        );
    }

    // ---- scaling with the kernel worker budget --------------------------
    // the per-thread budget override scopes the budget without touching
    // the process environment (same mechanism the pipeline stages use)
    let spec = zoo::get("vgg8b-narrow").unwrap();
    let mut shape = vec![batch];
    shape.extend(&spec.input_shape);
    let n: usize = shape.iter().product();
    let mut rng = Pcg32::new(3);
    let x = nitro::tensor::ITensor::from_vec(
        &shape, (0..n).map(|_| rng.range_i32(-127, 127)).collect());
    let labels: Vec<usize> = (0..batch).map(|i| i % 10).collect();
    let hp = Hyper::default();
    for workers in [1usize, 2, 4, 8] {
        par::set_thread_workers(workers);
        let mut net = Network::new(spec.clone(), 1);
        let mut drop = DropoutRngs::new(4, net.blocks.len());
        b.bench(&format!("vgg8b-narrow step workers={workers}"), None, || {
            std::hint::black_box(net.train_batch_parallel(
                &x, &labels, &hp, &mut drop,
            ));
        });
    }
    par::set_thread_workers(0);

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_parallel.json", b.json()).ok();
    println!("-> results/bench_parallel.json");
}
