//! The L3 contribution bench: block-parallel LES scheduling vs sequential.
//!
//! The paper (§3.3) observes that local-loss blocks train independently
//! "allowing them to be executed in parallel and enhancing the efficiency
//! of the training process" but does not build it; this repo's
//! `Network::train_batch_parallel` does (backward of block l overlaps the
//! forwards of blocks l+1..L). The two modes are bit-identical (tested in
//! nn::block); this bench quantifies the speedup across worker budgets.

use nitro::nn::{zoo, Hyper, Network};
use nitro::util::bench::Bencher;
use nitro::util::rng::Pcg32;

fn main() {
    let mut b = Bencher::default();
    println!("{}", Bencher::header());
    let batch = 16usize;

    for preset in ["vgg8b-narrow", "vgg11b-narrow"] {
        let spec = zoo::get(preset).unwrap();
        let mut shape = vec![batch];
        shape.extend(&spec.input_shape);
        let n: usize = shape.iter().product();
        let mut rng = Pcg32::new(3);
        let x = nitro::tensor::ITensor::from_vec(
            &shape, (0..n).map(|_| rng.range_i32(-127, 127)).collect());
        let labels: Vec<usize> = (0..batch).map(|i| i % 10).collect();
        let hp = Hyper { gamma_inv: 512, eta_fw_inv: 25000, eta_lr_inv: 3000 };

        let mut net = Network::new(spec.clone(), 1);
        let mut rng2 = Pcg32::new(4);
        let seq = b
            .bench(&format!("{preset} sequential step"), None, || {
                std::hint::black_box(
                    net.train_batch(&x, &labels, &hp, &mut rng2));
            })
            .median_ns;

        let mut net2 = Network::new(spec.clone(), 1);
        let mut rng3 = Pcg32::new(4);
        let par = b
            .bench(&format!("{preset} block-parallel step"), None, || {
                std::hint::black_box(
                    net2.train_batch_parallel(&x, &labels, &hp, &mut rng3));
            })
            .median_ns;

        println!("  {preset}: block-parallel speedup {:.2}x", seq / par);
    }

    // scaling with the kernel-level worker budget (participants per job
    // are re-read from NITRO_WORKERS each call; the persistent pool is
    // sized to the hardware, so budgets above it are clamped)
    let spec = zoo::get("vgg8b-narrow").unwrap();
    let mut shape = vec![batch];
    shape.extend(&spec.input_shape);
    let n: usize = shape.iter().product();
    let mut rng = Pcg32::new(3);
    let x = nitro::tensor::ITensor::from_vec(
        &shape, (0..n).map(|_| rng.range_i32(-127, 127)).collect());
    let labels: Vec<usize> = (0..batch).map(|i| i % 10).collect();
    let hp = Hyper::default();
    for workers in [1usize, 2, 4, 8] {
        std::env::set_var("NITRO_WORKERS", workers.to_string());
        let mut net = Network::new(spec.clone(), 1);
        let mut rng2 = Pcg32::new(4);
        b.bench(&format!("vgg8b-narrow step NITRO_WORKERS={workers}"), None,
                || {
                    std::hint::black_box(net.train_batch_parallel(
                        &x, &labels, &hp, &mut rng2));
                });
    }
    std::env::remove_var("NITRO_WORKERS");

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_parallel.json", b.json()).ok();
    println!("-> results/bench_parallel.json");
}
