//! Kernel micro-benchmarks: the integer contraction hot paths of the
//! NativeEngine vs their f32 twins, plus PJRT artifact execution when
//! available. Throughput is reported in MACs/s so integer-vs-float cost on
//! this CPU is directly visible (EXPERIMENTS.md §Perf feeds on the JSON).
//!
//! For the pool-vs-spawn dispatch comparison and the CI-tracked
//! `BENCH_kernels.json` record, use `nitro bench-kernels`
//! (`coordinator::kernelbench`) — this target focuses on int-vs-f32.

use nitro::tensor::{conv2d_i64, conv2d_weight_grad, matmul_i64, maxpool2d,
                    nitro_scale_relu, ops_f32, FTensor, ITensor, Tensor};
use nitro::util::bench::Bencher;
use nitro::util::rng::Pcg32;

fn rand_i(rng: &mut Pcg32, shape: &[usize], lo: i32, hi: i32) -> ITensor {
    let n = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.range_i32(lo, hi)).collect())
}

fn rand_f(rng: &mut Pcg32, shape: &[usize]) -> FTensor {
    let n = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect())
}

fn main() {
    let mut b = Bencher::default();
    let mut rng = Pcg32::new(1);
    // rows below go through the owning kernels, which dispatch on the
    // process-wide backend — pin with NITRO_ISA=scalar|avx2|neon; the
    // per-ISA side-by-side lives in `nitro bench-kernels`
    println!("kernel ISA: {}", nitro::tensor::backend::active().name());
    println!("{}", Bencher::header());

    // matmul shapes from the paper's MLPs: (batch 64) x (784 -> 1024)
    for &(m, k, n) in &[(64usize, 784usize, 1024usize), (64, 1024, 1024),
                        (64, 3072, 3000)] {
        let a = rand_i(&mut rng, &[m, k], -127, 127);
        let w = rand_i(&mut rng, &[k, n], -32768, 32767);
        let macs = (m * k * n) as f64;
        b.bench(&format!("int_matmul {m}x{k}x{n}"), Some(macs), || {
            std::hint::black_box(matmul_i64(&a, &w));
        });
        let af = rand_f(&mut rng, &[m, k]);
        let wf = rand_f(&mut rng, &[k, n]);
        b.bench(&format!("f32_matmul {m}x{k}x{n}"), Some(macs), || {
            std::hint::black_box(ops_f32::matmul(&af, &wf));
        });
    }

    // conv shapes from VGG8B (narrow + one full-width layer)
    for &(bt, c, o, h) in &[(8usize, 32usize, 64usize, 16usize),
                            (8, 128, 128, 8), (2, 128, 256, 32)] {
        let x = rand_i(&mut rng, &[bt, c, h, h], -127, 127);
        let w = rand_i(&mut rng, &[o, c, 3, 3], -4000, 4000);
        let macs = (bt * o * h * h * c * 9) as f64;
        b.bench(&format!("int_conv2d b{bt} {c}->{o} {h}x{h}"), Some(macs),
                || {
                    std::hint::black_box(conv2d_i64(&x, &w, 1));
                });
        let g = rand_i(&mut rng, &[bt, o, h, h], -500, 500);
        b.bench(&format!("conv_wgrad b{bt} {c}->{o} {h}x{h}"), Some(macs),
                || {
                    std::hint::black_box(conv2d_weight_grad(&x, &g, 3, 1));
                });
        let xf = rand_f(&mut rng, &[bt, c, h, h]);
        let wf = rand_f(&mut rng, &[o, c, 3, 3]);
        b.bench(&format!("f32_conv2d b{bt} {c}->{o} {h}x{h}"), Some(macs),
                || {
                    std::hint::black_box(ops_f32::conv2d(&xf, &wf, 1));
                });
    }

    // NITRO epilogue (fused scale+relu) — elements/s
    let z = nitro::tensor::LTensor::from_vec(
        &[64, 65536],
        (0..64 * 65536).map(|i| (i as i64 * 7919) % (1 << 40)).collect(),
    );
    b.bench("nitro_scale_relu 64x65536", Some((64 * 65536) as f64), || {
        std::hint::black_box(nitro_scale_relu(&z, 256 * 1152, 10));
    });

    // maxpool
    let x = rand_i(&mut rng, &[8, 128, 32, 32], -127, 127);
    b.bench("maxpool2d 8x128x32x32", Some((8 * 128 * 32 * 32) as f64), || {
        std::hint::black_box(maxpool2d(&x, 2, 2));
    });

    // PJRT artifact execution (whole tinycnn train step), if built
    if std::path::Path::new("artifacts/tinycnn/manifest.json").exists() {
        use nitro::coordinator::engine::{Engine, PjrtEngine};
        use nitro::nn::Hyper;
        let mut eng = PjrtEngine::load("artifacts/tinycnn", 7).unwrap();
        let m = eng.manifest.clone();
        let mut shape = vec![m.batch];
        shape.extend(&m.input_shape);
        let xn: usize = shape.iter().product();
        let x = rand_i(&mut rng, &shape, -127, 127);
        let labels: Vec<usize> = (0..m.batch).map(|i| i % 10).collect();
        let hp = Hyper::default();
        b.bench("pjrt tinycnn train step", Some(xn as f64), || {
            std::hint::black_box(eng.train_batch(&x, &labels, &hp));
        });
        b.bench("pjrt tinycnn infer", Some(xn as f64), || {
            std::hint::black_box(eng.infer(&x));
        });
    }

    std::fs::create_dir_all("results").ok();
    std::fs::write("results/bench_kernels.json", b.json()).ok();
    println!("-> results/bench_kernels.json");
}
