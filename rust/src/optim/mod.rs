//! Optimizers: IntegerSGD (paper Algorithm 1) for the NITRO-D path and the
//! plateau LR scheduler. The float SGD/Adam baselines live in
//! `baselines::optim_fp` — this module is an integer-domain surface under
//! the `nitro lint` no-float rule.

pub mod momentum;

use crate::tensor::{ITensor, LTensor};
use crate::util::{div_floor, div_trunc};

/// IntegerSGD with ad-hoc weight decay (paper Algorithm 1).
///
/// `delta = floor(grad / gamma_inv)`; if `eta_inv != 0`,
/// `delta += trunc(w / eta_inv)` (trunc, not floor — DESIGN.md interp. #8:
/// the paper guarantees |w| < eta_inv receives no penalization);
/// `w -= delta`.
///
/// `grad` is the batch-**summed** int64 gradient — which makes this the
/// natural step-from-accumulated-grad entry point: the data-parallel
/// replica trainer (`train::replica`) all-reduces per-shard i64 gradient
/// sums across replicas and feeds the result straight in, and because
/// the reduced sum equals the single-replica batch sum exactly (i64
/// addition is associative), the step is bit-identical to unreplicated
/// training.
pub fn integer_sgd(w: &mut ITensor, grad: &LTensor, gamma_inv: i64,
                   eta_inv: i64) {
    assert_eq!(w.shape, grad.shape, "optimizer shape mismatch");
    integer_sgd_slice(&mut w.data, &grad.data, gamma_inv, eta_inv);
}

/// [`integer_sgd`] on raw slices: the shape-free core, usable directly on
/// all-reduce accumulator buffers without wrapping them into tensors.
pub fn integer_sgd_slice(w: &mut [i32], grad: &[i64], gamma_inv: i64,
                         eta_inv: i64) {
    assert_eq!(w.len(), grad.len(), "optimizer length mismatch");
    assert!(gamma_inv > 0, "gamma_inv must be positive");
    if eta_inv != 0 {
        for (wv, &gv) in w.iter_mut().zip(grad) {
            let delta = div_floor(gv, gamma_inv)
                .wrapping_add(div_trunc(*wv as i64, eta_inv));
            *wv = (*wv as i64).wrapping_sub(delta) as i32;
        }
    } else {
        for (wv, &gv) in w.iter_mut().zip(grad) {
            *wv = (*wv as i64).wrapping_sub(div_floor(gv, gamma_inv)) as i32;
        }
    }
}

/// [`integer_sgd`] with bitwidth rails: the incoming (post-all-reduce)
/// i64 gradient is clamped to `±grad_rail` before the step, and the
/// updated weight is clamped to `±weight_rail` after it. Full-width
/// rails (`grad_rail == i64::MAX && weight_rail == i32::MAX`) take the
/// unrailed loops verbatim, so default-bits training is byte-identical
/// to [`integer_sgd`] — clamping to ±MAX is *not* a no-op (it would
/// remap `i32::MIN`), hence the explicit skip.
///
/// Clamping here — after the replica all-reduce, not per shard — is
/// what keeps low-bit runs byte-identical across replica counts: the
/// reduced sum is the same value regardless of sharding, and the rail
/// is applied exactly once to that sum.
pub fn integer_sgd_railed(w: &mut ITensor, grad: &LTensor, gamma_inv: i64,
                          eta_inv: i64, grad_rail: i64, weight_rail: i32) {
    assert_eq!(w.shape, grad.shape, "optimizer shape mismatch");
    integer_sgd_railed_slice(&mut w.data, &grad.data, gamma_inv, eta_inv,
                             grad_rail, weight_rail);
}

/// [`integer_sgd_railed`] on raw slices.
pub fn integer_sgd_railed_slice(w: &mut [i32], grad: &[i64], gamma_inv: i64,
                                eta_inv: i64, grad_rail: i64,
                                weight_rail: i32) {
    if grad_rail == i64::MAX && weight_rail == i32::MAX {
        integer_sgd_slice(w, grad, gamma_inv, eta_inv);
        return;
    }
    assert_eq!(w.len(), grad.len(), "optimizer length mismatch");
    assert!(gamma_inv > 0, "gamma_inv must be positive");
    assert!(grad_rail > 0, "grad_rail must be positive");
    assert!(weight_rail > 0, "weight_rail must be positive");
    let wr = weight_rail as i64;
    for (wv, &gv) in w.iter_mut().zip(grad) {
        let gv = gv.clamp(-grad_rail, grad_rail);
        let mut delta = div_floor(gv, gamma_inv);
        if eta_inv != 0 {
            delta = delta.wrapping_add(div_trunc(*wv as i64, eta_inv));
        }
        // the clamp keeps the i64 step inside the weight rail, so the
        // final i32 cast is always in range (never a wrap)
        *wv = (*wv as i64).wrapping_sub(delta).clamp(-wr, wr) as i32;
    }
}

/// Plateau LR scheduler (paper App. D): when the monitored accuracy fails
/// to improve for `patience` evaluations, the learning rate is reduced by
/// 3× — in inverse-rate space, `gamma_inv *= 3`.
#[derive(Clone, Debug)]
pub struct PlateauScheduler {
    pub gamma_inv: i64,
    pub patience: usize,
    pub factor: i64,
    /// Stop reducing after this many reductions: integer LR decay is
    /// one-way (gamma_inv only grows) and NITRO-D has a long bootstrap
    /// phase (the scaling layers start out truncating everything — the
    /// weights must grow ~100x from init before activations carry signal),
    /// so an uncapped scheduler would freeze training before it starts.
    pub max_reductions: usize,
    /// Ignore the first `warmup` reports entirely — the bootstrap phase is
    /// flat by construction and must not trigger reductions.
    pub warmup: usize,
    seen: usize,
    // nitro-lint: allow(no-float) accuracy monitoring only: compared, never
    best: f64,
    stale: usize,
    pub reductions: usize,
}

impl PlateauScheduler {
    pub fn new(gamma_inv: i64, patience: usize) -> Self {
        PlateauScheduler {
            gamma_inv,
            patience,
            factor: 3,
            max_reductions: 3,
            warmup: 0,
            seen: 0,
            // nitro-lint: allow(no-float) monitored accuracy, not weights
            best: f64::NEG_INFINITY,
            stale: 0,
            reductions: 0,
        }
    }

    /// Snapshot the mutable state for checkpointing. The plateau
    /// scheduler is history-dependent (best accuracy seen, staleness
    /// counter), so a resumed run must restore this rather than
    /// reconstructing a fresh scheduler — otherwise the resumed run's
    /// LR trajectory diverges from the uninterrupted one.
    pub fn state(&self) -> PlateauState {
        PlateauState {
            gamma_inv: self.gamma_inv,
            seen: self.seen,
            best: self.best,
            stale: self.stale,
            reductions: self.reductions,
        }
    }

    /// Restore a snapshot taken by [`PlateauScheduler::state`].
    pub fn restore(&mut self, s: &PlateauState) {
        self.gamma_inv = s.gamma_inv;
        self.seen = s.seen;
        self.best = s.best;
        self.stale = s.stale;
        self.reductions = s.reductions;
    }

    /// Report a new accuracy; returns true if the LR was reduced.
    // nitro-lint: allow(no-float) accuracy is a monitoring input; it gates
    pub fn step(&mut self, accuracy: f64) -> bool {
        self.seen += 1;
        if self.seen <= self.warmup {
            self.best = self.best.max(accuracy);
            return false;
        }
        if accuracy > self.best {
            self.best = accuracy;
            self.stale = 0;
            return false;
        }
        self.stale += 1;
        if self.stale >= self.patience && self.reductions < self.max_reductions
        {
            self.gamma_inv = self.gamma_inv.saturating_mul(self.factor);
            self.stale = 0;
            self.reductions += 1;
            return true;
        }
        false
    }
}

/// Mutable [`PlateauScheduler`] state, exported for checkpointing
/// (`train::checkpoint` serializes it into the `train_state` header key
/// so elastic rejoin resumes the exact LR trajectory).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlateauState {
    pub gamma_inv: i64,
    pub seen: usize,
    // nitro-lint: allow(no-float) checkpointed monitoring state, not weights
    pub best: f64,
    pub stale: usize,
    pub reductions: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn integer_sgd_matches_algorithm1_prop() {
        prop::check("isgd", 40, |g| {
            let n = g.usize_in(1, 64);
            let wdata = g.vec_i32(n, -30000, 30000);
            let gdata = g.vec_i64(n);
            let gamma = 1 + g.usize_in(0, 100_000) as i64;
            let eta = if g.usize_in(0, 1) == 0 {
                0
            } else {
                1 + g.usize_in(0, 50_000) as i64
            };
            let mut w = ITensor::from_vec(&[n], wdata.clone());
            let grad = LTensor::from_vec(&[n], gdata.clone());
            integer_sgd(&mut w, &grad, gamma, eta);
            for i in 0..n {
                let mut delta = gdata[i].div_euclid(gamma);
                if eta != 0 {
                    delta += (wdata[i] as i64) / eta;
                }
                // i32 storage wraps like the engine (paper guarantees the
                // trained regime stays in range; the op itself wraps)
                assert_eq!(w.data[i], (wdata[i] as i64 - delta) as i32);
            }
        });
    }

    #[test]
    fn slice_entry_point_matches_tensor_form() {
        prop::check("isgd-slice", 20, |g| {
            let n = g.usize_in(1, 48);
            let wdata = g.vec_i32(n, -30000, 30000);
            let gdata = g.vec_i64(n);
            let gamma = 1 + g.usize_in(0, 100_000) as i64;
            let eta = 1 + g.usize_in(0, 50_000) as i64;
            let mut w_t = ITensor::from_vec(&[n], wdata.clone());
            let grad = LTensor::from_vec(&[n], gdata.clone());
            integer_sgd(&mut w_t, &grad, gamma, eta);
            let mut w_s = wdata;
            integer_sgd_slice(&mut w_s, &gdata, gamma, eta);
            assert_eq!(w_t.data, w_s);
        });
    }

    #[test]
    fn railed_sgd_default_rails_are_byte_identical_to_unrailed() {
        prop::check("isgd-rail-default", 20, |g| {
            let n = g.usize_in(1, 48);
            let wdata = g.vec_i32(n, i32::MIN + 1, i32::MAX);
            let gdata = g.vec_i64(n);
            let gamma = 1 + g.usize_in(0, 100_000) as i64;
            let eta = if g.usize_in(0, 1) == 0 {
                0
            } else {
                1 + g.usize_in(0, 50_000) as i64
            };
            let mut plain = wdata.clone();
            integer_sgd_slice(&mut plain, &gdata, gamma, eta);
            let mut railed = wdata;
            integer_sgd_railed_slice(&mut railed, &gdata, gamma, eta,
                                     i64::MAX, i32::MAX);
            assert_eq!(plain, railed);
        });
    }

    #[test]
    fn railed_sgd_clamps_to_rails_including_exact_rail_values() {
        // b = 8: weight rail ±127, grad rail ±(2^31−1)
        let wr = 127i32;
        let gr = (1i64 << 31) - 1;
        // huge grads would swing weights far past the rail; exact-rail
        // inputs must pass through the grad clamp unchanged
        let mut w = ITensor::from_vec(&[5], vec![100, -100, 127, -127, 0]);
        let g = LTensor::from_vec(&[5], vec![-i64::MAX, i64::MAX, 0, 0, gr]);
        integer_sgd_railed(&mut w, &g, 1, 0, gr, wr);
        // grads clamp to ±gr first, then the weight update clamps to ±wr
        assert_eq!(w.data, vec![127, -127, 127, -127, -127]);
        for &v in &w.data {
            assert!(-wr <= v && v <= wr);
        }
        // property: post-step weights never exceed the rail for b in
        // {8, 16, 24}, whatever the inputs
        prop::check("isgd-rail", 30, |gen| {
            let n = gen.usize_in(1, 48);
            let b = [8u32, 16, 24][gen.usize_in(0, 2)];
            let wr = (1i32 << (b - 1)) - 1;
            let grb = [16u32, 32, 48][gen.usize_in(0, 2)];
            let gr = (1i64 << (grb - 1)) - 1;
            let wdata = gen.vec_i32(n, -wr, wr);
            let gdata = gen.vec_i64(n);
            let gamma = 1 + gen.usize_in(0, 1000) as i64;
            let mut w = wdata;
            integer_sgd_railed_slice(&mut w, &gdata, gamma, 0, gr, wr);
            for &v in &w {
                assert!(-wr <= v && v <= wr, "b={b} v={v}");
            }
        });
    }

    #[test]
    fn no_decay_below_threshold() {
        // paper §3.3 pinned example (shared with python tests)
        let mut w = ITensor::from_vec(&[6], vec![10, -10, 2999, -2999, 3000, -3001]);
        let g = LTensor::from_vec(&[6], vec![0; 6]);
        integer_sgd(&mut w, &g, 512, 3000);
        assert_eq!(w.data, vec![10, -10, 2999, -2999, 2999, -3000]);
    }

    #[test]
    fn gamma_truncates_small_updates_to_zero() {
        // App. E.1: too-large gamma_inv -> all updates truncate -> frozen
        let mut w = ITensor::from_vec(&[3], vec![5, -5, 100]);
        let g = LTensor::from_vec(&[3], vec![4095, 4095, 4095]);
        integer_sgd(&mut w, &g, 4096, 0);
        assert_eq!(w.data, vec![5, -5, 100]);
    }

    #[test]
    fn plateau_reduces_after_patience() {
        let mut s = PlateauScheduler::new(512, 2);
        assert!(!s.step(0.5));
        assert!(!s.step(0.6)); // improvement resets
        assert!(!s.step(0.55));
        assert!(s.step(0.55)); // 2 stale evals -> reduce
        assert_eq!(s.gamma_inv, 1536);
        assert_eq!(s.reductions, 1);
    }

    #[test]
    fn plateau_state_roundtrip_resumes_exact_trajectory() {
        // drive one scheduler straight through, and a second through a
        // snapshot/restore at the midpoint — the decision sequences must
        // be identical (the checkpoint-resume contract)
        let accs = [0.3, 0.5, 0.45, 0.45, 0.45, 0.6, 0.55, 0.55, 0.55];
        let mut a = PlateauScheduler::new(512, 2);
        let mut b = PlateauScheduler::new(512, 2);
        let mut decisions_a = Vec::new();
        let mut decisions_b = Vec::new();
        for &acc in &accs[..4] {
            decisions_a.push(a.step(acc));
            decisions_b.push(b.step(acc));
        }
        let snap = b.state();
        // a fresh scheduler restored from the snapshot picks up exactly
        let mut b2 = PlateauScheduler::new(512, 2);
        b2.restore(&snap);
        assert_eq!(b2.state(), snap);
        for &acc in &accs[4..] {
            decisions_a.push(a.step(acc));
            decisions_b.push(b2.step(acc));
        }
        assert_eq!(decisions_a, decisions_b);
        assert_eq!(a.state(), b2.state());
    }

}
