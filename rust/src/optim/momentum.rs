//! IntegerSGD with momentum — the paper's §5 future-work item ("the
//! development of an improved optimizer tailored specifically for
//! integer-only training"), built here as an extension.
//!
//! Design constraints inherited from IntegerSGD:
//!   * integer-only state (the velocity buffer is i64),
//!   * divisions are floor divisions by inverse-rate integers,
//!   * a naive `v = v*beta` would need a fraction — instead we use the
//!     leak form `v ← v − trunc(v / beta_inv) + grad`, an exponential
//!     moving sum with integer leak rate 1/beta_inv (beta_inv = 8 ≈
//!     momentum 0.875).
//!
//! Ablated against plain IntegerSGD by `nitro experiment momentum`
//! (EXPERIMENTS.md §Extensions).

use crate::tensor::{ITensor, LTensor};
use crate::util::{div_floor, div_trunc};

pub struct IntegerMomentum {
    /// Inverse leak rate: velocity decays by v/beta_inv each step.
    pub beta_inv: i64,
    /// Velocity buffers keyed by parameter slot.
    velocity: Vec<Vec<i64>>,
}

impl IntegerMomentum {
    pub fn new(beta_inv: i64) -> Self {
        assert!(beta_inv >= 2, "beta_inv < 2 disables the accumulator");
        IntegerMomentum { beta_inv, velocity: Vec::new() }
    }

    /// IntegerSGD-with-momentum step for parameter slot `idx`:
    /// `v ← v − trunc(v/beta_inv) + grad`;
    /// `delta = floor(v / (gamma_inv · beta_inv)) [+ trunc(w / eta_inv)]`;
    /// `w ← w − delta`.
    ///
    /// Like [`crate::optim::integer_sgd`], this is a
    /// step-from-accumulated-grad entry point: `grad` may be an
    /// all-reduced sum of per-shard gradients (`train::replica`), and
    /// because the velocity update is a deterministic function of the
    /// reduced gradient, replicas applying the same reduced step keep
    /// their velocity buffers in lockstep too.
    ///
    /// The extra `beta_inv` in the delta divisor normalizes the steady-state
    /// gain of the accumulator (Σ leak-weighted grads ≈ beta_inv · grad), so
    /// a tuned gamma_inv transfers directly from plain IntegerSGD.
    pub fn update(&mut self, idx: usize, w: &mut ITensor, grad: &LTensor,
                  gamma_inv: i64, eta_inv: i64) {
        while self.velocity.len() <= idx {
            self.velocity.push(Vec::new());
        }
        let v = &mut self.velocity[idx];
        if v.len() != w.data.len() {
            *v = vec![0i64; w.data.len()];
        }
        let div = gamma_inv.saturating_mul(self.beta_inv);
        for ((wv, &gv), vel) in w.data.iter_mut().zip(&grad.data).zip(v.iter_mut())
        {
            *vel = vel.wrapping_sub(div_trunc(*vel, self.beta_inv)).wrapping_add(gv);
            let mut delta = div_floor(*vel, div);
            if eta_inv != 0 {
                delta = delta.wrapping_add(div_trunc(*wv as i64, eta_inv));
            }
            *wv = (*wv as i64).wrapping_sub(delta) as i32;
        }
    }
}

/// A momentum-enabled variant of the local-loss trainer: wraps a
/// [`crate::nn::Network`] and applies IntegerMomentum to every weight
/// tensor instead of plain IntegerSGD. Implemented via the same block
/// forward/backward but with gradient interception would require plumbing;
/// instead the momentum trainer drives blocks with gamma escalation — used
/// by the `momentum` ablation experiment on MLP blocks where the update
/// path is a plain matmul.
pub struct MomentumMlp {
    pub dims: Vec<usize>,
    pub weights: Vec<ITensor>,
    pub heads: Vec<ITensor>,
    opt: IntegerMomentum,
}

impl MomentumMlp {
    pub fn new(dims: &[usize], beta_inv: i64, seed: u64) -> Self {
        use crate::nn::init::init_weights;
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(seed);
        let g = *dims.last().unwrap();
        let mut weights = Vec::new();
        let mut heads = Vec::new();
        for w in dims.windows(2) {
            weights.push(init_weights(&mut rng, &[w[0], w[1]], w[0]));
            heads.push(init_weights(&mut rng, &[w[1], g], w[1]));
        }
        MomentumMlp { dims: dims.to_vec(), weights, heads,
                      opt: IntegerMomentum::new(beta_inv) }
    }

    /// One LES step over all linear blocks with momentum updates.
    /// Returns the mean local loss.
    pub fn train_batch(&mut self, x: &ITensor, labels: &[usize],
                       gamma_inv: i64, eta_inv: i64) -> i64 {
        use crate::tensor as t;
        let g = *self.dims.last().unwrap();
        let y32 = t::one_hot32(labels, g);
        let af = (g as i64).wrapping_mul(64);
        let mut a = x.clone();
        let mut total = 0i64;
        let nblocks = self.weights.len();
        for li in 0..nblocks {
            let spec_sf = t::scale_factor_linear(self.dims[li]);
            let z = t::matmul_i64(&a, &self.weights[li]);
            let zs = t::nitro_scale(&z, spec_sf);
            let act = t::nitro_relu(&zs, 10);
            let zl = t::matmul_i64(&act, &self.heads[li]);
            let yhat = t::nitro_scale(&zl, t::scale_factor_linear(act.shape[1]));
            let (loss, grad_l) = t::rss_loss_grad(&yhat, &y32);
            total = total.wrapping_add(loss);
            let gw_l = t::matmul_at_b_i64(&act, &grad_l);
            let dfeat = t::matmul_a_bt_i64(&grad_l, &self.heads[li]).to_i32();
            self.opt.update(2 * li + 1, &mut self.heads[li], &gw_l,
                            gamma_inv, eta_inv);
            let d = t::nitro_relu_bwd(&zs, &dfeat, 10);
            let gw = t::matmul_at_b_i64(&a, &d);
            self.opt.update(2 * li, &mut self.weights[li], &gw,
                            gamma_inv.wrapping_mul(af), eta_inv);
            a = act;
        }
        total / nblocks as i64
    }

    // nitro-lint: allow(no-float) reported accuracy is monitoring output
    pub fn accuracy(&self, ds: &crate::data::Dataset, batch: usize) -> f64 {
        use crate::tensor as t;
        let mut correct = 0usize;
        for (x, labels) in crate::data::Batcher::sequential(ds, batch, true) {
            let mut a = x;
            for li in 0..self.weights.len() {
                let z = t::matmul_i64(&a, &self.weights[li]);
                let zs = t::nitro_scale(&z, t::scale_factor_linear(self.dims[li]));
                a = t::nitro_relu(&zs, 10);
            }
            // last block's local head serves as the classifier
            let li = self.weights.len() - 1;
            let zl = t::matmul_i64(&a, &self.heads[li]);
            let yhat = t::nitro_scale(&zl, t::scale_factor_linear(a.shape[1]));
            correct += crate::nn::block::count_correct(&yhat, &labels);
        }
        // nitro-lint: allow(no-float) monitoring ratio, not model state
        correct as f64 / ds.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn velocity_accumulates_and_leaks() {
        let mut opt = IntegerMomentum::new(8);
        let mut w = ITensor::from_vec(&[1], vec![0]);
        let grad = LTensor::from_vec(&[1], vec![8000]);
        // repeated identical gradients: velocity converges to ~beta_inv*g,
        // so delta converges to ~g/gamma — same steady state as plain SGD
        let mut deltas = Vec::new();
        let mut prev = 0i32;
        for _ in 0..50 {
            opt.update(0, &mut w, &grad, 100, 0);
            deltas.push(prev - w.data[0]);
            prev = w.data[0];
        }
        // first step is small (cold accumulator), later steps approach 80
        assert!(deltas[0] < deltas[49], "{deltas:?}");
        assert!((70..=90).contains(&deltas[49]), "{deltas:?}");
    }

    #[test]
    fn zero_grad_velocity_decays_to_zero() {
        let mut opt = IntegerMomentum::new(4);
        let mut w = ITensor::from_vec(&[1], vec![1000]);
        opt.update(0, &mut w, &LTensor::from_vec(&[1], vec![40_000]), 10, 0);
        let after_kick = w.data[0];
        for _ in 0..200 {
            opt.update(0, &mut w, &LTensor::from_vec(&[1], vec![0]), 10, 0);
        }
        let drift_stopped = w.data[0];
        let mut w2 = Tensor::from_vec(&[1], vec![drift_stopped]);
        opt.update(0, &mut w2, &LTensor::from_vec(&[1], vec![0]), 10, 0);
        assert_eq!(w2.data[0], drift_stopped, "velocity must die out");
        assert!(after_kick < 1000, "kick must move the weight");
    }

    #[test]
    fn momentum_mlp_learns() {
        use crate::data::synthetic;
        let mut ds = synthetic::by_name("tiny", 600, 3).unwrap();
        ds.mad_normalize();
        let (tr, te) = ds.split_test(120);
        let mut net = MomentumMlp::new(&[64, 48, 10], 8, 1);
        let mut rng = crate::util::rng::Pcg32::new(5);
        let mut first = 0;
        let mut last = 0;
        for epoch in 0..60 {
            for (x, labels) in crate::data::Batcher::new(&tr, 32, true, &mut rng)
            {
                let l = net.train_batch(&x, &labels, 512, 3000);
                if epoch == 0 && first == 0 {
                    first = l;
                }
                last = l;
            }
        }
        assert!(last < first, "momentum mlp loss {first} -> {last}");
        let acc = net.accuracy(&te, 32);
        assert!(acc > 0.3, "momentum mlp acc {acc}");
    }
}
