//! L3 coordinator: the execution-engine abstraction (pure-Rust NativeEngine
//! vs artifact-backed PjrtEngine), experiment drivers for every table and
//! figure in the paper, and the CLI plumbing.

pub mod engine;
pub mod experiments;

pub use engine::{Engine, NativeEngine, PjrtEngine};
