//! L3 coordinator: the execution-engine abstraction (pure-Rust NativeEngine
//! vs artifact-backed PjrtEngine), the declarative experiment harness
//! (`spec` + `runner` — the paper's tables as JSON under `experiments/`),
//! the inference-serving subsystem (`serve` — model registry +
//! micro-batcher behind `nitro serve` / `nitro predict`), the remaining
//! imperative figure drivers (`experiments`), and the CLI plumbing.

pub mod engine;
pub mod experiments;
pub mod kernelbench;
pub mod runner;
pub mod serve;
pub mod spec;

pub use engine::{Engine, NativeEngine, PjrtEngine};
pub use serve::{BatchClient, MicroBatcher, ModelRegistry, ServeConfig,
                ServedModel};
pub use spec::{EngineKind, ExperimentSpec};
