//! L3 coordinator: the execution-engine abstraction (pure-Rust NativeEngine
//! vs artifact-backed PjrtEngine), the declarative experiment harness
//! (`spec` + `runner` — the paper's tables as JSON under `experiments/`),
//! the inference-serving subsystem (`serve` — versioned model registry,
//! sharded micro-batchers, latency-budget load shedding, and the v0/v1
//! wire protocol behind `nitro serve` / `nitro predict` /
//! `nitro loadgen`), the remaining
//! imperative figure drivers (`experiments`), and the CLI plumbing.

pub mod engine;
pub mod experiments;
pub mod kernelbench;
pub mod runner;
pub mod serve;
pub mod spec;

pub use engine::{Engine, NativeEngine, PjrtEngine};
pub use serve::{BatchClient, ErrorKind, MicroBatcher, ModelRegistry,
                ServeConfig, ServeError, ServedModel, ShardedBatcher};
pub use spec::{EngineKind, ExperimentSpec};
