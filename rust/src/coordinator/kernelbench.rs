//! `nitro bench-kernels`: the kernel-runtime measurement harness behind
//! the CI perf-trajectory lane.
//!
//! Times the NativeEngine hot paths (matmul / conv / im2col / NITRO-ReLU
//! epilogue) across paper-relevant shapes plus one full table1-MLP and
//! table2-CNN training step, comparing the persistent worker pool against
//! the seed per-call-thread-spawn backend, and emits a schema-versioned
//! `BENCH_kernels.json` through the shared `jsonio` machinery.
//!
//! Two kinds of signal with two severities:
//! * **bit-exactness** (pool vs spawn vs single-thread vs workspace
//!   paths) — a mismatch is a hard failure (`Err`), CI goes red;
//! * **wall-clock vs a checked-in baseline** — advisory only: deltas
//!   beyond the gate print GitHub `::warning::` annotations but never
//!   fail the run (timings are machine-dependent).

use crate::data::synthetic;
use crate::nn::{zoo, DropoutRngs, Hyper, Network};
use crate::tensor::backend::{self, Isa};
use crate::tensor::{
    conv2d_i64, conv2d_weight_grad, im2col, kernels, matmul_i64,
    nitro_relu, nitro_scale_relu, scale_factor_conv, ITensor,
    KernelBackend, KernelWorkspace, LTensor, Tensor,
};
use crate::train::{fit, Scheduler, TrainConfig};
use crate::util::bench::Bencher;
use crate::util::jsonio::Json;
use crate::util::{par, rng::Pcg32};

/// Bump when a `BENCH_kernels.json` key changes meaning or disappears;
/// adding keys is allowed without a bump.
pub const SCHEMA_VERSION: i64 = 1;

/// Advisory wall-clock gate vs the baseline: ±30%.
pub const BASELINE_GATE: f64 = 0.30;

/// The checked-in baseline the CI advisory comparison reads, and the
/// target of `--write-baseline`.
pub const BASELINE_PATH: &str = "experiments/bench_baseline.json";

#[derive(Clone, Debug)]
pub struct Opts {
    /// Per-benchmark budget in seconds; `None` = `NITRO_BENCH_BUDGET` or
    /// the [`Bencher`] default.
    pub budget_s: Option<f64>,
    /// Output path for the aggregate JSON record.
    pub out: String,
    /// Optional baseline `BENCH_kernels.json` to compare against.
    pub baseline: Option<String>,
    /// Also write the record to [`BASELINE_PATH`] so a maintainer can
    /// regenerate the checked-in baseline in one step (then commit).
    pub write_baseline: bool,
    /// Small-shape subset only (no full train steps or epoch-level
    /// scheduler comparison) — used by the CLI test suite where the
    /// binary runs unoptimized.
    pub quick: bool,
    /// Output path for the serving-throughput record
    /// (`coordinator::serve::bench_serve`); empty = skip the serve
    /// section.
    pub serve_out: String,
}

impl Default for Opts {
    fn default() -> Self {
        Opts {
            budget_s: None,
            out: "BENCH_kernels.json".to_string(),
            baseline: None,
            write_baseline: false,
            quick: false,
            serve_out: "BENCH_serve.json".to_string(),
        }
    }
}

fn rand_i(rng: &mut Pcg32, shape: &[usize], lo: i32, hi: i32) -> ITensor {
    let n = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.range_i32(lo, hi)).collect())
}

/// Collects pool-vs-spawn speedups and bit-exactness verdicts.
struct Harness {
    b: Bencher,
    speedups: Vec<(String, f64)>,
    bitexact_failures: Vec<String>,
}

impl Harness {
    /// Bench `f` on the pool backend and the legacy spawn backend,
    /// recording the spawn/pool median ratio, after checking the two
    /// backends (plus the single-thread path via `check`) agree.
    fn pool_vs_spawn<F, C>(&mut self, name: &str, work: Option<f64>, f: F,
                           check: C)
    where
        F: Fn(),
        C: Fn() -> bool,
    {
        if !check() {
            self.bitexact_failures.push(name.to_string());
        }
        let pool_ns =
            self.b.bench(&format!("{name} [pool]"), work, &f).median_ns;
        par::set_spawn_mode(true);
        let spawn_ns =
            self.b.bench(&format!("{name} [spawn]"), work, &f).median_ns;
        par::set_spawn_mode(false);
        self.speedups.push((name.to_string(), spawn_ns / pool_ns));
    }
}

/// Run the harness; returns the emitted JSON. `Err` only on I/O problems
/// or a bit-exactness mismatch.
pub fn run(opts: &Opts) -> Result<Json, String> {
    let mut h = Harness {
        b: Bencher::default(),
        speedups: Vec::new(),
        bitexact_failures: Vec::new(),
    };
    if let Some(s) = opts.budget_s {
        h.b.budget_s = s;
    }
    let workers = par::default_workers();
    println!(
        "bench-kernels: {workers} workers (pool size {}), budget {:.3}s/bench{}",
        par::pool::size(),
        h.b.budget_s,
        if opts.quick { ", quick subset" } else { "" }
    );
    println!("{}", Bencher::header());
    let mut rng = Pcg32::new(1);

    // ---- matmul: paper MLP shapes + dispatch-bound small shapes --------
    let mm_shapes: &[(usize, usize, usize)] = if opts.quick {
        &[(8, 64, 64), (16, 128, 128)]
    } else {
        &[(8, 64, 64), (16, 128, 128), (64, 784, 1024), (64, 1024, 1024)]
    };
    for &(m, k, n) in mm_shapes {
        let a = rand_i(&mut rng, &[m, k], -127, 127);
        let w = rand_i(&mut rng, &[k, n], -32768, 32767);
        let macs = (m * k * n) as f64;
        let reference = matmul_single_thread(&a, &w);
        h.pool_vs_spawn(
            &format!("int_matmul {m}x{k}x{n}"),
            Some(macs),
            || {
                std::hint::black_box(matmul_i64(&a, &w));
            },
            || {
                let pool = matmul_i64(&a, &w);
                par::set_spawn_mode(true);
                let spawn = matmul_i64(&a, &w);
                par::set_spawn_mode(false);
                pool == reference && spawn == reference
            },
        );
    }

    // ---- im2col --------------------------------------------------------
    let xi = rand_i(&mut rng, &[8, 16, 16, 16], -127, 127);
    h.b.bench("im2col b8 c16 16x16 k3", Some((8 * 16 * 16 * 16 * 9) as f64),
              || {
                  std::hint::black_box(im2col(&xi, 3, 1));
              });

    // ---- conv2d + weight grad (with and without patch reuse) -----------
    let conv_shapes: &[(usize, usize, usize, usize)] = if opts.quick {
        &[(2, 8, 16, 10)]
    } else {
        &[(2, 8, 16, 10), (8, 32, 64, 16)]
    };
    for &(bt, c, o, hs) in conv_shapes {
        let x = rand_i(&mut rng, &[bt, c, hs, hs], -127, 127);
        let w = rand_i(&mut rng, &[o, c, 3, 3], -4000, 4000);
        let g = rand_i(&mut rng, &[bt, o, hs, hs], -500, 500);
        let macs = (bt * o * hs * hs * c * 9) as f64;
        let reference = conv2d_i64(&x, &w, 1);
        h.pool_vs_spawn(
            &format!("int_conv2d b{bt} {c}->{o} {hs}x{hs}"),
            Some(macs),
            || {
                std::hint::black_box(conv2d_i64(&x, &w, 1));
            },
            || {
                let mut ws = KernelWorkspace::new();
                let ws_out = kernels().conv2d(&x, &w, 1, &mut ws);
                par::set_spawn_mode(true);
                let spawn = conv2d_i64(&x, &w, 1);
                par::set_spawn_mode(false);
                ws_out == reference && spawn == reference
            },
        );
        // weight grad: fresh extraction vs forward-patch reuse
        let gw_fresh = conv2d_weight_grad(&x, &g, 3, 1);
        let mut ws = KernelWorkspace::new();
        let _ = kernels().conv2d(&x, &w, 1, &mut ws); // prime the patches
        if kernels().conv2d_weight_grad(&x, &g, 3, 1, &mut ws) != gw_fresh {
            h.bitexact_failures
                .push(format!("conv_wgrad b{bt} {c}->{o} {hs}x{hs}"));
        }
        h.b.bench(&format!("conv_wgrad b{bt} {c}->{o} {hs}x{hs} [fresh]"),
                  Some(macs), || {
                      std::hint::black_box(conv2d_weight_grad(&x, &g, 3, 1));
                  });
        h.b.bench(&format!("conv_wgrad b{bt} {c}->{o} {hs}x{hs} [ws-reuse]"),
                  Some(macs), || {
                      std::hint::black_box(kernels().conv2d_weight_grad(
                          &x, &g, 3, 1, &mut ws,
                      ));
                  });
    }

    // ---- NITRO elementwise ---------------------------------------------
    let elems: usize = if opts.quick { 16 * 4096 } else { 64 * 65536 };
    let z = LTensor::from_vec(
        &[64, elems / 64],
        (0..elems).map(|i| (i as i64 * 7919) % (1 << 40)).collect(),
    );
    h.b.bench(&format!("nitro_scale_relu 64x{}", elems / 64),
              Some(elems as f64), || {
                  std::hint::black_box(nitro_scale_relu(&z, 256 * 1152, 10));
              });
    let zs = rand_i(&mut rng, &[64, elems / 64], -127, 127);
    h.b.bench(&format!("nitro_relu 64x{}", elems / 64), Some(elems as f64),
              || {
                  std::hint::black_box(nitro_relu(&zs, 10));
              });

    // ---- per-ISA kernel comparison (speedup vs scalar, hard bit gate) --
    let isa_cmp = isa_comparison(&mut h.b, opts.quick, &mut rng,
                                 &mut h.bitexact_failures);

    // ---- full training steps (paper table 1 MLP / table 2 CNN) ---------
    if !opts.quick {
        for (label, preset, batch) in [
            ("table1-mlp train step (mlp1, b64)", "mlp1", 64usize),
            ("table2-cnn train step (vgg8b-narrow, b8)", "vgg8b-narrow", 8),
        ] {
            let spec = zoo::get(preset).expect("zoo preset");
            let mut shape = vec![batch];
            shape.extend(&spec.input_shape);
            let x = rand_i(&mut rng, &shape, -127, 127);
            let labels: Vec<usize> =
                (0..batch).map(|i| i % spec.num_classes).collect();
            let hp = Hyper { gamma_inv: 512, eta_fw_inv: 12000,
                             eta_lr_inv: 3000 };
            let mut net = Network::new(spec, 1);
            let mut drop = DropoutRngs::new(2, net.blocks.len());
            h.b.bench(label, None, || {
                std::hint::black_box(net.train_batch_parallel(
                    &x, &labels, &hp, &mut drop,
                ));
            });
        }
    }

    // ---- serve throughput (requests/sec, p50/p99 vs micro-batch size,
    // plus a non-quick open-loop overload section through the TCP server
    // and loadgen) -- written to its own schema-versioned
    // BENCH_serve.json; the serving identity checks (ckpt round-trip,
    // fused-vs-reference inference, shard count, hot reload of identical
    // bytes) feed the same hard bit-exactness gate as the kernel paths
    if !opts.serve_out.is_empty() {
        crate::coordinator::serve::bench_serve(
            opts.quick,
            h.b.budget_s,
            &opts.serve_out,
            &mut h.bitexact_failures,
        )?;
    }

    // ---- full-epoch scheduler comparison (samples/sec + bit-exactness) --
    let sched_cmp = if opts.quick {
        Json::Null
    } else {
        // fixed-size workload (not iteration-bounded like the Bencher
        // rows), so scale it with the per-bench budget: small CI budgets
        // get a short but still end-to-end epoch comparison
        let (epochs, n_train) =
            if h.b.budget_s < 0.2 { (2, 320) } else { (3, 640) };
        scheduler_comparison(epochs, n_train, &mut h.bitexact_failures)
    };

    // ---- replica-scaling curve (samples/sec + hard bit-exactness) ------
    let repl_cmp = if opts.quick {
        Json::Null
    } else {
        let (epochs, n_train) =
            if h.b.budget_s < 0.2 { (1, 320) } else { (2, 640) };
        replica_scaling(epochs, n_train, &mut h.bitexact_failures)
    };

    // ---- emit -----------------------------------------------------------
    let record = Json::obj(vec![
        ("schema_version", Json::Int(SCHEMA_VERSION)),
        ("experiment", Json::Str("kernels".to_string())),
        ("workers", Json::Int(workers as i64)),
        ("pool_size", Json::Int(par::pool::size() as i64)),
        ("budget_s", Json::Float(h.b.budget_s)),
        ("quick", Json::Bool(opts.quick)),
        ("rows", h.b.json_value()),
        (
            "pool_speedup_vs_spawn",
            Json::Object(
                h.speedups
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::Float(*v)))
                    .collect(),
            ),
        ),
        ("isa", isa_cmp),
        ("train_scheduler_comparison", sched_cmp),
        ("train_replica_scaling", repl_cmp),
        ("bitexact", Json::Bool(h.bitexact_failures.is_empty())),
        (
            "bitexact_failures",
            Json::Array(
                h.bitexact_failures.iter().cloned().map(Json::Str).collect(),
            ),
        ),
    ]);
    std::fs::write(&opts.out, record.pretty())
        .map_err(|e| format!("write {}: {e}", opts.out))?;
    println!("-> {}", opts.out);
    if opts.write_baseline {
        std::fs::write(BASELINE_PATH, record.pretty())
            .map_err(|e| format!("write {BASELINE_PATH}: {e}"))?;
        println!("-> {BASELINE_PATH} (commit to update the advisory gate)");
    }
    for (name, s) in &h.speedups {
        println!("  pool speedup vs per-call spawn: {s:5.2}x  {name}");
    }

    if let Some(path) = &opts.baseline {
        compare_to_baseline(&record, path)?;
    }
    if h.bitexact_failures.is_empty() {
        println!("bit-exactness: all kernel paths agree");
    } else {
        return Err(format!(
            "bit-exactness MISMATCH in: {}",
            h.bitexact_failures.join(", ")
        ));
    }
    Ok(record)
}

/// Full-epoch training throughput on the tinycnn preset: sequential vs
/// block-parallel vs cross-batch pipelined, with dropout enabled so the
/// per-block RNG streams are exercised. Records samples/sec per scheduler
/// plus speedups, and pushes into `failures` (hard CI failure) if any
/// scheduler's final weights or per-epoch losses deviate from sequential
/// order — the schedulers' bit-identity contract.
fn scheduler_comparison(epochs: usize, n_train: usize,
                        failures: &mut Vec<String>) -> Json {
    let ds = synthetic::by_name("tiny", n_train + 100, 11).expect("tiny");
    let (mut tr, mut te) = ds.split_test(100);
    tr.mad_normalize();
    te.mad_normalize();
    // tinycnn has 3 blocks + head = 4 stages; the pipeline only engages
    // when the worker budget covers one thread per stage, so raise this
    // thread's budget if the machine default is below that — otherwise
    // the "pipelined" row would silently measure block-parallel. The
    // guard restores the enclosing override (panic-safe).
    let nstages = 4usize;
    let workers = par::current_workers().max(nstages);
    let _scope = par::scoped_thread_workers(workers);
    let mut fields: Vec<(&str, Json)> = vec![
        ("preset", Json::Str("tinycnn".to_string())),
        ("n_train", Json::Int(tr.len() as i64)),
        ("epochs", Json::Int(epochs as i64)),
        ("batch", Json::Int(32)),
        ("dropout", Json::Float(0.25)),
        ("workers", Json::Int(workers as i64)),
    ];
    let mut reference: Option<(Vec<ITensor>, Vec<f64>)> = None;
    let mut seq_secs = 0f64;
    for sched in [Scheduler::Sequential, Scheduler::BlockParallel,
                  Scheduler::Pipelined] {
        let mut net = Network::new(zoo::get("tinycnn").unwrap(), 5);
        net.set_dropout(0.25, 0.25);
        let cfg = TrainConfig {
            epochs,
            batch: 32,
            hyper: Hyper { gamma_inv: 128, eta_fw_inv: 12000,
                           eta_lr_inv: 3000 },
            seed: 5,
            scheduler: sched,
            // minimize mid-run evals (epoch 0 and the final epoch still
            // evaluate); whatever eval cost remains is identical for
            // every scheduler, so the comparison stays fair
            eval_every: epochs.max(1),
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let res = fit(&mut net, &tr, &te, &cfg);
        let secs = t0.elapsed().as_secs_f64();
        let sps = (tr.len() * res.epochs.len()) as f64 / secs.max(1e-9);
        let weights: Vec<ITensor> =
            net.weights().into_iter().map(|(_, t)| t.clone()).collect();
        let losses: Vec<f64> =
            res.epochs.iter().map(|e| e.mean_head_loss).collect();
        match &reference {
            None => {
                seq_secs = secs;
                reference = Some((weights, losses));
            }
            Some((rw, rl)) => {
                if rw != &weights || rl != &losses {
                    failures.push(format!(
                        "train-epoch scheduler '{}' not bit-identical to \
                         sequential",
                        sched.name()
                    ));
                }
            }
        }
        println!(
            "  train-epoch [{:<14}] {:>9.1} samples/sec  ({:.3}s, \
             speedup {:.2}x)",
            sched.name(),
            sps,
            secs,
            seq_secs / secs.max(1e-9)
        );
        fields.push((
            sched.name(),
            Json::obj(vec![
                ("secs", Json::Float(secs)),
                ("samples_per_sec", Json::Float(sps)),
                ("speedup_vs_sequential",
                 Json::Float(seq_secs / secs.max(1e-9))),
            ]),
        ));
    }
    Json::obj(fields)
}

/// Full-epoch data-parallel scaling on the tinycnn preset: replicas ∈
/// {1, 2, 4} through the real `fit` path with dropout enabled (so the
/// per-shard mask slicing is exercised). Records the samples/sec scaling
/// curve per replica count and pushes into `failures` (hard CI failure)
/// if any replicated run's final weights or per-epoch losses deviate
/// from `replicas = 1` — the replicated-training bit-identity contract.
fn replica_scaling(epochs: usize, n_train: usize,
                   failures: &mut Vec<String>) -> Json {
    let ds = synthetic::by_name("tiny", n_train + 100, 13).expect("tiny");
    let (mut tr, mut te) = ds.split_test(100);
    tr.mad_normalize();
    te.mad_normalize();
    // cover the widest replica fan-out even on small boxes; the guard
    // restores the enclosing override (panic-safe)
    let workers = par::current_workers().max(4);
    let _scope = par::scoped_thread_workers(workers);
    let mut fields: Vec<(&str, Json)> = vec![
        ("preset", Json::Str("tinycnn".to_string())),
        ("n_train", Json::Int(tr.len() as i64)),
        ("epochs", Json::Int(epochs as i64)),
        ("batch", Json::Int(32)),
        ("dropout", Json::Float(0.25)),
        ("workers", Json::Int(workers as i64)),
    ];
    let mut reference: Option<(Vec<ITensor>, Vec<f64>)> = None;
    let mut base_secs = 0f64;
    for (replicas, key) in
        [(1usize, "replicas1"), (2, "replicas2"), (4, "replicas4")]
    {
        let mut net = Network::new(zoo::get("tinycnn").unwrap(), 5);
        net.set_dropout(0.25, 0.25);
        let cfg = TrainConfig {
            epochs,
            batch: 32,
            hyper: Hyper { gamma_inv: 128, eta_fw_inv: 12000,
                           eta_lr_inv: 3000 },
            seed: 5,
            scheduler: Scheduler::BlockParallel,
            replicas,
            eval_every: epochs.max(1),
            ..Default::default()
        };
        let t0 = std::time::Instant::now();
        let res = fit(&mut net, &tr, &te, &cfg);
        let secs = t0.elapsed().as_secs_f64();
        let sps = (tr.len() * res.epochs.len()) as f64 / secs.max(1e-9);
        let weights: Vec<ITensor> =
            net.weights().into_iter().map(|(_, t)| t.clone()).collect();
        let losses: Vec<f64> =
            res.epochs.iter().map(|e| e.mean_head_loss).collect();
        match &reference {
            None => {
                base_secs = secs;
                reference = Some((weights, losses));
            }
            Some((rw, rl)) => {
                if rw != &weights || rl != &losses {
                    failures.push(format!(
                        "train-epoch replicas={replicas} not bit-identical \
                         to replicas=1"
                    ));
                }
            }
        }
        println!(
            "  train-epoch [replicas={replicas}] {sps:>9.1} samples/sec  \
             ({secs:.3}s, scaling {:.2}x)",
            base_secs / secs.max(1e-9)
        );
        fields.push((
            key,
            Json::obj(vec![
                ("replicas", Json::Int(replicas as i64)),
                ("secs", Json::Float(secs)),
                ("samples_per_sec", Json::Float(sps)),
                ("speedup_vs_replicas1",
                 Json::Float(base_secs / secs.max(1e-9))),
            ]),
        ));
    }
    Json::obj(fields)
}

/// Per-ISA kernel comparison: run every kernel with SIMD variants on
/// each ISA the host supports (scalar always first), on identical
/// inputs with a single worker — this measures instruction throughput,
/// not pool scaling. Emits one row per (kernel, ISA) with the median
/// and the speedup vs the scalar row, and bit-compares every ISA's
/// output against scalar's; a divergence rides the same hard `Err`
/// gate as the pool/spawn checks, so a broken SIMD path goes CI-red.
fn isa_comparison(b: &mut Bencher, quick: bool, rng: &mut Pcg32,
                  failures: &mut Vec<String>) -> Json {
    fn compare<T: PartialEq>(
        b: &mut Bencher, rows: &mut Vec<Json>, failures: &mut Vec<String>,
        isas: &[Isa], name: &str, work: f64,
        mut run: impl FnMut(KernelBackend) -> T,
    ) {
        let mut scalar_ns = 0f64;
        let mut reference: Option<T> = None;
        for &isa in isas {
            let kb = KernelBackend::with_isa(isa);
            let out = run(kb);
            match &reference {
                None => reference = Some(out),
                Some(r) if *r != out => failures
                    .push(format!("isa[{}] {name} != scalar", isa.name())),
                _ => {}
            }
            let med = b
                .bench(&format!("{name} [{}]", isa.name()), Some(work),
                       || {
                           std::hint::black_box(run(kb));
                       })
                .median_ns;
            if isa == Isa::Scalar {
                scalar_ns = med;
            } else {
                println!("  isa speedup vs scalar: {:5.2}x  {name} [{}]",
                         scalar_ns / med.max(1e-9), isa.name());
            }
            rows.push(Json::obj(vec![
                ("kernel", Json::Str(name.to_string())),
                ("isa", Json::Str(isa.name().to_string())),
                ("median_ns", Json::Float(med)),
                ("speedup_vs_scalar",
                 Json::Float(scalar_ns / med.max(1e-9))),
            ]));
        }
    }

    let _scope = par::scoped_thread_workers(1);
    let isas = backend::supported_isas();
    let mut rows: Vec<Json> = Vec::new();

    // matmul
    let (m, k, n) = if quick { (16, 128, 128) } else { (64, 784, 1024) };
    let a = rand_i(rng, &[m, k], -127, 127);
    let w = rand_i(rng, &[k, n], -32768, 32767);
    let mut mm_out = vec![0i64; m * n];
    compare(b, &mut rows, failures, &isas,
            &format!("isa_matmul {m}x{k}x{n}"), (m * k * n) as f64, |kb| {
                mm_out.iter_mut().for_each(|v| *v = 0);
                kb.matmul_i64(&a.data, &w.data, m, k, n, &mut mm_out, 1);
                mm_out.clone()
            });

    // fused conv2d+scale (exercises im2col row copies + the scale
    // epilogue) and the standalone patch extraction
    let (cb, cc, co, chs) =
        if quick { (2, 8, 16, 10) } else { (8, 32, 64, 16) };
    let cx = rand_i(rng, &[cb, cc, chs, chs], -127, 127);
    let cw = rand_i(rng, &[co, cc, 3, 3], -4000, 4000);
    let csf = scale_factor_conv(3, cc);
    let mut cws = KernelWorkspace::new();
    let mut cout = ITensor::empty();
    compare(b, &mut rows, failures, &isas,
            &format!("isa_conv2d_scale b{cb} {cc}->{co} {chs}x{chs}"),
            (cb * co * chs * chs * cc * 9) as f64, |kb| {
                kb.conv2d_scale(&cx, &cw, 1, csf, &mut cws, &mut cout);
                cout.clone()
            });
    compare(b, &mut rows, failures, &isas,
            &format!("isa_im2col b{cb} c{cc} {chs}x{chs} k3"),
            (cb * cc * chs * chs * 9) as f64,
            |kb| kb.im2col(&cx, 3, 1));

    // NITRO element kernels
    let elems: usize = if quick { 16 * 4096 } else { 64 * 65536 };
    let z = LTensor::from_vec(
        &[64, elems / 64],
        (0..elems).map(|i| (i as i64 * 7919) % (1 << 40)).collect(),
    );
    let zs = rand_i(rng, &[64, elems / 64], -200, 200);
    let gr = rand_i(rng, &[64, elems / 64], -500, 500);
    compare(b, &mut rows, failures, &isas, "isa_nitro_scale",
            elems as f64, |kb| kb.nitro_scale(&z, 256 * 1152));
    compare(b, &mut rows, failures, &isas, "isa_nitro_scale_relu",
            elems as f64, |kb| kb.nitro_scale_relu(&z, 256 * 1152, 10));
    compare(b, &mut rows, failures, &isas, "isa_nitro_relu",
            elems as f64, |kb| kb.nitro_relu(&zs, 10));
    compare(b, &mut rows, failures, &isas, "isa_nitro_relu_bwd",
            elems as f64, |kb| kb.nitro_relu_bwd(&zs, &gr, 10));

    Json::obj(vec![
        ("active", Json::Str(backend::active().name().to_string())),
        (
            "supported",
            Json::Array(
                isas.iter()
                    .map(|i| Json::Str(i.name().to_string()))
                    .collect(),
            ),
        ),
        ("kernels", Json::Array(rows)),
    ])
}

/// Single-thread *scalar-ISA* reference matmul — the fixed point every
/// other (ISA × threading) combination is checked against.
fn matmul_single_thread(a: &ITensor, b: &ITensor) -> LTensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let n = b.shape[1];
    let mut out = vec![0i64; m * n];
    KernelBackend::with_isa(Isa::Scalar)
        .matmul_i64(&a.data, &b.data, m, k, n, &mut out, 1);
    Tensor::from_vec(&[m, n], out)
}

/// Advisory baseline comparison: per-row median deltas beyond
/// [`BASELINE_GATE`] print `::warning::` annotations (picked up by GitHub
/// Actions) but never fail. Only a missing/unreadable baseline is an
/// error.
fn compare_to_baseline(record: &Json, path: &str) -> Result<(), String> {
    let base = Json::parse_file(path)?;
    let base_rows: Vec<(&str, f64)> = base
        .get("rows")
        .and_then(Json::as_array)
        .map(|rows| {
            rows.iter()
                .filter_map(|r| {
                    Some((
                        r.get("name")?.as_str()?,
                        r.get("median_ns")?.as_f64()?,
                    ))
                })
                .collect()
        })
        .unwrap_or_default();
    let rows = record.get("rows").and_then(Json::as_array).unwrap_or(&[]);
    let mut compared = 0usize;
    let mut flagged = 0usize;
    for r in rows {
        let (Some(name), Some(med)) = (
            r.get("name").and_then(Json::as_str),
            r.get("median_ns").and_then(Json::as_f64),
        ) else {
            continue;
        };
        let Some(&(_, bmed)) =
            base_rows.iter().find(|(bn, _)| *bn == name)
        else {
            continue;
        };
        compared += 1;
        let delta = med / bmed - 1.0;
        if delta.abs() > BASELINE_GATE {
            flagged += 1;
            println!(
                "::warning title=bench-kernels::'{name}' median {:+.0}% vs \
                 baseline ({:.0} ns vs {:.0} ns) — advisory, timings are \
                 machine-dependent",
                delta * 100.0,
                med,
                bmed
            );
        }
    }
    println!(
        "baseline {path}: {compared} rows compared, {flagged} outside the \
         ±{:.0}% advisory gate",
        BASELINE_GATE * 100.0
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheduler_comparison_bitexact_and_reports_throughput() {
        let mut failures = Vec::new();
        let j = scheduler_comparison(1, 96, &mut failures);
        assert!(failures.is_empty(), "schedulers diverged: {failures:?}");
        for key in ["sequential", "block-parallel", "pipelined"] {
            let row = j.req(key).unwrap_or_else(|e| panic!("{key}: {e}"));
            let sps =
                row.req("samples_per_sec").unwrap().as_f64().unwrap();
            assert!(sps > 0.0, "{key}: {sps}");
        }
    }

    #[test]
    fn replica_scaling_bitexact_and_reports_throughput() {
        let mut failures = Vec::new();
        let j = replica_scaling(1, 96, &mut failures);
        assert!(failures.is_empty(), "replicas diverged: {failures:?}");
        for key in ["replicas1", "replicas2", "replicas4"] {
            let row = j.req(key).unwrap_or_else(|e| panic!("{key}: {e}"));
            let sps =
                row.req("samples_per_sec").unwrap().as_f64().unwrap();
            assert!(sps > 0.0, "{key}: {sps}");
        }
    }

    #[test]
    fn quick_harness_end_to_end() {
        let dir = std::env::temp_dir().join("nitro_kernelbench_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_kernels.json");
        let opts = Opts {
            budget_s: Some(0.005),
            out: out.to_str().unwrap().to_string(),
            baseline: None,
            write_baseline: false,
            quick: true,
            serve_out: dir
                .join("BENCH_serve.json")
                .to_str()
                .unwrap()
                .to_string(),
        };
        let rec = run(&opts).unwrap();
        // the serve-throughput record rides along
        let serve = Json::parse_file(&opts.serve_out).unwrap();
        assert_eq!(serve.req("experiment").unwrap().as_str(), Some("serve"));
        assert!(serve.req("serve_throughput").unwrap().as_array().unwrap()
                    .len() >= 3);
        assert_eq!(rec.req("schema_version").unwrap().as_i64(),
                   Some(SCHEMA_VERSION));
        assert_eq!(rec.req("bitexact").unwrap().as_bool(), Some(true));
        let rows = rec.req("rows").unwrap().as_array().unwrap();
        assert!(rows.len() >= 6, "expected several rows, got {}", rows.len());
        // the per-ISA section: every host supports scalar at minimum,
        // and each of the 7 kernels gets one row per supported ISA
        let isa = rec.req("isa").unwrap();
        let supported = isa.req("supported").unwrap().as_array().unwrap();
        assert!(!supported.is_empty());
        let krows = isa.req("kernels").unwrap().as_array().unwrap();
        assert_eq!(krows.len(), 7 * supported.len());
        for r in krows {
            let s = r.req("speedup_vs_scalar").unwrap().as_f64().unwrap();
            assert!(s > 0.0, "speedup: {s}");
        }
        // the record reparses from disk with the schema intact (integral
        // floats round-trip as ints, so no full structural equality here)
        let reread = Json::parse_file(out.to_str().unwrap()).unwrap();
        assert_eq!(reread.req("schema_version").unwrap().as_i64(),
                   Some(SCHEMA_VERSION));
        assert_eq!(reread.req("bitexact").unwrap().as_bool(), Some(true));
        assert_eq!(
            reread.req("rows").unwrap().as_array().unwrap().len(),
            rows.len()
        );
        // self-comparison stays advisory (exit Ok) even with noisy timings
        let opts2 = Opts {
            baseline: Some(out.to_str().unwrap().to_string()),
            out: dir.join("BENCH_kernels2.json").to_str().unwrap().to_string(),
            ..opts
        };
        run(&opts2).unwrap();
        // a missing baseline is a real error
        let opts3 = Opts {
            baseline: Some("does/not/exist.json".to_string()),
            quick: true,
            budget_s: Some(0.001),
            out: dir.join("BENCH_kernels3.json").to_str().unwrap().to_string(),
            write_baseline: false,
            serve_out: String::new(), // skip the serve section here
        };
        assert!(run(&opts3).is_err());
    }
}
