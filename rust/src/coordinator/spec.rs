//! Declarative experiment specifications.
//!
//! An [`ExperimentSpec`] is a JSON file under `experiments/` declaring one
//! paper table as data: the dataset/preset grid, the engine set
//! (NITRO-D native / FP baselines / PocketNN-DFA), seeds, scale knobs and
//! hyper-parameters. The runner (`coordinator::runner`) expands a spec
//! into [`ResolvedRun`]s and executes them; nothing about a table lives in
//! imperative driver code any more.
//!
//! Schema (all keys except `name` and `runs` optional — see README.md for
//! the full reference):
//!
//! ```text
//! {
//!   "name": "table1",
//!   "description": "...",
//!   "scale": "quick" | "full",            // default scale
//!   "seeds": [42, 43],                    // one run per (row, engine, seed)
//!   "engines": ["nitro","pocketnn","fp-les","fp-bp"],
//!   "bench_output": "BENCH_table1.json",  // aggregate record path
//!   "fixed_lr": false,                    // disable plateau LR scheduling
//!   "scheduler": "pipelined",             // LES scheduler (metric-identical)
//!   "replicas": 1,                        // data-parallel replicas (ditto)
//!   "ranks": 1,                           // loopback dist ranks (ditto)
//!   "fp_lr": 0.001,                       // Adam LR for the FP baselines
//!   "fp_epochs_div": 1,                   // FP baselines run epochs/div
//!   "defaults": {"batch": 64, "hyper": {...}, "dropout": [0.0, 0.0]},
//!   "quick": {"n_train": ..., "n_test": ..., "epochs": ...,
//!             "batch": ..., "gamma_inv": ...},
//!   "full":  {...},
//!   "runs": [
//!     {"id": "mlp1/mnist", "preset": "mlp1", "preset_quick": "...",
//!      "dataset": "mnist", "dataset_quick": "...",
//!      "hyper": {"eta_fw_inv": 30000},    // partial, merged over defaults
//!      "dropout": [0.05, 0.5], "epochs": 60, "batch": 32,
//!      "engines": [...], "scales": ["quick"],
//!      "paper_acc": 97.36, "paper_note": "..."}
//!   ]
//! }
//! ```
//!
//! Hyper-parameter resolution order (later wins): built-in default
//! `{512, 0, 0}` → `defaults.hyper` → the active scale section's
//! `gamma_inv` → the run's `hyper`.

use crate::coordinator::experiments::Scale;
use crate::nn::spec::BitsPlan;
use crate::nn::Hyper;
use crate::train::Scheduler;
use crate::util::jsonio::Json;

/// Execution engine requested by a spec.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// The pure-Rust integer NITRO-D engine (`nn::Network`).
    Nitro,
    /// Float Local-Error-Signals baseline (`baselines::fp::train_les`).
    FpLes,
    /// Float global-backprop baseline (`baselines::fp::train_bp`).
    FpBp,
    /// Integer DFA baseline (`baselines::pocketnn`) — MLP presets only.
    PocketNn,
}

impl EngineKind {
    pub fn parse(s: &str) -> Result<EngineKind, String> {
        Ok(match s {
            "nitro" => EngineKind::Nitro,
            "fp-les" => EngineKind::FpLes,
            "fp-bp" => EngineKind::FpBp,
            "pocketnn" => EngineKind::PocketNn,
            other => {
                return Err(format!(
                    "unknown engine '{other}' (nitro|fp-les|fp-bp|pocketnn)"
                ))
            }
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Nitro => "nitro",
            EngineKind::FpLes => "fp-les",
            EngineKind::FpBp => "fp-bp",
            EngineKind::PocketNn => "pocketnn",
        }
    }
}

/// Partial hyper-parameter override: only the keys present in the JSON.
#[derive(Clone, Copy, Debug, Default)]
pub struct PartialHyper {
    pub gamma_inv: Option<i64>,
    pub eta_fw_inv: Option<i64>,
    pub eta_lr_inv: Option<i64>,
}

impl PartialHyper {
    fn parse(j: Option<&Json>) -> Result<PartialHyper, String> {
        let Some(j) = j else { return Ok(PartialHyper::default()) };
        let grab = |key: &str| -> Result<Option<i64>, String> {
            match j.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_i64()
                    .map(Some)
                    .ok_or_else(|| format!("hyper.{key}: not an integer")),
            }
        };
        Ok(PartialHyper {
            gamma_inv: grab("gamma_inv")?,
            eta_fw_inv: grab("eta_fw_inv")?,
            eta_lr_inv: grab("eta_lr_inv")?,
        })
    }

    fn apply(&self, hp: &mut Hyper) {
        if let Some(v) = self.gamma_inv {
            hp.gamma_inv = v;
        }
        if let Some(v) = self.eta_fw_inv {
            hp.eta_fw_inv = v;
        }
        if let Some(v) = self.eta_lr_inv {
            hp.eta_lr_inv = v;
        }
    }
}

/// Scale-dependent workload knobs (one per `quick`/`full` section).
#[derive(Clone, Debug)]
pub struct ScaleCfg {
    pub n_train: usize,
    pub n_test: usize,
    pub epochs: usize,
    pub batch: Option<usize>,
    pub gamma_inv: Option<i64>,
}

impl ScaleCfg {
    fn parse(j: Option<&Json>, n_train: usize, n_test: usize,
             epochs: usize) -> Result<ScaleCfg, String> {
        let (nt, ns, ep, batch, gamma) = match j {
            None => (n_train, n_test, epochs, None, None),
            Some(j) => (
                opt_usize(j, "n_train")?.unwrap_or(n_train),
                opt_usize(j, "n_test")?.unwrap_or(n_test),
                opt_usize(j, "epochs")?.unwrap_or(epochs),
                opt_usize(j, "batch")?,
                j.get("gamma_inv").and_then(Json::as_i64),
            ),
        };
        Ok(ScaleCfg {
            n_train: nt,
            n_test: ns,
            epochs: ep,
            batch,
            gamma_inv: gamma,
        })
    }
}

/// Non-negative integer field; negative values are a spec error, never a
/// silent `as usize` wrap.
fn opt_usize(j: &Json, key: &str) -> Result<Option<usize>, String> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => {
            let n = v
                .as_i64()
                .ok_or_else(|| format!("{key}: not an integer"))?;
            if n < 0 {
                return Err(format!("{key}: must be >= 0, got {n}"));
            }
            Ok(Some(n as usize))
        }
    }
}

fn parse_dropout(j: Option<&Json>) -> Result<Option<(f64, f64)>, String> {
    let Some(j) = j else { return Ok(None) };
    let arr = j.as_array().ok_or("dropout: expected [p_c, p_l]")?;
    if arr.len() != 2 {
        return Err("dropout: expected exactly [p_c, p_l]".to_string());
    }
    let p = |v: &Json| v.as_f64().ok_or("dropout: not a number".to_string());
    Ok(Some((p(&arr[0])?, p(&arr[1])?)))
}

/// `"bits"` key: one bitwidth cell or an array of cells to sweep. A cell
/// is anything [`BitsPlan::from_json`] accepts — an integer (`8` =
/// uniform W/A at 8 bits, G/E at 64), a `"W/A/G/E"` string, or an object
/// with optional per-layer overrides. Absent = the full-width default
/// (32/32/64/64), which clamps nothing.
fn parse_bits(j: Option<&Json>) -> Result<Vec<BitsPlan>, String> {
    let Some(j) = j else { return Ok(vec![BitsPlan::default()]) };
    let cells = match j.as_array() {
        Some(arr) => {
            if arr.is_empty() {
                return Err("bits: must not be empty".to_string());
            }
            let mut out = Vec::with_capacity(arr.len());
            for (i, cell) in arr.iter().enumerate() {
                out.push(
                    BitsPlan::from_json(cell)
                        .map_err(|e| format!("bits[{i}]: {e}"))?,
                );
            }
            out
        }
        None => vec![BitsPlan::from_json(j).map_err(|e| format!("bits: {e}"))?],
    };
    Ok(cells)
}

fn parse_engines(j: Option<&Json>) -> Result<Option<Vec<EngineKind>>, String> {
    let Some(j) = j else { return Ok(None) };
    let arr = j.as_array().ok_or("engines: expected an array")?;
    let mut out = Vec::new();
    for e in arr {
        out.push(EngineKind::parse(
            e.as_str().ok_or("engines: expected strings")?,
        )?);
    }
    if out.is_empty() {
        return Err("engines: must not be empty".to_string());
    }
    Ok(Some(out))
}

/// One (preset, dataset) row of a table, before scale/engine expansion.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub id: String,
    pub preset: String,
    pub preset_quick: Option<String>,
    pub dataset: String,
    pub dataset_quick: Option<String>,
    pub hyper: PartialHyper,
    pub dropout: Option<(f64, f64)>,
    pub epochs: Option<usize>,
    pub batch: Option<usize>,
    pub engines: Option<Vec<EngineKind>>,
    /// Restrict the row to these scales (both when absent) — lets one spec
    /// carry scale-specific sweep grids (Table 8).
    pub scales: Option<Vec<Scale>>,
    pub paper_acc: Option<f64>,
    pub paper_note: Option<String>,
}

impl RunSpec {
    fn parse(j: &Json) -> Result<RunSpec, String> {
        let id = j
            .req("id")?
            .as_str()
            .ok_or("run id: not a string")?
            .to_string();
        let ctx = |e: String| format!("run '{id}': {e}");
        let preset = j
            .req("preset")
            .and_then(|v| v.as_str().ok_or("preset: not a string".into()))
            .map_err(&ctx)?
            .to_string();
        let dataset = j
            .req("dataset")
            .and_then(|v| v.as_str().ok_or("dataset: not a string".into()))
            .map_err(&ctx)?
            .to_string();
        let opt_str = |key: &str| {
            j.get(key).and_then(Json::as_str).map(str::to_string)
        };
        let scales = match j.get("scales") {
            None => None,
            Some(v) => {
                let arr = v.as_array().ok_or("scales: expected an array")
                    .map_err(|e| ctx(e.to_string()))?;
                let mut out = Vec::new();
                for (i, s) in arr.iter().enumerate() {
                    // a non-string element is its own error with its index,
                    // not a bogus Scale::parse("?") message
                    let s = s.as_str().ok_or_else(|| {
                        ctx(format!("scales[{i}]: expected string"))
                    })?;
                    out.push(Scale::parse(s).map_err(&ctx)?);
                }
                Some(out)
            }
        };
        Ok(RunSpec {
            preset,
            dataset,
            preset_quick: opt_str("preset_quick"),
            dataset_quick: opt_str("dataset_quick"),
            hyper: PartialHyper::parse(j.get("hyper")).map_err(&ctx)?,
            dropout: parse_dropout(j.get("dropout")).map_err(&ctx)?,
            epochs: opt_usize(j, "epochs").map_err(&ctx)?,
            batch: opt_usize(j, "batch").map_err(&ctx)?,
            engines: parse_engines(j.get("engines")).map_err(&ctx)?,
            scales,
            paper_acc: j.get("paper_acc").and_then(Json::as_f64),
            paper_note: opt_str("paper_note"),
            id,
        })
    }
}

/// A parsed experiment spec: the declarative form of one paper table.
#[derive(Clone, Debug)]
pub struct ExperimentSpec {
    pub name: String,
    pub description: String,
    pub scale: Scale,
    pub seeds: Vec<u64>,
    pub engines: Vec<EngineKind>,
    pub bench_output: String,
    pub fixed_lr: bool,
    /// LES scheduler for the nitro engine (`"scheduler"` key:
    /// sequential|block-parallel|pipelined; default pipelined). All three
    /// are metric-identical — this knob exists for benchmarking and CI
    /// cross-checks.
    pub scheduler: Scheduler,
    /// Data-parallel replica count for the nitro engine (`"replicas"`
    /// key, ≥ 1, default 1). Metric-identical for every value — like
    /// `scheduler`, a benchmarking/CI cross-check knob, not a modelling
    /// one.
    pub replicas: usize,
    /// Distributed world size for the nitro engine (`"ranks"` key, ≥ 1,
    /// default 1): the run executes as `ranks` loopback-TCP
    /// `train::dist` ranks, one thread each, and must stay
    /// metric-identical to `ranks = 1` (the integer all-reduce is
    /// exact). A cross-check knob like `scheduler` and `replicas`.
    pub ranks: usize,
    /// W/A/G/E bitwidth cells for the nitro engine (`"bits"` key): each
    /// cell expands every nitro row into its own run. Unlike `scheduler`
    /// and `replicas` this IS a modelling knob — different rails change
    /// the arithmetic. FP/PocketNN baselines ignore it (one default row).
    pub bits: Vec<BitsPlan>,
    pub fp_lr: f64,
    pub fp_epochs_div: usize,
    /// Batch size for the FP baselines (the paper's baselines always ran
    /// at batch 64 even where the integer engine uses a scale-calibrated
    /// batch); `None` = same as the integer engine's batch.
    pub fp_batch: Option<usize>,
    pub defaults_hyper: PartialHyper,
    pub defaults_dropout: (f64, f64),
    pub defaults_batch: usize,
    pub quick: ScaleCfg,
    pub full: ScaleCfg,
    pub runs: Vec<RunSpec>,
}

impl ExperimentSpec {
    pub fn parse(j: &Json) -> Result<ExperimentSpec, String> {
        let name = j
            .req("name")?
            .as_str()
            .ok_or("name: not a string")?
            .to_string();
        let seeds: Vec<u64> = match j.get("seeds") {
            None => vec![42],
            Some(v) => {
                let raw = v.i64_vec().map_err(|e| format!("seeds: {e}"))?;
                let mut out = Vec::with_capacity(raw.len());
                for s in raw {
                    if s < 0 {
                        return Err(format!("seeds: must be >= 0, got {s}"));
                    }
                    out.push(s as u64);
                }
                out
            }
        };
        if seeds.is_empty() {
            return Err("seeds: must not be empty".to_string());
        }
        let engines = parse_engines(j.get("engines"))?
            .unwrap_or_else(|| vec![EngineKind::Nitro]);
        let defaults = j.get("defaults");
        let (defaults_hyper, defaults_dropout, defaults_batch) = match defaults
        {
            None => (PartialHyper::default(), (0.0, 0.0), 64),
            Some(d) => (
                PartialHyper::parse(d.get("hyper"))?,
                parse_dropout(d.get("dropout"))?.unwrap_or((0.0, 0.0)),
                opt_usize(d, "batch")?.unwrap_or(64),
            ),
        };
        let runs_j = j
            .req("runs")?
            .as_array()
            .ok_or("runs: expected an array")?;
        if runs_j.is_empty() {
            return Err("runs: must not be empty".to_string());
        }
        let mut runs = Vec::new();
        for r in runs_j {
            runs.push(RunSpec::parse(r)?);
        }
        Ok(ExperimentSpec {
            description: j.str_or("description", ""),
            scale: Scale::parse(&j.str_or("scale", "quick"))?,
            seeds,
            engines,
            bench_output: {
                let d = format!("BENCH_{name}.json");
                j.str_or("bench_output", &d)
            },
            fixed_lr: j.bool_or("fixed_lr", false),
            scheduler: match j.get("scheduler") {
                None => Scheduler::default(),
                Some(v) => Scheduler::parse(
                    v.as_str().ok_or("scheduler: not a string")?,
                )?,
            },
            replicas: match opt_usize(j, "replicas")? {
                None => 1,
                Some(0) => {
                    return Err("replicas: must be >= 1".to_string())
                }
                Some(n) => n,
            },
            ranks: match opt_usize(j, "ranks")? {
                None => 1,
                Some(0) => return Err("ranks: must be >= 1".to_string()),
                Some(n) => n,
            },
            bits: parse_bits(j.get("bits"))?,
            fp_lr: j.f64_or("fp_lr", 1e-3),
            fp_epochs_div: opt_usize(j, "fp_epochs_div")?.unwrap_or(1).max(1),
            fp_batch: opt_usize(j, "fp_batch")?,
            defaults_hyper,
            defaults_dropout,
            defaults_batch,
            // scale-section fallbacks mirror the old ExpCtx quick/full
            // workload sizes
            quick: ScaleCfg::parse(j.get("quick"), 1200, 300, 60)
                .map_err(|e| format!("quick: {e}"))?,
            full: ScaleCfg::parse(j.get("full"), 20000, 4000, 150)
                .map_err(|e| format!("full: {e}"))?,
            runs,
            name,
        })
    }

    pub fn load(path: &str) -> Result<ExperimentSpec, String> {
        let j = Json::parse_file(path)?;
        ExperimentSpec::parse(&j).map_err(|e| format!("{path}: {e}"))
    }

    /// Embedded copies of the committed spec files, so `nitro experiment
    /// table1` works regardless of the process working directory.
    pub fn builtin_source(name: &str) -> Option<&'static str> {
        Some(match name {
            "smoke" => include_str!("../../../experiments/smoke.json"),
            "table1" => include_str!("../../../experiments/table1.json"),
            "table2" => include_str!("../../../experiments/table2.json"),
            "table8" => include_str!("../../../experiments/table8.json"),
            "table9" => include_str!("../../../experiments/table9.json"),
            _ => return None,
        })
    }

    pub fn load_builtin(name: &str) -> Result<ExperimentSpec, String> {
        let src = Self::builtin_source(name)
            .ok_or_else(|| format!("no builtin experiment spec '{name}'"))?;
        let j = Json::parse(src).map_err(|e| format!("builtin {name}: {e}"))?;
        ExperimentSpec::parse(&j).map_err(|e| format!("builtin {name}: {e}"))
    }

    /// Expand into the concrete (row × engine × seed) grid at `scale`.
    /// `seed_override` replaces the spec's seed list; `epochs_override > 0`
    /// replaces every run's epoch budget.
    pub fn resolve(&self, scale: Scale, seed_override: Option<u64>,
                   epochs_override: usize) -> Result<Vec<ResolvedRun>, String> {
        let seeds: Vec<u64> = match seed_override {
            Some(s) => vec![s],
            None => self.seeds.clone(),
        };
        let sc = match scale {
            Scale::Quick => &self.quick,
            Scale::Full => &self.full,
        };
        let mut out = Vec::new();
        for run in &self.runs {
            if let Some(ss) = &run.scales {
                if !ss.contains(&scale) {
                    continue;
                }
            }
            let pick = |full: &str, quick: &Option<String>| match scale {
                Scale::Quick => {
                    quick.clone().unwrap_or_else(|| full.to_string())
                }
                Scale::Full => full.to_string(),
            };
            let mut hyper = Hyper::default();
            self.defaults_hyper.apply(&mut hyper);
            if let Some(g) = sc.gamma_inv {
                hyper.gamma_inv = g;
            }
            run.hyper.apply(&mut hyper);
            let epochs = if epochs_override > 0 {
                epochs_override
            } else {
                run.epochs.unwrap_or(sc.epochs)
            };
            if epochs == 0 {
                return Err(format!("run '{}': zero epochs", run.id));
            }
            let fp_epochs =
                (epochs / self.fp_epochs_div).max(10).min(epochs);
            let batch = run.batch.or(sc.batch).unwrap_or(self.defaults_batch);
            let fp_batch = self.fp_batch.unwrap_or(batch);
            let engines = run.engines.as_ref().unwrap_or(&self.engines);
            let default_bits = [BitsPlan::default()];
            for &engine in engines {
                // only the nitro engine sweeps bitwidth cells; the FP and
                // PocketNN baselines have no integer rails to configure
                let cells: &[BitsPlan] = if engine == EngineKind::Nitro {
                    &self.bits
                } else {
                    &default_bits
                };
                for cell in cells {
                    for &seed in &seeds {
                        // non-default cells get an id suffix so detail
                        // files and BENCH rows stay collision-free
                        let id = if cell.is_default() {
                            run.id.clone()
                        } else {
                            format!("{}+bits{}", run.id,
                                    cell.label().replace('/', "-"))
                        };
                        out.push(ResolvedRun {
                            id,
                            preset: pick(&run.preset, &run.preset_quick),
                            dataset: pick(&run.dataset, &run.dataset_quick),
                            engine,
                            seed,
                            scale,
                            epochs,
                            fp_epochs,
                            batch,
                            fp_batch,
                            n_train: sc.n_train,
                            n_test: sc.n_test,
                            hyper,
                            dropout: run
                                .dropout
                                .unwrap_or(self.defaults_dropout),
                            fixed_lr: self.fixed_lr,
                            scheduler: self.scheduler,
                            replicas: self.replicas,
                            ranks: self.ranks,
                            bits: cell.clone(),
                            fp_lr: self.fp_lr,
                            paper_acc: run.paper_acc,
                            paper_note: run.paper_note.clone(),
                        });
                    }
                }
            }
        }
        if out.is_empty() {
            return Err(format!(
                "spec '{}' resolves to no runs at {} scale",
                self.name,
                scale.name()
            ));
        }
        Ok(out)
    }
}

/// A fully-resolved unit of work: one (row, engine, seed) at one scale.
/// Everything the runner needs, nothing left to look up.
#[derive(Clone, Debug)]
pub struct ResolvedRun {
    pub id: String,
    pub preset: String,
    pub dataset: String,
    pub engine: EngineKind,
    pub seed: u64,
    pub scale: Scale,
    pub epochs: usize,
    /// Epoch budget for the FP baselines (Adam needs no integer
    /// bootstrap, so specs may divide it down via `fp_epochs_div`).
    pub fp_epochs: usize,
    pub batch: usize,
    /// Batch size for the FP baselines (`fp_batch` spec key).
    pub fp_batch: usize,
    pub n_train: usize,
    pub n_test: usize,
    pub hyper: Hyper,
    pub dropout: (f64, f64),
    pub fixed_lr: bool,
    /// LES scheduler for the nitro engine (metric-identical across all
    /// three; see [`Scheduler`]).
    pub scheduler: Scheduler,
    /// Data-parallel replica count for the nitro engine
    /// (metric-identical for every value; see `train::replica`).
    pub replicas: usize,
    /// Distributed loopback world size for the nitro engine
    /// (metric-identical for every value; see `train::dist`).
    pub ranks: usize,
    /// W/A/G/E rails for this run (default = full width, no clamping).
    pub bits: BitsPlan,
    pub fp_lr: f64,
    pub paper_acc: Option<f64>,
    pub paper_note: Option<String>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_specs_parse_and_resolve_both_scales() {
        for name in ["smoke", "table1", "table2", "table8", "table9"] {
            let spec = ExperimentSpec::load_builtin(name)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(spec.name, name);
            for scale in [Scale::Quick, Scale::Full] {
                let runs = spec
                    .resolve(scale, None, 0)
                    .unwrap_or_else(|e| panic!("{name}/{scale:?}: {e}"));
                for r in &runs {
                    assert!(
                        crate::nn::zoo::get(&r.preset).is_some(),
                        "{name}: unknown preset '{}'",
                        r.preset
                    );
                    assert!(r.epochs > 0 && r.batch > 0);
                }
            }
        }
    }

    #[test]
    fn unknown_builtin_is_err() {
        assert!(ExperimentSpec::load_builtin("bogus").is_err());
    }

    #[test]
    fn hyper_resolution_order() {
        // defaults < scale gamma_inv < run hyper
        let j = Json::parse(
            r#"{
              "name": "t",
              "defaults": {"hyper": {"gamma_inv": 999, "eta_fw_inv": 7}},
              "quick": {"gamma_inv": 128, "epochs": 5},
              "runs": [
                {"id": "a", "preset": "tinycnn", "dataset": "tiny"},
                {"id": "b", "preset": "tinycnn", "dataset": "tiny",
                 "hyper": {"gamma_inv": 64}}
              ]
            }"#,
        )
        .unwrap();
        let spec = ExperimentSpec::parse(&j).unwrap();
        let runs = spec.resolve(Scale::Quick, None, 0).unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].hyper.gamma_inv, 128, "scale beats defaults");
        assert_eq!(runs[0].hyper.eta_fw_inv, 7, "defaults fill gaps");
        assert_eq!(runs[1].hyper.gamma_inv, 64, "run beats scale");
        // full scale: no gamma_inv section -> defaults win
        let runs = spec.resolve(Scale::Full, None, 0).unwrap();
        assert_eq!(runs[0].hyper.gamma_inv, 999);
    }

    #[test]
    fn scale_filter_and_overrides() {
        let spec = ExperimentSpec::load_builtin("table8").unwrap();
        let quick = spec.resolve(Scale::Quick, None, 0).unwrap();
        let full = spec.resolve(Scale::Full, None, 0).unwrap();
        assert_eq!(quick.len(), 5);
        assert_eq!(full.len(), 5);
        assert!(quick.iter().all(|r| r.preset == "tinycnn"));
        assert!(full.iter().all(|r| r.preset == "vgg11b"));
        // seed + epoch overrides
        let r = spec.resolve(Scale::Quick, Some(7), 3).unwrap();
        assert!(r.iter().all(|x| x.seed == 7 && x.epochs == 3));
        assert!(spec.fixed_lr);
    }

    #[test]
    fn scheduler_key_parses_and_defaults() {
        let base = |extra: &str| {
            format!(
                r#"{{"name": "t", {extra} "runs": [
                     {{"id": "a", "preset": "tinycnn", "dataset": "tiny"}}
                   ]}}"#
            )
        };
        let spec =
            ExperimentSpec::parse(&Json::parse(&base("")).unwrap()).unwrap();
        assert_eq!(spec.scheduler, Scheduler::Pipelined, "default");
        let spec = ExperimentSpec::parse(
            &Json::parse(&base(r#""scheduler": "sequential","#)).unwrap(),
        )
        .unwrap();
        assert_eq!(spec.scheduler, Scheduler::Sequential);
        let runs = spec.resolve(Scale::Quick, None, 0).unwrap();
        assert!(runs.iter().all(|r| r.scheduler == Scheduler::Sequential));
        assert!(ExperimentSpec::parse(
            &Json::parse(&base(r#""scheduler": "warp","#)).unwrap()
        )
        .is_err());
    }

    #[test]
    fn replicas_key_parses_defaults_and_rejects_zero() {
        let base = |extra: &str| {
            format!(
                r#"{{"name": "t", {extra} "runs": [
                     {{"id": "a", "preset": "tinycnn", "dataset": "tiny"}}
                   ]}}"#
            )
        };
        let spec =
            ExperimentSpec::parse(&Json::parse(&base("")).unwrap()).unwrap();
        assert_eq!(spec.replicas, 1, "default");
        let spec = ExperimentSpec::parse(
            &Json::parse(&base(r#""replicas": 4,"#)).unwrap(),
        )
        .unwrap();
        assert_eq!(spec.replicas, 4);
        let runs = spec.resolve(Scale::Quick, None, 0).unwrap();
        assert!(runs.iter().all(|r| r.replicas == 4));
        for bad in [r#""replicas": 0,"#, r#""replicas": -2,"#] {
            assert!(
                ExperimentSpec::parse(&Json::parse(&base(bad)).unwrap())
                    .is_err(),
                "{bad} must be rejected"
            );
        }
        // "ranks" follows the same contract
        assert_eq!(spec.ranks, 1, "default");
        let spec = ExperimentSpec::parse(
            &Json::parse(&base(r#""ranks": 3,"#)).unwrap(),
        )
        .unwrap();
        assert_eq!(spec.ranks, 3);
        let runs = spec.resolve(Scale::Quick, None, 0).unwrap();
        assert!(runs.iter().all(|r| r.ranks == 3));
        for bad in [r#""ranks": 0,"#, r#""ranks": -1,"#] {
            assert!(
                ExperimentSpec::parse(&Json::parse(&base(bad)).unwrap())
                    .is_err(),
                "{bad} must be rejected"
            );
        }
    }

    #[test]
    fn scales_element_type_error_reports_index() {
        // regression: a non-string scales element used to be parsed as
        // Scale::parse("?") and reported as an unknown scale name
        let j = Json::parse(
            r#"{"name": "t", "runs": [
                 {"id": "a", "preset": "tinycnn", "dataset": "tiny",
                  "scales": ["quick", 3]}
               ]}"#,
        )
        .unwrap();
        let err = ExperimentSpec::parse(&j).unwrap_err();
        assert!(
            err.contains("scales[1]: expected string"),
            "got: {err}"
        );
        // valid string elements still parse
        let j = Json::parse(
            r#"{"name": "t", "runs": [
                 {"id": "a", "preset": "tinycnn", "dataset": "tiny",
                  "scales": ["quick"]}
               ]}"#,
        )
        .unwrap();
        assert!(ExperimentSpec::parse(&j).is_ok());
    }

    #[test]
    fn bits_key_sweeps_nitro_rows_and_suffixes_ids() {
        let base = |extra: &str| {
            format!(
                r#"{{"name": "t", {extra} "runs": [
                     {{"id": "a", "preset": "tinycnn", "dataset": "tiny"}}
                   ]}}"#
            )
        };
        // absent -> one default cell, no suffix
        let spec =
            ExperimentSpec::parse(&Json::parse(&base("")).unwrap()).unwrap();
        assert_eq!(spec.bits.len(), 1);
        assert!(spec.bits[0].is_default());
        let runs = spec.resolve(Scale::Quick, None, 0).unwrap();
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].id, "a");
        assert!(runs[0].bits.is_default());
        // sweep: ints, strings and objects all accepted as cells;
        // "bits": 32 is the default config and keeps the bare id
        let spec = ExperimentSpec::parse(
            &Json::parse(&base(
                r#""bits": [32, "8/8/64/64", {"weights": 16}],"#,
            ))
            .unwrap(),
        )
        .unwrap();
        let runs = spec.resolve(Scale::Quick, None, 0).unwrap();
        assert_eq!(runs.len(), 3);
        assert_eq!(runs[0].id, "a");
        assert_eq!(runs[1].id, "a+bits8-8-64-64");
        assert_eq!(runs[2].id, "a+bits16-32-64-64");
        assert_eq!(runs[1].bits.base.weights, 8);
        // baselines do not expand the sweep: one default row each
        let spec = ExperimentSpec::parse(
            &Json::parse(&base(
                r#""bits": [32, 8], "engines": ["nitro", "fp-bp"],"#,
            ))
            .unwrap(),
        )
        .unwrap();
        let runs = spec.resolve(Scale::Quick, None, 0).unwrap();
        let nitro = runs
            .iter()
            .filter(|r| r.engine == EngineKind::Nitro)
            .count();
        let fp = runs.iter().filter(|r| r.engine == EngineKind::FpBp).count();
        assert_eq!((nitro, fp), (2, 1));
        assert!(runs
            .iter()
            .filter(|r| r.engine == EngineKind::FpBp)
            .all(|r| r.bits.is_default()));
        // malformed cells are typed errors with their index
        for bad in [
            r#""bits": [],"#,
            r#""bits": [32, "8/8"],"#,
            r#""bits": true,"#,
            r#""bits": [1],"#,
        ] {
            assert!(
                ExperimentSpec::parse(&Json::parse(&base(bad)).unwrap())
                    .is_err(),
                "{bad} must be rejected"
            );
        }
    }

    #[test]
    fn engine_parse_rejects_unknown() {
        assert!(EngineKind::parse("tpu").is_err());
        assert_eq!(EngineKind::parse("fp-les").unwrap(), EngineKind::FpLes);
    }

    #[test]
    fn fp_epochs_divided_with_floor() {
        let spec = ExperimentSpec::load_builtin("table2").unwrap();
        let runs = spec.resolve(Scale::Quick, None, 0).unwrap();
        // 60 epochs / div 3 = 20
        assert!(runs.iter().all(|r| r.epochs == 60 && r.fp_epochs == 20));
        // the FP baselines keep the paper's batch 64 even though the
        // integer engine runs the quick-calibrated batch 32
        assert!(runs.iter().all(|r| r.batch == 32 && r.fp_batch == 64));
    }

    #[test]
    fn negative_numbers_are_spec_errors_not_wraps() {
        let base = |extra: &str| {
            format!(
                r#"{{"name": "t", {extra} "runs": [
                     {{"id": "a", "preset": "tinycnn", "dataset": "tiny"}}
                   ]}}"#
            )
        };
        for (extra, what) in [
            (r#""seeds": [-1],"#, "negative seed"),
            (r#""quick": {"epochs": -1},"#, "negative epochs"),
            (r#""quick": {"n_train": -5},"#, "negative n_train"),
            (r#""defaults": {"batch": -2},"#, "negative batch"),
        ] {
            let j = Json::parse(&base(extra)).unwrap();
            assert!(
                ExperimentSpec::parse(&j).is_err(),
                "{what} must be rejected"
            );
        }
        // negative per-run epochs too
        let j = Json::parse(
            r#"{"name": "t", "runs": [
                 {"id": "a", "preset": "tinycnn", "dataset": "tiny",
                  "epochs": -1}
               ]}"#,
        )
        .unwrap();
        assert!(ExperimentSpec::parse(&j).is_err());
    }
}
