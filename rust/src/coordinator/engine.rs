//! Execution engines.
//!
//! * [`NativeEngine`] — the pure-Rust integer engine (`nn::Network`).
//! * [`PjrtEngine`] — executes the JAX/Pallas AOT artifacts through the
//!   PJRT CPU client; weights live host-side as int32 tensors and flow
//!   through each call (one `block<i>_train` executable per block).
//!
//! Integer arithmetic makes the two engines **bit-identical**; the
//! integration test `rust/tests/pjrt.rs` trains both for several steps and
//! asserts equality of every weight tensor.

use crate::nn::{DropoutRngs, Hyper, Network};
use crate::runtime::{Arg, Executable, Manifest, Runtime};
use crate::tensor::{one_hot32, ITensor};
use crate::util::rng::Pcg32;

/// A training/inference engine over a fixed (preset, batch) configuration.
pub trait Engine {
    fn name(&self) -> &'static str;

    /// One full training iteration (all blocks + head).
    /// Returns (per-block losses, head loss, correct-prediction count).
    fn train_batch(&mut self, x: &ITensor, labels: &[usize], hp: &Hyper)
                   -> (Vec<i64>, i64, usize);

    /// Integer inference producing class scores.
    fn infer(&mut self, x: &ITensor) -> ITensor;

    /// Snapshot of every weight tensor (wf0, wl0, wf1, ..., wo).
    fn weights(&self) -> Vec<ITensor>;
}

/// Pure-Rust engine. The per-batch `Engine` API cannot pipeline across
/// batches, so `parallel` selects the block-parallel scheduler (the
/// cross-batch pipeline lives in `train::fit` / `train::pipeline`).
pub struct NativeEngine {
    pub net: Network,
    drop: DropoutRngs,
    parallel: bool,
}

impl NativeEngine {
    pub fn new(net: Network, seed: u64, parallel: bool) -> Self {
        let drop = DropoutRngs::new(seed, net.blocks.len());
        NativeEngine { net, drop, parallel }
    }
}

impl Engine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn train_batch(&mut self, x: &ITensor, labels: &[usize], hp: &Hyper)
                   -> (Vec<i64>, i64, usize) {
        let rep = if self.parallel {
            self.net.train_batch_parallel(x, labels, hp, &mut self.drop)
        } else {
            self.net.train_batch(x, labels, hp, &mut self.drop)
        };
        (rep.block_loss, rep.head_loss, rep.correct)
    }

    fn infer(&mut self, x: &ITensor) -> ITensor {
        self.net.infer(x)
    }

    fn weights(&self) -> Vec<ITensor> {
        self.net.weights().into_iter().map(|(_, t)| t.clone()).collect()
    }
}

/// Artifact-backed engine: every block step and the head step run as an
/// AOT-compiled XLA executable produced from the L2 JAX graphs (which route
/// their contractions through the L1 Pallas kernels).
pub struct PjrtEngine {
    pub manifest: Manifest,
    rt: Runtime,
    block_train: Vec<Executable>,
    head_train: Executable,
    infer_exe: Executable,
    /// Host-side weights: (wf, wl) per block + wo.
    pub wf: Vec<ITensor>,
    pub wl: Vec<ITensor>,
    pub wo: ITensor,
}

impl PjrtEngine {
    /// Load a preset's artifacts; weights initialized from the golden trace
    /// seed on the Python side are loaded separately via
    /// [`Self::set_weights`] (or start from Rust-side init).
    pub fn load(dir: &str, seed: u64) -> Result<Self, String> {
        let manifest = Manifest::load(dir)?;
        let rt = Runtime::cpu()?;
        let mut block_train = Vec::new();
        for b in &manifest.blocks {
            block_train.push(rt.load(&manifest.artifact_path(&b.artifact_train))?);
        }
        let head_train =
            rt.load(&manifest.artifact_path(&manifest.head.artifact_train))?;
        let infer_exe = rt.load(&manifest.artifact_path(&manifest.infer))?;
        // init weights with the Rust initializer (overridable)
        let mut rng = Pcg32::new(seed);
        let mut wf = Vec::new();
        let mut wl = Vec::new();
        for b in &manifest.blocks {
            let fan_in: usize = b.wf_shape[1..].iter().product();
            wf.push(crate::nn::init::init_weights(&mut rng, &b.wf_shape,
                                                  fan_in.max(1)));
            wl.push(crate::nn::init::init_weights(&mut rng, &b.wl_shape,
                                                  b.wl_shape[0]));
        }
        let wo = crate::nn::init::init_weights(
            &mut rng,
            &manifest.head.w_shape,
            manifest.head.w_shape[0],
        );
        Ok(PjrtEngine {
            manifest,
            rt,
            block_train,
            head_train,
            infer_exe,
            wf,
            wl,
            wo,
        })
    }

    pub fn set_weights(&mut self, wf: Vec<ITensor>, wl: Vec<ITensor>,
                       wo: ITensor) {
        assert_eq!(wf.len(), self.wf.len());
        assert_eq!(wl.len(), self.wl.len());
        self.wf = wf;
        self.wl = wl;
        self.wo = wo;
    }
}

impl Engine for PjrtEngine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn train_batch(&mut self, x: &ITensor, labels: &[usize], hp: &Hyper)
                   -> (Vec<i64>, i64, usize) {
        let g = self.manifest.num_classes;
        let y32 = one_hot32(labels, g);
        let mut a = x.clone();
        let mut block_loss = Vec::new();
        for (i, exe) in self.block_train.iter().enumerate() {
            let b = &self.manifest.blocks[i];
            // flatten for linear blocks
            if b.kind == "linear" && a.shape.len() > 2 {
                let (bs, f) = a.batch_feat();
                a = a.reshaped(&[bs, f]);
            }
            let outs = self
                .rt
                .run(
                    exe,
                    &[
                        Arg::I32(a.clone()),
                        Arg::I32(self.wf[i].clone()),
                        Arg::I32(self.wl[i].clone()),
                        Arg::I32(y32.clone()),
                        Arg::ScalarI64(hp.gamma_inv),
                        Arg::ScalarI64(hp.eta_fw_inv),
                        Arg::ScalarI64(hp.eta_lr_inv),
                    ],
                )
                .expect("block_train artifact failed");
            // (a_out, wf', wl', loss)
            a = outs[0].as_i32().clone();
            self.wf[i] = outs[1].as_i32().clone();
            self.wl[i] = outs[2].as_i32().clone();
            block_loss.push(outs[3].scalar_i64());
        }
        if a.shape.len() > 2 {
            let (bs, f) = a.batch_feat();
            a = a.reshaped(&[bs, f]);
        }
        let outs = self
            .rt
            .run(
                &self.head_train,
                &[
                    Arg::I32(a),
                    Arg::I32(self.wo.clone()),
                    Arg::I32(y32),
                    Arg::ScalarI64(hp.gamma_inv),
                    Arg::ScalarI64(hp.eta_lr_inv),
                ],
            )
            .expect("head_train artifact failed");
        let yhat = outs[0].as_i32().clone();
        self.wo = outs[1].as_i32().clone();
        let head_loss = outs[2].scalar_i64();
        let correct = crate::nn::block::count_correct(&yhat, labels);
        (block_loss, head_loss, correct)
    }

    fn infer(&mut self, x: &ITensor) -> ITensor {
        let mut args: Vec<Arg> = vec![Arg::I32(x.clone())];
        for w in &self.wf {
            args.push(Arg::I32(w.clone()));
        }
        args.push(Arg::I32(self.wo.clone()));
        let outs = self
            .rt
            .run(&self.infer_exe, &args)
            .expect("infer artifact failed");
        outs[0].as_i32().clone()
    }

    fn weights(&self) -> Vec<ITensor> {
        let mut out = Vec::new();
        for (f, l) in self.wf.iter().zip(&self.wl) {
            out.push(f.clone());
            out.push(l.clone());
        }
        out.push(self.wo.clone());
        out
    }
}
