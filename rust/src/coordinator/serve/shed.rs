//! Admission control and per-shard serving statistics.
//!
//! Each micro-batcher shard owns one [`ShardState`]. Admission is a
//! latency-budget check, not a queue-length check: a request is shed
//! when the *estimated queue wait* — admitted-but-unfinished samples
//! times the shard's EWMA per-sample execution time — already exceeds
//! the configured budget. The estimate deliberately excludes the
//! request's own service time, so an idle shard (depth 0) admits
//! unconditionally and a budget smaller than one service time still
//! lets work through one request at a time instead of livelocking.
//!
//! Bookkeeping order matters for determinism: the executor updates
//! depth / EWMA / histograms *before* delivering responses
//! (`complete_batch` precedes the response sends in `run_group`), so a
//! client that observed its own response is guaranteed to observe the
//! post-batch admission state too — the shedding tests rely on this.

use crate::util::hist::LogHistogram;
use crate::util::jsonio::Json;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// EWMA weight: new = (old * (W-1) + sample) / W.
const EWMA_W: u64 = 8;

pub struct ShardState {
    shard: usize,
    /// Samples admitted but not yet completed (queued or executing).
    depth_samples: AtomicUsize,
    /// Smoothed per-sample execution time; 0 = no batch finished yet
    /// (bootstrap: admit everything until the first measurement).
    ewma_ns: AtomicU64,
    shed: AtomicU64,
    completed: AtomicU64,
    completed_samples: AtomicU64,
    /// End-to-end request latency (enqueue -> response ready), ns.
    hist: Mutex<LogHistogram>,
}

impl ShardState {
    pub fn new(shard: usize) -> ShardState {
        ShardState {
            shard,
            depth_samples: AtomicUsize::new(0),
            ewma_ns: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            completed_samples: AtomicU64::new(0),
            hist: Mutex::new(LogHistogram::new()),
        }
    }

    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Admit `nsamples` (charging them to the queue depth) or shed.
    /// `budget_ns == 0` disables shedding. `Err` carries the estimated
    /// wait that broke the budget and has already counted the shed.
    pub fn try_admit(&self, nsamples: usize, budget_ns: u64)
                     -> Result<(), u64> {
        if budget_ns > 0 {
            let ewma = self.ewma_ns.load(Ordering::Relaxed);
            if ewma > 0 {
                let wait_ns =
                    (self.depth_samples.load(Ordering::Relaxed) as u64)
                        .saturating_mul(ewma);
                if wait_ns > budget_ns {
                    self.shed.fetch_add(1, Ordering::Relaxed);
                    return Err(wait_ns);
                }
            }
        }
        self.depth_samples.fetch_add(nsamples, Ordering::Relaxed);
        Ok(())
    }

    /// Undo an admission whose request never reached the executor.
    pub fn cancel(&self, nsamples: usize) {
        self.depth_samples.fetch_sub(nsamples, Ordering::Relaxed);
    }

    /// Account one executed micro-batch: drop its samples from the
    /// depth, fold its per-sample time into the EWMA.
    pub fn complete_batch(&self, nreqs: usize, nsamples: usize,
                          exec_ns: u64) {
        // saturating decrement: a stray extra completion (tests driving
        // the state directly) must not wrap the depth to usize::MAX and
        // wedge admission into shedding everything
        let _ = self.depth_samples.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |d| Some(d.saturating_sub(nsamples)),
        );
        self.completed.fetch_add(nreqs as u64, Ordering::Relaxed);
        self.completed_samples
            .fetch_add(nsamples as u64, Ordering::Relaxed);
        // floor of 1: a sub-ns measurement must still mark the EWMA as
        // seeded, or admission control would stay in bootstrap forever
        let per = (exec_ns / nsamples.max(1) as u64).max(1);
        // single-writer (the shard's executor thread); load/store is fine
        let old = self.ewma_ns.load(Ordering::Relaxed);
        let new = if old == 0 {
            per
        } else {
            old.saturating_mul(EWMA_W.saturating_sub(1)).saturating_add(per)
                / EWMA_W
        };
        self.ewma_ns.store(new, Ordering::Relaxed);
    }

    pub fn record_latency_ns(&self, ns: u64) {
        self.hist.lock().expect("shard hist lock").record(ns);
    }

    pub fn depth_samples(&self) -> usize {
        self.depth_samples.load(Ordering::Relaxed)
    }

    pub fn ewma_ns(&self) -> u64 {
        self.ewma_ns.load(Ordering::Relaxed)
    }

    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn completed_count(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn snapshot_hist(&self) -> LogHistogram {
        self.hist.lock().expect("shard hist lock").clone()
    }

    /// Shard section of the `stats` response.
    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("shard", Json::Int(self.shard as i64)),
            ("completed", Json::Int(self.completed_count() as i64)),
            ("completed_samples",
             Json::Int(self.completed_samples.load(Ordering::Relaxed)
                           as i64)),
            ("shed", Json::Int(self.shed_count() as i64)),
            ("depth_samples", Json::Int(self.depth_samples() as i64)),
            ("ewma_ns_per_sample", Json::Int(self.ewma_ns() as i64)),
            ("latency", hist_json(&self.snapshot_hist())),
        ])
    }
}

/// p50/p99/p999 summary of a latency histogram, in microseconds.
pub fn hist_json(h: &LogHistogram) -> Json {
    let us = |ns: u64| Json::Float(ns as f64 / 1000.0);
    Json::obj(vec![
        ("count", Json::Int(h.count() as i64)),
        ("p50_us", us(h.quantile(0.50))),
        ("p99_us", us(h.quantile(0.99))),
        ("p999_us", us(h.quantile(0.999))),
        ("max_us", us(h.max())),
        ("mean_us", Json::Float(h.mean() / 1000.0)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bootstrap_admits_until_first_measurement() {
        let s = ShardState::new(0);
        // no EWMA yet: even a 1ns budget admits
        assert!(s.try_admit(100, 1).is_ok());
        assert_eq!(s.depth_samples(), 100);
        s.complete_batch(1, 100, 1_000_000); // 10_000 ns/sample
        assert_eq!(s.depth_samples(), 0);
        assert_eq!(s.ewma_ns(), 10_000);
        assert_eq!(s.completed_count(), 1);
    }

    #[test]
    fn sheds_on_queue_wait_not_own_service_time() {
        let s = ShardState::new(3);
        s.complete_batch(1, 1, 50_000); // seed EWMA at 50_000 ns
        // idle shard: estimated wait is 0, any budget admits even though
        // one service time (50us) exceeds the 10us budget
        assert!(s.try_admit(4, 10_000).is_ok());
        // now 4 samples deep: wait = 4 * 50us = 200us > 10us -> shed
        let wait = s.try_admit(1, 10_000).unwrap_err();
        assert_eq!(wait, 200_000);
        assert_eq!(s.shed_count(), 1);
        // depth unchanged by the shed; cancel rolls back an admission
        assert_eq!(s.depth_samples(), 4);
        s.cancel(4);
        assert_eq!(s.depth_samples(), 0);
        assert!(s.try_admit(1, 10_000).is_ok());
        // budget 0 disables shedding entirely
        let s2 = ShardState::new(0);
        s2.complete_batch(1, 1, u64::MAX / 2);
        assert!(s2.try_admit(1_000_000, 0).is_ok());
    }

    #[test]
    fn ewma_converges_and_stats_json_has_latency_summary() {
        let s = ShardState::new(1);
        s.complete_batch(1, 1, 8_000);
        for _ in 0..64 {
            s.complete_batch(2, 4, 4_000); // 1000 ns/sample
        }
        // converged near the steady-state per-sample time
        assert!(s.ewma_ns() >= 999 && s.ewma_ns() <= 2_000,
                "ewma {}", s.ewma_ns());
        s.record_latency_ns(10_000);
        s.record_latency_ns(20_000);
        let j = s.json();
        assert_eq!(j.req("shard").unwrap().as_i64(), Some(1));
        let lat = j.req("latency").unwrap();
        assert_eq!(lat.req("count").unwrap().as_i64(), Some(2));
        let p50 = lat.req("p50_us").unwrap().as_f64().unwrap();
        let p99 = lat.req("p99_us").unwrap().as_f64().unwrap();
        let p999 = lat.req("p999_us").unwrap().as_f64().unwrap();
        assert!(p50 <= p99 && p99 <= p999, "{p50} {p99} {p999}");
    }
}
