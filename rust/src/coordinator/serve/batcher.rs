//! Dynamic micro-batching executors: one per shard, thread-per-core.
//!
//! A [`MicroBatcher`] owns a single executor thread that coalesces
//! concurrent predict requests into micro-batches and runs them through
//! the grad-free fused forward path ([`Network::infer_into`]) with one
//! long-lived [`InferScratch`], so steady-state serving performs no
//! forward-path allocation. A [`ShardedBatcher`] runs N of them, each
//! executor pinned to a slice of the kernel worker budget
//! (`par::scoped_thread_workers`), so shards' fused forwards do not
//! fight over the same pool threads.
//!
//! **Determinism contract:** per-sample logits are a function of the
//! checkpoint and the sample alone — every kernel is row/sample
//! independent — so results are bit-identical regardless of micro-batch
//! composition, coalescing timing, shard assignment, kernel budget and
//! `NITRO_WORKERS`. CI asserts this end to end.
//!
//! Batches are grouped by model *identity* (`Arc` pointer), not name: a
//! hot reload swaps the registry entry mid-stream, and two requests that
//! resolved to different versions of the same name must never share one
//! fused forward.

use super::registry::ModelRegistry;
use super::shed::ShardState;
use super::wire::ServeError;
use super::{ServeConfig, ServedModel};
use crate::nn::InferScratch;
use crate::tensor::ITensor;
use crate::util::par;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

struct PredictReq {
    model: Arc<ServedModel>,
    x: Vec<i32>,
    nsamples: usize,
    /// Admission time; end-to-end latency is measured from here.
    enqueued: Instant,
    resp: mpsc::SyncSender<ITensor>,
}

/// Handle for submitting predict requests; clone one per connection
/// thread. [`Self::predict`] blocks until the micro-batch containing the
/// request has executed.
#[derive(Clone)]
pub struct BatchClient {
    tx: mpsc::Sender<PredictReq>,
    registry: Arc<ModelRegistry>,
    cfg: ServeConfig,
    state: Arc<ShardState>,
}

impl BatchClient {
    /// Score `x` (one or more flattened samples) on `model` (`None` =
    /// the registry's single model). Returns the resolved model and the
    /// `(n, num_classes)` logits. Rejections are typed: resolution
    /// failures map to `unknown_model` / `bad_request`, size violations
    /// to `too_large`, admission-control rejections to `overloaded`.
    pub fn predict(&self, model: Option<&str>, x: Vec<i32>)
                   -> Result<(Arc<ServedModel>, ITensor), ServeError> {
        let m = self.registry.resolve(model)?;
        let ss = m.sample_size;
        if x.is_empty() || x.len() % ss != 0 {
            return Err(ServeError::bad_request(format!(
                "input length {} is not a positive multiple of '{}' \
                 sample size {ss}",
                x.len(),
                m.name
            )));
        }
        let nsamples = x.len() / ss;
        let cap = self.cfg.max_request_samples.max(1);
        if nsamples > cap {
            return Err(ServeError::too_large(format!(
                "request has {nsamples} samples, above the per-request \
                 limit {cap} — split it into smaller requests"
            )));
        }
        let budget_ns = self.cfg.queue_budget_us.saturating_mul(1000);
        if let Err(wait_ns) = self.state.try_admit(nsamples, budget_ns) {
            return Err(ServeError::overloaded(format!(
                "shard {} queue needs ~{}us, over the {}us budget — \
                 retry with backoff",
                self.state.shard(),
                wait_ns / 1000,
                self.cfg.queue_budget_us
            )));
        }
        let (rtx, rrx) = mpsc::sync_channel(1);
        if self
            .tx
            .send(PredictReq {
                model: m.clone(),
                x,
                nsamples,
                enqueued: Instant::now(),
                resp: rtx,
            })
            .is_err()
        {
            self.state.cancel(nsamples);
            return Err(ServeError::internal(
                "serve executor has shut down"));
        }
        self.registry.note_request(&m.name, nsamples);
        let y = rrx.recv().map_err(|_| {
            ServeError::internal("serve executor dropped the request")
        })?;
        Ok((m, y))
    }
}

/// One shard of the serving plane: an executor thread draining a request
/// queue, coalescing up to `max_batch` samples (waiting at most
/// `max_wait_us` once work is pending), grouping them by model identity,
/// and running each group as a single fused forward on the worker-pool
/// kernels under this shard's kernel budget.
pub struct MicroBatcher {
    tx: Option<mpsc::Sender<PredictReq>>,
    registry: Arc<ModelRegistry>,
    cfg: ServeConfig,
    state: Arc<ShardState>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MicroBatcher {
    /// Single-shard batcher with the full kernel budget (the stdio
    /// server, `nitro predict`'s bench, and the public pre-shard API).
    pub fn start(registry: Arc<ModelRegistry>, cfg: ServeConfig)
                 -> MicroBatcher {
        MicroBatcher::start_shard(registry, cfg, 0,
                                  par::current_workers())
    }

    /// One shard of a [`ShardedBatcher`]: executes with a scoped kernel
    /// budget of `kernel_workers` pool threads.
    pub fn start_shard(registry: Arc<ModelRegistry>, cfg: ServeConfig,
                       shard: usize, kernel_workers: usize)
                       -> MicroBatcher {
        let (tx, rx) = mpsc::channel::<PredictReq>();
        let state = Arc::new(ShardState::new(shard));
        let st = state.clone();
        let handle = std::thread::Builder::new()
            .name(format!("nitro-serve-exec{shard}"))
            .spawn(move || executor(rx, cfg, st, kernel_workers))
            .expect("spawn serve executor");
        MicroBatcher {
            tx: Some(tx),
            registry,
            cfg,
            state,
            handle: Some(handle),
        }
    }

    /// A request handle for this batcher. Clients hold a sender into the
    /// executor queue, so every client must be dropped before (or
    /// strictly inside the lifetime of) the `MicroBatcher` — its `Drop`
    /// joins the executor, which exits only once all senders are gone.
    pub fn client(&self) -> BatchClient {
        BatchClient {
            tx: self.tx.as_ref().expect("running").clone(),
            registry: self.registry.clone(),
            cfg: self.cfg,
            state: self.state.clone(),
        }
    }

    /// This shard's admission/latency state (stats and tests).
    pub fn state(&self) -> Arc<ShardState> {
        self.state.clone()
    }
}

impl Drop for MicroBatcher {
    fn drop(&mut self) {
        // closing the channel ends the executor loop; join so in-flight
        // responses are delivered before the batcher disappears
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Thread-per-core serving plane: `cfg.shards` micro-batchers, each with
/// `current_workers / shards` (min 1) kernel workers. Connections hash
/// onto shards via [`Self::client`]; shards share nothing but the
/// registry, so there is no cross-shard lock on the request path.
pub struct ShardedBatcher {
    shards: Vec<MicroBatcher>,
}

impl ShardedBatcher {
    pub fn start(registry: Arc<ModelRegistry>, cfg: ServeConfig)
                 -> ShardedBatcher {
        let n = cfg.shards.max(1);
        let kernel_workers = (par::current_workers() / n).max(1);
        let shards = (0..n)
            .map(|s| {
                MicroBatcher::start_shard(
                    registry.clone(), cfg, s, kernel_workers)
            })
            .collect();
        ShardedBatcher { shards }
    }

    pub fn nshards(&self) -> usize {
        self.shards.len()
    }

    /// The client for the shard owning `key` (connection id, request
    /// counter, ...). A fixed key always lands on the same shard, so one
    /// connection's requests stay ordered.
    pub fn client(&self, key: u64) -> BatchClient {
        self.shards[(key % self.shards.len() as u64) as usize].client()
    }

    /// Per-shard states, indexed by shard id (stats responses).
    pub fn states(&self) -> Vec<Arc<ShardState>> {
        self.shards.iter().map(|s| s.state()).collect()
    }
}

fn executor(rx: mpsc::Receiver<PredictReq>, cfg: ServeConfig,
            state: Arc<ShardState>, kernel_workers: usize) {
    // the shard's slice of the pool, held for the thread's lifetime
    let _budget = par::scoped_thread_workers(kernel_workers.max(1));
    let mut scratch = InferScratch::new();
    let mut xbuf = ITensor::empty();
    let mut out = ITensor::empty();
    let max_batch = cfg.max_batch.max(1);
    while let Ok(first) = rx.recv() {
        let mut pending = vec![first];
        let mut total = pending[0].nsamples;
        // coalescing window: take whatever is queued, then wait out the
        // remainder of the window for stragglers
        let deadline = Instant::now()
            + Duration::from_micros(cfg.max_wait_us);
        while total < max_batch {
            let now = Instant::now();
            let r = if now >= deadline {
                match rx.try_recv() {
                    Ok(r) => r,
                    Err(_) => break,
                }
            } else {
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => r,
                    Err(_) => break,
                }
            };
            total += r.nsamples;
            pending.push(r);
        }
        // group by model identity (Arc pointer), preserving arrival
        // order within each group — name grouping would fuse requests
        // resolved against different versions across a hot reload
        while !pending.is_empty() {
            let key = Arc::as_ptr(&pending[0].model);
            let group: Vec<PredictReq> = {
                let (g, rest): (Vec<_>, Vec<_>) = pending
                    .into_iter()
                    .partition(|r| Arc::as_ptr(&r.model) == key);
                pending = rest;
                g
            };
            run_group(group, &state, &mut scratch, &mut xbuf, &mut out);
        }
    }
}

/// Execute one same-model group as a single fused forward and scatter the
/// per-request logit rows back to their response channels. Shard state is
/// updated **before** any response is sent: a client that has observed
/// its own response is guaranteed to observe the post-batch admission
/// state too (the shedding tests lean on this ordering).
fn run_group(group: Vec<PredictReq>, state: &ShardState,
             scratch: &mut InferScratch, xbuf: &mut ITensor,
             out: &mut ITensor) {
    let model = group[0].model.clone();
    let n: usize = group.iter().map(|r| r.nsamples).sum();
    xbuf.data.clear();
    for r in &group {
        xbuf.data.extend_from_slice(&r.x);
    }
    xbuf.shape.clear();
    xbuf.shape.push(n);
    xbuf.shape.extend(&model.input_shape);
    let t0 = Instant::now();
    model.net.infer_into(xbuf, scratch, out);
    let exec_ns = t0.elapsed().as_nanos() as u64;
    state.complete_batch(group.len(), n, exec_ns);
    let g = model.num_classes;
    let mut row = 0usize;
    for r in group {
        let y = ITensor::from_vec(
            &[r.nsamples, g],
            out.data[row * g..(row + r.nsamples) * g].to_vec(),
        );
        row += r.nsamples;
        state.record_latency_ns(r.enqueued.elapsed().as_nanos() as u64);
        let _ = r.resp.send(y);
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::{rand_samples, saved_model};
    use super::super::wire::ErrorKind;
    use super::*;
    use crate::nn::{zoo, Network};
    use crate::train::checkpoint;
    use crate::util::rng::Pcg32;

    #[test]
    fn micro_batched_logits_equal_reference_any_composition() {
        // the serving determinism contract: logits are bit-identical to
        // Network::infer regardless of how requests coalesce into batches
        let (path, net) = saved_model("tinycnn", 5, "comp");
        let reg =
            Arc::new(ModelRegistry::from_paths(&path).unwrap());
        let model = reg.resolve(None).unwrap();
        let mut rng = Pcg32::new(31);
        let flat = rand_samples(&model, 7, &mut rng);
        let x = ITensor::from_vec(&model.batch_shape(7), flat.clone());
        let want = net.infer(&x);
        let g = model.num_classes;
        for (max_batch, wait) in [(1usize, 0u64), (3, 0), (64, 100)] {
            let mb = MicroBatcher::start(
                reg.clone(),
                ServeConfig { max_batch, max_wait_us: wait,
                              ..Default::default() },
            );
            let client = mb.client();
            // one request per sample
            for i in 0..7 {
                let ss = model.sample_size;
                let (_, y) = client
                    .predict(None, flat[i * ss..(i + 1) * ss].to_vec())
                    .unwrap();
                assert_eq!(y.shape, vec![1, g]);
                assert_eq!(y.data, want.data[i * g..(i + 1) * g],
                           "sample {i} max_batch {max_batch}");
            }
            // one multi-sample request
            let (_, y) = client.predict(None, flat.clone()).unwrap();
            assert_eq!(y.data, want.data, "max_batch {max_batch}");
        }
    }

    #[test]
    fn concurrent_clients_coalesce_and_stay_bitexact() {
        let (path, net) = saved_model("tinycnn", 8, "conc");
        let reg = Arc::new(ModelRegistry::from_paths(&path).unwrap());
        let model = reg.resolve(None).unwrap();
        let mut rng = Pcg32::new(77);
        let nreq = 12usize;
        let flat = rand_samples(&model, nreq, &mut rng);
        let x = ITensor::from_vec(&model.batch_shape(nreq), flat.clone());
        let want = net.infer(&x);
        let g = model.num_classes;
        let mb = MicroBatcher::start(
            reg.clone(),
            ServeConfig { max_batch: 8, max_wait_us: 2000,
                          ..Default::default() },
        );
        let ss = model.sample_size;
        std::thread::scope(|s| {
            let mut joins = Vec::new();
            for i in 0..nreq {
                let client = mb.client();
                let sample = flat[i * ss..(i + 1) * ss].to_vec();
                joins.push(s.spawn(move || {
                    client.predict(None, sample).unwrap().1
                }));
            }
            for (i, j) in joins.into_iter().enumerate() {
                let y = j.join().unwrap();
                assert_eq!(y.data, want.data[i * g..(i + 1) * g],
                           "concurrent sample {i}");
            }
        });
        // the shard saw every request and recorded a latency for each
        let st = mb.state();
        assert_eq!(st.completed_count(), nreq as u64);
        assert_eq!(st.snapshot_hist().count(), nreq as u64);
        assert_eq!(st.depth_samples(), 0);
    }

    #[test]
    fn stress_ten_concurrent_clients_mixed_batches_no_deadlock() {
        // serve concurrency stress: ≥ 8 concurrent clients hammer the
        // micro-batcher with mixed batch sizes across several rounds.
        // Completion of every request is the no-deadlock assertion (a
        // wedged executor hangs the join and fails via test timeout);
        // every per-request logit block must be bit-identical to the
        // reference forward — the `nitro predict` path — regardless of
        // how the requests coalesced.
        let (path, net) = saved_model("tinycnn", 11, "stress");
        let reg = Arc::new(ModelRegistry::from_paths(&path).unwrap());
        let model = reg.resolve(None).unwrap();
        let mut rng = Pcg32::new(123);
        let (nclients, rounds) = (10usize, 6usize);
        let sizes = [1usize, 2, 3, 5, 8];
        // pre-generate every client's request sequence (mixed sizes)
        let requests: Vec<Vec<Vec<i32>>> = (0..nclients)
            .map(|c| {
                (0..rounds)
                    .map(|r| {
                        let n = sizes[(c + r) % sizes.len()];
                        rand_samples(&model, n, &mut rng)
                    })
                    .collect()
            })
            .collect();
        let g = model.num_classes;
        let mb = MicroBatcher::start(
            reg.clone(),
            ServeConfig { max_batch: 16, max_wait_us: 500,
                          ..Default::default() },
        );
        std::thread::scope(|s| {
            let mut joins = Vec::new();
            for flats in &requests {
                let client = mb.client();
                joins.push(s.spawn(move || {
                    flats
                        .iter()
                        .map(|f| client.predict(None, f.clone()).unwrap().1)
                        .collect::<Vec<_>>()
                }));
            }
            for (c, j) in joins.into_iter().enumerate() {
                let got = j.join().unwrap();
                assert_eq!(got.len(), rounds);
                for (r, y) in got.iter().enumerate() {
                    let flat = &requests[c][r];
                    let n = flat.len() / model.sample_size;
                    let x = ITensor::from_vec(&model.batch_shape(n),
                                              flat.clone());
                    let want = net.infer(&x);
                    assert_eq!(y.shape, vec![n, g],
                               "client {c} round {r}: shape");
                    assert_eq!(y.data, want.data,
                               "client {c} round {r}: logits drifted");
                }
            }
        });
    }

    #[test]
    fn oversized_requests_rejected_not_executed() {
        let (path, _) = saved_model("mlp1-mini", 6, "cap");
        let reg = Arc::new(ModelRegistry::from_paths(&path).unwrap());
        let model = reg.resolve(None).unwrap();
        let mb = MicroBatcher::start(
            reg.clone(),
            ServeConfig {
                max_batch: 4,
                max_wait_us: 0,
                max_request_samples: 2,
                ..Default::default()
            },
        );
        let client = mb.client();
        let mut rng = Pcg32::new(4);
        let ok = rand_samples(&model, 2, &mut rng);
        assert!(client.predict(None, ok).is_ok());
        let too_big = rand_samples(&model, 3, &mut rng);
        let err = client.predict(None, too_big).unwrap_err();
        assert_eq!(err.kind, ErrorKind::TooLarge);
        assert!(err.msg.contains("per-request"), "{err}");
        // a length mismatch is bad_request, not too_large
        let err = client.predict(None, vec![1, 2, 3]).unwrap_err();
        assert_eq!(err.kind, ErrorKind::BadRequest);
    }

    #[test]
    fn shed_when_over_budget_and_recovers() {
        let (path, _) = saved_model("tinycnn", 13, "shed");
        let reg = Arc::new(ModelRegistry::from_paths(&path).unwrap());
        let model = reg.resolve(None).unwrap();
        // one shard, one kernel worker, a long coalescing window (so an
        // admitted batch stays pending while we probe) and a 1us budget
        let mb = MicroBatcher::start_shard(
            reg.clone(),
            ServeConfig {
                max_batch: 1024,
                max_wait_us: 500_000,
                queue_budget_us: 1,
                ..Default::default()
            },
            0,
            1,
        );
        let client = mb.client();
        let state = mb.state();
        let mut rng = Pcg32::new(9);
        // prime: depth 0 admits despite the 1us budget (bootstrap, then
        // idle-shard rule), and seeds the EWMA with a real service time
        let one = rand_samples(&model, 1, &mut rng);
        client.predict(None, one.clone()).unwrap();
        assert!(state.ewma_ns() > 0);
        // park 4 samples in the executor's coalescing window
        let parked = rand_samples(&model, 4, &mut rng);
        let t = std::thread::spawn({
            let client = client.clone();
            move || client.predict(None, parked).unwrap().1
        });
        while state.depth_samples() == 0 {
            std::thread::yield_now();
        }
        // queue wait is now 4 x EWMA (tinycnn inference is far over
        // 250ns/sample), so the 1us budget sheds deterministically
        let err = client.predict(None, one.clone()).unwrap_err();
        assert_eq!(err.kind, ErrorKind::Overloaded);
        assert!(err.msg.contains("retry with backoff"), "{err}");
        assert_eq!(state.shed_count(), 1);
        // the server stays live: the parked batch completes...
        let y = t.join().unwrap();
        assert_eq!(y.shape[0], 4);
        // ...and with the queue drained the same request is admitted
        assert_eq!(state.depth_samples(), 0);
        assert!(client.predict(None, one).is_ok());
        assert_eq!(state.shed_count(), 1);
    }

    #[test]
    fn sharded_clients_bitexact_across_shards() {
        let (path, net) = saved_model("tinycnn", 15, "shards");
        let reg = Arc::new(ModelRegistry::from_paths(&path).unwrap());
        let model = reg.resolve(None).unwrap();
        let mut rng = Pcg32::new(55);
        let flat = rand_samples(&model, 3, &mut rng);
        let x = ITensor::from_vec(&model.batch_shape(3), flat.clone());
        let want = net.infer(&x);
        let sb = ShardedBatcher::start(
            reg.clone(),
            ServeConfig { shards: 3, max_wait_us: 0,
                          ..Default::default() },
        );
        assert_eq!(sb.nshards(), 3);
        // every shard serves bit-identical logits for the same request
        for key in 0..6u64 {
            let (m, y) = sb.client(key).predict(None, flat.clone())
                .unwrap();
            assert_eq!(m.version, 1);
            assert_eq!(y.data, want.data, "key {key}");
        }
        // a fixed key maps to a fixed shard; keys cover all shards
        let states = sb.states();
        assert_eq!(states.len(), 3);
        let total: u64 =
            states.iter().map(|s| s.completed_count()).sum();
        assert_eq!(total, 6);
        for s in &states {
            assert_eq!(s.completed_count(), 2, "shard {}", s.shard());
        }
    }

    #[test]
    fn hot_reload_race_no_torn_model() {
        // hammer predicts from 4 threads while the main thread reloads
        // the checkpoint 8 times, alternating between two weight sets.
        // Every response must match one of the two reference outputs
        // exactly — never a mixture — and versions must end monotone.
        let spec = zoo::get("tinycnn").unwrap();
        let net_a = Network::new(spec.clone(), 21);
        let net_b = Network::new(spec.clone(), 22);
        let dir = std::env::temp_dir().join("nitro_serve_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("reload-race-{}.ckpt",
                                    std::process::id()));
        let path = path.to_str().unwrap().to_string();
        checkpoint::save(&net_a, &path).unwrap();
        let reg = Arc::new(ModelRegistry::new());
        reg.load(&path).unwrap();
        let model = reg.resolve(None).unwrap();
        let mut rng = Pcg32::new(99);
        let flat = rand_samples(&model, 1, &mut rng);
        let x = ITensor::from_vec(&model.batch_shape(1), flat.clone());
        let want_a = net_a.infer(&x);
        let want_b = net_b.infer(&x);
        assert_ne!(want_a.data, want_b.data, "seeds must differ");
        let sb = ShardedBatcher::start(
            reg.clone(),
            ServeConfig { shards: 2, max_wait_us: 0,
                          ..Default::default() },
        );
        std::thread::scope(|s| {
            let mut joins = Vec::new();
            for c in 0..4u64 {
                let client = sb.client(c);
                let flat = flat.clone();
                let (wa, wb) = (want_a.data.clone(), want_b.data.clone());
                joins.push(s.spawn(move || {
                    for i in 0..40 {
                        let (m, y) = client
                            .predict(None, flat.clone())
                            .unwrap();
                        assert!(
                            y.data == wa || y.data == wb,
                            "client {c} iter {i} v{}: torn logits",
                            m.version
                        );
                    }
                }));
            }
            for v in 2..=9u64 {
                let net = if v % 2 == 0 { &net_b } else { &net_a };
                checkpoint::save(net, &path).unwrap();
                for (name, r) in reg.reload_all() {
                    assert_eq!(r.as_ref().unwrap(), &v, "{name}");
                }
            }
            for j in joins {
                j.join().unwrap();
            }
        });
        assert_eq!(reg.resolve(None).unwrap().version, 9);
        // final weights are net_a's (v9 = odd): served logits match
        let (_, y) = sb.client(0).predict(None, flat.clone()).unwrap();
        assert_eq!(y.data, want_a.data);
        let _ = std::fs::remove_file(&path);
    }
}
