//! One flag table for the serving CLI family (`serve`, `predict`,
//! `loadgen`): each flag declares which subcommands it belongs to, and
//! [`command`] projects the table into a `cli::Command`. `--help` for
//! every subcommand is generated from the same rows, so a flag cannot
//! drift between the command that documents it and the one that parses
//! it.

use crate::util::cli::Command;

pub const SERVE: u8 = 1 << 0;
pub const PREDICT: u8 = 1 << 1;
pub const LOADGEN: u8 = 1 << 2;

pub struct FlagDef {
    pub name: &'static str,
    pub default: &'static str,
    pub help: &'static str,
    pub is_flag: bool,
    /// Which subcommands carry this flag (bitwise OR of the masks).
    pub mask: u8,
}

const fn opt(name: &'static str, default: &'static str,
             help: &'static str, mask: u8) -> FlagDef {
    FlagDef { name, default, help, is_flag: false, mask }
}

const fn flag(name: &'static str, help: &'static str, mask: u8)
              -> FlagDef {
    FlagDef { name, default: "", help, is_flag: true, mask }
}

pub const TABLE: &[FlagDef] = &[
    opt("models", "",
        "name=path[,name=path...] checkpoints to serve (a bare path \
         serves under its recorded spec name)",
        SERVE),
    opt("listen", "",
        "host:port TCP listener (default: JSON lines on stdin/stdout)",
        SERVE),
    opt("shards", "0",
        "micro-batcher shards, each with its own executor thread and \
         kernel budget (0 = one per available worker, capped at 64)",
        SERVE),
    opt("max-batch", "64",
        "sample target per executed micro-batch", SERVE),
    opt("max-wait-us", "200",
        "coalescing window after the first queued request, us", SERVE),
    opt("max-request", "4096",
        "max samples in one request", SERVE),
    opt("queue-budget-ms", "100",
        "shed requests whose estimated queue wait exceeds this budget \
         (0 = never shed)",
        SERVE),
    opt("io-timeout-ms", "30000",
        "read/write timeout per TCP connection; a stalled client is \
         dropped and its handler reaped (0 = never time out)",
        SERVE),
    flag("reload-on-sighup",
         "hot-reload every checkpoint from its path on SIGHUP", SERVE),
    opt("out", "",
        "write the response/report JSON here instead of stdout",
        PREDICT | LOADGEN),
    opt("connect", "127.0.0.1:7878",
        "host:port of a running `nitro serve --listen`", LOADGEN),
    opt("rate", "1000",
        "offered request rate per second, open-loop", LOADGEN),
    opt("duration", "3", "run length, seconds", LOADGEN),
    opt("connections", "4", "concurrent connections", LOADGEN),
    opt("req-samples", "1", "samples per request", LOADGEN),
    opt("model", "",
        "model name to target (default: the server's single model)",
        LOADGEN),
    opt("seed", "42", "payload RNG seed", LOADGEN),
];

/// Build the `cli::Command` for one subcommand from the shared table.
pub fn command(name: &'static str, about: &'static str, mask: u8)
               -> Command {
    let mut c = Command::new(name, about);
    for f in TABLE.iter().filter(|f| f.mask & mask != 0) {
        c = if f.is_flag {
            c.flag(f.name, f.help)
        } else {
            c.opt(f.name, f.default, f.help)
        };
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_projects_per_subcommand_and_defaults_parse() {
        let p = command("serve", "x", SERVE).parse(&[]).unwrap();
        assert_eq!(p.get_usize("max-batch").unwrap(), 64);
        assert_eq!(p.get_f64("queue-budget-ms").unwrap(), 100.0);
        assert_eq!(p.get_u64("io-timeout-ms").unwrap(), 30_000);
        assert!(!p.has("reload-on-sighup"));
        // loadgen does not know serve's flags and vice versa
        assert!(command("loadgen", "x", LOADGEN)
            .parse(&["--max-batch".into(), "1".into()])
            .is_err());
        assert!(command("serve", "x", SERVE)
            .parse(&["--rate".into(), "10".into()])
            .is_err());
        // shared flags appear in both commands that declare them
        for mask in [PREDICT, LOADGEN] {
            let p = command("c", "x", mask)
                .parse(&["--out".into(), "f.json".into()])
                .unwrap();
            assert_eq!(p.get("out"), "f.json");
        }
        // every table row belongs to at least one subcommand
        assert!(TABLE.iter().all(|f| f.mask != 0));
    }
}
