//! Integer-only inference serving: versioned model registry, sharded
//! dynamic micro-batchers, admission control, and the `nitro serve` /
//! `nitro predict` / `nitro loadgen` backends.
//!
//! The deployment story of the paper (App. E.3) is that a `NITRO1`
//! checkpoint *is* the deployed model — no quantization pass between
//! training and inference. This module turns that into a serving
//! subsystem built for sustained overload:
//!
//! * [`registry`] — models behind `Arc` swap pointers on hot reload
//!   (SIGHUP or a v1 `reload` request); in-flight requests finish on the
//!   old version, new requests resolve the new one, and every response
//!   echoes the version that scored it.
//! * [`batcher`] — thread-per-core [`ShardedBatcher`]: connections hash
//!   onto shards, each shard's executor coalesces micro-batches and runs
//!   them under its own slice of the kernel worker budget.
//! * [`shed`] — latency-budget admission control: requests whose
//!   estimated queue wait exceeds `--queue-budget-ms` are refused with a
//!   typed `overloaded` error instead of silently queueing without
//!   bound. Per-shard log-bucketed histograms feed the `stats` response
//!   and `BENCH_serve.json`.
//! * [`wire`] — the versioned JSON-lines protocol: v1 envelopes with
//!   machine-readable error codes; bare v0 lines still answered in the
//!   legacy shape (deprecated).
//! * [`loadgen`] — an open-loop, coordinated-omission-safe generator
//!   (`nitro loadgen`) that charges server backlog to the percentiles
//!   instead of hiding it.
//!
//! **Determinism contract:** per-sample logits are a function of the
//! checkpoint and the sample alone — bit-identical across micro-batch
//! composition, shard count, kernel budget, `NITRO_WORKERS`, and a hot
//! reload of the same checkpoint bytes. CI asserts this end to end.

mod batcher;
pub mod flags;
pub mod loadgen;
mod registry;
mod shed;
mod wire;

pub use batcher::{BatchClient, MicroBatcher, ShardedBatcher};
pub use registry::{ModelRegistry, ModelStats};
pub use shed::ShardState;
pub use wire::{ErrorKind, ServeError};

use crate::nn::{InferScratch, Network};
use crate::tensor::ITensor;
use crate::train::checkpoint;
use crate::util::hist::LogHistogram;
use crate::util::jsonio::Json;
use crate::util::par;
use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Once};
use std::time::Duration;
use wire::{Op, Request, WIRE_V1};

/// Bump when a `BENCH_serve.json` key changes meaning or disappears;
/// adding keys is allowed without a bump.
pub const SCHEMA_VERSION: i64 = 1;

// ---------------------------------------------------------------------------
// served model
// ---------------------------------------------------------------------------

/// A checkpoint loaded for serving, with its derived geometry. One
/// immutable weight snapshot — a hot reload builds a *new* `ServedModel`
/// with a bumped [`Self::version`] and swaps the registry pointer.
pub struct ServedModel {
    /// Registry key: the `--models` alias, or the spec name recorded in
    /// the checkpoint.
    pub name: String,
    /// Checkpoint path it was loaded from (and reloads from).
    pub path: String,
    /// Per-sample input shape: `(C, H, W)` or `(F,)`.
    pub input_shape: Vec<usize>,
    /// Flattened ints per sample.
    pub sample_size: usize,
    pub num_classes: usize,
    /// Monotone per-name weight-snapshot counter, echoed in v1
    /// responses.
    pub version: u64,
    net: Network,
}

impl ServedModel {
    /// Load a checkpoint, reconstructing the network from its recorded
    /// spec. Every malformed input is an `Err`, never a panic.
    pub fn load(path: &str) -> Result<ServedModel, String> {
        ServedModel::load_versioned(path, None, 1)
    }

    /// Load under an explicit registry alias and version (the registry's
    /// reload path).
    pub fn load_versioned(path: &str, alias: Option<&str>, version: u64)
                          -> Result<ServedModel, String> {
        let net = checkpoint::load_network(path)?;
        Ok(ServedModel::from_parts(net, path, alias, version))
    }

    /// Wrap an in-memory network (tests and the serve bench).
    pub fn from_network(net: Network, path: &str) -> ServedModel {
        ServedModel::from_parts(net, path, None, 1)
    }

    fn from_parts(net: Network, path: &str, alias: Option<&str>,
                  version: u64) -> ServedModel {
        ServedModel {
            name: alias.unwrap_or(&net.spec.name).to_string(),
            path: path.to_string(),
            input_shape: net.spec.input_shape.clone(),
            sample_size: net.spec.input_shape.iter().product(),
            num_classes: net.spec.num_classes,
            version,
            net,
        }
    }

    /// Architecture name recorded in the checkpoint (the registry key
    /// may be an alias).
    pub fn spec_name(&self) -> &str {
        &self.net.spec.name
    }

    /// Batch shape for `n` samples of this model.
    fn batch_shape(&self, n: usize) -> Vec<usize> {
        let mut shape = vec![n];
        shape.extend(&self.input_shape);
        shape
    }

    /// Grad-free inference over an owned flat sample buffer (`n`
    /// samples; `flat.len()` must be `n * sample_size`), writing
    /// `(n, num_classes)` logits into `out`. Takes the buffer by value —
    /// no input copy is made (the micro-batcher's hot path instead
    /// gathers into its own reused buffer, see `run_group`).
    pub fn infer_into(&self, flat: Vec<i32>, n: usize,
                      scratch: &mut InferScratch, out: &mut ITensor) {
        let x = ITensor::from_vec(&self.batch_shape(n), flat);
        self.net.infer_into(&x, scratch, out);
    }

    /// Reference (unfused) inference — parity checks.
    pub fn infer_reference(&self, x: &ITensor) -> ITensor {
        self.net.infer(x)
    }
}

// ---------------------------------------------------------------------------
// configuration
// ---------------------------------------------------------------------------

/// Serving knobs. Construct directly (defaults preserve the pre-shard
/// behavior: one shard, no shedding) or through [`ServeConfig::builder`]
/// for validated, CLI-grade construction.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Sample target per executed micro-batch. The coalescing loop stops
    /// adding requests once this is reached, so an executed batch can
    /// exceed it by at most one request (bounded by
    /// `max_batch - 1 + max_request_samples`).
    pub max_batch: usize,
    /// How long the executor waits for more requests to coalesce after
    /// the first one arrives. 0 = batch only what is already queued.
    pub max_wait_us: u64,
    /// Samples allowed in a single request; larger requests are rejected
    /// with a typed `too_large` error. Bounds the executor's working-set
    /// size against a hostile or buggy client — requests are
    /// all-or-nothing (one response each), so an unbounded request would
    /// otherwise force an unbounded fused forward.
    pub max_request_samples: usize,
    /// Micro-batcher shards (executor threads). Connections hash onto
    /// shards; each shard gets `current_workers / shards` kernel workers.
    pub shards: usize,
    /// Latency-budget admission control: shed a request when its
    /// estimated queue wait on the shard exceeds this. 0 disables
    /// shedding.
    pub queue_budget_us: u64,
    /// Per-socket read/write timeout on accepted TCP connections. A
    /// client that opens a connection and then stalls mid-line (the
    /// slowloris pattern) would otherwise pin a handler thread forever —
    /// the blocking `read_until` never returns. 0 disables the timeout
    /// (stdio serving and in-process batcher clients are unaffected
    /// either way).
    pub io_timeout_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 64,
            max_wait_us: 200,
            max_request_samples: 4096,
            shards: 1,
            queue_budget_us: 0,
            io_timeout_ms: 30_000,
        }
    }
}

impl ServeConfig {
    pub fn builder() -> ServeConfigBuilder {
        ServeConfigBuilder::default()
    }
}

/// Validating builder for [`ServeConfig`]; the error messages name the
/// CLI flags so a bad `nitro serve` invocation fails at startup with the
/// flag to fix. `build` is the only exit — out-of-range values never
/// reach a running server.
pub struct ServeConfigBuilder {
    max_batch: usize,
    max_wait_us: u64,
    max_request_samples: usize,
    shards: usize,
    queue_budget_ms: f64,
    io_timeout_ms: u64,
}

impl Default for ServeConfigBuilder {
    fn default() -> Self {
        let d = ServeConfig::default();
        ServeConfigBuilder {
            max_batch: d.max_batch,
            max_wait_us: d.max_wait_us,
            max_request_samples: d.max_request_samples,
            shards: d.shards,
            queue_budget_ms: d.queue_budget_us as f64 / 1000.0,
            io_timeout_ms: d.io_timeout_ms,
        }
    }
}

impl ServeConfigBuilder {
    pub fn max_batch(mut self, v: usize) -> Self {
        self.max_batch = v;
        self
    }

    pub fn max_wait_us(mut self, v: u64) -> Self {
        self.max_wait_us = v;
        self
    }

    pub fn max_request_samples(mut self, v: usize) -> Self {
        self.max_request_samples = v;
        self
    }

    /// 0 = auto: one shard per available kernel worker, capped at 64.
    pub fn shards(mut self, v: usize) -> Self {
        self.shards = v;
        self
    }

    pub fn queue_budget_ms(mut self, v: f64) -> Self {
        self.queue_budget_ms = v;
        self
    }

    /// 0 = never time out (pre-timeout behavior; trusted networks only).
    pub fn io_timeout_ms(mut self, v: u64) -> Self {
        self.io_timeout_ms = v;
        self
    }

    pub fn build(self) -> Result<ServeConfig, String> {
        if self.max_batch == 0 || self.max_batch > 65_536 {
            return Err(format!(
                "--max-batch must be in 1..=65536, got {}",
                self.max_batch
            ));
        }
        if self.max_wait_us > 10_000_000 {
            return Err(format!(
                "--max-wait-us must be at most 10000000 (10s), got {}",
                self.max_wait_us
            ));
        }
        if self.max_request_samples == 0
            || self.max_request_samples > 1_048_576
        {
            return Err(format!(
                "--max-request must be in 1..=1048576, got {}",
                self.max_request_samples
            ));
        }
        if self.shards > 256 {
            return Err(format!(
                "--shards must be at most 256, got {}", self.shards));
        }
        let shards = if self.shards == 0 {
            par::current_workers().clamp(1, 64)
        } else {
            self.shards
        };
        if !self.queue_budget_ms.is_finite()
            || self.queue_budget_ms < 0.0
            || self.queue_budget_ms > 600_000.0
        {
            return Err(format!(
                "--queue-budget-ms must be in 0..=600000, got {}",
                self.queue_budget_ms
            ));
        }
        if self.io_timeout_ms > 3_600_000 {
            return Err(format!(
                "--io-timeout-ms must be at most 3600000 (1h; 0 \
                 disables), got {}",
                self.io_timeout_ms
            ));
        }
        Ok(ServeConfig {
            max_batch: self.max_batch,
            max_wait_us: self.max_wait_us,
            max_request_samples: self.max_request_samples,
            shards,
            queue_budget_us: (self.queue_budget_ms * 1000.0) as u64,
            io_timeout_ms: self.io_timeout_ms,
        })
    }
}

// ---------------------------------------------------------------------------
// request handling (shared by stdio, TCP and `predict`)
// ---------------------------------------------------------------------------

/// Everything a connection needs to answer requests: the live registry
/// (stats, reload), the sharded batcher, and the config.
pub struct ServeContext {
    pub registry: Arc<ModelRegistry>,
    pub batcher: ShardedBatcher,
    pub cfg: ServeConfig,
}

impl ServeContext {
    pub fn new(registry: Arc<ModelRegistry>, cfg: ServeConfig)
               -> ServeContext {
        let batcher = ShardedBatcher::start(registry.clone(), cfg);
        ServeContext { registry, batcher, cfg }
    }
}

static V0_DEPRECATION: Once = Once::new();

/// Handle one JSON-line request. Every failure mode is a JSON error
/// response — a malformed line must never take the server down. The
/// response speaks the protocol version the request did: v0 lines get
/// the legacy shape, v1 lines get the envelope with `model_version` and
/// typed error codes.
pub fn handle_line(ctx: &ServeContext, client: &BatchClient, line: &str)
                   -> Json {
    let Request { v, id, op } = match wire::parse_request(line) {
        Ok(r) => r,
        Err((v, id, e)) => return wire::err_response(v, id, &e),
    };
    if v == 0 {
        V0_DEPRECATION.call_once(|| {
            eprintln!(
                "nitro serve: deprecation: request without \"v\" \
                 handled as wire v0; send {{\"v\": 1, ...}} — v0 will \
                 be removed in a future release"
            );
        });
    }
    match op {
        Op::Predict { model, input } => {
            match client.predict(model.as_deref(), input) {
                Ok((m, y)) => {
                    wire::ok_response(v, id, &m.name, m.version, &y)
                }
                Err(e) => wire::err_response(v, id, &e),
            }
        }
        Op::Stats => stats_response(ctx, id),
        Op::Reload => reload_response(ctx, id),
    }
}

/// v1 `stats`: per-model counters, per-shard admission state, and the
/// merged latency summary.
fn stats_response(ctx: &ServeContext, id: Json) -> Json {
    let states = ctx.batcher.states();
    let mut merged = LogHistogram::new();
    let (mut completed, mut shed_total) = (0u64, 0u64);
    for st in &states {
        completed += st.completed_count();
        shed_total += st.shed_count();
        merged.merge(&st.snapshot_hist());
    }
    Json::obj(vec![
        ("v", Json::Int(WIRE_V1)),
        ("id", id),
        ("models", ctx.registry.models_json()),
        ("shards",
         Json::Array(states.iter().map(|s| s.json()).collect())),
        ("completed", Json::Int(completed as i64)),
        ("shed", Json::Int(shed_total as i64)),
        ("latency", shed::hist_json(&merged)),
    ])
}

/// v1 `reload`: hot-reload every model from its checkpoint path. Models
/// that fail keep serving their old version and report the error.
fn reload_response(ctx: &ServeContext, id: Json) -> Json {
    let (mut reloaded, mut errors) = (Vec::new(), Vec::new());
    for (name, r) in ctx.registry.reload_all() {
        match r {
            Ok(v) => reloaded.push(Json::obj(vec![
                ("model", Json::Str(name)),
                ("version", Json::Int(v as i64)),
            ])),
            Err(e) => errors.push(Json::obj(vec![
                ("model", Json::Str(name)),
                ("message", Json::Str(e)),
            ])),
        }
    }
    Json::obj(vec![
        ("v", Json::Int(WIRE_V1)),
        ("id", id),
        ("reloaded", Json::Array(reloaded)),
        ("errors", Json::Array(errors)),
    ])
}

/// Serve JSON lines over stdin/stdout until EOF.
pub fn serve_stdio(registry: ModelRegistry, cfg: ServeConfig)
                   -> Result<(), String> {
    let registry = Arc::new(registry);
    eprintln!(
        "nitro serve: models [{}], {} shard(s), max-batch {}, wait {}us",
        registry.names().join(", "),
        cfg.shards.max(1),
        cfg.max_batch,
        cfg.max_wait_us
    );
    let ctx = ServeContext::new(registry, cfg);
    let client = ctx.batcher.client(0);
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    for line in stdin.lock().lines() {
        let line = line.map_err(|e| format!("stdin: {e}"))?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle_line(&ctx, &client, &line);
        let mut out = stdout.lock();
        out.write_all(resp.dump().as_bytes())
            .and_then(|_| out.write_all(b"\n"))
            .and_then(|_| out.flush())
            .map_err(|e| format!("stdout: {e}"))?;
    }
    Ok(())
}

/// Largest wire line a TCP connection may send: the biggest legitimate
/// request is `max_request_samples` samples of the widest served model,
/// ~13 bytes per serialized int, plus envelope slack. Anything longer is
/// answered with an error and the connection closed **before** the line
/// is buffered whole — a client streaming an endless non-newline byte
/// stream must not grow server memory without bound.
fn max_line_bytes(registry: &ModelRegistry, cfg: &ServeConfig) -> u64 {
    (registry.widest_sample_size() as u64)
        * (cfg.max_request_samples.max(1) as u64)
        * 13
        + 4096
}

// ---------------------------------------------------------------------------
// TCP server
// ---------------------------------------------------------------------------

/// Counters the accept loop maintains; exposed for tests and shutdown
/// diagnostics.
#[derive(Default)]
pub struct ServerStats {
    /// Connection-handler threads currently running.
    pub live_handlers: AtomicUsize,
    /// Join handles the accept loop is currently tracking.
    pub tracked_handles: AtomicUsize,
    /// Finished handler threads joined and released so far.
    pub reaped: AtomicU64,
    pub accepted: AtomicU64,
    /// SIGHUP-triggered reload sweeps.
    pub reloads: AtomicU64,
}

/// A running TCP server (accept loop + shards). [`Self::shutdown`] stops
/// accepting, waits for open connections to finish, and joins every
/// handler thread.
pub struct TcpServer {
    addr: String,
    stats: Arc<ServerStats>,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl TcpServer {
    /// The bound address (resolves `:0` to the actual port).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn stats(&self) -> Arc<ServerStats> {
        self.stats.clone()
    }

    /// Stop accepting and join the accept loop (which drains its handler
    /// threads; blocks until open connections close).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Block on the accept loop (the foreground `nitro serve` path).
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        // signal stop but do not join — a dropped (not shut down) server
        // must not hang the dropping thread on open connections
        self.stop.store(true, Ordering::Relaxed);
    }
}

/// Bind `addr` and serve it from a background accept thread. The
/// listener is nonblocking so the loop can interleave accepting, reaping
/// finished handler threads, SIGHUP reload sweeps, and the stop flag.
pub fn spawn_tcp(registry: Arc<ModelRegistry>, cfg: ServeConfig,
                 addr: &str, reload_on_sighup: bool)
                 -> Result<TcpServer, String> {
    let listener = std::net::TcpListener::bind(addr)
        .map_err(|e| format!("bind {addr}: {e}"))?;
    listener
        .set_nonblocking(true)
        .map_err(|e| format!("set_nonblocking: {e}"))?;
    let bound = listener
        .local_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| addr.to_string());
    if reload_on_sighup {
        sighup::install();
    }
    let ctx = Arc::new(ServeContext::new(registry, cfg));
    let stats = Arc::new(ServerStats::default());
    let stop = Arc::new(AtomicBool::new(false));
    let (st, sp) = (stats.clone(), stop.clone());
    let handle = std::thread::Builder::new()
        .name("nitro-serve-accept".into())
        .spawn(move || accept_loop(listener, ctx, st, sp))
        .map_err(|e| format!("spawn accept loop: {e}"))?;
    Ok(TcpServer { addr: bound, stats, stop, handle: Some(handle) })
}

/// Serve JSON lines over TCP in the foreground: shard-hashed connection
/// threads, all feeding the sharded micro-batcher.
pub fn serve_tcp(registry: ModelRegistry, cfg: ServeConfig, addr: &str,
                 reload_on_sighup: bool) -> Result<(), String> {
    let registry = Arc::new(registry);
    let srv = spawn_tcp(registry.clone(), cfg, addr, reload_on_sighup)?;
    eprintln!(
        "nitro serve: listening on {}, models [{}], {} shard(s), \
         queue budget {}us{}",
        srv.addr(),
        registry.names().join(", "),
        cfg.shards.max(1),
        cfg.queue_budget_us,
        if reload_on_sighup { ", SIGHUP reloads" } else { "" }
    );
    srv.join();
    Ok(())
}

/// Increments `live_handlers` for the lifetime of one handler thread;
/// the `Drop` decrement runs on every exit path, panic included.
struct HandlerGauge(Arc<ServerStats>);

impl HandlerGauge {
    fn new(stats: Arc<ServerStats>) -> HandlerGauge {
        stats.live_handlers.fetch_add(1, Ordering::Relaxed);
        HandlerGauge(stats)
    }
}

impl Drop for HandlerGauge {
    fn drop(&mut self) {
        self.0.live_handlers.fetch_sub(1, Ordering::Relaxed);
    }
}

const ACCEPT_POLL: Duration = Duration::from_millis(2);
/// Tracked-handle high-water mark that forces a reap even under a
/// continuous accept stream (idle gaps already reap opportunistically).
const REAP_AT: usize = 64;

fn accept_loop(listener: std::net::TcpListener, ctx: Arc<ServeContext>,
               stats: Arc<ServerStats>, stop: Arc<AtomicBool>) {
    let line_cap = max_line_bytes(&ctx.registry, &ctx.cfg);
    let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut conn_id: u64 = 0;
    while !stop.load(Ordering::Relaxed) {
        if sighup::take() {
            stats.reloads.fetch_add(1, Ordering::Relaxed);
            for (name, r) in ctx.registry.reload_all() {
                match r {
                    Ok(v) => eprintln!(
                        "nitro serve: reloaded '{name}' -> v{v}"),
                    Err(e) => eprintln!(
                        "nitro serve: reload '{name}' failed, keeping \
                         the old version: {e}"
                    ),
                }
            }
        }
        match listener.accept() {
            Ok((stream, _)) => {
                stats.accepted.fetch_add(1, Ordering::Relaxed);
                // accepted sockets inherit the listener's nonblocking
                // mode on some platforms; handlers want blocking reads
                let _ = stream.set_nonblocking(false);
                if ctx.cfg.io_timeout_ms > 0 {
                    // bound every blocking read/write: a connection
                    // that stalls mid-line times out, the handler's
                    // read errors, the thread exits and is reaped —
                    // slowloris cannot pin handler threads
                    let t = Duration::from_millis(ctx.cfg.io_timeout_ms);
                    let _ = stream.set_read_timeout(Some(t));
                    let _ = stream.set_write_timeout(Some(t));
                }
                let client = ctx.batcher.client(conn_id);
                conn_id = conn_id.wrapping_add(1);
                let cctx = ctx.clone();
                let gauge = HandlerGauge::new(stats.clone());
                // fallible spawn: exhausting the OS thread limit (e.g. a
                // client holding thousands of connections open) must
                // drop that connection, not panic the accept loop and
                // take the server down
                let spawned = std::thread::Builder::new()
                    .name("nitro-serve-conn".into())
                    .spawn(move || {
                        let _gauge = gauge;
                        connection(stream, &cctx, &client, line_cap);
                    });
                match spawned {
                    Ok(h) => {
                        handles.push(h);
                        if handles.len() >= REAP_AT {
                            reap(&mut handles, &stats);
                        }
                    }
                    Err(e) => eprintln!(
                        "connection dropped: spawn handler thread: {e}"),
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock =>
            {
                reap(&mut handles, &stats);
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => {
                eprintln!("accept: {e}");
                std::thread::sleep(ACCEPT_POLL);
            }
        }
    }
    // drain on shutdown: every handler is joined, none leak
    for h in handles.drain(..) {
        let _ = h.join();
    }
    stats.tracked_handles.store(0, Ordering::Relaxed);
}

/// Join every finished handler thread and release its resources. The
/// pre-refactor server pushed handles nowhere and never joined them —
/// under a churn of short-lived connections that leaked a join handle
/// (and its thread bookkeeping) per connection, forever.
fn reap(handles: &mut Vec<std::thread::JoinHandle<()>>,
        stats: &ServerStats) {
    let (done, live): (Vec<_>, Vec<_>) =
        handles.drain(..).partition(|h| h.is_finished());
    for h in done {
        // cannot block: is_finished() was true
        stats.reaped.fetch_add(1, Ordering::Relaxed);
        let _ = h.join();
    }
    *handles = live;
    stats.tracked_handles.store(handles.len(), Ordering::Relaxed);
}

/// One connection: capped line reads, one response line per request.
fn connection(stream: std::net::TcpStream, ctx: &ServeContext,
              client: &BatchClient, line_cap: u64) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "?".into());
    let mut reader = std::io::BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{peer}: clone: {e}");
            return;
        }
    });
    let mut writer = stream;
    let mut buf = Vec::new();
    loop {
        // capped read: at most line_cap + 1 bytes are ever buffered for
        // one line, newline or not
        buf.clear();
        use std::io::Read;
        let n = match (&mut reader)
            .take(line_cap + 1)
            .read_until(b'\n', &mut buf)
        {
            Ok(0) => break,
            Ok(n) => n,
            Err(_) => break,
        };
        if n as u64 > line_cap {
            // oversized line: answer and drop the connection — there is
            // no way to resync to the next request without buffering
            // the rest of the flood
            let resp = wire::err_response(
                0,
                Json::Null,
                &ServeError::too_large(format!(
                    "request line exceeds {line_cap} bytes"
                )),
            );
            let _ = writer.write_all(resp.dump().as_bytes());
            let _ = writer.write_all(b"\n");
            break;
        }
        let line = String::from_utf8_lossy(&buf);
        let line = line.trim_end_matches(['\n', '\r']);
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle_line(ctx, client, line);
        if writer
            .write_all(resp.dump().as_bytes())
            .and_then(|_| writer.write_all(b"\n"))
            .is_err()
        {
            break;
        }
    }
}

// ---------------------------------------------------------------------------
// SIGHUP hot reload
// ---------------------------------------------------------------------------

#[cfg(unix)]
// `unsafe` is limited to the libc `signal()` FFI call; exempted from the
// crate-root `#![deny(unsafe_code)]`.
#[allow(unsafe_code)]
mod sighup {
    use std::sync::atomic::{AtomicBool, Ordering};

    static PENDING: AtomicBool = AtomicBool::new(false);
    const SIGHUP: i32 = 1;

    extern "C" fn on_sighup(_sig: i32) {
        // an atomic store is async-signal-safe; the accept loop does
        // the actual (allocating, locking) reload outside the handler
        PENDING.store(true, Ordering::Relaxed);
    }

    pub fn install() {
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32))
                      -> isize;
        }
        unsafe {
            signal(SIGHUP, on_sighup);
        }
    }

    pub fn take() -> bool {
        PENDING.swap(false, Ordering::Relaxed)
    }
}

#[cfg(not(unix))]
mod sighup {
    pub fn install() {
        eprintln!("nitro serve: --reload-on-sighup is unix-only; use \
                   the v1 `reload` request instead");
    }

    pub fn take() -> bool {
        false
    }
}

// ---------------------------------------------------------------------------
// one-shot prediction (`nitro predict`)
// ---------------------------------------------------------------------------

/// Parse a predict input document: a flat int array, an array of
/// per-sample arrays, or an object with an `inputs` field holding either.
fn parse_inputs(j: &Json, sample_size: usize) -> Result<Vec<i32>, String> {
    if let Some(inner) = j.get("inputs") {
        return parse_inputs(inner, sample_size);
    }
    let arr = j
        .as_array()
        .ok_or("input must be an array (flat or one array per sample)")?;
    match arr.first() {
        None => Err("input is empty".into()),
        Some(Json::Array(_)) => {
            let mut flat = Vec::new();
            for (i, row) in arr.iter().enumerate() {
                let r = wire::i32_vec_strict(row)
                    .map_err(|e| format!("sample {i}: {e}"))?;
                if r.len() != sample_size {
                    return Err(format!(
                        "sample {i}: {} values, expected {sample_size}",
                        r.len()
                    ));
                }
                flat.extend(r);
            }
            Ok(flat)
        }
        Some(_) => {
            let flat = wire::i32_vec_strict(j)?;
            if flat.is_empty() || flat.len() % sample_size != 0 {
                return Err(format!(
                    "flat input length {} is not a positive multiple of \
                     sample size {sample_size}",
                    flat.len()
                ));
            }
            Ok(flat)
        }
    }
}

/// One-shot batch scoring: load a checkpoint, score the input document
/// (`-` = stdin), return the response JSON. Runs inline on the caller —
/// under `NITRO_WORKERS=1` no thread is ever spawned, the fully
/// deterministic mode CI compares against multi-worker runs.
pub fn predict_once(ckpt: &str, input_src: &str) -> Result<Json, String> {
    let model = ServedModel::load(ckpt)?;
    let text = if input_src == "-" {
        let mut s = String::new();
        std::io::Read::read_to_string(&mut std::io::stdin(), &mut s)
            .map_err(|e| format!("stdin: {e}"))?;
        s
    } else {
        std::fs::read_to_string(input_src)
            .map_err(|e| format!("read {input_src}: {e}"))?
    };
    let j = Json::parse(&text).map_err(|e| format!("{input_src}: {e}"))?;
    let flat = parse_inputs(&j, model.sample_size)?;
    let n = flat.len() / model.sample_size;
    let mut scratch = InferScratch::new();
    let mut out = ITensor::empty();
    model.infer_into(flat, n, &mut scratch, &mut out);
    Ok(wire::ok_response(0, Json::Null, &model.name, model.version,
                         &out))
}

// ---------------------------------------------------------------------------
// serve throughput bench (BENCH_serve.json)
// ---------------------------------------------------------------------------

/// Requests/sec and latency percentiles vs micro-batch size through the
/// real micro-batcher, plus (non-quick) an open-loop overload section
/// through the real TCP server and `loadgen`, written to a
/// schema-versioned `BENCH_serve.json`. Also hard-checks the serving
/// identities — fused path vs reference, checkpoint round-trip, shard
/// count, and hot reload of identical bytes — pushing mismatches into
/// `failures`, which `bench-kernels` turns into a non-zero exit.
pub fn bench_serve(quick: bool, budget_s: f64, out_path: &str,
                   failures: &mut Vec<String>) -> Result<Json, String> {
    use crate::nn::zoo;
    use crate::util::rng::Pcg32;
    use std::time::Instant;

    let spec = zoo::get("tinycnn").expect("tinycnn preset");
    let net = Network::new(spec.clone(), 7);

    // serving identity: a round-tripped checkpoint must serve logits
    // bit-identical to the in-memory network on both forward paths
    let dir = std::env::temp_dir().join("nitro_serve_bench");
    std::fs::create_dir_all(&dir)
        .map_err(|e| format!("mkdir {}: {e}", dir.display()))?;
    let ckpt = dir.join(format!("tinycnn-{}.ckpt", std::process::id()));
    let ckpt_s = ckpt.to_str().expect("utf8 temp path").to_string();
    checkpoint::save(&net, &ckpt_s)?;
    // the checkpoint file stays on disk until after the hot-reload
    // identity check below; run the fallible body in a closure so every
    // early `?` return still removes it
    let result = (|| -> Result<Json, String> {
        let model = ServedModel::load(&ckpt_s)?;
        let mut rng = Pcg32::new(17);
        let probe_n = 5usize;
        let flat: Vec<i32> = (0..probe_n * model.sample_size)
            .map(|_| rng.range_i32(-127, 127))
            .collect();
        let x =
            ITensor::from_vec(&model.batch_shape(probe_n), flat.clone());
        let reference = net.infer(&x);
        let mut scratch = InferScratch::new();
        let mut out = ITensor::empty();
        model.infer_into(flat.clone(), probe_n, &mut scratch, &mut out);
        if out != reference {
            failures.push("serve: ckpt-roundtrip fused infer".to_string());
        }
        if model.infer_reference(&x) != reference {
            failures
                .push("serve: ckpt-roundtrip reference infer".to_string());
        }

        let registry = Arc::new(ModelRegistry::new());
        registry.insert(model)?;

        // hot-reload identity: reloading the same checkpoint bytes must
        // bump the version and serve bit-identical logits
        for (name, r) in registry.reload_all() {
            if let Err(e) = r {
                failures.push(format!("serve: hot reload '{name}': {e}"));
            }
        }
        let reloaded = registry.resolve(None)?;
        if reloaded.version < 2 {
            failures.push("serve: reload did not bump version".into());
        }
        let mut out2 = ITensor::empty();
        reloaded.infer_into(flat.clone(), probe_n, &mut scratch,
                            &mut out2);
        if out2 != reference {
            failures.push("serve: hot-reload identity".to_string());
        }

        // shard-count identity: every shard of a 1- and a 2-shard
        // batcher serves the reference logits bit-identically
        for nshards in [1usize, 2] {
            let sb = ShardedBatcher::start(
                registry.clone(),
                ServeConfig {
                    shards: nshards,
                    max_wait_us: 0,
                    ..Default::default()
                },
            );
            for key in 0..nshards as u64 {
                let (_, y) = sb.client(key).predict(None, flat.clone())?;
                if y != reference {
                    failures.push(format!(
                        "serve: shard identity ({nshards} shards, \
                         key {key})"
                    ));
                }
            }
        }

        let sample_size = registry.resolve(None)?.sample_size;
        let batch_sizes: &[usize] =
            if quick { &[1, 2, 8] } else { &[1, 4, 16, 64] };
        let mut rows = Vec::new();
        let mut est_rps = 0.0f64;
        println!("serve_throughput (tinycnn, through the micro-batcher):");
        for &bs in batch_sizes {
            let mb = MicroBatcher::start(
                registry.clone(),
                ServeConfig {
                    max_batch: bs.max(1),
                    max_wait_us: 0,
                    ..Default::default()
                },
            );
            let client = mb.client();
            let req: Vec<i32> = (0..bs * sample_size)
                .map(|_| rng.range_i32(-127, 127))
                .collect();
            // warm the scratch buffers so steady state is measured
            client.predict(None, req.clone())?;
            let budget = Duration::from_secs_f64(budget_s.max(1e-3));
            let t0 = Instant::now();
            let mut lat_ns: Vec<u64> = Vec::new();
            while t0.elapsed() < budget && lat_ns.len() < 10_000 {
                let t = Instant::now();
                let (_, y) = client.predict(None, req.clone())?;
                lat_ns.push(t.elapsed().as_nanos() as u64);
                std::hint::black_box(y);
            }
            let total_s = t0.elapsed().as_secs_f64();
            lat_ns.sort_unstable();
            let q = |p: f64| {
                lat_ns[(p * (lat_ns.len() - 1) as f64) as usize] as f64
            };
            let rps = lat_ns.len() as f64 / total_s.max(1e-9);
            est_rps = est_rps.max(rps);
            println!(
                "  batch {bs:>3}: {:>9.1} req/s {:>10.1} samples/s  \
                 p50 {:>9.0} ns  p99 {:>9.0} ns  ({} reqs)",
                rps,
                rps * bs as f64,
                q(0.5),
                q(0.99),
                lat_ns.len()
            );
            rows.push(Json::obj(vec![
                ("batch", Json::Int(bs as i64)),
                ("requests", Json::Int(lat_ns.len() as i64)),
                ("requests_per_sec", Json::Float(rps)),
                ("samples_per_sec", Json::Float(rps * bs as f64)),
                ("p50_ns", Json::Float(q(0.5))),
                ("p99_ns", Json::Float(q(0.99))),
                ("mean_ns", Json::Float(
                    lat_ns.iter().sum::<u64>() as f64
                        / lat_ns.len() as f64,
                )),
            ]));
        }

        let open_loop = if quick {
            Json::obj(vec![(
                "skipped",
                Json::Str("quick mode".to_string()),
            )])
        } else {
            open_loop_section(&registry, budget_s, est_rps)
        };

        Ok(Json::obj(vec![
            ("schema_version", Json::Int(SCHEMA_VERSION)),
            ("experiment", Json::Str("serve".to_string())),
            ("preset", Json::Str("tinycnn".to_string())),
            ("workers",
             Json::Int(crate::util::par::default_workers() as i64)),
            ("quick", Json::Bool(quick)),
            ("budget_s", Json::Float(budget_s)),
            ("serve_throughput", Json::Array(rows)),
            ("open_loop", open_loop),
            ("bitexact",
             Json::Bool(
                 !failures.iter().any(|f| f.starts_with("serve:")))),
        ]))
    })();
    let _ = std::fs::remove_file(&ckpt);
    let record = result?;
    std::fs::write(out_path, record.pretty())
        .map_err(|e| format!("write {out_path}: {e}"))?;
    println!("-> {out_path}");
    Ok(record)
}

/// Open-loop overload measurement through the real TCP server: offer
/// several times the closed-loop capacity with a tight queue budget, so
/// the record shows honest overload percentiles and a nonzero shed
/// count. Failures degrade to a `skipped` note — the bench record must
/// exist even on a machine that cannot bind a socket.
fn open_loop_section(registry: &Arc<ModelRegistry>, budget_s: f64,
                     est_rps: f64) -> Json {
    let cfg = match ServeConfig::builder()
        .shards(2)
        .max_wait_us(200)
        .queue_budget_ms(2.0)
        .build()
    {
        Ok(c) => c,
        Err(e) => return Json::obj(vec![("skipped", Json::Str(e))]),
    };
    let srv =
        match spawn_tcp(registry.clone(), cfg, "127.0.0.1:0", false) {
            Ok(s) => s,
            Err(e) => {
                return Json::obj(vec![("skipped", Json::Str(e))])
            }
        };
    let rate = (est_rps * 4.0).clamp(50.0, 20_000.0);
    let duration_s = budget_s.clamp(0.25, 1.5);
    let rep = loadgen::run(&loadgen::LoadgenOpts {
        addr: srv.addr().to_string(),
        rate,
        duration_s,
        connections: 8,
        model: None,
        req_samples: 1,
        seed: 42,
    });
    let out = match rep {
        Ok(r) => {
            println!(
                "open_loop: offered {:.0} rps for {duration_s:.2}s -> \
                 ok {} shed {} err {}  p50 {}us p99 {}us p999 {}us",
                rate,
                r.ok,
                r.shed,
                r.errors,
                r.hist.quantile(0.50) / 1000,
                r.hist.quantile(0.99) / 1000,
                r.hist.quantile(0.999) / 1000
            );
            Json::obj(vec![
                ("shards", Json::Int(cfg.shards as i64)),
                ("queue_budget_us",
                 Json::Int(cfg.queue_budget_us as i64)),
                ("loadgen", r.json()),
            ])
        }
        Err(e) => Json::obj(vec![("skipped", Json::Str(e))]),
    };
    srv.shutdown();
    out
}

// ---------------------------------------------------------------------------
// shared test fixtures
// ---------------------------------------------------------------------------

#[cfg(test)]
pub(crate) mod testutil {
    use super::ServedModel;
    use crate::nn::{zoo, Network};
    use crate::train::checkpoint;
    use crate::util::rng::Pcg32;

    pub fn saved_model(preset: &str, seed: u64, tag: &str)
                       -> (String, Network) {
        let dir = std::env::temp_dir().join("nitro_serve_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{preset}-{tag}-{}.ckpt",
                                    std::process::id()));
        let net = Network::new(zoo::get(preset).unwrap(), seed);
        checkpoint::save(&net, path.to_str().unwrap()).unwrap();
        (path.to_str().unwrap().to_string(), net)
    }

    pub fn rand_samples(model: &ServedModel, n: usize, rng: &mut Pcg32)
                        -> Vec<i32> {
        (0..n * model.sample_size)
            .map(|_| rng.range_i32(-127, 127))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::{rand_samples, saved_model};
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    fn ctx_for(path: &str, cfg: ServeConfig) -> ServeContext {
        let reg = Arc::new(ModelRegistry::from_paths(path).unwrap());
        ServeContext::new(reg, cfg)
    }

    #[test]
    fn handle_line_protocol_and_errors() {
        let (path, net) = saved_model("mlp1-mini", 2, "proto");
        let ctx = ctx_for(&path, ServeConfig::default());
        let client = ctx.batcher.client(0);
        let model = ctx.registry.resolve(None).unwrap();
        let mut rng = Pcg32::new(3);
        let flat = rand_samples(&model, 1, &mut rng);
        let input = Json::Array(
            flat.iter().map(|&v| Json::Int(v as i64)).collect(),
        );
        let line = Json::obj(vec![
            ("id", Json::Int(7)),
            ("input", input),
        ])
        .dump();
        let resp = handle_line(&ctx, &client, &line);
        assert_eq!(resp.req("id").unwrap().as_i64(), Some(7));
        assert_eq!(resp.req("model").unwrap().as_str(), Some("mlp1-mini"));
        // a v0 request gets the exact legacy shape: no "v", no version
        assert!(resp.get("v").is_none());
        assert!(resp.get("model_version").is_none());
        let x = ITensor::from_vec(&model.batch_shape(1), flat);
        let want = net.infer(&x);
        let logits =
            resp.req("logits").unwrap().as_array().unwrap()[0].i32_vec()
                .unwrap();
        assert_eq!(logits, want.data);
        let am = resp.req("argmax").unwrap().as_array().unwrap()[0]
            .as_i64()
            .unwrap();
        // first-max-wins, matching the server's argmax
        let mut best = 0usize;
        for j in 1..want.data.len() {
            if want.data[j] > want.data[best] {
                best = j;
            }
        }
        assert_eq!(am, best as i64);

        // error paths: bad JSON, missing input, wrong sample size,
        // unknown model — all JSON error responses, never a panic
        // a pathologically nested line must error, not blow the stack
        let deep = "[".repeat(100_000);
        for bad in [
            "{not json",
            r#"{"id": 1}"#,
            r#"{"id": 2, "input": [1, 2, 3]}"#,
            r#"{"id": 3, "model": "nope", "input": [1]}"#,
            r#"{"id": 4, "input": "xyz"}"#,
            // out-of-i32-range values must error, not wrap mod 2^32
            r#"{"id": 5, "input": [2147483648]}"#,
            // a non-string model must error, not silently fall back
            r#"{"id": 6, "model": 42, "input": [1]}"#,
            // v0 lines cannot use v1 control ops
            r#"{"id": 7, "op": "reload"}"#,
            deep.as_str(),
        ] {
            let resp = handle_line(&ctx, &client, bad);
            assert!(resp.get("error").is_some(), "no error for {bad}");
            // v0 errors stay legacy-shaped strings
            assert!(resp.req("error").unwrap().as_str().is_some(),
                    "v0 error must be a string for {bad}");
        }
    }

    #[test]
    fn v1_round_trip_stats_and_reload() {
        let (path, net) = saved_model("mlp1-mini", 12, "v1");
        let ctx = ctx_for(
            &path,
            ServeConfig { max_request_samples: 2,
                          ..Default::default() },
        );
        let client = ctx.batcher.client(0);
        let model = ctx.registry.resolve(None).unwrap();
        let mut rng = Pcg32::new(5);
        let flat = rand_samples(&model, 1, &mut rng);
        let input = Json::Array(
            flat.iter().map(|&v| Json::Int(v as i64)).collect(),
        );
        let line = Json::obj(vec![
            ("v", Json::Int(1)),
            ("id", Json::Int(1)),
            ("input", input),
        ])
        .dump();
        let resp = handle_line(&ctx, &client, &line);
        assert_eq!(resp.req("v").unwrap().as_i64(), Some(1));
        assert_eq!(resp.req("model_version").unwrap().as_i64(), Some(1));
        let x = ITensor::from_vec(&model.batch_shape(1), flat.clone());
        let want = net.infer(&x);
        let logits =
            resp.req("logits").unwrap().as_array().unwrap()[0].i32_vec()
                .unwrap();
        assert_eq!(logits, want.data);

        // typed error codes
        let resp = handle_line(
            &ctx, &client,
            r#"{"v": 1, "id": 2, "model": "nope", "input": [1]}"#,
        );
        assert_eq!(
            resp.req("error").unwrap().req("code").unwrap().as_str(),
            Some("unknown_model")
        );
        let big = rand_samples(&model, 3, &mut rng);
        let line = Json::obj(vec![
            ("v", Json::Int(1)),
            ("id", Json::Int(3)),
            ("input", Json::Array(
                big.iter().map(|&v| Json::Int(v as i64)).collect())),
        ])
        .dump();
        let resp = handle_line(&ctx, &client, &line);
        assert_eq!(
            resp.req("error").unwrap().req("code").unwrap().as_str(),
            Some("too_large")
        );

        // stats: models + shards + merged latency, all v1
        let resp = handle_line(&ctx, &client,
                               r#"{"v": 1, "id": 4, "op": "stats"}"#);
        assert_eq!(resp.req("v").unwrap().as_i64(), Some(1));
        let models = resp.req("models").unwrap().as_array().unwrap();
        assert_eq!(models[0].req("name").unwrap().as_str(),
                   Some("mlp1-mini"));
        assert!(models[0].req("requests").unwrap().as_i64().unwrap()
                >= 1);
        let shards = resp.req("shards").unwrap().as_array().unwrap();
        assert_eq!(shards.len(), ctx.cfg.shards.max(1));
        assert!(resp.req("completed").unwrap().as_i64().unwrap() >= 1);
        assert!(resp.req("latency").unwrap().get("p99_us").is_some());

        // reload: version bumps, echoed in subsequent predicts
        let resp = handle_line(&ctx, &client,
                               r#"{"v": 1, "id": 5, "op": "reload"}"#);
        let reloaded =
            resp.req("reloaded").unwrap().as_array().unwrap();
        assert_eq!(reloaded[0].req("version").unwrap().as_i64(),
                   Some(2));
        assert_eq!(resp.req("errors").unwrap().as_array().unwrap().len(),
                   0);
        let line = Json::obj(vec![
            ("v", Json::Int(1)),
            ("id", Json::Int(6)),
            ("input", Json::Array(
                flat.iter().map(|&v| Json::Int(v as i64)).collect())),
        ])
        .dump();
        let resp = handle_line(&ctx, &client, &line);
        assert_eq!(resp.req("model_version").unwrap().as_i64(), Some(2));
        // identical checkpoint bytes -> bit-identical logits after reload
        let logits =
            resp.req("logits").unwrap().as_array().unwrap()[0].i32_vec()
                .unwrap();
        assert_eq!(logits, want.data);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fuzzed_lines_always_get_a_json_answer() {
        let (path, _) = saved_model("mlp1-mini", 33, "fuzz");
        let ctx = ctx_for(&path, ServeConfig::default());
        let client = ctx.batcher.client(0);
        const CHARS: &[u8] =
            br#"{}[]":,vinputmodelopstatsreload 0123456789-"#;
        prop::check("serve_wire_fuzz", 300, |g| {
            let len = g.usize_in(0, 160);
            let line: String = (0..len)
                .map(|_| CHARS[g.usize_in(0, CHARS.len() - 1)] as char)
                .collect();
            let resp = handle_line(&ctx, &client, &line);
            // whatever the input, the server answers a JSON object that
            // either errors or carries a well-formed payload
            assert!(
                resp.get("error").is_some()
                    || resp.get("logits").is_some()
                    || resp.get("models").is_some()
                    || resp.get("reloaded").is_some(),
                "no structured answer for {line:?}"
            );
        });
    }

    #[test]
    fn tcp_reaps_short_lived_connections() {
        use std::io::{BufRead, BufReader, Write};
        let (path, _) = saved_model("mlp1-mini", 44, "reap");
        let reg = Arc::new(ModelRegistry::from_paths(&path).unwrap());
        let srv = spawn_tcp(
            reg,
            ServeConfig { max_wait_us: 0, ..Default::default() },
            "127.0.0.1:0",
            false,
        )
        .unwrap();
        let stats = srv.stats();
        let nconns = 40usize;
        for i in 0..nconns {
            let stream =
                std::net::TcpStream::connect(srv.addr()).unwrap();
            let mut reader =
                BufReader::new(stream.try_clone().unwrap());
            let mut writer = stream;
            writer
                .write_all(
                    format!("{{\"id\": {i}, \"input\": [1]}}\n")
                        .as_bytes(),
                )
                .unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            assert!(resp.contains("error"), "wrong sample size: {resp}");
            // connection closes here; the handler thread finishes
        }
        // the accept loop reaps finished handlers in its idle gaps —
        // without the reap, tracked handles grow one per connection
        // forever (the pre-refactor leak)
        let t0 = std::time::Instant::now();
        loop {
            let live = stats.live_handlers.load(Ordering::Relaxed);
            let reaped = stats.reaped.load(Ordering::Relaxed);
            if live == 0 && reaped >= (nconns as u64) - 4 {
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "handlers not reaped: live {live}, reaped {reaped}"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(stats.accepted.load(Ordering::Relaxed),
                   nconns as u64);
        assert!(
            stats.tracked_handles.load(Ordering::Relaxed) < REAP_AT,
            "tracked handles grew without bound"
        );
        srv.shutdown();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn tcp_stalled_connection_times_out_and_is_reaped() {
        use std::io::{BufRead, BufReader, Write};
        let (path, _) = saved_model("mlp1-mini", 45, "stall");
        let reg = Arc::new(ModelRegistry::from_paths(&path).unwrap());
        let cfg = ServeConfig::builder()
            .max_wait_us(0)
            .io_timeout_ms(60)
            .build()
            .unwrap();
        let srv = spawn_tcp(reg, cfg, "127.0.0.1:0", false).unwrap();
        let stats = srv.stats();
        // a slowloris client: opens the connection, sends a partial
        // line, never completes it, and never closes its end
        let mut stalled =
            std::net::TcpStream::connect(srv.addr()).unwrap();
        stalled.write_all(b"{\"id\": 1, \"inp").unwrap();
        // without socket timeouts the handler blocks in read_until
        // forever; with them the read errors and the thread exits
        let t0 = std::time::Instant::now();
        loop {
            if stats.live_handlers.load(Ordering::Relaxed) == 0
                && stats.reaped.load(Ordering::Relaxed) >= 1
            {
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(5),
                "stalled handler was not dropped and reaped"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        // the server still answers new, well-behaved connections
        let stream = std::net::TcpStream::connect(srv.addr()).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        writer
            .write_all(b"{\"id\": 2, \"input\": [1]}\n")
            .unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        assert!(resp.contains("error"), "wrong sample size: {resp}");
        drop(stalled);
        srv.shutdown();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn config_builder_validates_ranges() {
        // defaults build and equal ServeConfig::default()
        let d = ServeConfig::default();
        let b = ServeConfig::builder().build().unwrap();
        assert_eq!(b.max_batch, d.max_batch);
        assert_eq!(b.max_wait_us, d.max_wait_us);
        assert_eq!(b.max_request_samples, d.max_request_samples);
        assert_eq!(b.shards, d.shards);
        assert_eq!(b.queue_budget_us, d.queue_budget_us);
        assert_eq!(b.io_timeout_ms, d.io_timeout_ms);
        // unit conversion: ms (CLI) -> us (config)
        let c = ServeConfig::builder().queue_budget_ms(2.5).build()
            .unwrap();
        assert_eq!(c.queue_budget_us, 2500);
        // shards 0 = auto, at least 1
        let c = ServeConfig::builder().shards(0).build().unwrap();
        assert!(c.shards >= 1 && c.shards <= 64, "{}", c.shards);
        // every violation names its CLI flag
        for (err, flag) in [
            (ServeConfig::builder().max_batch(0).build(), "--max-batch"),
            (ServeConfig::builder().max_batch(100_000).build(),
             "--max-batch"),
            (ServeConfig::builder().max_wait_us(20_000_000).build(),
             "--max-wait-us"),
            (ServeConfig::builder().max_request_samples(0).build(),
             "--max-request"),
            (ServeConfig::builder().shards(1000).build(), "--shards"),
            (ServeConfig::builder().queue_budget_ms(-1.0).build(),
             "--queue-budget-ms"),
            (ServeConfig::builder().queue_budget_ms(f64::NAN).build(),
             "--queue-budget-ms"),
            (ServeConfig::builder().io_timeout_ms(3_600_001).build(),
             "--io-timeout-ms"),
        ] {
            let e = err.unwrap_err();
            assert!(e.contains(flag), "{e} should mention {flag}");
        }
    }

    #[test]
    fn tcp_line_cap_scales_with_widest_model() {
        let (path, _) = saved_model("tinycnn", 1, "linecap");
        let reg = ModelRegistry::from_paths(&path).unwrap();
        let cfg = ServeConfig::default();
        // tinycnn sample = 1*8*8 = 64 ints
        assert_eq!(max_line_bytes(&reg, &cfg),
                   64 * cfg.max_request_samples as u64 * 13 + 4096);
    }

    #[test]
    fn parse_inputs_forms() {
        let flat = Json::parse("[1, 2, 3, 4]").unwrap();
        assert_eq!(parse_inputs(&flat, 2).unwrap(), vec![1, 2, 3, 4]);
        let nested = Json::parse("[[1, 2], [3, 4]]").unwrap();
        assert_eq!(parse_inputs(&nested, 2).unwrap(), vec![1, 2, 3, 4]);
        let wrapped = Json::parse(r#"{"inputs": [[1, 2]]}"#).unwrap();
        assert_eq!(parse_inputs(&wrapped, 2).unwrap(), vec![1, 2]);
        assert!(parse_inputs(&flat, 3).is_err(), "not a multiple");
        assert!(parse_inputs(&Json::parse("[]").unwrap(), 2).is_err());
        assert!(parse_inputs(&Json::parse("[[1]]").unwrap(), 2).is_err());
        assert!(parse_inputs(&Json::parse("\"x\"").unwrap(), 2).is_err());
    }

    #[test]
    fn bench_serve_quick_emits_record_and_passes_identity() {
        let dir = std::env::temp_dir().join("nitro_serve_bench_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_serve.json");
        let mut failures = Vec::new();
        let rec = bench_serve(true, 0.01, out.to_str().unwrap(),
                              &mut failures)
            .unwrap();
        assert!(failures.is_empty(), "{failures:?}");
        assert_eq!(rec.req("schema_version").unwrap().as_i64(),
                   Some(SCHEMA_VERSION));
        assert_eq!(rec.req("bitexact").unwrap().as_bool(), Some(true));
        let rows =
            rec.req("serve_throughput").unwrap().as_array().unwrap();
        assert_eq!(rows.len(), 3, "quick batch sizes");
        for r in rows {
            assert!(r.req("requests_per_sec").unwrap().as_f64().unwrap()
                    > 0.0);
            assert!(r.req("p99_ns").unwrap().as_f64().unwrap()
                    >= r.req("p50_ns").unwrap().as_f64().unwrap());
        }
        // the open_loop key always exists; quick mode marks it skipped
        assert!(rec.req("open_loop").unwrap().get("skipped").is_some());
        let reread = Json::parse_file(out.to_str().unwrap()).unwrap();
        assert_eq!(reread.req("experiment").unwrap().as_str(),
                   Some("serve"));
    }
}
