//! `nitro loadgen`: an open-loop, coordinated-omission-safe load
//! generator for the serve TCP endpoint.
//!
//! A closed-loop client (like the serve bench's throughput rows) only
//! sends the next request after the previous response arrives, so a slow
//! server *slows the load down* and the measured latencies silently skip
//! exactly the moments that hurt — Gil Tene's "coordinated omission".
//! This generator instead fixes the arrival schedule up front: request
//! `i` of an `R`-per-second run is *due* at `start + i/R`, no matter how
//! the server is doing. Each connection owns an interleaved slice of the
//! schedule (connection `c` sends requests `c, c+conns, c+2·conns, ...`)
//! and sleeps until each due time. If the server falls behind, due times
//! land in the past — the send happens late (counted in `late_sends`)
//! and the request's latency is still charged **from its due time**, so
//! queueing delay the server caused shows up in the percentiles instead
//! of vanishing from them.
//!
//! Responses with an `overloaded` error code count as `shed` — that is
//! the server keeping its latency promise by refusing work — and are
//! excluded from the latency histogram; any other error is a hard error.
//!
//! The generator speaks wire v1 by default (every request carries
//! `"v": 1`); the deprecated v0 shape is neither sent nor accepted —
//! a v0 string error from the server is classified as a hard error.

use super::shed::hist_json;
use super::wire::WIRE_V1;
use crate::util::hist::LogHistogram;
use crate::util::jsonio::Json;
use crate::util::rng::Pcg32;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

pub struct LoadgenOpts {
    /// `host:port` of a running `nitro serve --listen`.
    pub addr: String,
    /// Offered request rate, requests/second (across all connections).
    pub rate: f64,
    pub duration_s: f64,
    pub connections: usize,
    /// Model to target; `None` = the server's single model.
    pub model: Option<String>,
    /// Samples per request.
    pub req_samples: usize,
    /// Seed for the (deterministic) request payloads.
    pub seed: u64,
}

/// Ask the server what it serves (one v1 `stats` round-trip). Returns
/// `(name, sample_size)` per model.
pub fn probe_models(addr: &str)
                    -> Result<Vec<(String, usize)>, String> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| format!("connect {addr}: {e}"))?;
    let mut reader = BufReader::new(
        stream.try_clone().map_err(|e| format!("clone: {e}"))?);
    let mut writer = stream;
    let req = Json::obj(vec![
        ("v", Json::Int(WIRE_V1)),
        ("id", Json::Int(0)),
        ("op", Json::Str("stats".to_string())),
    ]);
    writer
        .write_all(format!("{}\n", req.dump()).as_bytes())
        .map_err(|e| format!("send stats probe: {e}"))?;
    let mut line = String::new();
    reader
        .read_line(&mut line)
        .map_err(|e| format!("read stats probe: {e}"))?;
    let j = Json::parse(line.trim())
        .map_err(|e| format!("stats probe response: {e}"))?;
    let models = j
        .get("models")
        .and_then(|m| m.as_array())
        .ok_or_else(|| {
            format!(
                "stats probe got no model list — is the server at \
                 {addr} speaking wire v1? (response: {})",
                line.trim()
            )
        })?;
    let mut out = Vec::new();
    for m in models {
        let name = m
            .get("name")
            .and_then(|n| n.as_str())
            .ok_or("stats probe: model without a name")?;
        let ss = m
            .get("sample_size")
            .and_then(|s| s.as_i64())
            .filter(|&s| s > 0)
            .ok_or("stats probe: model without a sample_size")?;
        out.push((name.to_string(), ss as usize));
    }
    if out.is_empty() {
        return Err(format!("server at {addr} serves no models"));
    }
    Ok(out)
}

/// Merged result of one open-loop run.
pub struct OpenLoopReport {
    pub model: String,
    /// Requests on the arrival schedule (= attempted sends).
    pub offered: u64,
    pub ok: u64,
    pub shed: u64,
    pub errors: u64,
    /// Sends that happened after their due time (server backpressure).
    pub late_sends: u64,
    pub duration_s: f64,
    pub offered_rps: f64,
    pub connections: usize,
    pub req_samples: usize,
    /// Due-time-to-response latency of `ok` responses, ns.
    pub hist: LogHistogram,
}

impl OpenLoopReport {
    pub fn achieved_rps(&self) -> f64 {
        self.ok as f64 / self.duration_s.max(1e-9)
    }

    /// Flat record for `BENCH_serve.json` / `nitro loadgen --out`.
    pub fn json(&self) -> Json {
        let base = Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("offered", Json::Int(self.offered as i64)),
            ("ok", Json::Int(self.ok as i64)),
            ("shed", Json::Int(self.shed as i64)),
            ("errors", Json::Int(self.errors as i64)),
            ("late_sends", Json::Int(self.late_sends as i64)),
            ("duration_s", Json::Float(self.duration_s)),
            ("offered_rps", Json::Float(self.offered_rps)),
            ("achieved_rps", Json::Float(self.achieved_rps())),
            ("connections", Json::Int(self.connections as i64)),
            ("req_samples", Json::Int(self.req_samples as i64)),
        ]);
        // flatten the latency summary in (p50_us, p99_us, p999_us, ...)
        let (mut map, lat) = match (base, hist_json(&self.hist)) {
            (Json::Object(m), Json::Object(l)) => (m, l),
            _ => unreachable!("obj() builds objects"),
        };
        map.extend(lat);
        Json::Object(map)
    }
}

enum Outcome {
    Ok,
    Shed,
    Err,
}

/// Classify one response line: logits = success, a typed `overloaded`
/// error = shed, anything else (including unparseable) = error.
fn classify(line: &str) -> Outcome {
    let j = match Json::parse(line.trim()) {
        Ok(j) => j,
        Err(_) => return Outcome::Err,
    };
    if j.get("logits").is_some() {
        return Outcome::Ok;
    }
    if let Some(e) = j.get("error") {
        if e.get("code").and_then(|c| c.as_str()) == Some("overloaded") {
            return Outcome::Shed;
        }
    }
    Outcome::Err
}

#[derive(Default)]
struct ConnResult {
    ok: u64,
    shed: u64,
    errors: u64,
    late: u64,
    hist: LogHistogram,
}

#[allow(clippy::too_many_arguments)]
fn conn_worker(addr: &str, model: &str, conn: usize, conns: usize,
               total: u64, rate: f64, start: Instant,
               sample_size: usize, req_samples: usize, seed: u64)
               -> ConnResult {
    let mut res = ConnResult::default();
    let mine = |from: u64| (total.saturating_sub(from))
        .div_ceil(conns as u64);
    let stream = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(_) => {
            res.errors += mine(conn as u64);
            return res;
        }
    };
    let _ = stream.set_nodelay(true);
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => {
            res.errors += mine(conn as u64);
            return res;
        }
    };
    let mut writer = stream;
    // deterministic payload, one fixed request line per connection —
    // the schedule, not the body, is what this tool varies
    let mut rng = Pcg32::with_stream(seed, 0x6c67 + conn as u64);
    let payload: Vec<Json> = (0..sample_size * req_samples)
        .map(|_| Json::Int(rng.range_i32(-127, 127) as i64))
        .collect();
    let line = format!(
        "{}\n",
        Json::obj(vec![
            ("v", Json::Int(WIRE_V1)),
            ("id", Json::Int(conn as i64)),
            ("model", Json::Str(model.to_string())),
            ("input", Json::Array(payload)),
        ])
        .dump()
    );
    let mut resp = String::new();
    let mut i = conn as u64;
    while i < total {
        let due = start
            + Duration::from_nanos((i as f64 * 1e9 / rate) as u64);
        let now = Instant::now();
        if now < due {
            std::thread::sleep(due - now);
        } else {
            res.late += 1;
        }
        if writer.write_all(line.as_bytes()).is_err() {
            res.errors += mine(i);
            break;
        }
        resp.clear();
        match reader.read_line(&mut resp) {
            Ok(n) if n > 0 => {}
            _ => {
                res.errors += mine(i);
                break;
            }
        }
        // charged from the *due* time: a late send does not launder the
        // backlog it sat in out of the percentiles
        let lat = Instant::now()
            .saturating_duration_since(due)
            .as_nanos() as u64;
        match classify(&resp) {
            Outcome::Ok => {
                res.ok += 1;
                res.hist.record(lat);
            }
            Outcome::Shed => res.shed += 1,
            Outcome::Err => res.errors += 1,
        }
        i += conns as u64;
    }
    res
}

/// Run the open-loop schedule against a live server.
pub fn run(opts: &LoadgenOpts) -> Result<OpenLoopReport, String> {
    if !(opts.rate.is_finite() && opts.rate > 0.0) {
        return Err(format!("--rate must be positive, got {}", opts.rate));
    }
    if !(opts.duration_s.is_finite() && opts.duration_s > 0.0) {
        return Err(format!(
            "--duration must be positive, got {}", opts.duration_s));
    }
    let conns = opts.connections.max(1);
    let req_samples = opts.req_samples.max(1);
    let models = probe_models(&opts.addr)?;
    let names = || {
        models.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>()
            .join(", ")
    };
    let (model, sample_size) = match &opts.model {
        Some(m) => models
            .iter()
            .find(|(n, _)| n == m)
            .cloned()
            .ok_or_else(|| format!(
                "server does not serve '{m}' (serving: {})", names()))?,
        None if models.len() == 1 => models[0].clone(),
        None => {
            return Err(format!(
                "--model required with several served models \
                 (serving: {})",
                names()
            ))
        }
    };
    let total = ((opts.rate * opts.duration_s).ceil() as u64).max(1);
    // small lead so every connection is connected before t=0 of the
    // schedule — connect time must not count as server latency
    let start = Instant::now() + Duration::from_millis(20);
    let results: Vec<ConnResult> = std::thread::scope(|s| {
        let mut joins = Vec::new();
        for c in 0..conns {
            let (addr, model) = (opts.addr.clone(), model.clone());
            let (rate, seed) = (opts.rate, opts.seed);
            joins.push(s.spawn(move || {
                conn_worker(&addr, &model, c, conns, total, rate,
                            start, sample_size, req_samples, seed)
            }));
        }
        joins.into_iter()
            .map(|j| j.join().expect("loadgen connection thread"))
            .collect()
    });
    let mut rep = OpenLoopReport {
        model,
        offered: total,
        ok: 0,
        shed: 0,
        errors: 0,
        late_sends: 0,
        duration_s: opts.duration_s,
        offered_rps: opts.rate,
        connections: conns,
        req_samples,
        hist: LogHistogram::new(),
    };
    for r in &results {
        rep.ok += r.ok;
        rep.shed += r.shed;
        rep.errors += r.errors;
        rep.late_sends += r.late;
        rep.hist.merge(&r.hist);
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::super::testutil::saved_model;
    use super::super::{spawn_tcp, ModelRegistry, ServeConfig};
    use super::*;
    use std::sync::Arc;

    #[test]
    fn classify_splits_ok_shed_error() {
        assert!(matches!(
            classify(r#"{"v":1,"id":0,"logits":[[1,2]],"argmax":[1]}"#),
            Outcome::Ok
        ));
        assert!(matches!(
            classify(concat!(
                r#"{"v":1,"id":0,"error":{"code":"overloaded","#,
                r#""message":"queue full"}}"#
            )),
            Outcome::Shed
        ));
        assert!(matches!(
            classify(r#"{"v":1,"id":0,"error":{"code":"bad_request","message":"x"}}"#),
            Outcome::Err
        ));
        // v0 string errors and garbage are hard errors, not sheds
        assert!(matches!(classify(r#"{"id":0,"error":"overloaded"}"#),
                         Outcome::Err));
        assert!(matches!(classify("not json"), Outcome::Err));
    }

    #[test]
    fn loadgen_open_loop_against_live_server() {
        let (path, _) = saved_model("tinycnn", 40, "loadgen");
        let reg = Arc::new(ModelRegistry::new());
        reg.load(&path).unwrap();
        let cfg = ServeConfig { shards: 2, max_wait_us: 0,
                                ..Default::default() };
        let srv = spawn_tcp(reg, cfg, "127.0.0.1:0", false).unwrap();
        let opts = LoadgenOpts {
            addr: srv.addr().to_string(),
            rate: 200.0,
            duration_s: 0.3,
            connections: 3,
            model: None,
            req_samples: 1,
            seed: 42,
        };
        let rep = run(&opts).unwrap();
        assert_eq!(rep.offered, 60);
        assert_eq!(rep.errors, 0, "late {} ok {}", rep.late_sends, rep.ok);
        // no budget configured -> nothing sheds, every request answers
        assert_eq!(rep.shed, 0);
        assert_eq!(rep.ok, 60);
        assert!(rep.achieved_rps() > 0.0);
        let p50 = rep.hist.quantile(0.50);
        let p99 = rep.hist.quantile(0.99);
        let p999 = rep.hist.quantile(0.999);
        assert!(p50 <= p99 && p99 <= p999, "{p50} {p99} {p999}");
        // unknown model is a friendly error, not a hang
        let err = run(&LoadgenOpts {
            addr: srv.addr().to_string(),
            rate: 10.0,
            duration_s: 0.05,
            connections: 1,
            model: Some("nope".to_string()),
            req_samples: 1,
            seed: 1,
        })
        .unwrap_err();
        assert!(err.contains("does not serve"), "{err}");
        srv.shutdown();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn loadgen_refuses_bad_rates_and_dead_servers() {
        let opts = LoadgenOpts {
            addr: "127.0.0.1:1".to_string(), // reserved port, closed
            rate: 100.0,
            duration_s: 0.1,
            connections: 1,
            model: None,
            req_samples: 1,
            seed: 1,
        };
        let err = run(&opts).unwrap_err();
        assert!(err.contains("connect"), "{err}");
        let err = run(&LoadgenOpts { rate: 0.0, ..opts }).unwrap_err();
        assert!(err.contains("--rate"), "{err}");
    }
}
