//! Versioned model registry with hot checkpoint reload.
//!
//! Models live behind `Arc<ServedModel>` in an `RwLock`ed map, so a
//! reload is a pointer swap: requests already resolved keep executing
//! on the old version (the `Arc` keeps it alive until its last in-flight
//! request finishes), requests resolved after the swap get the new one,
//! and no request ever observes a half-written model — the checkpoint
//! loader builds the replacement off to the side and the atomic-rename
//! write (`checkpoint::save`) guarantees the file read is all-old or
//! all-new. Every successful reload bumps the model's `version`, which
//! v1 responses echo so clients can tell which weights scored them.

use super::wire::ServeError;
use super::ServedModel;
use crate::util::jsonio::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Per-model request counters; survive reloads (they describe the name,
/// not one weight snapshot).
#[derive(Default)]
pub struct ModelStats {
    pub requests: AtomicU64,
    pub samples: AtomicU64,
}

struct Entry {
    model: Arc<ServedModel>,
    stats: Arc<ModelStats>,
}

/// The set of served models, keyed by name (an explicit `--models`
/// alias, or the spec name recorded in the checkpoint). Shared across
/// connection threads and shard executors; interior mutability makes
/// hot reload possible without stopping the world.
#[derive(Default)]
pub struct ModelRegistry {
    inner: RwLock<BTreeMap<String, Entry>>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Register an in-memory model (tests and the serve bench). A name
    /// collision is an error — two models shadowing each other is a
    /// config mistake, not a reload.
    pub fn insert(&self, model: ServedModel)
                  -> Result<Arc<ServedModel>, String> {
        let m = Arc::new(model);
        let mut map = self.inner.write().expect("registry lock");
        if let Some(prev) = map.get(&m.name) {
            return Err(format!(
                "model '{}' already loaded from {} (also in {})",
                m.name, prev.model.path, m.path
            ));
        }
        map.insert(m.name.clone(), Entry {
            model: m.clone(),
            stats: Arc::new(ModelStats::default()),
        });
        Ok(m)
    }

    /// Load a checkpoint keyed by its recorded spec name.
    pub fn load(&self, path: &str) -> Result<Arc<ServedModel>, String> {
        self.load_as(None, path)
    }

    /// Load a checkpoint under an explicit alias (`--models name=path`).
    pub fn load_as(&self, alias: Option<&str>, path: &str)
                   -> Result<Arc<ServedModel>, String> {
        self.insert(ServedModel::load_versioned(path, alias, 1)?)
    }

    /// Build a registry from a comma-separated checkpoint path list
    /// (the deprecated positional `nitro serve` form).
    pub fn from_paths(paths: &str) -> Result<ModelRegistry, String> {
        let reg = ModelRegistry::new();
        for p in paths.split(',').map(str::trim).filter(|p| !p.is_empty())
        {
            reg.load(p)?;
        }
        if reg.is_empty() {
            return Err("no checkpoint paths given".into());
        }
        Ok(reg)
    }

    /// Build a registry from a `--models` spec: comma-separated
    /// `name=path` entries (a bare `path` keys by the checkpoint's
    /// recorded spec name).
    pub fn from_spec(spec: &str) -> Result<ModelRegistry, String> {
        let reg = ModelRegistry::new();
        for item in
            spec.split(',').map(str::trim).filter(|p| !p.is_empty())
        {
            match item.split_once('=') {
                Some((name, path)) => {
                    let name = name.trim();
                    if name.is_empty() || path.trim().is_empty() {
                        return Err(format!(
                            "--models entry '{item}': want name=path"));
                    }
                    reg.load_as(Some(name), path.trim())?;
                }
                None => {
                    reg.load(item)?;
                }
            }
        }
        if reg.is_empty() {
            return Err("--models lists no checkpoints".into());
        }
        Ok(reg)
    }

    pub fn get(&self, name: &str) -> Option<Arc<ServedModel>> {
        self.inner
            .read()
            .expect("registry lock")
            .get(name)
            .map(|e| e.model.clone())
    }

    pub fn names(&self) -> Vec<String> {
        self.inner.read().expect("registry lock").keys().cloned()
            .collect()
    }

    pub fn len(&self) -> usize {
        self.inner.read().expect("registry lock").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Widest per-sample input across served models (sizes the TCP line
    /// cap). Reloads cannot change it: a reload must match the name's
    /// existing spec geometry or it is rejected.
    pub fn widest_sample_size(&self) -> usize {
        self.inner
            .read()
            .expect("registry lock")
            .values()
            .map(|e| e.model.sample_size)
            .max()
            .unwrap_or(1)
    }

    /// Resolve a request's model field: an explicit name must exist; an
    /// omitted name is allowed only when exactly one model is served.
    pub fn resolve(&self, name: Option<&str>)
                   -> Result<Arc<ServedModel>, ServeError> {
        let map = self.inner.read().expect("registry lock");
        let serving = || {
            map.keys().cloned().collect::<Vec<_>>().join(", ")
        };
        match name {
            Some(n) => map.get(n).map(|e| e.model.clone()).ok_or_else(
                || ServeError::unknown_model(format!(
                    "unknown model '{n}' (serving: {})", serving())),
            ),
            None if map.len() == 1 => {
                Ok(map.values().next().expect("len 1").model.clone())
            }
            None => Err(ServeError::bad_request(format!(
                "request must name a model (serving: {})", serving()))),
        }
    }

    /// Count an admitted request against the model's stats.
    pub fn note_request(&self, name: &str, nsamples: usize) {
        if let Some(e) =
            self.inner.read().expect("registry lock").get(name)
        {
            e.stats.requests.fetch_add(1, Ordering::Relaxed);
            e.stats.samples.fetch_add(nsamples as u64, Ordering::Relaxed);
        }
    }

    /// Hot-reload every model from its checkpoint path. Per model: on
    /// success the entry is swapped to the new `Arc` with a bumped
    /// version (in-flight requests finish on the old one); on failure
    /// (missing/corrupt file, or a checkpoint whose spec geometry no
    /// longer matches the name) the old version stays live and the error
    /// is reported. The checkpoint read happens outside the write lock —
    /// serving never blocks on disk.
    pub fn reload_all(&self) -> Vec<(String, Result<u64, String>)> {
        let targets: Vec<(String, String, u64)> = self
            .inner
            .read()
            .expect("registry lock")
            .iter()
            .map(|(k, e)| {
                (k.clone(), e.model.path.clone(), e.model.version)
            })
            .collect();
        let mut out = Vec::new();
        for (name, path, old_v) in targets {
            let loaded = ServedModel::load_versioned(
                &path, Some(&name), old_v + 1,
            )
            .and_then(|m| {
                let mut map = self.inner.write().expect("registry lock");
                match map.get_mut(&name) {
                    Some(e) => {
                        if m.sample_size != e.model.sample_size
                            || m.num_classes != e.model.num_classes
                        {
                            return Err(format!(
                                "checkpoint at {path} changed geometry \
                                 ({} ints -> {} ints per sample)",
                                e.model.sample_size, m.sample_size
                            ));
                        }
                        // last writer wins, versions stay monotone even
                        // under concurrent reload requests
                        let m = Arc::new(m);
                        if e.model.version < m.version {
                            e.model = m;
                        }
                        Ok(e.model.version)
                    }
                    None => Err("model vanished during reload".into()),
                }
            });
            out.push((name, loaded));
        }
        out
    }

    /// `models` section of the `stats` response.
    pub fn models_json(&self) -> Json {
        let map = self.inner.read().expect("registry lock");
        Json::Array(
            map.values()
                .map(|e| {
                    Json::obj(vec![
                        ("name", Json::Str(e.model.name.clone())),
                        ("path", Json::Str(e.model.path.clone())),
                        ("spec", Json::Str(e.model.spec_name()
                                               .to_string())),
                        ("version", Json::Int(e.model.version as i64)),
                        ("sample_size",
                         Json::Int(e.model.sample_size as i64)),
                        ("num_classes",
                         Json::Int(e.model.num_classes as i64)),
                        ("requests",
                         Json::Int(e.stats.requests
                                       .load(Ordering::Relaxed)
                                       as i64)),
                        ("samples",
                         Json::Int(e.stats.samples
                                       .load(Ordering::Relaxed)
                                       as i64)),
                    ])
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::saved_model;
    use super::super::wire::ErrorKind;
    use super::*;
    use crate::train::checkpoint;
    use crate::nn::{zoo, Network};

    #[test]
    fn registry_loads_by_recorded_spec_and_resolves() {
        let (p1, _) = saved_model("tinycnn", 3, "reg");
        let (p2, _) = saved_model("mlp1-mini", 4, "reg");
        let reg =
            ModelRegistry::from_paths(&format!("{p1}, {p2}")).unwrap();
        assert_eq!(reg.names(), vec!["mlp1-mini", "tinycnn"]);
        assert_eq!(reg.get("tinycnn").unwrap().input_shape, vec![1, 8, 8]);
        assert_eq!(reg.get("tinycnn").unwrap().version, 1);
        assert_eq!(reg.widest_sample_size(), 64);
        // explicit name resolves; omitted name is ambiguous with 2 models
        assert!(reg.resolve(Some("mlp1-mini")).is_ok());
        let err = reg.resolve(None).unwrap_err();
        assert_eq!(err.kind, ErrorKind::BadRequest);
        assert!(err.msg.contains("tinycnn"), "{err}");
        let err = reg.resolve(Some("nope")).unwrap_err();
        assert_eq!(err.kind, ErrorKind::UnknownModel);
        // duplicate spec rejected
        let (p3, _) = saved_model("tinycnn", 9, "dup");
        let err = ModelRegistry::from_paths(&format!("{p1},{p3}"))
            .unwrap_err();
        assert!(err.contains("already loaded"), "{err}");
        // corrupt checkpoint is an Err, not a panic
        let dir = std::env::temp_dir().join("nitro_serve_test");
        let bad = dir.join("bad.ckpt");
        std::fs::write(&bad, b"NITRO1\n\xff\xff\xff\xff").unwrap();
        assert!(ModelRegistry::from_paths(bad.to_str().unwrap()).is_err());
    }

    #[test]
    fn models_spec_aliases_and_rejects_malformed_entries() {
        let (p1, _) = saved_model("tinycnn", 5, "alias");
        let reg = ModelRegistry::from_spec(&format!("prod={p1}")).unwrap();
        assert_eq!(reg.names(), vec!["prod"]);
        let m = reg.resolve(Some("prod")).unwrap();
        assert_eq!(m.spec_name(), "tinycnn");
        // two aliases may serve the same checkpoint file
        let reg = ModelRegistry::from_spec(&format!("a={p1}, b={p1}"))
            .unwrap();
        assert_eq!(reg.names(), vec!["a", "b"]);
        // bare path falls back to the recorded spec name
        let reg = ModelRegistry::from_spec(&p1).unwrap();
        assert_eq!(reg.names(), vec!["tinycnn"]);
        assert!(ModelRegistry::from_spec("=x").is_err());
        assert!(ModelRegistry::from_spec("a=").is_err());
        assert!(ModelRegistry::from_spec("  ,, ").is_err());
    }

    #[test]
    fn reload_bumps_version_and_keeps_old_on_failure() {
        let (path, _) = saved_model("tinycnn", 21, "reload");
        let reg = ModelRegistry::new();
        reg.load(&path).unwrap();
        assert_eq!(reg.resolve(None).unwrap().version, 1);

        // overwrite with new weights -> version 2, new weights served
        let net2 = Network::new(zoo::get("tinycnn").unwrap(), 22);
        checkpoint::save(&net2, &path).unwrap();
        let results = reg.reload_all();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].1.as_ref().unwrap(), &2);
        let m2 = reg.resolve(None).unwrap();
        assert_eq!(m2.version, 2);

        // a failing reload reports the error and keeps version 2 live
        std::fs::write(&path, b"NITRO1\n\xff garbage").unwrap();
        let results = reg.reload_all();
        assert!(results[0].1.is_err(), "{results:?}");
        assert_eq!(reg.resolve(None).unwrap().version, 2);

        // a checkpoint of different geometry under the same name is
        // rejected too (the TCP line cap was sized off the old geometry)
        let other = Network::new(zoo::get("mlp1-mini").unwrap(), 1);
        checkpoint::save(&other, &path).unwrap();
        let results = reg.reload_all();
        let err = results[0].1.as_ref().unwrap_err();
        assert!(err.contains("geometry"), "{err}");
        assert_eq!(reg.resolve(None).unwrap().version, 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn stats_json_counts_requests_per_model() {
        let (path, _) = saved_model("tinycnn", 30, "stats");
        let reg = ModelRegistry::new();
        reg.load_as(Some("m"), &path).unwrap();
        reg.note_request("m", 3);
        reg.note_request("m", 1);
        reg.note_request("ghost", 9); // unknown names are ignored
        let j = reg.models_json();
        let rows = j.as_array().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].req("name").unwrap().as_str(), Some("m"));
        assert_eq!(rows[0].req("requests").unwrap().as_i64(), Some(2));
        assert_eq!(rows[0].req("samples").unwrap().as_i64(), Some(4));
        assert_eq!(rows[0].req("version").unwrap().as_i64(), Some(1));
        let _ = std::fs::remove_file(&path);
    }
}
