//! Wire protocol: versioned JSON-line envelopes and the typed serve
//! error that maps 1:1 onto machine-readable wire error codes.
//!
//! v1 request: `{"v": 1, "id": <any>, "model": "<name>"?,
//! "input": [<i32>...]}` (or `"op": "stats" | "reload"`); v1 response:
//! `{"v": 1, "id": ..., "model": ..., "model_version": N,
//! "logits": [[...]], "argmax": [...]}` or `{"v": 1, "id": ...,
//! "error": {"code": "...", "message": "..."}}`.
//!
//! v0 lines (no `"v"` key) are still accepted and answered in the v0
//! shape — string `"error"`, no `"v"`/`"model_version"` keys — with a
//! one-time deprecation note on stderr (see `handle_line`). Control ops
//! are v1-only: v0 never had them, so there is no legacy shape to honor.
//! The once-public v0 response builders (`ok_response_v0` /
//! `err_response_v0`) have been removed as announced; the legacy shapes
//! live only inside [`ok_response`] / [`err_response`]'s v0 dispatch
//! now, and the next step of the deprecation drops v0 acceptance too.

// A `no-panic` surface under `nitro lint`: in non-test code, prefer
// `Result` over unwrap/expect (enforced for clippy runs too).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use crate::tensor::ITensor;
use crate::util::jsonio::Json;

/// Current wire protocol version.
pub const WIRE_V1: i64 = 1;

/// Machine-readable error class; `code()` is the wire `error.code`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorKind {
    /// Malformed envelope, bad input array, unsupported version.
    BadRequest,
    /// The named model is not in the registry.
    UnknownModel,
    /// Admission control shed the request (queue over latency budget).
    Overloaded,
    /// Request exceeds the per-request sample limit.
    TooLarge,
    /// Server-side failure (executor gone); client retry is reasonable.
    Internal,
}

impl ErrorKind {
    pub fn code(self) -> &'static str {
        match self {
            ErrorKind::BadRequest => "bad_request",
            ErrorKind::UnknownModel => "unknown_model",
            ErrorKind::Overloaded => "overloaded",
            ErrorKind::TooLarge => "too_large",
            ErrorKind::Internal => "internal",
        }
    }
}

/// Typed serve-layer error: a wire error code plus human context.
/// Replaces the stringly-typed `Result<_, String>` the serve layer used
/// to thread around — shedding and protocol decisions dispatch on
/// `kind`, never on substring matching.
#[derive(Clone, Debug)]
pub struct ServeError {
    pub kind: ErrorKind,
    pub msg: String,
}

impl ServeError {
    pub fn bad_request(msg: impl Into<String>) -> ServeError {
        ServeError { kind: ErrorKind::BadRequest, msg: msg.into() }
    }

    pub fn unknown_model(msg: impl Into<String>) -> ServeError {
        ServeError { kind: ErrorKind::UnknownModel, msg: msg.into() }
    }

    pub fn overloaded(msg: impl Into<String>) -> ServeError {
        ServeError { kind: ErrorKind::Overloaded, msg: msg.into() }
    }

    pub fn too_large(msg: impl Into<String>) -> ServeError {
        ServeError { kind: ErrorKind::TooLarge, msg: msg.into() }
    }

    pub fn internal(msg: impl Into<String>) -> ServeError {
        ServeError { kind: ErrorKind::Internal, msg: msg.into() }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.kind.code(), self.msg)
    }
}

impl From<ServeError> for String {
    fn from(e: ServeError) -> String {
        e.to_string()
    }
}

/// A parsed request envelope.
pub struct Request {
    /// Protocol version the client spoke (0 or [`WIRE_V1`]); responses
    /// mirror it.
    pub v: i64,
    pub id: Json,
    pub op: Op,
}

pub enum Op {
    Predict { model: Option<String>, input: Vec<i32> },
    /// Per-model request counters + per-shard queue/latency state.
    Stats,
    /// Hot-reload every model from its checkpoint path.
    Reload,
}

/// Strict i32 vector for wire input: rejects non-integers and values
/// outside i32 range with an error (jsonio's `i32_vec` truncates with
/// `as i32` — fine for trusted golden vectors, silently wrong for
/// untrusted requests).
pub(crate) fn i32_vec_strict(j: &Json) -> Result<Vec<i32>, String> {
    j.as_array()
        .ok_or("not an array")?
        .iter()
        .map(|v| {
            let n = v
                .as_i64()
                .ok_or_else(|| "not an integer".to_string())?;
            i32::try_from(n)
                .map_err(|_| format!("value {n} does not fit i32"))
        })
        .collect()
}

/// Parse one wire line into a [`Request`]. On failure returns the
/// `(version, id, error)` triple the caller needs to answer in the right
/// shape — a parse error must still produce a well-formed response.
pub fn parse_request(line: &str)
                     -> Result<Request, (i64, Json, ServeError)> {
    let j = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            return Err((0, Json::Null,
                        ServeError::bad_request(format!("bad request: {e}"))));
        }
    };
    let id = j.get("id").cloned().unwrap_or(Json::Null);
    let v = match j.get("v") {
        None => 0,
        Some(Json::Int(n)) if *n == 0 || *n == WIRE_V1 => *n,
        Some(Json::Int(n)) => {
            return Err((WIRE_V1, id, ServeError::bad_request(format!(
                "unsupported protocol version {n} (this server speaks \
                 v0 and v{WIRE_V1})"))));
        }
        Some(_) => {
            return Err((WIRE_V1, id,
                        ServeError::bad_request("'v' must be an integer")));
        }
    };
    match j.get("op") {
        None => {}
        Some(Json::Str(op)) => match op.as_str() {
            "predict" => {}
            "stats" | "reload" if v < WIRE_V1 => {
                return Err((v, id, ServeError::bad_request(format!(
                    "op '{op}' requires a v{WIRE_V1} envelope \
                     (\"v\": {WIRE_V1})"))));
            }
            "stats" => return Ok(Request { v, id, op: Op::Stats }),
            "reload" => return Ok(Request { v, id, op: Op::Reload }),
            other => {
                return Err((v, id, ServeError::bad_request(format!(
                    "unknown op '{other}' (predict, stats, reload)"))));
            }
        },
        Some(_) => {
            return Err((v, id,
                        ServeError::bad_request("'op' must be a string")));
        }
    }
    let model = match j.get("model") {
        None => None,
        Some(Json::Str(s)) => Some(s.clone()),
        Some(_) => {
            return Err((v, id,
                        ServeError::bad_request("'model' must be a string")));
        }
    };
    let input = match j.get("input") {
        Some(val) => match i32_vec_strict(val) {
            Ok(x) => x,
            Err(e) => {
                return Err((v, id,
                            ServeError::bad_request(
                                format!("bad 'input': {e}"))));
            }
        },
        None => {
            return Err((v, id, ServeError::bad_request("missing 'input'")));
        }
    };
    Ok(Request { v, id, op: Op::Predict { model, input } })
}

/// `id`/`model`/`logits`/`argmax` — the fields common to both response
/// generations.
fn predict_fields(id: Json, model: &str, y: &ITensor)
                  -> Vec<(&'static str, Json)> {
    // nitro-lint: allow(no-panic) y is infer output: always [n, g]
    let g = y.shape[1];
    // nitro-lint: allow(no-panic) y is infer output: always [n, g]
    let mut logits = Vec::with_capacity(y.shape[0]);
    // nitro-lint: allow(no-panic) y is infer output: always [n, g]
    let mut argmax = Vec::with_capacity(y.shape[0]);
    for row in y.data.chunks(g) {
        logits.push(Json::Array(
            row.iter().map(|&v| Json::Int(v as i64)).collect(),
        ));
        let mut best = 0usize;
        for j in 1..g {
            // nitro-lint: allow(no-panic) j, best < g == row.len()
            if row[j] > row[best] {
                best = j;
            }
        }
        argmax.push(Json::Int(best as i64));
    }
    vec![
        ("id", id),
        ("model", Json::Str(model.to_string())),
        ("logits", Json::Array(logits)),
        ("argmax", Json::Array(argmax)),
    ]
}

/// Success response for `(n, num_classes)` logits, in the shape of the
/// protocol version the request used: v1 adds `"v"` and the served
/// `"model_version"`; v0 is byte-compatible with the pre-versioned
/// protocol.
pub fn ok_response(v: i64, id: Json, model: &str, model_version: u64,
                   y: &ITensor) -> Json {
    if v >= WIRE_V1 {
        let mut fields = predict_fields(id, model, y);
        fields.push(("v", Json::Int(WIRE_V1)));
        fields.push(("model_version", Json::Int(model_version as i64)));
        Json::obj(fields)
    } else {
        // v0 success shape: no "v", no "model_version" — answered only
        // to bare legacy lines (no "v" key in the request)
        Json::obj(predict_fields(id, model, y))
    }
}

/// Error response in the request's protocol shape: v1 carries a
/// structured `{"code", "message"}` object, v0 the legacy string (with
/// the code as a `"code: "` prefix).
pub fn err_response(v: i64, id: Json, e: &ServeError) -> Json {
    if v >= WIRE_V1 {
        Json::obj(vec![
            ("v", Json::Int(WIRE_V1)),
            ("id", id),
            ("error", Json::obj(vec![
                ("code", Json::Str(e.kind.code().to_string())),
                ("message", Json::Str(e.msg.clone())),
            ])),
        ])
    } else {
        // v0 error shape: a flat "error" string with the machine code
        // as a "code: " prefix instead of v1's structured object
        Json::obj(vec![("id", id), ("error", Json::Str(e.to_string()))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v_detection_and_version_negotiation() {
        // no "v" key = v0; explicit 0 and 1 accepted; others rejected
        let r = parse_request(r#"{"id": 1, "input": [3]}"#).unwrap();
        assert_eq!(r.v, 0);
        let r = parse_request(r#"{"v": 0, "id": 1, "input": [3]}"#).unwrap();
        assert_eq!(r.v, 0);
        let r = parse_request(r#"{"v": 1, "id": 1, "input": [3]}"#).unwrap();
        assert_eq!(r.v, WIRE_V1);
        match r.op {
            Op::Predict { model, input } => {
                assert_eq!(model, None);
                assert_eq!(input, vec![3]);
            }
            _ => panic!("not a predict"),
        }
        let (v, id, e) =
            parse_request(r#"{"v": 2, "id": 9, "input": [1]}"#).unwrap_err();
        // future versions are answered in v1 shape, id echoed
        assert_eq!(v, WIRE_V1);
        assert_eq!(id.as_i64(), Some(9));
        assert_eq!(e.kind, ErrorKind::BadRequest);
        let (_, _, e) =
            parse_request(r#"{"v": "x", "input": [1]}"#).unwrap_err();
        assert_eq!(e.kind, ErrorKind::BadRequest);
    }

    #[test]
    fn control_ops_are_v1_only() {
        assert!(matches!(
            parse_request(r#"{"v": 1, "op": "stats"}"#).unwrap().op,
            Op::Stats
        ));
        assert!(matches!(
            parse_request(r#"{"v": 1, "op": "reload"}"#).unwrap().op,
            Op::Reload
        ));
        // explicit "op": "predict" is allowed and still needs input
        let (_, _, e) =
            parse_request(r#"{"v": 1, "op": "predict"}"#).unwrap_err();
        assert!(e.msg.contains("input"), "{e}");
        let (v, _, e) = parse_request(r#"{"op": "stats"}"#).unwrap_err();
        assert_eq!(v, 0);
        assert!(e.msg.contains("v\": 1"), "{e}");
        let (_, _, e) =
            parse_request(r#"{"v": 1, "op": "frob"}"#).unwrap_err();
        assert!(e.msg.contains("unknown op"), "{e}");
    }

    #[test]
    fn response_shapes_match_protocol_version() {
        let y = ITensor::from_vec(&[1, 3], vec![5, 9, 2]);
        let v0 = ok_response(0, Json::Int(7), "m", 3, &y);
        assert!(v0.get("v").is_none(), "v0 response must not carry 'v'");
        assert!(v0.get("model_version").is_none());
        assert_eq!(v0.req("argmax").unwrap().as_array().unwrap()[0]
                       .as_i64(),
                   Some(1));
        let v1 = ok_response(WIRE_V1, Json::Int(7), "m", 3, &y);
        assert_eq!(v1.req("v").unwrap().as_i64(), Some(WIRE_V1));
        assert_eq!(v1.req("model_version").unwrap().as_i64(), Some(3));
        assert_eq!(v1.req("logits").unwrap(), v0.req("logits").unwrap());

        let e = ServeError::overloaded("queue full");
        let e0 = err_response(0, Json::Null, &e);
        assert_eq!(e0.req("error").unwrap().as_str(),
                   Some("overloaded: queue full"));
        let e1 = err_response(WIRE_V1, Json::Null, &e);
        assert_eq!(e1.req("error").unwrap().req("code").unwrap().as_str(),
                   Some("overloaded"));
        assert_eq!(e1.req("error").unwrap().req("message").unwrap()
                       .as_str(),
                   Some("queue full"));
    }

    #[test]
    fn error_kinds_map_to_stable_codes() {
        for (e, code) in [
            (ServeError::bad_request("x"), "bad_request"),
            (ServeError::unknown_model("x"), "unknown_model"),
            (ServeError::overloaded("x"), "overloaded"),
            (ServeError::too_large("x"), "too_large"),
            (ServeError::internal("x"), "internal"),
        ] {
            assert_eq!(e.kind.code(), code);
            assert!(e.to_string().starts_with(code));
        }
    }

    #[test]
    fn strict_input_rejects_overflow_and_non_ints() {
        let (_, _, e) = parse_request(
            r#"{"v": 1, "input": [2147483648]}"#).unwrap_err();
        assert!(e.msg.contains("does not fit i32"), "{e}");
        let (_, _, e) =
            parse_request(r#"{"v": 1, "input": [1.5]}"#).unwrap_err();
        assert_eq!(e.kind, ErrorKind::BadRequest);
        let (_, _, e) =
            parse_request(r#"{"v": 1, "input": "xyz"}"#).unwrap_err();
        assert!(e.msg.contains("not an array"), "{e}");
        let (_, _, e) =
            parse_request(r#"{"v": 1, "model": 42, "input": [1]}"#)
                .unwrap_err();
        assert!(e.msg.contains("'model'"), "{e}");
    }
}
