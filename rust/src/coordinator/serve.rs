//! Integer-only inference serving: model registry, dynamic micro-batcher
//! and the `nitro serve` / `nitro predict` backends.
//!
//! The deployment story of the paper (App. E.3) is that a `NITRO1`
//! checkpoint *is* the deployed model — no quantization pass between
//! training and inference. This module turns that into a serving
//! subsystem:
//!
//! * [`ModelRegistry`] loads checkpoints by path, reconstructs each
//!   [`Network`] from the spec name recorded in the header
//!   (`checkpoint::load_network`), validates shapes, and keys the models
//!   by spec name.
//! * [`MicroBatcher`] owns a single executor thread that coalesces
//!   concurrent predict requests into micro-batches and runs them through
//!   the grad-free fused forward path ([`Network::infer_into`]) with one
//!   long-lived [`InferScratch`], so steady-state serving performs no
//!   forward-path allocation. The kernels inside fan out on the
//!   persistent worker pool (`util::par`).
//! * **Determinism contract:** per-sample logits are a function of the
//!   checkpoint and the sample alone — every kernel is row/sample
//!   independent — so results are bit-identical regardless of micro-batch
//!   composition, coalescing timing, and `NITRO_WORKERS`. CI asserts
//!   this end to end.
//!
//! Wire protocol (`nitro serve`): JSON lines. Request
//! `{"id": <any>, "model": "<name>"?, "input": [<i32>...]}` where
//! `input` holds one or more flattened samples; response
//! `{"id": ..., "model": ..., "logits": [[...]], "argmax": [...]}` or
//! `{"id": ..., "error": "..."}`. The same handler backs stdin/stdout
//! and the TCP listener (`--listen`).

use crate::nn::{InferScratch, Network};
use crate::tensor::ITensor;
use crate::train::checkpoint;
use crate::util::jsonio::Json;
use std::collections::BTreeMap;
use std::io::{BufRead, Write};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Bump when a `BENCH_serve.json` key changes meaning or disappears;
/// adding keys is allowed without a bump.
pub const SCHEMA_VERSION: i64 = 1;

// ---------------------------------------------------------------------------
// model registry
// ---------------------------------------------------------------------------

/// A checkpoint loaded for serving, with its derived geometry.
pub struct ServedModel {
    /// Spec name recorded in the checkpoint (the registry key).
    pub name: String,
    /// Checkpoint path it was loaded from.
    pub path: String,
    /// Per-sample input shape: `(C, H, W)` or `(F,)`.
    pub input_shape: Vec<usize>,
    /// Flattened ints per sample.
    pub sample_size: usize,
    pub num_classes: usize,
    net: Network,
}

impl ServedModel {
    /// Load a checkpoint, reconstructing the network from its recorded
    /// spec. Every malformed input is an `Err`, never a panic.
    pub fn load(path: &str) -> Result<ServedModel, String> {
        let net = checkpoint::load_network(path)?;
        Ok(ServedModel::from_network(net, path))
    }

    /// Wrap an in-memory network (tests and the serve bench).
    pub fn from_network(net: Network, path: &str) -> ServedModel {
        ServedModel {
            name: net.spec.name.clone(),
            path: path.to_string(),
            input_shape: net.spec.input_shape.clone(),
            sample_size: net.spec.input_shape.iter().product(),
            num_classes: net.spec.num_classes,
            net,
        }
    }

    /// Batch shape for `n` samples of this model.
    fn batch_shape(&self, n: usize) -> Vec<usize> {
        let mut shape = vec![n];
        shape.extend(&self.input_shape);
        shape
    }

    /// Grad-free inference over an owned flat sample buffer (`n`
    /// samples; `flat.len()` must be `n * sample_size`), writing
    /// `(n, num_classes)` logits into `out`. Takes the buffer by value —
    /// no input copy is made (the micro-batcher's hot path instead
    /// gathers into its own reused buffer, see `run_group`).
    pub fn infer_into(&self, flat: Vec<i32>, n: usize,
                      scratch: &mut InferScratch, out: &mut ITensor) {
        let x = ITensor::from_vec(&self.batch_shape(n), flat);
        self.net.infer_into(&x, scratch, out);
    }

    /// Reference (unfused) inference — parity checks.
    pub fn infer_reference(&self, x: &ITensor) -> ITensor {
        self.net.infer(x)
    }
}

/// Immutable set of served models, keyed by spec name. Built once at
/// startup, then shared (`Arc`) across connection threads and the
/// executor.
#[derive(Default)]
pub struct ModelRegistry {
    models: BTreeMap<String, Arc<ServedModel>>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Load a checkpoint into the registry. Two checkpoints of the same
    /// spec would shadow each other, so that is an error.
    pub fn load(&mut self, path: &str) -> Result<Arc<ServedModel>, String> {
        let m = Arc::new(ServedModel::load(path)?);
        if let Some(prev) = self.models.get(&m.name) {
            return Err(format!(
                "model '{}' already loaded from {} (also in {path})",
                m.name, prev.path
            ));
        }
        self.models.insert(m.name.clone(), m.clone());
        Ok(m)
    }

    /// Build a registry from a comma-separated checkpoint path list.
    pub fn from_paths(paths: &str) -> Result<ModelRegistry, String> {
        let mut reg = ModelRegistry::new();
        for p in paths.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            reg.load(p)?;
        }
        if reg.models.is_empty() {
            return Err("no checkpoint paths given".into());
        }
        Ok(reg)
    }

    pub fn get(&self, name: &str) -> Option<Arc<ServedModel>> {
        self.models.get(name).cloned()
    }

    pub fn names(&self) -> Vec<String> {
        self.models.keys().cloned().collect()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }

    /// Resolve a request's model field: an explicit name must exist; an
    /// omitted name is allowed only when exactly one model is served.
    pub fn resolve(&self, name: Option<&str>)
                   -> Result<Arc<ServedModel>, String> {
        match name {
            Some(n) => self.get(n).ok_or_else(|| {
                format!("unknown model '{n}' (serving: {})",
                        self.names().join(", "))
            }),
            None if self.models.len() == 1 => {
                Ok(self.models.values().next().expect("len 1").clone())
            }
            None => Err(format!(
                "request must name a model (serving: {})",
                self.names().join(", ")
            )),
        }
    }
}

// ---------------------------------------------------------------------------
// dynamic micro-batcher
// ---------------------------------------------------------------------------

/// Micro-batching knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Sample target per executed micro-batch. The coalescing loop stops
    /// adding requests once this is reached, so an executed batch can
    /// exceed it by at most one request (bounded by
    /// `max_batch - 1 + max_request_samples`).
    pub max_batch: usize,
    /// How long the executor waits for more requests to coalesce after
    /// the first one arrives. 0 = batch only what is already queued.
    pub max_wait_us: u64,
    /// Samples allowed in a single request; larger requests are rejected
    /// with an error response. Bounds the executor's working-set size
    /// against a hostile or buggy client — requests are all-or-nothing
    /// (one response each), so an unbounded request would otherwise force
    /// an unbounded fused forward.
    pub max_request_samples: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_batch: 64, max_wait_us: 200,
                      max_request_samples: 4096 }
    }
}

struct PredictReq {
    model: Arc<ServedModel>,
    x: Vec<i32>,
    nsamples: usize,
    resp: mpsc::SyncSender<Result<ITensor, String>>,
}

/// Handle for submitting predict requests; clone one per connection
/// thread. [`Self::predict`] blocks until the micro-batch containing the
/// request has executed.
#[derive(Clone)]
pub struct BatchClient {
    tx: mpsc::Sender<PredictReq>,
    registry: Arc<ModelRegistry>,
    max_request_samples: usize,
}

impl BatchClient {
    /// Score `x` (one or more flattened samples) on `model` (`None` =
    /// the registry's single model). Returns the resolved model and the
    /// `(n, num_classes)` logits.
    pub fn predict(&self, model: Option<&str>, x: Vec<i32>)
                   -> Result<(Arc<ServedModel>, ITensor), String> {
        let m = self.registry.resolve(model)?;
        let ss = m.sample_size;
        if x.is_empty() || x.len() % ss != 0 {
            return Err(format!(
                "input length {} is not a positive multiple of '{}' \
                 sample size {ss}",
                x.len(),
                m.name
            ));
        }
        let nsamples = x.len() / ss;
        if nsamples > self.max_request_samples {
            return Err(format!(
                "request has {nsamples} samples, above the per-request \
                 limit {} — split it into smaller requests",
                self.max_request_samples
            ));
        }
        let (rtx, rrx) = mpsc::sync_channel(1);
        self.tx
            .send(PredictReq { model: m.clone(), x, nsamples, resp: rtx })
            .map_err(|_| "serve executor has shut down".to_string())?;
        let y = rrx
            .recv()
            .map_err(|_| "serve executor dropped the request".to_string())??;
        Ok((m, y))
    }
}

/// The dynamic micro-batcher: one executor thread drains the request
/// queue, coalesces up to `max_batch` samples (waiting at most
/// `max_wait_us` once work is pending), groups them by model, and runs
/// each group as a single fused forward on the worker-pool kernels.
pub struct MicroBatcher {
    tx: Option<mpsc::Sender<PredictReq>>,
    registry: Arc<ModelRegistry>,
    cfg: ServeConfig,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MicroBatcher {
    pub fn start(registry: Arc<ModelRegistry>, cfg: ServeConfig)
                 -> MicroBatcher {
        let (tx, rx) = mpsc::channel::<PredictReq>();
        let handle = std::thread::Builder::new()
            .name("nitro-serve-exec".into())
            .spawn(move || executor(rx, cfg))
            .expect("spawn serve executor");
        MicroBatcher { tx: Some(tx), registry, cfg, handle: Some(handle) }
    }

    /// A request handle for this batcher. Clients hold a sender into the
    /// executor queue, so every client must be dropped before (or
    /// strictly inside the lifetime of) the `MicroBatcher` — its `Drop`
    /// joins the executor, which exits only once all senders are gone.
    pub fn client(&self) -> BatchClient {
        BatchClient {
            tx: self.tx.as_ref().expect("running").clone(),
            registry: self.registry.clone(),
            max_request_samples: self.cfg.max_request_samples.max(1),
        }
    }
}

impl Drop for MicroBatcher {
    fn drop(&mut self) {
        // closing the channel ends the executor loop; join so in-flight
        // responses are delivered before the batcher disappears
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn executor(rx: mpsc::Receiver<PredictReq>, cfg: ServeConfig) {
    let mut scratch = InferScratch::new();
    let mut xbuf = ITensor::empty();
    let mut out = ITensor::empty();
    let max_batch = cfg.max_batch.max(1);
    while let Ok(first) = rx.recv() {
        let mut pending = vec![first];
        let mut total = pending[0].nsamples;
        // coalescing window: take whatever is queued, then wait out the
        // remainder of the window for stragglers
        let deadline = Instant::now()
            + Duration::from_micros(cfg.max_wait_us);
        while total < max_batch {
            let now = Instant::now();
            let r = if now >= deadline {
                match rx.try_recv() {
                    Ok(r) => r,
                    Err(_) => break,
                }
            } else {
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => r,
                    Err(_) => break,
                }
            };
            total += r.nsamples;
            pending.push(r);
        }
        // group by model, preserving arrival order within each group (the
        // common case is a single group — one served model)
        while !pending.is_empty() {
            let name = pending[0].model.name.clone();
            let group: Vec<PredictReq> = {
                let (g, rest): (Vec<_>, Vec<_>) = pending
                    .into_iter()
                    .partition(|r| r.model.name == name);
                pending = rest;
                g
            };
            run_group(group, &mut scratch, &mut xbuf, &mut out);
        }
    }
}

/// Execute one same-model group as a single fused forward and scatter the
/// per-request logit rows back to their response channels.
fn run_group(group: Vec<PredictReq>, scratch: &mut InferScratch,
             xbuf: &mut ITensor, out: &mut ITensor) {
    let model = group[0].model.clone();
    let n: usize = group.iter().map(|r| r.nsamples).sum();
    xbuf.data.clear();
    for r in &group {
        xbuf.data.extend_from_slice(&r.x);
    }
    xbuf.shape.clear();
    xbuf.shape.push(n);
    xbuf.shape.extend(&model.input_shape);
    model.net.infer_into(xbuf, scratch, out);
    let g = model.num_classes;
    let mut row = 0usize;
    for r in group {
        let y = ITensor::from_vec(
            &[r.nsamples, g],
            out.data[row * g..(row + r.nsamples) * g].to_vec(),
        );
        row += r.nsamples;
        let _ = r.resp.send(Ok(y));
    }
}

// ---------------------------------------------------------------------------
// JSON-lines protocol
// ---------------------------------------------------------------------------

fn err_json(id: Json, msg: String) -> Json {
    Json::obj(vec![("id", id), ("error", Json::Str(msg))])
}

/// Strict i32 vector for wire input: rejects non-integers and values
/// outside i32 range with an error (jsonio's `i32_vec` truncates with
/// `as i32` — fine for trusted golden vectors, silently wrong for
/// untrusted requests).
fn i32_vec_strict(j: &Json) -> Result<Vec<i32>, String> {
    j.as_array()
        .ok_or("not an array")?
        .iter()
        .map(|v| {
            let n = v
                .as_i64()
                .ok_or_else(|| "not an integer".to_string())?;
            i32::try_from(n)
                .map_err(|_| format!("value {n} does not fit i32"))
        })
        .collect()
}

/// Response for `(n, num_classes)` logits.
fn response_json(id: Json, model: &str, y: &ITensor) -> Json {
    let g = y.shape[1];
    let mut logits = Vec::with_capacity(y.shape[0]);
    let mut argmax = Vec::with_capacity(y.shape[0]);
    for row in y.data.chunks(g) {
        logits.push(Json::Array(
            row.iter().map(|&v| Json::Int(v as i64)).collect(),
        ));
        let mut best = 0usize;
        for j in 1..g {
            if row[j] > row[best] {
                best = j;
            }
        }
        argmax.push(Json::Int(best as i64));
    }
    Json::obj(vec![
        ("id", id),
        ("model", Json::Str(model.to_string())),
        ("logits", Json::Array(logits)),
        ("argmax", Json::Array(argmax)),
    ])
}

/// Handle one JSON-line request through the micro-batcher. Every failure
/// mode is a JSON error response — a malformed line must never take the
/// server down.
pub fn handle_line(line: &str, client: &BatchClient) -> Json {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return err_json(Json::Null, format!("bad request: {e}")),
    };
    let id = req.get("id").cloned().unwrap_or(Json::Null);
    let model = match req.get("model") {
        None => None,
        Some(Json::Str(s)) => Some(s.clone()),
        Some(_) => {
            return err_json(id, "'model' must be a string".to_string())
        }
    };
    let input = match req.get("input") {
        Some(v) => match i32_vec_strict(v) {
            Ok(x) => x,
            Err(e) => {
                return err_json(id, format!("bad 'input': {e}"));
            }
        },
        None => return err_json(id, "missing 'input'".to_string()),
    };
    match client.predict(model.as_deref(), input) {
        Ok((m, y)) => response_json(id, &m.name, &y),
        Err(e) => err_json(id, e),
    }
}

/// Serve JSON lines over stdin/stdout until EOF.
pub fn serve_stdio(registry: ModelRegistry, cfg: ServeConfig)
                   -> Result<(), String> {
    let registry = Arc::new(registry);
    eprintln!("nitro serve: models [{}], max-batch {}, wait {}us",
              registry.names().join(", "), cfg.max_batch, cfg.max_wait_us);
    let mb = MicroBatcher::start(registry, cfg);
    let client = mb.client();
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    for line in stdin.lock().lines() {
        let line = line.map_err(|e| format!("stdin: {e}"))?;
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle_line(&line, &client);
        let mut out = stdout.lock();
        out.write_all(resp.dump().as_bytes())
            .and_then(|_| out.write_all(b"\n"))
            .and_then(|_| out.flush())
            .map_err(|e| format!("stdout: {e}"))?;
    }
    Ok(())
}

/// Largest wire line a TCP connection may send: the biggest legitimate
/// request is `max_request_samples` samples of the widest served model,
/// ~13 bytes per serialized int, plus envelope slack. Anything longer is
/// answered with an error and the connection closed **before** the line
/// is buffered whole — a client streaming an endless non-newline byte
/// stream must not grow server memory without bound.
fn max_line_bytes(registry: &ModelRegistry, cfg: &ServeConfig) -> u64 {
    let widest = registry
        .models
        .values()
        .map(|m| m.sample_size)
        .max()
        .unwrap_or(1);
    (widest as u64) * (cfg.max_request_samples.max(1) as u64) * 13 + 4096
}

/// Serve JSON lines over TCP: one thread per connection, all feeding the
/// shared micro-batcher (concurrent clients coalesce into one batch).
pub fn serve_tcp(registry: ModelRegistry, cfg: ServeConfig, addr: &str)
                 -> Result<(), String> {
    let registry = Arc::new(registry);
    let listener = std::net::TcpListener::bind(addr)
        .map_err(|e| format!("bind {addr}: {e}"))?;
    eprintln!("nitro serve: listening on {addr}, models [{}]",
              registry.names().join(", "));
    let line_cap = max_line_bytes(&registry, &cfg);
    let mb = MicroBatcher::start(registry, cfg);
    for conn in listener.incoming() {
        let stream = match conn {
            Ok(s) => s,
            Err(e) => {
                eprintln!("accept: {e}");
                continue;
            }
        };
        let client = mb.client();
        // fallible spawn: exhausting the OS thread limit (e.g. a client
        // holding thousands of connections open) must drop that
        // connection, not panic the accept loop and take the server down
        let spawned = std::thread::Builder::new()
            .name("nitro-serve-conn".into())
            .spawn(move || {
            let peer = stream
                .peer_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "?".into());
            let mut reader =
                std::io::BufReader::new(match stream.try_clone() {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("{peer}: clone: {e}");
                        return;
                    }
                });
            let mut writer = stream;
            let mut buf = Vec::new();
            loop {
                // capped read: at most line_cap + 1 bytes are ever
                // buffered for one line, newline or not
                buf.clear();
                use std::io::Read;
                let n = match (&mut reader)
                    .take(line_cap + 1)
                    .read_until(b'\n', &mut buf)
                {
                    Ok(0) => break,
                    Ok(n) => n,
                    Err(_) => break,
                };
                if n as u64 > line_cap {
                    // oversized line: answer and drop the connection —
                    // there is no way to resync to the next request
                    // without buffering the rest of the flood
                    let resp = err_json(
                        Json::Null,
                        format!("request line exceeds {line_cap} bytes"),
                    );
                    let _ = writer.write_all(resp.dump().as_bytes());
                    let _ = writer.write_all(b"\n");
                    break;
                }
                let line = String::from_utf8_lossy(&buf);
                let line = line.trim_end_matches(['\n', '\r']);
                if line.trim().is_empty() {
                    continue;
                }
                let resp = handle_line(line, &client);
                if writer
                    .write_all(resp.dump().as_bytes())
                    .and_then(|_| writer.write_all(b"\n"))
                    .is_err()
                {
                    break;
                }
            }
        });
        if let Err(e) = spawned {
            eprintln!("connection dropped: spawn handler thread: {e}");
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// one-shot prediction (`nitro predict`)
// ---------------------------------------------------------------------------

/// Parse a predict input document: a flat int array, an array of
/// per-sample arrays, or an object with an `inputs` field holding either.
fn parse_inputs(j: &Json, sample_size: usize) -> Result<Vec<i32>, String> {
    if let Some(inner) = j.get("inputs") {
        return parse_inputs(inner, sample_size);
    }
    let arr = j
        .as_array()
        .ok_or("input must be an array (flat or one array per sample)")?;
    match arr.first() {
        None => Err("input is empty".into()),
        Some(Json::Array(_)) => {
            let mut flat = Vec::new();
            for (i, row) in arr.iter().enumerate() {
                let r = i32_vec_strict(row)
                    .map_err(|e| format!("sample {i}: {e}"))?;
                if r.len() != sample_size {
                    return Err(format!(
                        "sample {i}: {} values, expected {sample_size}",
                        r.len()
                    ));
                }
                flat.extend(r);
            }
            Ok(flat)
        }
        Some(_) => {
            let flat = i32_vec_strict(j)?;
            if flat.is_empty() || flat.len() % sample_size != 0 {
                return Err(format!(
                    "flat input length {} is not a positive multiple of \
                     sample size {sample_size}",
                    flat.len()
                ));
            }
            Ok(flat)
        }
    }
}

/// One-shot batch scoring: load a checkpoint, score the input document
/// (`-` = stdin), return the response JSON. Runs inline on the caller —
/// under `NITRO_WORKERS=1` no thread is ever spawned, the fully
/// deterministic mode CI compares against multi-worker runs.
pub fn predict_once(ckpt: &str, input_src: &str) -> Result<Json, String> {
    let model = ServedModel::load(ckpt)?;
    let text = if input_src == "-" {
        let mut s = String::new();
        std::io::Read::read_to_string(&mut std::io::stdin(), &mut s)
            .map_err(|e| format!("stdin: {e}"))?;
        s
    } else {
        std::fs::read_to_string(input_src)
            .map_err(|e| format!("read {input_src}: {e}"))?
    };
    let j = Json::parse(&text).map_err(|e| format!("{input_src}: {e}"))?;
    let flat = parse_inputs(&j, model.sample_size)?;
    let n = flat.len() / model.sample_size;
    let mut scratch = InferScratch::new();
    let mut out = ITensor::empty();
    model.infer_into(flat, n, &mut scratch, &mut out);
    Ok(response_json(Json::Null, &model.name, &out))
}

// ---------------------------------------------------------------------------
// serve throughput bench (BENCH_serve.json)
// ---------------------------------------------------------------------------

/// Requests/sec and latency percentiles vs micro-batch size, through the
/// real micro-batcher, written to a schema-versioned `BENCH_serve.json`.
/// Also hard-checks the serving identities (fused path vs reference,
/// checkpoint round-trip) — mismatches are pushed into `failures`, which
/// `bench-kernels` turns into a non-zero exit.
pub fn bench_serve(quick: bool, budget_s: f64, out_path: &str,
                   failures: &mut Vec<String>) -> Result<Json, String> {
    use crate::nn::zoo;
    use crate::util::rng::Pcg32;

    let spec = zoo::get("tinycnn").expect("tinycnn preset");
    let net = Network::new(spec.clone(), 7);

    // serving identity: a round-tripped checkpoint must serve logits
    // bit-identical to the in-memory network on both forward paths
    let dir = std::env::temp_dir().join("nitro_serve_bench");
    std::fs::create_dir_all(&dir)
        .map_err(|e| format!("mkdir {}: {e}", dir.display()))?;
    let ckpt = dir.join(format!("tinycnn-{}.ckpt", std::process::id()));
    let ckpt_s = ckpt.to_str().expect("utf8 temp path");
    checkpoint::save(&net, ckpt_s)?;
    // the model is in memory once loaded; remove the temp file before
    // any fallible step so an early `?` return cannot leak it
    let loaded = ServedModel::load(ckpt_s);
    let _ = std::fs::remove_file(&ckpt);
    let model = loaded?;
    let mut rng = Pcg32::new(17);
    let probe_n = 5usize;
    let flat: Vec<i32> = (0..probe_n * model.sample_size)
        .map(|_| rng.range_i32(-127, 127))
        .collect();
    let x = ITensor::from_vec(&model.batch_shape(probe_n), flat.clone());
    let reference = net.infer(&x);
    let mut scratch = InferScratch::new();
    let mut out = ITensor::empty();
    model.infer_into(flat, probe_n, &mut scratch, &mut out);
    if out != reference {
        failures.push("serve: ckpt-roundtrip fused infer".to_string());
    }
    if model.infer_reference(&x) != reference {
        failures.push("serve: ckpt-roundtrip reference infer".to_string());
    }

    let registry = Arc::new({
        let mut r = ModelRegistry::new();
        r.models.insert(model.name.clone(), Arc::new(model));
        r
    });
    let sample_size = registry.resolve(None)?.sample_size;
    let batch_sizes: &[usize] =
        if quick { &[1, 2, 8] } else { &[1, 4, 16, 64] };
    let mut rows = Vec::new();
    println!("serve_throughput (tinycnn, through the micro-batcher):");
    for &bs in batch_sizes {
        let mb = MicroBatcher::start(
            registry.clone(),
            ServeConfig {
                max_batch: bs.max(1),
                max_wait_us: 0,
                ..Default::default()
            },
        );
        let client = mb.client();
        let req: Vec<i32> = (0..bs * sample_size)
            .map(|_| rng.range_i32(-127, 127))
            .collect();
        // warm the scratch buffers so steady state is measured
        client.predict(None, req.clone())?;
        let budget = Duration::from_secs_f64(budget_s.max(1e-3));
        let t0 = Instant::now();
        let mut lat_ns: Vec<u64> = Vec::new();
        while t0.elapsed() < budget && lat_ns.len() < 10_000 {
            let t = Instant::now();
            let (_, y) = client.predict(None, req.clone())?;
            lat_ns.push(t.elapsed().as_nanos() as u64);
            std::hint::black_box(y);
        }
        let total_s = t0.elapsed().as_secs_f64();
        lat_ns.sort_unstable();
        let q = |p: f64| {
            lat_ns[(p * (lat_ns.len() - 1) as f64) as usize] as f64
        };
        let rps = lat_ns.len() as f64 / total_s.max(1e-9);
        println!(
            "  batch {bs:>3}: {:>9.1} req/s {:>10.1} samples/s  \
             p50 {:>9.0} ns  p99 {:>9.0} ns  ({} reqs)",
            rps,
            rps * bs as f64,
            q(0.5),
            q(0.99),
            lat_ns.len()
        );
        rows.push(Json::obj(vec![
            ("batch", Json::Int(bs as i64)),
            ("requests", Json::Int(lat_ns.len() as i64)),
            ("requests_per_sec", Json::Float(rps)),
            ("samples_per_sec", Json::Float(rps * bs as f64)),
            ("p50_ns", Json::Float(q(0.5))),
            ("p99_ns", Json::Float(q(0.99))),
            ("mean_ns", Json::Float(
                lat_ns.iter().sum::<u64>() as f64 / lat_ns.len() as f64,
            )),
        ]));
    }
    let record = Json::obj(vec![
        ("schema_version", Json::Int(SCHEMA_VERSION)),
        ("experiment", Json::Str("serve".to_string())),
        ("preset", Json::Str("tinycnn".to_string())),
        ("workers",
         Json::Int(crate::util::par::default_workers() as i64)),
        ("quick", Json::Bool(quick)),
        ("budget_s", Json::Float(budget_s)),
        ("serve_throughput", Json::Array(rows)),
        ("bitexact",
         Json::Bool(!failures.iter().any(|f| f.starts_with("serve:")))),
    ]);
    std::fs::write(out_path, record.pretty())
        .map_err(|e| format!("write {out_path}: {e}"))?;
    println!("-> {out_path}");
    Ok(record)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;
    use crate::util::rng::Pcg32;

    fn saved_model(preset: &str, seed: u64, tag: &str) -> (String, Network) {
        let dir = std::env::temp_dir().join("nitro_serve_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{preset}-{tag}-{}.ckpt",
                                    std::process::id()));
        let net = Network::new(zoo::get(preset).unwrap(), seed);
        checkpoint::save(&net, path.to_str().unwrap()).unwrap();
        (path.to_str().unwrap().to_string(), net)
    }

    fn rand_samples(model: &ServedModel, n: usize, rng: &mut Pcg32)
                    -> Vec<i32> {
        (0..n * model.sample_size).map(|_| rng.range_i32(-127, 127))
            .collect()
    }

    #[test]
    fn registry_loads_by_recorded_spec_and_resolves() {
        let (p1, _) = saved_model("tinycnn", 3, "reg");
        let (p2, _) = saved_model("mlp1-mini", 4, "reg");
        let reg =
            ModelRegistry::from_paths(&format!("{p1}, {p2}")).unwrap();
        assert_eq!(reg.names(), vec!["mlp1-mini", "tinycnn"]);
        assert_eq!(reg.get("tinycnn").unwrap().input_shape, vec![1, 8, 8]);
        // explicit name resolves; omitted name is ambiguous with 2 models
        assert!(reg.resolve(Some("mlp1-mini")).is_ok());
        let err = reg.resolve(None).unwrap_err();
        assert!(err.contains("tinycnn"), "{err}");
        let err = reg.resolve(Some("nope")).unwrap_err();
        assert!(err.contains("unknown model"), "{err}");
        // duplicate spec rejected
        let (p3, _) = saved_model("tinycnn", 9, "dup");
        let err = ModelRegistry::from_paths(&format!("{p1},{p3}"))
            .unwrap_err();
        assert!(err.contains("already loaded"), "{err}");
        // corrupt checkpoint is an Err, not a panic
        let dir = std::env::temp_dir().join("nitro_serve_test");
        let bad = dir.join("bad.ckpt");
        std::fs::write(&bad, b"NITRO1\n\xff\xff\xff\xff").unwrap();
        assert!(ModelRegistry::from_paths(bad.to_str().unwrap()).is_err());
    }

    #[test]
    fn micro_batched_logits_equal_reference_any_composition() {
        // the serving determinism contract: logits are bit-identical to
        // Network::infer regardless of how requests coalesce into batches
        let (path, net) = saved_model("tinycnn", 5, "comp");
        let reg =
            Arc::new(ModelRegistry::from_paths(&path).unwrap());
        let model = reg.resolve(None).unwrap();
        let mut rng = Pcg32::new(31);
        let flat = rand_samples(&model, 7, &mut rng);
        let x = ITensor::from_vec(&model.batch_shape(7), flat.clone());
        let want = net.infer(&x);
        let g = model.num_classes;
        for (max_batch, wait) in [(1usize, 0u64), (3, 0), (64, 100)] {
            let mb = MicroBatcher::start(
                reg.clone(),
                ServeConfig { max_batch, max_wait_us: wait,
                              ..Default::default() },
            );
            let client = mb.client();
            // one request per sample
            for i in 0..7 {
                let ss = model.sample_size;
                let (_, y) = client
                    .predict(None, flat[i * ss..(i + 1) * ss].to_vec())
                    .unwrap();
                assert_eq!(y.shape, vec![1, g]);
                assert_eq!(y.data, want.data[i * g..(i + 1) * g],
                           "sample {i} max_batch {max_batch}");
            }
            // one multi-sample request
            let (_, y) = client.predict(None, flat.clone()).unwrap();
            assert_eq!(y.data, want.data, "max_batch {max_batch}");
        }
    }

    #[test]
    fn concurrent_clients_coalesce_and_stay_bitexact() {
        let (path, net) = saved_model("tinycnn", 8, "conc");
        let reg = Arc::new(ModelRegistry::from_paths(&path).unwrap());
        let model = reg.resolve(None).unwrap();
        let mut rng = Pcg32::new(77);
        let nreq = 12usize;
        let flat = rand_samples(&model, nreq, &mut rng);
        let x = ITensor::from_vec(&model.batch_shape(nreq), flat.clone());
        let want = net.infer(&x);
        let g = model.num_classes;
        let mb = MicroBatcher::start(
            reg.clone(),
            ServeConfig { max_batch: 8, max_wait_us: 2000,
                          ..Default::default() },
        );
        let ss = model.sample_size;
        std::thread::scope(|s| {
            let mut joins = Vec::new();
            for i in 0..nreq {
                let client = mb.client();
                let sample = flat[i * ss..(i + 1) * ss].to_vec();
                joins.push(s.spawn(move || {
                    client.predict(None, sample).unwrap().1
                }));
            }
            for (i, j) in joins.into_iter().enumerate() {
                let y = j.join().unwrap();
                assert_eq!(y.data, want.data[i * g..(i + 1) * g],
                           "concurrent sample {i}");
            }
        });
    }

    #[test]
    fn stress_ten_concurrent_clients_mixed_batches_no_deadlock() {
        // serve concurrency stress: ≥ 8 concurrent clients hammer the
        // micro-batcher with mixed batch sizes across several rounds.
        // Completion of every request is the no-deadlock assertion (a
        // wedged executor hangs the join and fails via test timeout);
        // every per-request logit block must be bit-identical to the
        // reference forward — the `nitro predict` path — regardless of
        // how the requests coalesced.
        let (path, net) = saved_model("tinycnn", 11, "stress");
        let reg = Arc::new(ModelRegistry::from_paths(&path).unwrap());
        let model = reg.resolve(None).unwrap();
        let mut rng = Pcg32::new(123);
        let (nclients, rounds) = (10usize, 6usize);
        let sizes = [1usize, 2, 3, 5, 8];
        // pre-generate every client's request sequence (mixed sizes)
        let requests: Vec<Vec<Vec<i32>>> = (0..nclients)
            .map(|c| {
                (0..rounds)
                    .map(|r| {
                        let n = sizes[(c + r) % sizes.len()];
                        rand_samples(&model, n, &mut rng)
                    })
                    .collect()
            })
            .collect();
        let g = model.num_classes;
        let mb = MicroBatcher::start(
            reg.clone(),
            ServeConfig { max_batch: 16, max_wait_us: 500,
                          ..Default::default() },
        );
        std::thread::scope(|s| {
            let mut joins = Vec::new();
            for flats in &requests {
                let client = mb.client();
                joins.push(s.spawn(move || {
                    flats
                        .iter()
                        .map(|f| client.predict(None, f.clone()).unwrap().1)
                        .collect::<Vec<_>>()
                }));
            }
            for (c, j) in joins.into_iter().enumerate() {
                let got = j.join().unwrap();
                assert_eq!(got.len(), rounds);
                for (r, y) in got.iter().enumerate() {
                    let flat = &requests[c][r];
                    let n = flat.len() / model.sample_size;
                    let x = ITensor::from_vec(&model.batch_shape(n),
                                              flat.clone());
                    let want = net.infer(&x);
                    assert_eq!(y.shape, vec![n, g],
                               "client {c} round {r}: shape");
                    assert_eq!(y.data, want.data,
                               "client {c} round {r}: logits drifted");
                }
            }
        });
    }

    #[test]
    fn handle_line_protocol_and_errors() {
        let (path, net) = saved_model("mlp1-mini", 2, "proto");
        let reg = Arc::new(ModelRegistry::from_paths(&path).unwrap());
        let model = reg.resolve(None).unwrap();
        let mb = MicroBatcher::start(reg, ServeConfig::default());
        let client = mb.client();
        let mut rng = Pcg32::new(3);
        let flat = rand_samples(&model, 1, &mut rng);
        let input = Json::Array(
            flat.iter().map(|&v| Json::Int(v as i64)).collect(),
        );
        let line = Json::obj(vec![
            ("id", Json::Int(7)),
            ("input", input),
        ])
        .dump();
        let resp = handle_line(&line, &client);
        assert_eq!(resp.req("id").unwrap().as_i64(), Some(7));
        assert_eq!(resp.req("model").unwrap().as_str(), Some("mlp1-mini"));
        let x = ITensor::from_vec(&model.batch_shape(1), flat);
        let want = net.infer(&x);
        let logits =
            resp.req("logits").unwrap().as_array().unwrap()[0].i32_vec()
                .unwrap();
        assert_eq!(logits, want.data);
        let am = resp.req("argmax").unwrap().as_array().unwrap()[0]
            .as_i64()
            .unwrap();
        // first-max-wins, matching the server's argmax
        let mut best = 0usize;
        for j in 1..want.data.len() {
            if want.data[j] > want.data[best] {
                best = j;
            }
        }
        assert_eq!(am, best as i64);

        // error paths: bad JSON, missing input, wrong sample size,
        // unknown model — all JSON error responses, never a panic
        // a pathologically nested line must error, not blow the stack
        let deep = "[".repeat(100_000);
        for bad in [
            "{not json",
            r#"{"id": 1}"#,
            r#"{"id": 2, "input": [1, 2, 3]}"#,
            r#"{"id": 3, "model": "nope", "input": [1]}"#,
            r#"{"id": 4, "input": "xyz"}"#,
            // out-of-i32-range values must error, not wrap mod 2^32
            r#"{"id": 5, "input": [2147483648]}"#,
            // a non-string model must error, not silently fall back
            r#"{"id": 6, "model": 42, "input": [1]}"#,
            deep.as_str(),
        ] {
            let resp = handle_line(bad, &client);
            assert!(resp.get("error").is_some(), "no error for {bad}");
        }
    }

    #[test]
    fn tcp_line_cap_scales_with_widest_model() {
        let (path, _) = saved_model("tinycnn", 1, "linecap");
        let reg = ModelRegistry::from_paths(&path).unwrap();
        let cfg = ServeConfig::default();
        // tinycnn sample = 1*8*8 = 64 ints
        assert_eq!(max_line_bytes(&reg, &cfg),
                   64 * cfg.max_request_samples as u64 * 13 + 4096);
    }

    #[test]
    fn oversized_requests_rejected_not_executed() {
        let (path, _) = saved_model("mlp1-mini", 6, "cap");
        let reg = Arc::new(ModelRegistry::from_paths(&path).unwrap());
        let model = reg.resolve(None).unwrap();
        let mb = MicroBatcher::start(
            reg.clone(),
            ServeConfig {
                max_batch: 4,
                max_wait_us: 0,
                max_request_samples: 2,
            },
        );
        let client = mb.client();
        let mut rng = Pcg32::new(4);
        let ok = rand_samples(&model, 2, &mut rng);
        assert!(client.predict(None, ok).is_ok());
        let too_big = rand_samples(&model, 3, &mut rng);
        let err = client.predict(None, too_big).unwrap_err();
        assert!(err.contains("per-request"), "{err}");
    }

    #[test]
    fn parse_inputs_forms() {
        let flat = Json::parse("[1, 2, 3, 4]").unwrap();
        assert_eq!(parse_inputs(&flat, 2).unwrap(), vec![1, 2, 3, 4]);
        let nested = Json::parse("[[1, 2], [3, 4]]").unwrap();
        assert_eq!(parse_inputs(&nested, 2).unwrap(), vec![1, 2, 3, 4]);
        let wrapped = Json::parse(r#"{"inputs": [[1, 2]]}"#).unwrap();
        assert_eq!(parse_inputs(&wrapped, 2).unwrap(), vec![1, 2]);
        assert!(parse_inputs(&flat, 3).is_err(), "not a multiple");
        assert!(parse_inputs(&Json::parse("[]").unwrap(), 2).is_err());
        assert!(parse_inputs(&Json::parse("[[1]]").unwrap(), 2).is_err());
        assert!(parse_inputs(&Json::parse("\"x\"").unwrap(), 2).is_err());
    }

    #[test]
    fn bench_serve_quick_emits_record_and_passes_identity() {
        let dir = std::env::temp_dir().join("nitro_serve_bench_test");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_serve.json");
        let mut failures = Vec::new();
        let rec = bench_serve(true, 0.01, out.to_str().unwrap(),
                              &mut failures)
            .unwrap();
        assert!(failures.is_empty(), "{failures:?}");
        assert_eq!(rec.req("schema_version").unwrap().as_i64(),
                   Some(SCHEMA_VERSION));
        assert_eq!(rec.req("bitexact").unwrap().as_bool(), Some(true));
        let rows =
            rec.req("serve_throughput").unwrap().as_array().unwrap();
        assert_eq!(rows.len(), 3, "quick batch sizes");
        for r in rows {
            assert!(r.req("requests_per_sec").unwrap().as_f64().unwrap()
                    > 0.0);
            assert!(r.req("p99_ns").unwrap().as_f64().unwrap()
                    >= r.req("p50_ns").unwrap().as_f64().unwrap());
        }
        let reread = Json::parse_file(out.to_str().unwrap()).unwrap();
        assert_eq!(reread.req("experiment").unwrap().as_str(),
                   Some("serve"));
    }
}
