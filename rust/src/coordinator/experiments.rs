//! Experiment drivers (DESIGN.md per-experiment index).
//!
//! The paper's *tables* (1, 2, 8, 9) are declarative [`ExperimentSpec`]
//! JSON files under `experiments/` executed by [`crate::coordinator::
//! runner`]; `run("table1", ..)` below just dispatches to the embedded
//! copy of the committed spec. The figure/extension drivers (fig2-*,
//! fig3, momentum, probe) stay imperative because they probe network
//! internals mid-run (weight magnitudes, bit-widths, custom topologies)
//! that a dataset/preset/engine grid cannot express.
//!
//! Scale knob: `--scale quick|full`. `quick` uses the narrow presets and
//! small synthetic datasets (~minutes on CPU); `full` uses the paper-width
//! architectures (hours — provided for completeness).

use crate::coordinator::runner::{self, RunnerOpts};
use crate::coordinator::spec::ExperimentSpec;
use crate::data::loader;
use crate::nn::{zoo, Hyper, Network};
use crate::train::{fit, weight_stats, TrainConfig};
use crate::util::jsonio::Json;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Scale {
    Quick,
    Full,
}

impl Scale {
    pub fn parse(s: &str) -> Result<Scale, String> {
        match s {
            "quick" => Ok(Scale::Quick),
            "full" => Ok(Scale::Full),
            _ => Err(format!("unknown scale '{s}' (quick|full)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Scale::Quick => "quick",
            Scale::Full => "full",
        }
    }
}

pub struct ExpCtx {
    pub scale: Scale,
    pub seed: u64,
    pub epochs: usize,
    /// The raw `--epochs` value (0 = caller did not override); table specs
    /// resolve their own scale-default epoch budgets from this.
    pub epochs_override: usize,
    pub n_train: usize,
    pub n_test: usize,
    pub out_dir: String,
}

impl ExpCtx {
    pub fn new(scale: Scale, seed: u64, epochs: usize) -> Self {
        // quick: micro presets, enough epochs to clear the integer
        // bootstrap phase (weights must grow ~100x before the scaling
        // layers stop truncating — see EXPERIMENTS.md); full: paper scale.
        let epochs_override = epochs;
        let (n_train, n_test, epochs) = match scale {
            Scale::Quick => (1200, 300, if epochs == 0 { 60 } else { epochs }),
            Scale::Full => (20000, 4000, if epochs == 0 { 150 } else { epochs }),
        };
        ExpCtx {
            scale,
            seed,
            epochs,
            epochs_override,
            n_train,
            n_test,
            out_dir: "results".to_string(),
        }
    }

    fn preset(&self, full: &str, narrow: &str) -> String {
        match self.scale {
            Scale::Full => full.to_string(),
            Scale::Quick => narrow.to_string(),
        }
    }

    /// Inverse learning rate: the paper's 512 is tuned for full-width
    /// architectures; the micro presets have ~16x smaller gradient sums,
    /// so their calibrated value is 128 (see EXPERIMENTS.md bootstrap
    /// section).
    fn gamma_cnn(&self) -> i64 {
        match self.scale {
            Scale::Full => 512,
            Scale::Quick => 128,
        }
    }

    pub fn save(&self, name: &str, rows: &Json) {
        std::fs::create_dir_all(&self.out_dir).ok();
        let path = format!("{}/{name}.json", self.out_dir);
        let record = Json::obj(vec![
            ("experiment", Json::Str(name.to_string())),
            ("scale", Json::Str(format!("{:?}", self.scale))),
            ("seed", Json::Int(self.seed as i64)),
            ("rows", rows.clone()),
        ]);
        if std::fs::write(&path, record.dump()).is_ok() {
            println!("  -> {path}");
        }
    }
}

fn load_data(ctx: &ExpCtx, name: &str)
             -> (crate::data::Dataset, crate::data::Dataset) {
    let (mut tr, mut te) =
        loader::load(name, "data", ctx.n_train, ctx.n_test, ctx.seed)
            .expect("dataset");
    tr.mad_normalize();
    te.mad_normalize();
    (tr, te)
}

/// The micro CNN presets are calibrated at batch 32 / gamma_inv 128
/// (EXPERIMENTS.md); full scale uses the paper's batch 64.
fn cnn_batch(ctx: &ExpCtx) -> usize {
    match ctx.scale {
        Scale::Full => 64,
        Scale::Quick => 32,
    }
}

// ---------------------------------------------------------------------------
// Tables 1/2/8/9 — declarative specs under experiments/
// ---------------------------------------------------------------------------

/// Execute a paper-table spec (embedded copy of `experiments/<name>.json`)
/// with this context's scale/seed/epoch overrides applied.
fn run_table_spec(name: &str, ctx: &ExpCtx) -> Result<(), String> {
    let spec = ExperimentSpec::load_builtin(name)?;
    let opts = RunnerOpts {
        scale: Some(ctx.scale),
        seed: Some(ctx.seed),
        epochs: ctx.epochs_override,
        out_dir: ctx.out_dir.clone(),
        ..Default::default()
    };
    runner::execute(&spec, &opts).map(|_| ())
}

// ---------------------------------------------------------------------------
// Figure 2 left — weight-decay effect on weight magnitude
// ---------------------------------------------------------------------------

pub fn fig2_left(ctx: &ExpCtx) {
    println!("== Fig. 2 (left): decay rates vs mean |W| of a mid conv layer ==");
    let preset = ctx.preset("vgg8b", "tinycnn");
    let data = if ctx.scale == Scale::Full { "cifar10" } else { "tiny" };
    let (tr, te) = load_data(ctx, data);
    // (label, eta_fw, eta_lr) — "No decay" plus the 2x2 strong/weak grid
    let settings: &[(&str, i64, i64)] = &[
        ("no-decay", 0, 0),
        ("fw-weak/lr-weak", 50000, 20000),
        ("fw-weak/lr-strong", 50000, 3000),
        ("fw-strong/lr-weak", 10000, 20000),
        ("fw-strong/lr-strong", 10000, 3000),
    ];
    println!("{:<22} {:>12} {:>10}", "setting", "mean|W| conv", "test_acc");
    let mut out_rows = Vec::new();
    let mut no_decay_mean = 0.0f64;
    for &(label, eta_fw, eta_lr) in settings {
        let spec = zoo::get(&preset).unwrap();
        let mut net = Network::new(spec, ctx.seed);
        let cfg = TrainConfig {
            epochs: ctx.epochs,
            batch: 64,
            hyper: Hyper { gamma_inv: 512, eta_fw_inv: eta_fw,
                           eta_lr_inv: eta_lr },
            seed: ctx.seed,
            ..Default::default()
        };
        let res = fit(&mut net, &tr, &te, &cfg);
        // mid conv layer forward weights (paper probes an Integer Conv2D)
        let mid = net.blocks.len() / 2;
        let mean_abs = net.blocks[mid].wf.mean_abs();
        if label == "no-decay" {
            no_decay_mean = mean_abs;
        }
        println!("{label:<22} {mean_abs:>12.2} {:>9.2}%",
                 res.final_test_acc * 100.0);
        out_rows.push(Json::obj(vec![
            ("setting", Json::Str(label.to_string())),
            ("eta_fw_inv", Json::Int(eta_fw)),
            ("eta_lr_inv", Json::Int(eta_lr)),
            ("mean_abs_w", Json::Float(mean_abs)),
            ("test_acc", Json::Float(res.final_test_acc * 100.0)),
        ]));
    }
    println!("paper shape: no-decay has the largest |W|; strong fw+lr decay \
              the smallest (no-decay here: {no_decay_mean:.2})");
    ctx.save("fig2_left", &Json::Array(out_rows));
}

// ---------------------------------------------------------------------------
// Figure 2 right — d_lr sweep
// ---------------------------------------------------------------------------

pub fn fig2_right(ctx: &ExpCtx) {
    println!("== Fig. 2 (right): learning-layer width d_lr vs accuracy ==");
    let data = if ctx.scale == Scale::Full { "cifar10" } else { "tiny" };
    let (tr, te) = load_data(ctx, data);
    // paper sweeps d_lr around 4096 on VGG8B; the scaled preset sweeps
    // proportionally around tinycnn's default 64
    let sweep: &[usize] = match ctx.scale {
        Scale::Quick => &[8, 16, 64, 256],
        Scale::Full => &[256, 1024, 4096, 16384],
    };
    println!("{:>8} {:>10}", "d_lr", "test_acc");
    let mut out_rows = Vec::new();
    for &dlr in sweep {
        use crate::nn::zoo::Plan::*;
        let spec = match ctx.scale {
            Scale::Quick => zoo::cnn(
                "tinycnn-dlr", &[Cp(8), Cp(16), L(32)], (1, 8, 8), 10, dlr),
            Scale::Full => zoo::cnn(
                "vgg8b-dlr",
                &[C(128), Cp(256), C(256), Cp(512), Cp(512), Cp(512), L(1024)],
                (3, 32, 32), 10, dlr),
        };
        let mut net = Network::new(spec, ctx.seed);
        let cfg = TrainConfig {
            epochs: ctx.epochs,
            batch: 64,
            hyper: Hyper { gamma_inv: 512, eta_fw_inv: 25000,
                           eta_lr_inv: 3000 },
            seed: ctx.seed,
            ..Default::default()
        };
        let res = fit(&mut net, &tr, &te, &cfg);
        println!("{dlr:>8} {:>9.2}%", res.final_test_acc * 100.0);
        out_rows.push(Json::obj(vec![
            ("d_lr", Json::Int(dlr as i64)),
            ("test_acc", Json::Float(res.final_test_acc * 100.0)),
        ]));
    }
    println!("paper shape: accuracy rises then flattens around d_lr=4096");
    ctx.save("fig2_right", &Json::Array(out_rows));
}

// ---------------------------------------------------------------------------
// Figure 3 / App. E.3 — weight magnitudes & bit-widths
// ---------------------------------------------------------------------------

pub fn fig3(ctx: &ExpCtx) {
    println!("== Fig. 3: |W| distribution per layer + int16 claim ==");
    let preset = ctx.preset("vgg8b-mnist", "vgg8b-micro-mnist");
    let (tr, te) = load_data(ctx, "fashion-mnist");
    let spec = zoo::get(&preset).unwrap();
    let mut net = Network::new(spec, ctx.seed);
    let cfg = TrainConfig {
        epochs: ctx.epochs,
        batch: cnn_batch(ctx),
        hyper: Hyper { gamma_inv: ctx.gamma_cnn(), eta_fw_inv: 28000,
                       eta_lr_inv: 3500 },
        seed: ctx.seed,
        verbose: true,
        ..Default::default()
    };
    let res = fit(&mut net, &tr, &te, &cfg);
    println!("{:<14} {:>10} {:>7} {:>7} {:>8} {:>5}", "tensor", "mean|W|",
             "q50", "q90", "max|W|", "bits");
    let stats = weight_stats(&net);
    let mut out_rows = Vec::new();
    let mut max_bits = 0u32;
    for s in &stats {
        max_bits = max_bits.max(s.bitwidth);
        println!("{:<14} {:>10.2} {:>7} {:>7} {:>8} {:>5}", s.name,
                 s.mean_abs, s.q50, s.q90, s.max_abs, s.bitwidth);
        out_rows.push(Json::obj(vec![
            ("tensor", Json::Str(s.name.clone())),
            ("mean_abs", Json::Float(s.mean_abs)),
            ("q50", Json::Int(s.q50 as i64)),
            ("q90", Json::Int(s.q90 as i64)),
            ("max_abs", Json::Int(s.max_abs as i64)),
            ("bitwidth", Json::Int(s.bitwidth as i64)),
        ]));
    }
    let verdict = if max_bits <= 16 { "HOLDS" } else { "VIOLATED" };
    println!("App. E.3 int16 weights claim: max bit-width {max_bits} -> \
              {verdict} (test_acc {:.2}%)", res.final_test_acc * 100.0);
    ctx.save("fig3", &Json::obj(vec![
        ("layers", Json::Array(out_rows)),
        ("max_bitwidth", Json::Int(max_bits as i64)),
        ("int16_claim_holds", Json::Bool(max_bits <= 16)),
        ("test_acc", Json::Float(res.final_test_acc * 100.0)),
    ]));
}

// ---------------------------------------------------------------------------
// Extensions (paper §5 future work)
// ---------------------------------------------------------------------------

/// Ablation: plain IntegerSGD vs the integer momentum optimizer (§5
/// "improved optimizer tailored for integer-only training") on an MLP.
pub fn momentum(ctx: &ExpCtx) {
    use crate::optim::momentum::MomentumMlp;
    use crate::util::rng::Pcg32;
    println!("== Extension: IntegerSGD vs IntegerMomentum (MLP/LES) ==");
    let (tr, te) = load_data(ctx, "mnist");
    let dims = [tr.sample_size(), 128, 64, 10];
    let mut out_rows = Vec::new();
    // plain IntegerSGD path via the standard network trainer
    let spec = zoo::mlp("mlp-mom", &dims[1..dims.len() - 1], dims[0], 10);
    let mut net = Network::new(spec, ctx.seed);
    let cfg = TrainConfig {
        epochs: ctx.epochs,
        batch: 64,
        hyper: Hyper { gamma_inv: 512, eta_fw_inv: 12000, eta_lr_inv: 3000 },
        seed: ctx.seed,
        ..Default::default()
    };
    let res = fit(&mut net, &tr, &te, &cfg);
    println!("{:<28} {:>9.2}%", "IntegerSGD", res.final_test_acc * 100.0);
    out_rows.push(Json::obj(vec![
        ("optimizer", Json::Str("integer_sgd".into())),
        ("test_acc", Json::Float(res.final_test_acc * 100.0)),
    ]));
    for beta_inv in [4i64, 8, 16] {
        let mut m = MomentumMlp::new(&dims, beta_inv, ctx.seed);
        let mut rng = Pcg32::with_stream(ctx.seed, 0x6d6f);
        for _ in 0..ctx.epochs {
            for (x, labels) in
                crate::data::Batcher::new(&tr, 64, true, &mut rng)
            {
                m.train_batch(&x, &labels, 512, 3000);
            }
        }
        let acc = m.accuracy(&te, 64);
        println!("{:<28} {:>9.2}%",
                 format!("IntegerMomentum b={beta_inv}"), acc * 100.0);
        out_rows.push(Json::obj(vec![
            ("optimizer", Json::Str(format!("momentum_b{beta_inv}"))),
            ("test_acc", Json::Float(acc * 100.0)),
        ]));
    }
    ctx.save("momentum", &Json::Array(out_rows));
}

/// App. E.3 intermediate bit-width probe on a trained network.
pub fn probe(ctx: &ExpCtx) {
    use crate::nn::probe::{probe_network, verdict};
    println!("== App. E.3: intermediate bit-widths after training ==");
    let preset = ctx.preset("vgg8b", "vgg8b-micro");
    let (tr, te) = load_data(ctx, "cifar10");
    let spec = zoo::get(&preset).unwrap();
    let mut net = Network::new(spec, ctx.seed);
    let cfg = TrainConfig {
        epochs: ctx.epochs,
        batch: cnn_batch(ctx),
        hyper: Hyper { gamma_inv: ctx.gamma_cnn(), eta_fw_inv: 25000,
                       eta_lr_inv: 3000 },
        seed: ctx.seed,
        ..Default::default()
    };
    let res = fit(&mut net, &tr, &te, &cfg);
    let (x, labels) = tr.gather(&(0..64.min(tr.len())).collect::<Vec<_>>(),
                                net.spec.input_shape.len() == 1);
    let probes = probe_network(&net, &x, &labels);
    println!("{:>6} {:>12} {:>9} {:>11} {:>12}", "block", "preact_bits",
             "act_bits", "delta_bits", "weight_bits");
    let mut rows = Vec::new();
    for p in &probes {
        println!("{:>6} {:>12} {:>9} {:>11} {:>12}", p.block, p.preact_bits,
                 p.act_bits, p.delta_bits, p.weight_bits);
        rows.push(Json::obj(vec![
            ("block", Json::Int(p.block as i64)),
            ("preact_bits", Json::Int(p.preact_bits as i64)),
            ("act_bits", Json::Int(p.act_bits as i64)),
            ("delta_bits", Json::Int(p.delta_bits as i64)),
            ("weight_bits", Json::Int(p.weight_bits as i64)),
        ]));
    }
    let (w16, i32ok) = verdict(&probes);
    println!("weights int16: {w16}; intermediates int32: {i32ok} \
              (test acc {:.2}%)", res.final_test_acc * 100.0);
    ctx.save("probe", &Json::obj(vec![
        ("blocks", Json::Array(rows)),
        ("weights_int16", Json::Bool(w16)),
        ("intermediates_int32", Json::Bool(i32ok)),
    ]));
}

/// Dispatch by experiment name.
pub fn run(name: &str, ctx: &ExpCtx) -> Result<(), String> {
    match name {
        "table1" | "table2" | "table8" | "table9" => {
            return run_table_spec(name, ctx)
        }
        "fig2-left" => fig2_left(ctx),
        "fig2-right" => fig2_right(ctx),
        "fig3" => fig3(ctx),
        "momentum" => momentum(ctx),
        "probe" => probe(ctx),
        "all" => {
            for n in ["table1", "table2", "table8", "table9", "fig2-left",
                      "fig2-right", "fig3", "momentum", "probe"] {
                run(n, ctx)?;
            }
        }
        _ => {
            return Err(format!(
                "unknown experiment '{name}' (table1|table2|table8|table9|\
                 fig2-left|fig2-right|fig3|momentum|probe|all)"
            ))
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_parse() {
        assert_eq!(Scale::parse("quick").unwrap(), Scale::Quick);
        assert_eq!(Scale::parse("full").unwrap(), Scale::Full);
        assert!(Scale::parse("x").is_err());
        assert_eq!(Scale::Quick.name(), "quick");
        assert_eq!(Scale::Full.name(), "full");
    }

    #[test]
    fn unknown_experiment_errors() {
        let ctx = ExpCtx::new(Scale::Quick, 1, 1);
        assert!(run("bogus", &ctx).is_err());
    }

    #[test]
    fn ctx_records_raw_epoch_override() {
        let ctx = ExpCtx::new(Scale::Quick, 1, 0);
        assert_eq!(ctx.epochs, 60, "resolved default for figure drivers");
        assert_eq!(ctx.epochs_override, 0, "specs see the raw value");
        let ctx = ExpCtx::new(Scale::Full, 1, 7);
        assert_eq!((ctx.epochs, ctx.epochs_override), (7, 7));
    }
}
