//! Experiment drivers — one per table/figure of the paper's evaluation
//! (DESIGN.md per-experiment index). Each driver trains scaled workloads
//! (DESIGN.md §Substitutions), prints the same row structure the paper
//! reports (paper value alongside the measured value), and appends a JSON
//! record under `results/`.
//!
//! Scale knob: `--scale quick|full`. `quick` uses the narrow presets and
//! small synthetic datasets (~minutes on CPU); `full` uses the paper-width
//! architectures (hours — provided for completeness).

use crate::baselines::{fp, pocketnn};
use crate::data::loader;
use crate::nn::{zoo, Hyper, Network};
use crate::train::{fit, weight_stats, TrainConfig};
use crate::util::jsonio::Json;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Scale {
    Quick,
    Full,
}

impl Scale {
    pub fn parse(s: &str) -> Result<Scale, String> {
        match s {
            "quick" => Ok(Scale::Quick),
            "full" => Ok(Scale::Full),
            _ => Err(format!("unknown scale '{s}' (quick|full)")),
        }
    }
}

pub struct ExpCtx {
    pub scale: Scale,
    pub seed: u64,
    pub epochs: usize,
    pub n_train: usize,
    pub n_test: usize,
    pub out_dir: String,
}

impl ExpCtx {
    pub fn new(scale: Scale, seed: u64, epochs: usize) -> Self {
        // quick: micro presets, enough epochs to clear the integer
        // bootstrap phase (weights must grow ~100x before the scaling
        // layers stop truncating — see EXPERIMENTS.md); full: paper scale.
        let (n_train, n_test, epochs) = match scale {
            Scale::Quick => (1200, 300, if epochs == 0 { 60 } else { epochs }),
            Scale::Full => (20000, 4000, if epochs == 0 { 150 } else { epochs }),
        };
        ExpCtx {
            scale,
            seed,
            epochs,
            n_train,
            n_test,
            out_dir: "results".to_string(),
        }
    }

    fn preset(&self, full: &str, narrow: &str) -> String {
        match self.scale {
            Scale::Full => full.to_string(),
            Scale::Quick => narrow.to_string(),
        }
    }

    /// Inverse learning rate: the paper's 512 is tuned for full-width
    /// architectures; the micro presets have ~16x smaller gradient sums,
    /// so their calibrated value is 128 (see EXPERIMENTS.md bootstrap
    /// section).
    fn gamma_cnn(&self) -> i64 {
        match self.scale {
            Scale::Full => 512,
            Scale::Quick => 128,
        }
    }

    pub fn save(&self, name: &str, rows: &Json) {
        std::fs::create_dir_all(&self.out_dir).ok();
        let path = format!("{}/{name}.json", self.out_dir);
        let record = Json::obj(vec![
            ("experiment", Json::Str(name.to_string())),
            ("scale", Json::Str(format!("{:?}", self.scale))),
            ("seed", Json::Int(self.seed as i64)),
            ("rows", rows.clone()),
        ]);
        if std::fs::write(&path, record.dump()).is_ok() {
            println!("  -> {path}");
        }
    }
}

fn load_data(ctx: &ExpCtx, name: &str)
             -> (crate::data::Dataset, crate::data::Dataset) {
    let (mut tr, mut te) =
        loader::load(name, "data", ctx.n_train, ctx.n_test, ctx.seed)
            .expect("dataset");
    tr.mad_normalize();
    te.mad_normalize();
    (tr, te)
}

fn nitro_run_b(ctx: &ExpCtx, preset: &str, data: &str, hp: Hyper,
               dropout: (f64, f64), batch: usize)
               -> crate::train::TrainResult {
    let (tr, te) = load_data(ctx, data);
    let spec = zoo::get(preset).unwrap_or_else(|| panic!("preset {preset}"));
    let mut net = Network::new(spec, ctx.seed);
    net.set_dropout(dropout.0, dropout.1);
    let cfg = TrainConfig {
        epochs: ctx.epochs,
        batch,
        hyper: hp,
        seed: ctx.seed,
        verbose: true,
        ..Default::default()
    };
    fit(&mut net, &tr, &te, &cfg)
}

fn nitro_run(ctx: &ExpCtx, preset: &str, data: &str, hp: Hyper,
             dropout: (f64, f64)) -> crate::train::TrainResult {
    nitro_run_b(ctx, preset, data, hp, dropout, 64)
}

/// The micro CNN presets are calibrated at batch 32 / gamma_inv 128
/// (EXPERIMENTS.md); full scale uses the paper's batch 64.
fn cnn_batch(ctx: &ExpCtx) -> usize {
    match ctx.scale {
        Scale::Full => 64,
        Scale::Quick => 32,
    }
}

// ---------------------------------------------------------------------------
// Table 1 — MLP architectures
// ---------------------------------------------------------------------------

/// Paper Table 1: NITRO-D vs PocketNN vs FP LES vs FP BP on MLPs.
/// Paper reference values are carried in the printed rows.
pub fn table1(ctx: &ExpCtx) {
    println!("== Table 1: MLP architectures ==");
    println!("{:<14} {:<14} {:>9} {:>10} {:>8} {:>8}   (paper NITRO-D)",
             "arch", "dataset", "NITRO-D", "PocketNN", "FP LES", "FP BP");
    // (arch-full, arch-narrow, dataset, paper NITRO-D accuracy)
    let rows_spec: &[(&str, &str, &str, f64)] = &[
        ("mlp1", "mlp1", "mnist", 97.36),
        ("mlp2", "mlp2", "fashion-mnist", 88.66),
        ("mlp3", "mlp3-narrow", "mnist", 98.28),
        ("mlp3", "mlp3-narrow", "fashion-mnist", 89.13),
        ("mlp4", "mlp4-narrow", "cifar10", 61.03),
    ];
    let mut out_rows = Vec::new();
    // MLP epochs are cheap; the deeper MLPs need the longer budget to
    // clear the integer bootstrap (EXPERIMENTS.md)
    let ctx = &ExpCtx::new(ctx.scale, ctx.seed, ctx.epochs.max(120));
    for &(full, narrow, data, paper) in rows_spec {
        let preset = ctx.preset(full, narrow);
        let hp = Hyper { gamma_inv: 512, eta_fw_inv: 12000, eta_lr_inv: 3000 };
        let res = nitro_run(ctx, &preset, data, hp, (0.0, 0.0));
        let nitro_acc = res.final_test_acc * 100.0;

        // PocketNN baseline: same hidden dims
        let (tr, te) = load_data(ctx, data);
        let spec = zoo::get(&preset).unwrap();
        let mut dims = vec![spec.input_shape[0]];
        for b in &spec.blocks {
            dims.push(b.out_features());
        }
        dims.push(spec.num_classes);
        let (_, pocket_acc) =
            pocketnn::train(&dims, &tr, &te, ctx.epochs, 64, 512, ctx.seed);
        let pocket_acc = pocket_acc * 100.0;

        // float baselines on the same topology
        let mut fnet = fp::FpNet::new(zoo::get(&preset).unwrap(), ctx.seed);
        let les = fp::train_les(&mut fnet, &tr, &te, ctx.epochs, 64, 1e-3,
                                ctx.seed);
        let mut fnet2 = fp::FpNet::new(zoo::get(&preset).unwrap(), ctx.seed);
        let bp = fp::train_bp(&mut fnet2, &tr, &te, ctx.epochs, 64, 1e-3,
                              ctx.seed);
        println!(
            "{:<14} {:<14} {:>8.2}% {:>9.2}% {:>7.2}% {:>7.2}%   ({paper:.2}%)",
            preset, data, nitro_acc, pocket_acc,
            les.test_acc * 100.0, bp.test_acc * 100.0
        );
        out_rows.push(Json::obj(vec![
            ("arch", Json::Str(preset.clone())),
            ("dataset", Json::Str(data.to_string())),
            ("nitro_d", Json::Float(nitro_acc)),
            ("pocketnn", Json::Float(pocket_acc)),
            ("fp_les", Json::Float(les.test_acc * 100.0)),
            ("fp_bp", Json::Float(bp.test_acc * 100.0)),
            ("paper_nitro_d", Json::Float(paper)),
        ]));
    }
    ctx.save("table1", &Json::Array(out_rows));
}

// ---------------------------------------------------------------------------
// Table 2 — CNN architectures
// ---------------------------------------------------------------------------

/// Paper Table 2: NITRO-D vs FP LES vs FP BP on VGG8B/VGG11B.
pub fn table2(ctx: &ExpCtx) {
    println!("== Table 2: CNN architectures ==");
    println!("{:<18} {:<14} {:>9} {:>8} {:>8}   (paper NITRO-D)",
             "arch", "dataset", "NITRO-D", "FP LES", "FP BP");
    let rows_spec: &[(&str, &str, &str, f64, i64, i64)] = &[
        // full preset, narrow preset, dataset, paper acc, eta_fw, eta_lr
        ("vgg8b-mnist", "vgg8b-micro-mnist", "mnist", 99.45, 30000, 3000),
        ("vgg8b-mnist", "vgg8b-micro-mnist", "fashion-mnist", 93.66, 28000, 3500),
        ("vgg8b", "vgg8b-micro", "cifar10", 87.96, 25000, 3000),
        ("vgg11b", "vgg11b-micro", "cifar10", 87.39, 28000, 4500),
    ];
    let mut out_rows = Vec::new();
    for &(full, narrow, data, paper, eta_fw, eta_lr) in rows_spec {
        let preset = ctx.preset(full, narrow);
        let hp = Hyper { gamma_inv: ctx.gamma_cnn(), eta_fw_inv: eta_fw,
                         eta_lr_inv: eta_lr };
        let res = nitro_run_b(ctx, &preset, data, hp, (0.0, 0.0),
                              cnn_batch(ctx));
        let nitro_acc = res.final_test_acc * 100.0;
        let (tr, te) = load_data(ctx, data);
        // Adam needs no integer bootstrap: a third of the epochs suffices
        let fp_epochs = (ctx.epochs / 3).max(10);
        let mut fnet = fp::FpNet::new(zoo::get(&preset).unwrap(), ctx.seed);
        let les = fp::train_les(&mut fnet, &tr, &te, fp_epochs, 64, 1e-3,
                                ctx.seed);
        let mut fnet2 = fp::FpNet::new(zoo::get(&preset).unwrap(), ctx.seed);
        let bp = fp::train_bp(&mut fnet2, &tr, &te, fp_epochs, 64, 1e-3,
                              ctx.seed);
        println!(
            "{:<18} {:<14} {:>8.2}% {:>7.2}% {:>7.2}%   ({paper:.2}%)",
            preset, data, nitro_acc, les.test_acc * 100.0,
            bp.test_acc * 100.0
        );
        out_rows.push(Json::obj(vec![
            ("arch", Json::Str(preset.clone())),
            ("dataset", Json::Str(data.to_string())),
            ("nitro_d", Json::Float(nitro_acc)),
            ("fp_les", Json::Float(les.test_acc * 100.0)),
            ("fp_bp", Json::Float(bp.test_acc * 100.0)),
            ("paper_nitro_d", Json::Float(paper)),
        ]));
    }
    ctx.save("table2", &Json::Array(out_rows));
}

// ---------------------------------------------------------------------------
// Table 8 — learning-rate ablation (App. E.1)
// ---------------------------------------------------------------------------

/// gamma_inv sweep {256, 512, 1024, 2048, 4096}: the paper reports
/// (unstable) at 256, best at 512, degradation at 1024/2048, (no learning)
/// at 4096.
pub fn table8(ctx: &ExpCtx) {
    println!("== Table 8: learning-rate sweep (VGG11B/CIFAR-10 scaled) ==");
    // quick scale: tinycnn carries the same sweep shape at 1/1000 the cost
    let preset = ctx.preset("vgg11b", "tinycnn");
    let data = if ctx.scale == Scale::Full { "cifar10" } else { "tiny" };
    let (tr, te) = load_data(ctx, data);
    println!("{:>9} {:>12} {:>12}  paper", "gamma_inv", "train_acc", "test_acc");
    // full scale sweeps the paper's exact grid; quick scale shifts the
    // grid by the micro preset's 4x-smaller calibrated gamma_inv so the
    // same unstable / sweet-spot / dead shape is visible
    let paper: &[(i64, &str)] = match ctx.scale {
        Scale::Full => &[
            (256, "(unstable)"),
            (512, "88.86 / 84.66"),
            (1024, "85.95 / 83.10"),
            (2048, "72.43 / 70.23"),
            (4096, "(no learning)"),
        ],
        Scale::Quick => &[
            (64, "(unstable)  [paper: 256]"),
            (512, "sweet spot [paper: 512 -> 88.86/84.66]"),
            (1024, "degraded   [paper: 1024 -> 85.95/83.10]"),
            (4096, "degraded   [paper: 2048 -> 72.43/70.23]"),
            (32768, "(no learning) [paper: 4096]"),
        ],
    };
    let mut out_rows = Vec::new();
    for &(gamma, paper_note) in paper {
        let spec = zoo::get(&preset).unwrap();
        let mut net = Network::new(spec, ctx.seed);
        let cfg = TrainConfig {
            epochs: ctx.epochs,
            batch: 64,
            hyper: Hyper { gamma_inv: gamma, eta_fw_inv: 0, eta_lr_inv: 0 },
            seed: ctx.seed,
            plateau_patience: usize::MAX, // fixed LR for the sweep
            ..Default::default()
        };
        let res = fit(&mut net, &tr, &te, &cfg);
        let train_acc = res.epochs.last().map(|e| e.train_acc).unwrap_or(0.0);
        let status = if res.diverged {
            "(unstable)".to_string()
        } else if train_acc < 0.15 {
            "(no learning)".to_string()
        } else {
            format!("{:.2} / {:.2}", train_acc * 100.0,
                    res.final_test_acc * 100.0)
        };
        println!("{gamma:>9} {status:>26}  {paper_note}");
        out_rows.push(Json::obj(vec![
            ("gamma_inv", Json::Int(gamma)),
            ("train_acc", Json::Float(train_acc * 100.0)),
            ("test_acc", Json::Float(res.final_test_acc * 100.0)),
            ("diverged", Json::Bool(res.diverged)),
            ("paper", Json::Str(paper_note.to_string())),
        ]));
    }
    ctx.save("table8", &Json::Array(out_rows));
}

// ---------------------------------------------------------------------------
// Table 9 — dropout ablation (App. E.2)
// ---------------------------------------------------------------------------

pub fn table9(ctx: &ExpCtx) {
    println!("== Table 9: dropout grid (VGG11B/CIFAR-10 scaled) ==");
    let preset = ctx.preset("vgg11b", "tinycnn");
    let data = if ctx.scale == Scale::Full { "cifar10" } else { "tiny" };
    let (tr, te) = load_data(ctx, data);
    let grid: &[(f64, f64)] = &[
        (0.0, 0.55), (0.05, 0.5), (0.0, 0.85), (0.0, 0.4), (0.0, 0.05),
        (0.2, 0.45), (0.05, 0.55), (0.1, 0.55), (0.2, 0.25),
    ];
    println!("{:>6} {:>6} {:>11} {:>10}", "p_c", "p_l", "train_acc",
             "test_acc");
    let mut out_rows = Vec::new();
    for &(pc, pl) in grid {
        let spec = zoo::get(&preset).unwrap();
        let mut net = Network::new(spec, ctx.seed);
        net.set_dropout(pc, pl);
        let cfg = TrainConfig {
            epochs: ctx.epochs,
            batch: 64,
            hyper: Hyper { gamma_inv: 512, eta_fw_inv: 0, eta_lr_inv: 0 },
            seed: ctx.seed,
            ..Default::default()
        };
        let res = fit(&mut net, &tr, &te, &cfg);
        let train_acc = res.epochs.last().map(|e| e.train_acc).unwrap_or(0.0);
        println!("{pc:>6.2} {pl:>6.2} {:>10.2}% {:>9.2}%",
                 train_acc * 100.0, res.final_test_acc * 100.0);
        out_rows.push(Json::obj(vec![
            ("p_c", Json::Float(pc)),
            ("p_l", Json::Float(pl)),
            ("train_acc", Json::Float(train_acc * 100.0)),
            ("test_acc", Json::Float(res.final_test_acc * 100.0)),
        ]));
    }
    ctx.save("table9", &Json::Array(out_rows));
}

// ---------------------------------------------------------------------------
// Figure 2 left — weight-decay effect on weight magnitude
// ---------------------------------------------------------------------------

pub fn fig2_left(ctx: &ExpCtx) {
    println!("== Fig. 2 (left): decay rates vs mean |W| of a mid conv layer ==");
    let preset = ctx.preset("vgg8b", "tinycnn");
    let data = if ctx.scale == Scale::Full { "cifar10" } else { "tiny" };
    let (tr, te) = load_data(ctx, data);
    // (label, eta_fw, eta_lr) — "No decay" plus the 2x2 strong/weak grid
    let settings: &[(&str, i64, i64)] = &[
        ("no-decay", 0, 0),
        ("fw-weak/lr-weak", 50000, 20000),
        ("fw-weak/lr-strong", 50000, 3000),
        ("fw-strong/lr-weak", 10000, 20000),
        ("fw-strong/lr-strong", 10000, 3000),
    ];
    println!("{:<22} {:>12} {:>10}", "setting", "mean|W| conv", "test_acc");
    let mut out_rows = Vec::new();
    let mut no_decay_mean = 0.0f64;
    for &(label, eta_fw, eta_lr) in settings {
        let spec = zoo::get(&preset).unwrap();
        let mut net = Network::new(spec, ctx.seed);
        let cfg = TrainConfig {
            epochs: ctx.epochs,
            batch: 64,
            hyper: Hyper { gamma_inv: if ctx.scale == Scale::Full { 512 }
                                      else { 512 },
                           eta_fw_inv: eta_fw, eta_lr_inv: eta_lr },
            seed: ctx.seed,
            ..Default::default()
        };
        let res = fit(&mut net, &tr, &te, &cfg);
        // mid conv layer forward weights (paper probes an Integer Conv2D)
        let mid = net.blocks.len() / 2;
        let mean_abs = net.blocks[mid].wf.mean_abs();
        if label == "no-decay" {
            no_decay_mean = mean_abs;
        }
        println!("{label:<22} {mean_abs:>12.2} {:>9.2}%",
                 res.final_test_acc * 100.0);
        out_rows.push(Json::obj(vec![
            ("setting", Json::Str(label.to_string())),
            ("eta_fw_inv", Json::Int(eta_fw)),
            ("eta_lr_inv", Json::Int(eta_lr)),
            ("mean_abs_w", Json::Float(mean_abs)),
            ("test_acc", Json::Float(res.final_test_acc * 100.0)),
        ]));
    }
    println!("paper shape: no-decay has the largest |W|; strong fw+lr decay \
              the smallest (no-decay here: {no_decay_mean:.2})");
    ctx.save("fig2_left", &Json::Array(out_rows));
}

// ---------------------------------------------------------------------------
// Figure 2 right — d_lr sweep
// ---------------------------------------------------------------------------

pub fn fig2_right(ctx: &ExpCtx) {
    println!("== Fig. 2 (right): learning-layer width d_lr vs accuracy ==");
    let data = if ctx.scale == Scale::Full { "cifar10" } else { "tiny" };
    let (tr, te) = load_data(ctx, data);
    // paper sweeps d_lr around 4096 on VGG8B; the scaled preset sweeps
    // proportionally around tinycnn's default 64
    let sweep: &[usize] = match ctx.scale {
        Scale::Quick => &[8, 16, 64, 256],
        Scale::Full => &[256, 1024, 4096, 16384],
    };
    println!("{:>8} {:>10}", "d_lr", "test_acc");
    let mut out_rows = Vec::new();
    for &dlr in sweep {
        use crate::nn::zoo::Plan::*;
        let spec = match ctx.scale {
            Scale::Quick => zoo::cnn(
                "tinycnn-dlr", &[Cp(8), Cp(16), L(32)], (1, 8, 8), 10, dlr),
            Scale::Full => zoo::cnn(
                "vgg8b-dlr",
                &[C(128), Cp(256), C(256), Cp(512), Cp(512), Cp(512), L(1024)],
                (3, 32, 32), 10, dlr),
        };
        let mut net = Network::new(spec, ctx.seed);
        let cfg = TrainConfig {
            epochs: ctx.epochs,
            batch: 64,
            hyper: Hyper { gamma_inv: 512, eta_fw_inv: 25000,
                           eta_lr_inv: 3000 },
            seed: ctx.seed,
            ..Default::default()
        };
        let res = fit(&mut net, &tr, &te, &cfg);
        println!("{dlr:>8} {:>9.2}%", res.final_test_acc * 100.0);
        out_rows.push(Json::obj(vec![
            ("d_lr", Json::Int(dlr as i64)),
            ("test_acc", Json::Float(res.final_test_acc * 100.0)),
        ]));
    }
    println!("paper shape: accuracy rises then flattens around d_lr=4096");
    ctx.save("fig2_right", &Json::Array(out_rows));
}

// ---------------------------------------------------------------------------
// Figure 3 / App. E.3 — weight magnitudes & bit-widths
// ---------------------------------------------------------------------------

pub fn fig3(ctx: &ExpCtx) {
    println!("== Fig. 3: |W| distribution per layer + int16 claim ==");
    let preset = ctx.preset("vgg8b-mnist", "vgg8b-micro-mnist");
    let (tr, te) = load_data(ctx, "fashion-mnist");
    let spec = zoo::get(&preset).unwrap();
    let mut net = Network::new(spec, ctx.seed);
    let cfg = TrainConfig {
        epochs: ctx.epochs,
        batch: cnn_batch(ctx),
        hyper: Hyper { gamma_inv: ctx.gamma_cnn(), eta_fw_inv: 28000,
                       eta_lr_inv: 3500 },
        seed: ctx.seed,
        verbose: true,
        ..Default::default()
    };
    let res = fit(&mut net, &tr, &te, &cfg);
    println!("{:<14} {:>10} {:>7} {:>7} {:>8} {:>5}", "tensor", "mean|W|",
             "q50", "q90", "max|W|", "bits");
    let stats = weight_stats(&net);
    let mut out_rows = Vec::new();
    let mut max_bits = 0u32;
    for s in &stats {
        max_bits = max_bits.max(s.bitwidth);
        println!("{:<14} {:>10.2} {:>7} {:>7} {:>8} {:>5}", s.name,
                 s.mean_abs, s.q50, s.q90, s.max_abs, s.bitwidth);
        out_rows.push(Json::obj(vec![
            ("tensor", Json::Str(s.name.clone())),
            ("mean_abs", Json::Float(s.mean_abs)),
            ("q50", Json::Int(s.q50 as i64)),
            ("q90", Json::Int(s.q90 as i64)),
            ("max_abs", Json::Int(s.max_abs as i64)),
            ("bitwidth", Json::Int(s.bitwidth as i64)),
        ]));
    }
    let verdict = if max_bits <= 16 { "HOLDS" } else { "VIOLATED" };
    println!("App. E.3 int16 weights claim: max bit-width {max_bits} -> \
              {verdict} (test_acc {:.2}%)", res.final_test_acc * 100.0);
    ctx.save("fig3", &Json::obj(vec![
        ("layers", Json::Array(out_rows)),
        ("max_bitwidth", Json::Int(max_bits as i64)),
        ("int16_claim_holds", Json::Bool(max_bits <= 16)),
        ("test_acc", Json::Float(res.final_test_acc * 100.0)),
    ]));
}

// ---------------------------------------------------------------------------
// Extensions (paper §5 future work)
// ---------------------------------------------------------------------------

/// Ablation: plain IntegerSGD vs the integer momentum optimizer (§5
/// "improved optimizer tailored for integer-only training") on an MLP.
pub fn momentum(ctx: &ExpCtx) {
    use crate::optim::momentum::MomentumMlp;
    use crate::util::rng::Pcg32;
    println!("== Extension: IntegerSGD vs IntegerMomentum (MLP/LES) ==");
    let (tr, te) = load_data(ctx, "mnist");
    let dims = [tr.sample_size(), 128, 64, 10];
    let mut out_rows = Vec::new();
    // plain IntegerSGD path via the standard network trainer
    let spec = zoo::mlp("mlp-mom", &dims[1..dims.len() - 1], dims[0], 10);
    let mut net = Network::new(spec, ctx.seed);
    let cfg = TrainConfig {
        epochs: ctx.epochs,
        batch: 64,
        hyper: Hyper { gamma_inv: 512, eta_fw_inv: 12000, eta_lr_inv: 3000 },
        seed: ctx.seed,
        ..Default::default()
    };
    let res = fit(&mut net, &tr, &te, &cfg);
    println!("{:<28} {:>9.2}%", "IntegerSGD", res.final_test_acc * 100.0);
    out_rows.push(Json::obj(vec![
        ("optimizer", Json::Str("integer_sgd".into())),
        ("test_acc", Json::Float(res.final_test_acc * 100.0)),
    ]));
    for beta_inv in [4i64, 8, 16] {
        let mut m = MomentumMlp::new(&dims, beta_inv, ctx.seed);
        let mut rng = Pcg32::with_stream(ctx.seed, 0x6d6f);
        for _ in 0..ctx.epochs {
            for (x, labels) in
                crate::data::Batcher::new(&tr, 64, true, &mut rng)
            {
                m.train_batch(&x, &labels, 512, 3000);
            }
        }
        let acc = m.accuracy(&te, 64);
        println!("{:<28} {:>9.2}%",
                 format!("IntegerMomentum b={beta_inv}"), acc * 100.0);
        out_rows.push(Json::obj(vec![
            ("optimizer", Json::Str(format!("momentum_b{beta_inv}"))),
            ("test_acc", Json::Float(acc * 100.0)),
        ]));
    }
    ctx.save("momentum", &Json::Array(out_rows));
}

/// App. E.3 intermediate bit-width probe on a trained network.
pub fn probe(ctx: &ExpCtx) {
    use crate::nn::probe::{probe_network, verdict};
    println!("== App. E.3: intermediate bit-widths after training ==");
    let preset = ctx.preset("vgg8b", "vgg8b-micro");
    let (tr, te) = load_data(ctx, "cifar10");
    let spec = zoo::get(&preset).unwrap();
    let mut net = Network::new(spec, ctx.seed);
    let cfg = TrainConfig {
        epochs: ctx.epochs,
        batch: cnn_batch(ctx),
        hyper: Hyper { gamma_inv: ctx.gamma_cnn(), eta_fw_inv: 25000,
                       eta_lr_inv: 3000 },
        seed: ctx.seed,
        ..Default::default()
    };
    let res = fit(&mut net, &tr, &te, &cfg);
    let (x, labels) = tr.gather(&(0..64.min(tr.len())).collect::<Vec<_>>(),
                                net.spec.input_shape.len() == 1);
    let probes = probe_network(&net, &x, &labels);
    println!("{:>6} {:>12} {:>9} {:>11} {:>12}", "block", "preact_bits",
             "act_bits", "delta_bits", "weight_bits");
    let mut rows = Vec::new();
    for p in &probes {
        println!("{:>6} {:>12} {:>9} {:>11} {:>12}", p.block, p.preact_bits,
                 p.act_bits, p.delta_bits, p.weight_bits);
        rows.push(Json::obj(vec![
            ("block", Json::Int(p.block as i64)),
            ("preact_bits", Json::Int(p.preact_bits as i64)),
            ("act_bits", Json::Int(p.act_bits as i64)),
            ("delta_bits", Json::Int(p.delta_bits as i64)),
            ("weight_bits", Json::Int(p.weight_bits as i64)),
        ]));
    }
    let (w16, i32ok) = verdict(&probes);
    println!("weights int16: {w16}; intermediates int32: {i32ok} \
              (test acc {:.2}%)", res.final_test_acc * 100.0);
    ctx.save("probe", &Json::obj(vec![
        ("blocks", Json::Array(rows)),
        ("weights_int16", Json::Bool(w16)),
        ("intermediates_int32", Json::Bool(i32ok)),
    ]));
}

/// Dispatch by experiment name.
pub fn run(name: &str, ctx: &ExpCtx) -> Result<(), String> {
    match name {
        "table1" => table1(ctx),
        "table2" => table2(ctx),
        "table8" => table8(ctx),
        "table9" => table9(ctx),
        "fig2-left" => fig2_left(ctx),
        "fig2-right" => fig2_right(ctx),
        "fig3" => fig3(ctx),
        "momentum" => momentum(ctx),
        "probe" => probe(ctx),
        "all" => {
            for n in ["table1", "table2", "table8", "table9", "fig2-left",
                      "fig2-right", "fig3", "momentum", "probe"] {
                run(n, ctx)?;
            }
        }
        _ => {
            return Err(format!(
                "unknown experiment '{name}' (table1|table2|table8|table9|\
                 fig2-left|fig2-right|fig3|momentum|probe|all)"
            ))
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_parse() {
        assert_eq!(Scale::parse("quick").unwrap(), Scale::Quick);
        assert_eq!(Scale::parse("full").unwrap(), Scale::Full);
        assert!(Scale::parse("x").is_err());
    }

    #[test]
    fn unknown_experiment_errors() {
        let ctx = ExpCtx::new(Scale::Quick, 1, 1);
        assert!(run("bogus", &ctx).is_err());
    }
}
