//! Spec runner: executes an [`ExperimentSpec`] and emits schema-stable
//! JSON metric records.
//!
//! One [`ResolvedRun`] = one training job with its own isolated RNG state
//! (network init, batch shuffling and dropout streams are all derived from
//! the run's seed, never shared across runs). Per-epoch
//! loss/accuracy/wall-clock metrics flow through the shared
//! [`MetricSink`]; each run is written to
//! `<out_dir>/<experiment>/<id>__<engine>__s<seed>.json` with its full
//! epoch log, and the aggregate table goes to the spec's `bench_output`
//! (`BENCH_<name>.json`) with one row per run.
//!
//! Record schema is versioned ([`SCHEMA_VERSION`]); CI consumes the BENCH
//! file as a workflow artifact, so keys are append-only.

use std::time::Instant;

use crate::baselines::{fp, pocketnn};
use crate::coordinator::experiments::Scale;
use crate::coordinator::spec::{EngineKind, ExperimentSpec, ResolvedRun};
use crate::data::{loader, Dataset};
use crate::nn::spec::BitsPlan;
use crate::nn::{zoo, Network};
use crate::train::{dist, fit_dist, fit_observed, EpochRecord,
                   MetricSink, NullSink, Scheduler, TrainConfig,
                   TrainResult};
use crate::util::bench::peak_rss_kb;
use crate::util::jsonio::Json;

/// Bump when a BENCH record key changes meaning or disappears; adding keys
/// is allowed without a bump.
pub const SCHEMA_VERSION: i64 = 1;

/// CLI-level overrides applied on top of a spec.
#[derive(Clone, Debug)]
pub struct RunnerOpts {
    /// `None` = the spec's own default scale.
    pub scale: Option<Scale>,
    /// `Some(s)` replaces the spec's seed list with the single seed `s`.
    pub seed: Option<u64>,
    /// `0` = the spec's epoch budgets.
    pub epochs: usize,
    /// `Some(s)` overrides the spec's LES scheduler for the nitro engine
    /// (metric-identical; CI uses this to cross-check all three).
    pub scheduler: Option<Scheduler>,
    /// `Some(n)` overrides the spec's data-parallel replica count for
    /// the nitro engine (metric-identical; CI cross-checks replica
    /// counts the same way it cross-checks schedulers).
    pub replicas: Option<usize>,
    /// `Some(n)` overrides the spec's distributed world size for the
    /// nitro engine: the run executes as `n` loopback-TCP
    /// `train::dist` ranks in one process, metric-identical to `1`.
    pub ranks: Option<usize>,
    /// `Some(plan)` replaces the spec's `"bits"` sweep with this single
    /// W/A/G/E bitwidth cell (`--bits` CLI flag). Unlike the knobs above
    /// this changes the arithmetic, not just the execution strategy.
    pub bits: Option<BitsPlan>,
    /// Directory for per-run records (default `results`).
    pub out_dir: String,
    /// Directory for the aggregate BENCH file (default `.`, i.e. the
    /// repository top level).
    pub bench_dir: String,
    /// Per-epoch trainer logs to stderr.
    pub verbose: bool,
}

impl Default for RunnerOpts {
    fn default() -> Self {
        RunnerOpts {
            scale: None,
            seed: None,
            epochs: 0,
            scheduler: None,
            replicas: None,
            ranks: None,
            bits: None,
            out_dir: "results".to_string(),
            bench_dir: ".".to_string(),
            verbose: false,
        }
    }
}

/// Sink collecting every epoch as a JSON row (the per-run epoch log).
struct EpochLog {
    rows: Vec<Json>,
}

impl MetricSink for EpochLog {
    fn on_epoch(&mut self, rec: &EpochRecord) {
        self.rows.push(Json::obj(vec![
            ("epoch", Json::Int(rec.epoch as i64)),
            ("head_loss", Json::Float(rec.mean_head_loss)),
            (
                "block_loss",
                Json::Array(
                    rec.mean_block_loss.iter().map(|&l| Json::Float(l))
                        .collect(),
                ),
            ),
            ("train_acc", Json::Float(rec.train_acc)),
            (
                "test_acc",
                if rec.test_acc.is_nan() {
                    Json::Null
                } else {
                    Json::Float(rec.test_acc)
                },
            ),
            ("gamma_inv", Json::Int(rec.gamma_inv)),
            ("secs", Json::Float(rec.secs)),
        ]));
    }
}

/// Outcome of one resolved run.
struct RunOutcome {
    /// Schema-stable aggregate row (no epoch log).
    record: Json,
    /// Full record including the per-epoch log.
    detail: Json,
    final_test_acc: f64,
}

/// Execute every resolved run of `spec`, write per-run records and the
/// aggregate BENCH file, and return the aggregate JSON.
pub fn execute(spec: &ExperimentSpec, opts: &RunnerOpts)
               -> Result<Json, String> {
    // --bits replaces the spec's sweep with one cell before resolution,
    // so id suffixing and per-run threading work identically either way
    let spec_override;
    let spec = match &opts.bits {
        Some(plan) => {
            spec_override = ExperimentSpec {
                bits: vec![plan.clone()],
                ..spec.clone()
            };
            &spec_override
        }
        None => spec,
    };
    let scale = opts.scale.unwrap_or(spec.scale);
    let runs = spec.resolve(scale, opts.seed, opts.epochs)?;
    println!(
        "experiment '{}': {} runs at {} scale",
        spec.name,
        runs.len(),
        scale.name()
    );
    let run_dir = format!("{}/{}", opts.out_dir, spec.name);
    std::fs::create_dir_all(&run_dir)
        .map_err(|e| format!("mkdir {run_dir}: {e}"))?;
    let mut rows = Vec::new();
    // Accuracy-only rows for BENCH_bitwidth.json: no timing, scheduler,
    // replica or rank keys, so CI can diff the whole file byte-for-byte
    // across scheduler/replica lanes per bits setting.
    let mut bw_rows = Vec::new();
    // Consecutive runs of one row (engine × seed expansion) share the same
    // dataset; cache the last one so it is loaded + normalized once.
    let mut cache: Option<((String, usize, usize, u64), (Dataset, Dataset))> =
        None;
    for r in &runs {
        let t0 = Instant::now();
        let key = (r.dataset.clone(), r.n_train, r.n_test, r.seed);
        let hit = matches!(&cache, Some((k, _)) if *k == key);
        if !hit {
            let (mut tr, mut te) =
                loader::load(&r.dataset, "data", r.n_train, r.n_test,
                             r.seed)?;
            tr.mad_normalize();
            te.mad_normalize();
            cache = Some((key, (tr, te)));
        }
        let (tr, te) = &cache.as_ref().unwrap().1;
        let scheduler = opts.scheduler.unwrap_or(r.scheduler);
        let replicas = opts.replicas.unwrap_or(r.replicas).max(1);
        let ranks = opts.ranks.unwrap_or(r.ranks).max(1);
        let out = execute_run(r, tr, te, scheduler, replicas, ranks,
                              opts.verbose)?;
        let path = format!(
            "{run_dir}/{}__{}__s{}.json",
            sanitize(&r.id),
            r.engine.name(),
            r.seed
        );
        std::fs::write(&path, out.detail.pretty())
            .map_err(|e| format!("write {path}: {e}"))?;
        println!(
            "  {:<22} {:<9} seed {:<4} acc {:>6.2}%  ({:.1}s) -> {path}",
            r.id,
            r.engine.name(),
            r.seed,
            out.final_test_acc * 100.0,
            t0.elapsed().as_secs_f64()
        );
        if r.engine == EngineKind::Nitro {
            bw_rows.push(Json::obj(vec![
                ("id", Json::Str(r.id.clone())),
                ("preset", Json::Str(r.preset.clone())),
                ("dataset", Json::Str(r.dataset.clone())),
                ("seed", Json::Int(r.seed as i64)),
                ("epochs", Json::Int(r.epochs as i64)),
                ("bits", Json::Str(r.bits.label())),
                ("final_test_acc", Json::Float(out.final_test_acc)),
                (
                    "final_train_acc",
                    out.record
                        .get("final_train_acc")
                        .cloned()
                        .unwrap_or(Json::Null),
                ),
                (
                    "diverged",
                    out.record
                        .get("diverged")
                        .cloned()
                        .unwrap_or(Json::Bool(false)),
                ),
            ]));
        }
        rows.push(out.record);
    }
    let bench = Json::obj(vec![
        ("schema_version", Json::Int(SCHEMA_VERSION)),
        ("experiment", Json::Str(spec.name.clone())),
        ("description", Json::Str(spec.description.clone())),
        ("scale", Json::Str(scale.name().to_string())),
        ("rows", Json::Array(rows)),
    ]);
    let bench_path = if opts.bench_dir == "." || opts.bench_dir.is_empty() {
        spec.bench_output.clone()
    } else {
        std::fs::create_dir_all(&opts.bench_dir)
            .map_err(|e| format!("mkdir {}: {e}", opts.bench_dir))?;
        format!("{}/{}", opts.bench_dir, spec.bench_output)
    };
    std::fs::write(&bench_path, bench.pretty())
        .map_err(|e| format!("write {bench_path}: {e}"))?;
    println!("  -> {bench_path}");
    let bw = Json::obj(vec![
        ("schema_version", Json::Int(SCHEMA_VERSION)),
        ("experiment", Json::Str(spec.name.clone())),
        ("scale", Json::Str(scale.name().to_string())),
        ("rows", Json::Array(bw_rows)),
    ]);
    let bw_path = if opts.bench_dir == "." || opts.bench_dir.is_empty() {
        "BENCH_bitwidth.json".to_string()
    } else {
        format!("{}/BENCH_bitwidth.json", opts.bench_dir)
    };
    std::fs::write(&bw_path, bw.pretty())
        .map_err(|e| format!("write {bw_path}: {e}"))?;
    println!("  -> {bw_path}");
    Ok(bench)
}

fn execute_run(r: &ResolvedRun, tr: &Dataset, te: &Dataset,
               scheduler: Scheduler, replicas: usize, ranks: usize,
               verbose: bool) -> Result<RunOutcome, String> {
    let net_spec = zoo::get(&r.preset)
        .ok_or_else(|| format!("run '{}': unknown preset '{}'", r.id,
                               r.preset))?
        .with_bits(r.bits.clone());
    let mut log = EpochLog { rows: Vec::new() };
    let t0 = Instant::now();
    // (test acc, train acc if the engine reports one, diverged)
    let (final_test_acc, final_train_acc, diverged): (f64, Option<f64>, bool) =
        match r.engine {
            EngineKind::Nitro => {
                let mut net = Network::new(net_spec, r.seed);
                net.set_dropout(r.dropout.0, r.dropout.1);
                let cfg = TrainConfig {
                    epochs: r.epochs,
                    batch: r.batch,
                    hyper: r.hyper,
                    seed: r.seed,
                    verbose,
                    scheduler,
                    replicas,
                    plateau_patience: if r.fixed_lr {
                        usize::MAX
                    } else {
                        TrainConfig::default().plateau_patience
                    },
                    ..Default::default()
                };
                let res = if ranks > 1 {
                    run_dist_world(&mut net, tr, te, &cfg, ranks,
                                   r.dropout, &mut log)?
                } else {
                    fit_observed(&mut net, tr, te, &cfg, &mut log)
                };
                (
                    res.final_test_acc,
                    res.epochs.last().map(|e| e.train_acc),
                    res.diverged,
                )
            }
            EngineKind::FpLes | EngineKind::FpBp => {
                let mut fnet = fp::FpNet::new(net_spec, r.seed);
                let res = if r.engine == EngineKind::FpLes {
                    fp::train_les(&mut fnet, tr, te, r.fp_epochs, r.fp_batch,
                                  r.fp_lr as f32, r.seed)
                } else {
                    fp::train_bp(&mut fnet, tr, te, r.fp_epochs, r.fp_batch,
                                 r.fp_lr as f32, r.seed)
                };
                (res.test_acc, Some(res.train_acc), false)
            }
            EngineKind::PocketNn => {
                if net_spec.input_shape.len() != 1 {
                    return Err(format!(
                        "run '{}': the pocketnn engine needs an MLP preset, \
                         got '{}'",
                        r.id, r.preset
                    ));
                }
                let mut dims = vec![net_spec.input_shape[0]];
                for b in &net_spec.blocks {
                    dims.push(b.out_features());
                }
                dims.push(net_spec.num_classes);
                let (_, acc) = pocketnn::train(&dims, tr, te, r.epochs,
                                               r.batch, r.hyper.gamma_inv,
                                               r.seed);
                (acc, None, false)
            }
        };
    let wall = t0.elapsed().as_secs_f64();
    // record what this engine actually ran with
    let (effective_epochs, effective_batch) = match r.engine {
        EngineKind::FpLes | EngineKind::FpBp => (r.fp_epochs, r.fp_batch),
        _ => (r.epochs, r.batch),
    };
    let opt_f = |v: Option<f64>| v.map(Json::Float).unwrap_or(Json::Null);
    let base = vec![
        ("id", Json::Str(r.id.clone())),
        ("engine", Json::Str(r.engine.name().to_string())),
        ("preset", Json::Str(r.preset.clone())),
        ("dataset", Json::Str(r.dataset.clone())),
        ("scale", Json::Str(r.scale.name().to_string())),
        ("seed", Json::Int(r.seed as i64)),
        ("epochs", Json::Int(effective_epochs as i64)),
        ("batch", Json::Int(effective_batch as i64)),
        ("n_train", Json::Int(r.n_train as i64)),
        ("n_test", Json::Int(r.n_test as i64)),
        (
            "hyper",
            Json::obj(vec![
                ("gamma_inv", Json::Int(r.hyper.gamma_inv)),
                ("eta_fw_inv", Json::Int(r.hyper.eta_fw_inv)),
                ("eta_lr_inv", Json::Int(r.hyper.eta_lr_inv)),
            ]),
        ),
        (
            "dropout",
            Json::Array(vec![
                Json::Float(r.dropout.0),
                Json::Float(r.dropout.1),
            ]),
        ),
        (
            // LES scheduler actually used (nitro engine only; the FP/DFA
            // baselines have no block scheduler). Metric keys are
            // scheduler-invariant — CI asserts that — so comparisons
            // across scheduler runs strip this key like the timing ones.
            "scheduler",
            match r.engine {
                EngineKind::Nitro => {
                    Json::Str(scheduler.name().to_string())
                }
                _ => Json::Null,
            },
        ),
        (
            // data-parallel replica count actually used (nitro engine
            // only). Metric keys are replica-invariant — CI asserts that
            // — so cross-replica comparisons strip this key like
            // `scheduler` and the timing ones.
            "replicas",
            match r.engine {
                EngineKind::Nitro => Json::Int(replicas as i64),
                _ => Json::Null,
            },
        ),
        (
            // distributed loopback world size actually used (nitro
            // engine only; metric keys are rank-invariant — CI asserts
            // that — so cross-rank comparisons strip this key too).
            "ranks",
            match r.engine {
                EngineKind::Nitro => Json::Int(ranks as i64),
                _ => Json::Null,
            },
        ),
        (
            // W/A/G/E rails the nitro engine trained with ("W/A/G/E"
            // label, plus any per-layer overrides). NOT stripped in
            // cross-lane comparisons: different rails are different
            // arithmetic, not a different execution strategy.
            "bits",
            match r.engine {
                EngineKind::Nitro => Json::Str(r.bits.label()),
                _ => Json::Null,
            },
        ),
        ("final_test_acc", Json::Float(final_test_acc)),
        ("final_train_acc", opt_f(final_train_acc)),
        ("diverged", Json::Bool(diverged)),
        ("wall_secs", Json::Float(wall)),
        (
            "peak_rss_kb",
            peak_rss_kb().map(|v| Json::Int(v as i64)).unwrap_or(Json::Null),
        ),
        ("paper_acc", opt_f(r.paper_acc)),
        (
            "paper_note",
            r.paper_note
                .clone()
                .map(Json::Str)
                .unwrap_or(Json::Null),
        ),
    ];
    let record = Json::obj(base.clone());
    let mut detail = base;
    detail.push(("epoch_metrics", Json::Array(log.rows)));
    Ok(RunOutcome {
        record,
        detail: Json::obj(detail),
        final_test_acc,
    })
}

/// Execute one nitro run as `ranks` loopback-TCP distributed ranks in a
/// single process: rank 0 trains `net` on the calling thread and feeds
/// `sink`; every other rank builds the identical network from
/// `(net.spec, cfg.seed, dropout)` on its own thread and trains through
/// its own [`dist::DistTrainer`]. Before returning rank 0's result,
/// every rank's final weights are checked byte-identical to rank 0's —
/// the distributed integer all-reduce is exact, so any divergence is a
/// bug, not noise.
fn run_dist_world(net: &mut Network, tr: &Dataset, te: &Dataset,
                  cfg: &TrainConfig, ranks: usize, dropout: (f64, f64),
                  sink: &mut dyn MetricSink)
                  -> Result<TrainResult, String> {
    use std::net::TcpListener;
    let mut listeners = Vec::with_capacity(ranks);
    let mut peers = Vec::with_capacity(ranks);
    for _ in 0..ranks {
        let l = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| format!("bind loopback rank listener: {e}"))?;
        peers.push(l
            .local_addr()
            .map_err(|e| format!("listener addr: {e}"))?
            .to_string());
        listeners.push(l);
    }
    let dcfg = |rank: usize| dist::DistConfig {
        rank,
        peers: peers.clone(),
        ..Default::default()
    };
    let spec = net.spec.clone();
    let mut others: Vec<Network> = Vec::new();
    let res = std::thread::scope(
        |s| -> Result<TrainResult, String> {
            let mut handles = Vec::new();
            let mut it = listeners.into_iter();
            let l0 = it.next().unwrap();
            for (i, l) in it.enumerate() {
                let rank = i + 1;
                let rcfg = dcfg(rank);
                let spec = spec.clone();
                handles.push(s.spawn(
                    move || -> Result<Network, String> {
                        let mut n = Network::new(spec, cfg.seed);
                        n.set_dropout(dropout.0, dropout.1);
                        let mut dt = dist::DistTrainer::with_listener(
                            &n, rcfg, l)?;
                        dt.wait_connected(5_000);
                        fit_dist(&mut n, tr, te, cfg, &mut dt,
                                 &mut NullSink);
                        Ok(n)
                    },
                ));
            }
            let mut dt =
                dist::DistTrainer::with_listener(net, dcfg(0), l0)?;
            dt.wait_connected(5_000);
            let res = fit_dist(net, tr, te, cfg, &mut dt, sink);
            for h in handles {
                others.push(h.join().map_err(
                    |_| "dist rank thread panicked".to_string())??);
            }
            Ok(res)
        },
    )?;
    for (i, n) in others.iter().enumerate() {
        for ((name, w0), (_, wr)) in
            net.weights().iter().zip(n.weights())
        {
            if w0.data != wr.data {
                return Err(format!(
                    "dist rank {}: weight {name} diverged from rank 0 \
                     (the integer all-reduce must be exact)",
                    i + 1
                ));
            }
        }
    }
    Ok(res)
}

/// File-name-safe form of a run id (`mlp1/mnist` -> `mlp1-mnist`).
fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '-' || c == '_' {
                c
            } else {
                '-'
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitize_ids() {
        assert_eq!(sanitize("mlp1/mnist"), "mlp1-mnist");
        assert_eq!(sanitize("pc0.05-pl0.5"), "pc0-05-pl0-5");
        assert_eq!(sanitize("plain_id-7"), "plain_id-7");
    }

    /// End-to-end through the declarative stack at 1 epoch: spec parse ->
    /// resolve -> both engines -> per-run records -> aggregate BENCH file.
    #[test]
    fn smoke_spec_end_to_end() {
        let spec = ExperimentSpec::load_builtin("smoke").unwrap();
        let dir = std::env::temp_dir().join("nitro_runner_test");
        let dir = dir.to_str().unwrap().to_string();
        let opts = RunnerOpts {
            epochs: 1,
            out_dir: format!("{dir}/results"),
            bench_dir: dir.clone(),
            ..Default::default()
        };
        let bench = execute(&spec, &opts).unwrap();
        assert_eq!(
            bench.req("schema_version").unwrap().as_i64(),
            Some(SCHEMA_VERSION)
        );
        assert_eq!(bench.req("experiment").unwrap().as_str(), Some("smoke"));
        let rows = bench.req("rows").unwrap().as_array().unwrap();
        assert_eq!(rows.len(), 2, "nitro + fp-bp");
        for row in rows {
            for key in ["id", "engine", "final_test_acc", "wall_secs",
                        "diverged", "seed", "hyper", "scheduler",
                        "replicas", "ranks", "bits"] {
                assert!(row.get(key).is_some(), "row missing '{key}'");
            }
            let acc = row.req("final_test_acc").unwrap().as_f64().unwrap();
            assert!((0.0..=1.0).contains(&acc));
        }
        // the BENCH file exists, reparses, and matches what execute returned
        let path = format!("{dir}/BENCH_smoke.json");
        let reread = Json::parse_file(&path).unwrap();
        assert_eq!(reread, bench);
        // per-run detail record carries the epoch log (nitro run: 1 epoch)
        let detail_path =
            format!("{dir}/results/smoke/tinycnn-tiny__nitro__s42.json");
        let detail = Json::parse_file(&detail_path).unwrap();
        let epochs = detail.req("epoch_metrics").unwrap().as_array().unwrap();
        assert_eq!(epochs.len(), 1);
        assert!(epochs[0].get("head_loss").is_some());
    }

    /// A `"bits"` sweep must expand nitro rows only, suffix the swept
    /// ids, and emit an accuracy-only `BENCH_bitwidth.json` free of
    /// timing/scheduler/replica keys (so CI can byte-compare it across
    /// execution-strategy lanes).
    #[test]
    fn bits_sweep_emits_bitwidth_bench() {
        use crate::nn::spec::BitwidthCfg;
        let mut spec = ExperimentSpec::load_builtin("smoke").unwrap();
        spec.bits = vec![
            BitsPlan::default(),
            BitsPlan::uniform(BitwidthCfg::uniform(8)),
        ];
        let dir = std::env::temp_dir().join("nitro_runner_bits_test");
        let dir = dir.to_str().unwrap().to_string();
        let opts = RunnerOpts {
            epochs: 1,
            out_dir: format!("{dir}/results"),
            bench_dir: dir.clone(),
            ..Default::default()
        };
        let bench = execute(&spec, &opts).unwrap();
        let rows = bench.req("rows").unwrap().as_array().unwrap();
        // 2 nitro cells + 1 fp-bp row (baselines don't sweep)
        assert_eq!(rows.len(), 3);
        let ids: Vec<&str> = rows
            .iter()
            .filter(|r| r.req("engine").unwrap().as_str() == Some("nitro"))
            .map(|r| r.req("id").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(ids, vec!["tinycnn/tiny", "tinycnn/tiny+bits8-8-64-64"]);
        let bw = Json::parse_file(&format!("{dir}/BENCH_bitwidth.json"))
            .unwrap();
        let bw_rows = bw.req("rows").unwrap().as_array().unwrap();
        assert_eq!(bw_rows.len(), 2, "nitro rows only");
        for row in bw_rows {
            assert_eq!(
                row.get("bits").and_then(Json::as_str).is_some(),
                true
            );
            assert!(row.get("final_test_acc").is_some());
            for absent in ["wall_secs", "peak_rss_kb", "scheduler",
                           "replicas", "ranks"] {
                assert!(row.get(absent).is_none(),
                        "bitwidth row must not carry '{absent}'");
            }
        }
        // --bits override collapses the sweep to its single cell
        let opts = RunnerOpts {
            epochs: 1,
            bits: Some(BitsPlan::uniform(BitwidthCfg::uniform(16))),
            out_dir: format!("{dir}/o/results"),
            bench_dir: format!("{dir}/o"),
            ..Default::default()
        };
        let bench = execute(&spec, &opts).unwrap();
        let rows = bench.req("rows").unwrap().as_array().unwrap();
        assert_eq!(rows.len(), 2, "one nitro cell + fp-bp");
        assert!(rows.iter().any(|r| {
            r.req("bits").unwrap().as_str() == Some("16/16/64/64")
        }));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// `--ranks 2` (loopback distributed world) must leave every metric
    /// key untouched relative to the single-rank run — the same
    /// invariance CI asserts for schedulers and replicas, here enforced
    /// through the in-runner world (which also byte-compares final
    /// weights across ranks internally).
    #[test]
    fn ranks_world_is_metric_identical() {
        let spec = ExperimentSpec::load_builtin("smoke").unwrap();
        let dir = std::env::temp_dir().join("nitro_runner_ranks_test");
        let dir = dir.to_str().unwrap().to_string();
        let run = |ranks: Option<usize>, sub: &str| {
            let opts = RunnerOpts {
                epochs: 1,
                ranks,
                out_dir: format!("{dir}/{sub}/results"),
                bench_dir: format!("{dir}/{sub}"),
                ..Default::default()
            };
            execute(&spec, &opts).unwrap()
        };
        let solo = run(None, "r1");
        let world = run(Some(2), "r2");
        let nitro_rows = |b: &Json| -> Vec<Json> {
            b.req("rows")
                .unwrap()
                .as_array()
                .unwrap()
                .iter()
                .filter(|r| r.req("engine").unwrap().as_str()
                    == Some("nitro"))
                .cloned()
                .collect()
        };
        let (a, b) = (nitro_rows(&solo), nitro_rows(&world));
        assert_eq!(a.len(), 1);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].req("ranks").unwrap().as_i64(), Some(2));
        for key in ["final_test_acc", "final_train_acc", "diverged"] {
            assert_eq!(a[0].req(key).unwrap(), b[0].req(key).unwrap(),
                       "'{key}' must be rank-invariant");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
