//! Data pipeline: integer MAD pre-processing (paper App. B.2), synthetic
//! dataset generators (DESIGN.md §Substitutions — no network access, so
//! MNIST/FashionMNIST/CIFAR-10 are replaced by shape- and
//! difficulty-matched synthetic sets; real IDX/CIFAR files are picked up
//! from `data/` when present), and the shuffled batcher.

pub mod loader;
pub mod synthetic;

use crate::tensor::ITensor;
use crate::util::rng::Pcg32;

/// A labelled integer image-classification dataset. Pixels are raw int
/// (e.g. 0..255) until [`Dataset::mad_normalize`] is applied.
#[derive(Clone)]
pub struct Dataset {
    pub name: String,
    /// (C, H, W)
    pub shape: Vec<usize>,
    pub num_classes: usize,
    /// len = n * C*H*W
    pub images: Vec<i32>,
    pub labels: Vec<usize>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn sample_size(&self) -> usize {
        self.shape.iter().product()
    }

    /// Integer-only MAD normalization over the whole dataset (paper App.
    /// B.2): `x̂ = (x − µ_int) · 51 / ω_int` with floor division — mirrors
    /// `ref.mad_normalize` bit-exactly.
    pub fn mad_normalize(&mut self) {
        let n = self.images.len() as i64;
        if n == 0 {
            return;
        }
        let sum: i64 = self.images.iter().map(|&v| v as i64).sum();
        let mu = sum.div_euclid(n);
        let dev: i64 = self.images.iter().map(|&v| (v as i64 - mu).abs()).sum();
        let omega = dev.div_euclid(n).max(1);
        for v in &mut self.images {
            *v = (((*v as i64 - mu) * 51).div_euclid(omega)) as i32;
        }
    }

    /// Pull a batch by indices into an (B, C, H, W) / (B, F) tensor.
    pub fn gather(&self, idxs: &[usize], flatten: bool) -> (ITensor, Vec<usize>) {
        let mut x = ITensor::empty();
        let mut labels = Vec::with_capacity(idxs.len());
        self.gather_into(idxs, flatten, &mut x, &mut labels);
        (x, labels)
    }

    /// [`Self::gather`] into caller-owned buffers, reusing their
    /// allocations: the training loop recycles one batch tensor (or, in
    /// pipelined mode, a bounded ring of them) across every iteration of
    /// every epoch, so the steady state performs no per-batch gather
    /// allocation.
    pub fn gather_into(&self, idxs: &[usize], flatten: bool, x: &mut ITensor,
                       labels: &mut Vec<usize>) {
        let ss = self.sample_size();
        x.data.clear();
        x.data.reserve(idxs.len() * ss);
        labels.clear();
        labels.reserve(idxs.len());
        for &i in idxs {
            x.data.extend_from_slice(&self.images[i * ss..(i + 1) * ss]);
            labels.push(self.labels[i]);
        }
        x.shape.clear();
        x.shape.push(idxs.len());
        if flatten || self.shape.len() == 1 {
            x.shape.push(ss);
        } else {
            x.shape.extend(&self.shape);
        }
    }

    /// Split off the last `n` samples as a test set.
    pub fn split_test(mut self, n: usize) -> (Dataset, Dataset) {
        let n = n.min(self.len());
        let train_n = self.len() - n;
        let ss = self.sample_size();
        let test = Dataset {
            name: format!("{}-test", self.name),
            shape: self.shape.clone(),
            num_classes: self.num_classes,
            images: self.images.split_off(train_n * ss),
            labels: self.labels.split_off(train_n),
        };
        (self, test)
    }
}

/// Epoch iterator producing shuffled batches.
pub struct Batcher<'a> {
    ds: &'a Dataset,
    order: Vec<usize>,
    pos: usize,
    pub batch: usize,
    flatten: bool,
}

impl<'a> Batcher<'a> {
    pub fn new(ds: &'a Dataset, batch: usize, flatten: bool,
               rng: &mut Pcg32) -> Self {
        let mut order: Vec<usize> = (0..ds.len()).collect();
        rng.shuffle(&mut order);
        Batcher { ds, order, pos: 0, batch, flatten }
    }

    /// Sequential (unshuffled) order — evaluation.
    pub fn sequential(ds: &'a Dataset, batch: usize, flatten: bool) -> Self {
        Batcher {
            ds,
            order: (0..ds.len()).collect(),
            pos: 0,
            batch,
            flatten,
        }
    }

    /// Whether another batch remains in this epoch. Lets callers that
    /// must acquire a buffer before gathering (the pipeline's recycle
    /// ring) avoid taking one they would immediately strand.
    pub fn has_next(&self) -> bool {
        self.pos < self.order.len()
    }

    /// Streaming variant of `next()`: gather the next batch into
    /// caller-owned buffers (see [`Dataset::gather_into`]), returning
    /// `false` when the epoch is exhausted. The hot training loops use
    /// this; the `Iterator` impl stays for callers that want owned
    /// batches.
    pub fn next_into(&mut self, x: &mut ITensor, labels: &mut Vec<usize>)
                     -> bool {
        if self.pos >= self.order.len() {
            return false;
        }
        let end = (self.pos + self.batch).min(self.order.len());
        let idxs = &self.order[self.pos..end];
        self.pos = end;
        self.ds.gather_into(idxs, self.flatten, x, labels);
        true
    }
}

impl<'a> Iterator for Batcher<'a> {
    type Item = (ITensor, Vec<usize>);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos >= self.order.len() {
            return None;
        }
        let end = (self.pos + self.batch).min(self.order.len());
        let idxs = &self.order[self.pos..end];
        self.pos = end;
        Some(self.ds.gather(idxs, self.flatten))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            name: "t".into(),
            shape: vec![1, 2, 2],
            num_classes: 2,
            images: (0..40).map(|v| (v * 13) % 256).collect(),
            labels: (0..10).map(|i| i % 2).collect(),
        }
    }

    #[test]
    fn mad_normalize_properties() {
        let mut ds = tiny();
        ds.mad_normalize();
        let n = ds.images.len() as i64;
        let mean = ds.images.iter().map(|&v| v as i64).sum::<i64>() / n;
        assert!(mean.abs() <= 2, "mean {mean}");
        let mad = ds.images.iter().map(|&v| (v as i64).abs()).sum::<i64>() / n;
        assert!((30..=70).contains(&mad), "mad {mad}");
    }

    #[test]
    fn mad_normalize_matches_python_pin() {
        // mirror of ref.mad_normalize on a fixed vector
        let mut ds = Dataset {
            name: "p".into(),
            shape: vec![1, 1, 5],
            num_classes: 1,
            images: vec![0, 50, 100, 200, 255],
            labels: vec![0],
        };
        // mu = 605 // 5 = 121; dev = 121+71+21+79+134 = 426; omega = 85
        ds.mad_normalize();
        let want: Vec<i32> = [0i64, 50, 100, 200, 255]
            .iter()
            .map(|&x| (((x - 121) * 51).div_euclid(85)) as i32)
            .collect();
        assert_eq!(ds.images, want);
    }

    #[test]
    fn batcher_covers_every_sample_once() {
        let ds = tiny();
        let mut rng = Pcg32::new(4);
        let mut seen = vec![0usize; ds.len()];
        for (x, labels) in Batcher::new(&ds, 3, false, &mut rng) {
            assert_eq!(x.shape[1..], [1, 2, 2]);
            assert!(labels.len() <= 3);
            for (bi, &l) in labels.iter().enumerate() {
                // recover the index by matching the first pixel
                let px = x.data[bi * 4];
                let idx = ds.images.chunks(4).position(|c| c[0] == px).unwrap();
                assert_eq!(ds.labels[idx], l);
                seen[idx] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
    }

    #[test]
    fn next_into_matches_iterator_and_reuses_buffers() {
        let ds = tiny();
        let mut rng_a = Pcg32::new(4);
        let mut rng_b = Pcg32::new(4);
        let owned: Vec<_> = Batcher::new(&ds, 3, false, &mut rng_a).collect();
        let mut b = Batcher::new(&ds, 3, false, &mut rng_b);
        let mut x = ITensor::empty();
        let mut labels = Vec::new();
        let mut got = 0usize;
        let mut cap_after_first = 0usize;
        while b.next_into(&mut x, &mut labels) {
            assert_eq!((&x, &labels), (&owned[got].0, &owned[got].1));
            if got == 0 {
                cap_after_first = x.data.capacity();
            } else {
                assert_eq!(x.data.capacity(), cap_after_first,
                           "batch buffer must be reused, not reallocated");
            }
            got += 1;
        }
        assert_eq!(got, owned.len());
    }

    #[test]
    fn gather_flatten() {
        let ds = tiny();
        let (x, _) = ds.gather(&[0, 3], true);
        assert_eq!(x.shape, vec![2, 4]);
    }

    #[test]
    fn split_test_sizes() {
        let (tr, te) = tiny().split_test(3);
        assert_eq!(tr.len(), 7);
        assert_eq!(te.len(), 3);
        assert_eq!(te.images.len(), 3 * 4);
    }
}
