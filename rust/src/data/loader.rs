//! Loaders for real datasets when files are present under `data/`:
//! IDX (MNIST/FashionMNIST `*-images-idx3-ubyte`, `*-labels-idx1-ubyte`)
//! and the CIFAR-10 binary format (`data_batch_*.bin`). Falls back to the
//! synthetic generators otherwise (DESIGN.md §Substitutions).

use super::{synthetic, Dataset};

/// Resolve a dataset name: real files if available, else synthetic.
/// Synthetic sizes: `n_train + n_test` samples.
pub fn load(name: &str, dir: &str, n_train: usize, n_test: usize,
            seed: u64) -> Result<(Dataset, Dataset), String> {
    match name {
        "mnist" | "fashion-mnist" => {
            let prefix = if name == "mnist" { "" } else { "fashion-" };
            let tr_img = format!("{dir}/{prefix}train-images-idx3-ubyte");
            let tr_lbl = format!("{dir}/{prefix}train-labels-idx1-ubyte");
            let te_img = format!("{dir}/{prefix}t10k-images-idx3-ubyte");
            let te_lbl = format!("{dir}/{prefix}t10k-labels-idx1-ubyte");
            let files = [&tr_img, &tr_lbl, &te_img, &te_lbl];
            match probe_file_set(name, dir, &files)? {
                true => {
                    let tr = load_idx_pair(name, &tr_img, &tr_lbl)?;
                    let te = load_idx_pair(name, &te_img, &te_lbl)?;
                    Ok((tr, te))
                }
                false => {
                    let syn = if name == "mnist" {
                        "mnist-like"
                    } else {
                        "fashion-like"
                    };
                    synth_pair(syn, n_train, n_test, seed)
                }
            }
        }
        "cifar10" => {
            let mut files: Vec<String> = (1..=5)
                .map(|i| format!("{dir}/data_batch_{i}.bin"))
                .collect();
            files.push(format!("{dir}/test_batch.bin"));
            match probe_file_set(name, dir, &files)? {
                true => {
                    let mut tr = load_cifar_bin(&files[0])?;
                    for f in &files[1..5] {
                        let more = load_cifar_bin(f)?;
                        tr.images.extend(more.images);
                        tr.labels.extend(more.labels);
                    }
                    let te = load_cifar_bin(&files[5])?;
                    Ok((tr, te))
                }
                false => synth_pair("cifar-like", n_train, n_test, seed),
            }
        }
        other => {
            // direct synthetic name
            if synthetic::by_name(other, 1, 0).is_some() {
                synth_pair(other, n_train, n_test, seed)
            } else {
                Err(format!("unknown dataset '{other}'"))
            }
        }
    }
}

/// Probe a dataset's complete file set up front. `Ok(true)` = every file
/// present (commit to the real-file path), `Ok(false)` = none present
/// (fall back to synthetic), `Err` naming the missing file(s) when the
/// directory is only partially populated — a partial download must fail
/// loudly here, not as a confusing error deep inside a reader.
fn probe_file_set<S: AsRef<str>>(name: &str, dir: &str, files: &[S])
                                 -> Result<bool, String> {
    let missing: Vec<&str> = files
        .iter()
        .map(|f| f.as_ref())
        .filter(|f| !std::path::Path::new(f).exists())
        .collect();
    if missing.is_empty() {
        Ok(true)
    } else if missing.len() == files.len() {
        Ok(false)
    } else {
        Err(format!(
            "{name}: data dir '{dir}' is incomplete — missing {}; \
             restore the full file set or remove the directory to use the \
             synthetic fallback",
            missing.join(", ")
        ))
    }
}

fn synth_pair(name: &str, n_train: usize, n_test: usize, seed: u64)
              -> Result<(Dataset, Dataset), String> {
    let ds = synthetic::by_name(name, n_train + n_test, seed)
        .ok_or_else(|| format!("unknown synthetic dataset '{name}'"))?;
    Ok(ds.split_test(n_test))
}

/// Parse an IDX images + labels file pair.
pub fn load_idx_pair(name: &str, images: &str, labels: &str)
                     -> Result<Dataset, String> {
    let img = std::fs::read(images).map_err(|e| format!("{images}: {e}"))?;
    let lbl = std::fs::read(labels).map_err(|e| format!("{labels}: {e}"))?;
    let (shape, pixels) = parse_idx(&img)?;
    if shape.len() != 3 {
        return Err(format!("{images}: expected idx3, got rank {}", shape.len()));
    }
    let (lshape, lab) = parse_idx(&lbl)?;
    if lshape.len() != 1 || lshape[0] != shape[0] {
        return Err(format!("{labels}: label count mismatch"));
    }
    Ok(Dataset {
        name: name.to_string(),
        shape: vec![1, shape[1], shape[2]],
        num_classes: 10,
        images: pixels.iter().map(|&b| b as i32).collect(),
        labels: lab.iter().map(|&b| b as usize).collect(),
    })
}

/// Parse the IDX container: magic 0x00 0x08 rank, then rank u32 dims, then
/// u8 payload.
fn parse_idx(buf: &[u8]) -> Result<(Vec<usize>, &[u8]), String> {
    if buf.len() < 4 || buf[0] != 0 || buf[1] != 0 || buf[2] != 0x08 {
        return Err("bad idx magic".into());
    }
    let rank = buf[3] as usize;
    let mut dims = Vec::with_capacity(rank);
    let mut off = 4;
    for _ in 0..rank {
        if off + 4 > buf.len() {
            return Err("truncated idx header".into());
        }
        dims.push(u32::from_be_bytes(buf[off..off + 4].try_into().unwrap())
            as usize);
        off += 4;
    }
    let n: usize = dims.iter().product();
    if buf.len() < off + n {
        return Err("truncated idx payload".into());
    }
    Ok((dims, &buf[off..off + n]))
}

/// CIFAR-10 binary: 10000 records of [label u8][3072 u8 pixels, CHW].
pub fn load_cifar_bin(path: &str) -> Result<Dataset, String> {
    let buf = std::fs::read(path).map_err(|e| format!("{path}: {e}"))?;
    const REC: usize = 3073;
    if buf.len() % REC != 0 {
        return Err(format!("{path}: not a multiple of {REC}"));
    }
    let n = buf.len() / REC;
    let mut images = Vec::with_capacity(n * 3072);
    let mut labels = Vec::with_capacity(n);
    for r in 0..n {
        labels.push(buf[r * REC] as usize);
        images.extend(buf[r * REC + 1..(r + 1) * REC].iter().map(|&b| b as i32));
    }
    Ok(Dataset {
        name: "cifar10".into(),
        shape: vec![3, 32, 32],
        num_classes: 10,
        images,
        labels,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_idx3(path: &std::path::Path, n: usize, h: usize, w: usize) {
        let mut buf = vec![0u8, 0, 0x08, 3];
        for d in [n, h, w] {
            buf.extend((d as u32).to_be_bytes());
        }
        buf.extend((0..n * h * w).map(|i| (i % 251) as u8));
        std::fs::write(path, buf).unwrap();
    }

    fn write_idx1(path: &std::path::Path, n: usize) {
        let mut buf = vec![0u8, 0, 0x08, 1];
        buf.extend((n as u32).to_be_bytes());
        buf.extend((0..n).map(|i| (i % 10) as u8));
        std::fs::write(path, buf).unwrap();
    }

    #[test]
    fn idx_roundtrip() {
        let dir = std::env::temp_dir().join("nitro_idx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let img = dir.join("img");
        let lbl = dir.join("lbl");
        write_idx3(&img, 7, 5, 4);
        write_idx1(&lbl, 7);
        let ds = load_idx_pair("x", img.to_str().unwrap(), lbl.to_str().unwrap())
            .unwrap();
        assert_eq!(ds.len(), 7);
        assert_eq!(ds.shape, vec![1, 5, 4]);
        assert_eq!(ds.images[1], 1);
        assert_eq!(ds.labels[3], 3);
    }

    #[test]
    fn idx_rejects_bad_magic() {
        assert!(parse_idx(&[1, 2, 3, 4]).is_err());
        assert!(parse_idx(&[0, 0, 0x08, 1, 0, 0]).is_err()); // truncated
    }

    #[test]
    fn cifar_bin_roundtrip() {
        let dir = std::env::temp_dir().join("nitro_cifar_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("batch.bin");
        let mut buf = Vec::new();
        for r in 0..3u8 {
            buf.push(r); // label
            buf.extend(std::iter::repeat(r * 10).take(3072));
        }
        std::fs::write(&p, &buf).unwrap();
        let ds = load_cifar_bin(p.to_str().unwrap()).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.labels, vec![0, 1, 2]);
        assert_eq!(ds.images[3072], 10);
    }

    #[test]
    fn falls_back_to_synthetic() {
        let (tr, te) = load("mnist", "/nonexistent", 60, 20, 5).unwrap();
        assert_eq!(tr.len(), 60);
        assert_eq!(te.len(), 20);
        assert_eq!(tr.shape, vec![1, 28, 28]);
    }

    #[test]
    fn partial_mnist_dir_errors_naming_missing_files() {
        // only the train-images file present: a partial download must be
        // a loud up-front error, not a synthetic fallback or a late read
        // failure on the labels file
        let dir = std::env::temp_dir().join("nitro_partial_mnist");
        std::fs::create_dir_all(&dir).unwrap();
        write_idx3(&dir.join("train-images-idx3-ubyte"), 4, 28, 28);
        let err =
            load("mnist", dir.to_str().unwrap(), 10, 5, 1).unwrap_err();
        assert!(err.contains("incomplete"), "{err}");
        assert!(err.contains("train-labels-idx1-ubyte"), "{err}");
        assert!(err.contains("t10k-images-idx3-ubyte"), "{err}");
        assert!(!err.contains("train-images-idx3-ubyte,"),
                "present file listed as missing: {err}");
    }

    #[test]
    fn partial_cifar_dir_errors_naming_missing_files() {
        let dir = std::env::temp_dir().join("nitro_partial_cifar");
        std::fs::create_dir_all(&dir).unwrap();
        // two of six files present
        for f in ["data_batch_1.bin", "data_batch_2.bin"] {
            let mut buf = vec![0u8];
            buf.extend(std::iter::repeat(7u8).take(3072));
            std::fs::write(dir.join(f), &buf).unwrap();
        }
        let err =
            load("cifar10", dir.to_str().unwrap(), 10, 5, 1).unwrap_err();
        assert!(err.contains("incomplete"), "{err}");
        for f in ["data_batch_3.bin", "data_batch_4.bin",
                  "data_batch_5.bin", "test_batch.bin"] {
            assert!(err.contains(f), "missing {f} in: {err}");
        }
    }

    #[test]
    fn complete_cifar_dir_loads_all_batches() {
        let dir = std::env::temp_dir().join("nitro_full_cifar");
        std::fs::create_dir_all(&dir).unwrap();
        for (i, f) in ["data_batch_1.bin", "data_batch_2.bin",
                       "data_batch_3.bin", "data_batch_4.bin",
                       "data_batch_5.bin", "test_batch.bin"]
            .iter()
            .enumerate()
        {
            let mut buf = vec![(i % 10) as u8];
            buf.extend(std::iter::repeat(i as u8).take(3072));
            std::fs::write(dir.join(f), &buf).unwrap();
        }
        let (tr, te) = load("cifar10", dir.to_str().unwrap(), 0, 0, 1)
            .unwrap();
        assert_eq!(tr.len(), 5, "one record per train batch file");
        assert_eq!(te.len(), 1);
        assert_eq!(tr.labels, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn unknown_dataset_errors() {
        assert!(load("bogus", "/tmp", 1, 1, 0).is_err());
    }
}
