//! Synthetic dataset generators (DESIGN.md §Substitutions).
//!
//! The paper evaluates on MNIST, FashionMNIST and CIFAR-10; this image has
//! no network access, so we synthesize class-separable image datasets with
//! matching shapes and tunable difficulty. Each class gets a deterministic
//! structured prototype (oriented bars + blobs — enough spatial structure
//! that convolution genuinely beats a linear model); samples are prototype
//! + per-sample jitter (shift, amplitude, pixel noise), quantized to 0..255
//! like real 8-bit images so the MAD pre-processing path is exercised
//! end-to-end.
//!
//! What this preserves from the paper's evaluation: relative orderings
//! (NITRO-D vs baselines), learning dynamics, and the integer bit-width
//! phenomena. What it cannot preserve: absolute accuracy values.

use super::Dataset;
use crate::util::rng::Pcg32;

/// Difficulty knob: pixel-noise amplitude (0..128) and max shift.
#[derive(Clone, Copy, Debug)]
pub struct Difficulty {
    pub noise: i32,
    pub max_shift: usize,
    /// Amplitude jitter in percent.
    pub amp_jitter: i32,
}

impl Difficulty {
    /// MNIST-like: easy, well-separated classes.
    pub fn easy() -> Self {
        Difficulty { noise: 18, max_shift: 1, amp_jitter: 10 }
    }

    /// FashionMNIST-like: moderate overlap.
    pub fn medium() -> Self {
        Difficulty { noise: 36, max_shift: 2, amp_jitter: 20 }
    }

    /// CIFAR-like: heavy noise + shifts; linear models degrade hard.
    pub fn hard() -> Self {
        Difficulty { noise: 60, max_shift: 3, amp_jitter: 35 }
    }
}

/// Dataset presets mirroring the paper's three benchmarks.
pub fn by_name(name: &str, n: usize, seed: u64) -> Option<Dataset> {
    Some(match name {
        "mnist-like" => generate("mnist-like", (1, 28, 28), 10, n,
                                 Difficulty::easy(), seed),
        "fashion-like" => generate("fashion-like", (1, 28, 28), 10, n,
                                   Difficulty::medium(), seed),
        "cifar-like" => generate("cifar-like", (3, 32, 32), 10, n,
                                 Difficulty::hard(), seed),
        "tiny" => generate("tiny", (1, 8, 8), 10, n, Difficulty::easy(), seed),
        _ => return None,
    })
}

pub fn names() -> &'static [&'static str] {
    &["mnist-like", "fashion-like", "cifar-like", "tiny"]
}

/// Build `n` samples of a `(c, h, w)` dataset with `classes` classes.
pub fn generate(name: &str, chw: (usize, usize, usize), classes: usize,
                n: usize, diff: Difficulty, seed: u64) -> Dataset {
    let (c, h, w) = chw;
    let mut proto_rng = Pcg32::with_stream(seed, 0x70726f74);
    let protos: Vec<Vec<i32>> = (0..classes)
        .map(|cls| prototype(&mut proto_rng, cls, c, h, w))
        .collect();
    let mut rng = Pcg32::with_stream(seed, 0x73616d70);
    let ss = c * h * w;
    let mut images = Vec::with_capacity(n * ss);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let cls = i % classes;
        labels.push(cls);
        let dy = rng.range_i32(-(diff.max_shift as i32), diff.max_shift as i32);
        let dx = rng.range_i32(-(diff.max_shift as i32), diff.max_shift as i32);
        let amp = 100 + rng.range_i32(-diff.amp_jitter, diff.amp_jitter);
        for ci in 0..c {
            for y in 0..h {
                for x in 0..w {
                    let sy = y as i32 + dy;
                    let sx = x as i32 + dx;
                    let base = if sy >= 0 && sy < h as i32 && sx >= 0
                        && sx < w as i32
                    {
                        protos[cls][(ci * h + sy as usize) * w + sx as usize]
                    } else {
                        0
                    };
                    let v = base * amp / 100 + rng.range_i32(-diff.noise, diff.noise);
                    images.push(v.clamp(0, 255));
                }
            }
        }
    }
    // deterministic interleave -> shuffle so splits are class-balanced
    let mut order: Vec<usize> = (0..n).collect();
    let mut shuffle_rng = Pcg32::with_stream(seed, 0x73687566);
    shuffle_rng.shuffle(&mut order);
    let mut s_images = Vec::with_capacity(n * ss);
    let mut s_labels = Vec::with_capacity(n);
    for &i in &order {
        s_images.extend_from_slice(&images[i * ss..(i + 1) * ss]);
        s_labels.push(labels[i]);
    }
    Dataset {
        name: name.to_string(),
        shape: vec![c, h, w],
        num_classes: classes,
        images: s_images,
        labels: s_labels,
    }
}

/// Structured class prototype: an oriented bar + 2 gaussian-ish blobs +
/// class-dependent checker field, per channel. Values 0..200.
fn prototype(rng: &mut Pcg32, cls: usize, c: usize, h: usize, w: usize)
             -> Vec<i32> {
    let mut img = vec![0i32; c * h * w];
    let angle = cls as f64 * std::f64::consts::PI / 5.0;
    let (sin, cos) = angle.sin_cos();
    for ci in 0..c {
        // oriented bar through the centre
        for y in 0..h {
            for x in 0..w {
                let fy = y as f64 - h as f64 / 2.0;
                let fx = x as f64 - w as f64 / 2.0;
                let d = (fx * sin - fy * cos).abs();
                let bar = (140.0 * (-d * d / 6.0).exp()) as i32;
                img[(ci * h + y) * w + x] += bar;
            }
        }
        // two blobs at class-dependent positions
        for b in 0..2 {
            let cy = ((cls * 7 + b * 11 + ci * 3) % h) as f64;
            let cx = ((cls * 13 + b * 5 + ci * 7) % w) as f64;
            let amp = 60.0 + rng.below(40) as f64;
            for y in 0..h {
                for x in 0..w {
                    let dy = y as f64 - cy;
                    let dx = x as f64 - cx;
                    let v = (amp * (-(dy * dy + dx * dx) / 8.0).exp()) as i32;
                    img[(ci * h + y) * w + x] += v;
                }
            }
        }
    }
    for v in &mut img {
        *v = (*v).clamp(0, 200);
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_balance() {
        let ds = by_name("mnist-like", 200, 1).unwrap();
        assert_eq!(ds.shape, vec![1, 28, 28]);
        assert_eq!(ds.len(), 200);
        let mut counts = vec![0usize; 10];
        for &l in &ds.labels {
            counts[l] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20), "{counts:?}");
        assert!(ds.images.iter().all(|&v| (0..=255).contains(&v)));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = by_name("tiny", 50, 9).unwrap();
        let b = by_name("tiny", 50, 9).unwrap();
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let c = by_name("tiny", 50, 10).unwrap();
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn classes_are_separable_by_nearest_prototype() {
        // sanity: a trivial nearest-class-mean classifier on the raw pixels
        // must beat chance by a wide margin on the easy preset, and the
        // hard preset must be harder than the easy one.
        for (name, min_acc) in [("mnist-like", 0.8), ("cifar-like", 0.35)] {
            let ds = by_name(name, 400, 3).unwrap();
            let ss = ds.sample_size();
            let mut means = vec![vec![0i64; ss]; ds.num_classes];
            let mut counts = vec![0i64; ds.num_classes];
            for (i, &l) in ds.labels.iter().enumerate().take(200) {
                counts[l] += 1;
                for (m, &px) in means[l].iter_mut().zip(&ds.images[i * ss..(i + 1) * ss]) {
                    *m += px as i64;
                }
            }
            for (m, &cnt) in means.iter_mut().zip(&counts) {
                for v in m.iter_mut() {
                    *v /= cnt.max(1);
                }
            }
            let mut correct = 0;
            for i in 200..400 {
                let img = &ds.images[i * ss..(i + 1) * ss];
                let mut best = (i64::MAX, 0usize);
                for (cls, m) in means.iter().enumerate() {
                    let d: i64 = img
                        .iter()
                        .zip(m)
                        .map(|(&a, &b)| {
                            let d = a as i64 - b;
                            d * d
                        })
                        .sum();
                    if d < best.0 {
                        best = (d, cls);
                    }
                }
                if best.1 == ds.labels[i] {
                    correct += 1;
                }
            }
            let acc = correct as f64 / 200.0;
            assert!(acc >= min_acc, "{name}: nearest-mean acc {acc}");
        }
    }
}
