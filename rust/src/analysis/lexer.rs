//! Token-level lexer for `nitro lint`.
//!
//! A hand-rolled scanner, not a full Rust parser: it produces just
//! enough structure for the rule passes — identifier / integer / float /
//! punctuation / lifetime / string tokens with 1-based line numbers —
//! while being exact about the places a naive scanner goes wrong:
//! nested block comments, raw and byte strings (`r#"..."#`, `b"..."`),
//! char literals vs lifetimes (`'a'` vs `'a`), float literals including
//! exponents and `f32`/`f64` suffixes, and escaped newlines inside
//! string literals (they still advance the line counter, so diagnostics
//! after a long string point at the right line).
//!
//! Comments are also where the allow escapes live; [`lex`] extracts
//! them while scanning, so rule passes never re-read the source.

/// The rule names an allow comment may reference.
pub const KNOWN_RULES: [&str; 4] =
    ["int-discipline", "no-float", "no-panic", "determinism"];

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Int,
    Float,
    Punct,
    Lifetime,
    /// String and char literals; their content never matters to a rule,
    /// so the text is dropped.
    Str,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

/// A parsed `allow` escape comment. A non-file-wide allow covers its
/// own line and the next one, so it can sit above the flagged line.
#[derive(Clone, Debug)]
pub struct Allow {
    pub line: usize,
    pub rules: Vec<String>,
    pub reason: String,
    pub file_wide: bool,
}

/// Everything one scan of a file produces: the token stream, the
/// well-formed allow escapes, and the malformed ones (reported as
/// `allow-syntax` findings — a broken escape must never silently
/// suppress anything).
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub allows: Vec<Allow>,
    pub bad_allows: Vec<(usize, String)>,
}

fn is_ident_start(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphabetic()
}

fn is_ident_cont(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

const PUNCTS: &[&str] = &[
    "<<=", ">>=", "...", "..=", "::", "->", "=>", "<<", ">>", "+=", "-=",
    "*=", "/=", "%=", "&&", "||", "==", "!=", "<=", ">=", "&=", "|=",
    "^=", "..",
];

pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut allows = Vec::new();
    let mut bad_allows = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        if b[i..].starts_with(b"//") {
            let j = b[i..]
                .iter()
                .position(|&x| x == b'\n')
                .map(|p| i + p)
                .unwrap_or(n);
            parse_allow(&src[i..j], line, &mut allows, &mut bad_allows);
            i = j;
            continue;
        }
        if b[i..].starts_with(b"/*") {
            let mut depth = 1;
            i += 2;
            while i < n && depth > 0 {
                if b[i..].starts_with(b"/*") {
                    depth += 1;
                    i += 2;
                } else if b[i..].starts_with(b"*/") {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        if matches!(c, b'r' | b'b' | b'R' | b'B') && is_raw_or_byte_str(b, i)
        {
            let (ni, nl) = skip_raw_str(b, i, line);
            i = ni;
            line = nl;
            toks.push(Tok { kind: TokKind::Str, text: String::new(), line });
            continue;
        }
        if c == b'"' {
            i += 1;
            while i < n {
                if b[i] == b'\\' {
                    if i + 1 < n && b[i + 1] == b'\n' {
                        line += 1;
                    }
                    i += 2;
                    continue;
                }
                if b[i] == b'\n' {
                    line += 1;
                }
                if b[i] == b'"' {
                    i += 1;
                    break;
                }
                i += 1;
            }
            toks.push(Tok { kind: TokKind::Str, text: String::new(), line });
            continue;
        }
        if c == b'\'' {
            // `'a` (lifetime) vs `'a'` (char literal): a lifetime is a
            // quote + identifier NOT followed by a closing quote
            if i + 1 < n
                && is_ident_start(b[i + 1])
                && !(i + 2 < n && b[i + 2] == b'\'')
            {
                let mut j = i + 1;
                while j < n && is_ident_cont(b[j]) {
                    j += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: src[i..j].to_string(),
                    line,
                });
                i = j;
                continue;
            }
            i += 1;
            while i < n {
                if b[i] == b'\\' {
                    i += 2;
                    continue;
                }
                if b[i] == b'\'' {
                    i += 1;
                    break;
                }
                i += 1;
            }
            toks.push(Tok { kind: TokKind::Str, text: String::new(), line });
            continue;
        }
        if is_ident_start(c) {
            let mut j = i;
            while j < n && is_ident_cont(b[j]) {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: src[i..j].to_string(),
                line,
            });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            let mut isfloat = false;
            if c == b'0' && i + 1 < n && matches!(b[i + 1], b'x' | b'o' | b'b')
            {
                j = i + 2;
                while j < n && is_ident_cont(b[j]) {
                    j += 1;
                }
            } else {
                while j < n && (b[j].is_ascii_digit() || b[j] == b'_') {
                    j += 1;
                }
                // `1.5` is a float; `1..n` is a range; `1.max(x)` is a
                // method call on an integer
                if j < n && b[j] == b'.' && !(j + 1 < n && b[j + 1] == b'.') {
                    if j + 1 >= n || !is_ident_start(b[j + 1]) {
                        isfloat = true;
                        j += 1;
                        while j < n && (b[j].is_ascii_digit() || b[j] == b'_')
                        {
                            j += 1;
                        }
                    }
                }
                if j < n && matches!(b[j], b'e' | b'E') {
                    let mut k = j + 1;
                    if k < n && matches!(b[k], b'+' | b'-') {
                        k += 1;
                    }
                    if k < n && b[k].is_ascii_digit() {
                        isfloat = true;
                        j = k;
                        while j < n && (b[j].is_ascii_digit() || b[j] == b'_')
                        {
                            j += 1;
                        }
                    }
                }
                let sfx = j;
                while j < n && is_ident_cont(b[j]) {
                    j += 1;
                }
                let suffix = &src[sfx..j];
                if suffix == "f32" || suffix == "f64" {
                    isfloat = true;
                }
            }
            toks.push(Tok {
                kind: if isfloat { TokKind::Float } else { TokKind::Int },
                text: src[i..j].to_string(),
                line,
            });
            i = j;
            continue;
        }
        let mut matched = false;
        for &op in PUNCTS {
            if b[i..].starts_with(op.as_bytes()) {
                toks.push(Tok {
                    kind: TokKind::Punct,
                    text: op.to_string(),
                    line,
                });
                i += op.len();
                matched = true;
                break;
            }
        }
        if !matched {
            toks.push(Tok {
                kind: TokKind::Punct,
                text: (c as char).to_string(),
                line,
            });
            i += 1;
        }
    }
    Lexed { toks, allows, bad_allows }
}

/// `r"..."`, `r#"..."#`, `b"..."`, `br#"..."#` and friends: at most two
/// prefix letters, then optional hashes, then a quote.
fn is_raw_or_byte_str(b: &[u8], i: usize) -> bool {
    let mut j = i;
    while j < b.len() && matches!(b[j], b'b' | b'r' | b'B' | b'R') && j - i < 2
    {
        j += 1;
    }
    while j < b.len() && b[j] == b'#' {
        j += 1;
    }
    j < b.len() && b[j] == b'"' && j > i
}

/// Skip a raw/byte string starting at `start`; returns (index after the
/// closing delimiter, updated line counter). Plain byte strings still
/// process escapes; raw strings do not.
fn skip_raw_str(b: &[u8], start: usize, mut line: usize) -> (usize, usize) {
    let mut j = start;
    while j < b.len() && matches!(b[j], b'b' | b'r' | b'B' | b'R') {
        j += 1;
    }
    let mut hashes = 0usize;
    while j < b.len() && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    j += 1; // opening quote
    let prefix_end = b.len().min(start + 2);
    let raw = b[start..prefix_end]
        .iter()
        .any(|&x| x == b'r' || x == b'R');
    while j < b.len() {
        if b[j] == b'\\' && !raw {
            if j + 1 < b.len() && b[j + 1] == b'\n' {
                line += 1;
            }
            j += 2;
            continue;
        }
        if b[j] == b'\n' {
            line += 1;
        }
        if b[j] == b'"' {
            let mut h = 0usize;
            let mut k = j + 1;
            while k < b.len() && h < hashes && b[k] == b'#' {
                h += 1;
                k += 1;
            }
            if h == hashes {
                return (j + 1 + hashes, line);
            }
        }
        j += 1;
    }
    (j, line)
}

/// Parse an allow escape out of one `//` comment, if present. The
/// accepted grammar (also documented in README §Static analysis):
/// an `allow(rule[,rule...])` or `allow-file(rule[,rule...])` marker
/// introduced by the tool name and a colon, followed by a mandatory
/// free-text justification of at least 8 characters that is not an
/// unedited `FIXME` placeholder. Anything that names the tool but does
/// not parse lands in `bad` and becomes an `allow-syntax` finding.
pub fn parse_allow(
    comment: &str,
    line: usize,
    allows: &mut Vec<Allow>,
    bad: &mut Vec<(usize, String)>,
) {
    let marker = "nitro-lint:";
    let p = match comment.find(marker) {
        Some(p) => p,
        None => return,
    };
    let rest = comment[p + marker.len()..].trim();
    let (file_wide, body) = if let Some(r) = rest.strip_prefix("allow-file(")
    {
        (true, r)
    } else if let Some(r) = rest.strip_prefix("allow(") {
        (false, r)
    } else {
        bad.push((
            line,
            "nitro-lint comment must be `nitro-lint: allow(<rule>) \
             <reason>` or allow-file(...)"
                .to_string(),
        ));
        return;
    };
    let close = match body.find(')') {
        Some(c) => c,
        None => {
            bad.push((line, "unterminated allow( rule list".to_string()));
            return;
        }
    };
    let rules: Vec<String> = body[..close]
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    let reason = body[close + 1..].trim().to_string();
    if rules.is_empty()
        || rules.iter().any(|r| !KNOWN_RULES.contains(&r.as_str()))
    {
        bad.push((
            line,
            format!("unknown rule in allow(): '{}'", &body[..close]),
        ));
        return;
    }
    if reason.len() < 8 {
        bad.push((
            line,
            "allow() requires a justification (>= 8 chars) after the \
             rule list"
                .to_string(),
        ));
        return;
    }
    if reason.contains("FIXME") {
        bad.push((
            line,
            "allow() reason is an unedited FIXME placeholder".to_string(),
        ));
        return;
    }
    allows.push(Allow { line, rules, reason, file_wide });
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src)
            .toks
            .into_iter()
            .map(|t| (t.kind, t.text))
            .collect()
    }

    #[test]
    fn float_vs_int_vs_range_literals() {
        let toks = kinds("1 + 2.5 - 0x1f << 3e4 .. 1..4 7f64 8i32 9usize");
        let floats: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Float)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(floats, ["2.5", "3e4", "7f64"]);
        let ints: Vec<&str> = toks
            .iter()
            .filter(|(k, _)| *k == TokKind::Int)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(ints, ["1", "0x1f", "1", "4", "8i32", "9usize"]);
    }

    #[test]
    fn lifetime_vs_char_literal() {
        let toks = kinds("fn f<'a>(x: &'a str) { let c = 'a'; }");
        assert_eq!(
            toks.iter()
                .filter(|(k, _)| *k == TokKind::Lifetime)
                .count(),
            2
        );
        assert_eq!(
            toks.iter().filter(|(k, _)| *k == TokKind::Str).count(),
            1
        );
    }

    #[test]
    fn raw_strings_and_comments_hide_operators() {
        let toks = kinds(
            "let s = r#\"a + b\"#; /* x * y /* nested */ */ let t = \"c + d\";",
        );
        assert!(toks
            .iter()
            .all(|(k, t)| *k != TokKind::Punct || (t != "+" && t != "*")));
    }

    #[test]
    fn escaped_newline_in_string_keeps_line_numbers() {
        let l = lex("let s = \"a\\\n b\";\nlet x = 1;");
        let last = l.toks.last().expect("tokens");
        assert_eq!(last.line, 3, "line counter lost a string newline");
    }

    #[test]
    fn allow_grammar_accept_and_reject() {
        let ok = "// nitro-lint: allow(no-panic,no-float) length checked \
                  two lines up";
        let l = lex(ok);
        assert_eq!(l.bad_allows.len(), 0);
        assert_eq!(l.allows.len(), 1);
        assert_eq!(l.allows[0].rules, ["no-panic", "no-float"]);
        assert!(!l.allows[0].file_wide);

        let filewide =
            "// nitro-lint: allow-file(determinism) fixture data, not \
             compute";
        assert!(lex(filewide).allows[0].file_wide);

        for bad in [
            "// nitro-lint: allow(no-panic)",        // no reason
            "// nitro-lint: allow(no-panic) short",  // reason too short
            "// nitro-lint: allow(nonsense) some reason here", // bad rule
            "// nitro-lint: allow(no-panic some reason",       // unclosed
            "// nitro-lint: allowing things casually",         // bad verb
            "// nitro-lint: allow(no-panic) FIXME: justify this exemption",
        ] {
            let l = lex(bad);
            assert_eq!(l.allows.len(), 0, "accepted: {bad}");
            assert_eq!(l.bad_allows.len(), 1, "not rejected: {bad}");
        }
    }
}
