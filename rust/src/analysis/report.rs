//! Diagnostics for `nitro lint`: human-readable text and a
//! schema-versioned JSON report for CI tooling.

use crate::util::jsonio::Json;

/// One violation, anchored to a file and line.
pub struct Finding {
    /// Repo-relative path with forward slashes.
    pub file: String,
    pub line: usize,
    /// Rule id: one of `int-discipline`, `no-float`, `no-panic`,
    /// `determinism`, or `allow-syntax` for malformed escapes.
    pub rule: &'static str,
    pub msg: String,
}

/// Whole-tree scan result.
pub struct Report {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
    /// Violations suppressed by reasoned allow escapes.
    pub allowed: usize,
}

impl Report {
    /// `file:line: [rule] message` per finding, plus a summary line.
    pub fn text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!(
                "{}:{}: [{}] {}\n",
                f.file, f.line, f.rule, f.msg
            ));
        }
        out.push_str(&format!(
            "nitro lint: {} files scanned, {} violation(s), {} allowed\n",
            self.files_scanned,
            self.findings.len(),
            self.allowed
        ));
        out
    }

    /// Stable machine-readable form. `schema_version` is bumped on any
    /// breaking change to the layout; CI consumers key on it.
    pub fn to_json(&self) -> Json {
        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("file", Json::Str(f.file.clone())),
                    ("line", Json::Int(f.line as i64)),
                    ("rule", Json::Str(f.rule.to_string())),
                    ("message", Json::Str(f.msg.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema_version", Json::Int(1)),
            ("files_scanned", Json::Int(self.files_scanned as i64)),
            ("violations", Json::Int(self.findings.len() as i64)),
            ("allowed", Json::Int(self.allowed as i64)),
            ("findings", Json::Array(findings)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        Report {
            files_scanned: 42,
            findings: vec![Finding {
                file: "rust/src/tensor/ops_int.rs".to_string(),
                line: 7,
                rule: "int-discipline",
                msg: "bare `+` on integer data".to_string(),
            }],
            allowed: 3,
        }
    }

    #[test]
    fn text_has_location_rule_and_summary() {
        let t = sample().text();
        assert!(t.contains("rust/src/tensor/ops_int.rs:7: [int-discipline]"));
        assert!(t.contains("42 files scanned, 1 violation(s), 3 allowed"));
    }

    #[test]
    fn json_schema_is_stable() {
        let d = sample().to_json().dump();
        assert!(d.contains("\"schema_version\":1"), "{d}");
        assert!(d.contains("\"files_scanned\":42"), "{d}");
        assert!(d.contains("\"violations\":1"), "{d}");
        assert!(d.contains("\"allowed\":3"), "{d}");
        assert!(d.contains("\"rule\":\"int-discipline\""), "{d}");
        assert!(d.contains("\"line\":7"), "{d}");
    }
}
