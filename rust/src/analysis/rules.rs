//! Rule passes for `nitro lint`: token-stream analyses with just enough
//! type evidence to keep the integer-discipline rule precise.
//!
//! The analyses are deliberately syntactic — no name resolution, no
//! trait solving — but they track the evidence a reviewer would use:
//! function parameter types, `let` bindings, struct field declarations,
//! `for` loop induction variables (`usize`), `.len()`/`.capacity()`
//! calls (`usize`), and `as` casts. An operand classifies as integer
//! data, `usize` bookkeeping, or float; the `int-discipline` rule in
//! "wrapping" mode only fires when an operand is integer *data*, while
//! "guarded" mode flags every bare op whose operands are not float.
//! Items under `#[cfg(test)]`/`#[test]`, `const`/`static` initializers
//! (compile-time evaluated, overflow is a hard error there already) and
//! declaration generics are skipped.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{lex, Tok, TokKind};
use super::report::Finding;
use super::{scoped, R1_GUARDED, R1_WRAPPING, R2_SCOPE, R3_SCOPE, R4_SCOPE};

/// Integer *data* types. `usize`/`isize` are intentionally absent:
/// shape and index arithmetic is bookkeeping, not the paper's integer
/// pipeline, and already aborts on overflow in debug builds.
const INT_DATA_TYPES: &[&str] =
    &["i8", "i16", "i32", "i64", "i128", "u8", "u16", "u32", "u64", "u128"];

const RUST_KEYWORDS: &[&str] = &[
    "as", "break", "const", "continue", "crate", "dyn", "else", "enum",
    "extern", "false", "fn", "for", "if", "impl", "in", "let", "loop",
    "match", "mod", "move", "mut", "pub", "ref", "return", "self", "Self",
    "static", "struct", "trait", "true", "type", "unsafe", "use", "where",
    "while",
];

/// Methods whose return value is `usize` wherever they appear in this
/// codebase; calls resolve as bookkeeping, not integer data.
const USIZE_RETURNING: &[&str] = &["len", "capacity"];

const BARE_OPS: &[&str] = &["+", "-", "*", "<<", "+=", "-=", "*=", "<<="];

const PANIC_MACROS: &[&str] =
    &["panic", "unreachable", "todo", "unimplemented"];

const R4_BANNED: &[&str] = &[
    "HashMap", "HashSet", "Instant", "SystemTime", "RandomState",
    "thread_rng",
];

/// Operand evidence class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Cls {
    Int,
    Usize,
    Float,
}

fn is_keyword(s: &str) -> bool {
    RUST_KEYWORDS.contains(&s)
}

/// What one file's scan produced, before and after allow application.
pub struct FileResult {
    pub findings: Vec<Finding>,
    /// Violations that an allow escape suppressed.
    pub allowed: usize,
}

type Span = (usize, usize);

/// One fn body: `(body_start, body_end, name -> class evidence)`.
type FnEv = (usize, usize, BTreeMap<String, Cls>);

fn in_span(idx: usize, spans: &[Span]) -> bool {
    spans.iter().any(|&(a, b)| a <= idx && idx < b)
}

/// Token-index ranges of `#[cfg(test)]` / `#[test]` items (the
/// attribute, any stacked attributes after it, and the item body).
fn skip_ranges(toks: &[Tok]) -> Vec<Span> {
    let mut skips = Vec::new();
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        let t = &toks[i];
        if t.kind == TokKind::Punct
            && t.text == "#"
            && i + 1 < n
            && toks[i + 1].text == "["
        {
            let mut j = i + 2;
            let mut depth = 1i32;
            let mut attr: Vec<&str> = Vec::new();
            while j < n && depth > 0 {
                if toks[j].text == "[" {
                    depth += 1;
                } else if toks[j].text == "]" {
                    depth -= 1;
                }
                if depth > 0 {
                    attr.push(toks[j].text.as_str());
                }
                j += 1;
            }
            let is_test = attr.first() == Some(&"test")
                || (attr.first() == Some(&"cfg")
                    && attr.contains(&"test"));
            if is_test {
                let mut k = j;
                // stacked attributes between the test marker and the item
                while k < n
                    && toks[k].text == "#"
                    && k + 1 < n
                    && toks[k + 1].text == "["
                {
                    let mut d = 1i32;
                    k += 2;
                    while k < n && d > 0 {
                        if toks[k].text == "[" {
                            d += 1;
                        } else if toks[k].text == "]" {
                            d -= 1;
                        }
                        k += 1;
                    }
                }
                let mut d = 0i32;
                while k < n {
                    let tk = &toks[k];
                    if tk.kind == TokKind::Punct {
                        let tx = tk.text.as_str();
                        if tx == ";" && d == 0 {
                            k += 1;
                            break;
                        }
                        if matches!(tx, "(" | "[" | "{") {
                            d += 1;
                            if tx == "{" && d == 1 {
                                k += 1;
                                while k < n && d > 0 {
                                    if toks[k].kind == TokKind::Punct {
                                        let kx = toks[k].text.as_str();
                                        if matches!(kx, "(" | "[" | "{") {
                                            d += 1;
                                        } else if matches!(kx, ")" | "]" | "}")
                                        {
                                            d -= 1;
                                        }
                                    }
                                    k += 1;
                                }
                                break;
                            }
                        } else if matches!(tx, ")" | "]" | "}") {
                            d -= 1;
                        }
                    }
                    k += 1;
                }
                skips.push((i, k));
                i = k;
                continue;
            }
            i = j;
            continue;
        }
        i += 1;
    }
    skips
}

/// Token ranges of `const`/`static` items (declaration through `;`).
fn const_spans(toks: &[Tok]) -> Vec<Span> {
    let mut spans = Vec::new();
    let n = toks.len();
    for i in 0..n {
        let t = &toks[i];
        if t.kind == TokKind::Ident && (t.text == "const" || t.text == "static")
        {
            // `*const T` / `&'static` are type syntax, not items
            if i > 0
                && toks[i - 1].kind == TokKind::Punct
                && (toks[i - 1].text == "*" || toks[i - 1].text == "&")
            {
                continue;
            }
            let mut j = i + 1;
            if j < n && toks[j].kind != TokKind::Ident {
                continue;
            }
            let mut d = 0i32;
            while j < n {
                if toks[j].kind == TokKind::Punct {
                    let tx = toks[j].text.as_str();
                    if matches!(tx, "(" | "[" | "{") {
                        d += 1;
                    } else if matches!(tx, ")" | "]" | "}") {
                        d -= 1;
                    } else if tx == ";" && d == 0 {
                        break;
                    }
                }
                j += 1;
            }
            spans.push((i, j));
        }
    }
    spans
}

/// Token ranges inside declaration generics: `fn f<...>`,
/// `struct S<...>`, `impl<...>`, `trait T<...>`, `enum E<...>` — where
/// `<` is a bracket, never an operator.
fn generic_spans(toks: &[Tok]) -> Vec<Span> {
    let mut spans = Vec::new();
    let n = toks.len();
    for i in 0..n {
        let t = &toks[i];
        if t.kind != TokKind::Punct || t.text != "<" {
            continue;
        }
        let prev = if i > 0 { Some(&toks[i - 1]) } else { None };
        let prev2 = if i > 1 { Some(&toks[i - 2]) } else { None };
        let mut decl = false;
        if let Some(p) = prev {
            if p.kind == TokKind::Ident {
                if p.text == "impl" {
                    decl = true;
                } else if let Some(p2) = prev2 {
                    if p2.kind == TokKind::Ident
                        && matches!(
                            p2.text.as_str(),
                            "fn" | "struct" | "enum" | "trait"
                        )
                    {
                        decl = true;
                    }
                }
            }
        }
        if !decl {
            continue;
        }
        let mut d = 1i32;
        let mut j = i + 1;
        while j < n && d > 0 {
            if toks[j].kind == TokKind::Punct {
                match toks[j].text.as_str() {
                    "<" => d += 1,
                    ">" => d -= 1,
                    ">>" => d -= 2,
                    _ => {}
                }
            }
            j += 1;
        }
        spans.push((i, j));
    }
    spans
}

/// Classify the token run `toks[i..]` (until a stop punct at depth 0)
/// as a type mention; returns the class and the index reached.
fn classify_type_run(
    toks: &[Tok],
    start: usize,
    stops: &[&str],
) -> (Option<Cls>, usize) {
    let mut d = 0i32;
    let mut cls: Option<Cls> = None;
    let mut i = start;
    let n = toks.len();
    while i < n {
        let t = &toks[i];
        if t.kind == TokKind::Punct {
            let tx = t.text.as_str();
            if matches!(tx, "(" | "[" | "{" | "<") {
                d += 1;
            } else if matches!(tx, ")" | "]" | "}" | ">") {
                if d == 0 && stops.contains(&tx) {
                    break;
                }
                d -= 1;
            } else if d == 0 && stops.contains(&tx) {
                break;
            }
        }
        if t.kind == TokKind::Ident {
            let tx = t.text.as_str();
            if matches!(tx, "usize" | "f32" | "f64") && cls.is_none() {
                cls = Some(if tx == "usize" { Cls::Usize } else { Cls::Float });
            } else if INT_DATA_TYPES.contains(&tx) {
                cls = Some(Cls::Int);
            }
        }
        i += 1;
    }
    (cls, i)
}

/// `(body_start, body_end, param evidence)` for each `fn` item; nested
/// functions are found too. Evidence maps are fn-scoped so identical
/// names in different functions never collide.
fn fn_ranges(toks: &[Tok]) -> Vec<FnEv> {
    let mut out = Vec::new();
    let n = toks.len();
    let mut i = 0usize;
    while i < n {
        let t = &toks[i];
        if t.kind == TokKind::Ident
            && t.text == "fn"
            && i + 1 < n
            && toks[i + 1].kind == TokKind::Ident
        {
            let mut j = i + 2;
            if j < n && toks[j].text == "<" {
                let mut d = 1i32;
                j += 1;
                while j < n && d > 0 {
                    match toks[j].text.as_str() {
                        "<" => d += 1,
                        ">" => d -= 1,
                        ">>" => d -= 2,
                        _ => {}
                    }
                    j += 1;
                }
            }
            let mut params: BTreeMap<String, Cls> = BTreeMap::new();
            if j < n && toks[j].text == "(" {
                let mut d = 1i32;
                j += 1;
                while j < n && d > 0 {
                    let tj = &toks[j];
                    if tj.kind == TokKind::Punct {
                        let tx = tj.text.as_str();
                        if matches!(tx, "(" | "[" | "{") {
                            d += 1;
                        } else if matches!(tx, ")" | "]" | "}") {
                            d -= 1;
                        }
                    }
                    if d == 1
                        && tj.kind == TokKind::Punct
                        && tj.text == ":"
                        && j > 0
                        && toks[j - 1].kind == TokKind::Ident
                    {
                        let (cls, _) =
                            classify_type_run(toks, j + 1, &[",", ")"]);
                        if let Some(c) = cls {
                            params.insert(toks[j - 1].text.clone(), c);
                        }
                    }
                    j += 1;
                }
            }
            // find the body `{`, skipping return type and where clause;
            // a `;` first means a bodyless decl (trait method, extern)
            let mut d = 0i32;
            let mut no_body = false;
            while j < n {
                let tj = &toks[j];
                if tj.kind == TokKind::Punct {
                    let tx = tj.text.as_str();
                    if tx == ";" && d == 0 {
                        no_body = true;
                        break;
                    }
                    if matches!(tx, "(" | "[" | "<") {
                        d += 1;
                    } else if matches!(tx, ")" | "]" | ">") {
                        d -= 1;
                    } else if tx == "{" && d <= 0 {
                        break;
                    }
                }
                j += 1;
            }
            if no_body {
                i += 1;
                continue;
            }
            let body_start = j;
            let mut d = 0i32;
            let mut k = body_start;
            while k < n {
                let tk = &toks[k];
                if tk.kind == TokKind::Punct {
                    if tk.text == "{" {
                        d += 1;
                    } else if tk.text == "}" {
                        d -= 1;
                        if d == 0 {
                            k += 1;
                            break;
                        }
                    }
                }
                k += 1;
            }
            out.push((body_start, k, params));
            i = body_start + 1;
            continue;
        }
        i += 1;
    }
    out
}

/// File-level `name: type` evidence from struct/enum field declarations.
fn collect_field_evidence(toks: &[Tok]) -> BTreeMap<String, Cls> {
    let mut ev: BTreeMap<String, Cls> = BTreeMap::new();
    let n = toks.len();
    for i in 0..n {
        let t = &toks[i];
        if t.kind == TokKind::Ident && (t.text == "struct" || t.text == "enum")
        {
            let mut j = i + 1;
            while j < n && !matches!(toks[j].text.as_str(), "{" | ";" | "(") {
                j += 1;
            }
            if j >= n || toks[j].text != "{" {
                continue;
            }
            let mut d = 1i32;
            j += 1;
            while j < n && d > 0 {
                let tj = &toks[j];
                if tj.kind == TokKind::Punct {
                    let tx = tj.text.as_str();
                    if matches!(tx, "(" | "[" | "{") {
                        d += 1;
                    } else if matches!(tx, ")" | "]" | "}") {
                        d -= 1;
                    }
                }
                if d == 1
                    && tj.kind == TokKind::Punct
                    && tj.text == ":"
                    && j > 0
                    && toks[j - 1].kind == TokKind::Ident
                {
                    let (cls, _) = classify_type_run(toks, j + 1, &[",", "}"]);
                    if let Some(c) = cls {
                        ev.entry(toks[j - 1].text.clone()).or_insert(c);
                    }
                }
                j += 1;
            }
        }
    }
    ev
}

fn put(ev: &mut BTreeMap<String, Cls>, name: &str, cls: Option<Cls>) {
    let c = match cls {
        Some(c) => c,
        None => return,
    };
    if name == "self" {
        return;
    }
    // Int evidence is the strongest claim; never downgrade it
    if matches!(ev.get(name), Some(Cls::Int)) {
        return;
    }
    ev.insert(name.to_string(), c);
}

/// `let`/`for`/typed-binding evidence inside one fn body, seeded with
/// its parameter evidence.
fn collect_local_evidence(
    toks: &[Tok],
    start: usize,
    end: usize,
    params: &BTreeMap<String, Cls>,
) -> BTreeMap<String, Cls> {
    let mut ev = params.clone();
    let n = end;
    let mut i = start;
    while i < n {
        let t = &toks[i];
        // `let x: T` / `let mut x: T` / closure `|p: T|`
        if t.kind == TokKind::Punct && t.text == ":" && i > 0 {
            let prev = &toks[i - 1];
            if prev.kind == TokKind::Ident && !is_keyword(&prev.text) && i > 1
            {
                let p2 = &toks[i - 2];
                let introduces = (p2.kind == TokKind::Punct && p2.text == "|")
                    || (p2.kind == TokKind::Ident
                        && (p2.text == "let" || p2.text == "mut"));
                if introduces {
                    let (cls, _) = classify_type_run(
                        toks,
                        i + 1,
                        &[",", ")", "=", ";", "|"],
                    );
                    put(&mut ev, &prev.text, cls);
                }
            }
        }
        // untyped `let x = <rhs>`: classify from rhs literal/cast/len
        if t.kind == TokKind::Ident && t.text == "let" {
            let mut j = i + 1;
            if j < n && toks[j].kind == TokKind::Ident && toks[j].text == "mut"
            {
                j += 1;
            }
            if j < n
                && toks[j].kind == TokKind::Ident
                && !is_keyword(&toks[j].text)
            {
                let name = toks[j].text.clone();
                if j + 1 < n && toks[j + 1].text == "=" {
                    put(&mut ev, &name, rhs_evidence(toks, j + 2));
                }
            }
        }
        // `for x in ...`: induction variables are bookkeeping
        if t.kind == TokKind::Ident && t.text == "for" && i + 2 < n {
            let t1 = &toks[i + 1];
            let t2 = &toks[i + 2];
            if t1.kind == TokKind::Ident
                && !is_keyword(&t1.text)
                && t2.kind == TokKind::Ident
                && t2.text == "in"
            {
                put(&mut ev, &t1.text, Some(Cls::Usize));
            }
        }
        i += 1;
    }
    ev
}

/// `usize`/`isize` suffix handling on literals: `usize` classifies as
/// bookkeeping, `isize` as nothing (unused in this codebase), i8..u128
/// as integer data.
fn int_literal_cls(text: &str) -> Option<Cls> {
    if text.ends_with("usize") {
        return Some(Cls::Usize);
    }
    if text.ends_with("isize") {
        return None;
    }
    for s in INT_DATA_TYPES {
        if text.ends_with(s) {
            return Some(Cls::Int);
        }
    }
    None
}

/// Evidence class of a `let` rhs starting at `start`, scanned to `;`.
fn rhs_evidence(toks: &[Tok], start: usize) -> Option<Cls> {
    let mut d = 0i32;
    let n = toks.len();
    let mut cls: Option<Cls> = None;
    let mut i = start;
    while i < n {
        let t = &toks[i];
        if t.kind == TokKind::Punct {
            let tx = t.text.as_str();
            if matches!(tx, "(" | "[" | "{") {
                d += 1;
            } else if matches!(tx, ")" | "]" | "}") {
                d -= 1;
            } else if tx == ";" && d <= 0 {
                break;
            }
        }
        if t.kind == TokKind::Int {
            match int_literal_cls(&t.text) {
                Some(Cls::Usize) if cls.is_none() => cls = Some(Cls::Usize),
                Some(Cls::Int) => cls = Some(Cls::Int),
                _ => {}
            }
        }
        if t.kind == TokKind::Float && cls.is_none() {
            cls = Some(Cls::Float);
        }
        if t.kind == TokKind::Ident
            && USIZE_RETURNING.contains(&t.text.as_str())
            && cls.is_none()
            && i > 0
            && toks[i - 1].kind == TokKind::Punct
            && toks[i - 1].text == "."
            && i + 1 < n
            && toks[i + 1].text == "("
        {
            cls = Some(Cls::Usize);
        }
        if t.kind == TokKind::Ident && t.text == "as" && i + 1 < n {
            let nxt = &toks[i + 1];
            if nxt.kind == TokKind::Ident {
                let nx = nxt.text.as_str();
                if matches!(nx, "usize" | "f32" | "f64") && cls.is_none() {
                    cls = Some(if nx == "usize" {
                        Cls::Usize
                    } else {
                        Cls::Float
                    });
                } else if INT_DATA_TYPES.contains(&nx) {
                    cls = Some(Cls::Int);
                }
            }
        }
        i += 1;
    }
    cls
}

/// Class of the operand *ending* at token `start` (the token just
/// before a binary op): walks back over call/index suffixes and field
/// chains to the base name, then consults the evidence maps.
fn resolve_back(
    toks: &[Tok],
    start: usize,
    locals: &BTreeMap<String, Cls>,
    fields: &BTreeMap<String, Cls>,
) -> Option<Cls> {
    let mut i = start as isize;
    let mut last_field: Option<&str> = None;
    let mut guard = 0;
    while i >= 0 && guard < 64 {
        guard += 1;
        let t = &toks[i as usize];
        if t.kind == TokKind::Punct && (t.text == ")" || t.text == "]") {
            let was_call = t.text == ")";
            let mut d = 1i32;
            i -= 1;
            while i >= 0 && d > 0 {
                let tx = &toks[i as usize];
                if tx.kind == TokKind::Punct {
                    if tx.text == ")" || tx.text == "]" {
                        d += 1;
                    } else if tx.text == "(" || tx.text == "[" {
                        d -= 1;
                    }
                }
                i -= 1;
            }
            if was_call
                && i >= 0
                && toks[i as usize].kind == TokKind::Ident
                && USIZE_RETURNING
                    .contains(&toks[i as usize].text.as_str())
            {
                return Some(Cls::Usize);
            }
            continue;
        }
        match t.kind {
            TokKind::Int => return int_literal_cls(&t.text),
            TokKind::Float => return Some(Cls::Float),
            TokKind::Ident => {
                let name = t.text.as_str();
                let after_as = i > 0
                    && toks[(i - 1) as usize].kind == TokKind::Ident
                    && toks[(i - 1) as usize].text == "as";
                if INT_DATA_TYPES.contains(&name) {
                    return if after_as { Some(Cls::Int) } else { None };
                }
                if matches!(name, "usize" | "f32" | "f64") {
                    if after_as {
                        return Some(if name == "usize" {
                            Cls::Usize
                        } else {
                            Cls::Float
                        });
                    }
                    return None;
                }
                if i > 0
                    && toks[(i - 1) as usize].kind == TokKind::Punct
                    && (toks[(i - 1) as usize].text == "."
                        || toks[(i - 1) as usize].text == "::")
                {
                    if last_field.is_none() {
                        last_field = Some(name);
                    }
                    i -= 2;
                    continue;
                }
                if let Some(f) = last_field {
                    return fields.get(f).copied();
                }
                return locals.get(name).copied();
            }
            _ => return None,
        }
    }
    None
}

/// Class of the operand *starting* at token `start` (the token just
/// after a binary op): skips unary prefixes, honors a trailing
/// `as <type>` cast within the expression, and walks field chains.
fn resolve_fwd(
    toks: &[Tok],
    mut i: usize,
    locals: &BTreeMap<String, Cls>,
    fields: &BTreeMap<String, Cls>,
) -> Option<Cls> {
    let n = toks.len();
    let mut guard = 0;
    while i < n
        && toks[i].kind == TokKind::Punct
        && matches!(toks[i].text.as_str(), "-" | "!" | "*" | "&")
    {
        i += 1;
        guard += 1;
        if guard > 8 {
            return None;
        }
        if i < n && toks[i].kind == TokKind::Ident && toks[i].text == "mut" {
            i += 1;
        }
    }
    if i >= n {
        return None;
    }
    // a cast dominates: scan a short window for `as <type>` at depth 0
    let mut d = 0i32;
    let mut j = i;
    while j < n && j - i < 40 {
        let tj = &toks[j];
        if tj.kind == TokKind::Punct {
            let tx = tj.text.as_str();
            if matches!(tx, "(" | "[" | "{") {
                d += 1;
            } else if matches!(tx, ")" | "]" | "}") {
                if d == 0 {
                    break;
                }
                d -= 1;
            } else if d == 0
                && matches!(
                    tx,
                    "," | ";" | "+" | "-" | "*" | "<<" | "==" | "!=" | "<"
                        | ">" | "<=" | ">=" | "&&" | "||"
                )
            {
                break;
            }
        }
        if d == 0 && tj.kind == TokKind::Ident && tj.text == "as" && j + 1 < n
        {
            let nx = &toks[j + 1];
            if nx.kind == TokKind::Ident {
                let nxt = nx.text.as_str();
                if INT_DATA_TYPES.contains(&nxt) {
                    return Some(Cls::Int);
                }
                if matches!(nxt, "usize" | "f32" | "f64") {
                    return Some(if nxt == "usize" {
                        Cls::Usize
                    } else {
                        Cls::Float
                    });
                }
            }
        }
        j += 1;
    }
    let t = &toks[i];
    match t.kind {
        TokKind::Int => int_literal_cls(&t.text),
        TokKind::Float => Some(Cls::Float),
        TokKind::Ident if !is_keyword(&t.text) => {
            let mut k = i;
            let mut chained = false;
            while k + 1 < n
                && toks[k + 1].kind == TokKind::Punct
                && toks[k + 1].text == "."
            {
                if k + 2 < n && toks[k + 2].kind == TokKind::Ident {
                    if USIZE_RETURNING.contains(&toks[k + 2].text.as_str()) {
                        return Some(Cls::Usize);
                    }
                    chained = true;
                    k += 2;
                } else {
                    break;
                }
            }
            if chained {
                // a method call at the chain end is unknown; a plain
                // field chain resolves by the final field's type
                if k + 1 < n && toks[k + 1].text == "(" {
                    return None;
                }
                return fields.get(toks[k].text.as_str()).copied();
            }
            locals.get(t.text.as_str()).copied()
        }
        _ => None,
    }
}

/// Run every rule whose scope covers `rel` over one file's source.
pub fn check_file(rel: &str, src: &str) -> FileResult {
    let lexed = lex(src);
    let toks = &lexed.toks;
    let skips = skip_ranges(toks);
    let consts = const_spans(toks);
    let generics = generic_spans(toks);
    let fields = collect_field_evidence(toks);
    let fn_evs: Vec<FnEv> = fn_ranges(toks)
        .into_iter()
        .map(|(s, e, p)| (s, e, collect_local_evidence(toks, s, e, &p)))
        .collect();

    let mut out: Vec<(usize, &'static str, String)> = lexed
        .bad_allows
        .iter()
        .map(|(l, m)| (*l, "allow-syntax", m.clone()))
        .collect();

    let r1 = scoped(rel, R1_WRAPPING) || scoped(rel, R1_GUARDED);
    let guarded = scoped(rel, R1_GUARDED);
    let mode = if guarded { "guarded" } else { "wrapping" };
    let r2 = scoped(rel, R2_SCOPE);
    let r3 = scoped(rel, R3_SCOPE);
    let r4 = scoped(rel, R4_SCOPE);

    let empty: BTreeMap<String, Cls> = BTreeMap::new();
    let n = toks.len();
    let mut bracket_stack: Vec<&str> = Vec::new();
    for i in 0..n {
        let t = &toks[i];
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" | "[" | "{" => bracket_stack.push(t.text.as_str()),
                ")" | "]" | "}" => {
                    bracket_stack.pop();
                }
                _ => {}
            }
        }
        if in_span(i, &skips) {
            continue;
        }
        // innermost enclosing fn's evidence wins
        let mut locals = &empty;
        let mut best_start: Option<usize> = None;
        for (s, e, m) in &fn_evs {
            let better = match best_start {
                Some(b) => *s > b,
                None => true,
            };
            if *s <= i && i < *e && better {
                best_start = Some(*s);
                locals = m;
            }
        }
        if r1
            && t.kind == TokKind::Punct
            && BARE_OPS.contains(&t.text.as_str())
            && !in_span(i, &consts)
            && !in_span(i, &generics)
            && i > 0
            && i + 1 < n
        {
            let prev = &toks[i - 1];
            let nxt = &toks[i + 1];
            // binary vs unary: a binary op follows an operand
            let mut binary = matches!(
                prev.kind,
                TokKind::Ident | TokKind::Int | TokKind::Float
            ) || (prev.kind == TokKind::Punct
                && (prev.text == ")" || prev.text == "]"));
            if prev.kind == TokKind::Ident && is_keyword(&prev.text) {
                binary = false;
            }
            if matches!(t.text.as_str(), "+=" | "-=" | "*=" | "<<=") {
                binary = true;
            }
            // `*const T` / `*mut T` raw pointer types
            if binary
                && t.text == "*"
                && nxt.kind == TokKind::Ident
                && (nxt.text == "const" || nxt.text == "mut")
            {
                binary = false;
            }
            if binary
                && (prev.kind == TokKind::Float || nxt.kind == TokKind::Float)
            {
                binary = false;
            }
            if binary
                && (prev.kind == TokKind::Lifetime
                    || nxt.kind == TokKind::Lifetime)
            {
                binary = false;
            }
            // index/shape expressions inside `[...]` are bookkeeping
            if binary && !guarded && bracket_stack.iter().any(|&b| b == "[") {
                binary = false;
            }
            if binary {
                let lhs = resolve_back(toks, i - 1, locals, &fields);
                let rhs = resolve_fwd(toks, i + 1, locals, &fields);
                if lhs == Some(Cls::Float) || rhs == Some(Cls::Float) {
                    // float math is no-float's concern, not this rule's
                    binary = false;
                } else if !guarded
                    && lhs != Some(Cls::Int)
                    && rhs != Some(Cls::Int)
                {
                    binary = false;
                }
            }
            if binary {
                out.push((
                    t.line,
                    "int-discipline",
                    format!(
                        "bare `{}` on integer data (mode {mode}): use \
                         wrapping_*/checked_*/saturating_*",
                        t.text
                    ),
                ));
            }
        }
        if r2 {
            if t.kind == TokKind::Ident
                && (t.text == "f32" || t.text == "f64")
            {
                out.push((
                    t.line,
                    "no-float",
                    format!("`{}` in integer-domain module", t.text),
                ));
            } else if t.kind == TokKind::Float {
                out.push((
                    t.line,
                    "no-float",
                    format!(
                        "float literal `{}` in integer-domain module",
                        t.text
                    ),
                ));
            }
        }
        if r3 {
            if t.kind == TokKind::Ident
                && (t.text == "unwrap" || t.text == "expect")
                && i > 0
                && toks[i - 1].text == "."
            {
                out.push((
                    t.line,
                    "no-panic",
                    format!("`.{}()` in hostile-input module", t.text),
                ));
            }
            if t.kind == TokKind::Ident
                && PANIC_MACROS.contains(&t.text.as_str())
                && i + 1 < n
                && toks[i + 1].text == "!"
            {
                out.push((
                    t.line,
                    "no-panic",
                    format!("`{}!` in hostile-input module", t.text),
                ));
            }
            if t.kind == TokKind::Punct && t.text == "[" && i > 0 {
                let prev = &toks[i - 1];
                let indexes = (prev.kind == TokKind::Ident
                    && !is_keyword(&prev.text))
                    || (prev.kind == TokKind::Punct
                        && (prev.text == ")" || prev.text == "]"));
                if indexes {
                    out.push((
                        t.line,
                        "no-panic",
                        "unchecked indexing in hostile-input module (use \
                         .get()/.get_mut())"
                            .to_string(),
                    ));
                }
            }
        }
        if r4
            && t.kind == TokKind::Ident
            && R4_BANNED.contains(&t.text.as_str())
        {
            out.push((
                t.line,
                "determinism",
                format!(
                    "`{}` in deterministic compute/serialization module",
                    t.text
                ),
            ));
        }
    }

    // apply allow escapes: line allows cover their own line + the next
    let mut allowed_lines: BTreeMap<&str, BTreeSet<usize>> = BTreeMap::new();
    let mut file_allows: BTreeSet<&str> = BTreeSet::new();
    for a in &lexed.allows {
        for r in &a.rules {
            if a.file_wide {
                file_allows.insert(r.as_str());
            } else {
                let lines = allowed_lines.entry(r.as_str()).or_default();
                lines.insert(a.line);
                lines.insert(a.line + 1);
            }
        }
    }
    let mut findings = Vec::new();
    let mut allowed = 0usize;
    for (line, rule, msg) in out {
        let hit = file_allows.contains(rule)
            || matches!(allowed_lines.get(rule), Some(s) if s.contains(&line));
        if hit {
            allowed += 1;
            continue;
        }
        findings.push(Finding { file: rel.to_string(), line, rule, msg });
    }
    FileResult { findings, allowed }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(rel: &str, src: &str) -> Vec<(String, usize)> {
        check_file(rel, src)
            .findings
            .iter()
            .map(|f| (f.rule.to_string(), f.line))
            .collect()
    }

    #[test]
    fn r1_wrapping_flags_bare_ops_on_int_data() {
        let src = "fn f(a: i32, b: i32) -> i32 { a + b }";
        assert_eq!(
            rules_of("rust/src/tensor/ops_int.rs", src),
            [("int-discipline".to_string(), 1)]
        );
        // the acceptance-criterion mutation: dropping a wrapping_ call
        // back to a bare op must be caught
        let clean = "fn scale(a: i32, s: i32) -> i32 { a.wrapping_mul(s) }";
        assert!(rules_of("rust/src/tensor/ops_int.rs", clean).is_empty());
        let mutated = "fn scale(a: i32, s: i32) -> i32 { a * s }";
        assert_eq!(
            rules_of("rust/src/tensor/ops_int.rs", mutated),
            [("int-discipline".to_string(), 1)]
        );
    }

    #[test]
    fn r1_wrapping_exempts_usize_bookkeeping_and_indexing() {
        let src = "fn f(v: &[i32]) -> usize { v.len() + 1 }";
        assert!(rules_of("rust/src/tensor/ops_int.rs", src).is_empty());
        let idx = "fn g(v: &[i32], i: usize, j: usize) -> i32 {\n\
                   let w = 4usize;\n\
                   v[i * w + j]\n\
                   }";
        assert!(rules_of("rust/src/train/replica.rs", idx).is_empty());
    }

    #[test]
    fn r1_guarded_flags_every_non_float_bare_op() {
        let src = "fn f(v: &[i32]) -> usize { v.len() + 1 }";
        assert_eq!(
            rules_of("rust/src/util/hist.rs", src),
            [("int-discipline".to_string(), 1)]
        );
    }

    #[test]
    fn r1_exempts_float_math_in_both_modes() {
        // float arithmetic cannot wrap; it is no-float's concern, and
        // only in no-float's (narrower) scope
        let src = "fn f(x: f64) -> f64 { x * 2.0 }";
        assert!(rules_of("rust/src/train/replica.rs", src).is_empty());
        assert!(rules_of("rust/src/util/bench.rs", src).is_empty());
    }

    #[test]
    fn r1_skips_consts_generics_and_test_items() {
        let consts = "const K: i32 = 1 + 2;";
        assert!(rules_of("rust/src/tensor/ops_int.rs", consts).is_empty());
        let generics = "fn f<const N: usize>(a: [i32; N]) -> usize { N }";
        assert!(rules_of("rust/src/tensor/ops_int.rs", generics).is_empty());
        let test_item = "#[cfg(test)]\nmod tests {\n\
                         fn f(a: i32, b: i32) -> i32 { a + b }\n}";
        assert!(rules_of("rust/src/tensor/ops_int.rs", test_item).is_empty());
    }

    #[test]
    fn r2_flags_float_types_and_literals() {
        let src = "fn half(x: i64) -> f32 { x as f32 * 0.5 }";
        let got = rules_of("rust/src/optim/momentum.rs", src);
        let nf: Vec<usize> = got
            .iter()
            .filter(|(r, _)| r == "no-float")
            .map(|(_, l)| *l)
            .collect();
        assert_eq!(nf.len(), 3, "f32 x2 + literal: {got:?}");
    }

    #[test]
    fn r2_allow_escape_with_reason_suppresses() {
        let src = "// nitro-lint: allow(no-float) documented floor-div \
                   lemma bound\nfn f() -> f64 { 0.25 }";
        let res = check_file("rust/src/optim/momentum.rs", src);
        assert!(res.findings.is_empty(), "{:?}", res.findings);
        assert_eq!(res.allowed, 2); // `f64` + `0.25`, both on line 2
    }

    #[test]
    fn r3_flags_unwrap_panics_and_indexing() {
        let src = "fn f(o: Option<u32>, v: &[u8]) -> u8 {\n\
                   let x = o.unwrap();\n\
                   if v.is_empty() { panic!(\"empty\") }\n\
                   v[0]\n\
                   }";
        let got = rules_of("rust/src/util/jsonio.rs", src);
        let lines: Vec<usize> = got
            .iter()
            .filter(|(r, _)| r == "no-panic")
            .map(|(_, l)| *l)
            .collect();
        assert_eq!(lines, [2, 3, 4], "{got:?}");
        // the acceptance-criterion mutation target: serve/wire.rs
        let wire = "fn f(j: Option<i64>) -> i64 { j.unwrap() }";
        assert_eq!(
            rules_of("rust/src/coordinator/serve/wire.rs", wire),
            [("no-panic".to_string(), 1)]
        );
    }

    #[test]
    fn r3_accepts_checked_access() {
        let src = "fn f(v: &[u8]) -> Result<u8, String> {\n\
                   v.first().copied().ok_or_else(|| \"empty\".to_string())\n\
                   }";
        assert!(rules_of("rust/src/train/framing.rs", src).is_empty());
    }

    #[test]
    fn r4_flags_nondeterministic_types() {
        let src = "use std::collections::HashMap;\n\
                   fn f() { let m: HashMap<u32, u32> = HashMap::new(); }";
        let got = rules_of("rust/src/nn/mod.rs", src);
        assert_eq!(
            got.iter().filter(|(r, _)| r == "determinism").count(),
            3,
            "{got:?}"
        );
        let timing = "fn f() { let t = Instant::now(); }";
        assert_eq!(
            rules_of("rust/src/train/replica.rs", timing),
            [("determinism".to_string(), 1)]
        );
    }

    #[test]
    fn allow_without_reason_is_rejected_and_suppresses_nothing() {
        let src = "// nitro-lint: allow(no-panic)\n\
                   fn f(o: Option<u32>) -> u32 { o.unwrap() }";
        let got = rules_of("rust/src/util/jsonio.rs", src);
        assert!(
            got.contains(&("allow-syntax".to_string(), 1)),
            "{got:?}"
        );
        assert!(got.contains(&("no-panic".to_string(), 2)), "{got:?}");
    }

    #[test]
    fn allow_file_covers_the_whole_file() {
        let src = "// nitro-lint: allow-file(determinism) fixture module \
                   exercising file-wide escapes\n\
                   use std::collections::HashMap;\n\
                   fn g() { let m = HashMap::new(); }";
        let res = check_file("rust/src/nn/mod.rs", src);
        assert!(res.findings.is_empty(), "{:?}", res.findings);
        assert_eq!(res.allowed, 2);
    }

    #[test]
    fn out_of_scope_files_are_untouched() {
        let src = "fn f(a: i32, b: i32) -> f64 { (a + b) as f64 * 0.5 }\n\
                   fn g(o: Option<u32>) -> u32 { o.unwrap() }";
        assert!(rules_of("rust/src/coordinator/spec.rs", src).is_empty());
    }
}
