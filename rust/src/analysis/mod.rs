//! `nitro lint`: a static analyzer for the repo's integer-discipline
//! contract (NITRO-D §3 — training must be bit-exact integer-only).
//!
//! Four rules, each scoped to the modules where its invariant is
//! load-bearing:
//!
//! - `int-discipline` — no bare `+ - * << += -= *= <<=` on integer
//!   *data*. "wrapping" modules (the integer pipeline) must spell out
//!   `wrapping_*`/`checked_*`/`saturating_*`; "guarded" modules
//!   (histograms, shedding counters, benchmarks) flag every bare op so
//!   saturation points are explicit.
//! - `no-float` — no `f32`/`f64` types or float literals in the
//!   integer-domain modules; floats anywhere in the pipeline silently
//!   break cross-platform bit-exactness.
//! - `no-panic` — no `unwrap`/`expect`, panic-family macros, or
//!   unchecked indexing in modules that parse hostile input (wire
//!   codecs, checkpoints, JSON); malformed bytes must be an `Err`.
//! - `determinism` — no `HashMap`/`HashSet`/`Instant`/`SystemTime`/
//!   `RandomState`/`thread_rng` in compute or serialization modules;
//!   iteration order and timing must never influence results.
//!
//! A violation can be waived in place with an escape comment: the tool
//! name and a colon, then `allow(rule[,rule]) reason` to cover that
//! line and the next, or `allow-file(rule[,rule]) reason` for the whole
//! file. The reason is mandatory (at least 8 characters, and not an
//! unedited FIXME stub), so every waiver carries its justification in
//! the diff. Malformed escapes are themselves violations
//! (`allow-syntax`) and cannot be waived — there is no baseline file
//! and nothing is grandfathered.

pub mod lexer;
pub mod report;
pub mod rules;

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

pub use report::{Finding, Report};

/// Integer-pipeline modules: bare ops on int data must be spelled
/// `wrapping_*`/`checked_*`/`saturating_*`.
pub const R1_WRAPPING: &[&str] = &[
    "rust/src/tensor/ops_int.rs",
    "rust/src/tensor/backend.rs",
    "rust/src/optim/",
    "rust/src/train/replica.rs",
    "rust/src/train/dist.rs",
];

/// Saturation-sensitive counters: every bare op is flagged, float or
/// bookkeeping excepted, so overflow handling is always explicit.
pub const R1_GUARDED: &[&str] = &[
    "rust/src/util/hist.rs",
    "rust/src/coordinator/serve/shed.rs",
    "rust/src/util/bench.rs",
];

/// Integer-domain modules: `f32`/`f64` and float literals are banned.
pub const R2_SCOPE: &[&str] = &[
    "rust/src/tensor/ops_int.rs",
    "rust/src/tensor/backend.rs",
    "rust/src/optim/",
];

/// Hostile-input surfaces: parsing must return `Err`, never panic.
pub const R3_SCOPE: &[&str] = &[
    "rust/src/coordinator/serve/wire.rs",
    "rust/src/train/checkpoint.rs",
    "rust/src/train/framing.rs",
    "rust/src/util/jsonio.rs",
];

/// Deterministic compute/serialization modules: no unordered
/// collections, clocks, or RNG handles.
pub const R4_SCOPE: &[&str] = &[
    "rust/src/tensor/",
    "rust/src/nn/",
    "rust/src/optim/",
    "rust/src/train/replica.rs",
    "rust/src/train/framing.rs",
    "rust/src/util/jsonio.rs",
];

/// A scope entry is an exact file path, or a directory prefix when it
/// ends with `/`. Paths are repo-relative with forward slashes.
pub fn scoped(rel: &str, scopes: &[&str]) -> bool {
    scopes
        .iter()
        .any(|s| rel == *s || (s.ends_with('/') && rel.starts_with(s)))
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let rd = std::fs::read_dir(dir)
        .map_err(|e| format!("read dir {}: {e}", dir.display()))?;
    let mut entries: Vec<PathBuf> =
        rd.filter_map(|r| r.ok().map(|d| d.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, p: &Path) -> String {
    let rel = p.strip_prefix(root).unwrap_or(p);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

/// Scan every `.rs` file under `<root>/rust/src` and report violations
/// in deterministic (sorted-path, then token) order.
pub fn run(root: &Path) -> Result<Report, String> {
    let src_root = root.join("rust").join("src");
    if !src_root.is_dir() {
        return Err(format!(
            "{} does not look like a repo root (no rust/src)",
            root.display()
        ));
    }
    let mut files = Vec::new();
    walk(&src_root, &mut files)?;
    let mut findings = Vec::new();
    let mut allowed = 0usize;
    for p in &files {
        let src = std::fs::read_to_string(p)
            .map_err(|e| format!("read {}: {e}", p.display()))?;
        let rel = rel_path(root, p);
        let mut res = rules::check_file(&rel, &src);
        findings.append(&mut res.findings);
        allowed += res.allowed;
    }
    Ok(Report { files_scanned: files.len(), findings, allowed })
}

/// Insert placeholder escape comments above each violating line. The
/// stub's FIXME reason is deliberately rejected by the parser, so the
/// tree stays red until a human replaces it with a real justification.
/// Returns the number of comments inserted.
/// Per-file map of violating line -> rules to stub an allow for.
type LineRules<'a> = BTreeMap<usize, BTreeSet<&'a str>>;

pub fn fix_allow(root: &Path, report: &Report) -> Result<usize, String> {
    let mut by_file: BTreeMap<&str, LineRules> = BTreeMap::new();
    for f in &report.findings {
        if f.rule == "allow-syntax" {
            continue;
        }
        by_file
            .entry(f.file.as_str())
            .or_default()
            .entry(f.line)
            .or_default()
            .insert(f.rule);
    }
    let mut inserted = 0usize;
    for (file, lines) in &by_file {
        let path = root.join(file);
        let src = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let mut text: Vec<String> =
            src.lines().map(|s| s.to_string()).collect();
        // insert bottom-up so earlier line numbers stay valid
        for (&line, rules) in lines.iter().rev() {
            let idx = line.saturating_sub(1);
            if idx > text.len() {
                continue;
            }
            let indent: String = text
                .get(idx)
                .map(|l| {
                    l.chars().take_while(|c| c.is_whitespace()).collect()
                })
                .unwrap_or_default();
            let joined =
                rules.iter().copied().collect::<Vec<_>>().join(",");
            text.insert(
                idx,
                format!(
                    "{indent}// nitro-lint: allow({joined}) FIXME: \
                     justify this exemption"
                ),
            );
            inserted += 1;
        }
        let mut out = text.join("\n");
        out.push('\n');
        std::fs::write(&path, out)
            .map_err(|e| format!("write {}: {e}", path.display()))?;
    }
    Ok(inserted)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The gate behind the `lint-invariants` CI lane: the tree itself
    /// must carry zero unwaived violations, with no baseline file.
    #[test]
    fn repo_is_lint_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("rust/ has a parent dir");
        let rep = run(root).expect("lint scan succeeds");
        assert!(rep.findings.is_empty(), "\n{}", rep.text());
        assert!(rep.files_scanned > 30, "scanned {}", rep.files_scanned);
    }

    #[test]
    fn scope_matching_handles_files_and_dir_prefixes() {
        assert!(scoped("rust/src/optim/momentum.rs", R1_WRAPPING));
        assert!(scoped("rust/src/tensor/ops_int.rs", R1_WRAPPING));
        assert!(!scoped("rust/src/tensor/ops_int.rs", R1_GUARDED));
        assert!(!scoped("rust/src/coordinator/spec.rs", R4_SCOPE));
    }
}
