//! Micro-benchmark harness with warmup and robust statistics (replaces
//! `criterion`, not vendored). `cargo bench` targets are `harness = false`
//! binaries built on this module; they print aligned rows and can emit
//! JSON for EXPERIMENTS.md.

use crate::util::jsonio::Json;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub min_ns: f64,
    /// Optional work metric (elements, ops) for throughput reporting.
    pub work: Option<f64>,
}

impl Stats {
    pub fn throughput(&self) -> Option<f64> {
        self.work.map(|w| w / (self.median_ns * 1e-9))
    }

    pub fn row(&self) -> String {
        let thr = match self.throughput() {
            Some(t) if t >= 1e9 => format!("{:8.2} G/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("{:8.2} M/s", t / 1e6),
            Some(t) => format!("{:8.0}  /s", t),
            None => "          --".to_string(),
        };
        format!(
            "{:<44} {:>12} {:>12} {:>12} {:>6} {}",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
            self.iters,
            thr
        )
    }
}

/// Peak resident set size of this process in KiB (`VmHWM` from
/// `/proc/self/status`); `None` where procfs is unavailable (non-Linux).
/// The experiment runner records this per run as its peak-memory metric.
pub fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            return rest.split_whitespace().next()?.parse().ok();
        }
    }
    None
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

pub struct Bencher {
    /// Target wall-clock budget per benchmark, seconds.
    pub budget_s: f64,
    pub min_iters: usize,
    pub max_iters: usize,
    pub results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        // NITRO_BENCH_BUDGET lets CI shrink the run.
        let budget_s = std::env::var("NITRO_BENCH_BUDGET")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1.0);
        Bencher { budget_s, min_iters: 5, max_iters: 10_000, results: Vec::new() }
    }
}

impl Bencher {
    pub fn header() -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12} {:>6} {:>12}",
            "benchmark", "median", "p10", "p90", "iters", "throughput"
        )
    }

    /// Run `f` repeatedly; `work` is the per-iteration work metric for
    /// throughput (e.g. MACs) or None.
    pub fn bench<F: FnMut()>(&mut self, name: &str, work: Option<f64>,
                             mut f: F) -> &Stats {
        // warmup + calibration
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let target = (self.budget_s / once) as usize;
        let iters = target.clamp(self.min_iters, self.max_iters);
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = |p: f64| samples[(p * samples.len().saturating_sub(1) as f64) as usize];
        let stats = Stats {
            name: name.to_string(),
            iters,
            mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
            median_ns: q(0.5),
            p10_ns: q(0.1),
            p90_ns: q(0.9),
            min_ns: samples[0],
            work,
        };
        println!("{}", stats.row());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// All results as a [`Json`] array value (one row per benchmark) —
    /// the building block of `BENCH_kernels.json` and the bench binaries'
    /// result files.
    pub fn json_value(&self) -> Json {
        Json::Array(
            self.results
                .iter()
                .map(|s| {
                    Json::obj(vec![
                        ("name", Json::Str(s.name.clone())),
                        ("median_ns", Json::Float(s.median_ns)),
                        ("p10_ns", Json::Float(s.p10_ns)),
                        ("p90_ns", Json::Float(s.p90_ns)),
                        ("mean_ns", Json::Float(s.mean_ns)),
                        ("iters", Json::Int(s.iters as i64)),
                        (
                            "throughput",
                            s.throughput().map(Json::Float).unwrap_or(Json::Null),
                        ),
                    ])
                })
                .collect(),
        )
    }

    /// Dump all results as a JSON array (consumed by EXPERIMENTS.md
    /// tooling).
    pub fn json(&self) -> String {
        self.json_value().dump()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut b = Bencher { budget_s: 0.02, ..Default::default() };
        let mut x = 0u64;
        let s = b
            .bench("spin", Some(1000.0), || {
                for i in 0..1000u64 {
                    x = x.wrapping_add(i * i);
                }
            })
            .clone();
        assert!(s.median_ns > 0.0);
        assert!(s.p10_ns <= s.median_ns && s.median_ns <= s.p90_ns);
        assert!(s.iters >= 5);
        assert!(s.throughput().unwrap() > 0.0);
        assert!(b.json().contains("spin"));
        assert!(x > 0); // defeat DCE
    }

    #[test]
    fn peak_rss_reported_on_linux() {
        // On Linux procfs is always there; elsewhere None is the contract.
        if std::path::Path::new("/proc/self/status").exists() {
            let kb = peak_rss_kb().expect("VmHWM parse");
            assert!(kb > 0);
        } else {
            assert!(peak_rss_kb().is_none());
        }
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12e3).ends_with("µs"));
        assert!(fmt_ns(12e6).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with(" s"));
    }
}
