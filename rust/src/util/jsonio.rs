//! Minimal JSON parser + writer (replaces `serde_json`, which is not
//! vendored in this offline image).
//!
//! Scope: everything the repo reads/writes — artifact manifests, golden
//! vectors, experiment configs and result rows. Supports the full JSON
//! grammar except exotic number forms; integers up to i64 are preserved
//! exactly (golden vectors are integers — float round-tripping them would
//! defeat the bit-exactness story).

// A `no-panic` surface under `nitro lint`: in non-test code, prefer
// `Result` over unwrap/expect (enforced for clippy runs too).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn parse_file(path: &str) -> Result<Json, String> {
        let s = std::fs::read_to_string(path)
            .map_err(|e| format!("read {path}: {e}"))?;
        Json::parse(&s).map_err(|e| format!("{path}: {e}"))
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(v) => Some(*v),
            Json::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(v) => Some(*v as f64),
            Json::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(v) => Some(v),
            _ => None,
        }
    }

    pub fn i64_vec(&self) -> Result<Vec<i64>, String> {
        self.as_array()
            .ok_or_else(|| "not an array".to_string())?
            .iter()
            .map(|v| v.as_i64().ok_or_else(|| "not an int".to_string()))
            .collect()
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>, String> {
        Ok(self.i64_vec()?.into_iter().map(|v| v as usize).collect())
    }

    pub fn i32_vec(&self) -> Result<Vec<i32>, String> {
        Ok(self.i64_vec()?.into_iter().map(|v| v as i32).collect())
    }

    // ---- defaulted accessors (experiment-spec parsing) -------------------

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(Json::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Json::as_i64).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.i64_or(key, default as i64) as usize
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }

    // ---- writer ----------------------------------------------------------

    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Int(v) => {
                let _ = write!(out, "{v}");
            }
            Json::Float(f) => {
                if f.is_finite() {
                    let _ = write!(out, "{f}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Array(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Object(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Pretty writer: 2-space indentation, object keys in BTreeMap order.
    /// Committed spec files and emitted BENCH records use this form so
    /// re-generation produces readable, stable diffs.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        fn indent(out: &mut String, depth: usize) {
            for _ in 0..depth {
                out.push_str("  ");
            }
        }
        match self {
            Json::Array(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    x.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Object(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    write_escaped(k, out);
                    out.push_str(": ");
                    x.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
            _ => self.write(out),
        }
    }

    // ---- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn ints(v: &[i64]) -> Json {
        Json::Array(v.iter().map(|&x| Json::Int(x)).collect())
    }

    pub fn strs(v: &[&str]) -> Json {
        Json::Array(v.iter().map(|s| Json::Str(s.to_string())).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Nesting-depth cap: the recursive-descent parser consumes one stack
/// frame per level, so untrusted input (serve wire protocol, checkpoint
/// headers) must be bounded or a line of 100k `[`s would overflow the
/// stack and abort the process. Every legitimate document in this repo
/// nests single digits deep.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while matches!(self.b.get(self.i), Some(b' ' | b'\t' | b'\n' | b'\r'))
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        let tail = self.b.get(self.i..).unwrap_or(&[]);
        if tail.starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} levels"));
        }
        let v = self.value_inner();
        self.depth -= 1;
        v
    }

    fn value_inner(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Object(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Object(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Array(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Array(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hb = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let hex = std::str::from_utf8(hb)
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    let run = self.b.get(start..self.i).unwrap_or(&[]);
                    s.push_str(
                        std::str::from_utf8(run)
                            .map_err(|_| "invalid utf8")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            is_float = true;
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let digits = self.b.get(start..self.i).unwrap_or(&[]);
        let txt = std::str::from_utf8(digits).map_err(|_| "bad number")?;
        if is_float {
            txt.parse::<f64>()
                .map(Json::Float)
                .map_err(|e| format!("bad number '{txt}': {e}"))
        } else {
            txt.parse::<i64>()
                .map(Json::Int)
                .map_err(|e| format!("bad int '{txt}': {e}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, -2, 3.5], "b": {"c": "x\ny", "d": null, "e": true}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_array().unwrap()[1].as_i64(), Some(-2));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn big_integers_exact() {
        // i64 golden checksums must not go through f64
        let v = Json::parse("9223372036854775807").unwrap();
        assert_eq!(v.as_i64(), Some(i64::MAX));
        let v = Json::parse("-9223372036854775808").unwrap();
        assert_eq!(v.as_i64(), Some(i64::MIN));
        assert_eq!(Json::Int(i64::MAX).dump(), "9223372036854775807");
    }

    #[test]
    fn u64_fnv_checksums_fit_via_two_i64() {
        // fnv hashes > i64::MAX are stored by python as plain ints; json
        // spec allows them but we reject — aot.py masks to u64 and we read
        // them as the raw literal when <= i64::MAX, else error. Verify the
        // error is clean.
        assert!(Json::parse("18446744073709551615").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn deep_nesting_errs_instead_of_blowing_the_stack() {
        // adversarial wire input: one line of brackets far beyond any
        // legitimate document must come back as Err, never a stack
        // overflow (which aborts the whole process)
        for n in [MAX_DEPTH + 1, 100_000] {
            let s = "[".repeat(n);
            let err = Json::parse(&s).unwrap_err();
            assert!(err.contains("nesting"), "{err}");
            let s = format!("{}1{}", "[".repeat(n), "]".repeat(n));
            assert!(Json::parse(&s).is_err());
        }
        // mixed arrays/objects count too
        let s = "{\"a\":[".repeat(MAX_DEPTH);
        assert!(Json::parse(&s).is_err());
        // legitimate depth still parses
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH - 1),
                         "]".repeat(MAX_DEPTH - 1));
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn int_vecs() {
        let v = Json::parse("[1,2,3]").unwrap();
        assert_eq!(v.i32_vec().unwrap(), vec![1, 2, 3]);
        assert_eq!(v.usize_vec().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn escapes_written() {
        let v = Json::Str("a\"b\\c\n".into());
        assert_eq!(v.dump(), r#""a\"b\\c\n""#);
    }

    #[test]
    fn pretty_roundtrips_and_indents() {
        let src = r#"{"a": [1, 2, {"b": true}], "c": {}, "d": [], "e": "x"}"#;
        let v = Json::parse(src).unwrap();
        let p = v.pretty();
        assert_eq!(Json::parse(&p).unwrap(), v);
        assert!(p.contains("  \"a\": ["), "{p}");
        assert!(p.contains("\"c\": {}"), "empty containers stay inline: {p}");
        assert!(p.ends_with('\n'));
    }

    #[test]
    fn defaulted_accessors() {
        let v = Json::parse(r#"{"s": "x", "n": 7, "f": 1.5, "b": true}"#)
            .unwrap();
        assert_eq!(v.str_or("s", "d"), "x");
        assert_eq!(v.str_or("missing", "d"), "d");
        assert_eq!(v.i64_or("n", 0), 7);
        assert_eq!(v.usize_or("missing", 3), 3);
        assert_eq!(v.f64_or("f", 0.0), 1.5);
        assert_eq!(v.f64_or("n", 0.0), 7.0);
        assert!(v.bool_or("b", false));
        assert!(!v.bool_or("missing", false));
    }
}
