//! Scoped data-parallel helpers (replaces `rayon` for this repo's needs).
//!
//! The LES training step is embarrassingly parallel across local-loss
//! blocks (the paper notes block backward passes are independent — §3.3);
//! conv/matmul kernels are parallel across the batch. Both use
//! [`scoped_map`] / [`for_each_chunk`], built on `std::thread::scope` so no
//! 'static bounds or channels are needed.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of workers to use: `NITRO_THREADS` env var, else available
/// parallelism, else 1.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("NITRO_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Apply `f` to every item of `items`, running at most `workers` threads,
/// returning outputs in input order. Panics in workers propagate.
pub fn scoped_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    let items: Vec<std::sync::Mutex<Option<T>>> =
        items.into_iter().map(|t| std::sync::Mutex::new(Some(t))).collect();
    let next = AtomicUsize::new(0);
    let done = std::sync::Mutex::new(Vec::<(usize, R)>::with_capacity(n));
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = items[i].lock().unwrap().take().unwrap();
                let r = f(item); // the expensive part, outside any lock
                done.lock().unwrap().push((i, r));
            });
        }
    });
    let mut done = done.into_inner().unwrap();
    done.sort_by_key(|(i, _)| *i);
    assert_eq!(done.len(), n);
    done.into_iter().map(|(_, r)| r).collect()
}

/// Split `data` into `chunks` contiguous mutable chunks and run `f(chunk
/// index, chunk)` in parallel. Used by the tensor kernels to parallelize
/// over the batch dimension.
pub fn for_each_chunk<T, F>(data: &mut [T], chunk_len: usize, workers: usize,
                            f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() || chunk_len == 0 {
        return;
    }
    let workers = workers.max(1);
    if workers == 1 {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let nchunks = data.len().div_ceil(chunk_len);
    let chunks: Vec<std::sync::Mutex<Option<(usize, &mut [T])>>> = data
        .chunks_mut(chunk_len)
        .enumerate()
        .map(|(i, c)| std::sync::Mutex::new(Some((i, c))))
        .collect();
    std::thread::scope(|s| {
        for _ in 0..workers.min(nchunks) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= nchunks {
                    break;
                }
                let (idx, chunk) = chunks[i].lock().unwrap().take().unwrap();
                f(idx, chunk);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let out = scoped_map((0..100).collect::<Vec<_>>(), 8, |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_single_worker_path() {
        let out = scoped_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn map_empty() {
        let out: Vec<i32> = scoped_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn chunks_cover_all_disjointly() {
        let mut data = vec![0u32; 1003]; // non-divisible tail
        for_each_chunk(&mut data, 100, 7, |i, c| {
            for v in c.iter_mut() {
                *v += 1 + i as u32;
            }
        });
        // every element written exactly once with its chunk index
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, 1 + (i / 100) as u32);
        }
    }

    #[test]
    fn map_order_stable_under_uneven_work() {
        // early items sleep so later items finish first; outputs must
        // still come back in input order for every worker budget
        for workers in [2, 3, 8, 64] {
            let out = scoped_map((0..48u64).collect::<Vec<_>>(), workers,
                |x| {
                    if x < 4 {
                        std::thread::sleep(
                            std::time::Duration::from_millis(5));
                    }
                    x * x
                });
            assert_eq!(out, (0..48u64).map(|x| x * x).collect::<Vec<_>>(),
                       "workers={workers}");
        }
    }

    #[test]
    fn map_worker_panic_propagates() {
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            scoped_map((0..16).collect::<Vec<_>>(), 4, |x| {
                if x == 9 {
                    panic!("worker bug");
                }
                x
            })
        }));
        assert!(r.is_err(), "a worker panic must reach the caller");
        // single-worker (sequential) path propagates too
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            scoped_map(vec![1, 2, 3], 1, |x| {
                if x == 2 {
                    panic!("worker bug");
                }
                x
            })
        }));
        assert!(r.is_err());
    }

    #[test]
    fn workers_actually_parallel() {
        // With 4 workers and 4 sleeping tasks the wall time must be well
        // under the serial sum (smoke check, generous margins).
        use std::time::{Duration, Instant};
        let t0 = Instant::now();
        scoped_map(vec![(); 4], 4, |_| {
            std::thread::sleep(Duration::from_millis(100))
        });
        assert!(t0.elapsed() < Duration::from_millis(350));
    }
}
