//! Data-parallel helpers backed by a persistent worker pool (replaces
//! `rayon` for this repo's needs).
//!
//! The LES training step is embarrassingly parallel across local-loss
//! blocks (the paper notes block backward passes are independent — §3.3);
//! conv/matmul kernels are parallel across the batch and output rows. Both
//! funnel through [`scoped_map`] / [`for_each_chunk`].
//!
//! ## Threading model
//!
//! * A process-wide [`pool`] of `available_parallelism() - 1` workers is
//!   spawned lazily on the first parallel call and lives for the process
//!   lifetime, parked on a condvar when idle. Kernel calls no longer spawn
//!   OS threads — the seed's per-call `std::thread::scope` backend cost
//!   tens of microseconds of spawn/join per kernel invocation.
//! * Each call enqueues participation tickets for one job and the caller
//!   participates too, so `workers = w` runs on `min(w, pool + 1)`
//!   threads. `workers <= 1` is executed inline on the caller — the fully
//!   deterministic single-thread mode selected by `NITRO_WORKERS=1`
//!   (no pool is ever built, no thread is ever spawned).
//! * Jobs submitted *from* a pool worker run inline (hierarchical
//!   parallelism: the outer level fans out, inner levels stay
//!   sequential), which makes nested-submission deadlock impossible.
//! * Long-lived **stage workers** (the pipelined LES scheduler's
//!   per-block threads, `train::pipeline`) coexist with the pool under
//!   the single `NITRO_WORKERS` budget: each stage sets a thread-local
//!   budget override ([`set_thread_workers`]) of
//!   `max(1, budget / stages)`, and every kernel consults
//!   [`current_workers`] instead of the global default — so with budget
//!   == stages each stage's kernels run inline and total thread usage
//!   stays at the budget.
//! * A panicking task is caught on the worker, forwarded, and re-raised
//!   on the submitting caller; the worker thread itself survives and
//!   keeps serving subsequent jobs.
//! * Results are bit-identical for every worker count and backend: work
//!   items write disjoint output regions, integer arithmetic is exact,
//!   and [`scoped_map`] restores input order.
//!
//! The seed per-call-spawn backend is kept behind [`set_spawn_mode`] so
//! `nitro bench-kernels` can measure the pool against it and property
//! tests can cross-check bit-exactness.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Number of workers to use: `NITRO_WORKERS` env var (legacy alias
/// `NITRO_THREADS`), else available parallelism, else 1.
pub fn default_workers() -> usize {
    workers_from_env(
        std::env::var("NITRO_WORKERS").ok(),
        std::env::var("NITRO_THREADS").ok(),
    )
}

thread_local! {
    /// Per-thread kernel worker-budget override (0 = unset). Long-lived
    /// stage workers of the pipelined LES scheduler set this so the single
    /// `NITRO_WORKERS` budget is split across stages instead of each stage
    /// fanning its kernels out to the full budget.
    static THREAD_WORKERS: std::cell::Cell<usize> =
        const { std::cell::Cell::new(0) };
}

/// Set (or with `0` clear) this thread's kernel worker budget. The
/// pipelined scheduler gives each stage thread a budget of
/// `max(1, NITRO_WORKERS / stages)`; `1` makes every kernel on that
/// thread run inline — the fully deterministic no-thread mode, per
/// thread.
pub fn set_thread_workers(n: usize) {
    THREAD_WORKERS.set(n);
}

/// The worker budget in effect on this thread: the thread-local override
/// if set, else [`default_workers`]. Kernels and schedulers consult this,
/// never `default_workers` directly, so stage workers and tests can scope
/// the budget without touching the process environment.
pub fn current_workers() -> usize {
    let t = THREAD_WORKERS.get();
    if t > 0 {
        t
    } else {
        default_workers()
    }
}

/// The raw thread-local override (0 = unset). Scoped code that narrows
/// the budget temporarily (the replica trainer's per-shard split, bench
/// harnesses) saves this and restores it afterwards instead of
/// clobbering an enclosing override back to "unset" — use
/// [`scoped_thread_workers`], which makes that the only option.
pub fn thread_workers() -> usize {
    THREAD_WORKERS.get()
}

/// RAII scope for the thread-local worker budget: the override the
/// guard saw at construction (including "unset") comes back on drop —
/// panic-safe, and nesting-correct by construction. The replica
/// trainer's per-shard budget split and the bench harnesses use this
/// instead of hand-rolled reset guards.
#[must_use = "dropping the guard immediately restores the old budget"]
pub struct BudgetScope(usize);

impl Drop for BudgetScope {
    fn drop(&mut self) {
        set_thread_workers(self.0);
    }
}

/// Set this thread's worker budget for the lifetime of the returned
/// guard.
pub fn scoped_thread_workers(n: usize) -> BudgetScope {
    let prev = thread_workers();
    set_thread_workers(n);
    BudgetScope(prev)
}

fn workers_from_env(primary: Option<String>, legacy: Option<String>) -> usize {
    for v in [primary, legacy].into_iter().flatten() {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Benchmark-only switch: route [`scoped_map`] / [`for_each_chunk`]
/// through the legacy per-call `std::thread::scope` backend instead of
/// the persistent pool. Semantics (including bit-exact outputs and panic
/// propagation) are identical; only dispatch cost differs. Used by
/// `nitro bench-kernels` to quantify the pool speedup.
pub fn set_spawn_mode(on: bool) {
    SPAWN_MODE.store(on, Ordering::Relaxed);
}

static SPAWN_MODE: AtomicBool = AtomicBool::new(false);

fn spawn_mode() -> bool {
    SPAWN_MODE.load(Ordering::Relaxed)
}

/// Run `task` concurrently on up to `participants` threads: the caller
/// plus `participants - 1` pool workers (or freshly spawned threads in
/// spawn mode). `task` must be a self-scheduling work loop (the helpers
/// below share an atomic cursor).
fn run_on(participants: usize, task: &(dyn Fn() + Sync)) {
    if participants <= 1 || pool::on_pool_thread() {
        task();
        return;
    }
    if spawn_mode() {
        std::thread::scope(|s| {
            for _ in 1..participants {
                s.spawn(task);
            }
            task();
        });
        return;
    }
    pool::run(participants - 1, task);
}

/// Apply `f` to every item of `items`, running at most `workers` threads,
/// returning outputs in input order. Panics in workers propagate.
pub fn scoped_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.into_iter().map(f).collect();
    }
    let items: Vec<std::sync::Mutex<Option<T>>> =
        items.into_iter().map(|t| std::sync::Mutex::new(Some(t))).collect();
    let next = AtomicUsize::new(0);
    let done = std::sync::Mutex::new(Vec::<(usize, R)>::with_capacity(n));
    run_on(workers, &|| loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        let item = items[i].lock().unwrap().take().unwrap();
        let r = f(item); // the expensive part, outside any lock
        done.lock().unwrap().push((i, r));
    });
    let mut done = done.into_inner().unwrap();
    done.sort_by_key(|(i, _)| *i);
    assert_eq!(done.len(), n);
    done.into_iter().map(|(_, r)| r).collect()
}

/// Split `data` into contiguous mutable chunks of `chunk_len` and run
/// `f(chunk index, chunk)` in parallel. Used by the tensor kernels to
/// parallelize over the batch dimension and output row blocks.
pub fn for_each_chunk<T, F>(data: &mut [T], chunk_len: usize, workers: usize,
                            f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if data.is_empty() || chunk_len == 0 {
        return;
    }
    let nchunks = data.len().div_ceil(chunk_len);
    let workers = workers.max(1).min(nchunks);
    if workers == 1 {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let chunks: Vec<std::sync::Mutex<Option<(usize, &mut [T])>>> = data
        .chunks_mut(chunk_len)
        .enumerate()
        .map(|(i, c)| std::sync::Mutex::new(Some((i, c))))
        .collect();
    run_on(workers, &|| loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= nchunks {
            break;
        }
        let (idx, chunk) = chunks[i].lock().unwrap().take().unwrap();
        f(idx, chunk);
    });
}

/// The persistent worker pool behind [`scoped_map`] / [`for_each_chunk`].
pub mod pool {
    use super::*;
    use std::collections::VecDeque;
    use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
    use std::sync::{Arc, Condvar, Mutex, OnceLock};

    thread_local! {
        static IS_POOL_WORKER: std::cell::Cell<bool> =
            const { std::cell::Cell::new(false) };
    }

    /// True on a pool worker thread. Parallel helpers called from inside a
    /// pool task run inline instead of re-submitting (no nested blocking,
    /// hence no deadlock).
    pub fn on_pool_thread() -> bool {
        IS_POOL_WORKER.get()
    }

    /// Number of persistent workers (0 on a single-core box, where every
    /// call runs inline on the caller). Querying the size does **not**
    /// spawn the workers — only an actual job submission does.
    pub fn size() -> usize {
        pool_data().threads
    }

    /// One submitted job. `task` is a lifetime-erased borrow of the
    /// caller's closure; [`run`] guarantees the caller blocks until every
    /// ticket finished, so workers never observe a dangling reference.
    struct JobState {
        task: &'static (dyn Fn() + Sync),
        pending: AtomicUsize,
        panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
        done: Mutex<bool>,
        cv: Condvar,
    }

    struct Pool {
        queue: Mutex<VecDeque<Arc<JobState>>>,
        work_cv: Condvar,
        threads: usize,
    }

    static POOL: OnceLock<Pool> = OnceLock::new();
    static STARTED: OnceLock<()> = OnceLock::new();

    fn pool_data() -> &'static Pool {
        POOL.get_or_init(|| Pool {
            queue: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            // sized to the hardware; per-call worker budgets
            // (NITRO_WORKERS) are clamped to `threads + 1` participants
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .saturating_sub(1),
        })
    }

    fn global() -> &'static Pool {
        let p = pool_data();
        STARTED.get_or_init(|| {
            for i in 0..p.threads {
                std::thread::Builder::new()
                    .name(format!("nitro-pool-{i}"))
                    .spawn(move || worker_loop(p))
                    .expect("spawn nitro pool worker");
            }
        });
        p
    }

    fn worker_loop(p: &'static Pool) {
        IS_POOL_WORKER.set(true);
        loop {
            let job = {
                let mut q = p.queue.lock().unwrap();
                loop {
                    if let Some(j) = q.pop_front() {
                        break j;
                    }
                    q = p.work_cv.wait(q).unwrap();
                }
            };
            run_ticket(&job);
        }
    }

    /// Execute one participation ticket: run the job's work loop once,
    /// catching panics so the worker survives, and signal the caller when
    /// the last ticket completes.
    fn run_ticket(job: &JobState) {
        let r = catch_unwind(AssertUnwindSafe(|| (job.task)()));
        if let Err(e) = r {
            let mut slot = job.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(e);
            }
        }
        if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            let mut d = job.done.lock().unwrap();
            *d = true;
            job.cv.notify_all();
        }
    }

    /// Run `task` on this thread plus up to `extra` pool workers; returns
    /// after every participant finished. Worker panics re-raise here.
    ///
    /// Contract: `task` must be a **self-scheduling work loop** over a
    /// shared cursor (as [`super::scoped_map`] / [`super::for_each_chunk`]
    /// build) — once one participant's loop exhausts the cursor, extra
    /// invocations are no-ops. That is what makes cancelling this job's
    /// unclaimed tickets sound after the caller's own loop returns.
    // `unsafe` is limited to the lifetime-erasure transmute below;
    // exempted from the crate-root `#![deny(unsafe_code)]`.
    #[allow(unsafe_code)]
    pub(super) fn run(extra: usize, task: &(dyn Fn() + Sync)) {
        let p = global();
        let extra = extra.min(p.threads);
        if extra == 0 {
            task();
            return;
        }
        // SAFETY: lifetime erasure only — the reference is handed to pool
        // workers and this function does not return (or unwind) until
        // `pending` hit zero, i.e. until no worker can touch it again.
        let task: &'static (dyn Fn() + Sync) =
            unsafe { std::mem::transmute(task) };
        let job = Arc::new(JobState {
            task,
            pending: AtomicUsize::new(extra),
            panic: Mutex::new(None),
            done: Mutex::new(false),
            cv: Condvar::new(),
        });
        {
            let mut q = p.queue.lock().unwrap();
            for _ in 0..extra {
                q.push_back(job.clone());
            }
        }
        if extra == 1 {
            p.work_cv.notify_one();
        } else {
            p.work_cv.notify_all();
        }
        let caller = catch_unwind(AssertUnwindSafe(|| (job.task)()));
        // The caller's work loop exhausted the shared cursor, so tickets
        // still sitting in the queue would only run a no-op pass — cancel
        // them instead of stalling behind other jobs' queued work. Tickets
        // already popped belong to workers mid-execution; those are waited
        // for below.
        {
            let mut q = p.queue.lock().unwrap();
            let before = q.len();
            q.retain(|j| !Arc::ptr_eq(j, &job));
            let cancelled = before - q.len();
            if cancelled > 0
                && job.pending.fetch_sub(cancelled, Ordering::AcqRel)
                    == cancelled
            {
                let mut d = job.done.lock().unwrap();
                *d = true;
            }
        }
        // Wait for every remaining ticket even if the caller's share
        // panicked: the borrow behind `task` must outlive all workers' use
        // of it.
        let mut d = job.done.lock().unwrap();
        while !*d {
            d = job.cv.wait(d).unwrap();
        }
        drop(d);
        if let Err(e) = caller {
            resume_unwind(e);
        }
        if let Some(e) = job.panic.lock().unwrap().take() {
            resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let out = scoped_map((0..100).collect::<Vec<_>>(), 8, |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_single_worker_path() {
        let out = scoped_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn map_empty() {
        let out: Vec<i32> = scoped_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn chunks_cover_all_disjointly() {
        let mut data = vec![0u32; 1003]; // non-divisible tail
        for_each_chunk(&mut data, 100, 7, |i, c| {
            for v in c.iter_mut() {
                *v += 1 + i as u32;
            }
        });
        // every element written exactly once with its chunk index
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, 1 + (i / 100) as u32);
        }
    }

    #[test]
    fn map_order_stable_under_uneven_work() {
        // early items sleep so later items finish first; outputs must
        // still come back in input order for every worker budget
        for workers in [2, 3, 8, 64] {
            let out = scoped_map((0..48u64).collect::<Vec<_>>(), workers,
                |x| {
                    if x < 4 {
                        std::thread::sleep(
                            std::time::Duration::from_millis(5));
                    }
                    x * x
                });
            assert_eq!(out, (0..48u64).map(|x| x * x).collect::<Vec<_>>(),
                       "workers={workers}");
        }
    }

    #[test]
    fn map_worker_panic_propagates() {
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            scoped_map((0..16).collect::<Vec<_>>(), 4, |x| {
                if x == 9 {
                    panic!("worker bug");
                }
                x
            })
        }));
        assert!(r.is_err(), "a worker panic must reach the caller");
        // single-worker (sequential) path propagates too
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            scoped_map(vec![1, 2, 3], 1, |x| {
                if x == 2 {
                    panic!("worker bug");
                }
                x
            })
        }));
        assert!(r.is_err());
    }

    #[test]
    fn pool_survives_panicking_task() {
        // a panicking job must not kill pool workers or wedge the queue:
        // subsequent jobs complete with correct results
        for round in 0..3 {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || {
                    scoped_map((0..32).collect::<Vec<_>>(), 8, |x| {
                        if x % 11 == round {
                            panic!("deliberate task panic");
                        }
                        x
                    })
                },
            ));
            assert!(r.is_err(), "round {round}");
            let out =
                scoped_map((0..64).collect::<Vec<_>>(), 8, |x| x + round);
            assert_eq!(
                out,
                (0..64).map(|x| x + round).collect::<Vec<_>>(),
                "pool wedged after panic round {round}"
            );
        }
    }

    #[test]
    fn nested_calls_from_pool_tasks_run_inline() {
        // a parallel helper invoked inside a pool task must not deadlock
        // (it runs sequentially on the worker) and must stay correct
        let sums = scoped_map((0..8u64).collect::<Vec<_>>(), 4, |x| {
            let mut v = vec![0u64; 100];
            for_each_chunk(&mut v, 10, 4, |i, c| {
                for w in c.iter_mut() {
                    *w = x + i as u64;
                }
            });
            v.iter().sum::<u64>()
        });
        let want: Vec<u64> =
            (0..8u64).map(|x| (0..10u64).map(|i| (x + i) * 10).sum()).collect();
        assert_eq!(sums, want);
    }

    #[test]
    fn spawn_backend_matches_pool_backend() {
        // the legacy per-call-spawn backend must be observationally
        // identical (bench-kernels relies on this to compare them). Spawn
        // mode is a global perf knob, so restore it even on panic.
        struct Reset;
        impl Drop for Reset {
            fn drop(&mut self) {
                set_spawn_mode(false);
            }
        }
        let _reset = Reset;
        let pool_out = scoped_map((0..50).collect::<Vec<_>>(), 6, |x| x * 3);
        set_spawn_mode(true);
        let spawn_out = scoped_map((0..50).collect::<Vec<_>>(), 6, |x| x * 3);
        set_spawn_mode(false);
        assert_eq!(pool_out, spawn_out);
    }

    #[test]
    fn workers_env_parsing() {
        let s = |v: &str| Some(v.to_string());
        assert_eq!(workers_from_env(s("4"), None), 4);
        assert_eq!(workers_from_env(s("0"), None), 1, "clamped to >= 1");
        assert_eq!(workers_from_env(None, s("3")), 3, "legacy alias");
        assert_eq!(workers_from_env(s("6"), s("3")), 6, "primary wins");
        // unparseable primary falls through to the legacy alias
        assert_eq!(workers_from_env(s("lots"), s("2")), 2);
        let hw = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        assert_eq!(workers_from_env(None, None), hw);
        assert_eq!(workers_from_env(s(""), s("junk")), hw);
    }

    #[test]
    fn thread_budget_override_scopes_to_thread() {
        // the override wins on the setting thread, is invisible to other
        // threads, and clears with 0
        set_thread_workers(1);
        assert_eq!(current_workers(), 1);
        std::thread::spawn(|| {
            assert_eq!(current_workers(), default_workers());
            set_thread_workers(3);
            assert_eq!(current_workers(), 3);
        })
        .join()
        .unwrap();
        assert_eq!(current_workers(), 1, "other thread must not leak");
        set_thread_workers(0);
        assert_eq!(current_workers(), default_workers());
    }

    #[test]
    fn scoped_budget_restores_enclosing_override() {
        // nested scopes restore the *prior* override, not "unset"
        set_thread_workers(0);
        {
            let _outer = scoped_thread_workers(5);
            assert_eq!(current_workers(), 5);
            {
                let _inner = scoped_thread_workers(2);
                assert_eq!(current_workers(), 2);
            }
            assert_eq!(current_workers(), 5, "inner scope must restore 5");
        }
        assert_eq!(thread_workers(), 0, "outer scope must restore unset");
    }

    #[test]
    fn workers_actually_parallel() {
        // With 4 workers and 4 sleeping tasks the wall time must be well
        // under the serial sum (smoke check, generous margins).
        use std::time::{Duration, Instant};
        if pool::size() < 3 {
            eprintln!("skipping: not enough pool workers");
            return;
        }
        // warm the pool so thread startup is not measured
        scoped_map(vec![(); 4], 4, |_| {});
        let t0 = Instant::now();
        scoped_map(vec![(); 4], 4, |_| {
            std::thread::sleep(Duration::from_millis(100))
        });
        assert!(t0.elapsed() < Duration::from_millis(350));
    }
}
