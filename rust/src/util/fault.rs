//! Deterministic fault injection for the distributed training transport.
//!
//! A [`FaultPlan`] is a list of [`FaultRule`]s parsed from a JSON array
//! (CLI `--fault-plan FILE|JSON`, env `NITRO_FAULT`). Each rule names a
//! fault `kind` and matches on the injecting rank, the peer on the other
//! end of the connection, and the training step — all optional, absent
//! means "any". The transport seam in `train::dist` consults the plan at
//! three points:
//!
//! * **connect** — before dialing a peer ([`FaultPlan::on_connect`]):
//!   `drop` refuses the attempt, `partition` refuses it for as long as
//!   the rule matches, `delay` sleeps before dialing.
//! * **send** — before writing a frame ([`FaultPlan::on_send`]): `drop`
//!   discards the frame (the peer sees a silent loss), `delay` sleeps
//!   `ms` first, `stall` holds the frame for `ms` (a slow-peer stall the
//!   receiver's deadline must absorb or cut), `partition` severs the
//!   link (the write errors as if the cable were pulled).
//! * **step boundary** — after finishing step `k`
//!   ([`FaultPlan::crash_at`]): `crash` terminates the rank. The CLI
//!   exits the process with [`CRASH_EXIT_CODE`]; in-process test harness
//!   ranks return from their thread instead.
//!
//! Every decision is a pure function of (rule list, rank, peer, step) —
//! no randomness, no wall clock — so a fault schedule replays exactly
//! and the recovery path it exercises is testable bit-for-bit.
//!
//! Grammar (JSON, one object per rule):
//!
//! ```jsonc
//! [
//!   {"kind": "crash", "rank": 1, "step": 5},
//!   {"kind": "drop",  "rank": 0, "peer": 2, "step": 3},
//!   {"kind": "delay", "rank": 1, "ms": 40},
//!   {"kind": "stall", "rank": 2, "peer": 0, "step": 2, "ms": 200},
//!   {"kind": "partition", "rank": 0, "peer": 1, "step": 4, "until_step": 6}
//! ]
//! ```
//!
//! `step`/`until_step` bound the half-open step window `[step,
//! until_step)`; omitting `until_step` makes the rule fire on `step`
//! alone (or, with `step` also absent, on every step). `ms` is required
//! for `delay`/`stall` and ignored otherwise.

use crate::util::jsonio::Json;

/// Exit code a rank terminates with when a `crash` rule fires — distinct
/// from clean exit (0) and usage/config errors (2) so the CI fault lane
/// can assert the crash actually happened before the rejoin.
pub const CRASH_EXIT_CODE: i32 = 43;

/// One fault kind at the transport seam.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Discard one matching frame / refuse one connect attempt.
    Drop,
    /// Sleep `ms` before the matching send / connect proceeds.
    Delay,
    /// Hold a matching frame for `ms` before sending (slow peer).
    Stall,
    /// Sever the link: sends error, connects are refused, for the whole
    /// matching step window.
    Partition,
    /// Terminate the rank at the matching step boundary.
    Crash,
}

impl FaultKind {
    fn parse(s: &str) -> Result<FaultKind, String> {
        Ok(match s {
            "drop" => FaultKind::Drop,
            "delay" => FaultKind::Delay,
            "stall" => FaultKind::Stall,
            "partition" => FaultKind::Partition,
            "crash" => FaultKind::Crash,
            other => {
                return Err(format!(
                    "fault plan: unknown kind '{other}' (expected drop, \
                     delay, stall, partition or crash)"
                ))
            }
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Drop => "drop",
            FaultKind::Delay => "delay",
            FaultKind::Stall => "stall",
            FaultKind::Partition => "partition",
            FaultKind::Crash => "crash",
        }
    }
}

/// One rule: a kind plus optional match fields.
#[derive(Clone, Debug)]
pub struct FaultRule {
    pub kind: FaultKind,
    /// Rank doing the injecting (`None` = any rank).
    pub rank: Option<usize>,
    /// Peer on the other end of the link (`None` = any peer).
    pub peer: Option<usize>,
    /// First step the rule fires on (`None` = any step).
    pub step: Option<u64>,
    /// One past the last step of the window; `None` with `step` set
    /// means the single step `step`.
    pub until_step: Option<u64>,
    /// Sleep duration for `delay` / `stall`.
    pub ms: u64,
}

impl FaultRule {
    fn matches(&self, rank: usize, peer: Option<usize>, step: u64) -> bool {
        if self.rank.is_some_and(|r| r != rank) {
            return false;
        }
        match (self.peer, peer) {
            (Some(want), Some(got)) if want != got => return false,
            (Some(_), None) => return false,
            _ => {}
        }
        match (self.step, self.until_step) {
            (Some(s), Some(u)) => step >= s && step < u,
            (Some(s), None) => step == s,
            (None, _) => true,
        }
    }
}

/// What the transport seam should do with one send / connect attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SendAction {
    /// No matching rule: perform the operation normally.
    Deliver,
    /// Discard the frame (send) / refuse this attempt (connect).
    Drop,
    /// Sleep this many ms, then perform the operation.
    DelayMs(u64),
    /// The link is severed for this step window: error the operation.
    Partitioned,
}

/// A parsed fault plan: the ordered rule list. First matching rule wins,
/// so plans compose left to right like a firewall table.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// Parse from JSON text (a JSON array of rule objects).
    pub fn parse(text: &str) -> Result<FaultPlan, String> {
        let j = Json::parse(text).map_err(|e| format!("fault plan: {e}"))?;
        let arr = j
            .as_array()
            .ok_or("fault plan: top level must be a JSON array")?;
        let mut rules = Vec::with_capacity(arr.len());
        for (i, r) in arr.iter().enumerate() {
            let kind_s = r
                .req("kind")
                .map_err(|e| format!("fault plan rule {i}: {e}"))?
                .as_str()
                .ok_or_else(|| {
                    format!("fault plan rule {i}: 'kind' is not a string")
                })?;
            let kind = FaultKind::parse(kind_s)
                .map_err(|e| format!("rule {i}: {e}"))?;
            let opt_u64 = |key: &str| -> Result<Option<u64>, String> {
                match r.get(key) {
                    None => Ok(None),
                    Some(v) => v
                        .as_i64()
                        .filter(|&n| n >= 0)
                        .map(|n| Some(n as u64))
                        .ok_or_else(|| {
                            format!(
                                "fault plan rule {i}: '{key}' must be a \
                                 non-negative integer"
                            )
                        }),
                }
            };
            let ms = opt_u64("ms")?.unwrap_or(0);
            if matches!(kind, FaultKind::Delay | FaultKind::Stall) && ms == 0
            {
                return Err(format!(
                    "fault plan rule {i}: '{}' needs a positive 'ms'",
                    kind.name()
                ));
            }
            let step = opt_u64("step")?;
            let until_step = opt_u64("until_step")?;
            if let (Some(s), Some(u)) = (step, until_step) {
                if u <= s {
                    return Err(format!(
                        "fault plan rule {i}: until_step {u} <= step {s}"
                    ));
                }
            }
            rules.push(FaultRule {
                kind,
                rank: opt_u64("rank")?.map(|v| v as usize),
                peer: opt_u64("peer")?.map(|v| v as usize),
                step,
                until_step,
                ms,
            });
        }
        Ok(FaultPlan { rules })
    }

    /// Parse from a CLI argument: a path to a JSON file, or inline JSON
    /// (anything starting with `[`).
    pub fn from_arg(arg: &str) -> Result<FaultPlan, String> {
        let trimmed = arg.trim_start();
        if trimmed.starts_with('[') {
            FaultPlan::parse(arg)
        } else {
            let text = std::fs::read_to_string(arg)
                .map_err(|e| format!("fault plan {arg}: {e}"))?;
            FaultPlan::parse(&text)
        }
    }

    /// Parse from the `NITRO_FAULT` environment variable, if set.
    pub fn from_env() -> Result<Option<FaultPlan>, String> {
        match std::env::var("NITRO_FAULT") {
            Ok(v) if !v.is_empty() => FaultPlan::from_arg(&v).map(Some),
            _ => Ok(None),
        }
    }

    fn first_match(&self, rank: usize, peer: Option<usize>, step: u64)
                   -> Option<&FaultRule> {
        self.rules
            .iter()
            .find(|r| r.kind != FaultKind::Crash
                      && r.matches(rank, peer, step))
    }

    /// Decide the fate of a frame `rank` is about to send to `peer` at
    /// training step `step`. `stall` and `delay` both map to
    /// [`SendAction::DelayMs`] — at the send seam the difference is only
    /// intent (stall models a slow peer, delay models a slow link).
    pub fn on_send(&self, rank: usize, peer: usize, step: u64)
                   -> SendAction {
        match self.first_match(rank, Some(peer), step) {
            None => SendAction::Deliver,
            Some(r) => match r.kind {
                FaultKind::Drop => SendAction::Drop,
                FaultKind::Delay | FaultKind::Stall => {
                    SendAction::DelayMs(r.ms)
                }
                FaultKind::Partition => SendAction::Partitioned,
                FaultKind::Crash => unreachable!("filtered above"),
            },
        }
    }

    /// Decide the fate of a connect attempt from `rank` to `peer` at
    /// step `step`. `drop` refuses one attempt (retry may succeed if the
    /// window moves), `partition` refuses while the window matches.
    pub fn on_connect(&self, rank: usize, peer: usize, step: u64)
                      -> SendAction {
        self.on_send(rank, peer, step)
    }

    /// True when `rank` must crash after finishing step `step`.
    pub fn crash_at(&self, rank: usize, step: u64) -> bool {
        self.rules.iter().any(|r| {
            r.kind == FaultKind::Crash && r.matches(rank, None, step)
        })
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_kind_and_matches() {
        let plan = FaultPlan::parse(
            r#"[
                {"kind": "crash", "rank": 1, "step": 5},
                {"kind": "drop",  "rank": 0, "peer": 2, "step": 3},
                {"kind": "delay", "rank": 1, "ms": 40},
                {"kind": "stall", "rank": 2, "peer": 0, "ms": 200},
                {"kind": "partition", "rank": 3, "step": 4,
                 "until_step": 6}
            ]"#,
        )
        .unwrap();
        assert_eq!(plan.rules.len(), 5);
        // crash matches only its rank and step
        assert!(plan.crash_at(1, 5));
        assert!(!plan.crash_at(1, 4));
        assert!(!plan.crash_at(0, 5));
        // drop matches its (rank, peer, step) triple exactly
        assert_eq!(plan.on_send(0, 2, 3), SendAction::Drop);
        assert_eq!(plan.on_send(0, 1, 3), SendAction::Deliver);
        assert_eq!(plan.on_send(0, 2, 4), SendAction::Deliver);
        // delay has no step bound: fires on every step for rank 1
        assert_eq!(plan.on_send(1, 0, 0), SendAction::DelayMs(40));
        assert_eq!(plan.on_send(1, 2, 99), SendAction::DelayMs(40));
        // stall maps to a delay at the send seam
        assert_eq!(plan.on_send(2, 0, 7), SendAction::DelayMs(200));
        assert_eq!(plan.on_send(2, 1, 7), SendAction::Deliver);
        // partition holds for the half-open window [4, 6)
        assert_eq!(plan.on_connect(3, 0, 4), SendAction::Partitioned);
        assert_eq!(plan.on_connect(3, 0, 5), SendAction::Partitioned);
        assert_eq!(plan.on_connect(3, 0, 6), SendAction::Deliver);
    }

    #[test]
    fn first_matching_rule_wins() {
        let plan = FaultPlan::parse(
            r#"[{"kind": "drop", "rank": 0, "step": 1},
                {"kind": "delay", "rank": 0, "ms": 10}]"#,
        )
        .unwrap();
        assert_eq!(plan.on_send(0, 1, 1), SendAction::Drop);
        assert_eq!(plan.on_send(0, 1, 2), SendAction::DelayMs(10));
    }

    #[test]
    fn crash_rules_do_not_shadow_send_decisions() {
        let plan = FaultPlan::parse(
            r#"[{"kind": "crash", "step": 2},
                {"kind": "drop", "step": 2}]"#,
        )
        .unwrap();
        // the crash rule is ignored at the send seam even though it is
        // listed first and matches
        assert_eq!(plan.on_send(0, 1, 2), SendAction::Drop);
        assert!(plan.crash_at(0, 2));
    }

    #[test]
    fn rejects_malformed_plans() {
        for (text, needle) in [
            ("{}", "array"),
            ("[{\"step\": 1}]", "kind"),
            ("[{\"kind\": \"melt\"}]", "unknown kind"),
            ("[{\"kind\": \"delay\"}]", "ms"),
            ("[{\"kind\": \"stall\", \"ms\": 0}]", "ms"),
            ("[{\"kind\": \"drop\", \"rank\": -1}]", "non-negative"),
            (
                "[{\"kind\": \"partition\", \"step\": 5, \
                  \"until_step\": 5}]",
                "until_step",
            ),
            ("not json", "fault plan"),
        ] {
            let err = FaultPlan::parse(text).unwrap_err();
            assert!(err.contains(needle), "{text}: {err}");
        }
    }

    #[test]
    fn from_arg_reads_inline_or_file() {
        let plan =
            FaultPlan::from_arg(r#"[{"kind": "crash", "step": 0}]"#).unwrap();
        assert!(plan.crash_at(0, 0));
        let dir = std::env::temp_dir().join("nitro_fault_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("plan.json");
        std::fs::write(&path, r#"[{"kind": "drop", "rank": 2}]"#).unwrap();
        let plan = FaultPlan::from_arg(path.to_str().unwrap()).unwrap();
        assert_eq!(plan.on_send(2, 0, 0), SendAction::Drop);
        let err = FaultPlan::from_arg("does/not/exist.json").unwrap_err();
        assert!(err.contains("exist.json"), "{err}");
    }

    #[test]
    fn empty_plan_delivers_everything() {
        let plan = FaultPlan::default();
        assert!(plan.is_empty());
        assert_eq!(plan.on_send(0, 1, 0), SendAction::Deliver);
        assert!(!plan.crash_at(0, 0));
    }
}
