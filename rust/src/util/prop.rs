//! Seeded property-test driver (replaces `proptest`, not vendored —
//! DESIGN.md §Substitutions).
//!
//! A property runs `cases` times with a [`Gen`] built from a per-case seed
//! derived from a base seed. On failure the driver retries with the same
//! seed to confirm determinism and reports the seed so the case can be
//! replayed with `NITRO_PROP_SEED`.

use super::rng::Pcg32;

pub struct Gen {
    pub rng: Pcg32,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo + 1) as u32) as usize
    }

    pub fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        self.rng.range_i32(lo, hi)
    }

    pub fn i64_wide(&mut self) -> i64 {
        // mixture: small values + full-range — integer bugs hide at rails
        match self.rng.below(4) {
            0 => self.rng.range_i32(-8, 8) as i64,
            1 => self.rng.range_i32(i32::MIN, i32::MAX) as i64,
            2 => (self.rng.next_u64() >> 20) as i64 * if self.rng.below(2) == 0 { -1 } else { 1 },
            _ => self.rng.range_i32(-200_000, 200_000) as i64,
        }
    }

    pub fn vec_i32(&mut self, len: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..len).map(|_| self.rng.range_i32(lo, hi)).collect()
    }

    pub fn vec_i64(&mut self, len: usize) -> Vec<i64> {
        (0..len).map(|_| self.i64_wide()).collect()
    }
}

/// Run `prop` for `cases` seeded cases; panic with the failing seed.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut prop: F) {
    let base = std::env::var("NITRO_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok());
    let (start, count) = match base {
        Some(seed) => (seed, 1usize),
        None => (0x5eed_0000u64, cases),
    };
    for c in 0..count {
        let seed = start.wrapping_add(c as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let mut g = Gen { rng: Pcg32::new(seed), case: c };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g)
        }));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {c} (replay with \
                 NITRO_PROP_SEED={})",
                start.wrapping_add(c as u64)
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut n = 0;
        check("count", 32, |_| n += 1);
        assert_eq!(n, 32);
    }

    #[test]
    fn deterministic_generation() {
        let mut first: Vec<i64> = Vec::new();
        check("gen1", 8, |g| first.push(g.i64_wide()));
        let mut second: Vec<i64> = Vec::new();
        check("gen2", 8, |g| second.push(g.i64_wide()));
        assert_eq!(first, second);
    }

    #[test]
    #[should_panic]
    fn failure_propagates() {
        check("fails", 10, |g| {
            let v = g.i32_in(0, 100);
            assert!(v < 1000); // passes...
            if g.case == 5 {
                panic!("boom");
            }
        });
    }
}
