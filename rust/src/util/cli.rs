//! Declarative command-line parser (replaces `clap`, not vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and subcommands, with generated `--help` text.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

#[derive(Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    args: Vec<ArgSpec>,
    positionals: Vec<ArgSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, args: Vec::new(), positionals: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &str,
               help: &'static str) -> Self {
        self.args.push(ArgSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn positional(mut self, name: &'static str,
                      help: &'static str) -> Self {
        self.positionals.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for p in &self.positionals {
            s.push_str(&format!("  <{}>  {}\n", p.name, p.help));
        }
        for a in &self.args {
            if a.is_flag {
                s.push_str(&format!("  --{:<18} {}\n", a.name, a.help));
            } else {
                s.push_str(&format!(
                    "  --{:<18} {} (default: {})\n",
                    format!("{} <v>", a.name),
                    a.help,
                    a.default.as_deref().unwrap_or("-")
                ));
            }
        }
        s
    }

    pub fn parse(&self, argv: &[String]) -> Result<Parsed, String> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut pos: Vec<String> = Vec::new();
        for a in &self.args {
            if let Some(d) = &a.default {
                values.insert(a.name.to_string(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(self.usage());
            }
            if let Some(rest) = tok.strip_prefix("--") {
                let (key, inline) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .args
                    .iter()
                    .find(|a| a.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}",
                                           self.usage()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("--{key} takes no value"));
                    }
                    flags.push(key);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} needs a value"))?
                        }
                    };
                    values.insert(key, v);
                }
            } else {
                pos.push(tok.clone());
            }
            i += 1;
        }
        if pos.len() > self.positionals.len() {
            return Err(format!("unexpected positional '{}'\n\n{}", pos.last().unwrap(),
                               self.usage()));
        }
        Ok(Parsed { values, flags, positionals: pos })
    }
}

#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positionals: Vec<String>,
}

impl Parsed {
    pub fn get(&self, key: &str) -> &str {
        self.values.get(key).map(|s| s.as_str()).unwrap_or("")
    }

    pub fn get_usize(&self, key: &str) -> Result<usize, String> {
        self.get(key)
            .parse()
            .map_err(|e| format!("--{key}: {e}"))
    }

    pub fn get_i64(&self, key: &str) -> Result<i64, String> {
        self.get(key)
            .parse()
            .map_err(|e| format!("--{key}: {e}"))
    }

    pub fn get_u64(&self, key: &str) -> Result<u64, String> {
        self.get(key)
            .parse()
            .map_err(|e| format!("--{key}: {e}"))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64, String> {
        self.get(key)
            .parse()
            .map_err(|e| format!("--{key}: {e}"))
    }

    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_options_flags_positionals() {
        let cmd = Command::new("train", "train a model")
            .opt("epochs", "10", "number of epochs")
            .opt("preset", "mlp1", "model preset")
            .flag("quiet", "suppress logs")
            .positional("dataset", "dataset name");
        let p = cmd
            .parse(&argv(&["mnist", "--epochs=5", "--quiet"]))
            .unwrap();
        assert_eq!(p.get_usize("epochs").unwrap(), 5);
        assert_eq!(p.get("preset"), "mlp1"); // default
        assert!(p.has("quiet"));
        assert_eq!(p.positionals, vec!["mnist"]);
    }

    #[test]
    fn space_separated_value() {
        let cmd = Command::new("x", "").opt("k", "0", "");
        let p = cmd.parse(&argv(&["--k", "7"])).unwrap();
        assert_eq!(p.get_i64("k").unwrap(), 7);
    }

    #[test]
    fn unknown_option_is_error_with_usage() {
        let cmd = Command::new("x", "").opt("k", "0", "");
        let err = cmd.parse(&argv(&["--nope"])).unwrap_err();
        assert!(err.contains("unknown option"));
        assert!(err.contains("--k"));
    }

    #[test]
    fn help_is_error_channel() {
        let cmd = Command::new("x", "does x").flag("v", "verbose");
        let err = cmd.parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("does x") && err.contains("--v"));
    }
}
