//! Offline substrates: everything a production crate would pull from
//! crates.io but this image cannot (no network). Each submodule replaces a
//! well-known dependency and is tested in place:
//!
//! * [`rng`]     — PCG32 deterministic PRNG (replaces `rand`)
//! * [`jsonio`]  — minimal JSON parser/writer (replaces `serde_json`)
//! * [`cli`]     — declarative argument parser (replaces `clap`)
//! * [`par`]     — scoped worker pool (replaces `rayon`/`tokio` for the
//!                 block-parallel LES scheduler)
//! * [`bench`]   — statistics-reporting micro-bench harness (replaces
//!                 `criterion`)
//! * [`prop`]    — seeded property-test driver (replaces `proptest`)
//! * [`hist`]    — log-bucketed mergeable latency histogram (replaces
//!                 `hdrhistogram`, for the serving percentiles)
//! * [`fault`]   — deterministic fault-injection plans for the
//!                 distributed training transport (replaces `toxiproxy`
//!                 -style chaos tooling with a replayable pure function)

pub mod bench;
pub mod cli;
pub mod fault;
pub mod hist;
pub mod jsonio;
pub mod par;
pub mod prop;
pub mod rng;

/// Floor division toward −∞ (Python `//`). Rust `/` truncates; using it on
/// negative NITRO pre-activations is the classic porting bug — see
/// DESIGN.md §Numeric-format rules.
#[inline(always)]
pub fn div_floor(a: i64, b: i64) -> i64 {
    debug_assert!(b > 0, "NITRO divisors are positive");
    a.div_euclid(b)
}

/// Division truncating toward zero (C semantics). Used only by the
/// IntegerSGD weight-decay term (DESIGN.md interpretation #8).
#[inline(always)]
pub fn div_trunc(a: i64, b: i64) -> i64 {
    debug_assert!(b > 0);
    a / b
}

/// Integer square root (floor). Mirrors Python `math.isqrt` for the values
/// used by the integer Kaiming initializer.
pub fn isqrt(n: u64) -> u64 {
    if n == 0 {
        return 0;
    }
    let mut x = (n as f64).sqrt() as u64;
    // correct the float seed to the exact floor (checked_mul: x near 2^32
    // overflows u64 squaring — saturating would loop forever at u64::MAX)
    while x > 0 && x.checked_mul(x).map_or(true, |s| s > n) {
        x -= 1;
    }
    while (x + 1).checked_mul(x + 1).is_some_and(|s| s <= n) {
        x += 1;
    }
    x
}

/// Order-sensitive FNV-1a over little-endian i64 bytes plus an i64 element
/// sum. Mirrors `aot._checksum` — the cross-layer fingerprint used by the
/// golden training-trace tests.
pub fn checksum_i32(data: &[i32]) -> (u64, i64) {
    checksum_i64_iter(data.iter().map(|&v| v as i64))
}

pub fn checksum_i64(data: &[i64]) -> (u64, i64) {
    checksum_i64_iter(data.iter().copied())
}

fn checksum_i64_iter(it: impl Iterator<Item = i64>) -> (u64, i64) {
    let mut h: u64 = 14695981039346656037;
    let mut sum: i64 = 0;
    for v in it {
        for b in v.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(1099511628211);
        }
        sum = sum.wrapping_add(v);
    }
    (h, sum)
}

/// Wall-clock seconds helper for logs.
pub fn now_secs() -> f64 {
    use std::time::{SystemTime, UNIX_EPOCH};
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_floor_matches_python() {
        // (a, b, a // b in Python)
        for &(a, b, want) in &[
            (7i64, 2i64, 3i64),
            (-7, 2, -4),
            (-1, 256, -1),
            (-256, 256, -1),
            (-257, 256, -2),
            (255, 256, 0),
            (0, 5, 0),
            (-3001, 3000, -2),
        ] {
            assert_eq!(div_floor(a, b), want, "{a} // {b}");
        }
    }

    #[test]
    fn div_trunc_matches_c() {
        assert_eq!(div_trunc(-3001, 3000), -1);
        assert_eq!(div_trunc(3001, 3000), 1);
        assert_eq!(div_trunc(-2999, 3000), 0);
    }

    #[test]
    fn isqrt_exact() {
        for n in 0..2000u64 {
            let s = isqrt(n);
            assert!(s * s <= n && (s + 1) * (s + 1) > n, "isqrt({n})={s}");
        }
        assert_eq!(isqrt(784), 28);
        assert_eq!(isqrt(u64::MAX), (1u64 << 32) - 1);
    }

    #[test]
    fn checksum_matches_python_pin() {
        // mirrored in python tests/test_aot.py::test_checksum_mirrors_spec
        let (fnv, sum) = checksum_i32(&[1, -2, 300000]);
        assert_eq!(sum, 299999);
        let mut h: u64 = 14695981039346656037;
        for v in [1i64, -2, 300000] {
            for b in v.to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(1099511628211);
            }
        }
        assert_eq!(fnv, h);
    }
}
