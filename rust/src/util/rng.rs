//! PCG32 — deterministic, seedable PRNG (O'Neill 2014, `pcg32_oneseq`).
//!
//! Every stochastic choice in the coordinator (weight init, dataset
//! synthesis, shuffling, dropout masks) flows through this generator so
//! experiments are exactly reproducible from a seed recorded in results.

#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire rejection).
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        debug_assert!(lo <= hi);
        let span = hi as i64 - lo as i64 + 1;
        if span > u32::MAX as i64 {
            // full i32 range: every u32 maps to exactly one value
            return self.next_u32() as i32;
        }
        lo.wrapping_add(self.below(span as u32) as i32)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f64() as f32
    }

    /// Standard normal via Box–Muller (baseline float engines only — the
    /// integer path never draws Gaussians).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::new(43);
        assert_ne!(a.next_u32(), c.next_u32());
    }

    #[test]
    fn reference_vector_pcg32_oneseq() {
        // First outputs of pcg32 with seed=42, default stream — pinned so
        // refactors cannot silently change every experiment in the repo.
        let mut r = Pcg32::new(42);
        let first: Vec<u32> = (0..4).map(|_| r.next_u32()).collect();
        let mut r2 = Pcg32::new(42);
        assert_eq!(first, (0..4).map(|_| r2.next_u32()).collect::<Vec<_>>());
    }

    #[test]
    fn range_bounds_inclusive() {
        let mut r = Pcg32::new(7);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range_i32(-3, 3);
            assert!((-3..=3).contains(&v));
            seen_lo |= v == -3;
            seen_hi |= v == 3;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn below_unbiased_small_bound() {
        let mut r = Pcg32::new(9);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.below(3) as usize] += 1;
        }
        for &c in &counts {
            assert!((9000..11000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg32::new(1);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
