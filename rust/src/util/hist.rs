//! Log-bucketed latency histogram (replaces `hdrhistogram`, not
//! vendored — DESIGN.md §Substitutions).
//!
//! Serving percentiles must be cheap to record (one increment on the
//! executor hot path), mergeable across shards (per-shard histograms sum
//! into one server-wide view), and bounded in memory regardless of how
//! many samples land. Sorting raw latency vectors — what the closed-loop
//! bench does — is none of those. This histogram buckets values
//! logarithmically: exact below [`SUB`], then `SUB` linear sub-buckets
//! per power of two, for a worst-case relative error of `1/SUB` (~3.1%)
//! and at most [`NBUCKETS`] counters (~15 KiB) ever.
//!
//! Quantiles are reported as the upper bound of the bucket holding the
//! rank, clamped into the observed `[min, max]` — so `quantile(q)` is an
//! overestimate by at most one bucket width, never an underestimate, and
//! quantiles are monotone in `q` by construction.

/// Sub-bucket resolution: `1 << SUB_BITS` linear buckets per octave.
const SUB_BITS: u32 = 5;
/// Buckets per octave; also the threshold below which values are exact.
const SUB: usize = 1 << SUB_BITS;
/// Sub-bucket index mask within an octave.
const SUB_MASK: usize = SUB - 1;
/// Upper bound on the bucket index (`bucket_of(u64::MAX) + 1`).
const NBUCKETS: usize = (64 - SUB_BITS as usize) * SUB + SUB;

fn bucket_of(v: u64) -> usize {
    if v < SUB as u64 {
        v as usize
    } else {
        // v >= SUB here, so msb >= SUB_BITS and the subtractions cannot
        // underflow; saturating_* keeps that explicit under `nitro lint`.
        let msb = 63usize.saturating_sub(v.leading_zeros() as usize);
        let shift = msb.saturating_sub(SUB_BITS as usize);
        shift
            .wrapping_shl(SUB_BITS)
            .saturating_add(SUB)
            .saturating_add((v >> shift) as usize & SUB_MASK)
    }
}

/// Inclusive lower bound of bucket `i` (the inverse of [`bucket_of`]).
fn bucket_low(i: usize) -> u64 {
    if i < SUB {
        i as u64
    } else {
        let shift = i.saturating_sub(SUB) >> SUB_BITS;
        let sub = i.saturating_sub(SUB) & SUB_MASK;
        // max in-range operands: (SUB + sub) <= 63 < 2^6 and shift <= 58,
        // so the shifted value fits u64 for every valid bucket index
        (SUB.saturating_add(sub) as u64).wrapping_shl(shift as u32)
    }
}

/// Inclusive upper bound of bucket `i`.
fn bucket_high(i: usize) -> u64 {
    if i.saturating_add(1) >= NBUCKETS {
        u64::MAX
    } else {
        bucket_low(i.saturating_add(1)).saturating_sub(1)
    }
}

#[derive(Clone, Debug, Default)]
pub struct LogHistogram {
    /// Grown on demand up to `NBUCKETS`; an idle histogram stays empty.
    counts: Vec<u64>,
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram::default()
    }

    pub fn record(&mut self, v: u64) {
        let b = bucket_of(v);
        if b >= self.counts.len() {
            self.counts.resize(b.saturating_add(1), 0);
        }
        self.counts[b] = self.counts[b].saturating_add(1);
        self.sum = self.sum.saturating_add(v);
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count = self.count.saturating_add(1);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Fold another histogram into this one (per-shard -> server-wide).
    pub fn merge(&mut self, other: &LogHistogram) {
        if other.count == 0 {
            return;
        }
        if other.counts.len() > self.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (dst, src) in self.counts.iter_mut().zip(&other.counts) {
            *dst = dst.saturating_add(*src);
        }
        self.sum = self.sum.saturating_add(other.sum);
        if self.count == 0 {
            self.min = other.min;
            self.max = other.max;
        } else {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
        self.count = self.count.saturating_add(other.count);
    }

    /// Value at quantile `q`, clamped into `[0, 1]` first (NaN reads as
    /// 0): the upper bound of the bucket containing rank
    /// `ceil(q * count)`, clamped into `[min, max]`. Returns 0 on an
    /// empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        // q outside [0, 1] (or NaN, where `q * count` is NaN and the
        // `as u64` cast would read as rank 0 -> 1) must not be able to
        // select a rank past `count`; out-of-range requests saturate to
        // the nearest valid quantile instead.
        let q = if q.is_nan() { 0.0 } else { q.clamp(0.0, 1.0) };
        let rank = ((q * self.count as f64).ceil() as u64)
            .clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum = cum.saturating_add(c);
            if cum >= rank {
                return bucket_high(i).clamp(self.min, self.max);
            }
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_bounds_are_a_partition() {
        // every bucket's low is exactly the previous bucket's high + 1,
        // and bucket_of maps both endpoints back to the bucket itself
        for i in 1..NBUCKETS {
            assert_eq!(bucket_low(i), bucket_high(i - 1) + 1, "bucket {i}");
        }
        for v in [0u64, 1, 31, 32, 33, 63, 64, 127, 128, 1000, 1 << 20,
                  u64::MAX] {
            let b = bucket_of(v);
            assert!(bucket_low(b) <= v && v <= bucket_high(b),
                    "v={v} b={b}");
        }
        assert_eq!(bucket_of(u64::MAX), NBUCKETS - 1);
    }

    #[test]
    fn exact_below_sub_and_bounded_error_above() {
        let mut h = LogHistogram::new();
        for v in 0..SUB as u64 {
            h.record(v);
        }
        // ranks are exact below SUB: quantile k/SUB ends at value k-1
        assert_eq!(h.quantile(1.0 / SUB as f64), 0);
        assert_eq!(h.quantile(0.5), (SUB as u64) / 2 - 1);
        assert_eq!(h.quantile(1.0), SUB as u64 - 1);
        // above SUB the reported quantile overestimates by < 1/SUB
        let mut h = LogHistogram::new();
        for v in [1000u64, 2000, 3000, 4000] {
            h.record(v);
        }
        for (q, true_v) in [(0.25, 1000u64), (0.5, 2000), (1.0, 4000)] {
            let got = h.quantile(q);
            assert!(got >= true_v, "q={q}: {got} < {true_v}");
            assert!((got - true_v) as f64 <= true_v as f64 / SUB as f64,
                    "q={q}: {got} vs {true_v}");
        }
    }

    #[test]
    fn quantiles_are_monotone_and_clamped() {
        let mut h = LogHistogram::new();
        let mut state = 0x9e3779b9u64;
        for _ in 0..5000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            h.record(state >> 40); // ~24-bit values
        }
        let mut prev = 0u64;
        for i in 0..=100 {
            let v = h.quantile(i as f64 / 100.0);
            assert!(v >= prev, "quantile not monotone at {i}%");
            prev = v;
        }
        assert_eq!(h.quantile(1.0), h.max());
        // a single sample is reported exactly (clamping to [min, max])
        let mut one = LogHistogram::new();
        one.record(123_456_789);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(one.quantile(q), 123_456_789);
        }
    }

    #[test]
    fn merge_equals_combined_recording() {
        let (mut a, mut b, mut both) =
            (LogHistogram::new(), LogHistogram::new(), LogHistogram::new());
        for v in [5u64, 70, 900, 44] {
            a.record(v);
            both.record(v);
        }
        for v in [1u64, 1_000_000, 33] {
            b.record(v);
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.max(), both.max());
        assert_eq!(a.mean(), both.mean());
        for i in 0..=20 {
            let q = i as f64 / 20.0;
            assert_eq!(a.quantile(q), both.quantile(q), "q={q}");
        }
        // merging into an empty histogram copies min/max correctly
        let mut empty = LogHistogram::new();
        empty.merge(&both);
        assert_eq!(empty.quantile(0.0), both.quantile(0.0));
        assert_eq!(empty.quantile(1.0), both.quantile(1.0));
    }

    #[test]
    fn out_of_range_quantiles_saturate() {
        let mut h = LogHistogram::new();
        for v in [10u64, 20, 30, 40, 50] {
            h.record(v);
        }
        // below-range and NaN behave exactly like q = 0.0
        assert_eq!(h.quantile(f64::NAN), h.quantile(0.0));
        assert_eq!(h.quantile(-1.0), h.quantile(0.0));
        assert_eq!(h.quantile(f64::NEG_INFINITY), h.quantile(0.0));
        // above-range behaves exactly like q = 1.0
        assert_eq!(h.quantile(2.0), h.quantile(1.0));
        assert_eq!(h.quantile(f64::INFINITY), h.quantile(1.0));
        assert_eq!(h.quantile(2.0), h.max());
        // an empty histogram still reports 0 for any q
        let empty = LogHistogram::new();
        assert_eq!(empty.quantile(f64::NAN), 0);
        assert_eq!(empty.quantile(-3.5), 0);
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
    }
}
