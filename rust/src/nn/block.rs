//! Integer local-loss blocks and the NITRO-D network (paper §3.2–3.3).
//!
//! Bit-exact mirror of `python/compile/model.py`:
//! `conv_block_train` / `linear_block_train` / `head_train`. Verified
//! against `artifacts/golden/<preset>_steps.json` (full 3-step training
//! traces) in `rust/tests/golden.rs`.

use crate::nn::spec::{BitwidthCfg, BlockSpec, HeadSpec, NetworkSpec};
use crate::optim::integer_sgd_railed;
use crate::tensor::{
    conv2d_i64, kernels, matmul_a_bt_i64, matmul_at_b_i64, matmul_i64,
    maxpool2d, maxpool2d_bwd, nitro_relu, nitro_relu_bwd,
    nitro_relu_inplace, nitro_scale, one_hot32, rss_loss_grad_raw,
    scale_factor_linear, ITensor, KernelWorkspace, LTensor,
};
use crate::util::rng::Pcg32;

/// Saturate a NITRO-Scaling output (or error signal) to `±rail`.
///
/// At the full-width rail (`i32::MAX`, the 32-bit default) this is a
/// **no-call**: clamping to `±i32::MAX` is not the identity (it would
/// remap `i32::MIN`), and skipping the kernel entirely keeps the default
/// configuration byte-identical to the pre-rail code path — including the
/// golden traces.
fn clamp_rail(t: &mut ITensor, rail: i32) {
    if rail < i32::MAX {
        kernels().clamp_i32(t, rail);
    }
}

/// Per-step hyper-parameters (paper Table 6/7 names).
#[derive(Clone, Copy, Debug)]
pub struct Hyper {
    /// Inverse learning rate γ_inv (learning layers & head).
    pub gamma_inv: i64,
    /// Inverse decay rate of forward layers η_inv^fw (0 = off).
    pub eta_fw_inv: i64,
    /// Inverse decay rate of learning layers η_inv^lr (0 = off).
    pub eta_lr_inv: i64,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper { gamma_inv: 512, eta_fw_inv: 0, eta_lr_inv: 0 }
    }
}

/// Stream id base for the per-block dropout RNGs: block `l` draws its
/// masks from `Pcg32::with_stream(seed, DROPOUT_STREAM_BASE + l)`.
pub const DROPOUT_STREAM_BASE: u64 = 0x64726f70; // "drop"

/// Per-block dropout RNG streams.
///
/// Every block owns an independent PCG32 stream derived from the training
/// seed and the block index, so a block's mask sequence is a function of
/// `(seed, block, batch ordinal)` alone — independent of how a scheduler
/// interleaves block execution. This is what makes the block-parallel and
/// cross-batch pipelined schedulers bit-identical to sequential order
/// under dropout: each block consumes its own stream in batch order no
/// matter which thread (or pipeline stage) runs it.
pub struct DropoutRngs {
    streams: Vec<Pcg32>,
}

impl DropoutRngs {
    pub fn new(seed: u64, nblocks: usize) -> Self {
        DropoutRngs {
            streams: (0..nblocks as u64)
                .map(|l| Pcg32::with_stream(seed, DROPOUT_STREAM_BASE + l))
                .collect(),
        }
    }

    /// Block `l`'s stream.
    pub fn stream(&mut self, l: usize) -> &mut Pcg32 {
        &mut self.streams[l]
    }

    /// Move the streams out — the pipelined scheduler hands each stage
    /// worker its block's stream to own directly.
    pub fn into_streams(self) -> Vec<Pcg32> {
        self.streams
    }
}

/// Forward-pass intermediates needed by the local backward pass.
pub struct BlockCache {
    /// Scaled pre-activations (NITRO-ReLU input) — its backward mask.
    zs: ITensor,
    /// Shape of the activation before the block's own MaxPool.
    act_shape: Vec<usize>,
    /// Block MaxPool argmax (if `pool`).
    pool_arg: Option<ITensor>,
    /// Dropout keep-mask over the block output (if dropout enabled).
    drop_mask: Option<Vec<bool>>,
    /// Block output after pool/dropout (learning-layer input).
    pub a_out: ITensor,
}

/// Batch-summed local gradients of one block, exported without applying
/// any update — the unit the data-parallel replica path
/// (`train::replica`) all-reduces across replicas. The tensors are moved
/// out of the backward pass, never copied. `loss_raw` is the un-halved
/// RSS sum (`Σ(ŷ−y)²`): per-shard halves cannot be summed without losing
/// odd bits, so callers halve once after any reduction.
pub struct BlockGrads {
    pub loss_raw: i64,
    /// Forward-layer gradient (rate role `γ_inv · AF`, decay `η_fw`).
    pub gw_f: LTensor,
    /// Learning-layer gradient (rate role `γ_inv`, decay `η_lr`).
    pub gw_l: LTensor,
}

/// Zero the dropped outputs and stash the keep-mask for the backward
/// pass.
fn apply_drop_mask(cache: &mut BlockCache, mask: Vec<bool>) {
    for (v, &keep) in cache.a_out.data.iter_mut().zip(&mask) {
        if !keep {
            *v = 0;
        }
    }
    cache.drop_mask = Some(mask);
}

/// A stateful integer local-loss block: forward weights + learning-layer
/// weights.
pub struct Block {
    pub spec: BlockSpec,
    /// Forward-layer weights (conv (O,C,K,K) or linear (M,N)).
    pub wf: ITensor,
    /// Learning-layer weights (F, G).
    pub wl: ITensor,
    /// Dropout probability in 1/256ths (0 = disabled). Mask-only dropout —
    /// DESIGN.md interp. #5.
    pub drop_p256: u32,
    /// W/A/G/E rails for this block (default 32/32/64/64 = no clamping).
    /// Assigned by [`Network::new`] from the spec's per-layer plan.
    pub bits: BitwidthCfg,
    /// Per-block kernel scratch: transpose / im2col / accumulator buffers
    /// reused across steps, so the training forward and weight-grad share
    /// one im2col extraction and the steady state allocates no scratch.
    ws: KernelWorkspace,
}

impl Block {
    pub fn new(spec: BlockSpec, rng: &mut Pcg32) -> Self {
        use crate::nn::init::init_weights;
        let (wf, wl) = match &spec {
            BlockSpec::Conv(c) => (
                init_weights(rng, &c.wf_shape(), c.fan_in()),
                init_weights(rng, &c.wl_shape(), c.lr_features()),
            ),
            BlockSpec::Linear(l) => (
                init_weights(rng, &l.wf_shape(), l.fan_in()),
                init_weights(rng, &l.wl_shape(), l.out_features),
            ),
        };
        Block {
            spec,
            wf,
            wl,
            drop_p256: 0,
            bits: BitwidthCfg::default(),
            ws: KernelWorkspace::new(),
        }
    }

    /// Inference forward (no dropout, no cache).
    pub fn forward(&self, a: &ITensor) -> ITensor {
        let rail = self.bits.act_rail();
        match &self.spec {
            BlockSpec::Conv(c) => {
                let z = conv2d_i64(a, &self.wf, c.padding);
                let mut zs = nitro_scale(&z, c.sf());
                clamp_rail(&mut zs, rail);
                let act = nitro_relu(&zs, c.alpha_inv);
                if c.pool {
                    maxpool2d(&act, 2, 2).0
                } else {
                    act
                }
            }
            BlockSpec::Linear(l) => {
                let z = matmul_i64(a, &self.wf);
                let mut zs = nitro_scale(&z, l.sf());
                clamp_rail(&mut zs, rail);
                nitro_relu(&zs, l.alpha_inv)
            }
        }
    }

    /// Grad-free serving forward into caller-owned buffers: the fused
    /// contract-and-scale kernels run on `ws`, the ReLU is applied in
    /// place, and no backward cache, dropout mask or i64 pre-activation
    /// tensor is materialized. `mid` is block-internal scratch (pre-pool
    /// activation); the block output lands in `out`. Bit-identical to
    /// [`Self::forward`].
    pub fn infer_into(&self, a: &ITensor, ws: &mut KernelWorkspace,
                      mid: &mut ITensor, out: &mut ITensor) {
        let kb = kernels();
        let rail = self.bits.act_rail();
        match &self.spec {
            BlockSpec::Conv(c) => {
                if c.pool {
                    kb.conv2d_scale(a, &self.wf, c.padding, c.sf(), ws, mid);
                    clamp_rail(mid, rail);
                    nitro_relu_inplace(mid, c.alpha_inv);
                    kb.maxpool2d(mid, 2, 2, out);
                } else {
                    kb.conv2d_scale(a, &self.wf, c.padding, c.sf(), ws, out);
                    clamp_rail(out, rail);
                    nitro_relu_inplace(out, c.alpha_inv);
                }
            }
            BlockSpec::Linear(l) => {
                kb.matmul_scale(a, &self.wf, l.sf(), ws, out);
                clamp_rail(out, rail);
                nitro_relu_inplace(out, l.alpha_inv);
            }
        }
    }

    /// Training forward: returns output + backward cache. Dropout is drawn
    /// from `rng` when `drop_p256 > 0`. Runs on the block's workspace: the
    /// conv path leaves its im2col patches cached for [`Self::backward_step`],
    /// and the fused contract-and-scale kernels never materialize the i64
    /// pre-activations outside the reused accumulator.
    pub fn forward_train(&mut self, a: &ITensor, rng: Option<&mut Pcg32>)
                         -> BlockCache {
        let mut cache = self.forward_core(a);
        if self.drop_p256 > 0 {
            let rng = rng.expect("dropout requires an RNG");
            let mask: Vec<bool> = (0..cache.a_out.len())
                .map(|_| rng.below(256) >= self.drop_p256)
                .collect();
            apply_drop_mask(&mut cache, mask);
        }
        cache
    }

    /// [`Self::forward_train`] with a **pre-drawn** dropout keep-mask.
    /// The data-parallel replica path draws each block's masks for the
    /// whole global batch from the canonical per-block stream and hands
    /// every replica its shard's slice, so a mask element stays a
    /// function of (seed, block, batch ordinal, sample position) no
    /// matter how many replicas split the batch (`train::replica`).
    /// `mask` must cover the block output and is required exactly when
    /// `drop_p256 > 0`.
    pub fn forward_train_masked(&mut self, a: &ITensor,
                                mask: Option<&[bool]>) -> BlockCache {
        let mut cache = self.forward_core(a);
        if self.drop_p256 > 0 {
            let mask = mask.expect("dropout requires a pre-drawn mask");
            assert_eq!(mask.len(), cache.a_out.len(),
                       "dropout mask does not cover the block output");
            apply_drop_mask(&mut cache, mask.to_vec());
        }
        cache
    }

    /// Training forward minus dropout: fused contract-and-scale on the
    /// block workspace, activation, block pooling.
    fn forward_core(&mut self, a: &ITensor) -> BlockCache {
        let kb = kernels();
        let rail = self.bits.act_rail();
        let (zs, act_shape, pool_arg, out) = match &self.spec {
            BlockSpec::Conv(c) => {
                let mut zs = ITensor::empty();
                kb.conv2d_scale(a, &self.wf, c.padding, c.sf(), &mut self.ws,
                                &mut zs);
                clamp_rail(&mut zs, rail);
                let act = nitro_relu(&zs, c.alpha_inv);
                let act_shape = act.shape.clone();
                if c.pool {
                    let (p, arg) = maxpool2d(&act, 2, 2);
                    (zs, act_shape, Some(arg), p)
                } else {
                    (zs, act_shape, None, act)
                }
            }
            BlockSpec::Linear(l) => {
                let mut zs = ITensor::empty();
                kb.matmul_scale(a, &self.wf, l.sf(), &mut self.ws, &mut zs);
                clamp_rail(&mut zs, rail);
                let act = nitro_relu(&zs, l.alpha_inv);
                let act_shape = act.shape.clone();
                (zs, act_shape, None, act)
            }
        };
        BlockCache { zs, act_shape, pool_arg, drop_mask: None, a_out: out }
    }

    /// Local backward **without updates**: export the batch-summed i64
    /// gradients plus the raw local loss. [`Self::backward_step`] applies
    /// them immediately; the data-parallel replica path
    /// (`train::replica`) all-reduces them across replicas first.
    /// Deferring the update is bit-identical to the eager order because
    /// nothing in the backward pass reads a weight after that weight's
    /// own update — `dfeat` is computed from the pre-step learning
    /// weights.
    pub fn backward_grads(&mut self, a_in: &ITensor, cache: &BlockCache,
                          y32: &ITensor) -> BlockGrads {
        // ---- learning layers ------------------------------------------
        let lr = lr_features(&cache.a_out, &self.spec);
        let feat: &ITensor = match &lr {
            // logical (B,F) view of the block output — no flatten copy
            LrFeat::Flat => &cache.a_out,
            LrFeat::Pooled { feat, .. } => feat,
        };
        let (_, fcols) = feat.batch_feat();
        let mut yhat = ITensor::empty();
        kernels().matmul_scale(feat, &self.wl, scale_factor_linear(fcols),
                               &mut self.ws, &mut yhat);
        clamp_rail(&mut yhat, self.bits.act_rail());
        let (loss_raw, mut grad_l) = rss_loss_grad_raw(&yhat, y32);
        // error signal is per-sample elementwise — clamping here is
        // shard-invariant under any batch split
        clamp_rail(&mut grad_l, self.bits.err_rail());
        let gw_l = matmul_at_b_i64(feat, &grad_l); // featᵀ·∇L (F,G)
        let dfeat = matmul_a_bt_i64(&grad_l, &self.wl).to_i32(); // ∇L·Wᵀ

        // ---- delta^fw back through the forward layers ------------------
        // learning-head scaling backward = STE (identity)
        let mut d = match &lr {
            LrFeat::Flat => dfeat.reshaped(&cache.a_out.shape),
            LrFeat::Pooled { arg, pooled_shape, .. } => adaptive_pool_bwd(
                &dfeat, Some(arg), pooled_shape, &cache.a_out.shape,
                &self.spec,
            ),
        };
        if let Some(mask) = &cache.drop_mask {
            for (v, &keep) in d.data.iter_mut().zip(mask) {
                if !keep {
                    *v = 0;
                }
            }
        }
        if let Some(arg) = &cache.pool_arg {
            d = maxpool2d_bwd(&d, arg, &cache.act_shape, 2, 2);
        }
        let alpha_inv = match &self.spec {
            BlockSpec::Conv(c) => c.alpha_inv,
            BlockSpec::Linear(l) => l.alpha_inv,
        };
        let mut d = nitro_relu_bwd(&cache.zs, &d, alpha_inv);
        clamp_rail(&mut d, self.bits.err_rail());
        let d = d;
        // NITRO scaling backward = STE (identity)
        let gw_f: LTensor = match &self.spec {
            // reuses the im2col patches the forward pass left in the
            // workspace — no second extraction per step
            BlockSpec::Conv(c) => {
                kernels().conv2d_weight_grad(a_in, &d, c.kernel, c.padding,
                                             &mut self.ws)
            }
            BlockSpec::Linear(_) => matmul_at_b_i64(a_in, &d),
        };
        BlockGrads { loss_raw, gw_f, gw_l }
    }

    /// Local backward + IntegerSGD updates given the cached forward.
    /// Returns the local RSS loss sum. Gradients never leave the block.
    pub fn backward_step(&mut self, a_in: &ITensor, cache: &BlockCache,
                         y32: &ITensor, hp: &Hyper) -> i64 {
        let g = self.backward_grads(a_in, cache, y32);
        self.apply_grads(&g.gw_f, &g.gw_l, hp);
        g.loss_raw / 2
    }

    /// One IntegerSGD step from (possibly all-reduced) batch-summed
    /// gradients, with the per-role rate wiring: forward layers run at
    /// `γ_inv^fw = γ_inv^lr · AF` (DESIGN.md interp. #1) with `η_fw`
    /// decay, learning layers at `γ_inv` with `η_lr` decay.
    /// Rails: this is the single post-reduce funnel every scheduler and
    /// replica count goes through, so clamping the (all-reduced) gradient
    /// to the G rail and the updated weight to the W rail here is
    /// replica-count invariant.
    pub fn apply_grads(&mut self, gw_f: &LTensor, gw_l: &LTensor,
                       hp: &Hyper) {
        let af = 64 * self.spec.num_classes() as i64;
        let (gr, wr) = (self.bits.grad_rail(), self.bits.weight_rail());
        integer_sgd_railed(&mut self.wl, gw_l, hp.gamma_inv, hp.eta_lr_inv,
                           gr, wr);
        integer_sgd_railed(&mut self.wf, gw_f, hp.gamma_inv * af,
                           hp.eta_fw_inv, gr, wr);
    }

    /// Convenience: forward + backward in one call (sequential mode).
    pub fn train_step(&mut self, a_in: &ITensor, y32: &ITensor, hp: &Hyper,
                      rng: Option<&mut Pcg32>) -> (ITensor, i64) {
        let cache = self.forward_train(a_in, rng);
        let loss = self.backward_step(a_in, &cache, y32, hp);
        (cache.a_out, loss)
    }
}

/// Learning-layer feature view of a block output: either the output
/// itself, read as a logical (B, F) matrix by the shape-agnostic matmuls
/// (linear blocks, and conv blocks whose activation already matches the
/// learning-pool geometry — zero-copy), or an adaptively max-pooled
/// feature tensor plus its argmax (conv blocks needing pooling).
enum LrFeat {
    Flat,
    Pooled { feat: ITensor, arg: ITensor, pooled_shape: Vec<usize> },
}

fn lr_features(a_out: &ITensor, spec: &BlockSpec) -> LrFeat {
    match spec {
        BlockSpec::Linear(_) => LrFeat::Flat,
        BlockSpec::Conv(c) => {
            let (s, k) = c.lr_pool();
            let (b, ch, h, w) = (a_out.shape[0], a_out.shape[1],
                                 a_out.shape[2], a_out.shape[3]);
            if k <= 1 && h == s && w == s {
                return LrFeat::Flat;
            }
            let k = k.max(1);
            let (pooled, arg) = maxpool2d(a_out, k, k);
            // keep the top-left s x s windows (remainder gets no gradient)
            let (ph, pw) = (pooled.shape[2], pooled.shape[3]);
            let mut feat = vec![0i32; b * ch * s * s];
            let mut args = vec![0i32; b * ch * s * s];
            for bc in 0..b * ch {
                for oy in 0..s {
                    for ox in 0..s {
                        feat[bc * s * s + oy * s + ox] =
                            pooled.data[bc * ph * pw + oy * pw + ox];
                        args[bc * s * s + oy * s + ox] =
                            arg.data[bc * ph * pw + oy * pw + ox];
                    }
                }
            }
            LrFeat::Pooled {
                feat: ITensor::from_vec(&[b, ch * s * s], feat),
                arg: ITensor::from_vec(&[b, ch, s, s], args),
                pooled_shape: vec![b, ch, s, s],
            }
        }
    }
}

/// Adaptive max-pool for conv-block learning layers (identity flatten for
/// linear blocks). Mirrors `model._adaptive_pool`. The training hot path
/// uses [`lr_features`] (which skips the identity-flatten copies); this
/// materializing form serves the probes and tests.
pub fn adaptive_pool(a_out: &ITensor, spec: &BlockSpec)
                     -> (ITensor, Option<ITensor>, Vec<usize>) {
    match lr_features(a_out, spec) {
        LrFeat::Flat => {
            let (b, f) = a_out.batch_feat();
            (a_out.clone().reshaped(&[b, f]), None, a_out.shape.clone())
        }
        LrFeat::Pooled { feat, arg, pooled_shape } => {
            (feat, Some(arg), pooled_shape)
        }
    }
}

/// Backward of [`adaptive_pool`]: scatter dfeat to the argmax positions.
pub fn adaptive_pool_bwd(dfeat: &ITensor, arg: Option<&ITensor>,
                         pooled_shape: &[usize], out_shape: &[usize],
                         spec: &BlockSpec) -> ITensor {
    match (spec, arg) {
        (BlockSpec::Linear(_), _) | (BlockSpec::Conv(_), None) => {
            dfeat.clone().reshaped(out_shape)
        }
        (BlockSpec::Conv(c), Some(arg)) => {
            let (_, k) = c.lr_pool();
            let k = k.max(1);
            let (b, ch, s, _) = (pooled_shape[0], pooled_shape[1],
                                 pooled_shape[2], pooled_shape[3]);
            let (h, w) = (out_shape[2], out_shape[3]);
            let mut out = vec![0i32; out_shape.iter().product()];
            for bc in 0..b * ch {
                let plane = &mut out[bc * h * w..(bc + 1) * h * w];
                for oy in 0..s {
                    for ox in 0..s {
                        let g = dfeat.data[bc * s * s + oy * s + ox];
                        let a = arg.data[bc * s * s + oy * s + ox] as usize;
                        let (ki, kj) = (a / k, a % k);
                        plane[(oy * k + ki) * w + ox * k + kj] += g;
                    }
                }
            }
            ITensor::from_vec(out_shape, out)
        }
    }
}

/// The network output layers (Integer Linear -> NITRO scaling), trained on
/// the global RSS loss.
pub struct Head {
    pub spec: HeadSpec,
    pub wo: ITensor,
    /// W/A/G/E rails for the head (default 32/32/64/64 = no clamping).
    /// Assigned by [`Network::new`] from the spec's base config.
    pub bits: BitwidthCfg,
    /// Kernel scratch reused across training steps.
    ws: KernelWorkspace,
}

impl Head {
    pub fn new(spec: HeadSpec, rng: &mut Pcg32) -> Self {
        use crate::nn::init::init_weights;
        let wo = init_weights(
            rng,
            &[spec.in_features, spec.num_classes],
            spec.fan_in(),
        );
        Head {
            spec,
            wo,
            bits: BitwidthCfg::default(),
            ws: KernelWorkspace::new(),
        }
    }

    pub fn forward(&self, a: &ITensor) -> ITensor {
        let z = matmul_i64(a, &self.wo);
        let mut zs = nitro_scale(&z, self.spec.sf());
        clamp_rail(&mut zs, self.bits.act_rail());
        zs
    }

    /// Grad-free serving forward into a caller buffer (see
    /// [`Block::infer_into`]). Bit-identical to [`Self::forward`].
    pub fn infer_into(&self, a: &ITensor, ws: &mut KernelWorkspace,
                      out: &mut ITensor) {
        kernels().matmul_scale(a, &self.wo, self.spec.sf(), ws, out);
        clamp_rail(out, self.bits.act_rail());
    }

    /// Head forward + gradient without the update: `(ŷ, raw RSS loss,
    /// batch-summed weight gradient)`. [`Self::train_step`] applies the
    /// gradient immediately; the data-parallel replica path all-reduces
    /// it across replicas first (`train::replica`).
    pub fn grads(&mut self, a: &ITensor, y32: &ITensor)
                 -> (ITensor, i64, LTensor) {
        let mut yhat = ITensor::empty();
        kernels().matmul_scale(a, &self.wo, self.spec.sf(), &mut self.ws,
                               &mut yhat);
        clamp_rail(&mut yhat, self.bits.act_rail());
        let (loss_raw, mut grad) = rss_loss_grad_raw(&yhat, y32);
        clamp_rail(&mut grad, self.bits.err_rail());
        let gw = matmul_at_b_i64(a, &grad);
        (yhat, loss_raw, gw)
    }

    /// Head step: receives the global loss gradient directly (learning-rate
    /// role — no amplification factor). `a` may be any shape with batch
    /// leading — the matmuls read it as a logical (B, F) matrix.
    pub fn train_step(&mut self, a: &ITensor, y32: &ITensor, hp: &Hyper)
                      -> (ITensor, i64) {
        let (yhat, loss_raw, gw) = self.grads(a, y32);
        self.apply_grad(&gw, hp);
        (yhat, loss_raw / 2)
    }

    /// IntegerSGD step from a (possibly all-reduced) head gradient
    /// (learning-rate role: `γ_inv`, `η_lr` decay). Clamping to the G/W
    /// rails happens here, after any replica reduction, so the result is
    /// replica-count invariant.
    pub fn apply_grad(&mut self, gw: &LTensor, hp: &Hyper) {
        integer_sgd_railed(&mut self.wo, gw, hp.gamma_inv, hp.eta_lr_inv,
                           self.bits.grad_rail(), self.bits.weight_rail());
    }

    /// Move the head's state out (pipelined-scheduler stage ownership),
    /// leaving an empty husk behind; [`Self::restore`] puts it back at a
    /// pipeline sync point.
    pub fn take(&mut self) -> Head {
        Head {
            spec: self.spec.clone(),
            wo: std::mem::replace(&mut self.wo, ITensor::empty()),
            bits: self.bits,
            ws: std::mem::take(&mut self.ws),
        }
    }

    /// Undo [`Self::take`].
    pub fn restore(&mut self, from: Head) {
        self.wo = from.wo;
        self.ws = from.ws;
    }
}

/// Long-lived scratch for the grad-free serving forward
/// ([`Network::infer_into`]): one kernel workspace plus activation
/// ping/pong buffers and block-internal scratch. All buffers grow to a
/// high-water mark and are then reused, so steady-state serving performs
/// no forward-path allocation. One scratch serves any number of models
/// and batch shapes (buffers are shape-agnostic).
#[derive(Default)]
pub struct InferScratch {
    ws: KernelWorkspace,
    ping: ITensor,
    pong: ITensor,
    mid: ITensor,
}

impl InferScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// A full NITRO-D network with its LES training scheduler.
pub struct Network {
    pub spec: NetworkSpec,
    pub blocks: Vec<Block>,
    pub head: Head,
}

/// Per-step training report.
#[derive(Clone, Debug, Default)]
pub struct StepReport {
    pub block_loss: Vec<i64>,
    pub head_loss: i64,
    pub correct: usize,
}

impl Network {
    pub fn new(spec: NetworkSpec, seed: u64) -> Self {
        let mut rng = Pcg32::new(seed);
        let blocks: Vec<Block> = spec
            .blocks
            .iter()
            .enumerate()
            .map(|(l, b)| {
                let mut blk = Block::new(b.clone(), &mut rng);
                blk.bits = spec.bits.for_layer(l);
                blk
            })
            .collect();
        let mut head = Head::new(spec.head.clone(), &mut rng);
        head.bits = spec.bits.base;
        Network { spec, blocks, head }
    }

    /// Set dropout rates (p_c on conv blocks, p_l on linear blocks),
    /// probabilities quantized to 1/256ths.
    pub fn set_dropout(&mut self, p_c: f64, p_l: f64) {
        for b in &mut self.blocks {
            b.drop_p256 = match b.spec {
                BlockSpec::Conv(_) => (p_c * 256.0).round() as u32,
                BlockSpec::Linear(_) => (p_l * 256.0).round() as u32,
            };
        }
    }

    /// Integer-only inference. x: (B,C,H,W) or (B,F). The input is
    /// borrowed and conv→linear boundaries need no flatten copy — the
    /// matmuls read activations as logical (B, F).
    pub fn infer(&self, x: &ITensor) -> ITensor {
        let mut a: Option<ITensor> = None;
        for blk in &self.blocks {
            let a_in = a.as_ref().unwrap_or(x);
            a = Some(blk.forward(a_in));
        }
        self.head.forward(a.as_ref().unwrap_or(x))
    }

    /// Grad-free fused inference into a caller buffer — the serving hot
    /// path. Threads one [`InferScratch`] through every block (fused
    /// contract+scale kernels, in-place ReLU, argmax-free pooling) so no
    /// backward/optimizer buffer is ever touched and, with long-lived
    /// `scratch`/`out`, the steady state allocates nothing on the forward
    /// path. Bit-identical to [`Self::infer`] for every input, batch
    /// composition and worker count.
    pub fn infer_into(&self, x: &ITensor, scratch: &mut InferScratch,
                      out: &mut ITensor) {
        let InferScratch { ws, ping, pong, mid } = scratch;
        let (mut cur, mut next) = (ping, pong);
        for (l, blk) in self.blocks.iter().enumerate() {
            let a_in: &ITensor = if l == 0 { x } else { cur };
            blk.infer_into(a_in, ws, mid, next);
            std::mem::swap(&mut cur, &mut next);
        }
        let a_in: &ITensor = if self.blocks.is_empty() { x } else { cur };
        self.head.infer_into(a_in, ws, out);
    }

    /// One training iteration, sequential block order (reference mode).
    ///
    /// The input is borrowed, activations are moved block to block, and
    /// conv→linear boundaries are handled by the logical-2-D matmuls — the
    /// steady state copies no activation. Dropout masks are drawn from
    /// `drop`'s per-block streams, so every scheduler (sequential,
    /// block-parallel, pipelined) sees identical masks for a given
    /// (seed, block, batch ordinal).
    pub fn train_batch(&mut self, x: &ITensor, labels: &[usize], hp: &Hyper,
                       drop: &mut DropoutRngs) -> StepReport {
        let y32 = one_hot32(labels, self.spec.num_classes);
        let mut report = StepReport::default();
        let mut a: Option<ITensor> = None;
        for (l, blk) in self.blocks.iter_mut().enumerate() {
            let a_in = a.as_ref().unwrap_or(x);
            let (out, loss) = blk.train_step(a_in, &y32, hp,
                                             Some(drop.stream(l)));
            report.block_loss.push(loss);
            a = Some(out);
        }
        let a_ref = a.as_ref().unwrap_or(x);
        let (yhat, head_loss) = self.head.train_step(a_ref, &y32, hp);
        report.head_loss = head_loss;
        report.correct = count_correct(&yhat, labels);
        report
    }

    /// One training iteration with the **block-parallel LES scheduler**:
    /// forwards run in block order on the caller, then every block's
    /// backward pass (learning layers, gradients, IntegerSGD updates) and
    /// the head step fan out **on the persistent worker pool**. This
    /// exploits the independence the paper notes in §3.3 ("the training of
    /// all the integer local-loss blocks operates independently ...
    /// allowing them to be executed in parallel"). Results are
    /// bit-identical to [`Self::train_batch`] because no data crosses
    /// block boundaries backwards and each block reads its own dropout
    /// stream.
    pub fn train_batch_parallel(&mut self, x: &ITensor, labels: &[usize],
                                hp: &Hyper, drop: &mut DropoutRngs)
                                -> StepReport {
        // deterministic single-thread mode (NITRO_WORKERS=1): honour the
        // "no thread is ever spawned" guarantee for every caller by
        // falling back to sequential order (bit-identical results)
        if crate::util::par::current_workers() <= 1 {
            return self.train_batch(x, labels, hp, drop);
        }
        let y32 = one_hot32(labels, self.spec.num_classes);
        let nblocks = self.blocks.len();
        // phase 1: forwards in block order on the caller; block l+1 reads
        // block l's cached output in place (logical 2-D at flatten
        // boundaries), so no activation is copied
        let mut caches: Vec<BlockCache> = Vec::with_capacity(nblocks);
        for l in 0..nblocks {
            let cache = {
                let a_in = if l == 0 { x } else { &caches[l - 1].a_out };
                self.blocks[l].forward_train(a_in, Some(drop.stream(l)))
            };
            caches.push(cache);
        }
        // phase 2: every block backward + the head step run as one pool
        // job (the caller participates); outputs return in task order
        enum Task<'a> {
            Block(usize, &'a mut Block),
            Head(&'a mut Head),
        }
        enum Done {
            Loss(i64),
            Head(ITensor, i64),
        }
        let Network { blocks, head, .. } = self;
        let mut tasks: Vec<Task> = blocks
            .iter_mut()
            .enumerate()
            .map(|(l, b)| Task::Block(l, b))
            .collect();
        tasks.push(Task::Head(head));
        let caches = &caches;
        let y32_ref = &y32;
        let outs = crate::util::par::scoped_map(
            tasks,
            crate::util::par::current_workers(),
            |t| match t {
                Task::Block(l, blk) => {
                    let a_in = if l == 0 { x } else { &caches[l - 1].a_out };
                    Done::Loss(blk.backward_step(a_in, &caches[l], y32_ref,
                                                 hp))
                }
                Task::Head(h) => {
                    let a_in = caches.last().map(|c| &c.a_out).unwrap_or(x);
                    let (yhat, loss) = h.train_step(a_in, y32_ref, hp);
                    Done::Head(yhat, loss)
                }
            },
        );
        let mut report = StepReport::default();
        for d in outs {
            match d {
                Done::Loss(l) => report.block_loss.push(l),
                Done::Head(yhat, loss) => {
                    report.head_loss = loss;
                    report.correct = count_correct(&yhat, labels);
                }
            }
        }
        report
    }

    /// Count correct argmax predictions over a labelled batch.
    pub fn eval_batch(&self, x: &ITensor, labels: &[usize]) -> usize {
        count_correct(&self.infer(x), labels)
    }

    /// A fresh replica of this network: identical spec, weights and
    /// dropout rates, with its own kernel workspaces. The data-parallel
    /// trainer (`train::replica`) builds one per extra replica; the
    /// weight tensors are copied exactly once here — afterwards replicas
    /// stay in lockstep by construction, because every replica applies
    /// the same all-reduced IntegerSGD step instead of receiving a
    /// weight broadcast.
    pub fn replicate(&self) -> Network {
        let mut n = Network::new(self.spec.clone(), 0);
        for (dst, src) in n.blocks.iter_mut().zip(&self.blocks) {
            dst.wf = src.wf.clone();
            dst.wl = src.wl.clone();
            dst.drop_p256 = src.drop_p256;
        }
        n.head.wo = self.head.wo.clone();
        n
    }

    /// Weight snapshot in block order: wf_0, wl_0, ..., wo. Used by
    /// checkpointing and the golden trace tests.
    pub fn weights(&self) -> Vec<(&'static str, &ITensor)> {
        let mut out = Vec::new();
        for b in &self.blocks {
            out.push(("wf", &b.wf));
            out.push(("wl", &b.wl));
        }
        out.push(("wo", &self.head.wo));
        out
    }
}

pub fn count_correct(yhat: &ITensor, labels: &[usize]) -> usize {
    let (b, g) = (yhat.shape[0], yhat.shape[1]);
    let mut correct = 0;
    for i in 0..b {
        let row = &yhat.data[i * g..(i + 1) * g];
        let mut best = 0usize;
        for j in 1..g {
            if row[j] > row[best] {
                best = j;
            }
        }
        if best == labels[i] {
            correct += 1;
        }
    }
    correct
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    fn toy_batch(rng: &mut Pcg32, spec: &NetworkSpec, b: usize)
                 -> (ITensor, Vec<usize>) {
        let mut shape = vec![b];
        shape.extend(&spec.input_shape);
        let n: usize = shape.iter().product();
        let x = ITensor::from_vec(&shape,
                                  (0..n).map(|_| rng.range_i32(-127, 127)).collect());
        let labels = (0..b).map(|i| i % spec.num_classes).collect();
        (x, labels)
    }

    #[test]
    fn forward_shapes_tinycnn() {
        let spec = zoo::get("tinycnn").unwrap();
        let net = Network::new(spec.clone(), 1);
        let mut rng = Pcg32::new(2);
        let (x, _) = toy_batch(&mut rng, &spec, 4);
        let yhat = net.infer(&x);
        assert_eq!(yhat.shape, vec![4, 10]);
    }

    #[test]
    fn activations_stay_int8_range() {
        let spec = zoo::get("tinycnn").unwrap();
        let net = Network::new(spec.clone(), 1);
        let mut rng = Pcg32::new(2);
        let (x, _) = toy_batch(&mut rng, &spec, 4);
        let mut a = x;
        for blk in &net.blocks {
            a = blk.forward(&a);
            let (lo, hi) = a.minmax();
            // NITRO-ReLU output range: [-127-mu, 127-mu]
            assert!(lo >= -300 && hi <= 300, "({lo},{hi})");
            assert!(a.bitwidth() <= 9);
        }
    }

    #[test]
    fn infer_into_matches_infer_bitexact() {
        // the serving fast path must equal the reference inference forward
        // byte for byte, across presets (conv with/without pool, linear),
        // batch sizes, and one reused scratch across everything
        let mut scratch = InferScratch::new();
        let mut out = ITensor::empty();
        let mut rng = Pcg32::new(3);
        for preset in ["tinycnn", "mlp1-mini"] {
            let spec = zoo::get(preset).unwrap();
            let net = Network::new(spec.clone(), 21);
            for b in [1usize, 3, 8] {
                let (x, _) = toy_batch(&mut rng, &spec, b);
                let want = net.infer(&x);
                net.infer_into(&x, &mut scratch, &mut out);
                assert_eq!(out, want, "{preset} b{b}");
            }
        }
    }

    #[test]
    fn infer_batch_composition_invariant() {
        // per-sample logits must not depend on which other samples share
        // the batch — the micro-batching determinism contract
        let spec = zoo::get("tinycnn").unwrap();
        let net = Network::new(spec.clone(), 5);
        let mut rng = Pcg32::new(9);
        let (x, _) = toy_batch(&mut rng, &spec, 6);
        let full = net.infer(&x);
        let ss: usize = spec.input_shape.iter().product();
        let g = spec.num_classes;
        let mut scratch = InferScratch::new();
        let mut out = ITensor::empty();
        for i in 0..6 {
            let mut shape = vec![1];
            shape.extend(&spec.input_shape);
            let xi = ITensor::from_vec(&shape,
                                       x.data[i * ss..(i + 1) * ss].to_vec());
            net.infer_into(&xi, &mut scratch, &mut out);
            assert_eq!(out.data, full.data[i * g..(i + 1) * g], "sample {i}");
        }
    }

    #[test]
    fn parallel_equals_sequential_bitexact() {
        // the load-bearing L3 property: the block-parallel scheduler must
        // produce byte-identical weights and losses to sequential order —
        // including under dropout, where each block reads its own RNG
        // stream regardless of scheduler.
        for dropout in [0.0, 0.3] {
            let spec = zoo::get("tinycnn").unwrap();
            let mut net_a = Network::new(spec.clone(), 7);
            let mut net_b = Network::new(spec.clone(), 7);
            net_a.set_dropout(dropout, dropout);
            net_b.set_dropout(dropout, dropout);
            let hp = Hyper { gamma_inv: 512, eta_fw_inv: 12000,
                             eta_lr_inv: 3000 };
            let mut drop_a = DropoutRngs::new(9, net_a.blocks.len());
            let mut drop_b = DropoutRngs::new(9, net_b.blocks.len());
            let mut data_rng = Pcg32::new(11);
            for _ in 0..3 {
                let (x, labels) = toy_batch(&mut data_rng, &spec, 6);
                let ra = net_a.train_batch(&x, &labels, &hp, &mut drop_a);
                let rb =
                    net_b.train_batch_parallel(&x, &labels, &hp, &mut drop_b);
                assert_eq!(ra.block_loss, rb.block_loss, "dropout {dropout}");
                assert_eq!(ra.head_loss, rb.head_loss, "dropout {dropout}");
                assert_eq!(ra.correct, rb.correct, "dropout {dropout}");
            }
            for ((na, ta), (nb, tb)) in
                net_a.weights().iter().zip(net_b.weights())
            {
                assert_eq!(na, &nb);
                assert_eq!(ta, &tb, "weight {na} diverged (dropout {dropout})");
            }
        }
    }

    #[test]
    fn low_bit_rails_bound_scaled_values_and_weights() {
        // satellite property at the network level: with a b-bit config the
        // scaled pre-activations, head logits and post-step weights never
        // leave ±(2^(b-1)-1) — including b=32, where the rail is the full
        // i32 range and no clamp kernel must fire
        use crate::nn::spec::{BitsPlan, BitwidthCfg};
        for b in [8u32, 16, 32] {
            let rail = if b >= 32 {
                i32::MAX
            } else {
                (1i32 << (b - 1)) - 1
            };
            let spec = zoo::get("tinycnn").unwrap()
                .with_bits(BitsPlan::uniform(BitwidthCfg::uniform(b)));
            let mut net = Network::new(spec.clone(), 7);
            let hp = Hyper { gamma_inv: 8, eta_fw_inv: 0, eta_lr_inv: 0 };
            let mut drop = DropoutRngs::new(3, net.blocks.len());
            let mut rng = Pcg32::new(13);
            for _ in 0..3 {
                let (x, labels) = toy_batch(&mut rng, &spec, 4);
                // scaled pre-activations obey the A rail
                let cache = net.blocks[0].forward_train(&x, None);
                let (lo, hi) = cache.zs.minmax();
                assert!(lo >= -rail && hi <= rail, "b{b} zs ({lo},{hi})");
                let _ = net.train_batch(&x, &labels, &hp, &mut drop);
                // head logits obey the A rail
                let yhat = net.infer(&x);
                let (lo, hi) = yhat.minmax();
                assert!(lo >= -rail && hi <= rail, "b{b} yhat ({lo},{hi})");
                // post-step weights obey the W rail
                for (name, t) in net.weights() {
                    let (lo, hi) = t.minmax();
                    assert!(lo >= -rail && hi <= rail,
                            "b{b} weight {name} ({lo},{hi})");
                }
            }
        }
    }

    #[test]
    fn low_bit_parallel_equals_sequential_bitexact() {
        // the scheduler-identity contract must survive rail clamping: the
        // block-parallel scheduler stays byte-identical to sequential
        // order under an 8-bit W/A config with clamped grads/errors
        use crate::nn::spec::{BitsPlan, BitwidthCfg};
        let bits = BitwidthCfg {
            weights: 8,
            activations: 8,
            grads: 32,
            errors: 16,
        };
        let spec = zoo::get("tinycnn").unwrap()
            .with_bits(BitsPlan::uniform(bits));
        let mut net_a = Network::new(spec.clone(), 7);
        let mut net_b = Network::new(spec.clone(), 7);
        net_a.set_dropout(0.2, 0.2);
        net_b.set_dropout(0.2, 0.2);
        let hp = Hyper { gamma_inv: 64, eta_fw_inv: 12000,
                         eta_lr_inv: 3000 };
        let mut drop_a = DropoutRngs::new(9, net_a.blocks.len());
        let mut drop_b = DropoutRngs::new(9, net_b.blocks.len());
        let mut data_rng = Pcg32::new(11);
        for _ in 0..3 {
            let (x, labels) = toy_batch(&mut data_rng, &spec, 6);
            let ra = net_a.train_batch(&x, &labels, &hp, &mut drop_a);
            let rb = net_b.train_batch_parallel(&x, &labels, &hp,
                                                &mut drop_b);
            assert_eq!(ra.block_loss, rb.block_loss);
            assert_eq!(ra.head_loss, rb.head_loss);
            assert_eq!(ra.correct, rb.correct);
        }
        for ((na, ta), (nb, tb)) in
            net_a.weights().iter().zip(net_b.weights())
        {
            assert_eq!(na, &nb);
            assert_eq!(ta, &tb, "weight {na} diverged under 8-bit rails");
        }
    }

    #[test]
    fn per_layer_bits_override_reaches_blocks() {
        use crate::nn::spec::{BitsPlan, BitwidthCfg};
        let mut plan = BitsPlan::uniform(BitwidthCfg::uniform(16));
        plan.overrides = vec![(1, BitwidthCfg::uniform(8))];
        let spec = zoo::get("tinycnn").unwrap().with_bits(plan);
        let net = Network::new(spec, 1);
        assert_eq!(net.blocks[0].bits.weights, 16);
        assert_eq!(net.blocks[1].bits.weights, 8);
        assert_eq!(net.blocks[2].bits.weights, 16);
        assert_eq!(net.head.bits.weights, 16);
        // replicas inherit the per-layer rails through the spec
        let rep = net.replicate();
        assert_eq!(rep.blocks[1].bits, net.blocks[1].bits);
        assert_eq!(rep.head.bits, net.head.bits);
    }

    #[test]
    fn training_learns_separable_toy() {
        // strongly separable 4-class problem on an MLP block stack
        let spec = zoo::mlp("toy", &[24, 16], 32, 4);
        let mut net = Network::new(spec, 3);
        let hp = Hyper::default();
        let mut rng = Pcg32::new(5);
        let mut protos = Vec::new();
        for _ in 0..4 {
            protos.push((0..32).map(|_| rng.range_i32(-100, 100)).collect::<Vec<_>>());
        }
        let make_batch = |rng: &mut Pcg32| {
            let mut xs = Vec::new();
            let mut ys = Vec::new();
            for i in 0..32usize {
                let c = i % 4;
                ys.push(c);
                xs.extend(protos[c].iter().map(|&v: &i32| {
                    (v + rng.range_i32(-10, 10)).clamp(-127, 127)
                }));
            }
            (ITensor::from_vec(&[32, 32], xs), ys)
        };
        let mut first = 0i64;
        let mut last = 0i64;
        let mut drop = DropoutRngs::new(5, net.blocks.len());
        // integer bootstrap: weights must grow before the scaled
        // pre-activations carry signal — give it a few hundred steps
        for step in 0..400 {
            let (x, y) = make_batch(&mut rng);
            let rep = net.train_batch(&x, &y, &hp, &mut drop);
            let total: i64 = rep.head_loss;
            if step == 0 {
                first = total;
            }
            last = total;
        }
        assert!(last < first / 2, "head loss {first} -> {last}");
        let (x, y) = make_batch(&mut rng);
        let correct = net.eval_batch(&x, &y);
        assert!(correct >= 20, "accuracy {correct}/32");
    }

    #[test]
    fn dropout_masks_applied_and_eval_identity() {
        let spec = zoo::get("tinycnn").unwrap();
        let mut net = Network::new(spec.clone(), 1);
        net.set_dropout(0.5, 0.5);
        let mut rng = Pcg32::new(2);
        let (x, labels) = toy_batch(&mut rng, &spec, 4);
        let hp = Hyper::default();
        // train path: some outputs zeroed
        let cache = net.blocks[0].forward_train(&x, Some(&mut rng));
        let zeros = cache.a_out.data.iter().filter(|&&v| v == 0).count();
        assert!(zeros > cache.a_out.len() / 4, "dropout not applied");
        // eval path unaffected by drop_p256
        let mut drop = DropoutRngs::new(2, net.blocks.len());
        let _ = net.train_batch(&x, &labels, &hp, &mut drop);
        let y1 = net.infer(&x);
        let y2 = net.infer(&x);
        assert_eq!(y1, y2);
    }

    #[test]
    fn head_updates_move_weights() {
        let mut rng = Pcg32::new(1);
        let mut head = Head::new(HeadSpec { in_features: 8, num_classes: 3 },
                                 &mut rng);
        let before = head.wo.clone();
        let a = ITensor::from_vec(&[2, 8], (0..16).map(|v| v * 7 - 50).collect());
        let y32 = one_hot32(&[0, 2], 3);
        let hp = Hyper { gamma_inv: 8, eta_fw_inv: 0, eta_lr_inv: 0 };
        head.train_step(&a, &y32, &hp);
        assert_ne!(before, head.wo);
    }
}
