//! Integer Kaiming initialization (paper App. B.1).
//!
//! `b = floor(128 · 1732 / (isqrt(fan_in) · 1000))`, weights drawn from the
//! discrete uniform U(−b, b); biases are disabled everywhere (the NITRO
//! scaling truncation would erase them — App. B.1).

use crate::tensor::{ITensor, Tensor};
use crate::util::{isqrt, rng::Pcg32};

/// Integer Kaiming bound. Mirrors `ref.kaiming_bound`.
pub fn kaiming_bound(fan_in: usize) -> i32 {
    ((128 * 1732) / (isqrt(fan_in as u64) as i64 * 1000)).max(1) as i32
}

/// Draw an int32 weight tensor U(−b, b) inclusive.
pub fn init_weights(rng: &mut Pcg32, shape: &[usize], fan_in: usize) -> ITensor {
    let b = kaiming_bound(fan_in);
    let n: usize = shape.iter().product();
    Tensor::from_vec(shape, (0..n).map(|_| rng.range_i32(-b, b)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bound_matches_python_ref() {
        // pinned against ref.kaiming_bound in python tests
        assert_eq!(kaiming_bound(784), (128 * 1732) / (28 * 1000));
        assert_eq!(kaiming_bound(9), (128 * 1732) / (3 * 1000));
        assert_eq!(kaiming_bound(1_000_000), 1); // never 0 — dead layer guard
    }

    #[test]
    fn init_within_bound_and_covers_range() {
        let mut rng = Pcg32::new(5);
        let b = kaiming_bound(64);
        let w = init_weights(&mut rng, &[64, 64], 64);
        let (lo, hi) = w.minmax();
        assert!(lo >= -b && hi <= b);
        assert_eq!(lo, -b, "uniform should hit the bound over 4096 draws");
        assert_eq!(hi, b);
        // roughly centered
        let mean = w.data.iter().map(|&v| v as i64).sum::<i64>() as f64
            / w.len() as f64;
        assert!(mean.abs() < b as f64 * 0.1, "mean {mean}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = init_weights(&mut Pcg32::new(1), &[10, 10], 100);
        let b = init_weights(&mut Pcg32::new(1), &[10, 10], 100);
        assert_eq!(a, b);
    }
}
