//! Bit-width probes for the App. E.3 claims: weights fit int16;
//! intermediate pre-activations z_l and backward deltas may exceed int16
//! but stay within int32. These probes measure, rather than assume, both
//! claims on a live network + batch.

use crate::nn::block::adaptive_pool;
use crate::nn::spec::BlockSpec;
use crate::nn::Network;
use crate::tensor::{
    conv2d_i64, matmul_a_bt_i64, matmul_i64, nitro_relu, nitro_scale,
    one_hot32, rss_loss_grad, scale_factor_linear, ITensor,
};

/// Bits needed for an i64 slice in two's complement.
fn bits_i64(xs: &[i64]) -> u32 {
    xs.iter()
        .map(|&v| {
            let m = if v < 0 { !v } else { v } as u64;
            64 - m.leading_zeros() + 1
        })
        .max()
        .unwrap_or(1)
}

#[derive(Clone, Debug)]
pub struct BlockProbe {
    pub block: usize,
    /// Pre-activation z_l (before the NITRO scaling layer).
    pub preact_bits: u32,
    /// Block output activation a_l.
    pub act_bits: u32,
    /// delta^fw entering the forward layers (learning-layer backward).
    pub delta_bits: u32,
    /// Forward weights.
    pub weight_bits: u32,
}

/// Run one forward pass (+ the learning-layer gradient of each block) and
/// record the bit-width of every intermediate the paper's App. E.3
/// discusses. Read-only: no weights are updated.
pub fn probe_network(net: &Network, x: &ITensor, labels: &[usize])
                     -> Vec<BlockProbe> {
    let y32 = one_hot32(labels, net.spec.num_classes);
    let mut probes = Vec::new();
    let mut a = x.clone();
    for (bi, blk) in net.blocks.iter().enumerate() {
        if matches!(blk.spec, BlockSpec::Linear(_)) && a.shape.len() > 2 {
            let (b, f) = a.batch_feat();
            a = a.reshaped(&[b, f]);
        }
        let (z_bits, out) = match &blk.spec {
            BlockSpec::Conv(c) => {
                let z = conv2d_i64(&a, &blk.wf, c.padding);
                let zs = nitro_scale(&z, c.sf());
                let act = nitro_relu(&zs, c.alpha_inv);
                let out = if c.pool {
                    crate::tensor::maxpool2d(&act, 2, 2).0
                } else {
                    act
                };
                (bits_i64(&z.data), out)
            }
            BlockSpec::Linear(l) => {
                let z = matmul_i64(&a, &blk.wf);
                let zs = nitro_scale(&z, l.sf());
                (bits_i64(&z.data), nitro_relu(&zs, l.alpha_inv))
            }
        };
        // learning-layer gradient magnitude (delta^fw before unpooling)
        let (feat, _, _) = adaptive_pool(&out, &blk.spec);
        let zl = matmul_i64(&feat, &blk.wl);
        let yhat = nitro_scale(&zl, scale_factor_linear(feat.shape[1]));
        let (_, grad_l) = rss_loss_grad(&yhat, &y32);
        let dfeat = matmul_a_bt_i64(&grad_l, &blk.wl);
        probes.push(BlockProbe {
            block: bi,
            preact_bits: z_bits,
            act_bits: out.bitwidth(),
            delta_bits: bits_i64(&dfeat.data),
            weight_bits: blk.wf.bitwidth(),
        });
        a = out;
    }
    probes
}

/// The App. E.3 verdict over a probe set: (weights_int16, intermediates_int32).
pub fn verdict(probes: &[BlockProbe]) -> (bool, bool) {
    let w16 = probes.iter().all(|p| p.weight_bits <= 16);
    let i32ok = probes
        .iter()
        .all(|p| p.preact_bits <= 32 && p.delta_bits <= 32 && p.act_bits <= 32);
    (w16, i32ok)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;
    use crate::util::rng::Pcg32;

    #[test]
    fn probe_fresh_network() {
        let spec = zoo::get("tinycnn").unwrap();
        let net = Network::new(spec.clone(), 3);
        let mut rng = Pcg32::new(1);
        let x = ITensor::from_vec(
            &[4, 1, 8, 8],
            (0..256).map(|_| rng.range_i32(-127, 127)).collect(),
        );
        let probes = probe_network(&net, &x, &[0, 1, 2, 3]);
        assert_eq!(probes.len(), 3);
        for p in &probes {
            // activations int8-ish, pre-activations well under int32
            assert!(p.act_bits <= 9, "{p:?}");
            assert!(p.preact_bits <= 32, "{p:?}");
            assert!(p.weight_bits <= 8, "{p:?}"); // Kaiming init is tiny
        }
        let (w16, i32ok) = verdict(&probes);
        assert!(w16 && i32ok);
    }

    #[test]
    fn bits_i64_twos_complement() {
        assert_eq!(bits_i64(&[0]), 1);
        assert_eq!(bits_i64(&[-128]), 8);
        assert_eq!(bits_i64(&[127]), 8);
        assert_eq!(bits_i64(&[i64::from(i32::MAX)]), 32);
        assert_eq!(bits_i64(&[i64::from(i32::MIN)]), 32);
        assert_eq!(bits_i64(&[i64::from(i32::MAX) + 1]), 33);
    }
}
