//! Topology specifications — the Rust mirror of `python/compile/model.py`
//! dataclasses. Every derived constant (SF, mu, AF, adaptive-pool geometry)
//! is computed identically in both languages and cross-checked against the
//! artifact manifests.

use crate::tensor::{scale_factor_conv, scale_factor_linear};
use crate::util::isqrt;
use crate::util::jsonio::Json;

pub const DEFAULT_ALPHA_INV: i64 = 10; // LeakyReLU slope 0.1

// ---------------------------------------------------------------------------
// Bitwidth configuration (W/A/G/E rails)
// ---------------------------------------------------------------------------

/// Per-signal integer bitwidths. Each signal is clamped to the symmetric
/// rail ±(2^(b−1)−1); the default 32/32/64/64 makes every rail the full
/// native width, where clamping is skipped entirely so default-bits runs
/// stay byte-identical to the pre-bitwidth behavior.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BitwidthCfg {
    /// Weight rail (i32 storage): 2..=32 bits.
    pub weights: u32,
    /// Activation rail applied to NITRO-Scaling outputs (i32): 2..=32.
    pub activations: u32,
    /// Weight-gradient rail (i64 accumulators): 2..=64 bits.
    pub grads: u32,
    /// Backprop error-signal rail (i32 signals; >=32 disables): 2..=64.
    pub errors: u32,
}

impl Default for BitwidthCfg {
    fn default() -> BitwidthCfg {
        BitwidthCfg { weights: 32, activations: 32, grads: 64, errors: 64 }
    }
}

/// ±rail for a b-bit i32 signal; b >= 32 means "no clamp" and must be
/// treated as a skip marker (clamping to ±i32::MAX would still remap
/// i32::MIN and break byte-identity of default runs).
fn rail_i32(b: u32) -> i32 {
    if b >= 32 {
        i32::MAX
    } else {
        ((1i64 << b.saturating_sub(1)) - 1) as i32
    }
}

/// ±rail for a b-bit i64 signal; b >= 64 means "no clamp".
fn rail_i64(b: u32) -> i64 {
    if b >= 64 {
        i64::MAX
    } else {
        (1i64 << b.saturating_sub(1)) - 1
    }
}

impl BitwidthCfg {
    /// Uniform W/A bits with default (full-width) grad/error rails —
    /// the `"bits": N` spec shorthand.
    pub fn uniform(b: u32) -> BitwidthCfg {
        BitwidthCfg { weights: b, activations: b, ..BitwidthCfg::default() }
    }

    pub fn is_default(&self) -> bool {
        *self == BitwidthCfg::default()
    }

    pub fn validate(&self) -> Result<(), String> {
        let fields = [
            ("weights", self.weights, 32u32),
            ("activations", self.activations, 32),
            ("grads", self.grads, 64),
            ("errors", self.errors, 64),
        ];
        for (name, b, max) in fields {
            if !(2..=max).contains(&b) {
                return Err(format!(
                    "bits.{name}: {b} out of range 2..={max}"
                ));
            }
        }
        Ok(())
    }

    pub fn weight_rail(&self) -> i32 {
        rail_i32(self.weights)
    }

    pub fn act_rail(&self) -> i32 {
        rail_i32(self.activations)
    }

    /// Error signals are i32; errors >= 32 disables the clamp.
    pub fn err_rail(&self) -> i32 {
        rail_i32(self.errors)
    }

    pub fn grad_rail(&self) -> i64 {
        rail_i64(self.grads)
    }

    /// Canonical `W/A/G/E` label (spec strings, BENCH rows, run ids).
    pub fn label(&self) -> String {
        format!(
            "{}/{}/{}/{}",
            self.weights, self.activations, self.grads, self.errors
        )
    }

    /// Parse `"N"` (uniform W/A) or `"W/A/G/E"`.
    pub fn parse_label(s: &str) -> Result<BitwidthCfg, String> {
        fn one(p: &str) -> Result<u32, String> {
            p.trim()
                .parse::<u32>()
                .map_err(|_| format!("bits: bad width {p:?}"))
        }
        let s = s.trim();
        let parts: Vec<&str> = s.split('/').collect();
        let cfg = match parts.as_slice() {
            [b] => BitwidthCfg::uniform(one(b)?),
            [w, a, g, e] => BitwidthCfg {
                weights: one(w)?,
                activations: one(a)?,
                grads: one(g)?,
                errors: one(e)?,
            },
            _ => {
                return Err(format!(
                    "bits: expected \"N\" or \"W/A/G/E\", got {s:?}"
                ))
            }
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse a JSON cell: integer (uniform), `"W/A/G/E"` string, or an
    /// object with optional `weights`/`activations`/`grads`/`errors`
    /// keys defaulting from `base`.
    pub fn from_json_over(
        j: &Json, base: BitwidthCfg,
    ) -> Result<BitwidthCfg, String> {
        fn field(j: &Json, key: &str, default: u32) -> Result<u32, String> {
            match j.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_i64()
                    .and_then(|b| u32::try_from(b).ok())
                    .ok_or_else(|| {
                        format!("bits.{key}: expected a non-negative integer")
                    }),
            }
        }
        let cfg = match j {
            Json::Int(_) => {
                let b = j.as_i64().and_then(|b| u32::try_from(b).ok());
                BitwidthCfg::uniform(b.ok_or_else(|| {
                    "bits: expected a non-negative integer".to_string()
                })?)
            }
            Json::Str(s) => return BitwidthCfg::parse_label(s),
            Json::Object(_) => BitwidthCfg {
                weights: field(j, "weights", base.weights)?,
                activations: field(j, "activations", base.activations)?,
                grads: field(j, "grads", base.grads)?,
                errors: field(j, "errors", base.errors)?,
            },
            _ => {
                return Err(
                    "bits: expected an integer, \"W/A/G/E\" string, or object"
                        .to_string(),
                )
            }
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_json(j: &Json) -> Result<BitwidthCfg, String> {
        BitwidthCfg::from_json_over(j, BitwidthCfg::default())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("weights", Json::Int(self.weights as i64)),
            ("activations", Json::Int(self.activations as i64)),
            ("grads", Json::Int(self.grads as i64)),
            ("errors", Json::Int(self.errors as i64)),
        ])
    }
}

/// A network-wide bitwidth assignment: one base [`BitwidthCfg`] plus
/// optional per-block overrides (block index → full cfg). The head uses
/// the base cfg. Override indices past the last block are inert.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct BitsPlan {
    pub base: BitwidthCfg,
    pub overrides: Vec<(usize, BitwidthCfg)>,
}

impl BitsPlan {
    pub fn uniform(base: BitwidthCfg) -> BitsPlan {
        BitsPlan { base, overrides: Vec::new() }
    }

    pub fn for_layer(&self, l: usize) -> BitwidthCfg {
        self.overrides
            .iter()
            .find(|(i, _)| *i == l)
            .map(|(_, c)| *c)
            .unwrap_or(self.base)
    }

    pub fn is_default(&self) -> bool {
        self.base.is_default()
            && self.overrides.iter().all(|(_, c)| c.is_default())
    }

    /// Human label: base `W/A/G/E`, plus `+L<i>=<label>` per override.
    pub fn label(&self) -> String {
        let mut s = self.base.label();
        for (i, c) in &self.overrides {
            s.push_str(&format!("+L{i}={}", c.label()));
        }
        s
    }

    /// Parse a JSON cell: any [`BitwidthCfg`] form, where the object
    /// form may carry `"layers": {"<index>": {<partial cfg>}}`.
    pub fn from_json(j: &Json) -> Result<BitsPlan, String> {
        let base = BitwidthCfg::from_json(j)?;
        let mut overrides = Vec::new();
        if let Some(layers) = j.get("layers") {
            let m = match layers {
                Json::Object(m) => m,
                _ => {
                    return Err(
                        "bits.layers: expected an object of block indices"
                            .to_string(),
                    )
                }
            };
            for (k, v) in m {
                let idx = k.parse::<usize>().map_err(|_| {
                    format!("bits.layers: bad block index {k:?}")
                })?;
                overrides.push((idx, BitwidthCfg::from_json_over(v, base)?));
            }
            overrides.sort_by_key(|(i, _)| *i);
        }
        Ok(BitsPlan { base, overrides })
    }

    pub fn to_json(&self) -> Json {
        let mut obj = self.base.to_json();
        if !self.overrides.is_empty() {
            if let Json::Object(m) = &mut obj {
                let layers = self
                    .overrides
                    .iter()
                    .map(|(i, c)| (i.to_string(), c.to_json()))
                    .collect();
                m.insert("layers".to_string(), Json::Object(layers));
            }
        }
        obj
    }
}

/// One integer convolutional local-loss block.
#[derive(Clone, Debug, PartialEq)]
pub struct ConvSpec {
    pub in_channels: usize,
    pub out_channels: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub kernel: usize,
    pub padding: usize,
    /// 2x2/s2 MaxPool after the activation.
    pub pool: bool,
    pub alpha_inv: i64,
    /// Learning-layers input-feature budget (paper §4.3, d^lr).
    pub d_lr: usize,
    pub num_classes: usize,
}

impl ConvSpec {
    pub fn conv_h(&self) -> usize {
        self.in_h + 2 * self.padding - self.kernel + 1
    }

    pub fn conv_w(&self) -> usize {
        self.in_w + 2 * self.padding - self.kernel + 1
    }

    pub fn out_h(&self) -> usize {
        if self.pool { self.conv_h() / 2 } else { self.conv_h() }
    }

    pub fn out_w(&self) -> usize {
        if self.pool { self.conv_w() / 2 } else { self.conv_w() }
    }

    /// NITRO scaling factor: 2^8 · K² · C_in.
    pub fn sf(&self) -> i64 {
        scale_factor_conv(self.kernel, self.in_channels)
    }

    /// Adaptive max-pool geometry for the learning layers:
    /// target side `s = max(1, isqrt(d_lr / C_out))` clamped to the map,
    /// window `k = floor(min(H,W) / s)` (DESIGN.md interp. #3).
    pub fn lr_pool(&self) -> (usize, usize) {
        let s = isqrt((self.d_lr / self.out_channels).max(1) as u64) as usize;
        let s = s.max(1).min(self.out_h()).min(self.out_w());
        let k = self.out_h().min(self.out_w()) / s;
        (s, k)
    }

    pub fn lr_features(&self) -> usize {
        let (s, _) = self.lr_pool();
        self.out_channels * s * s
    }

    pub fn fan_in(&self) -> usize {
        self.in_channels * self.kernel * self.kernel
    }

    pub fn wf_shape(&self) -> Vec<usize> {
        vec![self.out_channels, self.in_channels, self.kernel, self.kernel]
    }

    pub fn wl_shape(&self) -> Vec<usize> {
        vec![self.lr_features(), self.num_classes]
    }
}

/// One integer linear local-loss block.
#[derive(Clone, Debug, PartialEq)]
pub struct LinearSpec {
    pub in_features: usize,
    pub out_features: usize,
    pub alpha_inv: i64,
    pub num_classes: usize,
}

impl LinearSpec {
    pub fn sf(&self) -> i64 {
        scale_factor_linear(self.in_features)
    }

    pub fn fan_in(&self) -> usize {
        self.in_features
    }

    pub fn wf_shape(&self) -> Vec<usize> {
        vec![self.in_features, self.out_features]
    }

    pub fn wl_shape(&self) -> Vec<usize> {
        vec![self.out_features, self.num_classes]
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum BlockSpec {
    Conv(ConvSpec),
    Linear(LinearSpec),
}

impl BlockSpec {
    pub fn num_classes(&self) -> usize {
        match self {
            BlockSpec::Conv(c) => c.num_classes,
            BlockSpec::Linear(l) => l.num_classes,
        }
    }

    pub fn out_features(&self) -> usize {
        match self {
            BlockSpec::Conv(c) => c.out_channels * c.out_h() * c.out_w(),
            BlockSpec::Linear(l) => l.out_features,
        }
    }

    pub fn param_count(&self) -> usize {
        let (wf, wl) = match self {
            BlockSpec::Conv(c) => (c.wf_shape(), c.wl_shape()),
            BlockSpec::Linear(l) => (l.wf_shape(), l.wl_shape()),
        };
        wf.iter().product::<usize>() + wl.iter().product::<usize>()
    }
}

/// Output layers: Integer Linear -> NITRO scaling, trained on the global
/// loss.
#[derive(Clone, Debug, PartialEq)]
pub struct HeadSpec {
    pub in_features: usize,
    pub num_classes: usize,
}

impl HeadSpec {
    pub fn sf(&self) -> i64 {
        scale_factor_linear(self.in_features)
    }

    pub fn fan_in(&self) -> usize {
        self.in_features
    }
}

/// A full NITRO-D network.
#[derive(Clone, Debug, PartialEq)]
pub struct NetworkSpec {
    pub name: String,
    /// (C, H, W) for CNNs, (F,) for MLPs.
    pub input_shape: Vec<usize>,
    pub blocks: Vec<BlockSpec>,
    pub head: HeadSpec,
    pub num_classes: usize,
    /// W/A/G/E rails; default 32/32/64/64 ≡ no clamping anywhere.
    pub bits: BitsPlan,
}

impl NetworkSpec {
    pub fn with_bits(mut self, bits: BitsPlan) -> NetworkSpec {
        self.bits = bits;
        self
    }

    /// Rails for block `l` (the head uses `self.bits.base`).
    pub fn bits_for(&self, l: usize) -> BitwidthCfg {
        self.bits.for_layer(l)
    }

    /// NITRO Amplification Factor AF = 2^6 · G (paper §3.3).
    pub fn amplification_factor(&self) -> i64 {
        64 * self.num_classes as i64
    }

    pub fn param_count(&self) -> usize {
        self.blocks.iter().map(|b| b.param_count()).sum::<usize>()
            + self.head.in_features * self.head.num_classes
    }

    /// Parameters kept at inference (learning layers dropped — App. E.3).
    pub fn inference_param_count(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| match b {
                BlockSpec::Conv(c) => c.wf_shape().iter().product::<usize>(),
                BlockSpec::Linear(l) => l.wf_shape().iter().product(),
            })
            .sum::<usize>()
            + self.head.in_features * self.head.num_classes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    #[test]
    fn conv_spec_geometry() {
        let c = ConvSpec {
            in_channels: 3,
            out_channels: 128,
            in_h: 32,
            in_w: 32,
            kernel: 3,
            padding: 1,
            pool: true,
            alpha_inv: 10,
            d_lr: 4096,
            num_classes: 10,
        };
        assert_eq!((c.conv_h(), c.conv_w()), (32, 32));
        assert_eq!((c.out_h(), c.out_w()), (16, 16));
        assert_eq!(c.sf(), 256 * 9 * 3);
        // d_lr/C = 32 -> s = isqrt(32) = 5, k = 16/5 = 3
        assert_eq!(c.lr_pool(), (5, 3));
        assert_eq!(c.lr_features(), 128 * 25);
    }

    #[test]
    fn bitwidth_cfg_defaults_rails_and_labels() {
        let d = BitwidthCfg::default();
        assert!(d.is_default());
        assert_eq!(d.label(), "32/32/64/64");
        // full-width rails are the "no clamp" markers
        assert_eq!(d.weight_rail(), i32::MAX);
        assert_eq!(d.act_rail(), i32::MAX);
        assert_eq!(d.err_rail(), i32::MAX);
        assert_eq!(d.grad_rail(), i64::MAX);
        let b8 = BitwidthCfg::uniform(8);
        assert_eq!(b8.label(), "8/8/64/64");
        assert_eq!(b8.weight_rail(), 127);
        assert_eq!(b8.act_rail(), 127);
        assert_eq!(b8.grad_rail(), i64::MAX);
        let b16 = BitwidthCfg::parse_label("16/8/32/16").unwrap();
        assert_eq!(b16.weight_rail(), 32767);
        assert_eq!(b16.act_rail(), 127);
        assert_eq!(b16.grad_rail(), (1i64 << 31) - 1);
        assert_eq!(b16.err_rail(), 32767);
        // errors >= 32 disables the (i32) error clamp
        let e = BitwidthCfg { errors: 48, ..BitwidthCfg::default() };
        assert_eq!(e.err_rail(), i32::MAX);
    }

    #[test]
    fn bitwidth_cfg_parse_and_validate() {
        assert_eq!(
            BitwidthCfg::parse_label("8").unwrap(),
            BitwidthCfg::uniform(8)
        );
        assert_eq!(
            BitwidthCfg::parse_label(" 16/16/48/32 ").unwrap().grads,
            48
        );
        for bad in ["", "8/8", "8/8/8/8/8", "x", "1", "33", "8/8/65/64"] {
            assert!(BitwidthCfg::parse_label(bad).is_err(), "{bad:?}");
        }
        // json forms: int, string, object (+ partial object over default)
        let j = Json::parse("8").unwrap();
        assert_eq!(BitwidthCfg::from_json(&j).unwrap(),
                   BitwidthCfg::uniform(8));
        let j = Json::parse(r#""16/16/64/64""#).unwrap();
        assert_eq!(BitwidthCfg::from_json(&j).unwrap(),
                   BitwidthCfg::uniform(16));
        let j = Json::parse(r#"{"weights": 8}"#).unwrap();
        let c = BitwidthCfg::from_json(&j).unwrap();
        assert_eq!((c.weights, c.activations, c.grads, c.errors),
                   (8, 32, 64, 64));
        assert!(BitwidthCfg::from_json(&Json::parse("true").unwrap())
            .is_err());
        assert!(BitwidthCfg::from_json(&Json::parse("-8").unwrap()).is_err());
    }

    #[test]
    fn bits_plan_overrides_and_roundtrip() {
        let j = Json::parse(
            r#"{"weights": 8, "activations": 8,
                "layers": {"1": {"weights": 16}}}"#,
        )
        .unwrap();
        let p = BitsPlan::from_json(&j).unwrap();
        assert_eq!(p.base, BitwidthCfg::uniform(8));
        assert_eq!(p.for_layer(0), BitwidthCfg::uniform(8));
        // layer override is partial *over the base cell*
        assert_eq!(p.for_layer(1).weights, 16);
        assert_eq!(p.for_layer(1).activations, 8);
        assert_eq!(p.for_layer(9), p.base);
        assert!(!p.is_default());
        assert_eq!(p.label(), "8/8/64/64+L1=16/8/64/64");
        // json roundtrip preserves the plan
        let back = BitsPlan::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
        // default plan roundtrips and reports default
        assert!(BitsPlan::default().is_default());
        assert_eq!(
            BitsPlan::from_json(&BitsPlan::default().to_json()).unwrap(),
            BitsPlan::default()
        );
        // bad layer keys are typed errors
        let j = Json::parse(r#"{"weights": 8, "layers": {"x": {}}}"#).unwrap();
        assert!(BitsPlan::from_json(&j).is_err());
        let j = Json::parse(r#"{"weights": 8, "layers": [1]}"#).unwrap();
        assert!(BitsPlan::from_json(&j).is_err());
    }

    #[test]
    fn af_matches_paper() {
        let spec = zoo::get("vgg8b").unwrap();
        assert_eq!(spec.amplification_factor(), 640);
    }

    #[test]
    fn vgg8b_param_count_plausible() {
        // ~8.9M conv/linear forward params, VGG8B-scale
        let spec = zoo::get("vgg8b").unwrap();
        let p = spec.inference_param_count();
        assert!(p > 7_000_000 && p < 13_000_000, "{p}");
        assert!(spec.param_count() > p);
    }
}
