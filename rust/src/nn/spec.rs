//! Topology specifications — the Rust mirror of `python/compile/model.py`
//! dataclasses. Every derived constant (SF, mu, AF, adaptive-pool geometry)
//! is computed identically in both languages and cross-checked against the
//! artifact manifests.

use crate::tensor::{scale_factor_conv, scale_factor_linear};
use crate::util::isqrt;

pub const DEFAULT_ALPHA_INV: i64 = 10; // LeakyReLU slope 0.1

/// One integer convolutional local-loss block.
#[derive(Clone, Debug, PartialEq)]
pub struct ConvSpec {
    pub in_channels: usize,
    pub out_channels: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub kernel: usize,
    pub padding: usize,
    /// 2x2/s2 MaxPool after the activation.
    pub pool: bool,
    pub alpha_inv: i64,
    /// Learning-layers input-feature budget (paper §4.3, d^lr).
    pub d_lr: usize,
    pub num_classes: usize,
}

impl ConvSpec {
    pub fn conv_h(&self) -> usize {
        self.in_h + 2 * self.padding - self.kernel + 1
    }

    pub fn conv_w(&self) -> usize {
        self.in_w + 2 * self.padding - self.kernel + 1
    }

    pub fn out_h(&self) -> usize {
        if self.pool { self.conv_h() / 2 } else { self.conv_h() }
    }

    pub fn out_w(&self) -> usize {
        if self.pool { self.conv_w() / 2 } else { self.conv_w() }
    }

    /// NITRO scaling factor: 2^8 · K² · C_in.
    pub fn sf(&self) -> i64 {
        scale_factor_conv(self.kernel, self.in_channels)
    }

    /// Adaptive max-pool geometry for the learning layers:
    /// target side `s = max(1, isqrt(d_lr / C_out))` clamped to the map,
    /// window `k = floor(min(H,W) / s)` (DESIGN.md interp. #3).
    pub fn lr_pool(&self) -> (usize, usize) {
        let s = isqrt((self.d_lr / self.out_channels).max(1) as u64) as usize;
        let s = s.max(1).min(self.out_h()).min(self.out_w());
        let k = self.out_h().min(self.out_w()) / s;
        (s, k)
    }

    pub fn lr_features(&self) -> usize {
        let (s, _) = self.lr_pool();
        self.out_channels * s * s
    }

    pub fn fan_in(&self) -> usize {
        self.in_channels * self.kernel * self.kernel
    }

    pub fn wf_shape(&self) -> Vec<usize> {
        vec![self.out_channels, self.in_channels, self.kernel, self.kernel]
    }

    pub fn wl_shape(&self) -> Vec<usize> {
        vec![self.lr_features(), self.num_classes]
    }
}

/// One integer linear local-loss block.
#[derive(Clone, Debug, PartialEq)]
pub struct LinearSpec {
    pub in_features: usize,
    pub out_features: usize,
    pub alpha_inv: i64,
    pub num_classes: usize,
}

impl LinearSpec {
    pub fn sf(&self) -> i64 {
        scale_factor_linear(self.in_features)
    }

    pub fn fan_in(&self) -> usize {
        self.in_features
    }

    pub fn wf_shape(&self) -> Vec<usize> {
        vec![self.in_features, self.out_features]
    }

    pub fn wl_shape(&self) -> Vec<usize> {
        vec![self.out_features, self.num_classes]
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum BlockSpec {
    Conv(ConvSpec),
    Linear(LinearSpec),
}

impl BlockSpec {
    pub fn num_classes(&self) -> usize {
        match self {
            BlockSpec::Conv(c) => c.num_classes,
            BlockSpec::Linear(l) => l.num_classes,
        }
    }

    pub fn out_features(&self) -> usize {
        match self {
            BlockSpec::Conv(c) => c.out_channels * c.out_h() * c.out_w(),
            BlockSpec::Linear(l) => l.out_features,
        }
    }

    pub fn param_count(&self) -> usize {
        let (wf, wl) = match self {
            BlockSpec::Conv(c) => (c.wf_shape(), c.wl_shape()),
            BlockSpec::Linear(l) => (l.wf_shape(), l.wl_shape()),
        };
        wf.iter().product::<usize>() + wl.iter().product::<usize>()
    }
}

/// Output layers: Integer Linear -> NITRO scaling, trained on the global
/// loss.
#[derive(Clone, Debug, PartialEq)]
pub struct HeadSpec {
    pub in_features: usize,
    pub num_classes: usize,
}

impl HeadSpec {
    pub fn sf(&self) -> i64 {
        scale_factor_linear(self.in_features)
    }

    pub fn fan_in(&self) -> usize {
        self.in_features
    }
}

/// A full NITRO-D network.
#[derive(Clone, Debug, PartialEq)]
pub struct NetworkSpec {
    pub name: String,
    /// (C, H, W) for CNNs, (F,) for MLPs.
    pub input_shape: Vec<usize>,
    pub blocks: Vec<BlockSpec>,
    pub head: HeadSpec,
    pub num_classes: usize,
}

impl NetworkSpec {
    /// NITRO Amplification Factor AF = 2^6 · G (paper §3.3).
    pub fn amplification_factor(&self) -> i64 {
        64 * self.num_classes as i64
    }

    pub fn param_count(&self) -> usize {
        self.blocks.iter().map(|b| b.param_count()).sum::<usize>()
            + self.head.in_features * self.head.num_classes
    }

    /// Parameters kept at inference (learning layers dropped — App. E.3).
    pub fn inference_param_count(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| match b {
                BlockSpec::Conv(c) => c.wf_shape().iter().product::<usize>(),
                BlockSpec::Linear(l) => l.wf_shape().iter().product(),
            })
            .sum::<usize>()
            + self.head.in_features * self.head.num_classes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    #[test]
    fn conv_spec_geometry() {
        let c = ConvSpec {
            in_channels: 3,
            out_channels: 128,
            in_h: 32,
            in_w: 32,
            kernel: 3,
            padding: 1,
            pool: true,
            alpha_inv: 10,
            d_lr: 4096,
            num_classes: 10,
        };
        assert_eq!((c.conv_h(), c.conv_w()), (32, 32));
        assert_eq!((c.out_h(), c.out_w()), (16, 16));
        assert_eq!(c.sf(), 256 * 9 * 3);
        // d_lr/C = 32 -> s = isqrt(32) = 5, k = 16/5 = 3
        assert_eq!(c.lr_pool(), (5, 3));
        assert_eq!(c.lr_features(), 128 * 25);
    }

    #[test]
    fn af_matches_paper() {
        let spec = zoo::get("vgg8b").unwrap();
        assert_eq!(spec.amplification_factor(), 640);
    }

    #[test]
    fn vgg8b_param_count_plausible() {
        // ~8.9M conv/linear forward params, VGG8B-scale
        let spec = zoo::get("vgg8b").unwrap();
        let p = spec.inference_param_count();
        assert!(p > 7_000_000 && p < 13_000_000, "{p}");
        assert!(spec.param_count() > p);
    }
}
