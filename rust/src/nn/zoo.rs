//! Model zoo — the paper's architectures (App. C, Tables 4 & 5) plus the
//! CPU-budget presets. Mirrors `python/compile/model.ZOO`; the shared
//! presets (`tinycnn`, `mlp1-mini`) must produce identical topology
//! constants on both sides, which `rust/tests/golden.rs` verifies against
//! the artifact manifests.

use crate::nn::spec::{
    BitsPlan, BlockSpec, ConvSpec, HeadSpec, LinearSpec, NetworkSpec,
    DEFAULT_ALPHA_INV,
};

/// Build an MLP spec: hidden layer widths, input dim, classes.
pub fn mlp(name: &str, dims: &[usize], input_dim: usize,
           num_classes: usize) -> NetworkSpec {
    let mut blocks = Vec::new();
    let mut prev = input_dim;
    for &d in dims {
        blocks.push(BlockSpec::Linear(LinearSpec {
            in_features: prev,
            out_features: d,
            alpha_inv: DEFAULT_ALPHA_INV,
            num_classes,
        }));
        prev = d;
    }
    NetworkSpec {
        name: name.to_string(),
        input_shape: vec![input_dim],
        blocks,
        head: HeadSpec { in_features: prev, num_classes },
        num_classes,
        bits: BitsPlan::default(),
    }
}

/// CNN plan entry: `('C', n)` conv block, `('CP', n)` conv block + 2x2
/// maxpool, `('L', n)` linear block.
#[derive(Clone, Copy)]
pub enum Plan {
    C(usize),
    Cp(usize),
    L(usize),
}

pub fn cnn(name: &str, plan: &[Plan], in_shape: (usize, usize, usize),
           num_classes: usize, d_lr: usize) -> NetworkSpec {
    let (mut c, mut h, mut w) = in_shape;
    let mut blocks = Vec::new();
    for &p in plan {
        match p {
            Plan::C(n) | Plan::Cp(n) => {
                let pool = matches!(p, Plan::Cp(_));
                let blk = ConvSpec {
                    in_channels: c,
                    out_channels: n,
                    in_h: h,
                    in_w: w,
                    kernel: 3,
                    padding: 1,
                    pool,
                    alpha_inv: DEFAULT_ALPHA_INV,
                    d_lr,
                    num_classes,
                };
                h = blk.out_h();
                w = blk.out_w();
                c = n;
                blocks.push(BlockSpec::Conv(blk));
            }
            Plan::L(n) => {
                blocks.push(BlockSpec::Linear(LinearSpec {
                    in_features: c * h * w,
                    out_features: n,
                    alpha_inv: DEFAULT_ALPHA_INV,
                    num_classes,
                }));
                c = n;
                h = 1;
                w = 1;
            }
        }
    }
    NetworkSpec {
        name: name.to_string(),
        input_shape: vec![in_shape.0, in_shape.1, in_shape.2],
        blocks,
        head: HeadSpec { in_features: c * h * w, num_classes },
        num_classes,
        bits: BitsPlan::default(),
    }
}

/// Look up a named preset. `None` for unknown names.
pub fn get(name: &str) -> Option<NetworkSpec> {
    use Plan::*;
    Some(match name {
        // ---- paper App. C, exact --------------------------------------
        "mlp1" => mlp("mlp1", &[100, 50], 784, 10),
        "mlp2" => mlp("mlp2", &[200, 100, 50], 784, 10),
        "mlp3" => mlp("mlp3", &[1024, 1024, 1024], 784, 10),
        "mlp4" => mlp("mlp4", &[3000, 3000, 3000], 3072, 10),
        "vgg8b" => cnn(
            "vgg8b",
            &[C(128), Cp(256), C(256), Cp(512), Cp(512), Cp(512), L(1024)],
            (3, 32, 32),
            10,
            4096,
        ),
        "vgg8b-mnist" => cnn(
            "vgg8b-mnist",
            &[C(128), Cp(256), C(256), Cp(512), Cp(512), Cp(512), L(1024)],
            (1, 28, 28),
            10,
            4096,
        ),
        "vgg11b" => cnn(
            "vgg11b",
            &[C(128), C(128), C(128), Cp(256), C(256), Cp(512), C(512),
              Cp(512), Cp(512), L(1024)],
            (3, 32, 32),
            10,
            4096,
        ),
        // ---- CPU-budget presets (DESIGN.md §Substitutions) -------------
        "tinycnn" => cnn("tinycnn", &[Cp(8), Cp(16), L(32)], (1, 8, 8), 10, 64),
        "mlp1-mini" => mlp("mlp1-mini", &[32, 16], 64, 10),
        "vgg8b-narrow" => cnn(
            "vgg8b-narrow",
            &[C(32), Cp(64), C(64), Cp(128), Cp(128), Cp(128), L(256)],
            (3, 32, 32),
            10,
            1024,
        ),
        "vgg8b-narrow-mnist" => cnn(
            "vgg8b-narrow-mnist",
            &[C(32), Cp(64), C(64), Cp(128), Cp(128), Cp(128), L(256)],
            (1, 28, 28),
            10,
            1024,
        ),
        "vgg11b-narrow" => cnn(
            "vgg11b-narrow",
            &[C(32), C(32), C(32), Cp(64), C(64), Cp(128), C(128), Cp(128),
              Cp(128), L(256)],
            (3, 32, 32),
            10,
            1024,
        ),
        "mlp3-narrow" => mlp("mlp3-narrow", &[256, 256, 256], 784, 10),
        "mlp4-narrow" => mlp("mlp4-narrow", &[512, 512, 512], 3072, 10),
        // micro presets: width/16 — single-core CPU experiment budget
        "vgg8b-micro" => cnn(
            "vgg8b-micro",
            &[C(8), Cp(16), C(16), Cp(32), Cp(32), Cp(32), L(64)],
            (3, 32, 32),
            10,
            256,
        ),
        "vgg8b-micro-mnist" => cnn(
            "vgg8b-micro-mnist",
            &[C(8), Cp(16), C(16), Cp(32), Cp(32), Cp(32), L(64)],
            (1, 28, 28),
            10,
            256,
        ),
        "vgg11b-micro" => cnn(
            "vgg11b-micro",
            &[C(8), C(8), C(8), Cp(16), C(16), Cp(32), C(32), Cp(32),
              Cp(32), L(64)],
            (3, 32, 32),
            10,
            256,
        ),
        _ => return None,
    })
}

/// Every preset name (for CLI help / sweeps).
pub fn names() -> &'static [&'static str] {
    &[
        "mlp1", "mlp2", "mlp3", "mlp4", "vgg8b", "vgg8b-mnist", "vgg11b",
        "tinycnn", "mlp1-mini", "vgg8b-narrow", "vgg8b-narrow-mnist",
        "vgg11b-narrow", "mlp3-narrow", "mlp4-narrow", "vgg8b-micro",
        "vgg8b-micro-mnist", "vgg11b-micro",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_names_resolve() {
        for n in names() {
            let spec = get(n).unwrap_or_else(|| panic!("missing {n}"));
            assert!(!spec.blocks.is_empty());
            assert_eq!(spec.num_classes, 10);
        }
        assert!(get("nope").is_none());
    }

    #[test]
    fn paper_mlp_shapes() {
        let m1 = get("mlp1").unwrap();
        assert_eq!(m1.blocks.len(), 2);
        assert_eq!(m1.head.in_features, 50);
        let m4 = get("mlp4").unwrap();
        assert_eq!(m4.input_shape, vec![3072]); // CIFAR-10 flattened
        match &m4.blocks[0] {
            BlockSpec::Linear(l) => assert_eq!(l.in_features, 3072),
            _ => panic!(),
        }
    }

    #[test]
    fn vgg_block_counts_match_paper_table5() {
        // VGG8B: 6 conv + 1 linear blocks + head = 8 trainable layers
        let v8 = get("vgg8b").unwrap();
        assert_eq!(v8.blocks.len(), 7);
        // VGG11B: 9 conv + 1 linear blocks + head = 11 trainable layers
        let v11 = get("vgg11b").unwrap();
        assert_eq!(v11.blocks.len(), 10);
        let convs = v11
            .blocks
            .iter()
            .filter(|b| matches!(b, BlockSpec::Conv(_)))
            .count();
        assert_eq!(convs, 9);
    }

    #[test]
    fn vgg8b_spatial_chain() {
        let v8 = get("vgg8b").unwrap();
        // 32 -> (pool) 16 -> 16 -> (pool) 8 -> (pool) 4 -> (pool) 2
        let hs: Vec<usize> = v8
            .blocks
            .iter()
            .filter_map(|b| match b {
                BlockSpec::Conv(c) => Some(c.out_h()),
                _ => None,
            })
            .collect();
        assert_eq!(hs, vec![32, 16, 16, 8, 4, 2]);
        match &v8.blocks[6] {
            BlockSpec::Linear(l) => assert_eq!(l.in_features, 512 * 4),
            _ => panic!(),
        }
    }

    #[test]
    fn mnist_variant_spatial_chain() {
        let v8 = get("vgg8b-mnist").unwrap();
        // 28 -> 14 -> 14 -> 7 -> 3 -> 1
        let hs: Vec<usize> = v8
            .blocks
            .iter()
            .filter_map(|b| match b {
                BlockSpec::Conv(c) => Some(c.out_h()),
                _ => None,
            })
            .collect();
        assert_eq!(hs, vec![28, 14, 14, 7, 3, 1]);
    }
}
