//! NITRO-D network components (paper §3.2): integer local-loss blocks,
//! output head, integer Kaiming init, and the model zoo.

pub mod block;
pub mod init;
pub mod probe;
pub mod spec;
pub mod zoo;

pub use block::{Block, BlockCache, BlockGrads, DropoutRngs, Head, Hyper,
                InferScratch, Network, StepReport};
pub use spec::{BlockSpec, ConvSpec, HeadSpec, LinearSpec, NetworkSpec};
