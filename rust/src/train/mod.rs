//! LES training orchestration: epoch loop, evaluation, metrics recording,
//! LR plateau scheduling, weight-magnitude probes (Fig. 3 / App. E.3) and
//! checkpointing.

pub mod checkpoint;

use crate::data::{Batcher, Dataset};
use crate::nn::{Hyper, Network};
use crate::optim::PlateauScheduler;
use crate::util::rng::Pcg32;

/// Training configuration (paper App. D defaults where applicable).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub batch: usize,
    pub hyper: Hyper,
    pub seed: u64,
    /// Evaluate every `eval_every` epochs (plateau scheduler input).
    pub eval_every: usize,
    pub plateau_patience: usize,
    /// Plateau reductions are suppressed for this many epochs: the integer
    /// bootstrap phase is flat by construction (see EXPERIMENTS.md).
    pub plateau_warmup: usize,
    /// Run block backward passes on worker threads (L3 scheduler).
    pub parallel_blocks: bool,
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 10,
            batch: 64,
            hyper: Hyper::default(),
            seed: 42,
            eval_every: 1,
            plateau_patience: 10,
            plateau_warmup: 40,
            parallel_blocks: true,
            verbose: false,
        }
    }
}

/// Per-epoch record for EXPERIMENTS.md and the figure harnesses.
#[derive(Clone, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub mean_head_loss: f64,
    pub mean_block_loss: Vec<f64>,
    pub train_acc: f64,
    pub test_acc: f64,
    pub gamma_inv: i64,
    pub secs: f64,
}

/// Receives every completed epoch record during [`fit_observed`]. This is
/// the shared collection point of the experiment harness: the trainer
/// streams metrics out without knowing anything about output formats, and
/// the spec runner (`coordinator::runner`) turns them into schema-stable
/// JSON records.
pub trait MetricSink {
    fn on_epoch(&mut self, rec: &EpochRecord);
}

/// Sink that drops everything — what plain [`fit`] uses.
pub struct NullSink;

impl MetricSink for NullSink {
    fn on_epoch(&mut self, _rec: &EpochRecord) {}
}

/// Weight-magnitude probe (Fig. 3): per-weight-tensor abs-value quartiles
/// and bit-width.
#[derive(Clone, Debug)]
pub struct WeightStats {
    pub name: String,
    pub mean_abs: f64,
    pub q50: i32,
    pub q90: i32,
    pub max_abs: i32,
    pub bitwidth: u32,
}

pub struct TrainResult {
    pub epochs: Vec<EpochRecord>,
    pub final_test_acc: f64,
    pub weight_stats: Vec<WeightStats>,
    /// Peak |activation| / |gradient-side| bit-width seen (App. E.3 int32
    /// claim is about these).
    pub diverged: bool,
}

/// Train `net` on `train`, evaluating on `test`. The single entry point
/// used by every experiment driver.
pub fn fit(net: &mut Network, train: &Dataset, test: &Dataset,
           cfg: &TrainConfig) -> TrainResult {
    fit_observed(net, train, test, cfg, &mut NullSink)
}

/// [`fit`] with a [`MetricSink`] that observes every epoch as it
/// completes.
pub fn fit_observed(net: &mut Network, train: &Dataset, test: &Dataset,
                    cfg: &TrainConfig, sink: &mut dyn MetricSink)
                    -> TrainResult {
    let flatten = net.spec.input_shape.len() == 1;
    let mut rng = Pcg32::with_stream(cfg.seed, 0x74726169);
    // NITRO_WORKERS=1 needs no handling here: train_batch_parallel itself
    // falls back to sequential order in deterministic single-thread mode.
    let mut sched = PlateauScheduler::new(cfg.hyper.gamma_inv,
                                          cfg.plateau_patience);
    sched.warmup = cfg.plateau_warmup;
    let mut epochs = Vec::new();
    let mut diverged = false;
    'outer: for epoch in 0..cfg.epochs {
        let t0 = std::time::Instant::now();
        let hp = Hyper { gamma_inv: sched.gamma_inv, ..cfg.hyper };
        let mut head_loss = 0f64;
        let mut block_loss: Vec<f64> = Vec::new();
        let mut correct = 0usize;
        let mut seen = 0usize;
        let mut batches = 0usize;
        for (x, labels) in Batcher::new(train, cfg.batch, flatten, &mut rng) {
            let rep = if cfg.parallel_blocks {
                net.train_batch_parallel(&x, &labels, &hp, &mut rng)
            } else {
                net.train_batch(&x, &labels, &hp, &mut rng)
            };
            if block_loss.is_empty() {
                block_loss = vec![0.0; rep.block_loss.len()];
            }
            for (acc, &l) in block_loss.iter_mut().zip(&rep.block_loss) {
                *acc += l as f64;
            }
            head_loss += rep.head_loss as f64;
            correct += rep.correct;
            seen += labels.len();
            batches += 1;
            // divergence guard (App. E.1 "(unstable)" rows): weights blowing
            // past int16 by orders of magnitude means the run is dead.
            if rep.head_loss.abs() > 1 << 40 {
                diverged = true;
            }
        }
        let train_acc = correct as f64 / seen.max(1) as f64;
        let test_acc = if epoch % cfg.eval_every == 0 || epoch + 1 == cfg.epochs
        {
            evaluate(net, test, cfg.batch)
        } else {
            f64::NAN
        };
        if !test_acc.is_nan() {
            sched.step(test_acc);
        }
        let rec = EpochRecord {
            epoch,
            mean_head_loss: head_loss / batches.max(1) as f64,
            mean_block_loss: block_loss
                .iter()
                .map(|&l| l / batches.max(1) as f64)
                .collect(),
            train_acc,
            test_acc,
            gamma_inv: sched.gamma_inv,
            secs: t0.elapsed().as_secs_f64(),
        };
        if cfg.verbose {
            eprintln!(
                "[epoch {:>3}] head_loss {:>12.1} train_acc {:.4} test_acc {} \
                 gamma_inv {} ({:.2}s)",
                rec.epoch,
                rec.mean_head_loss,
                rec.train_acc,
                if rec.test_acc.is_nan() {
                    "   -  ".to_string()
                } else {
                    format!("{:.4}", rec.test_acc)
                },
                rec.gamma_inv,
                rec.secs
            );
        }
        sink.on_epoch(&rec);
        epochs.push(rec);
        if diverged {
            break 'outer;
        }
    }
    let final_test_acc = evaluate(net, test, cfg.batch);
    let weight_stats = weight_stats(net);
    TrainResult { epochs, final_test_acc, weight_stats, diverged }
}

/// Accuracy over a dataset.
pub fn evaluate(net: &Network, ds: &Dataset, batch: usize) -> f64 {
    let flatten = net.spec.input_shape.len() == 1;
    let mut correct = 0usize;
    for (x, labels) in Batcher::sequential(ds, batch, flatten) {
        correct += net.eval_batch(&x, &labels);
    }
    correct as f64 / ds.len().max(1) as f64
}

/// Fig. 3 probe: abs-value distribution per weight tensor.
pub fn weight_stats(net: &Network) -> Vec<WeightStats> {
    let mut out = Vec::new();
    for (i, blk) in net.blocks.iter().enumerate() {
        out.push(stats_for(&format!("block{i}.wf"), &blk.wf));
        out.push(stats_for(&format!("block{i}.wl"), &blk.wl));
    }
    out.push(stats_for("head.wo", &net.head.wo));
    out
}

fn stats_for(name: &str, w: &crate::tensor::ITensor) -> WeightStats {
    let mut abs: Vec<i32> = w.data.iter().map(|&v| v.saturating_abs()).collect();
    abs.sort_unstable();
    let q = |p: f64| abs[((abs.len() - 1) as f64 * p) as usize];
    WeightStats {
        name: name.to_string(),
        mean_abs: w.mean_abs(),
        q50: q(0.5),
        q90: q(0.9),
        max_abs: *abs.last().unwrap_or(&0),
        bitwidth: w.bitwidth(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::nn::zoo;

    #[test]
    fn fit_learns_tiny_dataset() {
        // NITRO-D has a long integer bootstrap phase (the scaling layers
        // truncate everything until the weights grow ~100x from init), so
        // even the tiny preset needs ~100 epochs — they take ~0.01s each.
        let ds = synthetic::by_name("tiny", 1000, 1).unwrap();
        let (mut tr, te) = ds.split_test(200);
        tr.mad_normalize();
        let mut te = te;
        te.mad_normalize();
        let mut net = Network::new(zoo::get("tinycnn").unwrap(), 2);
        let cfg = TrainConfig {
            epochs: 140,
            batch: 64,
            hyper: Hyper { gamma_inv: 512, eta_fw_inv: 12000, eta_lr_inv: 3000 },
            ..Default::default()
        };
        let res = fit(&mut net, &tr, &te, &cfg);
        assert!(!res.diverged);
        assert!(
            res.final_test_acc > 0.5,
            "tinycnn should beat 10-class chance by 5x: {}",
            res.final_test_acc
        );
        // loss decreased
        let first = res.epochs.first().unwrap().mean_head_loss;
        let last = res.epochs.last().unwrap().mean_head_loss;
        assert!(last < first, "{first} -> {last}");
        // weight probes present for 3 blocks + head
        assert_eq!(res.weight_stats.len(), 7);
    }

    #[test]
    fn fit_observed_streams_every_epoch() {
        struct Count(usize);
        impl MetricSink for Count {
            fn on_epoch(&mut self, rec: &EpochRecord) {
                assert_eq!(rec.epoch, self.0);
                self.0 += 1;
            }
        }
        let ds = synthetic::by_name("tiny", 120, 5).unwrap();
        let (mut tr, mut te) = ds.split_test(40);
        tr.mad_normalize();
        te.mad_normalize();
        let mut net = Network::new(zoo::get("tinycnn").unwrap(), 2);
        let cfg = TrainConfig { epochs: 3, batch: 32, ..Default::default() };
        let mut sink = Count(0);
        let res = fit_observed(&mut net, &tr, &te, &cfg, &mut sink);
        assert_eq!(sink.0, 3);
        assert_eq!(res.epochs.len(), 3);
    }

    #[test]
    fn evaluate_is_deterministic() {
        let ds = synthetic::by_name("tiny", 100, 3).unwrap();
        let net = Network::new(zoo::get("tinycnn").unwrap(), 4);
        let a = evaluate(&net, &ds, 32);
        let b = evaluate(&net, &ds, 16); // batch size must not matter
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn weight_stats_bitwidths_start_small() {
        let net = Network::new(zoo::get("tinycnn").unwrap(), 4);
        for s in weight_stats(&net) {
            assert!(s.bitwidth <= 8, "{s:?}"); // Kaiming bounds are tiny
            assert!(s.max_abs >= s.q90 && s.q90 >= s.q50);
        }
    }
}
