//! LES training orchestration: epoch loop, scheduler selection
//! (sequential / block-parallel / cross-batch pipelined), deterministic
//! data-parallel replication ([`replica`], `TrainConfig::replicas`),
//! evaluation, metrics recording, LR plateau scheduling,
//! weight-magnitude probes (Fig. 3 / App. E.3) and checkpointing.

pub mod checkpoint;
pub mod dist;
pub mod framing;
pub mod pipeline;
pub mod replica;

use crate::data::{Batcher, Dataset};
use crate::nn::{DropoutRngs, Hyper, Network, StepReport};
use crate::optim::PlateauScheduler;
use crate::tensor::ITensor;
use crate::util::{par, rng::Pcg32};

/// LES training scheduler. All three produce **bit-identical** weights,
/// losses and accuracies for a given seed (enforced by property tests and
/// `nitro bench-kernels`); they differ only in how block work is laid out
/// over threads. The pipeline engages only when the `NITRO_WORKERS`
/// budget covers one thread per stage (`blocks + 1`), degrading to
/// block-parallel below that; under `NITRO_WORKERS=1` both parallel
/// schedulers fall back to sequential order and no thread is ever
/// spawned.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Scheduler {
    /// Reference order: block 0..L then the head, one batch at a time, on
    /// the calling thread.
    Sequential,
    /// Within one batch: forwards in block order, then every block
    /// backward + the head step fan out on the persistent worker pool.
    BlockParallel,
    /// Across batches: persistent per-block stage workers; block `l`
    /// trains on batch `t` while block `l+1` is still on batch `t-1`
    /// (see [`pipeline`]).
    #[default]
    Pipelined,
}

impl Scheduler {
    pub fn parse(s: &str) -> Result<Scheduler, String> {
        Ok(match s {
            "sequential" => Scheduler::Sequential,
            "block-parallel" => Scheduler::BlockParallel,
            "pipelined" => Scheduler::Pipelined,
            other => {
                return Err(format!(
                    "unknown scheduler '{other}' \
                     (sequential|block-parallel|pipelined)"
                ))
            }
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Scheduler::Sequential => "sequential",
            Scheduler::BlockParallel => "block-parallel",
            Scheduler::Pipelined => "pipelined",
        }
    }
}

/// Training configuration (paper App. D defaults where applicable).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub batch: usize,
    pub hyper: Hyper,
    pub seed: u64,
    /// Evaluate every `eval_every` epochs (plateau scheduler input).
    pub eval_every: usize,
    pub plateau_patience: usize,
    /// Plateau reductions are suppressed for this many epochs: the integer
    /// bootstrap phase is flat by construction (see EXPERIMENTS.md).
    pub plateau_warmup: usize,
    /// How block work is scheduled over threads (bit-identical results).
    pub scheduler: Scheduler,
    /// Data-parallel replica count (≥ 1). Each global batch splits into
    /// `replicas` disjoint contiguous shards; per-replica i64 gradients
    /// combine through a fixed-order integer all-reduce before one
    /// IntegerSGD step is applied to every replica — **bit-identical**
    /// to `replicas = 1` on the same global batches, under every
    /// scheduler and with dropout enabled (see [`replica`]).
    pub replicas: usize,
    /// |head loss| above this marks the run divergent (App. E.1
    /// "(unstable)" rows); the epoch completes, then training stops.
    pub divergence_guard: i64,
    pub verbose: bool,
    /// Resume from a checkpointed [`checkpoint::TrainState`]: training
    /// starts at `resume.epoch` with the plateau scheduler restored, and
    /// the shuffle/dropout RNG streams are deterministically
    /// fast-forwarded through the completed epochs — so {train k epochs,
    /// crash, resume, finish} is **byte-identical** to an uninterrupted
    /// run (the caller loads the checkpoint's weights first).
    pub resume: Option<checkpoint::TrainState>,
    /// Crash-safe periodic checkpointing: every `checkpoint_every`
    /// epochs the weights plus the training state are atomically written
    /// here (fsynced file and directory). `None` / `0` disables.
    pub checkpoint_path: Option<String>,
    pub checkpoint_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 10,
            batch: 64,
            hyper: Hyper::default(),
            seed: 42,
            eval_every: 1,
            plateau_patience: 10,
            plateau_warmup: 40,
            scheduler: Scheduler::default(),
            replicas: 1,
            divergence_guard: 1 << 40,
            verbose: false,
            resume: None,
            checkpoint_path: None,
            checkpoint_every: 0,
        }
    }
}

/// Per-epoch record for EXPERIMENTS.md and the figure harnesses.
#[derive(Clone, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub mean_head_loss: f64,
    pub mean_block_loss: Vec<f64>,
    pub train_acc: f64,
    pub test_acc: f64,
    pub gamma_inv: i64,
    pub secs: f64,
}

/// Receives every completed epoch record during [`fit_observed`]. This is
/// the shared collection point of the experiment harness: the trainer
/// streams metrics out without knowing anything about output formats, and
/// the spec runner (`coordinator::runner`) turns them into schema-stable
/// JSON records.
pub trait MetricSink {
    fn on_epoch(&mut self, rec: &EpochRecord);
}

/// Sink that drops everything — what plain [`fit`] uses.
pub struct NullSink;

impl MetricSink for NullSink {
    fn on_epoch(&mut self, _rec: &EpochRecord) {}
}

/// Weight-magnitude probe (Fig. 3): per-weight-tensor abs-value quartiles
/// and bit-width.
#[derive(Clone, Debug)]
pub struct WeightStats {
    pub name: String,
    pub mean_abs: f64,
    pub q50: i32,
    pub q90: i32,
    pub max_abs: i32,
    pub bitwidth: u32,
}

pub struct TrainResult {
    pub epochs: Vec<EpochRecord>,
    pub final_test_acc: f64,
    pub weight_stats: Vec<WeightStats>,
    /// Peak |activation| / |gradient-side| bit-width seen (App. E.3 int32
    /// claim is about these).
    pub diverged: bool,
    /// An injected crash terminated a distributed rank mid-run
    /// ([`dist::DistTrainer::step`] returned `None`); the partial-epoch
    /// work is discarded and `epochs` ends at the last completed epoch.
    pub interrupted: bool,
}

/// Train `net` on `train`, evaluating on `test`. The single entry point
/// used by every experiment driver.
pub fn fit(net: &mut Network, train: &Dataset, test: &Dataset,
           cfg: &TrainConfig) -> TrainResult {
    fit_observed(net, train, test, cfg, &mut NullSink)
}

/// Per-epoch metric accumulator shared by the inline and pipelined paths
/// (pipelined reports arrive with a lag, so accumulation is decoupled from
/// the feeding loop).
#[derive(Default)]
struct EpochAgg {
    head_loss: f64,
    block_loss: Vec<f64>,
    correct: usize,
    seen: usize,
    batches: usize,
    diverged: bool,
}

impl EpochAgg {
    fn add(&mut self, rep: &StepReport, guard: i64) {
        if self.block_loss.is_empty() {
            self.block_loss = vec![0.0; rep.block_loss.len()];
        }
        for (acc, &l) in self.block_loss.iter_mut().zip(&rep.block_loss) {
            *acc += l as f64;
        }
        self.head_loss += rep.head_loss as f64;
        self.correct += rep.correct;
        self.batches += 1;
        // divergence guard (App. E.1 "(unstable)" rows): weights blowing
        // past int16 by orders of magnitude means the run is dead.
        if rep.head_loss.abs() > guard {
            self.diverged = true;
        }
    }
}

/// [`fit`] with a [`MetricSink`] that observes every epoch as it
/// completes.
pub fn fit_observed(net: &mut Network, train: &Dataset, test: &Dataset,
                    cfg: &TrainConfig, sink: &mut dyn MetricSink)
                    -> TrainResult {
    fit_inner(net, train, test, cfg, None, sink)
}

/// [`fit_observed`] with every gradient step running through one rank of
/// a distributed group ([`dist::DistTrainer`]): same epoch loop, same
/// metrics, same checkpoint/resume semantics — the step itself is the
/// TCP integer all-reduce, byte-identical to `replicas = world`
/// single-process training on the same global batches.
pub fn fit_dist(net: &mut Network, train: &Dataset, test: &Dataset,
                cfg: &TrainConfig, dt: &mut dist::DistTrainer,
                sink: &mut dyn MetricSink) -> TrainResult {
    fit_inner(net, train, test, cfg, Some(dt), sink)
}

fn fit_inner(net: &mut Network, train: &Dataset, test: &Dataset,
             cfg: &TrainConfig, mut dist: Option<&mut dist::DistTrainer>,
             sink: &mut dyn MetricSink) -> TrainResult {
    let flatten = net.spec.input_shape.len() == 1;
    let mut rng = Pcg32::with_stream(cfg.seed, 0x74726169);
    // Per-block dropout streams: mask draws depend only on (seed, block,
    // batch ordinal), never on the scheduler. The batch-shuffle stream
    // above is likewise scheduler-independent.
    let mut drop = DropoutRngs::new(cfg.seed, net.blocks.len());
    let mut sched = PlateauScheduler::new(cfg.hyper.gamma_inv,
                                          cfg.plateau_patience);
    sched.warmup = cfg.plateau_warmup;
    // Resume: restore the plateau scheduler (its state depends on eval
    // accuracies, which cannot be replayed without compute — hence it is
    // persisted), then deterministically fast-forward the RNG streams
    // through the completed epochs: one batch shuffle per epoch (drawn
    // in the Batcher constructor) and `ds.len() × out` dropout elements
    // per enabled block per epoch (the per-epoch draw count is
    // independent of the batch split). Epoch `start_epoch` then sees
    // exactly the state the uninterrupted run would have, making
    // {crash, reload checkpoint, finish} byte-identical to never
    // crashing.
    let start_epoch = match &cfg.resume {
        Some(st) => {
            sched.restore(&st.plateau);
            st.epoch.min(cfg.epochs)
        }
        None => 0,
    };
    if start_epoch > 0 {
        let out_per_sample = replica::probe_out_sizes(net);
        for _ in 0..start_epoch {
            let _ = Batcher::new(train, cfg.batch, flatten, &mut rng);
            for (l, blk) in net.blocks.iter().enumerate() {
                if blk.drop_p256 > 0 {
                    let r = drop.stream(l);
                    for _ in 0..train.len() * out_per_sample[l] {
                        r.below(256);
                    }
                }
            }
        }
    }
    // A distributed rank's step counter is the global batch ordinal from
    // epoch 0, so a resumed rank lines its frames up with the group.
    if let Some(dt) = &mut dist {
        dt.set_start_step(
            (start_epoch * train.len().div_ceil(cfg.batch.max(1))) as u64,
        );
    }
    // The pipelined scheduler engages only when the worker budget covers
    // one thread per stage (blocks + head) — the stage threads ARE the
    // budget. Smaller budgets degrade to the block-parallel scheduler
    // (which clamps its pool fan-out to the budget), and budget 1 runs
    // the sequential path inline with no thread ever spawned. All paths
    // are bit-identical, so the degradation is a resource policy only.
    // A resumed run and a distributed rank both stay off the pipeline:
    // the resume fast-forward advances this function's dropout streams
    // (not the stage workers'), and a distributed step is a per-batch
    // barrier the pipeline cannot cross. Both fall back to paths that
    // are bit-identical anyway.
    let nstages = net.blocks.len() + 1;
    let replicas = cfg.replicas.max(1);
    let mut pipe = (replicas == 1
        && dist.is_none()
        && start_epoch == 0
        && cfg.scheduler == Scheduler::Pipelined
        && !net.blocks.is_empty()
        && par::current_workers() >= nstages)
    .then(|| pipeline::Pipeline::start(&mut *net, cfg.seed));
    // Data-parallel replication (replicas > 1): per-global-batch shard →
    // all-reduce → one step (see `replica`). The reduce barrier is per
    // batch, which cross-batch pipelining cannot cross, so the replicas
    // themselves become the outer parallel axis: both parallel schedulers
    // fan the shards out on the worker pool under the shared
    // NITRO_WORKERS budget (each shard scopes its kernels to
    // budget/replicas — the pipeline's budget-sharing policy), while the
    // sequential scheduler runs them inline with no thread ever spawned.
    // Every combination is bit-identical to replicas = 1.
    let mut repl = (replicas > 1 && dist.is_none()).then(|| {
        replica::ReplicaTrainer::new(net, replicas,
                                     cfg.scheduler != Scheduler::Sequential)
    });
    let mut epochs = Vec::new();
    let mut diverged = false;
    let mut interrupted = false;
    // Batch buffers reused across every iteration of every epoch — the
    // steady state performs no per-batch gather allocation. In pipelined
    // mode the input tensors recycle through the stage-0 return channel.
    let mut xbuf = ITensor::empty();
    let mut labels: Vec<usize> = Vec::new();
    let mut reports: Vec<StepReport> = Vec::new();
    'outer: for epoch in start_epoch..cfg.epochs {
        let t0 = std::time::Instant::now();
        let hp = Hyper { gamma_inv: sched.gamma_inv, ..cfg.hyper };
        let mut agg = EpochAgg::default();
        let mut batcher = Batcher::new(train, cfg.batch, flatten, &mut rng);
        if let Some(dt) = &mut dist {
            while batcher.next_into(&mut xbuf, &mut labels) {
                agg.seen += labels.len();
                match dt.step(net, &xbuf, &labels, &hp, &mut drop) {
                    Some(rep) => agg.add(&rep, cfg.divergence_guard),
                    None => {
                        // injected crash: this rank is dead — discard
                        // the partial epoch (the checkpoint cadence
                        // decides what survives, like a real crash)
                        interrupted = true;
                        break 'outer;
                    }
                }
            }
        } else if let Some(p) = &mut pipe {
            if !p.is_running() {
                p.resume(net);
            }
            while batcher.has_next() {
                let mut x = p.recycled();
                batcher.next_into(&mut x, &mut labels);
                agg.seen += labels.len();
                p.feed(x, &labels, &hp, &mut reports);
                for r in reports.drain(..) {
                    agg.add(&r, cfg.divergence_guard);
                }
            }
            // epoch barrier: drain the pipe and take the blocks back so
            // evaluation below sees the settled weights
            p.sync(net, &mut reports);
            for r in reports.drain(..) {
                agg.add(&r, cfg.divergence_guard);
            }
        } else if let Some(rt) = &mut repl {
            while batcher.next_into(&mut xbuf, &mut labels) {
                agg.seen += labels.len();
                let rep = rt.step(net, &xbuf, &labels, &hp, &mut drop);
                agg.add(&rep, cfg.divergence_guard);
            }
        } else {
            while batcher.next_into(&mut xbuf, &mut labels) {
                agg.seen += labels.len();
                let rep = match cfg.scheduler {
                    Scheduler::Sequential => {
                        net.train_batch(&xbuf, &labels, &hp, &mut drop)
                    }
                    _ => net.train_batch_parallel(&xbuf, &labels, &hp,
                                                  &mut drop),
                };
                agg.add(&rep, cfg.divergence_guard);
            }
        }
        diverged |= agg.diverged;
        let train_acc = agg.correct as f64 / agg.seen.max(1) as f64;
        let test_acc = if epoch % cfg.eval_every == 0 || epoch + 1 == cfg.epochs
        {
            evaluate(net, test, cfg.batch)
        } else {
            f64::NAN
        };
        if !test_acc.is_nan() {
            sched.step(test_acc);
        }
        let rec = EpochRecord {
            epoch,
            mean_head_loss: agg.head_loss / agg.batches.max(1) as f64,
            mean_block_loss: agg
                .block_loss
                .iter()
                .map(|&l| l / agg.batches.max(1) as f64)
                .collect(),
            train_acc,
            test_acc,
            gamma_inv: sched.gamma_inv,
            secs: t0.elapsed().as_secs_f64(),
        };
        if cfg.verbose {
            eprintln!(
                "[epoch {:>3}] head_loss {:>12.1} train_acc {:.4} test_acc {} \
                 gamma_inv {} ({:.2}s)",
                rec.epoch,
                rec.mean_head_loss,
                rec.train_acc,
                if rec.test_acc.is_nan() {
                    "   -  ".to_string()
                } else {
                    format!("{:.4}", rec.test_acc)
                },
                rec.gamma_inv,
                rec.secs
            );
        }
        sink.on_epoch(&rec);
        epochs.push(rec);
        // Crash-safe periodic checkpoint: weights plus training state
        // (epochs completed, plateau scheduler), written atomically and
        // fsynced. A failed write is reported but never kills training —
        // the run is still correct, just less durable.
        if let Some(path) = &cfg.checkpoint_path {
            if cfg.checkpoint_every > 0
                && (epoch + 1) % cfg.checkpoint_every == 0
            {
                let st = checkpoint::TrainState {
                    epoch: epoch + 1,
                    plateau: sched.state(),
                };
                if let Err(e) = checkpoint::save_with_state(net, path, &st)
                {
                    eprintln!("checkpoint {path}: {e}");
                }
            }
        }
        if diverged {
            break 'outer;
        }
    }
    if let Some(p) = pipe {
        // every epoch ended with a sync, so the network is whole; this
        // just tells the parked stage workers to exit and joins them
        p.shutdown(net, &mut reports);
        debug_assert!(reports.is_empty());
    }
    // The last executed epoch always evaluated (eval-epoch or final-epoch
    // rule above), so reuse that measurement instead of re-running the
    // whole test set; evaluation is deterministic, so this is the same
    // number.
    let final_test_acc = match epochs.last() {
        Some(e) if !e.test_acc.is_nan() => e.test_acc,
        _ => evaluate(net, test, cfg.batch),
    };
    let weight_stats = weight_stats(net);
    TrainResult { epochs, final_test_acc, weight_stats, diverged,
                  interrupted }
}

/// Accuracy over a dataset.
pub fn evaluate(net: &Network, ds: &Dataset, batch: usize) -> f64 {
    let flatten = net.spec.input_shape.len() == 1;
    let mut correct = 0usize;
    for (x, labels) in Batcher::sequential(ds, batch, flatten) {
        correct += net.eval_batch(&x, &labels);
    }
    correct as f64 / ds.len().max(1) as f64
}

/// Fig. 3 probe: abs-value distribution per weight tensor. Quartiles come
/// from `select_nth_unstable` (O(n) per quantile instead of a full sort)
/// over one scratch buffer reused across all tensors.
pub fn weight_stats(net: &Network) -> Vec<WeightStats> {
    let mut scratch: Vec<i32> = Vec::new();
    let mut out = Vec::new();
    for (i, blk) in net.blocks.iter().enumerate() {
        out.push(stats_for(&format!("block{i}.wf"), &blk.wf, &mut scratch));
        out.push(stats_for(&format!("block{i}.wl"), &blk.wl, &mut scratch));
    }
    out.push(stats_for("head.wo", &net.head.wo, &mut scratch));
    out
}

fn stats_for(name: &str, w: &crate::tensor::ITensor, scratch: &mut Vec<i32>)
             -> WeightStats {
    scratch.clear();
    scratch.extend(w.data.iter().map(|&v| v.saturating_abs()));
    let mut q = |p: f64| -> i32 {
        if scratch.is_empty() {
            return 0;
        }
        let idx = ((scratch.len() - 1) as f64 * p) as usize;
        *scratch.select_nth_unstable(idx).1
    };
    let q50 = q(0.5);
    let q90 = q(0.9);
    WeightStats {
        name: name.to_string(),
        mean_abs: w.mean_abs(),
        q50,
        q90,
        max_abs: scratch.iter().copied().max().unwrap_or(0),
        bitwidth: w.bitwidth(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::nn::zoo;

    #[test]
    fn fit_learns_tiny_dataset() {
        // NITRO-D has a long integer bootstrap phase (the scaling layers
        // truncate everything until the weights grow ~100x from init), so
        // even the tiny preset needs ~100 epochs — they take ~0.01s each.
        let ds = synthetic::by_name("tiny", 1000, 1).unwrap();
        let (mut tr, te) = ds.split_test(200);
        tr.mad_normalize();
        let mut te = te;
        te.mad_normalize();
        let mut net = Network::new(zoo::get("tinycnn").unwrap(), 2);
        let cfg = TrainConfig {
            epochs: 140,
            batch: 64,
            hyper: Hyper { gamma_inv: 512, eta_fw_inv: 12000, eta_lr_inv: 3000 },
            ..Default::default()
        };
        let res = fit(&mut net, &tr, &te, &cfg);
        assert!(!res.diverged);
        assert!(
            res.final_test_acc > 0.5,
            "tinycnn should beat 10-class chance by 5x: {}",
            res.final_test_acc
        );
        // loss decreased
        let first = res.epochs.first().unwrap().mean_head_loss;
        let last = res.epochs.last().unwrap().mean_head_loss;
        assert!(last < first, "{first} -> {last}");
        // weight probes present for 3 blocks + head
        assert_eq!(res.weight_stats.len(), 7);
    }

    #[test]
    fn fit_observed_streams_every_epoch() {
        struct Count(usize);
        impl MetricSink for Count {
            fn on_epoch(&mut self, rec: &EpochRecord) {
                assert_eq!(rec.epoch, self.0);
                self.0 += 1;
            }
        }
        let ds = synthetic::by_name("tiny", 120, 5).unwrap();
        let (mut tr, mut te) = ds.split_test(40);
        tr.mad_normalize();
        te.mad_normalize();
        let mut net = Network::new(zoo::get("tinycnn").unwrap(), 2);
        let cfg = TrainConfig { epochs: 3, batch: 32, ..Default::default() };
        let mut sink = Count(0);
        let res = fit_observed(&mut net, &tr, &te, &cfg, &mut sink);
        assert_eq!(sink.0, 3);
        assert_eq!(res.epochs.len(), 3);
    }

    #[test]
    fn evaluate_is_deterministic() {
        let ds = synthetic::by_name("tiny", 100, 3).unwrap();
        let net = Network::new(zoo::get("tinycnn").unwrap(), 4);
        let a = evaluate(&net, &ds, 32);
        let b = evaluate(&net, &ds, 16); // batch size must not matter
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn scheduler_parse_roundtrip() {
        for s in [Scheduler::Sequential, Scheduler::BlockParallel,
                  Scheduler::Pipelined] {
            assert_eq!(Scheduler::parse(s.name()).unwrap(), s);
        }
        assert!(Scheduler::parse("turbo").is_err());
        assert_eq!(Scheduler::default(), Scheduler::Pipelined);
    }

    #[test]
    fn weight_stats_quantiles_match_full_sort() {
        let net = Network::new(zoo::get("tinycnn").unwrap(), 9);
        for (s, (_, w)) in weight_stats(&net).iter().zip(net.weights()) {
            let mut abs: Vec<i32> =
                w.data.iter().map(|&v| v.saturating_abs()).collect();
            abs.sort_unstable();
            let q = |p: f64| abs[((abs.len() - 1) as f64 * p) as usize];
            assert_eq!(s.q50, q(0.5), "{}", s.name);
            assert_eq!(s.q90, q(0.9), "{}", s.name);
            assert_eq!(s.max_abs, *abs.last().unwrap(), "{}", s.name);
        }
    }

    #[test]
    fn weight_stats_bitwidths_start_small() {
        let net = Network::new(zoo::get("tinycnn").unwrap(), 4);
        for s in weight_stats(&net) {
            assert!(s.bitwidth <= 8, "{s:?}"); // Kaiming bounds are tiny
            assert!(s.max_abs >= s.q90 && s.q90 >= s.q50);
        }
    }

    /// Crash-resume contract: {train 4 epochs with periodic
    /// checkpointing, reload the checkpoint into a fresh process, finish
    /// to 6} must be byte-identical to one uninterrupted 6-epoch run —
    /// per-epoch records and final weights — under every scheduler.
    /// The resumed leg of the pipelined run exercises the deliberate
    /// degradation to block-parallel (`start_epoch > 0` disables the
    /// pipeline because stage workers' dropout streams cannot be
    /// fast-forwarded), which must not change a single bit.
    #[test]
    fn checkpoint_resume_is_byte_identical_across_schedulers() {
        let _guard = par::scoped_thread_workers(6);
        let ds = synthetic::by_name("tiny", 160, 5).unwrap();
        let (mut tr, mut te) = ds.split_test(40);
        tr.mad_normalize();
        te.mad_normalize();
        let dir = std::env::temp_dir()
            .join(format!("nitro_resume_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for sched in [Scheduler::Sequential, Scheduler::BlockParallel,
                      Scheduler::Pipelined] {
            let base = TrainConfig {
                epochs: 6,
                batch: 32,
                scheduler: sched,
                // force plateau activity inside 6 epochs so the
                // persisted PlateauState actually matters
                plateau_warmup: 0,
                plateau_patience: 1,
                hyper: Hyper {
                    gamma_inv: 128,
                    eta_fw_inv: 12000,
                    eta_lr_inv: 3000,
                },
                ..Default::default()
            };
            // uninterrupted reference
            let mut net_ref = Network::new(zoo::get("tinycnn").unwrap(), 2);
            net_ref.set_dropout(0.25, 0.25);
            let res_ref = fit(&mut net_ref, &tr, &te, &base);
            // leg 1: same run, checkpointing every 2 epochs, killed at 4
            let path = dir
                .join(format!("ck_{}.nitro", sched.name()))
                .to_string_lossy()
                .into_owned();
            let cfg_a = TrainConfig {
                epochs: 4,
                checkpoint_path: Some(path.clone()),
                checkpoint_every: 2,
                ..base.clone()
            };
            let mut net_a = Network::new(zoo::get("tinycnn").unwrap(), 2);
            net_a.set_dropout(0.25, 0.25);
            fit(&mut net_a, &tr, &te, &cfg_a);
            // leg 2: a fresh "process" reloads weights + train state and
            // finishes the remaining epochs
            let mut net_b = Network::new(zoo::get("tinycnn").unwrap(), 2);
            net_b.set_dropout(0.25, 0.25);
            checkpoint::load(&mut net_b, &path).unwrap();
            let st = checkpoint::load_state(&path).unwrap().unwrap();
            assert_eq!(st.epoch, 4, "{}", sched.name());
            let cfg_b = TrainConfig { resume: Some(st), ..base.clone() };
            let res_b = fit(&mut net_b, &tr, &te, &cfg_b);
            assert_eq!(res_b.epochs.len(), 2, "{}", sched.name());
            for (a, b) in res_ref.epochs[4..].iter().zip(&res_b.epochs) {
                assert_eq!(a.epoch, b.epoch, "{}", sched.name());
                assert_eq!(a.mean_head_loss, b.mean_head_loss,
                           "{} epoch {}", sched.name(), a.epoch);
                assert_eq!(a.train_acc, b.train_acc, "{}", sched.name());
                assert_eq!(a.gamma_inv, b.gamma_inv, "{}", sched.name());
            }
            assert_eq!(res_ref.final_test_acc, res_b.final_test_acc,
                       "{}", sched.name());
            for ((na, wa), (_, wb)) in
                net_ref.weights().iter().zip(net_b.weights())
            {
                assert_eq!(wa.data, wb.data,
                           "{}: weight {na} diverged after resume",
                           sched.name());
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
