//! Deterministic data-parallel replicated training
//! (`TrainConfig::replicas`).
//!
//! `N` model replicas train on disjoint contiguous shards of each global
//! batch, accumulate per-layer gradients in i64, combine them through a
//! **fixed-order deterministic integer all-reduce**, and then every
//! replica applies the same single IntegerSGD step. Because integer
//! gradient accumulation widened to i64 is exactly associative (NITI,
//! WAGE), the reduced gradient equals the single-replica batch sum bit
//! for bit — so replicated training is **bit-identical** to
//! `replicas = 1` on the same global batches, a property float
//! frameworks cannot offer.
//!
//! ## Why this is bit-identical to `Network::train_batch`
//!
//! 1. **Gradients decompose over samples.** Every kernel in the backward
//!    pass is either per-sample (loss gradient, ReLU/pool backward) or a
//!    batch-row sum (`featᵀ·∇L`, conv weight grad). Summing per-shard i64
//!    sums in any order reproduces the full-batch sum exactly — i64
//!    addition is associative and commutative, and the all-reduce uses
//!    wrapping adds so the operation is total. The trainer nevertheless
//!    reduces in replica-index order, making determinism hold by
//!    construction rather than by argument.
//! 2. **Deferred updates are eager updates.** Within one batch no weight
//!    is read after its own update (block `l+1` consumes block `l`'s
//!    already-materialized output; `dfeat` uses the pre-step learning
//!    weights) — the same independence the block-parallel scheduler
//!    exploits — so "compute all gradients, then step once" equals the
//!    sequential eager order.
//! 3. **Dropout masks are position-indexed, not replica-indexed.** The
//!    trainer pre-draws each block's keep-mask for the *whole* global
//!    batch from the canonical per-block stream
//!    ([`DropoutRngs`], exactly the element order `forward_train` would
//!    draw) and hands every replica its shard's slice
//!    ([`crate::nn::Block::forward_train_masked`]). A mask element is a
//!    function of (seed, block, batch ordinal, sample position) — the
//!    replica count never enters.
//! 4. **Losses reduce raw.** Local RSS losses travel un-halved
//!    (`rss_loss_grad_raw`) and are halved once after the reduction;
//!    halving per shard first would drop odd bits.
//!
//! ## Scheduler and thread-budget integration
//!
//! The all-reduce is a *per-global-batch* barrier, which cross-batch
//! pipelining cannot cross — so with `replicas > 1` the replicas
//! themselves become the outer parallel axis. `Scheduler::Sequential`
//! runs the shards replica-by-replica inline (under `NITRO_WORKERS=1` no
//! thread is ever spawned); `Scheduler::BlockParallel` and
//! `Scheduler::Pipelined` fan the shards out on the persistent worker
//! pool (PR-2), each shard scoping its kernel budget to
//! `max(1, NITRO_WORKERS / replicas)` via
//! [`par::set_thread_workers`] — the same budget-sharing policy the
//! pipelined scheduler's stage workers use. All dispatch modes are
//! bit-identical; they differ only in thread layout.
//!
//! Weight broadcast is free: replicas start from one weight copy
//! ([`crate::nn::Network::replicate`]) and every replica applies the
//! identical all-reduced step, so they stay in lockstep without any
//! per-step weight transfer — the "broadcast" is the gradient, not the
//! weights. This is the stepping stone to multi-process sharding: the
//! [`GradSet`] is a first-class transferable value.

use crate::nn::block::count_correct;
use crate::nn::{DropoutRngs, Hyper, Network, StepReport};
use crate::tensor::{one_hot32, ITensor, LTensor};
use crate::util::par;

/// Per-network gradient set in `Network::weights()` order
/// (`wf_0, wl_0, …, wo`): the unit of the integer all-reduce and the
/// input of [`apply_step`].
pub struct GradSet {
    pub tensors: Vec<LTensor>,
}

impl GradSet {
    /// All-zero gradient set shaped like `net`'s weights — the reduction
    /// identity (property tests seed accumulators with it; an empty
    /// shard contributes exactly this).
    pub fn zeros_like(net: &Network) -> GradSet {
        GradSet {
            tensors: net
                .weights()
                .into_iter()
                .map(|(_, w)| LTensor::zeros(&w.shape))
                .collect(),
        }
    }
}

/// The i64 all-reduce core: `acc[i] = acc[i] ⊞ part[i]` element-wise in
/// wrapping arithmetic. Wrapping addition is associative *and*
/// commutative, so every reduction order produces the same bits — the
/// shard-order permutation invariance the property tests pin down.
pub fn add_wrapping(acc: &mut [i64], part: &[i64]) {
    assert_eq!(acc.len(), part.len(), "all-reduce length mismatch");
    for (a, &p) in acc.iter_mut().zip(part) {
        *a = a.wrapping_add(p);
    }
}

/// Fold one replica's gradient set into the accumulator — one rank of
/// the fixed-order all-reduce.
pub fn accumulate(acc: &mut GradSet, part: &GradSet) {
    assert_eq!(acc.tensors.len(), part.tensors.len(),
               "all-reduce arity mismatch");
    for (a, p) in acc.tensors.iter_mut().zip(&part.tensors) {
        assert_eq!(a.shape, p.shape, "all-reduce shape mismatch");
        add_wrapping(&mut a.data, &p.data);
    }
}

/// One IntegerSGD step from the all-reduced gradient set, with the same
/// per-role rate wiring as the in-place training paths
/// ([`crate::nn::Block::apply_grads`] / [`crate::nn::Head::apply_grad`]).
pub fn apply_step(net: &mut Network, grads: &GradSet, hp: &Hyper) {
    assert_eq!(grads.tensors.len(), 2 * net.blocks.len() + 1,
               "gradient set arity");
    let mut it = grads.tensors.iter();
    for blk in &mut net.blocks {
        let gw_f = it.next().expect("wf grad");
        let gw_l = it.next().expect("wl grad");
        blk.apply_grads(gw_f, gw_l, hp);
    }
    net.head.apply_grad(it.next().expect("head grad"), hp);
}

/// Contiguous, order-preserving shard bounds for a global batch of `b`
/// samples over `n` replicas: the first `b % n` shards carry one extra
/// sample. Shards may be empty when `b < n` (final partial batches) —
/// empty shards are skipped, contributing the reduction identity.
pub fn shard_bounds(b: usize, n: usize) -> Vec<(usize, usize)> {
    let n = n.max(1);
    let base = b / n;
    let rem = b % n;
    let mut out = Vec::with_capacity(n);
    let mut start = 0usize;
    for r in 0..n {
        let len = base + usize::from(r < rem);
        out.push((start, start + len));
        start += len;
    }
    out
}

/// Output elements **per sample** of every block, from one zero-sample
/// probe forward: activation shapes depend on the spec alone, never on
/// the weights, so the probe pins the dropout-mask geometry once.
pub(crate) fn probe_out_sizes(net: &Network) -> Vec<usize> {
    let mut shape = vec![1usize];
    shape.extend(&net.spec.input_shape);
    let mut a = ITensor::zeros(&shape);
    net.blocks
        .iter()
        .map(|b| {
            a = b.forward(&a);
            a.len()
        })
        .collect()
}

/// Shard slice of one block's pre-drawn keep-mask (`None` when the
/// block's dropout is off, signalled by an empty mask).
pub(crate) fn mask_slice(mask: &[bool], per_sample: usize, start: usize,
                         end: usize) -> Option<&[bool]> {
    if mask.is_empty() {
        None
    } else {
        Some(&mask[start * per_sample..end * per_sample])
    }
}

/// One replica's contribution for one global batch: shard losses,
/// accuracy count, and the exported gradient set.
pub(crate) struct ShardOut {
    pub(crate) block_loss_raw: Vec<i64>,
    pub(crate) head_loss_raw: i64,
    pub(crate) correct: usize,
    pub(crate) grads: GradSet,
}

/// Forward + backward over one shard, exporting gradients without
/// applying any update. Gradient tensors are moved straight out of the
/// backward kernels into the [`GradSet`] — no copy.
pub(crate) fn shard_grads(net: &mut Network, x: &ITensor, labels: &[usize],
                          num_classes: usize, masks: &[Vec<bool>],
                          out_per_sample: &[usize], start: usize)
                          -> ShardOut {
    let y32 = one_hot32(labels, num_classes);
    let end = start + labels.len();
    let nblocks = net.blocks.len();
    let mut caches = Vec::with_capacity(nblocks);
    for l in 0..nblocks {
        let m = mask_slice(&masks[l], out_per_sample[l], start, end);
        let cache = {
            let a_in = if l == 0 { x } else { &caches[l - 1].a_out };
            net.blocks[l].forward_train_masked(a_in, m)
        };
        caches.push(cache);
    }
    let mut tensors = Vec::with_capacity(2 * nblocks + 1);
    let mut block_loss_raw = Vec::with_capacity(nblocks);
    for (l, blk) in net.blocks.iter_mut().enumerate() {
        let a_in = if l == 0 { x } else { &caches[l - 1].a_out };
        let g = blk.backward_grads(a_in, &caches[l], &y32);
        block_loss_raw.push(g.loss_raw);
        tensors.push(g.gw_f);
        tensors.push(g.gw_l);
    }
    let a_last = caches.last().map(|c| &c.a_out).unwrap_or(x);
    let (yhat, head_loss_raw, gw_o) = net.head.grads(a_last, &y32);
    tensors.push(gw_o);
    ShardOut {
        block_loss_raw,
        head_loss_raw,
        correct: count_correct(&yhat, labels),
        grads: GradSet { tensors },
    }
}

/// Data-parallel replica trainer: owns replicas `1..n` (replica 0 is the
/// caller's network, so evaluation and checkpointing always see live
/// weights), the pre-drawn dropout masks, and the reused shard buffers.
pub struct ReplicaTrainer {
    extras: Vec<Network>,
    /// Shard compute fans out on the worker pool (BlockParallel /
    /// Pipelined schedulers) instead of running replica-by-replica
    /// inline. Bit-identical either way.
    parallel: bool,
    /// Per-block output elements per sample (dropout-mask geometry).
    out_per_sample: Vec<usize>,
    /// Per-block keep-masks for the current global batch (empty where
    /// the block's dropout is off). Buffers reused across batches.
    masks: Vec<Vec<bool>>,
    /// Per-replica shard input buffers, reused across batches.
    shard_x: Vec<ITensor>,
}

impl ReplicaTrainer {
    pub fn new(net: &Network, replicas: usize, parallel: bool)
               -> ReplicaTrainer {
        assert!(replicas >= 1, "replicas must be >= 1");
        ReplicaTrainer {
            extras: (1..replicas).map(|_| net.replicate()).collect(),
            parallel,
            out_per_sample: probe_out_sizes(net),
            masks: vec![Vec::new(); net.blocks.len()],
            shard_x: (0..replicas).map(|_| ITensor::empty()).collect(),
        }
    }

    pub fn replicas(&self) -> usize {
        self.extras.len() + 1
    }

    /// One replicated training step on a global batch: shard →
    /// per-replica gradient export → fixed-order integer all-reduce →
    /// the same IntegerSGD step applied to every replica. Bit-identical
    /// to [`Network::train_batch`] on the same batch (module docs).
    pub fn step(&mut self, net: &mut Network, x: &ITensor,
                labels: &[usize], hp: &Hyper, drop: &mut DropoutRngs)
                -> StepReport {
        let b = labels.len();
        debug_assert_eq!(x.shape[0], b, "batch/label mismatch");
        let n = self.replicas();
        let nblocks = net.blocks.len();
        // Pre-draw each block's keep-mask for the whole global batch from
        // the canonical per-block stream, in exactly the element order
        // forward_train would draw it; replicas read their shard's slice,
        // so masks are independent of the replica count.
        for (l, blk) in net.blocks.iter().enumerate() {
            let mask = &mut self.masks[l];
            mask.clear();
            if blk.drop_p256 > 0 {
                let p = blk.drop_p256;
                let rng = drop.stream(l);
                mask.extend(
                    (0..b * self.out_per_sample[l])
                        .map(|_| rng.below(256) >= p),
                );
            }
        }
        // Slice the global batch into per-replica shard tensors (reused
        // buffers; shards are contiguous row ranges, one memcpy each).
        let bounds = shard_bounds(b, n);
        let ss = x.len() / b.max(1);
        for (buf, &(s, e)) in self.shard_x.iter_mut().zip(&bounds) {
            buf.data.clear();
            buf.data.extend_from_slice(&x.data[s * ss..e * ss]);
            buf.shape.clear();
            buf.shape.push(e - s);
            buf.shape.extend(&x.shape[1..]);
        }
        let num_classes = net.spec.num_classes;
        let masks = &self.masks;
        let shard_x = &self.shard_x;
        let out_per_sample = &self.out_per_sample;
        let budget = par::current_workers();
        let fan_out = self.parallel && budget > 1 && n > 1;
        // PR-2 thread-budget scoping: concurrent shards share the one
        // NITRO_WORKERS budget, so each scopes its kernels to an even
        // split (an inline shard keeps the whole budget). The enclosing
        // override is restored even on panic — pool workers keep their
        // TLS across jobs.
        let shard_budget = if fan_out { (budget / n).max(1) } else { budget };
        let compute = |(r, netr): (usize, &mut Network)| {
            let (s, e) = bounds[r];
            if s == e {
                return None;
            }
            let _scope = par::scoped_thread_workers(shard_budget);
            Some(shard_grads(netr, &shard_x[r], &labels[s..e], num_classes,
                             masks, out_per_sample, s))
        };
        let mut tasks: Vec<(usize, &mut Network)> = Vec::with_capacity(n);
        tasks.push((0, &mut *net));
        for (i, e) in self.extras.iter_mut().enumerate() {
            tasks.push((i + 1, e));
        }
        let outs: Vec<Option<ShardOut>> = if fan_out {
            par::scoped_map(tasks, budget.min(n), compute)
        } else {
            tasks.into_iter().map(compute).collect()
        };

        // Fixed-order all-reduce: replica 0's gradients seed the
        // accumulator, higher ranks fold in by ascending index. Losses
        // reduce raw (un-halved) with the loss kernel's saturating
        // accumulator semantics and are halved once below.
        let mut report = StepReport {
            block_loss: vec![0i64; nblocks],
            ..Default::default()
        };
        let mut acc: Option<GradSet> = None;
        for out in outs {
            let Some(o) = out else { continue };
            for (a, &l) in report.block_loss.iter_mut()
                .zip(&o.block_loss_raw)
            {
                *a = a.saturating_add(l);
            }
            report.head_loss =
                report.head_loss.saturating_add(o.head_loss_raw);
            report.correct += o.correct;
            match &mut acc {
                None => acc = Some(o.grads),
                Some(a) => accumulate(a, &o.grads),
            }
        }
        for l in &mut report.block_loss {
            *l /= 2;
        }
        report.head_loss /= 2;
        // Broadcast the *step*, not the weights: the same reduced
        // gradient applied everywhere keeps all replicas bit-identical
        // with zero weight traffic.
        if let Some(acc) = acc {
            apply_step(net, &acc, hp);
            for e in &mut self.extras {
                apply_step(e, &acc, hp);
            }
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::nn::zoo;
    use crate::train::{evaluate, fit, Scheduler, TrainConfig};
    use crate::util::prop;
    use crate::util::rng::Pcg32;

    fn toy_batch(rng: &mut Pcg32, spec: &crate::nn::NetworkSpec, b: usize)
                 -> (ITensor, Vec<usize>) {
        let mut shape = vec![b];
        shape.extend(&spec.input_shape);
        let n: usize = shape.iter().product();
        let x = ITensor::from_vec(
            &shape, (0..n).map(|_| rng.range_i32(-127, 127)).collect());
        let labels = (0..b).map(|i| i % spec.num_classes).collect();
        (x, labels)
    }

    #[test]
    fn shard_bounds_cover_in_order_with_max_one_sample_skew() {
        prop::check("shard-bounds", 40, |g| {
            let b = g.usize_in(0, 200);
            let n = g.usize_in(1, 9);
            let bounds = shard_bounds(b, n);
            assert_eq!(bounds.len(), n);
            let mut cursor = 0usize;
            let (mut lo, mut hi) = (usize::MAX, 0usize);
            for &(s, e) in &bounds {
                assert_eq!(s, cursor, "shards must be contiguous in order");
                assert!(e >= s);
                cursor = e;
                lo = lo.min(e - s);
                hi = hi.max(e - s);
            }
            assert_eq!(cursor, b, "shards must cover the batch");
            assert!(hi - lo <= 1, "shard sizes may differ by at most 1");
        });
    }

    #[test]
    fn all_reduce_shard_order_permutation_invariant() {
        // wrapping i64 addition is commutative + associative, so any
        // reduction order must produce identical bits — even at values
        // engineered to overflow intermediates
        prop::check("allreduce-perm", 40, |g| {
            let n_parts = g.usize_in(2, 6);
            let len = g.usize_in(1, 40);
            let parts: Vec<Vec<i64>> =
                (0..n_parts).map(|_| g.vec_i64(len)).collect();
            let mut fwd = vec![0i64; len];
            for p in &parts {
                add_wrapping(&mut fwd, p);
            }
            let k = g.usize_in(0, n_parts - 1);
            let mut rot = vec![0i64; len];
            for i in 0..n_parts {
                add_wrapping(&mut rot, &parts[(i + k) % n_parts]);
            }
            assert_eq!(fwd, rot, "rotated order diverged");
            let mut rev = vec![0i64; len];
            for p in parts.iter().rev() {
                add_wrapping(&mut rev, p);
            }
            assert_eq!(fwd, rev, "reversed order diverged");
        });
    }

    #[test]
    fn i64_accumulation_exact_at_i32_extremes() {
        // per-replica batch-summed gradients at the i32 rails accumulate
        // exactly in i64 — no saturation, no precision loss
        prop::check("allreduce-rails", 20, |g| {
            let n = g.usize_in(1, 4);
            let len = g.usize_in(1, 16);
            let parts: Vec<Vec<i64>> = (0..n)
                .map(|_| {
                    (0..len)
                        .map(|_| if g.usize_in(0, 1) == 0 {
                            i32::MAX as i64
                        } else {
                            i32::MIN as i64
                        })
                        .collect()
                })
                .collect();
            let mut acc = vec![0i64; len];
            for p in &parts {
                add_wrapping(&mut acc, p);
            }
            for i in 0..len {
                let want: i64 = parts.iter().map(|p| p[i]).sum();
                assert_eq!(acc[i], want, "rail sum must be exact");
            }
        });
        // associativity survives even when intermediates wrap i64
        let (a, b, c) = (i64::MAX, 2i64, -5i64);
        assert_eq!(a.wrapping_add(b).wrapping_add(c),
                   a.wrapping_add(b.wrapping_add(c)));
    }

    #[test]
    fn gradset_accumulate_matches_elementwise_math() {
        let net = Network::new(zoo::get("mlp1-mini").unwrap(), 3);
        let mut acc = GradSet::zeros_like(&net);
        let mut part = GradSet::zeros_like(&net);
        for (i, t) in part.tensors.iter_mut().enumerate() {
            for (j, v) in t.data.iter_mut().enumerate() {
                *v = (i as i64 + 1) * (j as i64 % 7 - 3) * i32::MAX as i64;
            }
        }
        accumulate(&mut acc, &part);
        accumulate(&mut acc, &part);
        for (a, p) in acc.tensors.iter().zip(&part.tensors) {
            for (av, pv) in a.data.iter().zip(&p.data) {
                assert_eq!(*av, 2 * pv);
            }
        }
    }

    #[test]
    fn replicas_1_to_4_byte_identical_to_train_batch() {
        // the tentpole property: for random tiny nets (conv stack and
        // MLP), every replica count produces byte-identical post-step
        // weights, losses and accuracy counts vs the eager sequential
        // path — dropout on, batch size not divisible by the replica
        // count
        for preset in ["tinycnn", "mlp1-mini"] {
            let spec = zoo::get(preset).unwrap();
            let hp = Hyper { gamma_inv: 64, eta_fw_inv: 12000,
                             eta_lr_inv: 3000 };
            let mut net_ref = Network::new(spec.clone(), 7);
            net_ref.set_dropout(0.25, 0.25);
            let mut drop_ref = DropoutRngs::new(9, net_ref.blocks.len());
            let mut rng = Pcg32::new(11);
            let batches: Vec<_> =
                (0..3).map(|_| toy_batch(&mut rng, &spec, 10)).collect();
            let reports: Vec<StepReport> = batches
                .iter()
                .map(|(x, y)| net_ref.train_batch(x, y, &hp, &mut drop_ref))
                .collect();
            for n in 1..=4usize {
                let mut net = Network::new(spec.clone(), 7);
                net.set_dropout(0.25, 0.25);
                let mut drop = DropoutRngs::new(9, net.blocks.len());
                // alternate inline and pool dispatch across replica counts
                let mut rt = ReplicaTrainer::new(&net, n, n % 2 == 0);
                for ((x, y), want) in batches.iter().zip(&reports) {
                    let rep = rt.step(&mut net, x, y, &hp, &mut drop);
                    assert_eq!(rep.block_loss, want.block_loss,
                               "{preset} n={n}: block losses");
                    assert_eq!(rep.head_loss, want.head_loss,
                               "{preset} n={n}: head loss");
                    assert_eq!(rep.correct, want.correct,
                               "{preset} n={n}: correct count");
                }
                for ((na, ta), (nb, tb)) in
                    net_ref.weights().iter().zip(net.weights())
                {
                    assert_eq!(na, &nb);
                    assert_eq!(ta, &tb,
                               "{preset} n={n}: weight {na} diverged");
                }
            }
        }
    }

    fn data(train: usize, test: usize)
            -> (crate::data::Dataset, crate::data::Dataset) {
        let ds = synthetic::by_name("tiny", train + test, 3).unwrap();
        let (mut tr, mut te) = ds.split_test(test);
        tr.mad_normalize();
        te.mad_normalize();
        (tr, te)
    }

    fn run_fit(tr: &crate::data::Dataset, te: &crate::data::Dataset,
               sched: Scheduler, replicas: usize, dropout: f64,
               cfg0: &TrainConfig) -> (crate::train::TrainResult, Network) {
        let mut net = Network::new(zoo::get("tinycnn").unwrap(), 2);
        net.set_dropout(dropout, dropout);
        let cfg = TrainConfig { scheduler: sched, replicas,
                                ..cfg0.clone() };
        let res = fit(&mut net, tr, te, &cfg);
        (res, net)
    }

    fn assert_equal(a: &(crate::train::TrainResult, Network),
                    b: &(crate::train::TrainResult, Network), what: &str) {
        assert_eq!(a.0.epochs.len(), b.0.epochs.len(), "{what}: epochs");
        for (ea, eb) in a.0.epochs.iter().zip(&b.0.epochs) {
            assert_eq!(ea.mean_head_loss, eb.mean_head_loss,
                       "{what}: head loss epoch {}", ea.epoch);
            assert_eq!(ea.mean_block_loss, eb.mean_block_loss,
                       "{what}: block loss epoch {}", ea.epoch);
            assert_eq!(ea.train_acc, eb.train_acc, "{what}: train acc");
            assert!(ea.test_acc == eb.test_acc
                        || (ea.test_acc.is_nan() && eb.test_acc.is_nan()),
                    "{what}: test acc epoch {}", ea.epoch);
        }
        assert_eq!(a.0.final_test_acc, b.0.final_test_acc, "{what}");
        assert_eq!(a.0.diverged, b.0.diverged, "{what}");
        for ((na, ta), (nb, tb)) in a.1.weights().iter().zip(b.1.weights())
        {
            assert_eq!(na, &nb);
            assert_eq!(ta, &tb, "{what}: weight {na} diverged");
        }
    }

    #[test]
    fn fit_replicated_bitexact_every_scheduler_with_dropout() {
        // acceptance criterion: fit with replicas ∈ {2, 4} is
        // bit-identical (weights and per-epoch metrics) to replicas = 1
        // on the same global batches, under every scheduler, with
        // dropout enabled
        let _guard = par::scoped_thread_workers(6);
        let (tr, te) = data(200, 60);
        let cfg = TrainConfig {
            epochs: 3,
            batch: 32,
            eval_every: 2, // metrics must match across non-eval epochs too
            hyper: Hyper { gamma_inv: 128, eta_fw_inv: 12000,
                           eta_lr_inv: 3000 },
            ..Default::default()
        };
        let reference = run_fit(&tr, &te, Scheduler::Sequential, 1, 0.25,
                                &cfg);
        for sched in [Scheduler::Sequential, Scheduler::BlockParallel,
                      Scheduler::Pipelined] {
            for n in [2usize, 4] {
                let got = run_fit(&tr, &te, sched, n, 0.25, &cfg);
                assert_equal(&reference, &got,
                             &format!("{} replicas={n}", sched.name()));
            }
        }
        // and without dropout, one parallel combination as a spot check
        let ref_nd = run_fit(&tr, &te, Scheduler::Sequential, 1, 0.0, &cfg);
        let got_nd = run_fit(&tr, &te, Scheduler::Pipelined, 2, 0.0, &cfg);
        assert_equal(&ref_nd, &got_nd, "no-dropout replicas=2");
    }

    #[test]
    fn low_bit_fit_bitexact_every_scheduler_and_replica_count() {
        // with narrow rails the clamp sites are live on every step, and
        // gradient clamping happens once, *after* the all-reduce (inside
        // apply_grads) — so low-bit training must stay byte-identical
        // across schedulers and replica counts just like full-width
        use crate::nn::spec::{BitsPlan, BitwidthCfg};
        let _guard = par::scoped_thread_workers(6);
        let (tr, te) = data(150, 40);
        let bits = BitsPlan::uniform(BitwidthCfg {
            weights: 8, activations: 8, grads: 32, errors: 16,
        });
        let cfg = TrainConfig {
            epochs: 2,
            batch: 32,
            hyper: Hyper { gamma_inv: 64, eta_fw_inv: 12000,
                           eta_lr_inv: 3000 },
            ..Default::default()
        };
        let run = |sched: Scheduler, replicas: usize| {
            let spec = zoo::get("tinycnn").unwrap().with_bits(bits.clone());
            let mut net = Network::new(spec, 2);
            net.set_dropout(0.25, 0.25);
            let cfg = TrainConfig { scheduler: sched, replicas,
                                    ..cfg.clone() };
            let res = fit(&mut net, &tr, &te, &cfg);
            (res, net)
        };
        let reference = run(Scheduler::Sequential, 1);
        // the 8-bit weight rail must actually bind after training
        for (name, w) in reference.1.weights() {
            let (lo, hi) = w.minmax();
            assert!(lo >= -127 && hi <= 127,
                    "{name}: weights [{lo}, {hi}] escaped the 8-bit rail");
        }
        for sched in [Scheduler::Sequential, Scheduler::BlockParallel,
                      Scheduler::Pipelined] {
            for n in [2usize, 4] {
                let got = run(sched, n);
                assert_equal(
                    &reference, &got,
                    &format!("low-bit {} replicas={n}", sched.name()),
                );
            }
        }
    }

    #[test]
    fn final_partial_batch_every_scheduler_and_replica_count() {
        // regression (satellite): dataset len % batch != 0 — the final
        // training batch is partial (here 1 sample, smaller than the
        // replica count, so some shards are empty) and the eval set is a
        // partial batch too; every scheduler × replica combination must
        // match the sequential single-replica reference, and evaluation
        // must count every sample exactly once at any batch size
        let _guard = par::scoped_thread_workers(6);
        let (tr, te) = data(97, 33);
        assert_eq!(tr.len() % 32, 1, "fixture must end on a partial batch");
        let cfg = TrainConfig {
            epochs: 2,
            batch: 32,
            hyper: Hyper { gamma_inv: 128, eta_fw_inv: 12000,
                           eta_lr_inv: 3000 },
            ..Default::default()
        };
        let reference = run_fit(&tr, &te, Scheduler::Sequential, 1, 0.25,
                                &cfg);
        for sched in [Scheduler::Sequential, Scheduler::BlockParallel,
                      Scheduler::Pipelined] {
            for n in [1usize, 2, 4] {
                let got = run_fit(&tr, &te, sched, n, 0.25, &cfg);
                assert_equal(
                    &reference, &got,
                    &format!("partial-batch {} replicas={n}", sched.name()),
                );
            }
        }
        // evaluate: partial tail batches must not drop or double-count
        let a = evaluate(&reference.1, &te, 64); // 33 % 64 != 0
        let b = evaluate(&reference.1, &te, 7); //  33 % 7  != 0
        let c = evaluate(&reference.1, &te, 33); // exact
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn empty_shards_contribute_reduction_identity() {
        // batch of 2 over 4 replicas: two shards are empty; the step must
        // still match the single-replica step exactly
        let spec = zoo::get("tinycnn").unwrap();
        let hp = Hyper { gamma_inv: 64, eta_fw_inv: 0, eta_lr_inv: 0 };
        let mut rng = Pcg32::new(5);
        let (x, labels) = toy_batch(&mut rng, &spec, 2);
        let mut net_ref = Network::new(spec.clone(), 4);
        let mut drop_ref = DropoutRngs::new(4, net_ref.blocks.len());
        let want = net_ref.train_batch(&x, &labels, &hp, &mut drop_ref);
        let mut net = Network::new(spec.clone(), 4);
        let mut drop = DropoutRngs::new(4, net.blocks.len());
        let mut rt = ReplicaTrainer::new(&net, 4, false);
        let rep = rt.step(&mut net, &x, &labels, &hp, &mut drop);
        assert_eq!(rep.block_loss, want.block_loss);
        assert_eq!(rep.head_loss, want.head_loss);
        assert_eq!(rep.correct, want.correct);
        for ((na, ta), (nb, tb)) in
            net_ref.weights().iter().zip(net.weights())
        {
            assert_eq!(na, &nb);
            assert_eq!(ta, &tb, "weight {na} diverged with empty shards");
        }
    }

    #[test]
    fn apply_step_from_zero_grads_applies_only_decay() {
        let mut net = Network::new(zoo::get("mlp1-mini").unwrap(), 1);
        let zeros = GradSet::zeros_like(&net);
        let before: Vec<ITensor> =
            net.weights().into_iter().map(|(_, w)| w.clone()).collect();
        // no decay: zero gradient must be a no-op
        apply_step(&mut net, &zeros,
                   &Hyper { gamma_inv: 512, eta_fw_inv: 0, eta_lr_inv: 0 });
        for ((_, w), b) in net.weights().iter().zip(&before) {
            assert_eq!(*w, b);
        }
    }
}
