//! Fault-tolerant cross-process distributed training: a rank-per-process
//! TCP integer all-reduce over the PR-5 gradient-export seam
//! ([`GradSet`] / [`accumulate`]), byte-identical to the in-process
//! [`super::replica::ReplicaTrainer`] on the same global batches.
//!
//! ## Why the network is never a correctness dependency
//!
//! Integer gradients widened to i64 make the all-reduce associative
//! *and* commutative, and every rank runs the same deterministic fit
//! loop (same dataset, same `Batcher` shuffle stream, same pre-drawn
//! dropout masks). A shard's gradient is therefore a pure function of
//! (weights, batch slice, masks) — all of which every rank already has.
//! **Any rank can locally recompute any other rank's shard, bit for
//! bit.** The wire exists only to avoid redundant compute: each rank
//! computes its own shard, broadcasts it to all peers, and collects the
//! rest. When a peer's shard does not arrive — dropped frame, stalled
//! link, partition, dead process — the rank computes that shard itself
//! after a bounded wait and folds it in the same fixed ascending-rank
//! order. Every failure mode degrades to local compute with
//! byte-identical results; fault handling changes *wall-clock time*,
//! never *bits*.
//!
//! ## Topology and liveness
//!
//! The group is a symmetric full mesh with no leader. Each rank binds a
//! listener at `peers[rank]` and runs one connector thread per peer
//! that dials with capped exponential backoff plus deterministic
//! jitter, performs a `Hello` handshake (magic, world size, rank), and
//! then carries heartbeats. Liveness is per-peer receive recency: a
//! peer silent for `peer_dead_ms` is considered dead and its shards are
//! solo-computed without waiting. The alive-set is re-evaluated every
//! step; a transition bumps the *view* counter — the coordinator-free
//! ring re-formation: survivors simply stop waiting for the dead rank
//! and keep stepping degraded. A restarted rank rebinds its address,
//! replays from its checkpoint (it is *behind*, so it never waits for
//! peers that are ahead — full-speed catch-up), and once its step
//! counter meets the group's, frames flow again and the mesh is whole —
//! elastic rejoin with zero coordination.
//!
//! ## Wire format (hostile-input hardened like `serve::wire`)
//!
//! The frame codec lives in [`super::framing`]: length-prefixed
//! `Hello` / `Grad` / `Heartbeat` frames with a model-derived size cap
//! and exact arity validation — a malformed, truncated or oversized
//! frame drops the connection instead of the process.
//!
//! ## Fault injection
//!
//! All failure handling is driven through [`FaultPlan`]
//! (`--fault-plan` / `NITRO_FAULT`): the connect and send seams consult
//! [`FaultPlan::on_connect`] / [`FaultPlan::on_send`] (drop, delay,
//! stall, partition), and the step boundary consults
//! [`FaultPlan::crash_at`] — a process rank exits with
//! [`fault::CRASH_EXIT_CODE`], an in-process test rank returns `None`
//! from [`DistTrainer::step`]. The seam is sender-side: a rule
//! `{rank: a, peer: b}` affects only `a → b` traffic, so a full
//! bidirectional partition lists both direction rules.

use std::collections::HashMap;
use std::io::{ErrorKind, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::nn::{DropoutRngs, Hyper, Network, StepReport};
use crate::tensor::{ITensor, LTensor};
use crate::train::framing::{decode, encode_grad, encode_hb, encode_hello,
                            grad_frame_len, read_frame, Msg, WireShard};
use crate::train::replica::{accumulate, apply_step, probe_out_sizes,
                            shard_bounds, shard_grads, GradSet, ShardOut};
use crate::util::fault::{self, FaultPlan, SendAction};
use crate::util::rng::Pcg32;

/// Configuration of one rank of a distributed training group.
#[derive(Clone, Debug)]
pub struct DistConfig {
    /// This process's rank: an index into `peers`.
    pub rank: usize,
    /// `host:port` listener address of every rank, index = rank. The
    /// world size is `peers.len()`; a single entry degenerates to
    /// plain single-process training.
    pub peers: Vec<String>,
    /// Per-dial TCP connect timeout.
    pub connect_timeout_ms: u64,
    /// Initial re-dial backoff; doubles (with deterministic jitter) up
    /// to `connect_backoff_max_ms`.
    pub connect_backoff_ms: u64,
    pub connect_backoff_max_ms: u64,
    /// Read/write timeout on every peer socket (0 = none). A stalled
    /// link errors out and the connection is re-dialed, exactly the
    /// slowloris discipline the serving path applies.
    pub io_timeout_ms: u64,
    /// How long a step waits for a live, in-step peer's shard before
    /// solo-computing it. Bounds the cost of any single fault.
    pub step_wait_ms: u64,
    /// Heartbeat cadence per outgoing connection.
    pub heartbeat_ms: u64,
    /// A peer silent for this long is dead: its shards are
    /// solo-computed without waiting until it speaks again.
    pub peer_dead_ms: u64,
    /// Artificial per-step sleep (testing/elastic-rejoin demos: lets a
    /// restarted rank catch up to a deliberately throttled group).
    pub pace_ms: u64,
    /// Deterministic fault schedule injected at the transport seam.
    pub fault: FaultPlan,
    /// `crash` rules call `process::exit(CRASH_EXIT_CODE)` when true
    /// (the CLI); in-process harness ranks instead get `None` from
    /// [`DistTrainer::step`] and unwind cleanly.
    pub crash_process: bool,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig {
            rank: 0,
            peers: Vec::new(),
            connect_timeout_ms: 1_000,
            connect_backoff_ms: 50,
            connect_backoff_max_ms: 2_000,
            io_timeout_ms: 10_000,
            step_wait_ms: 5_000,
            heartbeat_ms: 500,
            peer_dead_ms: 3_000,
            pace_ms: 0,
            fault: FaultPlan::default(),
            crash_process: false,
        }
    }
}

/// Transport counters for observability and test assertions. All values
/// are cumulative over the trainer's lifetime.
#[derive(Clone, Debug, Default)]
pub struct DistStats {
    /// Peer shards folded from received frames.
    pub remote_shards_used: u64,
    /// Peer shards recomputed locally (deadline, dead or lagging peer).
    pub solo_shards: u64,
    /// Frames for already-finished steps (late arrivals), discarded.
    pub stale_frames: u64,
    /// Successful re-dials after the first connect to a peer.
    pub reconnects: u64,
    /// Alive-set transitions observed (ring re-formations).
    pub view: u64,
}

/// Grad frames this far ahead of the current step are buffered for
/// adoption; anything further out is discarded (bounded memory under a
/// runaway peer).
const FUTURE_WINDOW: u64 = 8;

/// State shared between the training thread and the transport threads.
struct Shared {
    rank: usize,
    world: usize,
    plan: FaultPlan,
    /// Flat length of every gradient tensor, `Network::weights()`
    /// order — the exact arity a `Grad` frame must match.
    lens: Vec<usize>,
    nblocks: usize,
    /// Hard cap on any frame body, derived from the model itself.
    max_frame: usize,
    /// Current training step, read by heartbeats and the fault seam.
    step: AtomicU64,
    shutdown: AtomicBool,
    reconnects: AtomicU64,
    /// Per-peer last-receive instant, ms since `start` (0 = never).
    last_rx: Vec<AtomicU64>,
    /// Highest step seen from each peer (frames and heartbeats).
    peer_step: Vec<AtomicU64>,
    /// Outgoing connection per peer; `None` while down (the connector
    /// thread re-dials). Mutex-guarded so delayed-send threads and the
    /// step broadcast can share it.
    writers: Vec<Mutex<Option<TcpStream>>>,
    start: Instant,
}

impl Shared {
    fn now_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }

    /// Record traffic from `peer`: refresh liveness, advance its step
    /// high-water mark.
    fn touch(&self, peer: usize, step: u64) {
        self.last_rx[peer].store(self.now_ms().max(1), Ordering::Relaxed);
        self.peer_step[peer].fetch_max(step, Ordering::Relaxed);
    }

    fn alive(&self, peer: usize, dead_ms: u64) -> bool {
        let t = self.last_rx[peer].load(Ordering::Relaxed);
        t > 0 && self.now_ms().saturating_sub(t) <= dead_ms
    }
}

// ----------------------------------------------------- transport threads

/// Write a pre-encoded frame to `peer` if its connection is up; a write
/// error tears the connection down (the connector re-dials).
fn send_bytes(sh: &Shared, peer: usize, bytes: &[u8]) {
    let mut g = sh.writers[peer].lock().unwrap();
    if let Some(s) = g.as_mut() {
        if s.write_all(bytes).is_err() {
            *g = None;
        }
    }
}

/// Sever the outgoing link to `peer` as if the cable were pulled
/// (partition rules).
fn sever(sh: &Shared, peer: usize) {
    let mut g = sh.writers[peer].lock().unwrap();
    if let Some(s) = g.take() {
        let _ = s.shutdown(Shutdown::Both);
    }
}

fn dial(addr: &str, timeout_ms: u64) -> std::io::Result<TcpStream> {
    let addrs: Vec<SocketAddr> = addr.to_socket_addrs()?.collect();
    let a = addrs.first().ok_or_else(|| {
        std::io::Error::new(ErrorKind::NotFound, "address resolved empty")
    })?;
    TcpStream::connect_timeout(a, Duration::from_millis(timeout_ms.max(1)))
}

/// Sleep half the backoff plus deterministic jitter, then double the
/// backoff up to the cap — retry storms decorrelate without wall-clock
/// randomness.
fn backoff_sleep(rng: &mut Pcg32, backoff: &mut u64, max_ms: u64) {
    let half = (*backoff / 2).max(1).min(u32::MAX as u64) as u32;
    let jitter = u64::from(rng.below(half.saturating_add(1)));
    thread::sleep(Duration::from_millis(u64::from(half).saturating_add(jitter)));
    *backoff = backoff.saturating_mul(2).min(max_ms.max(1));
}

/// Accept loop: non-blocking poll (so shutdown is prompt), one reader
/// thread per accepted connection with the configured io timeouts.
fn listener_loop(sh: Arc<Shared>, listener: TcpListener,
                 tx: Sender<(usize, u64, WireShard)>, io_timeout_ms: u64) {
    let _ = listener.set_nonblocking(true);
    while !sh.shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_nodelay(true);
                if io_timeout_ms > 0 {
                    let t = Duration::from_millis(io_timeout_ms);
                    let _ = stream.set_read_timeout(Some(t));
                    let _ = stream.set_write_timeout(Some(t));
                }
                let sh2 = Arc::clone(&sh);
                let tx2 = tx.clone();
                thread::spawn(move || reader_loop(sh2, stream, tx2));
            }
            Err(_) => thread::sleep(Duration::from_millis(2)),
        }
    }
}

/// Per-connection reader: the first frame must be a valid `Hello`
/// naming a foreign rank; after that, heartbeats refresh liveness and
/// grad frames are forwarded to the training thread. Any malformed
/// frame or io error drops the connection — the peer's connector
/// re-establishes it.
fn reader_loop(sh: Arc<Shared>, mut stream: TcpStream,
               tx: Sender<(usize, u64, WireShard)>) {
    let mut buf = Vec::new();
    let hello = read_frame(&mut stream, sh.max_frame, &mut buf)
        .map_err(|e| e.to_string())
        .and_then(|()| decode(&buf, sh.world, sh.nblocks, &sh.lens));
    let peer = match hello {
        Ok(Msg::Hello { rank }) if rank != sh.rank => rank,
        _ => return,
    };
    sh.touch(peer, 0);
    while !sh.shutdown.load(Ordering::Relaxed) {
        if read_frame(&mut stream, sh.max_frame, &mut buf).is_err() {
            return;
        }
        match decode(&buf, sh.world, sh.nblocks, &sh.lens) {
            Ok(Msg::Heartbeat { rank, step }) if rank == peer => {
                sh.touch(peer, step);
            }
            Ok(Msg::Grad { rank, step, shard }) if rank == peer => {
                sh.touch(peer, step);
                if tx.send((peer, step, shard)).is_err() {
                    return;
                }
            }
            _ => return,
        }
    }
}

/// Per-peer connector: keeps the outgoing connection alive (dial with
/// capped exponential backoff + jitter, `Hello` on connect) and sends
/// heartbeats while it is up. The connect seam consults the fault plan;
/// heartbeats obey drop/partition rules so a partitioned link actually
/// goes quiet, but a delay rule does not hold them back — a late
/// heartbeat still proves liveness.
fn connector_loop(sh: Arc<Shared>, peer: usize, addr: String,
                  cfg: DistConfig) {
    let mut rng =
        Pcg32::with_stream(0x6e69_7472 ^ ((sh.rank as u64) << 20),
                           peer as u64);
    let mut backoff = cfg.connect_backoff_ms.max(1);
    let mut connected_before = false;
    while !sh.shutdown.load(Ordering::Relaxed) {
        if sh.writers[peer].lock().unwrap().is_some() {
            thread::sleep(Duration::from_millis(cfg.heartbeat_ms.max(1)));
            if sh.shutdown.load(Ordering::Relaxed) {
                return;
            }
            let step = sh.step.load(Ordering::Relaxed);
            match sh.plan.on_send(sh.rank, peer, step) {
                SendAction::Drop | SendAction::Partitioned => continue,
                SendAction::Deliver | SendAction::DelayMs(_) => {}
            }
            send_bytes(&sh, peer, &encode_hb(sh.rank, step));
            continue;
        }
        let step = sh.step.load(Ordering::Relaxed);
        match sh.plan.on_connect(sh.rank, peer, step) {
            SendAction::Drop | SendAction::Partitioned => {
                backoff_sleep(&mut rng, &mut backoff,
                              cfg.connect_backoff_max_ms);
                continue;
            }
            SendAction::DelayMs(ms) => {
                thread::sleep(Duration::from_millis(ms));
            }
            SendAction::Deliver => {}
        }
        match dial(&addr, cfg.connect_timeout_ms) {
            Ok(mut stream) => {
                let _ = stream.set_nodelay(true);
                if cfg.io_timeout_ms > 0 {
                    let t = Duration::from_millis(cfg.io_timeout_ms);
                    let _ = stream.set_write_timeout(Some(t));
                }
                if stream
                    .write_all(&encode_hello(sh.rank, sh.world))
                    .is_err()
                {
                    backoff_sleep(&mut rng, &mut backoff,
                                  cfg.connect_backoff_max_ms);
                    continue;
                }
                *sh.writers[peer].lock().unwrap() = Some(stream);
                if connected_before {
                    sh.reconnects.fetch_add(1, Ordering::Relaxed);
                }
                connected_before = true;
                backoff = cfg.connect_backoff_ms.max(1);
            }
            Err(_) => {
                backoff_sleep(&mut rng, &mut backoff,
                              cfg.connect_backoff_max_ms);
            }
        }
    }
}

// ------------------------------------------------------------- trainer

/// One rank of the distributed group: drop-in peer of
/// [`super::replica::ReplicaTrainer::step`], byte-identical to it (and
/// to `replicas = world` single-process training) on the same global
/// batches, no matter what the network does.
pub struct DistTrainer {
    cfg: DistConfig,
    shared: Arc<Shared>,
    rx: Receiver<(usize, u64, WireShard)>,
    /// Keeps the channel open across reader churn.
    _tx: Sender<(usize, u64, WireShard)>,
    /// Current training step (global batch ordinal from epoch 0).
    step: u64,
    /// Weight shapes in `Network::weights()` order, for re-tensoring
    /// wire shards.
    shapes: Vec<Vec<usize>>,
    out_per_sample: Vec<usize>,
    masks: Vec<Vec<bool>>,
    shard_x: ITensor,
    /// Early frames keyed by (step, rank), adopted when their step
    /// starts; bounded by [`FUTURE_WINDOW`].
    future: HashMap<(u64, usize), WireShard>,
    alive_prev: Vec<bool>,
    stats: DistStats,
}

impl DistTrainer {
    /// Bind the listener at `peers[rank]` and start the transport. The
    /// bind retries briefly so an elastically rejoining rank can
    /// reclaim its address while the OS releases the old socket.
    pub fn new(net: &Network, cfg: DistConfig)
               -> Result<DistTrainer, String> {
        let addr = cfg
            .peers
            .get(cfg.rank)
            .ok_or_else(|| {
                format!("rank {} has no peer address (world {})",
                        cfg.rank, cfg.peers.len())
            })?
            .clone();
        let mut last = String::new();
        for _ in 0..40 {
            match TcpListener::bind(&addr) {
                Ok(l) => return DistTrainer::with_listener(net, cfg, l),
                Err(e) => last = e.to_string(),
            }
            thread::sleep(Duration::from_millis(50));
        }
        Err(format!("rank {}: bind {addr}: {last}", cfg.rank))
    }

    /// Start from a pre-bound listener (tests bind `:0` listeners first
    /// and then know every rank's port before any rank starts).
    pub fn with_listener(net: &Network, cfg: DistConfig,
                         listener: TcpListener)
                         -> Result<DistTrainer, String> {
        let world = cfg.peers.len();
        if world == 0 {
            return Err("distributed config needs at least one peer \
                        address"
                .into());
        }
        if cfg.rank >= world {
            return Err(format!(
                "rank {} out of range for world size {world}", cfg.rank
            ));
        }
        let shapes: Vec<Vec<usize>> = net
            .weights()
            .into_iter()
            .map(|(_, w)| w.shape.clone())
            .collect();
        let lens: Vec<usize> =
            shapes.iter().map(|s| s.iter().product()).collect();
        let nblocks = net.blocks.len();
        let max_frame = grad_frame_len(nblocks, &lens) + 64;
        let shared = Arc::new(Shared {
            rank: cfg.rank,
            world,
            plan: cfg.fault.clone(),
            lens,
            nblocks,
            max_frame,
            step: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            reconnects: AtomicU64::new(0),
            last_rx: (0..world).map(|_| AtomicU64::new(0)).collect(),
            peer_step: (0..world).map(|_| AtomicU64::new(0)).collect(),
            writers: (0..world).map(|_| Mutex::new(None)).collect(),
            start: Instant::now(),
        });
        let (tx, rx) = mpsc::channel();
        {
            let sh = Arc::clone(&shared);
            let txl = tx.clone();
            let io = cfg.io_timeout_ms;
            thread::spawn(move || listener_loop(sh, listener, txl, io));
        }
        for p in (0..world).filter(|&p| p != cfg.rank) {
            let sh = Arc::clone(&shared);
            let addr = cfg.peers[p].clone();
            let c = cfg.clone();
            thread::spawn(move || connector_loop(sh, p, addr, c));
        }
        let mut alive_prev = vec![false; world];
        alive_prev[cfg.rank] = true;
        Ok(DistTrainer {
            out_per_sample: probe_out_sizes(net),
            masks: vec![Vec::new(); nblocks],
            shard_x: ITensor::empty(),
            shapes,
            future: HashMap::new(),
            alive_prev,
            stats: DistStats::default(),
            step: 0,
            shared,
            rx,
            _tx: tx,
            cfg,
        })
    }

    pub fn rank(&self) -> usize {
        self.shared.rank
    }

    pub fn world(&self) -> usize {
        self.shared.world
    }

    /// Resume support: position the step counter at the global batch
    /// ordinal the loaded checkpoint corresponds to, so frames line up
    /// with the group's counters.
    pub fn set_start_step(&mut self, step: u64) {
        self.step = step;
        self.shared.step.store(step, Ordering::Relaxed);
    }

    /// Cumulative transport counters (the in-flight `reconnects` value
    /// is folded in at read time).
    pub fn stats(&self) -> DistStats {
        let mut s = self.stats.clone();
        s.reconnects = self.shared.reconnects.load(Ordering::Relaxed);
        s
    }

    /// Block until every peer has been heard from at least once and our
    /// outgoing connections are up, or the timeout passes. Purely an
    /// optimization hook (warm mesh before step 0 so the first steps
    /// use remote shards); training is correct without it.
    pub fn wait_connected(&self, timeout_ms: u64) -> bool {
        let deadline =
            Instant::now() + Duration::from_millis(timeout_ms);
        loop {
            let up = (0..self.shared.world)
                .filter(|&p| p != self.shared.rank)
                .all(|p| {
                    self.shared.last_rx[p].load(Ordering::Relaxed) > 0
                        && self.shared.writers[p]
                            .lock()
                            .unwrap()
                            .is_some()
                });
            if up {
                return true;
            }
            if Instant::now() >= deadline {
                return false;
            }
            thread::sleep(Duration::from_millis(2));
        }
    }

    /// Stop the transport: readers and connectors wind down, sockets
    /// close. Called automatically on drop and on an injected crash.
    pub fn shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        for w in &self.shared.writers {
            let mut g = w.lock().unwrap();
            if let Some(s) = g.take() {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }

    fn wire_to_shard(&self, ws: WireShard) -> ShardOut {
        ShardOut {
            block_loss_raw: ws.block_loss_raw,
            head_loss_raw: ws.head_loss_raw,
            correct: ws.correct as usize,
            grads: GradSet {
                tensors: ws
                    .tensors
                    .into_iter()
                    .zip(&self.shapes)
                    .map(|(d, sh)| LTensor::from_vec(sh, d))
                    .collect(),
            },
        }
    }

    /// One distributed training step on a global batch. Returns `None`
    /// when an injected crash terminates this rank (in-process mode);
    /// otherwise the same [`StepReport`] every other surviving rank
    /// computes, with weights advanced by the identical reduced step.
    pub fn step(&mut self, net: &mut Network, x: &ITensor,
                labels: &[usize], hp: &Hyper, drop: &mut DropoutRngs)
                -> Option<StepReport> {
        let step = self.step;
        self.shared.step.store(step, Ordering::Relaxed);
        let b = labels.len();
        debug_assert_eq!(x.shape[0], b, "batch/label mismatch");
        let world = self.shared.world;
        let rank = self.shared.rank;
        let nblocks = net.blocks.len();
        let num_classes = net.spec.num_classes;
        // Pre-draw the whole batch's keep-masks exactly as the replica
        // trainer does: masks are position-indexed, so every rank draws
        // identical masks and shard gradients are rank-independent.
        for (l, blk) in net.blocks.iter().enumerate() {
            let mask = &mut self.masks[l];
            mask.clear();
            if blk.drop_p256 > 0 {
                let p = blk.drop_p256;
                let rng = drop.stream(l);
                mask.extend(
                    (0..b * self.out_per_sample[l])
                        .map(|_| rng.below(256) >= p),
                );
            }
        }
        let bounds = shard_bounds(b, world);
        let ss = x.len() / b.max(1);
        let mut outs: Vec<Option<ShardOut>> =
            (0..world).map(|_| None).collect();
        // Own shard first — it is both this rank's contribution to the
        // group and the payload of the broadcast below.
        let (s0, e0) = bounds[rank];
        if s0 != e0 {
            slice_rows(&mut self.shard_x, x, s0, e0, ss);
            outs[rank] = Some(shard_grads(
                net, &self.shard_x, &labels[s0..e0], num_classes,
                &self.masks, &self.out_per_sample, s0,
            ));
        }
        // Broadcast through the fault seam: drop discards, partition
        // severs the link, delay/stall hand the frame to a detached
        // timer thread (per-link latency never blocks the sender).
        if world > 1 {
            if let Some(own) = outs[rank].as_ref() {
                let bytes = Arc::new(encode_grad(rank, step, own));
                for p in (0..world).filter(|&p| p != rank) {
                    match self.shared.plan.on_send(rank, p, step) {
                        SendAction::Deliver => {
                            send_bytes(&self.shared, p, &bytes);
                        }
                        SendAction::Drop => {}
                        SendAction::Partitioned => {
                            sever(&self.shared, p);
                        }
                        SendAction::DelayMs(ms) => {
                            let sh = Arc::clone(&self.shared);
                            let f = Arc::clone(&bytes);
                            thread::spawn(move || {
                                thread::sleep(Duration::from_millis(ms));
                                send_bytes(&sh, p, &f);
                            });
                        }
                    }
                }
            }
        }
        // Adopt any frames for this step that arrived early, then age
        // the buffer out of the window.
        for p in 0..world {
            if p == rank || bounds[p].0 == bounds[p].1 {
                continue;
            }
            if let Some(ws) = self.future.remove(&(step, p)) {
                outs[p] = Some(self.wire_to_shard(ws));
                self.stats.remote_shards_used =
                    self.stats.remote_shards_used.saturating_add(1);
            }
        }
        self.future.retain(|&(s, _), _| {
            s > step && s <= step.saturating_add(FUTURE_WINDOW)
        });
        // Collect peer shards until complete or the deadline: a peer is
        // only worth waiting for while it is alive and *at* this step
        // (or one behind, i.e. about to reach it). A peer far behind is
        // a checkpoint replay — survivors skip it instead of stalling;
        // a peer ahead already sent this step's frame, which is either
        // in the channel/future buffer (drained below) or lost for
        // good — so the replaying rank never waits either and catches
        // up at full local speed. That asymmetry is what makes elastic
        // rejoin converge.
        let deadline = Instant::now()
            + Duration::from_millis(self.cfg.step_wait_ms);
        loop {
            let waiting = (0..world).any(|p| {
                let ps =
                    self.shared.peer_step[p].load(Ordering::Relaxed);
                p != rank
                    && bounds[p].0 != bounds[p].1
                    && outs[p].is_none()
                    && self.shared.alive(p, self.cfg.peer_dead_ms)
                    && ps + 1 >= step
                    && ps <= step
            });
            if !waiting {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let wait = (deadline - now).min(Duration::from_millis(5));
            match self.rx.recv_timeout(wait) {
                Ok((p, mstep, ws)) => {
                    if mstep == step {
                        if p < world
                            && bounds[p].0 != bounds[p].1
                            && outs[p].is_none()
                        {
                            outs[p] = Some(self.wire_to_shard(ws));
                            self.stats.remote_shards_used =
                                self.stats.remote_shards_used.saturating_add(1);
                        }
                    } else if mstep > step
                        && mstep <= step.saturating_add(FUTURE_WINDOW)
                    {
                        self.future.insert((mstep, p), ws);
                    } else {
                        self.stats.stale_frames =
                            self.stats.stale_frames.saturating_add(1);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // Solo fallback: recompute every missing shard locally. Same
        // weights, same batch slice, same masks — byte-identical to
        // what the peer would have sent.
        for p in 0..world {
            if outs[p].is_some() {
                continue;
            }
            let (s, e) = bounds[p];
            if s == e {
                continue;
            }
            slice_rows(&mut self.shard_x, x, s, e, ss);
            outs[p] = Some(shard_grads(
                net, &self.shard_x, &labels[s..e], num_classes,
                &self.masks, &self.out_per_sample, s,
            ));
            self.stats.solo_shards = self.stats.solo_shards.saturating_add(1);
        }
        // Fixed ascending-rank fold — identical to the replica
        // trainer's reduction, so the reduced gradient and the metrics
        // match it bit for bit.
        let mut report = StepReport {
            block_loss: vec![0i64; nblocks],
            ..Default::default()
        };
        let mut acc: Option<GradSet> = None;
        for out in outs {
            let Some(o) = out else { continue };
            for (a, &l) in
                report.block_loss.iter_mut().zip(&o.block_loss_raw)
            {
                *a = a.saturating_add(l);
            }
            report.head_loss =
                report.head_loss.saturating_add(o.head_loss_raw);
            report.correct = report.correct.saturating_add(o.correct);
            match &mut acc {
                None => acc = Some(o.grads),
                Some(a) => accumulate(a, &o.grads),
            }
        }
        for l in &mut report.block_loss {
            *l /= 2;
        }
        report.head_loss /= 2;
        if let Some(acc) = acc {
            apply_step(net, &acc, hp);
        }
        // View bookkeeping: an alive-set transition is a ring
        // re-formation (a rank died or (re)joined).
        let alive_now: Vec<bool> = (0..world)
            .map(|p| {
                p == rank
                    || self.shared.alive(p, self.cfg.peer_dead_ms)
            })
            .collect();
        if alive_now != self.alive_prev {
            self.stats.view = self.stats.view.saturating_add(1);
            self.alive_prev = alive_now;
        }
        if self.cfg.pace_ms > 0 {
            thread::sleep(Duration::from_millis(self.cfg.pace_ms));
        }
        // Injected crash fires after the step completes (the weights
        // for this step are applied; whether they survive depends on
        // the checkpoint cadence, exactly like a real crash).
        if self.shared.plan.crash_at(rank, step) {
            if self.cfg.crash_process {
                std::process::exit(fault::CRASH_EXIT_CODE);
            }
            self.shutdown();
            return None;
        }
        self.step = self.step.wrapping_add(1);
        Some(report)
    }
}

impl Drop for DistTrainer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Copy rows `[s, e)` of `x` into the reused shard buffer.
fn slice_rows(buf: &mut ITensor, x: &ITensor, s: usize, e: usize,
              ss: usize) {
    buf.data.clear();
    buf.data.extend_from_slice(&x.data[s * ss..e * ss]);
    buf.shape.clear();
    buf.shape.push(e - s);
    buf.shape.extend(&x.shape[1..]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;
    use crate::train::replica::ReplicaTrainer;

    const HP: Hyper =
        Hyper { gamma_inv: 64, eta_fw_inv: 12000, eta_lr_inv: 3000 };

    fn toy_batches(spec: &crate::nn::NetworkSpec, n: usize, b: usize,
                   seed: u64) -> Vec<(ITensor, Vec<usize>)> {
        let mut rng = Pcg32::new(seed);
        (0..n)
            .map(|_| {
                let mut shape = vec![b];
                shape.extend(&spec.input_shape);
                let len: usize = shape.iter().product();
                let x = ITensor::from_vec(
                    &shape,
                    (0..len).map(|_| rng.range_i32(-127, 127)).collect(),
                );
                let labels =
                    (0..b).map(|i| i % spec.num_classes).collect();
                (x, labels)
            })
            .collect()
    }

    /// Uninterrupted in-process reference: `ReplicaTrainer` with
    /// `replicas = world` — the thing every distributed run must match
    /// byte for byte.
    fn reference(world: usize, batches: &[(ITensor, Vec<usize>)])
                 -> (Vec<StepReport>, Network) {
        let mut net = Network::new(zoo::get("mlp1-mini").unwrap(), 7);
        net.set_dropout(0.25, 0.25);
        let mut drop = DropoutRngs::new(9, net.blocks.len());
        let mut rt = ReplicaTrainer::new(&net, world, false);
        let reports = batches
            .iter()
            .map(|(x, y)| rt.step(&mut net, x, y, &HP, &mut drop))
            .collect();
        (reports, net)
    }

    fn weights_of(net: &Network) -> Vec<ITensor> {
        net.weights().into_iter().map(|(_, w)| w.clone()).collect()
    }

    /// Pre-bound `:0` listeners so every rank knows every port before
    /// any rank starts — no port races in tests.
    fn bind_world(n: usize) -> (Vec<String>, Vec<TcpListener>) {
        let listeners: Vec<TcpListener> = (0..n)
            .map(|_| TcpListener::bind("127.0.0.1:0").unwrap())
            .collect();
        let peers = listeners
            .iter()
            .map(|l| l.local_addr().unwrap().to_string())
            .collect();
        (peers, listeners)
    }

    fn cfg_for(rank: usize, peers: &[String]) -> DistConfig {
        DistConfig {
            rank,
            peers: peers.to_vec(),
            connect_backoff_ms: 5,
            connect_backoff_max_ms: 50,
            io_timeout_ms: 5_000,
            step_wait_ms: 5_000,
            heartbeat_ms: 20,
            peer_dead_ms: 300,
            ..Default::default()
        }
    }

    struct RankRun {
        reports: Vec<StepReport>,
        crashed: bool,
        weights: Vec<ITensor>,
        stats: DistStats,
    }

    /// Run one in-process rank per thread over the same batch stream.
    fn run_world(cfgs: Vec<DistConfig>, listeners: Vec<TcpListener>,
                 batches: &[(ITensor, Vec<usize>)]) -> Vec<RankRun> {
        let spec = zoo::get("mlp1-mini").unwrap();
        thread::scope(|s| {
            let handles: Vec<_> = cfgs
                .into_iter()
                .zip(listeners)
                .map(|(cfg, listener)| {
                    let spec = spec.clone();
                    s.spawn(move || {
                        let mut net = Network::new(spec, 7);
                        net.set_dropout(0.25, 0.25);
                        let mut drop =
                            DropoutRngs::new(9, net.blocks.len());
                        let mut dt = DistTrainer::with_listener(
                            &net, cfg, listener,
                        )
                        .unwrap();
                        dt.wait_connected(800);
                        let mut reports = Vec::new();
                        let mut crashed = false;
                        for (x, y) in batches {
                            match dt.step(&mut net, x, y, &HP, &mut drop)
                            {
                                Some(r) => reports.push(r),
                                None => {
                                    crashed = true;
                                    break;
                                }
                            }
                        }
                        let stats = dt.stats();
                        RankRun {
                            reports,
                            crashed,
                            weights: weights_of(&net),
                            stats,
                        }
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    fn assert_reports(got: &[StepReport], want: &[StepReport],
                      what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: step count");
        for (g, w) in got.iter().zip(want) {
            assert_eq!(g.block_loss, w.block_loss, "{what}");
            assert_eq!(g.head_loss, w.head_loss, "{what}");
            assert_eq!(g.correct, w.correct, "{what}");
        }
    }

    #[test]
    fn config_validation() {
        let net = Network::new(zoo::get("mlp1-mini").unwrap(), 1);
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let err = DistTrainer::with_listener(
            &net, DistConfig::default(), l,
        )
        .unwrap_err();
        assert!(err.contains("at least one"), "{err}");
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let cfg = DistConfig {
            rank: 2,
            peers: vec!["a".into(), "b".into()],
            ..Default::default()
        };
        let err =
            DistTrainer::with_listener(&net, cfg, l).unwrap_err();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn world_1_matches_train_batch() {
        let spec = zoo::get("mlp1-mini").unwrap();
        let batches = toy_batches(&spec, 3, 8, 21);
        let mut net_ref = Network::new(spec.clone(), 7);
        net_ref.set_dropout(0.25, 0.25);
        let mut drop_ref = DropoutRngs::new(9, net_ref.blocks.len());
        let want: Vec<StepReport> = batches
            .iter()
            .map(|(x, y)| net_ref.train_batch(x, y, &HP, &mut drop_ref))
            .collect();
        let (peers, mut listeners) = bind_world(1);
        let mut net = Network::new(spec, 7);
        net.set_dropout(0.25, 0.25);
        let mut drop = DropoutRngs::new(9, net.blocks.len());
        let mut dt = DistTrainer::with_listener(
            &net, cfg_for(0, &peers), listeners.pop().unwrap(),
        )
        .unwrap();
        let got: Vec<StepReport> = batches
            .iter()
            .map(|(x, y)| {
                dt.step(&mut net, x, y, &HP, &mut drop).unwrap()
            })
            .collect();
        assert_reports(&got, &want, "world=1");
        assert_eq!(weights_of(&net), weights_of(&net_ref));
    }

    #[test]
    fn world_2_and_3_byte_identical_to_replicated() {
        for world in [2usize, 3] {
            let spec = zoo::get("mlp1-mini").unwrap();
            // batch 10 over world 3: uneven shards (4/3/3)
            let batches = toy_batches(&spec, 4, 10, 11);
            let (want, net_ref) = reference(world, &batches);
            let want_w = weights_of(&net_ref);
            let (peers, listeners) = bind_world(world);
            let cfgs =
                (0..world).map(|r| cfg_for(r, &peers)).collect();
            let runs = run_world(cfgs, listeners, &batches);
            let mut remote = 0;
            for (r, run) in runs.iter().enumerate() {
                assert!(!run.crashed, "rank {r} crashed");
                assert_reports(&run.reports, &want,
                               &format!("world={world} rank={r}"));
                assert_eq!(run.weights, want_w,
                           "world={world} rank={r}: weights diverged");
                remote += run.stats.remote_shards_used;
            }
            assert!(remote > 0,
                    "world={world}: the mesh never carried a shard");
        }
    }

    #[test]
    fn drop_fault_degrades_to_solo_compute() {
        // rank 0 drops everything it would send to rank 1 (grad frames,
        // heartbeats, connects): rank 1 must mark it dead and recompute
        // its shard locally — byte-identical anyway
        let spec = zoo::get("mlp1-mini").unwrap();
        let batches = toy_batches(&spec, 4, 10, 41);
        let (want, net_ref) = reference(2, &batches);
        let plan = FaultPlan::parse(
            r#"[{"kind": "drop", "rank": 0, "peer": 1}]"#,
        )
        .unwrap();
        let (peers, listeners) = bind_world(2);
        let cfgs = (0..2)
            .map(|r| {
                let mut c = cfg_for(r, &peers);
                c.fault = plan.clone();
                c.step_wait_ms = 150;
                c
            })
            .collect();
        let runs = run_world(cfgs, listeners, &batches);
        let want_w = weights_of(&net_ref);
        for (r, run) in runs.iter().enumerate() {
            assert_reports(&run.reports, &want, &format!("drop rank={r}"));
            assert_eq!(run.weights, want_w, "drop rank={r}: weights");
        }
        assert!(runs[1].stats.solo_shards > 0,
                "rank 1 never fell back to solo compute");
    }

    #[test]
    fn delay_fault_still_uses_remote_shards() {
        // every frame rank 0 sends is held 25 ms: well within the step
        // deadline, so peers wait it out and still fold the remote shard
        let spec = zoo::get("mlp1-mini").unwrap();
        let batches = toy_batches(&spec, 4, 10, 43);
        let (want, net_ref) = reference(2, &batches);
        let plan = FaultPlan::parse(
            r#"[{"kind": "delay", "rank": 0, "ms": 25}]"#,
        )
        .unwrap();
        let (peers, listeners) = bind_world(2);
        let cfgs = (0..2)
            .map(|r| {
                let mut c = cfg_for(r, &peers);
                c.fault = plan.clone();
                c
            })
            .collect();
        let runs = run_world(cfgs, listeners, &batches);
        let want_w = weights_of(&net_ref);
        for (r, run) in runs.iter().enumerate() {
            assert_reports(&run.reports, &want,
                           &format!("delay rank={r}"));
            assert_eq!(run.weights, want_w, "delay rank={r}: weights");
        }
        assert!(runs[1].stats.remote_shards_used > 0,
                "delayed frames should still arrive in time");
    }

    #[test]
    fn stall_fault_is_cut_by_the_step_deadline() {
        // rank 0 stalls its frames to rank 1 for 500 ms during steps
        // [1, 3) while rank 1's deadline is 80 ms: rank 1 must cut the
        // wait, solo-compute, and stay byte-identical
        let spec = zoo::get("mlp1-mini").unwrap();
        let batches = toy_batches(&spec, 4, 10, 47);
        let (want, net_ref) = reference(2, &batches);
        let plan = FaultPlan::parse(
            r#"[{"kind": "stall", "rank": 0, "peer": 1, "step": 1,
                 "until_step": 3, "ms": 500}]"#,
        )
        .unwrap();
        let (peers, listeners) = bind_world(2);
        let cfgs = (0..2)
            .map(|r| {
                let mut c = cfg_for(r, &peers);
                c.fault = plan.clone();
                c.step_wait_ms = 80;
                c
            })
            .collect();
        let runs = run_world(cfgs, listeners, &batches);
        let want_w = weights_of(&net_ref);
        for (r, run) in runs.iter().enumerate() {
            assert_reports(&run.reports, &want,
                           &format!("stall rank={r}"));
            assert_eq!(run.weights, want_w, "stall rank={r}: weights");
        }
        assert!(runs[1].stats.solo_shards > 0,
                "rank 1 never cut a stalled wait");
    }

    #[test]
    fn partition_window_heals_and_stays_identical() {
        // full bidirectional partition over steps [1, 3) — the seam is
        // sender-side, so both direction rules are listed. During the
        // window both ranks solo-compute; afterwards the connectors
        // re-dial and the mesh heals. Identity holds throughout, and at
        // least one rank observes the alive-set change (a view bump).
        let spec = zoo::get("mlp1-mini").unwrap();
        let batches = toy_batches(&spec, 6, 10, 53);
        let (want, net_ref) = reference(2, &batches);
        let plan = FaultPlan::parse(
            r#"[{"kind": "partition", "rank": 0, "peer": 1,
                 "step": 1, "until_step": 3},
                {"kind": "partition", "rank": 1, "peer": 0,
                 "step": 1, "until_step": 3}]"#,
        )
        .unwrap();
        let (peers, listeners) = bind_world(2);
        let cfgs = (0..2)
            .map(|r| {
                let mut c = cfg_for(r, &peers);
                c.fault = plan.clone();
                c.step_wait_ms = 100;
                c.peer_dead_ms = 150;
                c.pace_ms = 20;
                c
            })
            .collect();
        let runs = run_world(cfgs, listeners, &batches);
        let want_w = weights_of(&net_ref);
        for (r, run) in runs.iter().enumerate() {
            assert_reports(&run.reports, &want,
                           &format!("partition rank={r}"));
            assert_eq!(run.weights, want_w,
                       "partition rank={r}: weights");
            assert!(run.stats.solo_shards > 0,
                    "rank {r} never soloed through the partition");
        }
        assert!(runs.iter().any(|r| r.stats.view >= 1),
                "no rank observed a ring re-formation");
    }

    #[test]
    fn crash_at_step_then_elastic_rejoin_byte_identical() {
        // rank 1 crashes after finishing step 2; rank 0 survives the
        // whole run degraded. Rank 1 then restarts from its step-0
        // state, rebinds the same port, replays at full speed (its peer
        // is ahead, so it never waits), re-enters the mesh, and both
        // ranks finish with weights byte-identical to the uninterrupted
        // replicas=2 reference.
        let spec = zoo::get("mlp1-mini").unwrap();
        let batches = toy_batches(&spec, 10, 10, 31);
        let (_want, net_ref) = reference(2, &batches);
        let want_w = weights_of(&net_ref);
        let plan = FaultPlan::parse(
            r#"[{"kind": "crash", "rank": 1, "step": 2}]"#,
        )
        .unwrap();
        let (peers, mut listeners) = bind_world(2);
        let l1 = listeners.pop().unwrap();
        let l0 = listeners.pop().unwrap();
        let rank1_done = AtomicBool::new(false);
        let (w0, w1, remote1) = thread::scope(|s| {
            let h0 = s.spawn(|| {
                let mut net = Network::new(spec.clone(), 7);
                net.set_dropout(0.25, 0.25);
                let mut drop = DropoutRngs::new(9, net.blocks.len());
                let mut cfg = cfg_for(0, &peers);
                cfg.fault = plan.clone();
                cfg.step_wait_ms = 300;
                cfg.peer_dead_ms = 150;
                // throttle the survivor so the test can demonstrate the
                // rejoiner actually catching up mid-run
                cfg.pace_ms = 25;
                let mut dt =
                    DistTrainer::with_listener(&net, cfg, l0).unwrap();
                dt.wait_connected(800);
                for (x, y) in &batches {
                    dt.step(&mut net, x, y, &HP, &mut drop).unwrap();
                }
                // hold the mesh open until the rejoined rank finishes
                let deadline = Instant::now() + Duration::from_secs(10);
                while !rank1_done.load(Ordering::Relaxed)
                    && Instant::now() < deadline
                {
                    thread::sleep(Duration::from_millis(5));
                }
                weights_of(&net)
            });
            let h1 = s.spawn(|| {
                {
                    // first life: dies right after finishing step 2
                    let mut net = Network::new(spec.clone(), 7);
                    net.set_dropout(0.25, 0.25);
                    let mut drop =
                        DropoutRngs::new(9, net.blocks.len());
                    let mut cfg = cfg_for(1, &peers);
                    cfg.fault = plan.clone();
                    let mut dt = DistTrainer::with_listener(
                        &net, cfg, l1,
                    )
                    .unwrap();
                    dt.wait_connected(800);
                    let mut done = 0usize;
                    for (x, y) in &batches {
                        match dt.step(&mut net, x, y, &HP, &mut drop) {
                            Some(_) => done += 1,
                            None => break,
                        }
                    }
                    assert_eq!(done, 2,
                               "crash must fire after finishing step 2");
                } // trainer dropped: port released like a dead process
                // second life: restart from the step-0 state with the
                // fault cleared (an operator restart), rebinding the
                // same address
                let mut net = Network::new(spec.clone(), 7);
                net.set_dropout(0.25, 0.25);
                let mut drop = DropoutRngs::new(9, net.blocks.len());
                let cfg = cfg_for(1, &peers);
                let mut dt = DistTrainer::new(&net, cfg).unwrap();
                for (x, y) in &batches {
                    dt.step(&mut net, x, y, &HP, &mut drop).unwrap();
                }
                let stats = dt.stats();
                rank1_done.store(true, Ordering::Relaxed);
                (weights_of(&net), stats)
            });
            let w0 = h0.join().unwrap();
            let (w1, st1) = h1.join().unwrap();
            (w0, w1, st1.remote_shards_used)
        });
        assert_eq!(w0, want_w, "survivor weights diverged");
        assert_eq!(w1, want_w, "rejoined rank weights diverged");
        assert!(remote1 > 0,
                "the rejoined rank never re-entered the mesh");
    }
}
