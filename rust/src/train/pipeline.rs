//! Cross-batch pipelined LES scheduler: persistent block-stage workers.
//!
//! NITRO-D's local-loss blocks are independent in the backward direction
//! (paper §3.3), which the block-parallel scheduler exploits *within* one
//! batch. This module exploits it *across* batches: every block — plus the
//! head — becomes a long-lived pipeline stage with its own worker thread,
//! bounded activation queues between stages, and its own dropout RNG
//! stream. While block `l` trains on batch `t`, block `l+1` is still on
//! batch `t-1`: steady-state throughput approaches `(#blocks + 1)×` the
//! sequential step rate on sufficiently parallel hardware.
//!
//! ## Why this is bit-identical to sequential order
//!
//! In sequential mode, block `l` processes batch `t` with the weights it
//! produced after updating on batch `t-1`, reading the activation block
//! `l-1` computed for batch `t` *before* anything downstream ran. Those
//! are exactly the data dependencies the pipeline preserves: each stage
//! consumes batches in order from a FIFO queue against its own weight
//! history, and nothing flows backwards between stages. Dropout masks come
//! from per-block streams ([`crate::nn::DropoutRngs`]), so mask draws
//! depend only on (seed, block, batch ordinal) — not on scheduler
//! interleaving. The property test below and `bench-kernels` enforce the
//! equivalence on weights, losses and accuracy.
//!
//! ## Threading/budget model
//!
//! Stage workers are plain threads that live for the whole `fit` run
//! (parked on their queue when idle) and coexist with the kernel pool
//! under the single `NITRO_WORKERS` budget: the stage threads *are* the
//! budget, so `fit` builds a pipeline only when
//! `NITRO_WORKERS >= blocks + 1`, and each stage sets its thread-local
//! kernel budget to `max(1, NITRO_WORKERS / stages)`
//! ([`crate::util::par::set_thread_workers`]) — with budget == stages
//! every kernel runs inline on its stage and total thread usage stays at
//! the budget. Smaller budgets degrade to the block-parallel scheduler
//! (bit-identical results); `NITRO_WORKERS=1` runs sequential order
//! inline, preserving the no-thread guarantee.
//!
//! ## Epoch synchronisation
//!
//! Evaluation, plateau scheduling and checkpointing need the whole network
//! in one place, so at every epoch boundary the trainer calls
//! [`Pipeline::sync`]: a `Sync` marker flushes through the queues behind
//! the last batch, each stage hands its block back to the `Network`, and
//! the stage parks until [`Pipeline::resume`] returns the block for the
//! next epoch (or [`Pipeline::shutdown`] joins the workers). Input batch
//! tensors are recycled through a return channel, so the steady state
//! performs no per-batch gather allocation.

use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};

use crate::nn::block::count_correct;
use crate::nn::{Block, DropoutRngs, Head, Hyper, Network, StepReport};
use crate::tensor::{one_hot32, ITensor};
use crate::util::par;
use crate::util::rng::Pcg32;

/// Bounded depth of each inter-stage activation queue. Depth 2 lets a
/// stage run ahead without stalling on momentary imbalance while keeping
/// at most `stages * 2 + stages` batches in flight.
const QUEUE_DEPTH: usize = 2;

/// One batch travelling through the pipeline. Owned end to end — the
/// activation is moved stage to stage, never cloned; `y32` and `labels`
/// ride along because exactly one stage holds the job at a time.
struct Job {
    /// Stage input: the raw batch for stage 0, block `l-1`'s output for
    /// stage `l`. Conv→linear flatten boundaries need no reshape — the
    /// matmuls read activations as logical (B, F).
    a: ITensor,
    y32: ITensor,
    labels: Vec<usize>,
    hp: Hyper,
    /// Local losses accumulated in block order as the job flows.
    block_loss: Vec<i64>,
}

enum Msg {
    Job(Box<Job>),
    /// Epoch barrier: forwarded downstream behind the last job; the stage
    /// then returns its block and parks on its resume channel.
    Sync,
}

/// Stage state handed back to the trainer at a sync point.
enum Returned {
    Block(usize, Block),
    Head(Head),
}

enum Resume {
    Block(Block),
    Head(Head),
    Exit,
}

#[allow(clippy::too_many_arguments)] // stage wiring: channels are the point
fn block_stage(l: usize, mut blk: Block, mut drop_rng: Pcg32,
               rx: Receiver<Msg>, tx: SyncSender<Msg>,
               recycle: Option<Sender<ITensor>>, ret: Sender<Returned>,
               resume: Receiver<Resume>, kernel_budget: usize) {
    par::set_thread_workers(kernel_budget);
    loop {
        match rx.recv() {
            Ok(Msg::Job(mut job)) => {
                let cache = blk.forward_train(&job.a, Some(&mut drop_rng));
                let loss = blk.backward_step(&job.a, &cache, &job.y32,
                                             &job.hp);
                job.block_loss.push(loss);
                // hand the output on by value; the spent input goes back
                // to the feeder for reuse (stage 0) or is dropped
                let spent = std::mem::replace(&mut job.a, cache.a_out);
                if let Some(r) = &recycle {
                    let _ = r.send(spent);
                }
                if tx.send(Msg::Job(job)).is_err() {
                    return; // downstream died; trainer observes via feed
                }
            }
            Ok(Msg::Sync) => {
                let _ = tx.send(Msg::Sync);
                if ret.send(Returned::Block(l, blk)).is_err() {
                    return;
                }
                match resume.recv() {
                    Ok(Resume::Block(b)) => blk = b,
                    _ => return,
                }
            }
            Err(_) => return,
        }
    }
}

fn head_stage(mut head: Head, rx: Receiver<Msg>, reports: Sender<StepReport>,
              ret: Sender<Returned>, resume: Receiver<Resume>,
              kernel_budget: usize) {
    par::set_thread_workers(kernel_budget);
    loop {
        match rx.recv() {
            Ok(Msg::Job(job)) => {
                let job = *job;
                let (yhat, head_loss) =
                    head.train_step(&job.a, &job.y32, &job.hp);
                let correct = count_correct(&yhat, &job.labels);
                let rep = StepReport {
                    block_loss: job.block_loss,
                    head_loss,
                    correct,
                };
                if reports.send(rep).is_err() {
                    return;
                }
            }
            Ok(Msg::Sync) => {
                if ret.send(Returned::Head(head)).is_err() {
                    return;
                }
                match resume.recv() {
                    Ok(Resume::Head(h)) => head = h,
                    _ => return,
                }
            }
            Err(_) => return,
        }
    }
}

/// The persistent block-stage pipeline. Owns the network's blocks (and
/// head) while running; [`Self::sync`] returns them to the `Network` for
/// evaluation between epochs.
pub struct Pipeline {
    feed_tx: Option<SyncSender<Msg>>,
    report_rx: Receiver<StepReport>,
    recycle_rx: Receiver<ITensor>,
    ret_rx: Receiver<Returned>,
    resume_txs: Vec<Sender<Resume>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    nblocks: usize,
    num_classes: usize,
    in_flight: usize,
    running: bool,
}

impl Pipeline {
    /// Spawn one stage worker per block plus the head stage, moving the
    /// blocks out of `net`. Dropout streams are derived from `seed`
    /// exactly as [`DropoutRngs::new`] does for the other schedulers.
    pub fn start(net: &mut Network, seed: u64) -> Pipeline {
        let nblocks = net.blocks.len();
        assert!(nblocks > 0, "pipeline needs at least one block");
        let nstages = nblocks + 1;
        let kernel_budget = (par::current_workers() / nstages).max(1);
        let (feed_tx, mut next_rx) = sync_channel::<Msg>(QUEUE_DEPTH);
        let (ret_tx, ret_rx) = channel();
        let (report_tx, report_rx) = channel();
        let (recycle_tx, recycle_rx) = channel();
        let mut resume_txs = Vec::with_capacity(nstages);
        let mut handles = Vec::with_capacity(nstages);
        let streams = DropoutRngs::new(seed, nblocks).into_streams();
        let num_classes = net.spec.num_classes;
        for (l, (blk, drop_rng)) in
            net.blocks.drain(..).zip(streams).enumerate()
        {
            let (tx, downstream_rx) = sync_channel::<Msg>(QUEUE_DEPTH);
            let rx = std::mem::replace(&mut next_rx, downstream_rx);
            let (res_tx, res_rx) = channel();
            resume_txs.push(res_tx);
            let ret = ret_tx.clone();
            let recycle = (l == 0).then(|| recycle_tx.clone());
            handles.push(
                std::thread::Builder::new()
                    .name(format!("nitro-stage-{l}"))
                    .spawn(move || {
                        block_stage(l, blk, drop_rng, rx, tx, recycle, ret,
                                    res_rx, kernel_budget)
                    })
                    .expect("spawn pipeline stage worker"),
            );
        }
        let (res_tx, res_rx) = channel();
        resume_txs.push(res_tx);
        let head = net.head.take();
        handles.push(
            std::thread::Builder::new()
                .name("nitro-stage-head".to_string())
                .spawn(move || {
                    head_stage(head, next_rx, report_tx, ret_tx, res_rx,
                               kernel_budget)
                })
                .expect("spawn pipeline head worker"),
        );
        Pipeline {
            feed_tx: Some(feed_tx),
            report_rx,
            recycle_rx,
            ret_rx,
            resume_txs,
            handles,
            nblocks,
            num_classes,
            in_flight: 0,
            running: true,
        }
    }

    /// A spent input batch tensor returned by stage 0, or a fresh empty
    /// one — the feeder gathers the next batch straight into it.
    pub fn recycled(&mut self) -> ITensor {
        self.recycle_rx.try_recv().unwrap_or_else(|_| ITensor::empty())
    }

    /// A stage worker died (its channel hung up): disconnect everything
    /// so the remaining stages unwind, reap the threads, and re-raise the
    /// original panic payload on the caller — the same contract the
    /// worker pool gives kernel tasks. Falls back to a generic panic if
    /// no payload is found (should not happen).
    fn die(&mut self, context: &str) -> ! {
        self.feed_tx = None;
        self.resume_txs.clear();
        let mut payload: Option<Box<dyn std::any::Any + Send>> = None;
        for h in self.handles.drain(..) {
            if let Err(e) = h.join() {
                payload.get_or_insert(e);
            }
        }
        eprintln!("pipeline: {context}");
        match payload {
            Some(p) => std::panic::resume_unwind(p),
            None => panic!("pipeline: {context}"),
        }
    }

    /// Push one batch into stage 0 (blocking only when the pipeline is
    /// full — that backpressure is what bounds in-flight memory) and drain
    /// any reports the head has finished in the meantime.
    pub fn feed(&mut self, x: ITensor, labels: &[usize], hp: &Hyper,
                reports: &mut Vec<StepReport>) {
        assert!(self.running, "feed on a synced pipeline");
        let job = Box::new(Job {
            y32: one_hot32(labels, self.num_classes),
            a: x,
            labels: labels.to_vec(),
            hp: *hp,
            block_loss: Vec::with_capacity(self.nblocks),
        });
        if self
            .feed_tx
            .as_ref()
            .expect("pipeline was shut down")
            .send(Msg::Job(job))
            .is_err()
        {
            self.die("stage worker died while feeding a batch");
        }
        self.in_flight += 1;
        while let Ok(r) = self.report_rx.try_recv() {
            self.in_flight -= 1;
            reports.push(r);
        }
    }

    /// Epoch barrier: wait for every in-flight batch, collect the
    /// remaining reports, and move all blocks (and the head) back into
    /// `net` so the caller can evaluate/checkpoint. Call
    /// [`Self::resume`] before feeding again.
    pub fn sync(&mut self, net: &mut Network,
                reports: &mut Vec<StepReport>) {
        assert!(self.running, "sync on an already-synced pipeline");
        if self.feed_tx.as_ref().unwrap().send(Msg::Sync).is_err() {
            self.die("stage worker died before the epoch barrier");
        }
        while self.in_flight > 0 {
            match self.report_rx.recv() {
                Ok(r) => {
                    self.in_flight -= 1;
                    reports.push(r);
                }
                Err(_) => self.die("stage worker died mid-epoch"),
            }
        }
        let mut blocks: Vec<Option<Block>> =
            std::iter::repeat_with(|| None).take(self.nblocks).collect();
        for _ in 0..self.nblocks + 1 {
            match self.ret_rx.recv() {
                Ok(Returned::Block(i, b)) => blocks[i] = Some(b),
                Ok(Returned::Head(h)) => net.head.restore(h),
                Err(_) => self.die("stage worker died at the epoch barrier"),
            }
        }
        debug_assert!(net.blocks.is_empty());
        net.blocks
            .extend(blocks.into_iter().map(|b| b.expect("stage returned")));
        self.running = false;
    }

    /// Whether the blocks currently live in the stages (`true`) or in the
    /// `Network` (`false`, after a [`Self::sync`]).
    pub fn is_running(&self) -> bool {
        self.running
    }

    /// Hand the blocks back to the parked stage workers for the next
    /// epoch.
    pub fn resume(&mut self, net: &mut Network) {
        assert!(!self.running, "resume on a running pipeline");
        assert_eq!(net.blocks.len(), self.nblocks);
        for (tx, blk) in self.resume_txs.iter().zip(net.blocks.drain(..)) {
            tx.send(Resume::Block(blk))
                .expect("pipeline stage worker died");
        }
        self.resume_txs
            .last()
            .unwrap()
            .send(Resume::Head(net.head.take()))
            .expect("pipeline head worker died");
        self.running = true;
    }

    /// Clean teardown: sync if needed (returning any residual reports),
    /// tell every stage to exit, and join the workers.
    pub fn shutdown(mut self, net: &mut Network,
                    reports: &mut Vec<StepReport>) {
        if self.running {
            self.sync(net, reports);
        }
        for tx in &self.resume_txs {
            let _ = tx.send(Resume::Exit);
        }
        self.feed_tx = None;
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        // Abnormal teardown (caller panic / early drop): disconnect every
        // channel so the stage cascade unwinds — a stage blocked on recv
        // sees the hangup, drops its own sender, and the next stage
        // follows — then reap the threads. In-flight state is lost; the
        // normal path goes through `shutdown`, which leaves `handles`
        // empty so this is a no-op.
        self.feed_tx = None;
        self.resume_txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::nn::{zoo, Hyper};
    use crate::train::{fit, Scheduler, TrainConfig};

    /// Restore the thread-local worker budget even on panic.
    struct BudgetGuard;
    impl Drop for BudgetGuard {
        fn drop(&mut self) {
            par::set_thread_workers(0);
        }
    }

    fn data() -> (crate::data::Dataset, crate::data::Dataset) {
        let ds = synthetic::by_name("tiny", 260, 3).unwrap();
        let (mut tr, mut te) = ds.split_test(60);
        tr.mad_normalize();
        te.mad_normalize();
        (tr, te)
    }

    fn run(sched: Scheduler, dropout: f64, cfg0: &TrainConfig)
           -> (crate::train::TrainResult, Network) {
        let (tr, te) = data();
        // tinycnn = conv -> conv -> linear block -> head: covers the
        // conv→linear flatten boundary inside the pipeline
        let mut net = Network::new(zoo::get("tinycnn").unwrap(), 2);
        net.set_dropout(dropout, dropout);
        let cfg = TrainConfig { scheduler: sched, ..cfg0.clone() };
        let res = fit(&mut net, &tr, &te, &cfg);
        (res, net)
    }

    fn assert_equal(a: &(crate::train::TrainResult, Network),
                    b: &(crate::train::TrainResult, Network), what: &str) {
        assert_eq!(a.0.epochs.len(), b.0.epochs.len(), "{what}: epoch count");
        for (ea, eb) in a.0.epochs.iter().zip(&b.0.epochs) {
            assert_eq!(ea.mean_head_loss, eb.mean_head_loss,
                       "{what}: head loss epoch {}", ea.epoch);
            assert_eq!(ea.mean_block_loss, eb.mean_block_loss,
                       "{what}: block loss epoch {}", ea.epoch);
            assert_eq!(ea.train_acc, eb.train_acc, "{what}: train acc");
            assert!(
                ea.test_acc == eb.test_acc
                    || (ea.test_acc.is_nan() && eb.test_acc.is_nan()),
                "{what}: test acc epoch {}", ea.epoch
            );
        }
        assert_eq!(a.0.final_test_acc, b.0.final_test_acc, "{what}");
        assert_eq!(a.0.diverged, b.0.diverged, "{what}");
        for ((na, ta), (nb, tb)) in a.1.weights().iter().zip(b.1.weights()) {
            assert_eq!(na, &nb);
            assert_eq!(ta, &tb, "{what}: weight {na} diverged");
        }
    }

    #[test]
    fn pipelined_bitexact_vs_sequential_with_and_without_dropout() {
        // Force a multi-worker budget so the pipeline engages even on a
        // single-core test machine; stages then run kernels inline.
        let _guard = BudgetGuard;
        par::set_thread_workers(4);
        let cfg = TrainConfig {
            epochs: 4,
            batch: 32,
            eval_every: 2, // sync/resume must also cross non-eval epochs
            hyper: Hyper { gamma_inv: 128, eta_fw_inv: 12000,
                           eta_lr_inv: 3000 },
            ..Default::default()
        };
        for dropout in [0.0, 0.25] {
            let seq = run(Scheduler::Sequential, dropout, &cfg);
            let blk = run(Scheduler::BlockParallel, dropout, &cfg);
            let pipe = run(Scheduler::Pipelined, dropout, &cfg);
            assert_equal(&seq, &blk, &format!("block-parallel p={dropout}"));
            assert_equal(&seq, &pipe, &format!("pipelined p={dropout}"));
        }
    }

    #[test]
    fn divergence_early_exit_tears_the_pipeline_down_cleanly() {
        let _guard = BudgetGuard;
        par::set_thread_workers(4);
        // guard of 1 declares any nonzero head loss divergent: the run
        // must break after epoch 0 with batches mid-pipeline drained
        let cfg = TrainConfig {
            epochs: 6,
            batch: 32,
            divergence_guard: 1,
            ..Default::default()
        };
        let seq = run(Scheduler::Sequential, 0.0, &cfg);
        let pipe = run(Scheduler::Pipelined, 0.0, &cfg);
        assert!(pipe.0.diverged, "guard of 1 must trip");
        assert_eq!(pipe.0.epochs.len(), 1, "early exit after epoch 0");
        assert_equal(&seq, &pipe, "diverged run");
        // the network is whole after teardown: inference still works
        let (tr, _) = data();
        let (x, labels) = tr.gather(&[0, 1, 2, 3], false);
        let _ = pipe.1.eval_batch(&x, &labels);
    }

    #[test]
    fn single_worker_budget_never_builds_a_pipeline() {
        let _guard = BudgetGuard;
        par::set_thread_workers(4);
        let cfg = TrainConfig { epochs: 2, batch: 32, ..Default::default() };
        let multi = run(Scheduler::Pipelined, 0.25, &cfg);
        // NITRO_WORKERS=1 semantics via the thread-local budget: the
        // pipelined scheduler must fall back to sequential order inline
        par::set_thread_workers(1);
        let single = run(Scheduler::Pipelined, 0.25, &cfg);
        let seq = run(Scheduler::Sequential, 0.25, &cfg);
        assert_equal(&single, &seq, "workers=1 fallback");
        assert_equal(&single, &multi, "budget must not change results");
    }

    #[test]
    fn sync_resume_shutdown_lifecycle() {
        let _guard = BudgetGuard;
        par::set_thread_workers(4);
        let (tr, _) = data();
        let mut net = Network::new(zoo::get("tinycnn").unwrap(), 2);
        let nblocks = net.blocks.len();
        let hp = Hyper::default();
        let mut pipe = Pipeline::start(&mut net, 7);
        assert!(net.blocks.is_empty(), "stages own the blocks");
        let mut reports = Vec::new();
        for i in 0..3usize {
            let (x, labels) = tr.gather(&[i, i + 1], false);
            pipe.feed(x, &labels, &hp, &mut reports);
        }
        pipe.sync(&mut net, &mut reports);
        assert_eq!(reports.len(), 3, "every fed batch reports once");
        assert_eq!(net.blocks.len(), nblocks, "sync returns the blocks");
        assert!(reports.iter().all(|r| r.block_loss.len() == nblocks));
        pipe.resume(&mut net);
        let (x, labels) = tr.gather(&[5, 6], false);
        pipe.feed(x, &labels, &hp, &mut reports);
        pipe.shutdown(&mut net, &mut reports);
        assert_eq!(reports.len(), 4);
        assert_eq!(net.blocks.len(), nblocks);
    }
}
