//! Wire codec for the distributed trainer: length-prefixed frames,
//! hostile-input hardened like `serve::wire` (DESIGN.md §Serving).
//!
//! Length-prefixed frames: `[u32 LE body_len][u8 type][u32 rank]
//! [u64 step][payload]`. `Hello` carries a magic and the world size;
//! `Grad` carries raw (un-halved) block/head losses, the correct count
//! and the flat i64 gradient tensors; `Heartbeat` is the bare header.
//! Readers enforce a frame-length cap computed from the network's own
//! weight arity ([`grad_frame_len`]), and every count and tensor length
//! in a `Grad` frame must match the local model exactly — a malformed,
//! truncated or oversized frame is an `Err`, never a panic, so the
//! connection drops instead of the process. This module is a `no-panic`
//! surface under `nitro lint`.

// A `no-panic` surface under `nitro lint`: in non-test code, prefer
// `Result` over unwrap/expect (enforced for clippy runs too).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use std::io::{ErrorKind, Read};
use std::net::TcpStream;

use crate::train::replica::ShardOut;

pub(crate) const MAGIC: u32 = 0x4e49_5452; // "NITR"
pub(crate) const T_HELLO: u8 = 1;
pub(crate) const T_GRAD: u8 = 2;
pub(crate) const T_HB: u8 = 3;
/// Frame header: type (1) + rank (4) + step (8).
pub(crate) const HDR_LEN: usize = 13;

fn put_u32(v: &mut Vec<u8>, x: u32) {
    v.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(v: &mut Vec<u8>, x: u64) {
    v.extend_from_slice(&x.to_le_bytes());
}

fn put_i64(v: &mut Vec<u8>, x: i64) {
    v.extend_from_slice(&x.to_le_bytes());
}

fn frame(body: Vec<u8>) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + body.len());
    put_u32(&mut out, body.len() as u32);
    out.extend_from_slice(&body);
    out
}

fn header(t: u8, rank: usize, step: u64, cap: usize) -> Vec<u8> {
    let mut b = Vec::with_capacity(HDR_LEN + cap);
    b.push(t);
    put_u32(&mut b, rank as u32);
    put_u64(&mut b, step);
    b
}

pub(crate) fn encode_hello(rank: usize, world: usize) -> Vec<u8> {
    let mut b = header(T_HELLO, rank, 0, 8);
    put_u32(&mut b, MAGIC);
    put_u32(&mut b, world as u32);
    frame(b)
}

pub(crate) fn encode_hb(rank: usize, step: u64) -> Vec<u8> {
    frame(header(T_HB, rank, step, 0))
}

pub(crate) fn encode_grad(rank: usize, step: u64, out: &ShardOut)
                          -> Vec<u8> {
    let cap: usize =
        out.grads.tensors.iter().map(|t| 4 + 8 * t.data.len()).sum();
    let mut b = header(T_GRAD, rank, step, cap + 64);
    put_u32(&mut b, out.block_loss_raw.len() as u32);
    for &l in &out.block_loss_raw {
        put_i64(&mut b, l);
    }
    put_i64(&mut b, out.head_loss_raw);
    put_u64(&mut b, out.correct as u64);
    put_u32(&mut b, out.grads.tensors.len() as u32);
    for t in &out.grads.tensors {
        put_u32(&mut b, t.data.len() as u32);
        for &g in &t.data {
            put_i64(&mut b, g);
        }
    }
    frame(b)
}

/// Largest legal `Grad` body for a model with `nblocks` blocks and
/// gradient tensor lengths `lens` — the reader's frame cap.
pub(crate) fn grad_frame_len(nblocks: usize, lens: &[usize]) -> usize {
    HDR_LEN + 4 + 8 * nblocks + 8 + 8 + 4
        + lens.iter().map(|&n| 4 + 8 * n).sum::<usize>()
}

/// A peer's shard as it crosses the wire; re-tensored against the
/// local weight shapes on adoption.
pub(crate) struct WireShard {
    pub(crate) block_loss_raw: Vec<i64>,
    pub(crate) head_loss_raw: i64,
    pub(crate) correct: u64,
    pub(crate) tensors: Vec<Vec<i64>>,
}

pub(crate) enum Msg {
    Hello { rank: usize },
    Grad { rank: usize, step: u64, shard: WireShard },
    Heartbeat { rank: usize, step: u64 },
}

/// Bounds-checked little-endian cursor: every read is validated, so a
/// truncated or padded frame is an error, never a panic.
struct Cur<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self.i.checked_add(n).ok_or("truncated frame")?;
        let s = self.b.get(self.i..end).ok_or("truncated frame")?;
        self.i = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(*self.take(1)?.first().ok_or("truncated frame")?)
    }

    fn u32(&mut self) -> Result<u32, String> {
        let a: [u8; 4] =
            self.take(4)?.try_into().map_err(|_| "truncated frame")?;
        Ok(u32::from_le_bytes(a))
    }

    fn u64(&mut self) -> Result<u64, String> {
        let a: [u8; 8] =
            self.take(8)?.try_into().map_err(|_| "truncated frame")?;
        Ok(u64::from_le_bytes(a))
    }

    fn i64(&mut self) -> Result<i64, String> {
        let a: [u8; 8] =
            self.take(8)?.try_into().map_err(|_| "truncated frame")?;
        Ok(i64::from_le_bytes(a))
    }

    fn done(&self) -> Result<(), String> {
        if self.i != self.b.len() {
            return Err("trailing bytes after frame".into());
        }
        Ok(())
    }
}

/// Decode one frame body. Every count is validated against the local
/// model (`world`, `nblocks`, tensor `lens`): a frame that does not
/// match exactly is rejected and the connection is dropped.
pub(crate) fn decode(buf: &[u8], world: usize, nblocks: usize,
                     lens: &[usize]) -> Result<Msg, String> {
    let mut c = Cur { b: buf, i: 0 };
    let t = c.u8()?;
    let rank = c.u32()? as usize;
    let step = c.u64()?;
    if rank >= world {
        return Err(format!("frame rank {rank} >= world {world}"));
    }
    match t {
        T_HELLO => {
            if c.u32()? != MAGIC {
                return Err("bad hello magic".into());
            }
            let w = c.u32()? as usize;
            if w != world {
                return Err(format!(
                    "world mismatch: peer says {w}, ours is {world}"
                ));
            }
            c.done()?;
            Ok(Msg::Hello { rank })
        }
        T_HB => {
            c.done()?;
            Ok(Msg::Heartbeat { rank, step })
        }
        T_GRAD => {
            let nb = c.u32()? as usize;
            if nb != nblocks {
                return Err(format!("grad blocks {nb} != {nblocks}"));
            }
            let mut block_loss_raw = Vec::with_capacity(nb);
            for _ in 0..nb {
                block_loss_raw.push(c.i64()?);
            }
            let head_loss_raw = c.i64()?;
            let correct = c.u64()?;
            let nt = c.u32()? as usize;
            if nt != lens.len() {
                return Err(format!("grad arity {nt} != {}", lens.len()));
            }
            let mut tensors = Vec::with_capacity(nt);
            for (i, &want) in lens.iter().enumerate() {
                let n = c.u32()? as usize;
                if n != want {
                    return Err(format!(
                        "grad tensor {i} length {n} != {want}"
                    ));
                }
                let mut t = Vec::with_capacity(n);
                for _ in 0..n {
                    t.push(c.i64()?);
                }
                tensors.push(t);
            }
            c.done()?;
            Ok(Msg::Grad {
                rank,
                step,
                shard: WireShard {
                    block_loss_raw,
                    head_loss_raw,
                    correct,
                    tensors,
                },
            })
        }
        other => Err(format!("unknown frame type {other}")),
    }
}

/// Read one length-prefixed frame body into `buf`, enforcing the
/// model-derived size cap before allocating or reading the body.
pub(crate) fn read_frame(s: &mut TcpStream, max: usize, buf: &mut Vec<u8>)
                         -> std::io::Result<()> {
    let mut len4 = [0u8; 4];
    s.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if !(HDR_LEN..=max).contains(&len) {
        return Err(std::io::Error::new(
            ErrorKind::InvalidData,
            format!("frame length {len} outside [{HDR_LEN}, {max}]"),
        ));
    }
    buf.resize(len, 0);
    s.read_exact(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::LTensor;
    use crate::train::replica::GradSet;

    #[test]
    fn codec_roundtrip_and_hostile_frame_rejection() {
        let lens = [6usize, 4];
        let shard = ShardOut {
            block_loss_raw: vec![7, -9],
            head_loss_raw: -11,
            correct: 3,
            grads: GradSet {
                tensors: vec![
                    LTensor::from_vec(
                        &[2, 3],
                        (0..6).map(|i| i as i64 - 3).collect(),
                    ),
                    LTensor::from_vec(
                        &[4],
                        vec![i64::MAX, i64::MIN, 0, 1],
                    ),
                ],
            },
        };
        let f = encode_grad(1, 5, &shard);
        let body = &f[4..];
        assert_eq!(
            u32::from_le_bytes(f[..4].try_into().unwrap()) as usize,
            body.len()
        );
        // the cap derived from the model admits exactly this frame
        assert_eq!(body.len(), grad_frame_len(2, &lens));
        match decode(body, 3, 2, &lens).unwrap() {
            Msg::Grad { rank, step, shard: ws } => {
                assert_eq!((rank, step), (1, 5));
                assert_eq!(ws.block_loss_raw, vec![7, -9]);
                assert_eq!(ws.head_loss_raw, -11);
                assert_eq!(ws.correct, 3);
                assert_eq!(ws.tensors[0],
                           (0..6).map(|i| i as i64 - 3).collect::<Vec<_>>());
                assert_eq!(ws.tensors[1],
                           vec![i64::MAX, i64::MIN, 0, 1]);
            }
            _ => panic!("decoded to the wrong message type"),
        }
        let hello = encode_hello(2, 3);
        assert!(matches!(decode(&hello[4..], 3, 2, &lens),
                         Ok(Msg::Hello { rank: 2 })));
        let hb = encode_hb(0, 9);
        assert!(matches!(decode(&hb[4..], 3, 2, &lens),
                         Ok(Msg::Heartbeat { rank: 0, step: 9 })));
        // hostile inputs: every malformation is an error, never a panic
        let mut truncated = body.to_vec();
        truncated.pop();
        let mut padded = body.to_vec();
        padded.push(0);
        let mut bad_type = body.to_vec();
        bad_type[0] = 99;
        let mut bad_magic = hello[4..].to_vec();
        bad_magic[HDR_LEN] ^= 0xff;
        for (buf, world, needle) in [
            (&truncated, 3, "truncated"),
            (&padded, 3, "trailing"),
            (&bad_type, 3, "unknown frame type"),
            (&bad_magic, 3, "magic"),
            // sender rank out of range for the world
            (&body.to_vec(), 1, ">= world"),
            // world-size mismatch in the handshake
            (&encode_hello(0, 2)[4..].to_vec(), 3, "world mismatch"),
        ] {
            let err =
                decode(buf, world, 2, &lens).unwrap_err();
            assert!(err.contains(needle), "wanted {needle}: {err}");
        }
        // tensor arity/length mismatches against the local model
        assert!(decode(body, 3, 1, &lens).unwrap_err().contains("blocks"));
        assert!(decode(body, 3, 2, &[6]).unwrap_err().contains("arity"));
        assert!(decode(body, 3, 2, &[6, 5])
            .unwrap_err()
            .contains("length"));
    }
}
