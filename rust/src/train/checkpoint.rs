//! Checkpointing: save/restore integer network weights in a simple
//! self-describing binary container.
//!
//! Format (`NITRO1`, fully specified in README.md §Checkpoint format):
//! magic `NITRO1\n`, u32-LE JSON-header length, JSON header (spec name,
//! tensor names/shapes), then raw little-endian i32 payloads in header
//! order. Integer weights round-trip exactly — which is what makes the
//! paper's "local fine-tuning after deployment" story (App. E.3) work:
//! a checkpoint *is* the deployed model, no quantization step.
//!
//! Robustness contract (the serving path feeds this untrusted bytes):
//! * [`load`] / [`load_network`] return `Err` on **every** malformed
//!   input — truncation at any byte, oversized header length, bad JSON,
//!   shape/spec mismatches, trailing bytes — and never panic.
//! * [`save`] writes to a temp file in the target directory, fsyncs it,
//!   atomically renames it into place, and fsyncs the directory, so a
//!   checkpoint path always holds either the previous complete model or
//!   the new one — never a torn write — **and** an `Ok` return means the
//!   new bytes survive a power loss. (Rename alone is atomic against a
//!   process crash but not durable: without `sync_all` on the file the
//!   rename can land on disk before the data, leaving a zero-length or
//!   stale "successful" checkpoint after a machine crash — exactly the
//!   file elastic rejoin would then try to resume from.)
//!
//! Mid-run checkpoints carry a `train_state` header key
//! ([`save_with_state`] / [`load_state`]): the completed-epoch counter
//! and the plateau scheduler's history. Readers that do not ask for it
//! ignore unknown header keys, so stateful checkpoints stay loadable
//! everywhere a plain one is.

// A `no-panic` surface under `nitro lint`: in non-test code, prefer
// `Result` over unwrap/expect (enforced for clippy runs too).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

use crate::nn::spec::BitsPlan;
use crate::nn::{zoo, Network};
use crate::optim::PlateauState;
use crate::util::jsonio::Json;

const MAGIC: &[u8] = b"NITRO1\n";

/// Training progress stored in mid-run checkpoints: everything `fit`
/// needs to continue a run exactly where it stopped (the weights are the
/// payload; the RNG streams are recomputed by replaying their draw
/// counts from the epoch number).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrainState {
    /// Epochs fully completed; resume starts at this epoch index.
    pub epoch: usize,
    /// Plateau LR scheduler history (best accuracy seen, staleness) —
    /// history-dependent, so it cannot be reconstructed from the epoch
    /// number alone.
    pub plateau: PlateauState,
}

pub fn save(net: &Network, path: &str) -> Result<(), String> {
    save_impl(net, path, None)
}

/// [`save`] plus a `train_state` header key — the periodic mid-run
/// checkpoint form used for crash recovery and elastic rejoin.
pub fn save_with_state(net: &Network, path: &str, state: &TrainState)
                       -> Result<(), String> {
    save_impl(net, path, Some(state))
}

fn save_impl(net: &Network, path: &str, state: Option<&TrainState>)
             -> Result<(), String> {
    let weights = net.weights();
    let mut names = Vec::new();
    let mut shapes = Vec::new();
    for (i, (kind, t)) in weights.iter().enumerate() {
        names.push(Json::Str(format!("{i}:{kind}")));
        shapes.push(Json::ints(
            &t.shape.iter().map(|&d| d as i64).collect::<Vec<_>>(),
        ));
    }
    let mut fields = vec![
        ("spec", Json::Str(net.spec.name.clone())),
        ("tensors", Json::Array(names)),
        ("shapes", Json::Array(shapes)),
    ];
    // written only for non-default rails: old readers ignore unknown
    // header keys, so default-config checkpoints stay byte-compatible
    // both ways
    if !net.spec.bits.is_default() {
        fields.push(("bits", net.spec.bits.to_json()));
    }
    if let Some(s) = state {
        fields.push(("train_state", state_to_json(s)));
    }
    let header = Json::obj(fields).dump();
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend((header.len() as u32).to_le_bytes());
    buf.extend(header.as_bytes());
    for (_, t) in &weights {
        for &v in &t.data {
            buf.extend(v.to_le_bytes());
        }
    }
    atomic_write(path, &buf)
}

fn state_to_json(s: &TrainState) -> Json {
    Json::obj(vec![
        ("epoch", Json::Int(s.epoch as i64)),
        ("gamma_inv", Json::Int(s.plateau.gamma_inv)),
        ("seen", Json::Int(s.plateau.seen as i64)),
        // the pre-first-eval best is -inf, which JSON cannot carry;
        // Float dumps it as null and the parser maps null back
        ("best", Json::Float(s.plateau.best)),
        ("stale", Json::Int(s.plateau.stale as i64)),
        ("reductions", Json::Int(s.plateau.reductions as i64)),
    ])
}

fn state_from_json(j: &Json, path: &str) -> Result<TrainState, String> {
    let int = |key: &str| -> Result<i64, String> {
        j.req(key)
            .map_err(|e| format!("{path}: train_state: {e}"))?
            .as_i64()
            .filter(|&v| v >= 0)
            .ok_or_else(|| {
                format!(
                    "{path}: train_state: '{key}' is not a non-negative \
                     integer"
                )
            })
    };
    let best = match j.req("best")
        .map_err(|e| format!("{path}: train_state: {e}"))?
    {
        Json::Null => f64::NEG_INFINITY,
        v => v.as_f64().ok_or_else(|| {
            format!("{path}: train_state: 'best' is not a number")
        })?,
    };
    Ok(TrainState {
        epoch: int("epoch")? as usize,
        plateau: PlateauState {
            gamma_inv: int("gamma_inv")?,
            seen: int("seen")? as usize,
            best,
            stale: int("stale")? as usize,
            reductions: int("reductions")? as usize,
        },
    })
}

/// Write `bytes` to a temp file next to `path` and rename it into place.
/// A crash mid-write leaves the previous file untouched (rename on the
/// same filesystem is atomic); the temp name carries the pid plus a
/// process-wide sequence number so concurrent writers — other processes
/// *and* other threads of this one — never share a temp file.
///
/// Durability: the temp file is `sync_all`ed before the rename and the
/// parent directory is fsynced after it, so once this returns `Ok` the
/// new content survives a power loss — without the file fsync the
/// rename may hit disk before the data (a crash then leaves a
/// zero-length or stale file under the final name), and without the
/// directory fsync the rename itself may be lost.
fn atomic_write(path: &str, bytes: &[u8]) -> Result<(), String> {
    use std::io::Write;
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let target = std::path::Path::new(path);
    let dir = match target.parent() {
        Some(d) if !d.as_os_str().is_empty() => d,
        _ => std::path::Path::new("."),
    };
    let base = target
        .file_name()
        .and_then(|n| n.to_str())
        .unwrap_or("checkpoint");
    let tmp = dir.join(format!(
        ".{base}.tmp.{}.{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let write_synced = || -> std::io::Result<()> {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()
    };
    if let Err(e) = write_synced() {
        let _ = std::fs::remove_file(&tmp);
        return Err(format!("write {}: {e}", tmp.display()));
    }
    std::fs::rename(&tmp, target).map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        format!("rename {} -> {path}: {e}", tmp.display())
    })?;
    // persist the directory entry; non-unix platforms cannot open a
    // directory for fsync, so the guarantee there is file-data only
    #[cfg(unix)]
    {
        std::fs::File::open(dir)
            .and_then(|d| d.sync_all())
            .map_err(|e| format!("fsync dir {}: {e}", dir.display()))?;
    }
    Ok(())
}

/// Validated view of a checkpoint's header: the spec it was saved from,
/// the declared tensor shapes, and where the payload starts.
struct Header {
    spec_name: String,
    shapes: Vec<Vec<usize>>,
    payload_off: usize,
    state: Option<TrainState>,
    /// W/A/G/E rails recorded at save time; absent key = the full-width
    /// default (pre-rail checkpoints load unchanged).
    bits: BitsPlan,
}

/// Parse and bounds-check everything up to the payload. Every exit on
/// malformed input is an `Err` — no slice index here can panic.
fn parse_header(buf: &[u8], path: &str) -> Result<Header, String> {
    if buf.len() < MAGIC.len() || !buf.starts_with(MAGIC) {
        return Err(format!("{path}: bad magic (not a NITRO1 checkpoint)"));
    }
    let hstart = MAGIC.len() + 4;
    if buf.len() < hstart {
        return Err(format!("{path}: truncated before header length"));
    }
    let len4: [u8; 4] = buf
        .get(MAGIC.len()..hstart)
        .and_then(|s| s.try_into().ok())
        .ok_or_else(|| format!("{path}: truncated before header length"))?;
    let hlen = u32::from_le_bytes(len4) as usize;
    // checked: on 32-bit targets hstart + hlen could wrap and defeat
    // the bound below
    let hend = hstart.checked_add(hlen).ok_or_else(|| {
        format!("{path}: header length {hlen} overflows")
    })?;
    if buf.len() < hend {
        return Err(format!(
            "{path}: header length {hlen} exceeds file size {}",
            buf.len()
        ));
    }
    // nitro-lint: allow(no-panic) buf.len() >= hend checked above
    let header = std::str::from_utf8(&buf[hstart..hend])
        .map_err(|e| format!("{path}: header not UTF-8: {e}"))?;
    let h = Json::parse(header).map_err(|e| format!("{path}: {e}"))?;
    let spec_name = h
        .req("spec")
        .map_err(|e| format!("{path}: {e}"))?
        .as_str()
        .ok_or_else(|| format!("{path}: 'spec' is not a string"))?
        .to_string();
    let shapes = h
        .req("shapes")
        .map_err(|e| format!("{path}: {e}"))?
        .as_array()
        .ok_or_else(|| format!("{path}: 'shapes' is not an array"))?
        .iter()
        .map(|s| s.usize_vec())
        .collect::<Result<Vec<_>, _>>()
        .map_err(|e| format!("{path}: bad shape entry: {e}"))?;
    // optional (plain checkpoints omit it); present-but-malformed is an
    // error — a half-parsed resume state must never silently load
    let state = match h.get("train_state") {
        None => None,
        Some(j) => Some(state_from_json(j, path)?),
    };
    // optional like train_state: absent = default rails; a present but
    // malformed value is an error — loading a low-bit model under the
    // wrong rails would silently change its arithmetic
    let bits = match h.get("bits") {
        None => BitsPlan::default(),
        Some(j) => BitsPlan::from_json(j)
            .map_err(|e| format!("{path}: bits: {e}"))?,
    };
    Ok(Header { spec_name, shapes, payload_off: hend, state, bits })
}

/// Read the `train_state` header of a checkpoint saved by
/// [`save_with_state`]; `Ok(None)` for a plain checkpoint. Only the
/// header is validated — pair with [`load`] to restore the weights.
pub fn load_state(path: &str) -> Result<Option<TrainState>, String> {
    let buf = std::fs::read(path).map_err(|e| format!("read {path}: {e}"))?;
    Ok(parse_header(&buf, path)?.state)
}

/// Fill `net`'s weights from the checkpoint payload, validating every
/// declared shape against the network's and every payload extent against
/// the file size.
fn fill_weights(net: &mut Network, h: &Header, buf: &[u8], path: &str)
                -> Result<(), String> {
    let expected = 2 * net.blocks.len() + 1; // wf+wl per block, head wo
    if h.shapes.len() != expected {
        return Err(format!(
            "{path}: checkpoint declares {} tensors, network has {expected}",
            h.shapes.len()
        ));
    }
    let mut off = h.payload_off;
    let mut idx = 0usize;
    let mut assign = |t: &mut crate::tensor::ITensor| -> Result<(), String> {
        // nitro-lint: allow(no-panic) idx < expected == shapes.len()
        let shape = &h.shapes[idx];
        if shape != &t.shape {
            return Err(format!(
                "{path}: tensor {idx}: shape {shape:?} != expected {:?}",
                t.shape
            ));
        }
        let n = t.data.len();
        let need = n
            .checked_mul(4)
            .and_then(|b| b.checked_add(off))
            .ok_or_else(|| format!("{path}: payload extent overflows"))?;
        if buf.len() < need {
            return Err(format!(
                "{path}: truncated payload at tensor {idx} \
                 (need {need} bytes, have {})",
                buf.len()
            ));
        }
        for v in t.data.iter_mut() {
            let le: [u8; 4] = buf
                .get(off..off + 4)
                .and_then(|s| s.try_into().ok())
                .ok_or_else(|| {
                    format!("{path}: truncated payload at tensor {idx}")
                })?;
            *v = i32::from_le_bytes(le);
            off += 4;
        }
        idx += 1;
        Ok(())
    };
    for blk in &mut net.blocks {
        assign(&mut blk.wf)?;
        assign(&mut blk.wl)?;
    }
    assign(&mut net.head.wo)?;
    if off != buf.len() {
        return Err(format!("{path}: {} trailing bytes", buf.len() - off));
    }
    Ok(())
}

/// Restore weights into an already-constructed network of the same spec.
pub fn load(net: &mut Network, path: &str) -> Result<(), String> {
    let buf = std::fs::read(path).map_err(|e| format!("read {path}: {e}"))?;
    let h = parse_header(&buf, path)?;
    if h.spec_name != net.spec.name {
        return Err(format!(
            "{path}: checkpoint is for '{}', network is '{}'",
            h.spec_name, net.spec.name
        ));
    }
    if h.bits != net.spec.bits {
        return Err(format!(
            "{path}: checkpoint rails {} != network rails {} \
             (rebuild the network with the checkpoint's bits, or use \
             load_network)",
            h.bits.label(),
            net.spec.bits.label()
        ));
    }
    fill_weights(net, &h, &buf, path)
}

/// Construct a [`Network`] from a checkpoint alone: the recorded spec
/// name is resolved against the model zoo and the weights are restored —
/// the serving path, where no pre-built network exists.
pub fn load_network(path: &str) -> Result<Network, String> {
    let buf = std::fs::read(path).map_err(|e| format!("read {path}: {e}"))?;
    let h = parse_header(&buf, path)?;
    let spec = zoo::get(&h.spec_name).ok_or_else(|| {
        format!("{path}: checkpoint spec '{}' is not in the zoo", h.spec_name)
    })?;
    // the header's rails override the zoo default, so a low-bit model
    // serves with the arithmetic it was trained under
    let mut net = Network::new(spec.with_bits(h.bits.clone()), 0);
    fill_weights(&mut net, &h, &buf, path)?;
    Ok(net)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(name);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn roundtrip_exact() {
        let spec = zoo::get("tinycnn").unwrap();
        let net = Network::new(spec.clone(), 77);
        let dir = tmpdir("nitro_ckpt_test");
        let path = dir.join("a.ckpt");
        save(&net, path.to_str().unwrap()).unwrap();
        let mut net2 = Network::new(spec, 78); // different init
        assert_ne!(net.blocks[0].wf, net2.blocks[0].wf);
        load(&mut net2, path.to_str().unwrap()).unwrap();
        for ((_, a), (_, b)) in net.weights().iter().zip(net2.weights()) {
            assert_eq!(a, &b);
        }
    }

    #[test]
    fn save_is_atomic_no_temp_residue() {
        let net = Network::new(zoo::get("tinycnn").unwrap(), 3);
        let dir = tmpdir("nitro_ckpt_atomic");
        let path = dir.join("m.ckpt");
        // overwrite an existing (bogus) file: the final content must be
        // the complete new checkpoint and no temp file may survive
        std::fs::write(&path, b"old garbage").unwrap();
        save(&net, path.to_str().unwrap()).unwrap();
        let mut net2 = Network::new(zoo::get("tinycnn").unwrap(), 4);
        load(&mut net2, path.to_str().unwrap()).unwrap();
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
    }

    #[test]
    fn atomic_write_accepts_bare_filename() {
        // a path with no directory component must not panic in the
        // temp-file derivation (Path::parent is Some("") there)
        let name = format!("nitro-ckpt-bare-{}.ckpt", std::process::id());
        atomic_write(&name, b"x").unwrap();
        assert_eq!(std::fs::read(&name).unwrap(), b"x");
        std::fs::remove_file(&name).unwrap();
    }

    #[test]
    fn load_network_reconstructs_from_recorded_spec() {
        let net = Network::new(zoo::get("tinycnn").unwrap(), 13);
        let dir = tmpdir("nitro_ckpt_loadnet");
        let path = dir.join("n.ckpt");
        save(&net, path.to_str().unwrap()).unwrap();
        let net2 = load_network(path.to_str().unwrap()).unwrap();
        assert_eq!(net2.spec.name, "tinycnn");
        for ((_, a), (_, b)) in net.weights().iter().zip(net2.weights()) {
            assert_eq!(a, &b);
        }
        // round-tripped network must serve bit-identical logits
        let mut rng = crate::util::rng::Pcg32::new(8);
        let mut shape = vec![4];
        shape.extend(&net.spec.input_shape);
        let n: usize = shape.iter().product();
        let x = crate::tensor::ITensor::from_vec(
            &shape,
            (0..n).map(|_| rng.range_i32(-127, 127)).collect(),
        );
        assert_eq!(net.infer(&x), net2.infer(&x));
    }

    #[test]
    fn bits_header_roundtrip_and_geometry_mismatch() {
        use crate::nn::spec::BitwidthCfg;
        let bits = BitsPlan::uniform(BitwidthCfg::uniform(8));
        let spec = zoo::get("tinycnn").unwrap().with_bits(bits.clone());
        let net = Network::new(spec, 11);
        let dir = tmpdir("nitro_ckpt_bits");
        let path = dir.join("b8.ckpt");
        let path_s = path.to_str().unwrap();
        save(&net, path_s).unwrap();
        // load into a matching-rails network: exact roundtrip
        let mut same =
            Network::new(zoo::get("tinycnn").unwrap().with_bits(bits), 12);
        load(&mut same, path_s).unwrap();
        for ((_, a), (_, b)) in net.weights().iter().zip(same.weights()) {
            assert_eq!(a, &b);
        }
        // rail mismatch is a typed error, never a silent truncation
        let mut deflt = Network::new(zoo::get("tinycnn").unwrap(), 12);
        let err = load(&mut deflt, path_s).unwrap_err();
        assert!(err.contains("rails"), "{err}");
        assert!(err.contains("8/8/64/64"), "{err}");
        // load_network adopts the recorded rails
        let served = load_network(path_s).unwrap();
        assert_eq!(served.spec.bits.label(), "8/8/64/64");
        assert_eq!(served.blocks[0].bits.weights, 8);
        let mut rng = crate::util::rng::Pcg32::new(8);
        let mut shape = vec![2];
        shape.extend(&net.spec.input_shape);
        let n: usize = shape.iter().product();
        let x = crate::tensor::ITensor::from_vec(
            &shape,
            (0..n).map(|_| rng.range_i32(-127, 127)).collect(),
        );
        assert_eq!(net.infer(&x), served.infer(&x));
    }

    #[test]
    fn default_bits_omitted_from_header_for_back_compat() {
        // default-rail checkpoints must not grow a "bits" key: readers
        // predating the key would otherwise see files they can't trust
        let net = Network::new(zoo::get("mlp1-mini").unwrap(), 2);
        let dir = tmpdir("nitro_ckpt_bits_compat");
        let path = dir.join("d.ckpt");
        let path_s = path.to_str().unwrap();
        save(&net, path_s).unwrap();
        let buf = std::fs::read(&path).unwrap();
        let hlen = u32::from_le_bytes(
            buf[MAGIC.len()..MAGIC.len() + 4].try_into().unwrap(),
        ) as usize;
        let header =
            std::str::from_utf8(&buf[MAGIC.len() + 4..MAGIC.len() + 4 + hlen])
                .unwrap();
        assert!(!header.contains("\"bits\""), "{header}");
        // and a default network loads it without any rail check firing
        let mut net2 = Network::new(zoo::get("mlp1-mini").unwrap(), 3);
        load(&mut net2, path_s).unwrap();
    }

    #[test]
    fn malformed_bits_header_rejected() {
        use crate::nn::spec::BitwidthCfg;
        let bits = BitsPlan::uniform(BitwidthCfg::uniform(8));
        let spec = zoo::get("mlp1-mini").unwrap().with_bits(bits);
        let net = Network::new(spec, 5);
        let dir = tmpdir("nitro_ckpt_bits_bad");
        let path = dir.join("bad.ckpt");
        let path_s = path.to_str().unwrap();
        save(&net, path_s).unwrap();
        let mut full = std::fs::read(&path).unwrap();
        // corrupt a rail value in place: "weights":8 -> "weights":0 keeps
        // every length intact, and 0 is outside the valid 2..=32 range —
        // only the bits parse can fail
        let pos = full
            .windows(9)
            .position(|w| w == b"\"weights\"")
            .expect("header should contain 'weights'");
        let digit = full[pos + 9..]
            .iter()
            .position(|&b| b.is_ascii_digit())
            .unwrap();
        full[pos + 9 + digit] = b'0';
        std::fs::write(&path, &full).unwrap();
        let err = load_state(path_s).unwrap_err();
        assert!(err.contains("bits"), "{err}");
        let mut net2 = Network::new(zoo::get("mlp1-mini").unwrap(), 6);
        assert!(load(&mut net2, path_s).is_err());
        assert!(load_network(path_s).is_err());
    }

    #[test]
    fn spec_mismatch_rejected() {
        let net = Network::new(zoo::get("tinycnn").unwrap(), 1);
        let dir = tmpdir("nitro_ckpt_test2");
        let path = dir.join("b.ckpt");
        save(&net, path.to_str().unwrap()).unwrap();
        let mut other = Network::new(zoo::get("mlp1-mini").unwrap(), 1);
        let err = load(&mut other, path.to_str().unwrap()).unwrap_err();
        assert!(err.contains("tinycnn"), "{err}");
    }

    #[test]
    fn corrupt_file_rejected() {
        let dir = tmpdir("nitro_ckpt_test3");
        let path = dir.join("c.ckpt");
        std::fs::write(&path, b"garbage").unwrap();
        let mut net = Network::new(zoo::get("tinycnn").unwrap(), 1);
        assert!(load(&mut net, path.to_str().unwrap()).is_err());
    }

    /// Build one valid checkpoint byte buffer for corruption tests.
    fn valid_bytes() -> Vec<u8> {
        let net = Network::new(zoo::get("mlp1-mini").unwrap(), 7);
        let dir = tmpdir("nitro_ckpt_adv_src");
        let path = dir.join("src.ckpt");
        save(&net, path.to_str().unwrap()).unwrap();
        std::fs::read(&path).unwrap()
    }

    fn load_bytes(bytes: &[u8]) -> Result<(), String> {
        // unique file per call: tests run on concurrent threads and
        // same-length corruptions must never share a path
        use std::sync::atomic::{AtomicU64, Ordering};
        static CASE: AtomicU64 = AtomicU64::new(0);
        let dir = tmpdir("nitro_ckpt_adv");
        let path = dir.join(format!(
            "case-{}.ckpt",
            CASE.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&path, bytes).unwrap();
        let mut net = Network::new(zoo::get("mlp1-mini").unwrap(), 1);
        load(&mut net, path.to_str().unwrap())
    }

    #[test]
    fn truncation_at_every_section_boundary_errs() {
        let full = valid_bytes();
        let hlen = u32::from_le_bytes(
            full[MAGIC.len()..MAGIC.len() + 4].try_into().unwrap(),
        ) as usize;
        let payload_off = MAGIC.len() + 4 + hlen;
        assert!(payload_off < full.len(), "test checkpoint has a payload");
        // every boundary: mid-magic, end of magic (no hlen), mid-hlen,
        // end of hlen (no header), mid-header, end of header (no
        // payload), mid-payload, one byte short of complete
        for cut in [
            0,
            3,
            MAGIC.len(),
            MAGIC.len() + 2,
            MAGIC.len() + 4,
            MAGIC.len() + 4 + hlen / 2,
            payload_off,
            payload_off + 2,
            full.len() - 1,
        ] {
            let r = load_bytes(&full[..cut]);
            assert!(r.is_err(), "truncation at byte {cut} must be Err");
        }
    }

    #[test]
    fn every_truncation_point_errs_never_panics() {
        // fuzz-style sweep: *every* prefix of a valid checkpoint must come
        // back as Err, and none may panic (the mlp1-mini file is small
        // enough to sweep byte by byte)
        let full = valid_bytes();
        let dir = tmpdir("nitro_ckpt_sweep");
        let path = dir.join("cut.ckpt");
        let path_s = path.to_str().unwrap().to_string();
        let mut net = Network::new(zoo::get("mlp1-mini").unwrap(), 1);
        for cut in 0..full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            let r = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| load(&mut net, &path_s)),
            );
            assert!(r.is_ok(), "loader panicked at truncation {cut}");
            assert!(r.unwrap().is_err(), "truncation {cut} must be Err");
        }
    }

    #[test]
    fn oversized_header_length_rejected() {
        let mut bytes = valid_bytes();
        // claim a header far past the end of the file
        bytes[MAGIC.len()..MAGIC.len() + 4]
            .copy_from_slice(&u32::MAX.to_le_bytes());
        let err = load_bytes(&bytes).unwrap_err();
        assert!(err.contains("header length"), "{err}");
    }

    #[test]
    fn header_garbage_rejected() {
        let full = valid_bytes();
        let hlen = u32::from_le_bytes(
            full[MAGIC.len()..MAGIC.len() + 4].try_into().unwrap(),
        ) as usize;
        // non-UTF-8 header bytes
        let mut bad = full.clone();
        for b in &mut bad[MAGIC.len() + 4..MAGIC.len() + 4 + hlen] {
            *b = 0xff;
        }
        assert!(load_bytes(&bad).is_err());
        // valid UTF-8, invalid JSON
        let mut bad = full.clone();
        for b in &mut bad[MAGIC.len() + 4..MAGIC.len() + 4 + hlen] {
            *b = b'x';
        }
        assert!(load_bytes(&bad).is_err());
        // valid JSON, wrong keys: rewrite the header in place with
        // same-length padding
        let mut bad = full;
        let filler = format!("{{\"a\":\"{}\"}}", "p".repeat(hlen - 8));
        assert_eq!(filler.len(), hlen);
        bad[MAGIC.len() + 4..MAGIC.len() + 4 + hlen]
            .copy_from_slice(filler.as_bytes());
        let err = load_bytes(&bad).unwrap_err();
        assert!(err.contains("spec"), "{err}");
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut bytes = valid_bytes();
        bytes.extend_from_slice(&[0u8; 7]);
        let err = load_bytes(&bytes).unwrap_err();
        assert!(err.contains("trailing"), "{err}");
    }

    #[test]
    fn train_state_roundtrips_including_neg_infinity_best() {
        use crate::optim::PlateauState;
        let net = Network::new(zoo::get("mlp1-mini").unwrap(), 5);
        let dir = tmpdir("nitro_ckpt_state");
        let path = dir.join("s.ckpt");
        let path_s = path.to_str().unwrap();
        // a plain checkpoint has no state
        save(&net, path_s).unwrap();
        assert_eq!(load_state(path_s).unwrap(), None);
        // exact round-trip of a mid-run state, including a best that is
        // a non-trivial f64 and one that is -inf (pre-first-eval)
        for best in [0.123456789012345, f64::NEG_INFINITY] {
            let state = TrainState {
                epoch: 7,
                plateau: PlateauState {
                    gamma_inv: 1536,
                    seen: 7,
                    best,
                    stale: 2,
                    reductions: 1,
                },
            };
            save_with_state(&net, path_s, &state).unwrap();
            assert_eq!(load_state(path_s).unwrap(), Some(state));
        }
        // a stateful checkpoint stays loadable through the plain paths
        let mut net2 = Network::new(zoo::get("mlp1-mini").unwrap(), 6);
        load(&mut net2, path_s).unwrap();
        let net3 = load_network(path_s).unwrap();
        for ((_, a), (_, b)) in net.weights().iter().zip(net3.weights()) {
            assert_eq!(a, &b);
        }
    }

    #[test]
    fn malformed_train_state_rejected_not_ignored() {
        // a present-but-broken train_state must fail the load: resuming
        // from a half-parsed state would silently fork the run
        let net = Network::new(zoo::get("mlp1-mini").unwrap(), 5);
        let dir = tmpdir("nitro_ckpt_state_bad");
        let path = dir.join("bad.ckpt");
        let path_s = path.to_str().unwrap();
        let state = TrainState {
            epoch: 3,
            plateau: crate::optim::PlateauState {
                gamma_inv: 512,
                seen: 3,
                best: 0.5,
                stale: 0,
                reductions: 0,
            },
        };
        save_with_state(&net, path_s, &state).unwrap();
        let mut full = std::fs::read(&path).unwrap();
        // corrupt the state in place: "epoch" -> "epxch" keeps every
        // length intact so only the train_state parse can fail
        let pos = full
            .windows(5)
            .position(|w| w == b"epoch")
            .expect("header should contain 'epoch'");
        full[pos..pos + 5].copy_from_slice(b"epxch");
        std::fs::write(&path, &full).unwrap();
        let err = load_state(path_s).unwrap_err();
        assert!(err.contains("train_state"), "{err}");
        let mut net2 = Network::new(zoo::get("mlp1-mini").unwrap(), 6);
        assert!(load(&mut net2, path_s).is_err());
    }

    #[test]
    fn save_error_paths_are_clean_errors() {
        let net = Network::new(zoo::get("mlp1-mini").unwrap(), 1);
        // target directory does not exist: create of the temp file fails
        let err = save(&net, "does/not/exist/m.ckpt").unwrap_err();
        assert!(err.contains("does/not/exist"), "{err}");
        // target "directory" is a file: rename (or temp create) fails and
        // the temp file must not survive
        let dir = tmpdir("nitro_ckpt_saveerr");
        let blocker = dir.join("blocker");
        std::fs::write(&blocker, b"file, not dir").unwrap();
        let inside = blocker.join("m.ckpt");
        assert!(save(&net, inside.to_str().unwrap()).is_err());
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
    }

    #[test]
    fn unknown_zoo_spec_rejected_by_load_network() {
        let net = Network::new(zoo::get("mlp1-mini").unwrap(), 1);
        let dir = tmpdir("nitro_ckpt_zoo");
        let path = dir.join("z.ckpt");
        let mut renamed = net;
        renamed.spec.name = "not-a-preset".into();
        save(&renamed, path.to_str().unwrap()).unwrap();
        let err = load_network(path.to_str().unwrap()).unwrap_err();
        assert!(err.contains("not-a-preset"), "{err}");
    }
}
