//! Checkpointing: save/restore integer network weights in a simple
//! self-describing binary container.
//!
//! Format: magic `NITRO1\n`, u32 JSON-header length, JSON header (spec
//! name, tensor names/shapes), then raw little-endian i32 payloads in
//! header order. Integer weights round-trip exactly — which is what makes
//! the paper's "local fine-tuning after deployment" story (App. E.3) work:
//! a checkpoint *is* the deployed model, no quantization step.

use crate::nn::Network;
use crate::util::jsonio::Json;

const MAGIC: &[u8] = b"NITRO1\n";

pub fn save(net: &Network, path: &str) -> Result<(), String> {
    let weights = net.weights();
    let mut names = Vec::new();
    let mut shapes = Vec::new();
    for (i, (kind, t)) in weights.iter().enumerate() {
        names.push(Json::Str(format!("{i}:{kind}")));
        shapes.push(Json::ints(
            &t.shape.iter().map(|&d| d as i64).collect::<Vec<_>>(),
        ));
    }
    let header = Json::obj(vec![
        ("spec", Json::Str(net.spec.name.clone())),
        ("tensors", Json::Array(names)),
        ("shapes", Json::Array(shapes)),
    ])
    .dump();
    let mut buf = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend((header.len() as u32).to_le_bytes());
    buf.extend(header.as_bytes());
    for (_, t) in &weights {
        for &v in &t.data {
            buf.extend(v.to_le_bytes());
        }
    }
    std::fs::write(path, buf).map_err(|e| format!("write {path}: {e}"))
}

/// Restore weights into an already-constructed network of the same spec.
pub fn load(net: &mut Network, path: &str) -> Result<(), String> {
    let buf = std::fs::read(path).map_err(|e| format!("read {path}: {e}"))?;
    if !buf.starts_with(MAGIC) {
        return Err(format!("{path}: bad magic"));
    }
    let hlen = u32::from_le_bytes(
        buf[MAGIC.len()..MAGIC.len() + 4].try_into().unwrap(),
    ) as usize;
    let hstart = MAGIC.len() + 4;
    let header = std::str::from_utf8(&buf[hstart..hstart + hlen])
        .map_err(|e| format!("{path}: {e}"))?;
    let h = Json::parse(header)?;
    let spec_name = h.req("spec")?.as_str().unwrap_or("");
    if spec_name != net.spec.name {
        return Err(format!(
            "{path}: checkpoint is for '{spec_name}', network is '{}'",
            net.spec.name
        ));
    }
    let shapes = h.req("shapes")?.as_array().ok_or("bad shapes")?.to_vec();
    let mut off = hstart + hlen;
    let mut idx = 0;
    let mut assign = |t: &mut crate::tensor::ITensor| -> Result<(), String> {
        let shape = shapes
            .get(idx)
            .ok_or("missing tensor in checkpoint")?
            .usize_vec()?;
        if shape != t.shape {
            return Err(format!(
                "tensor {idx}: shape {shape:?} != expected {:?}",
                t.shape
            ));
        }
        let n = t.data.len();
        if buf.len() < off + 4 * n {
            return Err("truncated payload".into());
        }
        for v in t.data.iter_mut() {
            *v = i32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
            off += 4;
        }
        idx += 1;
        Ok(())
    };
    for blk in &mut net.blocks {
        assign(&mut blk.wf)?;
        assign(&mut blk.wl)?;
    }
    assign(&mut net.head.wo)?;
    if off != buf.len() {
        return Err(format!("{path}: {} trailing bytes", buf.len() - off));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    #[test]
    fn roundtrip_exact() {
        let spec = zoo::get("tinycnn").unwrap();
        let net = Network::new(spec.clone(), 77);
        let dir = std::env::temp_dir().join("nitro_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.ckpt");
        save(&net, path.to_str().unwrap()).unwrap();
        let mut net2 = Network::new(spec, 78); // different init
        assert_ne!(net.blocks[0].wf, net2.blocks[0].wf);
        load(&mut net2, path.to_str().unwrap()).unwrap();
        for ((_, a), (_, b)) in net.weights().iter().zip(net2.weights()) {
            assert_eq!(a, &b);
        }
    }

    #[test]
    fn spec_mismatch_rejected() {
        let net = Network::new(zoo::get("tinycnn").unwrap(), 1);
        let dir = std::env::temp_dir().join("nitro_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("b.ckpt");
        save(&net, path.to_str().unwrap()).unwrap();
        let mut other = Network::new(zoo::get("mlp1-mini").unwrap(), 1);
        let err = load(&mut other, path.to_str().unwrap()).unwrap_err();
        assert!(err.contains("tinycnn"), "{err}");
    }

    #[test]
    fn corrupt_file_rejected() {
        let dir = std::env::temp_dir().join("nitro_ckpt_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        std::fs::write(&path, b"garbage").unwrap();
        let mut net = Network::new(zoo::get("tinycnn").unwrap(), 1);
        assert!(load(&mut net, path.to_str().unwrap()).is_err());
    }
}
