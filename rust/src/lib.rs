//! # nitro-d
//!
//! Reproduction of **NITRO-D: Native Integer-only Training of Deep
//! Convolutional Neural Networks** (Pirillo, Colombo, Roveri, 2024) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: the LES block-parallel training
//!   scheduler, data pipeline, model zoo, experiment drivers, CLI; plus a
//!   bit-exact pure-Rust integer engine (`tensor`, `nn`) and the PJRT
//!   runtime (`runtime`) that executes the JAX/Pallas-lowered artifacts.
//! * **L2** — `python/compile/model.py`: the integer block graphs, AOT-
//!   lowered to HLO text at build time (`make artifacts`).
//! * **L1** — `python/compile/kernels/`: Pallas integer kernels.
//!
//! Integer arithmetic is bit-exact across implementations, so the three
//! layers are cross-checked for *equality*, not closeness — see DESIGN.md.

// Invariant hardening (README "Static analysis & invariants"): `unsafe`
// is confined to three audited sites — tensor/backend.rs SIMD, the
// serve SIGHUP handler, util/par's lifetime erasure — each carrying its
// own `#[allow(unsafe_code)]`; everywhere else it is a compile error,
// and inside `unsafe fn` every unsafe operation needs an explicit block.
#![deny(unsafe_code)]
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod nn;
pub mod optim;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;
