//! Comparison baselines for Tables 1 & 2 (DESIGN.md §Substitutions):
//!
//! * [`fp`] — floating-point trainers on the *same topologies*:
//!   `FP BP` (global backprop, Adam + CrossEntropy — the paper's strongest
//!   column) and `FP LES` (local error signals, float).
//! * [`pocketnn`] — a PocketNN-style native integer-only MLP trained with
//!   Direct Feedback Alignment and pocket (piecewise-linear integer)
//!   activations — the paper's integer-only state-of-the-art baseline
//!   [20].

pub mod fp;
pub mod optim_fp;
pub mod pocketnn;
