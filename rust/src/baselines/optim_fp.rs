//! Float optimizers for the FP baselines (Sgd with momentum, Adam).
//!
//! These live under `baselines/`, not `optim/`: the `optim/` module is an
//! integer-domain surface under the `no-float` lint rule (`nitro lint`),
//! while the float reference trainers deliberately use f32 throughout.

use crate::tensor::FTensor;

/// Float SGD with momentum and L2 decay (FP LES baseline).
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    velocity: Vec<Vec<f32>>,
}

impl Sgd {
    pub fn new(lr: f32, momentum: f32, weight_decay: f32) -> Self {
        Sgd { lr, momentum, weight_decay, velocity: Vec::new() }
    }

    /// Update parameter tensor `idx` (velocity slots are allocated lazily,
    /// call with a stable parameter order).
    pub fn update(&mut self, idx: usize, w: &mut FTensor, grad: &FTensor) {
        while self.velocity.len() <= idx {
            self.velocity.push(Vec::new());
        }
        let v = &mut self.velocity[idx];
        if v.len() != w.data.len() {
            *v = vec![0f32; w.data.len()];
        }
        for ((wv, &gv), vv) in w.data.iter_mut().zip(&grad.data).zip(v.iter_mut())
        {
            let g = gv + self.weight_decay * *wv;
            *vv = self.momentum * *vv + g;
            *wv -= self.lr * *vv;
        }
    }
}

/// Adam (Kingma & Ba) for the FP BP baseline — the optimizer the paper
/// credits for part of the float-vs-integer gap.
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    t: i32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f32) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
        }
    }

    /// Advance the shared timestep — call once per optimizer step, before
    /// the per-parameter updates.
    pub fn tick(&mut self) {
        self.t += 1;
    }

    pub fn update(&mut self, idx: usize, w: &mut FTensor, grad: &FTensor) {
        while self.m.len() <= idx {
            self.m.push(Vec::new());
            self.v.push(Vec::new());
        }
        if self.m[idx].len() != w.data.len() {
            self.m[idx] = vec![0f32; w.data.len()];
            self.v[idx] = vec![0f32; w.data.len()];
        }
        let t = self.t.max(1) as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        let (m, v) = (&mut self.m[idx], &mut self.v[idx]);
        for i in 0..w.data.len() {
            let g = grad.data[i];
            m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * g;
            v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * g * g;
            let mhat = m[i] / bc1;
            let vhat = v[i] / bc2;
            w.data[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn adam_reduces_quadratic() {
        // minimize ||w||^2 from w = (3, -2)
        let mut w = Tensor::from_vec(&[2], vec![3.0f32, -2.0]);
        let mut opt = Adam::new(0.1);
        for _ in 0..200 {
            opt.tick();
            let grad = Tensor::from_vec(&[2], vec![2.0 * w.data[0], 2.0 * w.data[1]]);
            opt.update(0, &mut w, &grad);
        }
        assert!(w.data[0].abs() < 0.05 && w.data[1].abs() < 0.05, "{:?}", w.data);
    }

    #[test]
    fn sgd_momentum_reduces_quadratic() {
        let mut w = Tensor::from_vec(&[1], vec![4.0f32]);
        let mut opt = Sgd::new(0.05, 0.9, 0.0);
        for _ in 0..100 {
            let grad = Tensor::from_vec(&[1], vec![2.0 * w.data[0]]);
            opt.update(0, &mut w, &grad);
        }
        assert!(w.data[0].abs() < 0.1);
    }
}
